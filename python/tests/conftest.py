"""Test-session setup for the Python (JAX/Pallas) layer.

Two things the offline environment needs (mirroring the Rust side's
offline substrates, DESIGN.md §10):

1. ``python/`` on ``sys.path`` so ``from compile...`` imports resolve
   when pytest is invoked from the repo root.
2. A deterministic stand-in for ``hypothesis`` when the real package is
   not installed. The stand-in supports exactly the surface these tests
   use — ``@settings(max_examples=..., deadline=...)``, ``@given(**kw)``
   with ``st.integers(lo, hi)`` / ``st.floats(lo, hi, allow_nan=False)``
   — and runs seeded pseudo-random examples so failures reproduce
   exactly (the same philosophy as Rust's ``util::prop``). When real
   hypothesis is available it is used untouched.
"""

import os
import random
import sys

sys.path.insert(0, os.path.abspath(os.path.join(os.path.dirname(__file__), "..")))

try:  # pragma: no cover - prefer the real thing when present
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    import types

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example(self, rng):
            return self._draw(rng)

    def _integers(min_value, max_value):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    def _floats(min_value, max_value, allow_nan=False, allow_infinity=False):
        del allow_nan, allow_infinity  # the stand-in never draws non-finite
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    def _sampled_from(options):
        options = list(options)
        return _Strategy(lambda rng: options[rng.randrange(len(options))])

    _DEFAULT_MAX_EXAMPLES = 10

    def _given(*arg_strategies, **kw_strategies):
        def decorate(fn):
            # Deliberately *not* functools.wraps: the runner must expose
            # a zero-argument signature, or pytest would treat the
            # property's drawn parameters as fixtures.
            def runner():
                max_examples = getattr(runner, "_qai_max_examples", _DEFAULT_MAX_EXAMPLES)
                for case in range(max_examples):
                    seed = 0xC0FFEE ^ (case * 0x9E3779B9)
                    rng = random.Random(seed)
                    drawn_args = [s.example(rng) for s in arg_strategies]
                    drawn_kwargs = {k: s.example(rng) for k, s in kw_strategies.items()}
                    try:
                        fn(*drawn_args, **drawn_kwargs)
                    except BaseException as err:
                        raise AssertionError(
                            f"property case {case} (seed {seed:#x}) failed with "
                            f"args={drawn_args} kwargs={drawn_kwargs}: {err}"
                        ) from err

            runner.__name__ = fn.__name__
            runner.__doc__ = fn.__doc__
            runner.hypothesis_stand_in = True
            return runner

        return decorate

    def _settings(max_examples=_DEFAULT_MAX_EXAMPLES, deadline=None, **_ignored):
        del deadline

        def decorate(fn):
            fn._qai_max_examples = max_examples
            return fn

        return decorate

    _st = types.ModuleType("hypothesis.strategies")
    _st.integers = _integers
    _st.floats = _floats
    _st.sampled_from = _sampled_from

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.strategies = _st
    _hyp.__offline_stand_in__ = True

    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st
