"""Pallas pre-quantization kernel vs oracle + the error-bound invariant
(Eq. 1 guarantees |d − dq| ≤ ε)."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels.prequant import prequant, BLOCK_ROWS, LANES
from compile.kernels.ref import prequant_ref

CHUNK = BLOCK_ROWS * LANES


def run_both(d, eps):
    d = jnp.asarray(d, jnp.float32)
    e = jnp.asarray(eps, jnp.float32)
    q, dq = prequant(d, e)
    q_ref, dq_ref = prequant_ref(d, e)
    return (np.asarray(q), np.asarray(dq)), (np.asarray(q_ref), np.asarray(dq_ref))


def test_matches_ref():
    rng = np.random.default_rng(0)
    d = rng.uniform(-5, 5, CHUNK).astype(np.float32)
    (q, dq), (q_ref, dq_ref) = run_both(d, 0.01)
    np.testing.assert_array_equal(q, q_ref)
    np.testing.assert_allclose(dq, dq_ref, rtol=1e-7)


def test_error_bound_invariant():
    rng = np.random.default_rng(1)
    d = rng.uniform(-100, 100, CHUNK).astype(np.float32)
    eps = 0.37
    (_, dq), _ = run_both(d, eps)
    err = np.max(np.abs(d - dq))
    assert err <= eps * (1 + 1e-5), err


def test_reconstruction_is_2qeps():
    rng = np.random.default_rng(2)
    d = rng.uniform(-1, 1, CHUNK).astype(np.float32)
    eps = 0.05
    (q, dq), _ = run_both(d, eps)
    np.testing.assert_allclose(dq, q.astype(np.float64) * 2 * eps, rtol=1e-5, atol=1e-7)


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    eps=st.floats(1e-4, 10.0, allow_nan=False),
    scale=st.floats(0.1, 1e4),
)
def test_hypothesis_bound_and_ref(seed, eps, scale):
    rng = np.random.default_rng(seed)
    d = (rng.uniform(-1, 1, CHUNK) * scale).astype(np.float32)
    (q, dq), (q_ref, dq_ref) = run_both(d, eps)
    np.testing.assert_array_equal(q, q_ref)
    np.testing.assert_allclose(dq, dq_ref, rtol=1e-6, atol=1e-7)
    # error bound with f32 slack proportional to the magnitudes involved
    slack = 1e-5 * (scale + np.abs(dq).max())
    assert np.max(np.abs(d - dq)) <= eps * (1 + 1e-5) + slack
