"""Layer-2 model composition + AOT lowering checks: the fused graph is
numerically consistent with its stages, every artifact lowers to valid
HLO text, and VMEM budgets hold."""

import numpy as np
import jax.numpy as jnp

from compile import aot, model


def test_fused_graph_matches_staged():
    rng = np.random.default_rng(0)
    n = aot.FLAT_N
    d = jnp.asarray(rng.uniform(-2, 2, n), jnp.float32)
    dist = jnp.asarray(rng.uniform(0.5, 20, n), jnp.float32)
    dist2 = jnp.asarray(rng.uniform(0.5, 20, n), jnp.float32)
    s = jnp.asarray(rng.integers(-1, 2, n), jnp.float32)
    eps = jnp.float32(0.01)
    eta_eps = jnp.float32(0.009)

    _q, dq = model.prequant(d, eps)
    staged = model.compensate(dq, dist, dist2, s, eta_eps)[0]
    fused = model.prequant_compensate(d, dist, dist2, s, eps, eta_eps)[0]
    np.testing.assert_allclose(np.asarray(fused), np.asarray(staged), rtol=1e-6)


def test_all_artifacts_lower_to_hlo_text():
    for name, fn, specs, vmem in aot.artifacts():
        assert vmem <= aot.VMEM_BUDGET, name
        text = model.lower_to_hlo_text(fn, *specs)
        assert text.startswith("HloModule"), f"{name}: not HLO text"
        assert "ROOT" in text, f"{name}: no root instruction"
        # interpret-mode pallas must not leave custom-calls the CPU
        # plugin cannot run
        assert "mosaic" not in text.lower(), f"{name}: mosaic custom call"


def test_artifact_names_match_rust_contract():
    names = {name for name, *_ in aot.artifacts()}
    assert {
        "idw_65536",
        "prequant_65536",
        "boundary3d_64",
        "boundary2d_256",
        "fused_65536",
    } <= names


def test_flat_kernels_have_zero_padding_waste():
    # bytes-moved / bytes-useful == 1 for full chunks (DESIGN.md §7)
    assert aot.FLAT_N % (64 * 128) == 0
