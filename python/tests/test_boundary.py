"""Pallas boundary/sign stencil vs oracle, plus semantic cases mirrored
from the Rust unit tests (the two implementations must agree)."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.boundary import boundary_sign_2d, boundary_sign_3d
from compile.kernels.ref import boundary_sign_2d_ref, boundary_sign_3d_ref


def run3d(q):
    q = jnp.asarray(q, jnp.int32)
    got = boundary_sign_3d(q)
    want = boundary_sign_3d_ref(q)
    return [np.asarray(x) for x in got], [np.asarray(x) for x in want]


def run2d(q):
    q = jnp.asarray(q, jnp.int32)
    got = boundary_sign_2d(q)
    want = boundary_sign_2d_ref(q)
    return [np.asarray(x) for x in got], [np.asarray(x) for x in want]


def test_uniform_block_has_no_boundary_3d():
    q = np.full((10, 10, 10), 7, np.int32)
    (mask, sign), _ = run3d(q)
    assert mask.sum() == 0
    assert sign.sum() == 0


def test_step_edge_signs_3d():
    q = np.zeros((10, 10, 10), np.int32)
    q[5:, :, :] = 1  # index step along axis 0 between 4 and 5
    (mask, sign), (mask_ref, sign_ref) = run3d(q)
    np.testing.assert_array_equal(mask, mask_ref)
    np.testing.assert_array_equal(sign, sign_ref)
    # interior coordinates shift by the halo: padded 4/5 -> interior 3/4
    assert mask[3, 4, 4] == 1 and sign[3, 4, 4] == 1
    assert mask[4, 4, 4] == 1 and sign[4, 4, 4] == -1
    assert mask[1, 4, 4] == 0


def test_fast_varying_zero_sign_2d():
    q = np.zeros((8, 8), np.int32)
    q[:, 4] = 2
    q[:, 5:] = 4
    (mask, sign), (mask_ref, sign_ref) = run2d(q)
    np.testing.assert_array_equal(mask, mask_ref)
    np.testing.assert_array_equal(sign, sign_ref)
    # column crossing 0->2->4 has central diffs >= 2: signs zeroed there
    assert mask[3, 3] == 1
    assert sign[3, 3] == 0


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), levels=st.integers(2, 6))
def test_hypothesis_random_3d(seed, levels):
    rng = np.random.default_rng(seed)
    q = rng.integers(0, levels, (12, 12, 12)).astype(np.int32)
    (mask, sign), (mask_ref, sign_ref) = run3d(q)
    np.testing.assert_array_equal(mask, mask_ref)
    np.testing.assert_array_equal(sign, sign_ref)
    assert set(np.unique(sign)).issubset({-1, 0, 1})


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_hypothesis_smooth_2d(seed):
    rng = np.random.default_rng(seed)
    # smooth ramp + noise: the realistic index field
    j = np.arange(20)[:, None]
    k = np.arange(20)[None, :]
    q = (0.3 * j + 0.2 * k + rng.uniform(0, 0.5, (20, 20))).astype(np.int32)
    (mask, sign), (mask_ref, sign_ref) = run2d(q)
    np.testing.assert_array_equal(mask, mask_ref)
    np.testing.assert_array_equal(sign, sign_ref)


def test_non_square_rejected():
    with pytest.raises(AssertionError):
        boundary_sign_2d(jnp.zeros((4, 6), jnp.int32))
