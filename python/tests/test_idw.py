"""Pallas IDW kernel vs pure-jnp oracle (+ semantic edge cases)."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.idw import idw_compensate, BLOCK_ROWS, LANES
from compile.kernels.ref import idw_compensate_ref

CHUNK = BLOCK_ROWS * LANES  # 8192, smallest tileable length


def run_both(dq, d1, d2, s, eta_eps):
    dq = jnp.asarray(dq, jnp.float32)
    d1 = jnp.asarray(d1, jnp.float32)
    d2 = jnp.asarray(d2, jnp.float32)
    s = jnp.asarray(s, jnp.float32)
    eta = jnp.asarray(eta_eps, jnp.float32)
    got = idw_compensate(dq, d1, d2, s, eta)
    want = idw_compensate_ref(dq, d1, d2, s, eta)
    return np.asarray(got), np.asarray(want)


def random_case(rng, n):
    dq = rng.uniform(-1.0, 1.0, n).astype(np.float32)
    # distances: mixture of sentinel (-1), zero, and positive values
    pick = rng.integers(0, 4, n)
    dist = rng.uniform(0.5, 50.0, n).astype(np.float32)
    d1 = np.where(pick == 0, -1.0, np.where(pick == 1, 0.0, dist)).astype(np.float32)
    pick2 = rng.integers(0, 4, n)
    d2 = np.where(pick2 == 0, -1.0, np.where(pick2 == 1, 0.0, dist[::-1])).astype(
        np.float32
    )
    s = rng.integers(-1, 2, n).astype(np.float32)
    return dq, d1, d2, s


def test_matches_ref_basic():
    rng = np.random.default_rng(0)
    dq, d1, d2, s = random_case(rng, CHUNK)
    got, want = run_both(dq, d1, d2, s, 0.009)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-7)


def test_matches_ref_large_multiblock():
    rng = np.random.default_rng(1)
    dq, d1, d2, s = random_case(rng, CHUNK * 8)  # 65536 = AOT shape
    got, want = run_both(dq, d1, d2, s, 0.5)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-7)


def test_edge_semantics():
    # [on B1, on B2, no B1 anywhere, no B2 anywhere, equidistant]
    dq = np.zeros(CHUNK, np.float32)
    d1 = np.full(CHUNK, 4.0, np.float32)
    d2 = np.full(CHUNK, 4.0, np.float32)
    s = np.ones(CHUNK, np.float32)
    d1[0], d2[0] = 0.0, 7.0  # on B1 -> w=1
    d1[1], d2[1] = 7.0, 0.0  # on B2 -> w=0
    d1[2], d2[2] = -1.0, 3.0  # no boundary -> w=0
    d1[3], d2[3] = 3.0, -1.0  # no sign-flip -> w=1
    got, want = run_both(dq, d1, d2, s, 1.0)
    np.testing.assert_allclose(got, want, rtol=1e-6)
    assert got[0] == pytest.approx(1.0)
    assert got[1] == pytest.approx(0.0)
    assert got[2] == pytest.approx(0.0)
    assert got[3] == pytest.approx(1.0)
    assert got[4] == pytest.approx(0.5)  # equidistant


def test_zero_sign_is_identity():
    rng = np.random.default_rng(2)
    dq, d1, d2, _ = random_case(rng, CHUNK)
    got, _ = run_both(dq, d1, d2, np.zeros(CHUNK, np.float32), 0.9)
    np.testing.assert_array_equal(got, dq)


def test_compensation_bounded_by_eta_eps():
    rng = np.random.default_rng(3)
    dq, d1, d2, s = random_case(rng, CHUNK)
    eta_eps = 0.0123
    got, _ = run_both(dq, d1, d2, s, eta_eps)
    # f32 addition of dq + c re-rounds at ulp(dq), so allow ~1 ulp of |dq|
    assert np.max(np.abs(got - dq)) <= eta_eps * (1 + 1e-6) + 2e-7


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    blocks=st.integers(1, 4),
    eta_eps=st.floats(1e-6, 10.0, allow_nan=False),
)
def test_hypothesis_matches_ref(seed, blocks, eta_eps):
    rng = np.random.default_rng(seed)
    dq, d1, d2, s = random_case(rng, CHUNK * blocks)
    got, want = run_both(dq, d1, d2, s, eta_eps)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_untileable_length_rejected():
    with pytest.raises(AssertionError):
        idw_compensate(
            jnp.zeros(100), jnp.zeros(100), jnp.zeros(100), jnp.zeros(100),
            jnp.float32(1.0),
        )
