"""Layer-2 JAX graphs: the compensation model composed from the Layer-1
Pallas kernels. These are the functions ``aot.py`` lowers to HLO text
for the Rust runtime; they also run directly under jit for the pytest
cross-checks.

The EDT (steps B/D of Alg. 4) deliberately stays in Rust: Maurer's scan
is data-dependent sequential per line with a variable-length Voronoi
stack — a poor fit for XLA's static dataflow (DESIGN.md §1). The graphs
here are the elementwise/stencil stages that XLA fuses well:

* :func:`compensate` — step E (IDW weight × sign × η·ε, added to data);
* :func:`boundary_sign_3d` / :func:`boundary_sign_2d` — step A on a
  ghost-padded block;
* :func:`prequant` — the quantizer itself (Eq. 1), used by demos;
* :func:`prequant_compensate` — fused quantize→compensate graph showing
  the kernels compose into one XLA program (used by the L2 fusion test).
"""

import jax
import jax.numpy as jnp

from compile.kernels import boundary, idw
from compile.kernels.prequant import prequant as _prequant_kernel


def compensate(dq, d1, d2, s, eta_eps):
    """Step E over flat f32 vectors (lengths fixed at lowering time)."""
    return (idw.idw_compensate(dq, d1, d2, s, eta_eps),)


def boundary_sign_3d(q_padded):
    """Step A over a ghost-padded i32 cube."""
    return boundary.boundary_sign_3d(q_padded)


def boundary_sign_2d(q_padded):
    """Step A over a ghost-padded i32 square."""
    return boundary.boundary_sign_2d(q_padded)


def prequant(d, eps):
    """Eq. 1 over a flat f32 vector."""
    return _prequant_kernel(d, eps)


def prequant_compensate(d, d1, d2, s, eps, eta_eps):
    """Fused graph: quantize, then compensate the quantized values —
    lowers to a single XLA program (one artifact, zero host round-trips
    between the stages)."""
    _q, dq = _prequant_kernel(d, eps)
    return (idw.idw_compensate(dq, d1, d2, s, eta_eps),)


def lower_to_hlo_text(fn, *example_args):
    """Lower a jitted function to HLO **text** — the interchange format
    the Rust loader requires (jax ≥ 0.5 serialized protos use 64-bit ids
    that xla_extension 0.5.1 rejects; the text parser reassigns ids)."""
    from jax._src.lib import xla_client as xc

    lowered = jax.jit(fn).lower(*example_args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=jnp.float32):
    """ShapeDtypeStruct shorthand for lowering."""
    return jax.ShapeDtypeStruct(shape, dtype)
