"""AOT compiler: lower the Layer-2 graphs to HLO text artifacts consumed
by the Rust runtime (``rust/src/runtime``). Runs once at build time
(`make artifacts`); Python never appears on the request path.

Shape contract (mirrored in ``rust/src/runtime/ops.rs``):

=================  ===========================================  =================
artifact           inputs                                       outputs
=================  ===========================================  =================
idw_65536          dq,d1,d2,s: f32[65536]; eta_eps: f32[]       (f32[65536],)
prequant_65536     d: f32[65536]; eps: f32[]                    (i32, f32)[65536]
boundary3d_64      q: i32[66,66,66]                             (mask, sign) i32[64³]
boundary2d_256     q: i32[258,258]                              (mask, sign) i32[256²]
fused_65536        d,d1,d2,s: f32[65536]; eps,eta_eps: f32[]    (f32[65536],)
=================  ===========================================  =================

Also performs the static Layer-1 performance checks of DESIGN.md §7/§9:
per-kernel VMEM footprint must stay under the 16 MiB budget, and the
flat kernels must keep bytes-moved/bytes-useful at 1.0 (no padding waste
beyond the final chunk).
"""

import argparse
import os

import jax.numpy as jnp

from compile import model

FLAT_N = 65536
TILE3D = 64
TILE2D = 256
VMEM_BUDGET = 16 * 1024 * 1024


def vmem_footprint(n_bufs_f32: int, elems: int) -> int:
    """Bytes resident per grid step for `n_bufs_f32` f32/i32 operands."""
    return n_bufs_f32 * elems * 4


def artifacts():
    f = jnp.float32
    i = jnp.int32
    s = model.spec
    scalar = s((), f)
    flat = s((FLAT_N,), f)
    yield (
        "idw_65536",
        model.compensate,
        (flat, flat, flat, flat, scalar),
        # 5 operands + 1 output tile of 64x128 f32
        vmem_footprint(6, 64 * 128),
    )
    yield (
        "prequant_65536",
        model.prequant,
        (flat, scalar),
        vmem_footprint(4, 64 * 128),
    )
    yield (
        "boundary3d_64",
        model.boundary_sign_3d,
        (s((TILE3D + 2,) * 3, i),),
        vmem_footprint(1, (TILE3D + 2) ** 3) + vmem_footprint(2, TILE3D**3),
    )
    yield (
        "boundary2d_256",
        model.boundary_sign_2d,
        (s((TILE2D + 2,) * 2, i),),
        vmem_footprint(1, (TILE2D + 2) ** 2) + vmem_footprint(2, TILE2D**2),
    )
    yield (
        "fused_65536",
        model.prequant_compensate,
        (flat, flat, flat, flat, scalar, scalar),
        vmem_footprint(7, 64 * 128),
    )


def main():
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default="../artifacts")
    args = parser.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = []
    for name, fn, specs, vmem in artifacts():
        assert vmem <= VMEM_BUDGET, f"{name}: VMEM {vmem} exceeds budget"
        text = model.lower_to_hlo_text(fn, *specs)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as fh:
            fh.write(text)
        print(f"{name}: {len(text)} chars, VMEM/step {vmem / 1024:.0f} KiB")
        manifest.append(f"{name}.hlo.txt")

    # Manifest last: it is the Make stamp, so a partial build re-runs.
    with open(os.path.join(args.out_dir, "manifest.txt"), "w") as fh:
        fh.write("\n".join(manifest) + "\n")
    print(f"wrote {len(manifest)} artifacts to {args.out_dir}")


if __name__ == "__main__":
    main()
