"""Layer-1 Pallas kernels (build-time only; never imported at runtime).

Every kernel is lowered with ``interpret=True``: the CPU PJRT plugin that
executes the AOT artifacts cannot run Mosaic custom-calls, so interpret
mode keeps the lowered HLO backend-portable while the BlockSpec structure
stays TPU-shaped (see DESIGN.md §7 Hardware adaptation).
"""

from compile.kernels.boundary import boundary_sign_2d, boundary_sign_3d
from compile.kernels.idw import idw_compensate
from compile.kernels.prequant import prequant

__all__ = [
    "boundary_sign_2d",
    "boundary_sign_3d",
    "idw_compensate",
    "prequant",
]
