"""Pre-quantization kernel (paper Eq. 1): ``q = round(d / 2ε)``,
``dq = 2qε`` — the single lossy stage of every compressor in the repo.

Note on rounding: XLA's ``round_nearest_even`` (what ``jnp.round``
lowers to) differs from the Rust quantizer's round-half-away exactly on
ties, which have measure zero for real data. The Rust compressors use
the native quantizer for bit-exact streams; this kernel serves the
demo/PJRT path and the L2 model.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
BLOCK_ROWS = 64


def _prequant_kernel(d_ref, eps_ref, q_ref, dq_ref):
    d = d_ref[...]
    eps = eps_ref[0, 0]
    qf = jnp.round(d / (2.0 * eps))
    q_ref[...] = qf.astype(jnp.int32)
    dq_ref[...] = qf * (2.0 * eps)


@functools.partial(jax.jit, static_argnames=())
def prequant(d, eps):
    """Quantize a flat f32 vector; returns ``(q_i32, dq_f32)``."""
    n = d.shape[0]
    assert n % (LANES * BLOCK_ROWS) == 0, f"length {n} not tileable"
    rows = n // LANES
    grid = rows // BLOCK_ROWS
    block = (BLOCK_ROWS, LANES)
    spec = pl.BlockSpec(block, lambda i: (i, 0))
    scalar_spec = pl.BlockSpec((1, 1), lambda i: (0, 0))
    q, dq = pl.pallas_call(
        _prequant_kernel,
        grid=(grid,),
        in_specs=[spec, scalar_spec],
        out_specs=[spec, spec],
        out_shape=[
            jax.ShapeDtypeStruct((rows, LANES), jnp.int32),
            jax.ShapeDtypeStruct((rows, LANES), jnp.float32),
        ],
        interpret=True,
    )(d.reshape(rows, LANES), eps.reshape(1, 1))
    return q.reshape(n), dq.reshape(n)
