"""Quantization-boundary + sign-map stencil (paper Alg. 2, step A).

Operates on a ghost-padded index block (halo 1, filled by the Rust
coordinator — replicated at true domain edges) and emits, for the
interior:

* ``mask`` — 1 where the index differs from any face neighbor;
* ``sign`` — majority vote of ``sgn(q_neighbor − q)`` over differing
  neighbors, zeroed in fast-varying regions where any central-difference
  gradient magnitude reaches 1 (``|fwd − bwd| ≥ 2``).

Semantics mirror ``rust/src/mitigation/boundary.rs`` exactly; the Rust
caller clears global-domain-edge cells afterwards (Alg. 2 loop bounds).

TPU shaping: one grid step owns the whole padded block in VMEM
(66³·i32 ≈ 1.1 MiB in 3D, 258²·i32 ≈ 0.26 MiB in 2D); all six shifted
reads are VMEM slices of that tile — the Pallas/TPU re-think of a CUDA
shared-memory halo (DESIGN.md §7).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _boundary3d_kernel(q_ref, mask_ref, sign_ref):
    q = q_ref[...]
    c = q[1:-1, 1:-1, 1:-1]
    shifts = [
        (q[2:, 1:-1, 1:-1], q[:-2, 1:-1, 1:-1]),
        (q[1:-1, 2:, 1:-1], q[1:-1, :-2, 1:-1]),
        (q[1:-1, 1:-1, 2:], q[1:-1, 1:-1, :-2]),
    ]
    differs = jnp.zeros(c.shape, dtype=jnp.bool_)
    vote = jnp.zeros(c.shape, dtype=jnp.int32)
    fast = jnp.zeros(c.shape, dtype=jnp.bool_)
    for fwd, bwd in shifts:
        differs = differs | (fwd != c) | (bwd != c)
        vote = vote + jnp.where(fwd != c, jnp.sign(fwd - c), 0)
        vote = vote + jnp.where(bwd != c, jnp.sign(bwd - c), 0)
        fast = fast | (jnp.abs(fwd - bwd) >= 2)
    mask_ref[...] = differs.astype(jnp.int32)
    sign_ref[...] = jnp.where(differs & ~fast, jnp.sign(vote), 0).astype(jnp.int32)


def _boundary2d_kernel(q_ref, mask_ref, sign_ref):
    q = q_ref[...]
    c = q[1:-1, 1:-1]
    shifts = [
        (q[2:, 1:-1], q[:-2, 1:-1]),
        (q[1:-1, 2:], q[1:-1, :-2]),
    ]
    differs = jnp.zeros(c.shape, dtype=jnp.bool_)
    vote = jnp.zeros(c.shape, dtype=jnp.int32)
    fast = jnp.zeros(c.shape, dtype=jnp.bool_)
    for fwd, bwd in shifts:
        differs = differs | (fwd != c) | (bwd != c)
        vote = vote + jnp.where(fwd != c, jnp.sign(fwd - c), 0)
        vote = vote + jnp.where(bwd != c, jnp.sign(bwd - c), 0)
        fast = fast | (jnp.abs(fwd - bwd) >= 2)
    mask_ref[...] = differs.astype(jnp.int32)
    sign_ref[...] = jnp.where(differs & ~fast, jnp.sign(vote), 0).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=())
def boundary_sign_3d(q_padded):
    """3D stencil over an ``i32[(B+2)³]`` padded block → two ``i32[B³]``."""
    p = q_padded.shape[0]
    assert q_padded.shape == (p, p, p), "cubic padded block expected"
    b = p - 2
    return pl.pallas_call(
        _boundary3d_kernel,
        out_shape=[
            jax.ShapeDtypeStruct((b, b, b), jnp.int32),
            jax.ShapeDtypeStruct((b, b, b), jnp.int32),
        ],
        interpret=True,
    )(q_padded)


@functools.partial(jax.jit, static_argnames=())
def boundary_sign_2d(q_padded):
    """2D stencil over an ``i32[(B+2)²]`` padded block → two ``i32[B²]``."""
    p = q_padded.shape[0]
    assert q_padded.shape == (p, p), "square padded block expected"
    b = p - 2
    return pl.pallas_call(
        _boundary2d_kernel,
        out_shape=[
            jax.ShapeDtypeStruct((b, b), jnp.int32),
            jax.ShapeDtypeStruct((b, b), jnp.int32),
        ],
        interpret=True,
    )(q_padded)
