"""Inverse-distance-weighted compensation kernel (paper Alg. 4 step E).

Computes ``out = dq + w(d1, d2) * s * eta_eps`` elementwise, where the
IDW weight follows the Rust reference semantics exactly
(`rust/src/mitigation/interpolate.rs::idw_weight`):

* ``d < 0`` encodes "no boundary anywhere" (the Rust side maps its
  integer INF squared-distances to −1.0 before crossing the PJRT
  boundary, avoiding inf/inf NaNs);
* ``d1 < 0``  → w = 0 (no quantization boundary → no compensation);
* ``d1 == 0`` → w = 1 (on B₁);
* ``d2 < 0``  → w = 1 (no sign-flip boundary: take the boundary value);
* ``d2 == 0`` → w = 0 (on B₂);
* otherwise    w = d2 / (d1 + d2).

TPU shaping: the flat vector is viewed as (rows, 128) lanes and tiled in
blocks of ``BLOCK_ROWS`` rows; with 5 operands resident that is
5·64·128·4 B = 160 KiB of VMEM per grid step — far under the ~16 MiB
budget, leaving room for double-buffering (DESIGN.md §7).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
BLOCK_ROWS = 64


def _idw_kernel(dq_ref, d1_ref, d2_ref, s_ref, eta_ref, out_ref):
    dq = dq_ref[...]
    d1 = d1_ref[...]
    d2 = d2_ref[...]
    s = s_ref[...]
    eta_eps = eta_ref[0, 0]
    interior = d2 / (d1 + d2)
    w = jnp.where(
        d1 < 0.0,
        0.0,
        jnp.where(
            d1 == 0.0,
            1.0,
            jnp.where(d2 < 0.0, 1.0, jnp.where(d2 == 0.0, 0.0, interior)),
        ),
    )
    out_ref[...] = dq + w * s * eta_eps


@functools.partial(jax.jit, static_argnames=())
def idw_compensate(dq, d1, d2, s, eta_eps):
    """Compensate a flat f32 vector. Length must be a multiple of
    ``LANES * BLOCK_ROWS`` (the AOT artifact uses 65536)."""
    n = dq.shape[0]
    assert n % (LANES * BLOCK_ROWS) == 0, f"length {n} not tileable"
    rows = n // LANES
    grid = rows // BLOCK_ROWS
    block = (BLOCK_ROWS, LANES)
    spec = pl.BlockSpec(block, lambda i: (i, 0))
    scalar_spec = pl.BlockSpec((1, 1), lambda i: (0, 0))
    out = pl.pallas_call(
        _idw_kernel,
        grid=(grid,),
        in_specs=[spec, spec, spec, spec, scalar_spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((rows, LANES), jnp.float32),
        interpret=True,
    )(
        dq.reshape(rows, LANES),
        d1.reshape(rows, LANES),
        d2.reshape(rows, LANES),
        s.reshape(rows, LANES),
        eta_eps.reshape(1, 1),
    )
    return out.reshape(n)
