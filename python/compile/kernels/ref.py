"""Pure-jnp oracles for every Pallas kernel — the build-time correctness
signal (pytest asserts allclose between kernel and oracle across shapes
and data regimes, including hypothesis sweeps)."""

import jax.numpy as jnp


def idw_compensate_ref(dq, d1, d2, s, eta_eps):
    """Reference for :func:`compile.kernels.idw.idw_compensate`."""
    interior = jnp.where((d1 > 0) & (d2 > 0), d2 / jnp.maximum(d1 + d2, 1e-30), 0.0)
    w = jnp.where(
        d1 < 0.0,
        0.0,
        jnp.where(
            d1 == 0.0,
            1.0,
            jnp.where(d2 < 0.0, 1.0, jnp.where(d2 == 0.0, 0.0, interior)),
        ),
    )
    return dq + w * s * eta_eps


def prequant_ref(d, eps):
    """Reference for :func:`compile.kernels.prequant.prequant`."""
    qf = jnp.round(d / (2.0 * eps))
    return qf.astype(jnp.int32), qf * (2.0 * eps)


def _boundary_ref(q_padded, ndim):
    if ndim == 3:
        c = q_padded[1:-1, 1:-1, 1:-1]
        shifts = [
            (q_padded[2:, 1:-1, 1:-1], q_padded[:-2, 1:-1, 1:-1]),
            (q_padded[1:-1, 2:, 1:-1], q_padded[1:-1, :-2, 1:-1]),
            (q_padded[1:-1, 1:-1, 2:], q_padded[1:-1, 1:-1, :-2]),
        ]
    else:
        c = q_padded[1:-1, 1:-1]
        shifts = [
            (q_padded[2:, 1:-1], q_padded[:-2, 1:-1]),
            (q_padded[1:-1, 2:], q_padded[1:-1, :-2]),
        ]
    differs = jnp.zeros(c.shape, dtype=bool)
    vote = jnp.zeros(c.shape, dtype=jnp.int32)
    fast = jnp.zeros(c.shape, dtype=bool)
    for fwd, bwd in shifts:
        differs = differs | (fwd != c) | (bwd != c)
        vote = vote + jnp.where(fwd != c, jnp.sign(fwd - c), 0)
        vote = vote + jnp.where(bwd != c, jnp.sign(bwd - c), 0)
        fast = fast | (jnp.abs(fwd - bwd) >= 2)
    mask = differs.astype(jnp.int32)
    sign = jnp.where(differs & ~fast, jnp.sign(vote), 0).astype(jnp.int32)
    return mask, sign


def boundary_sign_3d_ref(q_padded):
    """Reference for :func:`compile.kernels.boundary.boundary_sign_3d`."""
    return _boundary_ref(q_padded, 3)


def boundary_sign_2d_ref(q_padded):
    """Reference for :func:`compile.kernels.boundary.boundary_sign_2d`."""
    return _boundary_ref(q_padded, 2)
