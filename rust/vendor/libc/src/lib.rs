//! Minimal offline stand-in for the `libc` crate.
//!
//! The repo uses two libc facilities: `clock_gettime` with the
//! per-thread / per-process CPU-time clocks (the coordinator's compute
//! attribution and the Fig. 8 inflation metric), and — on Linux only —
//! `sched_setaffinity`/`sched_getcpu` for the thread pool's optional
//! worker pinning (`QAI_POOL_PIN=1`). This shim binds those symbols
//! directly against the platform C library.

#![allow(non_camel_case_types)]

/// Seconds field of [`timespec`].
pub type time_t = i64;
/// Nanoseconds field of [`timespec`].
pub type c_long = i64;
/// C `int`.
pub type c_int = i32;
/// Clock selector for [`clock_gettime`].
pub type clockid_t = c_int;

/// POSIX `struct timespec` (LP64 layout).
#[repr(C)]
#[derive(Debug, Clone, Copy)]
pub struct timespec {
    /// Whole seconds.
    pub tv_sec: time_t,
    /// Nanoseconds in `[0, 1e9)`.
    pub tv_nsec: c_long,
}

/// CPU time consumed by the whole process.
#[cfg(target_os = "linux")]
pub const CLOCK_PROCESS_CPUTIME_ID: clockid_t = 2;
/// CPU time consumed by the calling thread.
#[cfg(target_os = "linux")]
pub const CLOCK_THREAD_CPUTIME_ID: clockid_t = 3;

/// CPU time consumed by the whole process.
#[cfg(target_os = "macos")]
pub const CLOCK_PROCESS_CPUTIME_ID: clockid_t = 12;
/// CPU time consumed by the calling thread.
#[cfg(target_os = "macos")]
pub const CLOCK_THREAD_CPUTIME_ID: clockid_t = 16;

extern "C" {
    /// POSIX `clock_gettime(2)`.
    pub fn clock_gettime(clk_id: clockid_t, tp: *mut timespec) -> c_int;
}

/// Process/thread id for [`sched_setaffinity`] (0 = calling thread).
#[cfg(target_os = "linux")]
pub type pid_t = i32;

/// glibc `cpu_set_t`: a fixed 1024-bit CPU mask (`CPU_SETSIZE` bits,
/// stored as 16 × 64-bit words on LP64).
#[cfg(target_os = "linux")]
#[repr(C)]
#[derive(Debug, Clone, Copy)]
pub struct cpu_set_t {
    /// Mask words, CPU `n` at word `n / 64`, bit `n % 64`.
    pub bits: [u64; 16],
}

#[cfg(target_os = "linux")]
impl cpu_set_t {
    /// All-clear mask (`CPU_ZERO`).
    pub fn zero() -> Self {
        cpu_set_t { bits: [0; 16] }
    }

    /// Set CPU `cpu` in the mask (`CPU_SET`); out-of-range is a no-op.
    pub fn set(&mut self, cpu: usize) {
        if cpu < 1024 {
            self.bits[cpu / 64] |= 1u64 << (cpu % 64);
        }
    }
}

#[cfg(target_os = "linux")]
extern "C" {
    /// Linux `sched_setaffinity(2)`: restrict thread `pid` (0 = self)
    /// to the CPUs set in `mask`.
    pub fn sched_setaffinity(pid: pid_t, cpusetsize: usize, mask: *const cpu_set_t) -> c_int;

    /// Linux `sched_getcpu(3)`: the CPU the calling thread is running
    /// on, or −1 on error.
    pub fn sched_getcpu() -> c_int;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn thread_cpu_clock_ticks_forward() {
        let read = || {
            let mut ts = timespec { tv_sec: 0, tv_nsec: 0 };
            let rc = unsafe { clock_gettime(CLOCK_THREAD_CPUTIME_ID, &mut ts) };
            assert_eq!(rc, 0);
            ts.tv_sec as f64 + ts.tv_nsec as f64 * 1e-9
        };
        let t0 = read();
        // Burn a little CPU so the clock must advance.
        let mut acc = 0u64;
        for i in 0..200_000u64 {
            acc = acc.wrapping_add(i * i);
        }
        std::hint::black_box(acc);
        let t1 = read();
        assert!(t1 >= t0);
    }
}
