//! Minimal offline stand-in for the `libc` crate.
//!
//! The repo uses exactly one libc facility: `clock_gettime` with the
//! per-thread / per-process CPU-time clocks, for the coordinator's
//! compute attribution and the Fig. 8 inflation metric. This shim binds
//! that single symbol directly against the platform C library.

#![allow(non_camel_case_types)]

/// Seconds field of [`timespec`].
pub type time_t = i64;
/// Nanoseconds field of [`timespec`].
pub type c_long = i64;
/// C `int`.
pub type c_int = i32;
/// Clock selector for [`clock_gettime`].
pub type clockid_t = c_int;

/// POSIX `struct timespec` (LP64 layout).
#[repr(C)]
#[derive(Debug, Clone, Copy)]
pub struct timespec {
    /// Whole seconds.
    pub tv_sec: time_t,
    /// Nanoseconds in `[0, 1e9)`.
    pub tv_nsec: c_long,
}

/// CPU time consumed by the whole process.
#[cfg(target_os = "linux")]
pub const CLOCK_PROCESS_CPUTIME_ID: clockid_t = 2;
/// CPU time consumed by the calling thread.
#[cfg(target_os = "linux")]
pub const CLOCK_THREAD_CPUTIME_ID: clockid_t = 3;

/// CPU time consumed by the whole process.
#[cfg(target_os = "macos")]
pub const CLOCK_PROCESS_CPUTIME_ID: clockid_t = 12;
/// CPU time consumed by the calling thread.
#[cfg(target_os = "macos")]
pub const CLOCK_THREAD_CPUTIME_ID: clockid_t = 16;

extern "C" {
    /// POSIX `clock_gettime(2)`.
    pub fn clock_gettime(clk_id: clockid_t, tp: *mut timespec) -> c_int;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn thread_cpu_clock_ticks_forward() {
        let read = || {
            let mut ts = timespec { tv_sec: 0, tv_nsec: 0 };
            let rc = unsafe { clock_gettime(CLOCK_THREAD_CPUTIME_ID, &mut ts) };
            assert_eq!(rc, 0);
            ts.tv_sec as f64 + ts.tv_nsec as f64 * 1e-9
        };
        let t0 = read();
        // Burn a little CPU so the clock must advance.
        let mut acc = 0u64;
        for i in 0..200_000u64 {
            acc = acc.wrapping_add(i * i);
        }
        std::hint::black_box(acc);
        let t1 = read();
        assert!(t1 >= t0);
    }
}
