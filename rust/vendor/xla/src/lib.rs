//! Offline stub of the `xla` crate (PJRT bindings).
//!
//! The real crate links `libxla_extension.so`, which is not present in
//! the offline build environment. This stub provides the exact API
//! surface `crate::runtime` compiles against, with every entry point
//! that would touch PJRT returning [`XlaError`] — so the optional
//! [`Backend::Pjrt`] path fails *gracefully at runtime* (callers report
//! "PJRT unavailable" and tests skip), while the native Rust backend is
//! unaffected. Swapping the real `xla` crate back in requires only a
//! manifest change; no source edits.

use std::fmt;

/// Error type of every stubbed PJRT operation.
#[derive(Debug, Clone)]
pub struct XlaError(String);

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for XlaError {}

/// Result alias matching the real crate's signatures.
pub type Result<T> = std::result::Result<T, XlaError>;

fn unavailable() -> XlaError {
    XlaError(
        "PJRT/XLA support is not built into this binary (offline xla stub) — \
         use the native backend"
            .to_string(),
    )
}

/// Element types that can cross the literal boundary.
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for u32 {}
impl NativeType for u8 {}

/// Host-side tensor value (stub: carries no data).
pub struct Literal;

impl Literal {
    /// 1-D literal from a slice.
    pub fn vec1<T: NativeType>(_data: &[T]) -> Literal {
        Literal
    }

    /// Rank-0 literal.
    pub fn scalar<T: NativeType>(_value: T) -> Literal {
        Literal
    }

    /// Reshape to `dims`.
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(unavailable())
    }

    /// Copy out as a typed vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(unavailable())
    }

    /// Destructure a tuple literal.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(unavailable())
    }
}

/// Parsed HLO module (stub).
pub struct HloModuleProto;

impl HloModuleProto {
    /// Parse an HLO text file.
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable())
    }
}

/// XLA computation handle (stub).
pub struct XlaComputation;

impl XlaComputation {
    /// Wrap a parsed module.
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device-side buffer handle (stub).
pub struct PjRtBuffer;

impl PjRtBuffer {
    /// Fetch the buffer to the host.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable())
    }
}

/// Compiled executable handle (stub).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Execute with the given inputs; `[replica][output]` buffers.
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable())
    }
}

/// PJRT client handle (stub — construction always fails).
pub struct PjRtClient;

impl PjRtClient {
    /// Create a CPU client. Always errors in the stub.
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable())
    }

    /// Platform name (unreachable through public construction).
    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    /// Compile a computation. Always errors in the stub.
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_entry_point_reports_unavailable() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        let lit = Literal::vec1(&[1.0f32, 2.0]);
        assert!(lit.reshape(&[2, 1]).is_err());
        assert!(lit.to_vec::<f32>().is_err());
        assert!(Literal::scalar(0i32).to_tuple().is_err());
        let msg = PjRtClient::cpu().unwrap_err().to_string();
        assert!(msg.contains("stub"));
    }
}
