//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The build environment has no registry access (DESIGN.md §10), so this
//! vendored shim implements exactly the surface the repo uses:
//!
//! * [`Error`] / [`Result`] — a string-chain error type;
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`;
//! * the [`anyhow!`], [`bail!`] and [`ensure!`] macros.
//!
//! Divergence from upstream is deliberate and small: the error is a
//! rendered message chain rather than a boxed `dyn Error` (no downcast
//! support, which nothing here uses), and `Display` prints the full
//! `outer: inner` chain in both `{}` and `{:#}` forms so context is
//! never silently dropped.

use std::fmt;

/// A rendered error-message chain (outermost context first).
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { msg: message.to_string() }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(self, context: C) -> Self {
        Error { msg: format!("{context}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Upstream-compatible: any std error converts via `?`. `Error` itself
// deliberately does not implement `std::error::Error`, so this blanket
// impl cannot collide with the reflexive `From<Error> for Error`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Error::msg(e)
    }
}

/// `anyhow`-style result alias.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to fallible values.
pub trait Context<T> {
    /// Wrap the error with `context`.
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;

    /// Wrap the error with lazily-built context.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{context}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Build an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with a formatted [`Error`] unless `cond` holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read("/definitely/not/a/file")?;
        Ok(())
    }

    #[test]
    fn context_chains_render_outer_first() {
        let e: Result<()> = Err(anyhow!("root cause"));
        let e = e.context("mid").unwrap_err().context("outer");
        assert_eq!(e.to_string(), "outer: mid: root cause");
        assert_eq!(format!("{e:#}"), "outer: mid: root cause");
    }

    #[test]
    fn std_errors_convert_through_question_mark() {
        let e = io_fail().unwrap_err();
        assert!(!e.to_string().is_empty());
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing flag").unwrap_err();
        assert_eq!(e.to_string(), "missing flag");
        let v = Some(7u32).with_context(|| "unused").unwrap();
        assert_eq!(v, 7);
    }

    #[test]
    fn ensure_and_bail() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 3 {
                bail!("three is right out");
            }
            Ok(x)
        }
        assert_eq!(f(2).unwrap(), 2);
        assert_eq!(f(12).unwrap_err().to_string(), "x too big: 12");
        assert_eq!(f(3).unwrap_err().to_string(), "three is right out");
    }
}
