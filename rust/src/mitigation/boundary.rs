//! Step A: quantization-boundary detection and error-sign estimation
//! (paper Alg. 2, `GetBoundaryAndSignMap`), dimension-generic over the
//! grid's active axes (4-neighborhood in 2D, 6-neighborhood in 3D).
//!
//! A point is a **quantization boundary** if its quantization index
//! differs from at least one neighbor. Its error sign follows the
//! characterization of §V: the original value sits near the *top* of its
//! quantization interval (error ≈ +ε) when the index increases toward a
//! neighbor, and near the *bottom* (error ≈ −ε) when it decreases — i.e.
//! the sign of the difference toward the differing neighbor. Where
//! several neighbors differ we take the majority vote, which reduces to
//! the paper's forward-difference rule on monotone transitions. The sign
//! is discarded (set to 0) in fast-varying regions where any
//! central-difference gradient magnitude reaches 1.0, since the
//! smoothness assumption behind the interpolation breaks there.
//!
//! Domain-edge points (coordinate 0 or dim−1 on an active axis) are never
//! marked, matching Alg. 2's loop bounds.

use crate::data::grid::Grid;
use crate::quant::QIndex;
use crate::util::arena::ArenaHandle;
use crate::util::pool::{PoolHandle, UnsafeSlice};

/// Output of step A. With a pooled [`ArenaHandle`] both grids' buffers
/// are arena leases the caller must [`give`](crate::util::arena::Arena::give)
/// back (the pipeline does).
pub struct BoundaryResult {
    /// `B₁`: true at quantization-boundary points.
    pub mask: Grid<bool>,
    /// Sign map at boundary points (−1, 0, +1); 0 elsewhere.
    pub sign: Grid<i8>,
}

/// Detect quantization boundaries and their error signs (parallel
/// regions on the global pool, buffers freshly allocated).
pub fn boundary_and_sign(q: &Grid<QIndex>, threads: usize) -> BoundaryResult {
    boundary_and_sign_on(PoolHandle::Global, ArenaHandle::Fresh, q, threads)
}

/// [`boundary_and_sign`] with its parallel regions confined to `pool`
/// and its full-grid outputs acquired from `arena`.
pub fn boundary_and_sign_on(
    pool: PoolHandle<'_>,
    arena: ArenaHandle<'_>,
    q: &Grid<QIndex>,
    threads: usize,
) -> BoundaryResult {
    let shape = q.shape;
    let mut mask = Grid { shape, data: arena.take_filled(shape.len(), false) };
    let mut sign = Grid { shape, data: arena.take_filled(shape.len(), 0i8) };
    let dims = shape.dims;
    let strides = shape.strides();
    let active: Vec<usize> = shape.active_axes().collect();
    if active.is_empty() {
        return BoundaryResult { mask, sign };
    }

    let qd = &q.data;
    let ms = UnsafeSlice::new(&mut mask.data);
    let ss = UnsafeSlice::new(&mut sign.data);

    // Parallelize over the slowest active axis' slices.
    let par_axis = active[0];
    let n_slices = dims[par_axis];
    pool.for_range(n_slices, threads, 1, |slice| {
        // Interior test per active axis; the parallel axis' coordinate is
        // fixed to `slice`.
        let mut lo = [0usize; 3];
        let mut hi = dims; // exclusive
        for &a in &active {
            lo[a] = 1;
            hi[a] = dims[a] - 1;
        }
        lo[par_axis] = slice.max(lo[par_axis]);
        hi[par_axis] = (slice + 1).min(hi[par_axis]);
        if lo[par_axis] >= hi[par_axis] {
            return; // slice on the domain edge of the parallel axis
        }
        for i in lo[0]..hi[0] {
            for j in lo[1]..hi[1] {
                for k in lo[2]..hi[2] {
                    let idx = shape.idx(i, j, k);
                    let qc = qd[idx];
                    let mut vote = 0i32;
                    let mut differs = false;
                    let mut fast = false;
                    for &a in &active {
                        let fwd = qd[idx + strides[a]];
                        let bwd = qd[idx - strides[a]];
                        if fwd != qc {
                            differs = true;
                            vote += (fwd - qc).signum() as i32;
                        }
                        if bwd != qc {
                            differs = true;
                            vote += (bwd - qc).signum() as i32;
                        }
                        // central-difference gradient ≥ 1.0 ⇔ |fwd−bwd| ≥ 2
                        if (fwd - bwd).abs() >= 2 {
                            fast = true;
                        }
                    }
                    if differs {
                        // SAFETY: slices along par_axis are disjoint.
                        unsafe { ms.write(idx, true) };
                        let s = if fast { 0 } else { vote.signum() as i8 };
                        unsafe { ss.write(idx, s) };
                    }
                }
            }
        }
    });

    BoundaryResult { mask, sign }
}

/// Generic neighbor-differs boundary mask (used by step C to derive the
/// sign-flipping boundary `B₂` from the propagated sign map).
pub fn boundary_mask<T: PartialEq + Copy + Send + Sync>(g: &Grid<T>, threads: usize) -> Grid<bool> {
    boundary_mask_on(PoolHandle::Global, ArenaHandle::Fresh, g, threads)
}

/// [`boundary_mask`] with its parallel regions confined to `pool` and
/// its output mask acquired from `arena`.
pub fn boundary_mask_on<T: PartialEq + Copy + Send + Sync>(
    pool: PoolHandle<'_>,
    arena: ArenaHandle<'_>,
    g: &Grid<T>,
    threads: usize,
) -> Grid<bool> {
    let shape = g.shape;
    let mut mask = Grid { shape, data: arena.take_filled(shape.len(), false) };
    let dims = shape.dims;
    let strides = shape.strides();
    let active: Vec<usize> = shape.active_axes().collect();
    if active.is_empty() {
        return mask;
    }
    let data = &g.data;
    let ms = UnsafeSlice::new(&mut mask.data);
    let par_axis = active[0];
    pool.for_range(dims[par_axis], threads, 1, |slice| {
        let mut lo = [0usize; 3];
        let mut hi = dims;
        for &a in &active {
            lo[a] = 1;
            hi[a] = dims[a] - 1;
        }
        lo[par_axis] = slice.max(lo[par_axis]);
        hi[par_axis] = (slice + 1).min(hi[par_axis]);
        if lo[par_axis] >= hi[par_axis] {
            return;
        }
        for i in lo[0]..hi[0] {
            for j in lo[1]..hi[1] {
                for k in lo[2]..hi[2] {
                    let idx = shape.idx(i, j, k);
                    let c = data[idx];
                    let differs = active
                        .iter()
                        .any(|&a| data[idx + strides[a]] != c || data[idx - strides[a]] != c);
                    if differs {
                        unsafe { ms.write(idx, true) };
                    }
                }
            }
        }
    });
    mask
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::prop_check;

    fn qgrid(vals: Vec<i64>, dims: &[usize]) -> Grid<QIndex> {
        Grid::from_vec(vals, dims)
    }

    #[test]
    fn uniform_grid_has_no_boundary() {
        let q = qgrid(vec![3; 25], &[5, 5]);
        let r = boundary_and_sign(&q, 1);
        assert!(r.mask.data.iter().all(|&b| !b));
        assert!(r.sign.data.iter().all(|&s| s == 0));
    }

    #[test]
    fn step_edge_marks_both_sides_with_opposite_signs() {
        // 1 row, index steps 0→1 between k=4 and k=5 (1D semantics in 2D)
        let mut vals = vec![0i64; 10];
        for v in vals[5..].iter_mut() {
            *v = 1;
        }
        let q = Grid::from_vec(vals, &[10]);
        let r = boundary_and_sign(&q, 1);
        // k=4 (index 0, next is 1): boundary, sign +1 (value near top)
        assert!(r.mask.data[4]);
        assert_eq!(r.sign.data[4], 1);
        // k=5 (index 1, prev is 0): boundary, sign −1
        assert!(r.mask.data[5]);
        assert_eq!(r.sign.data[5], -1);
        // interior non-boundary
        assert!(!r.mask.data[2]);
        // domain edges never marked
        assert!(!r.mask.data[0] && !r.mask.data[9]);
    }

    #[test]
    fn fast_varying_region_gets_zero_sign() {
        // index jumps by 2 within one step → central gradient ≥ 1 → sign 0
        let vals = vec![0i64, 0, 2, 4, 4, 4];
        let q = Grid::from_vec(vals, &[6]);
        let r = boundary_and_sign(&q, 1);
        assert!(r.mask.data[2]);
        assert_eq!(r.sign.data[2], 0);
        assert!(r.mask.data[3]);
        assert_eq!(r.sign.data[3], 0);
    }

    #[test]
    fn domain_edges_never_marked_2d() {
        let mut vals = vec![0i64; 36];
        vals[14] = 5; // interior point differs
        let q = qgrid(vals, &[6, 6]);
        let r = boundary_and_sign(&q, 1);
        let shape = r.mask.shape;
        for j in 0..6 {
            for k in 0..6 {
                if j == 0 || j == 5 || k == 0 || k == 5 {
                    assert!(!r.mask.at(0, j, k), "edge ({j},{k}) marked");
                }
            }
        }
        // the differing point and its interior neighbors are marked
        assert!(r.mask.data[14]);
        assert!(r.mask.at(0, 2, 3) || shape.ndim == 2);
    }

    #[test]
    fn boundary_mask_generic_matches_sign_changes() {
        let vals = vec![-1i8, -1, 1, 1, 1, -1, -1];
        let g = Grid::from_vec(vals, &[7]);
        let m = boundary_mask(&g, 1);
        assert_eq!(
            m.data,
            vec![false, true, true, false, true, true, false]
        );
    }

    #[test]
    fn threaded_matches_sequential() {
        prop_check("boundary threads==seq", 30, |g| {
            let d0 = g.usize_in(3, 10);
            let d1 = g.usize_in(3, 10);
            let d2 = g.usize_in(3, 10);
            let n = d0 * d1 * d2;
            let vals: Vec<i64> = (0..n).map(|_| g.usize_in(0, 3) as i64).collect();
            let q = Grid::from_vec(vals, &[d0, d1, d2]);
            let a = boundary_and_sign(&q, 1);
            let b = boundary_and_sign(&q, 4);
            assert_eq!(a.mask.data, b.mask.data);
            assert_eq!(a.sign.data, b.sign.data);
        });
    }

    #[test]
    fn smooth_3d_transition_signs() {
        // 3D field increasing along axis 0: plane of boundary pairs
        let mut q = Grid::<QIndex>::zeros(&[6, 5, 5]);
        for i in 0..6 {
            for j in 0..5 {
                for k in 0..5 {
                    *q.at_mut(i, j, k) = if i >= 3 { 1 } else { 0 };
                }
            }
        }
        let r = boundary_and_sign(&q, 1);
        // interior of plane i=2 (index 0 next to 1): +1
        assert_eq!(r.sign.at(2, 2, 2), 1);
        assert!(r.mask.at(2, 2, 2));
        // interior of plane i=3: −1
        assert_eq!(r.sign.at(3, 2, 2), -1);
    }
}
