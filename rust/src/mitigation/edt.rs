//! Exact Euclidean distance transform with feature transform, after
//! Maurer, Qi & Raghavan (paper Alg. 1, ref [54]).
//!
//! Given a boundary mask, computes for every grid point
//! * the *squared* Euclidean distance to the nearest boundary point
//!   (integer-exact — squared distances on ℤᵏ are integers), and
//! * optionally the flat index of that nearest boundary point (the
//!   *feature transform*), which step C uses to propagate error signs.
//!
//! The algorithm runs dimension by dimension: the first active axis is a
//! two-sweep 1D pass; each further axis runs `VoronoiEDT` per line,
//! building and querying a partial Voronoi diagram of the previous
//! pass's results. Complexity is O(N) total. Lines within a pass are
//! independent, which is exactly where the shared-memory and distributed
//! parallelizations split the work (§VII).

use crate::data::grid::{Grid, Shape};
use crate::util::arena::ArenaHandle;
use crate::util::pool::{PoolHandle, UnsafeSlice};

/// "Infinite" squared distance (no boundary found yet); chosen so that
/// `INF + coordinate²` cannot overflow i64.
pub const INF: i64 = i64::MAX / 4;

/// Result of an EDT pass. With a pooled [`ArenaHandle`] both vectors
/// are arena leases the caller must
/// [`give`](crate::util::arena::Arena::give) back (the pipeline does).
pub struct EdtResult {
    /// Squared distance to the nearest boundary point, per grid point.
    pub dist_sq: Vec<i64>,
    /// Flat index of the nearest boundary point (feature transform),
    /// stored as u32 (grids are < 2³² elements; half the memory traffic
    /// of usize — §Perf iteration 4). `u32::MAX` where no boundary
    /// exists. `None` if not requested.
    pub nearest: Option<Vec<u32>>,
}

impl EdtResult {
    /// Euclidean distance at flat index `i` (∞ → `f32::INFINITY`).
    #[inline]
    pub fn dist(&self, i: usize) -> f32 {
        let d = self.dist_sq[i];
        if d >= INF {
            f32::INFINITY
        } else {
            (d as f64).sqrt() as f32
        }
    }

    /// True if the mask had no boundary points at all.
    pub fn is_unbounded(&self) -> bool {
        self.dist_sq.first().is_some_and(|&d| d >= INF)
    }
}

/// Compute the exact EDT of `mask` (true = boundary/feature point).
/// `with_features` additionally computes the nearest-feature index map.
/// `threads` parallelizes the independent lines of each pass (regions
/// on the global pool, buffers freshly allocated).
pub fn edt(mask: &Grid<bool>, with_features: bool, threads: usize) -> EdtResult {
    edt_on(PoolHandle::Global, ArenaHandle::Fresh, mask, with_features, threads)
}

/// [`edt`] with its parallel line passes confined to `pool` and its
/// full-grid outputs acquired from `arena`.
pub fn edt_on(
    pool: PoolHandle<'_>,
    arena: ArenaHandle<'_>,
    mask: &Grid<bool>,
    with_features: bool,
    threads: usize,
) -> EdtResult {
    let shape = mask.shape;
    let n = shape.len();
    assert!(n <= u32::MAX as usize, "grid too large for u32 feature transform");
    let mut dist_sq = arena.take_filled(n, INF);
    let mut nearest =
        if with_features { arena.take_filled(n, u32::MAX) } else { Vec::new() };
    for (i, &m) in mask.data.iter().enumerate() {
        if m {
            dist_sq[i] = 0;
            if with_features {
                nearest[i] = i as u32;
            }
        }
    }

    let axes: Vec<usize> = shape.active_axes().collect();
    if axes.is_empty() {
        // Single-point grid: distance is 0 if it is a boundary, ∞ otherwise.
        return EdtResult { dist_sq, nearest: with_features.then_some(nearest) };
    }

    // First active axis: 1D two-sweep propagation per line.
    first_pass(pool, &mut dist_sq, &mut nearest, shape, axes[0], with_features, threads);

    // Remaining axes: Voronoi construction/query per line.
    for &axis in &axes[1..] {
        voronoi_pass(pool, &mut dist_sq, &mut nearest, shape, axis, with_features, threads);
    }

    EdtResult { dist_sq, nearest: with_features.then_some(nearest) }
}

/// Enumerate the base flat index of every line along `axis`.
fn line_bases(shape: Shape, axis: usize) -> (usize, usize, usize) {
    // Returns (n_lines, along-axis stride, axis length); bases are derived
    // from the line id by the caller via `line_base`.
    let dims = shape.dims;
    let n_lines = shape.len() / dims[axis];
    (n_lines, shape.strides()[axis], dims[axis])
}

/// Base flat index of line `lid` along `axis`.
#[inline]
fn line_base(shape: Shape, axis: usize, lid: usize) -> usize {
    let dims = shape.dims;
    match axis {
        0 => {
            // lines vary over (j, k)
            let j = lid / dims[2];
            let k = lid % dims[2];
            shape.idx(0, j, k)
        }
        1 => {
            let i = lid / dims[2];
            let k = lid % dims[2];
            shape.idx(i, 0, k)
        }
        _ => {
            let i = lid / dims[1];
            let j = lid % dims[1];
            shape.idx(i, j, 0)
        }
    }
}

/// 1D two-sweep squared-distance propagation along `axis`.
fn first_pass(
    pool: PoolHandle<'_>,
    dist_sq: &mut [i64],
    nearest: &mut [u32],
    shape: Shape,
    axis: usize,
    with_features: bool,
    threads: usize,
) {
    let (n_lines, stride, len) = line_bases(shape, axis);
    let d = UnsafeSlice::new(dist_sq);
    let f = UnsafeSlice::new(nearest);
    // Incremental index walk instead of `base + p·stride` per element
    // (§Perf iteration 5), lines batched like the Voronoi pass.
    pool.for_batches(n_lines, threads, 16, |lines| {
        for lid in lines {
            let base = line_base(shape, axis, lid);
            // forward sweep: distance (in steps) to last feature seen
            let mut last: Option<(usize, u32)> = None; // (position, feature idx)
            let mut idx = base;
            for p in 0..len {
                // SAFETY: lines are disjoint index sets.
                let cur = unsafe { d.read(idx) };
                if cur == 0 {
                    let feat = if with_features { unsafe { f.read(idx) } } else { idx as u32 };
                    last = Some((p, feat));
                } else if let Some((fp, feat)) = last {
                    let dd = (p - fp) as i64;
                    unsafe { d.write(idx, dd * dd) };
                    if with_features {
                        unsafe { f.write(idx, feat) };
                    }
                }
                idx += stride;
            }
            // backward sweep
            let mut last: Option<(usize, u32)> = None;
            let mut idx = base + (len - 1) * stride;
            for p in (0..len).rev() {
                let cur = unsafe { d.read(idx) };
                if cur == 0 {
                    let feat = if with_features { unsafe { f.read(idx) } } else { idx as u32 };
                    last = Some((p, feat));
                } else if let Some((fp, feat)) = last {
                    let dd = (fp - p) as i64;
                    let dsq = dd * dd;
                    if dsq < cur {
                        unsafe { d.write(idx, dsq) };
                        if with_features {
                            unsafe { f.write(idx, feat) };
                        }
                    }
                }
                idx = idx.wrapping_sub(stride);
            }
        }
    });
}

/// One `VoronoiEDT` pass (Alg. 1) along `axis`, lines in parallel.
fn voronoi_pass(
    pool: PoolHandle<'_>,
    dist_sq: &mut [i64],
    nearest: &mut [u32],
    shape: Shape,
    axis: usize,
    with_features: bool,
    threads: usize,
) {
    let (n_lines, stride, len) = line_bases(shape, axis);
    let d = UnsafeSlice::new(dist_sq);
    let f = UnsafeSlice::new(nearest);
    // Batched lines: the Voronoi scratch (site stacks) is allocated once
    // per batch and reused across its lines — §Perf iteration 2.
    pool.for_batches(n_lines, threads, 16, |lines| {
        let mut g: Vec<i64> = Vec::with_capacity(len); // site values f_i
        let mut h: Vec<i64> = Vec::with_capacity(len); // site positions
        let mut ft: Vec<u32> = Vec::with_capacity(len); // site features
        for lid in lines {
            g.clear();
            h.clear();
            ft.clear();
            let base = line_base(shape, axis, lid);

            // Construct the partial Voronoi diagram.
            for p in 0..len {
                let idx = base + p * stride;
                let fi = unsafe { d.read(idx) };
                if fi >= INF {
                    continue;
                }
                let pi = p as i64;
                while g.len() >= 2 {
                    let l = g.len();
                    if remove_edt(g[l - 2], g[l - 1], fi, h[l - 2], h[l - 1], pi) {
                        g.pop();
                        h.pop();
                        ft.pop();
                    } else {
                        break;
                    }
                }
                g.push(fi);
                h.push(pi);
                ft.push(if with_features { unsafe { f.read(idx) } } else { 0 });
            }
            if g.is_empty() {
                continue;
            }

            // Query the diagram. (A precomputed expanded form
            // a[l]−b[l]·p was tried and reverted — §Perf iteration 3:
            // the extra per-line array writes cost more than the saved
            // square on this host.)
            let mut l = 0usize;
            for p in 0..len {
                let pi = p as i64;
                while l + 1 < g.len()
                    && g[l] + (h[l] - pi).pow(2) > g[l + 1] + (h[l + 1] - pi).pow(2)
                {
                    l += 1;
                }
                let idx = base + p * stride;
                unsafe { d.write(idx, g[l] + (h[l] - pi).pow(2)) };
                if with_features {
                    unsafe { f.write(idx, ft[l]) };
                }
            }
        }
    });
}

/// `RemoveEDT` predicate (Alg. 1): drop site `(g_l, h_l)` if the
/// candidate `(f, i)` dominates it against `(g_{l-1}, h_{l-1})`.
#[inline]
fn remove_edt(g_prev: i64, g_last: i64, f: i64, h_prev: i64, h_last: i64, i: i64) -> bool {
    let a = h_last - h_prev;
    let b = i - h_last;
    let c = i - h_prev;
    // c·g_l − b·g_{l−1} − a·f − a·b·c > 0  (all fits i64: g ≤ INF = 2⁶¹,
    // a,b,c ≤ grid extent ≤ 2²⁰ in practice — use i128 to be safe for
    // adversarial extents)
    (c as i128) * (g_last as i128) - (b as i128) * (g_prev as i128) - (a as i128) * (f as i128)
        > (a as i128) * (b as i128) * (c as i128)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::prop_check;
    use crate::util::rng::Rng;

    /// O(N·B) brute-force oracle.
    fn edt_brute(mask: &Grid<bool>) -> Vec<i64> {
        let shape = mask.shape;
        let features: Vec<(usize, usize, usize)> = (0..shape.len())
            .filter(|&i| mask.data[i])
            .map(|i| shape.coords(i))
            .collect();
        (0..shape.len())
            .map(|i| {
                let (x, y, z) = shape.coords(i);
                features
                    .iter()
                    .map(|&(a, b, c)| {
                        let dx = x as i64 - a as i64;
                        let dy = y as i64 - b as i64;
                        let dz = z as i64 - c as i64;
                        dx * dx + dy * dy + dz * dz
                    })
                    .min()
                    .unwrap_or(INF)
            })
            .collect()
    }

    fn random_mask(rng: &mut Rng, dims: &[usize], p: f64) -> Grid<bool> {
        let mut m = Grid::<bool>::zeros(dims);
        for v in m.data.iter_mut() {
            *v = rng.f64() < p;
        }
        m
    }

    #[test]
    fn matches_brute_force_2d() {
        let mut rng = Rng::new(21);
        for _ in 0..20 {
            let m = random_mask(&mut rng, &[13, 17], 0.08);
            let r = edt(&m, false, 1);
            assert_eq!(r.dist_sq, edt_brute(&m));
        }
    }

    #[test]
    fn matches_brute_force_3d() {
        let mut rng = Rng::new(22);
        for _ in 0..10 {
            let m = random_mask(&mut rng, &[7, 9, 11], 0.05);
            let r = edt(&m, false, 1);
            assert_eq!(r.dist_sq, edt_brute(&m));
        }
    }

    #[test]
    fn matches_brute_force_1d() {
        let mut rng = Rng::new(23);
        for _ in 0..20 {
            let m = random_mask(&mut rng, &[37], 0.1);
            let r = edt(&m, false, 1);
            assert_eq!(r.dist_sq, edt_brute(&m));
        }
    }

    #[test]
    fn feature_transform_points_to_a_true_nearest_feature() {
        let mut rng = Rng::new(24);
        for _ in 0..10 {
            let m = random_mask(&mut rng, &[8, 8, 8], 0.07);
            let r = edt(&m, true, 1);
            let feats = r.nearest.as_ref().unwrap();
            if r.is_unbounded() {
                continue;
            }
            let shape = m.shape;
            for i in 0..shape.len() {
                let fi = feats[i] as usize;
                assert!(m.data[fi], "nearest[{i}]={fi} is not a feature");
                let (x, y, z) = shape.coords(i);
                let (a, b, c) = shape.coords(fi);
                let d = (x as i64 - a as i64).pow(2)
                    + (y as i64 - b as i64).pow(2)
                    + (z as i64 - c as i64).pow(2);
                assert_eq!(d, r.dist_sq[i], "feature not at claimed distance");
            }
        }
    }

    #[test]
    fn empty_mask_is_unbounded() {
        let m = Grid::<bool>::zeros(&[5, 5]);
        let r = edt(&m, true, 1);
        assert!(r.is_unbounded());
        assert!(r.dist_sq.iter().all(|&d| d >= INF));
        assert!(r.dist(0).is_infinite());
    }

    #[test]
    fn full_mask_is_all_zero() {
        let m = Grid::from_vec(vec![true; 24], &[4, 6]);
        let r = edt(&m, false, 1);
        assert!(r.dist_sq.iter().all(|&d| d == 0));
    }

    #[test]
    fn threaded_equals_sequential() {
        let mut rng = Rng::new(25);
        let m = random_mask(&mut rng, &[16, 16, 16], 0.03);
        let seq = edt(&m, true, 1);
        let par = edt(&m, true, 4);
        assert_eq!(seq.dist_sq, par.dist_sq);
        // Feature ties may resolve differently between schedules only if
        // the scan order changed — it does not (same per-line order), so:
        assert_eq!(seq.nearest.unwrap(), par.nearest.unwrap());
    }

    #[test]
    fn property_matches_brute_force_random_shapes() {
        prop_check("edt == brute force", 40, |g| {
            let ndim = g.usize_in(1, 3);
            let dims: Vec<usize> = (0..ndim).map(|_| g.usize_in(1, 12)).collect();
            let p = g.f64_in(0.02, 0.3);
            let mut m = Grid::<bool>::zeros(&dims);
            for v in m.data.iter_mut() {
                *v = g.bool_with(p);
            }
            let r = edt(&m, false, 1);
            assert_eq!(r.dist_sq, edt_brute(&m));
        });
    }

    #[test]
    fn single_feature_distances_are_radial() {
        let mut m = Grid::<bool>::zeros(&[9, 9]);
        *m.at_mut(0, 4, 4) = true;
        let r = edt(&m, false, 1);
        let d = |j: usize, k: usize| r.dist_sq[m.shape.idx(0, j, k)];
        assert_eq!(d(4, 4), 0);
        assert_eq!(d(4, 0), 16);
        assert_eq!(d(0, 0), 32);
        assert_eq!(d(3, 2), 5);
    }
}
