//! The paper's contribution: quantization-aware interpolation for
//! artifact mitigation (Algorithms 1–4, §V–§VII-A).
//!
//! Pipeline (Fig. 3):
//!
//! 1. **A** [`boundary`] — find quantization boundaries `B₁` and their
//!    error signs (Alg. 2);
//! 2. **B** [`edt`] — exact EDT to `B₁` → `Dist₁` + nearest-boundary
//!    feature transform `I₁` (Alg. 1, Maurer et al.);
//! 3. **C** [`sign`] — propagate signs from nearest boundaries, derive
//!    the sign-flipping boundary `B₂` (Alg. 3);
//! 4. **D** [`edt`] — second EDT to `B₂` → `Dist₂`;
//! 5. **E** [`interpolate`] — inverse-distance-weighted compensation
//!    `C = k₂/(k₁+k₂) · S · η·ε` added to the decompressed data.
//!
//! [`pipeline`] assembles the steps sequentially or with shared-memory
//! threads (§VII-A) on the persistent pool runtime
//! ([`crate::util::pool`]); [`engine`] is the **one public front door**
//! for running them — a typed [`MitigationRequest`] → [`Engine`]
//! request/response API over sharded [`admission`] queues, with
//! tenant-aware routing and per-tenant quotas. The legacy [`service`]
//! façade and the `mitigate*` free functions survive as deprecated
//! bit-identical wrappers; the distributed version lives in
//! [`crate::coordinator`]. [`tiled`] runs the same pipeline as a
//! streaming tile decomposition with O(tile × lanes) scratch and
//! first-tile latency.

pub mod admission;
pub mod boundary;
pub mod edt;
pub mod engine;
pub mod interpolate;
pub mod pipeline;
pub mod quality;
pub mod service;
pub mod sign;
pub mod tiled;

pub use admission::{
    JobReport, JobTicket, LatencySnapshot, Priority, ServiceStats, SubmitError, SubmitOptions,
};
pub use engine::{
    Engine, EngineBuilder, EngineStats, MitigationRequest, MitigationResponse, ResponseTicket,
    TenantStats,
};
#[allow(deprecated)]
pub use pipeline::{mitigate, mitigate_with_stats, mitigate_with_stats_on};
pub use pipeline::{Backend, MitigationConfig, PipelineStats};
pub use quality::{QualityTarget, TunedParams};
pub use service::{
    render_latency_labeled, render_metrics, render_metrics_labeled, Job, JobResult,
    MitigationService, ServiceConfig, DEFAULT_QUEUE_CAPACITY,
};
pub use tiled::{
    run_tiled_observed, run_tiled_szp, TileDone, TiledConfig, TiledStreamOutcome, DEFAULT_HALO,
    SCRATCH_BYTES_PER_ELEM,
};
