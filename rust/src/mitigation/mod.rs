//! The paper's contribution: quantization-aware interpolation for
//! artifact mitigation (Algorithms 1–4, §V–§VII-A).
//!
//! Pipeline (Fig. 3):
//!
//! 1. **A** [`boundary`] — find quantization boundaries `B₁` and their
//!    error signs (Alg. 2);
//! 2. **B** [`edt`] — exact EDT to `B₁` → `Dist₁` + nearest-boundary
//!    feature transform `I₁` (Alg. 1, Maurer et al.);
//! 3. **C** [`sign`] — propagate signs from nearest boundaries, derive
//!    the sign-flipping boundary `B₂` (Alg. 3);
//! 4. **D** [`edt`] — second EDT to `B₂` → `Dist₂`;
//! 5. **E** [`interpolate`] — inverse-distance-weighted compensation
//!    `C = k₂/(k₁+k₂) · S · η·ε` added to the decompressed data.
//!
//! [`pipeline`] assembles the steps sequentially or with shared-memory
//! threads (§VII-A) on the persistent pool runtime
//! ([`crate::util::pool`]); [`service`] serves many independent fields
//! through the streaming [`admission`] queue onto the same pool (or a
//! confined one — every step accepts a
//! [`PoolHandle`](crate::util::pool::PoolHandle) via its `*_on`
//! variant); the distributed version lives in [`crate::coordinator`].

pub mod admission;
pub mod boundary;
pub mod edt;
pub mod interpolate;
pub mod pipeline;
pub mod service;
pub mod sign;

pub use admission::{JobReport, JobTicket, Priority, ServiceStats, SubmitError, SubmitOptions};
pub use pipeline::{
    mitigate, mitigate_with_stats, mitigate_with_stats_on, Backend, MitigationConfig,
    PipelineStats,
};
pub use service::{
    render_metrics, Job, JobResult, MitigationService, ServiceConfig, DEFAULT_QUEUE_CAPACITY,
};
