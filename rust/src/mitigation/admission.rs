//! Streaming admission for the mitigation service: a bounded two-class
//! submission queue with explicit backpressure, per-job completion
//! tickets, and deadline accounting.
//!
//! The ROADMAP's production scenario is a steady stream of independent
//! fields arriving from many users, not pre-assembled slices. This
//! module replaces the slice-in/vec-out batch front door with the
//! serving-layer primitives that scenario needs:
//!
//! * **Bounded queue + backpressure** — submissions beyond the
//!   configured capacity are rejected
//!   ([`SubmitError::QueueFull`], via
//!   [`try_submit`](crate::mitigation::service::MitigationService::try_submit))
//!   or block until space frees
//!   ([`submit`](crate::mitigation::service::MitigationService::submit),
//!   optionally bounded by a timeout). An unbounded queue would just
//!   move the overload into memory; a bounded one pushes it back to
//!   the caller, where load shedding and retry policy live.
//! * **Priority classes** — [`Priority::Interactive`] jobs are always
//!   dequeued before queued [`Priority::Bulk`] jobs, so latency-bound
//!   traffic overtakes backfill under contention.
//! * **EDF within a class** — inside one priority class, queued jobs
//!   that carry a deadline are dequeued earliest-deadline-first;
//!   deadline-less jobs drain FIFO after every deadline-carrying job
//!   of their class. (ROADMAP follow-up: deadline-aware scheduling
//!   instead of plain FIFO.) Arrival order still breaks ties, so
//!   deadline-free workloads behave exactly as before.
//! * **Completion tickets** — every accepted job yields a [`JobTicket`]
//!   the caller can block on, poll, or wait on with a timeout; the
//!   resolved [`JobReport`] carries the pipeline result plus queue-wait
//!   / execution durations and the deadline verdict.
//! * **Deadline accounting** — a submission may carry a completion
//!   budget; jobs that overrun are flagged in their report and counted
//!   in the [`ServiceStats`] snapshot.
//!
//! A single scheduler thread (spawned lazily on first submission,
//! counted by [`crate::util::pool::os_thread_spawns`]) drains the
//! queue: each dequeued job is handed to the service's
//! [`ThreadPool`](crate::util::pool::ThreadPool) as a detached task,
//! routed through the pool's worker-local deques. Up to `lanes` jobs
//! are admitted in flight — `workers` (= lanes − 1) execute
//! concurrently and one more sits staged so a freed worker starts
//! immediately; while every lane is busy with jobs still queued, the
//! scheduler **helps the pool** run queued region tickets — bounded
//! steps of in-flight jobs, never whole detached jobs — instead of
//! idling (cooperative blocking — see
//! [`ThreadPool::try_help_one`](crate::util::pool::ThreadPool::try_help_one)),
//! and otherwise never executes jobs (except on a single-lane pool,
//! inline in admission order), keeping admission of later interactive
//! jobs responsive whenever a lane is free. Size the pool one lane
//! larger if you need exactly `n` jobs truly concurrent. A
//! job's *internal* steps A–E run on the **same pool**
//! through the [`PoolHandle`](crate::util::pool::PoolHandle) plumbing —
//! a service built with
//! [`with_pool`](crate::mitigation::service::MitigationService::with_pool)
//! confines everything it does, cross-job and intra-job, to that pool
//! (the confinement tests prove the global pool is never touched). On
//! a single-lane pool the scheduler runs jobs inline, strictly in
//! admission order.
//!
//! Execution remains bit-exact: the pipeline is schedule-independent,
//! so a job's output through the queue is identical to a standalone
//! [`mitigate_with_stats`](crate::mitigation::pipeline::mitigate_with_stats)
//! call, whatever the pool, priority, or contention.
//!
//! This layer is consumed through the typed front door,
//! [`crate::mitigation::engine`]:
//!
//! ```
//! use qai::data::synthetic::{generate, DatasetKind};
//! use qai::mitigation::engine::{Engine, MitigationRequest};
//! use qai::quant::{quantize_grid, ErrorBound};
//! use std::time::Duration;
//!
//! let orig = generate(DatasetKind::ClimateLike, &[16, 16], 1);
//! let eb = ErrorBound::relative(1e-2).resolve(&orig.data);
//! let (q, dq) = quantize_grid(&orig, eb);
//!
//! let engine = Engine::builder().build();
//! let request = MitigationRequest::new(dq, q, eb)
//!     .interactive()
//!     .deadline(Duration::from_secs(60));
//! let response = engine.run(request).unwrap();
//! assert!(!response.deadline_missed);
//! assert_eq!(engine.stats().aggregate().completed, 1);
//! ```

#![deny(missing_docs)]

use crate::mitigation::pipeline::run_pipeline;
use crate::mitigation::service::{Job, JobResult};
use crate::util::arena::{Arena, ArenaHandle};
use crate::util::pool::{self, PoolHandle, ThreadPool};
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Scheduling class of a submitted job.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Priority {
    /// Latency-sensitive: dequeued before any queued [`Priority::Bulk`]
    /// job, whatever the arrival order.
    Interactive,
    /// Throughput traffic (the default): drained in FIFO order once no
    /// interactive job is waiting.
    #[default]
    Bulk,
}

/// Per-submission options: scheduling class, completion deadline, and
/// (for blocking submits) how long to wait for queue space.
#[derive(Debug, Clone, Copy, Default)]
pub struct SubmitOptions {
    /// Scheduling class.
    pub priority: Priority,
    /// Completion budget measured from submission. A job whose
    /// queue-wait plus execution exceeds it still completes, but is
    /// flagged in its [`JobReport`] and counted in
    /// [`ServiceStats::deadlines_missed`].
    pub deadline: Option<Duration>,
    /// Upper bound on how long a blocking submit may wait for queue
    /// space (`None` = wait indefinitely). Ignored by `try_submit`.
    pub timeout: Option<Duration>,
}

impl SubmitOptions {
    /// Interactive-class submission with no deadline or timeout.
    pub fn interactive() -> Self {
        SubmitOptions { priority: Priority::Interactive, ..Default::default() }
    }

    /// Bulk-class submission with no deadline or timeout (the default).
    pub fn bulk() -> Self {
        SubmitOptions::default()
    }

    /// Attach a completion deadline.
    pub fn with_deadline(mut self, deadline: Duration) -> Self {
        self.deadline = Some(deadline);
        self
    }

    /// Bound a blocking submit's wait for queue space.
    pub fn with_timeout(mut self, timeout: Duration) -> Self {
        self.timeout = Some(timeout);
        self
    }
}

/// Why a submission was not admitted. Every variant hands the job back
/// so the caller can shed, retry, or reroute it.
pub enum SubmitError {
    /// The bounded queue is at capacity (`try_submit` only; a blocking
    /// submit waits instead).
    QueueFull(Job),
    /// A blocking submit exhausted its [`SubmitOptions::timeout`]
    /// without space freeing.
    Timeout(Job),
    /// The service is shutting down and accepts nothing.
    Shutdown(Job),
    /// The request's tenant is at its concurrent-admission quota
    /// (engine-level admission control; see
    /// [`EngineBuilder::quota`](crate::mitigation::engine::EngineBuilder::quota)).
    /// Resolves as soon as one of the tenant's in-flight jobs finishes.
    QuotaExceeded(Job),
}

impl SubmitError {
    /// Recover the rejected job for a retry.
    pub fn into_job(self) -> Job {
        match self {
            SubmitError::QueueFull(job)
            | SubmitError::Timeout(job)
            | SubmitError::Shutdown(job)
            | SubmitError::QuotaExceeded(job) => job,
        }
    }
}

impl std::fmt::Debug for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        // Deliberately compact: the carried job references full grids.
        f.write_str(match self {
            SubmitError::QueueFull(_) => "QueueFull(..)",
            SubmitError::Timeout(_) => "Timeout(..)",
            SubmitError::Shutdown(_) => "Shutdown(..)",
            SubmitError::QuotaExceeded(_) => "QuotaExceeded(..)",
        })
    }
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            SubmitError::QueueFull(_) => "admission queue is full",
            SubmitError::Timeout(_) => "timed out waiting for admission-queue space",
            SubmitError::Shutdown(_) => "mitigation service is shutting down",
            SubmitError::QuotaExceeded(_) => "per-tenant admission quota exceeded",
        })
    }
}

impl std::error::Error for SubmitError {}

/// Source of the monotonically-assigned request trace ids. Starts at 1
/// so `0` can serve as "no trace yet" in stats snapshots.
static NEXT_TRACE: AtomicU64 = AtomicU64::new(1);

/// Allocate the next trace id. Called once per
/// [`MitigationRequest`](crate::mitigation::engine::MitigationRequest)
/// (and once per legacy `submit`/`try_submit`, which predate the typed
/// request), so a job can be followed across shard, queue, and lane.
pub(crate) fn next_trace_id() -> u64 {
    NEXT_TRACE.fetch_add(1, Ordering::SeqCst)
}

/// Completion record of one admitted job.
#[derive(Debug)]
pub struct JobReport {
    /// Pipeline outcome: the compensated grid plus per-step stats, or
    /// the error (shape mismatch, pipeline failure, captured panic, or
    /// cancellation at shutdown).
    pub result: JobResult,
    /// Global dequeue sequence number of this service, assigned when
    /// the scheduler pops the job — queued interactive jobs therefore
    /// always carry smaller numbers than the bulk jobs they overtook.
    /// `u64::MAX` for jobs cancelled before ever being scheduled.
    pub seq: u64,
    /// Process-wide monotonic trace id assigned at submission, threaded
    /// ticket → report → response so one job can be followed across
    /// shard, queue, and lane (the engine's `last_trace=` metrics
    /// token and `qai serve` failure lines print it).
    pub trace_id: u64,
    /// Class the job was submitted with.
    pub priority: Priority,
    /// Submission → start of pipeline execution.
    pub queue_wait: Duration,
    /// Pipeline execution duration.
    pub exec: Duration,
    /// Deadline the job was submitted with, if any.
    pub deadline: Option<Duration>,
    /// True iff a deadline was set and `queue_wait + exec` exceeded it.
    pub deadline_missed: bool,
}

/// Completion handle for one admitted job.
///
/// The ticket resolves exactly once — when the job completes, fails, or
/// is cancelled by service shutdown — so [`JobTicket::wait`] always
/// returns eventually on a *draining* service. On a paused service
/// nothing runs: `wait` blocks until some other thread resumes the
/// service (or drops it, which cancels the job).
pub struct JobTicket {
    state: Arc<TicketState>,
}

struct TicketState {
    slot: Mutex<Option<JobReport>>,
    done: Condvar,
}

impl JobTicket {
    fn new() -> (JobTicket, Arc<TicketState>) {
        let state = Arc::new(TicketState { slot: Mutex::new(None), done: Condvar::new() });
        (JobTicket { state: state.clone() }, state)
    }

    /// Block until the job's report is available.
    pub fn wait(self) -> JobReport {
        let mut slot = self.state.slot.lock().unwrap();
        loop {
            if let Some(report) = slot.take() {
                return report;
            }
            slot = self.state.done.wait(slot).unwrap();
        }
    }

    /// Non-blocking poll: the report if the job finished, the ticket
    /// back otherwise.
    pub fn try_wait(self) -> Result<JobReport, JobTicket> {
        let taken = self.state.slot.lock().unwrap().take();
        match taken {
            Some(report) => Ok(report),
            None => Err(self),
        }
    }

    /// [`JobTicket::wait`] bounded by `timeout`; the ticket comes back
    /// if the job is still running.
    pub fn wait_timeout(self, timeout: Duration) -> Result<JobReport, JobTicket> {
        let give_up = Instant::now() + timeout;
        let mut slot = self.state.slot.lock().unwrap();
        loop {
            if let Some(report) = slot.take() {
                return Ok(report);
            }
            let now = Instant::now();
            if now >= give_up {
                drop(slot);
                return Err(self);
            }
            slot = self.state.done.wait_timeout(slot, give_up - now).unwrap().0;
        }
    }

    /// True once the report is ready (a subsequent `wait` returns
    /// immediately).
    pub fn is_complete(&self) -> bool {
        self.state.slot.lock().unwrap().is_some()
    }
}

impl std::fmt::Debug for JobTicket {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("JobTicket").field("complete", &self.is_complete()).finish()
    }
}

/// Point-in-time snapshot of a service's admission counters.
///
/// All `u64` fields are monotonic totals since the service was built;
/// `queue_depth` / `running` are instantaneous gauges. Counter values
/// are deterministic functions of the submission history (timings are
/// not), which the stats-determinism tests rely on.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ServiceStats {
    /// Jobs accepted into the queue (rejections not included).
    pub submitted: u64,
    /// `try_submit` calls rejected because the queue was at capacity.
    pub rejected_full: u64,
    /// Blocking submits that gave up after their timeout.
    pub submit_timeouts: u64,
    /// Jobs whose pipeline ran to success.
    pub completed: u64,
    /// Jobs whose pipeline returned an error or panicked.
    pub failed: u64,
    /// Jobs cancelled at shutdown before they ever ran.
    pub cancelled: u64,
    /// Finished (completed or failed) interactive-class jobs.
    pub interactive_done: u64,
    /// Finished (completed or failed) bulk-class jobs.
    pub bulk_done: u64,
    /// Jobs submitted with a deadline.
    pub deadlines_set: u64,
    /// Jobs that finished after their deadline had already passed.
    pub deadlines_missed: u64,
    /// High-water mark of the queued-job count.
    pub max_queue_depth: usize,
    /// Jobs waiting in the queue right now.
    pub queue_depth: usize,
    /// Jobs executing right now.
    pub running: usize,
    /// Total seconds finished jobs spent waiting in the queue.
    pub total_queue_wait_s: f64,
    /// Total seconds finished jobs spent executing.
    pub total_exec_s: f64,
    /// Trace id of the most recently finished (completed or failed)
    /// job, `0` before any job finishes. Trace ids are process-wide
    /// monotonic, so this is an ordering probe, not a counter — it is
    /// excluded from the determinism contract above.
    pub last_trace_id: u64,
}

/// An opaque token attached to a submission by the engine layer. It is
/// dropped exactly when the job leaves the service — on completion,
/// failure, or shutdown cancellation, in each case *before* the
/// ticket resolves, so a client that waited on the ticket can reuse
/// the slot immediately — which the engine uses (via a `Drop` impl) to
/// release the tenant's admission-quota slot. A failed admission never
/// stores the token, so the caller's copy drops immediately and the
/// quota slot frees with it.
pub(crate) type AdmissionLease = Box<dyn std::any::Any + Send>;

/// One queued submission.
struct Pending {
    job: Job,
    /// Trace id (see [`JobReport::trace_id`]).
    trace: u64,
    priority: Priority,
    deadline: Option<Duration>,
    /// Absolute deadline instant (enqueue time + deadline), the EDF
    /// sort key within a priority class. `None` sorts after every
    /// deadline-carrying job.
    deadline_at: Option<Instant>,
    enqueued: Instant,
    ticket: Arc<TicketState>,
    /// Engine-layer quota token; explicitly dropped just before the
    /// job's ticket is fulfilled (or the job is cancelled).
    lease: Option<AdmissionLease>,
}

struct QueueInner {
    interactive: VecDeque<Pending>,
    bulk: VecDeque<Pending>,
    /// Jobs dispatched but not yet finished.
    running: usize,
    paused: bool,
    shutdown: bool,
}

impl QueueInner {
    fn depth(&self) -> usize {
        self.interactive.len() + self.bulk.len()
    }

    /// Dequeue the next job: strict interactive-over-bulk, and
    /// earliest-deadline-first within a class (deadline-less jobs drain
    /// FIFO after all deadline-carrying jobs of their class; arrival
    /// order breaks ties).
    fn pop(&mut self) -> Option<Pending> {
        Self::pop_edf(&mut self.interactive).or_else(|| Self::pop_edf(&mut self.bulk))
    }

    fn pop_edf(queue: &mut VecDeque<Pending>) -> Option<Pending> {
        if queue.len() <= 1 {
            return queue.pop_front();
        }
        // O(depth) scan per dequeue — fine at serving-queue depths
        // (default capacity 256) and free for deadline-less workloads
        // (the first entry wins immediately).
        let mut best = 0usize;
        let mut best_deadline = queue[0].deadline_at;
        for (i, pending) in queue.iter().enumerate().skip(1) {
            if let Some(d) = pending.deadline_at {
                let earlier = match best_deadline {
                    None => true,
                    Some(b) => d < b,
                };
                if earlier {
                    best = i;
                    best_deadline = Some(d);
                }
            }
        }
        queue.remove(best)
    }
}

/// State shared between the service handle, the scheduler thread, and
/// in-flight job tasks. Lock order: `queue` before `stats`, never the
/// reverse.
struct Shared {
    queue: Mutex<QueueInner>,
    /// Wakes the scheduler: job arrival, unpause, slot freed, shutdown.
    work: Condvar,
    /// Wakes blocked submitters: job dequeued, shutdown.
    space: Condvar,
    /// Monotonic counters; the two gauge fields inside stay zero and
    /// are overwritten at snapshot time.
    stats: Mutex<ServiceStats>,
    capacity: usize,
    next_seq: AtomicU64,
    /// Explicit pool, or `None` for the global one (resolved lazily so
    /// an idle service never forces global-pool creation).
    pool: Option<Arc<ThreadPool>>,
    /// Per-service scratch-buffer arena: every job's full-grid
    /// temporaries and output buffer cycle through it, so warm
    /// same-shaped jobs allocate nothing.
    arena: Arena,
}

impl Shared {
    /// The pool this service runs everything on.
    fn thread_pool(&self) -> &ThreadPool {
        self.pool.as_deref().unwrap_or_else(pool::global)
    }
}

/// The admission queue plus its scheduler thread. Owned by the
/// `MitigationService`; dropping it cancels queued jobs (their tickets
/// resolve with an error), waits for in-flight jobs to finish, and
/// joins the scheduler.
pub(crate) struct Admission {
    shared: Arc<Shared>,
    scheduler: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl Admission {
    pub(crate) fn new(
        pool: Option<Arc<ThreadPool>>,
        capacity: usize,
        start_paused: bool,
        arena: Arena,
    ) -> Self {
        let shared = Arc::new(Shared {
            queue: Mutex::new(QueueInner {
                interactive: VecDeque::new(),
                bulk: VecDeque::new(),
                running: 0,
                paused: start_paused,
                shutdown: false,
            }),
            work: Condvar::new(),
            space: Condvar::new(),
            stats: Mutex::new(ServiceStats::default()),
            capacity: capacity.max(1),
            next_seq: AtomicU64::new(0),
            pool,
            arena,
        });
        Admission { shared, scheduler: Mutex::new(None) }
    }

    /// The service's scratch-buffer arena.
    pub(crate) fn arena(&self) -> &Arena {
        &self.shared.arena
    }

    /// Spawn the scheduler thread on first use.
    fn ensure_scheduler(&self) {
        let mut slot = self.scheduler.lock().unwrap();
        if slot.is_none() {
            pool::note_os_thread_spawn();
            let shared = self.shared.clone();
            let handle = std::thread::Builder::new()
                .name("qai-admission".into())
                .spawn(move || scheduler_loop(shared))
                .expect("spawn admission scheduler");
            *slot = Some(handle);
        }
    }

    /// Append an accepted job to its class queue and bump counters.
    /// Caller holds the queue lock and has verified there is space.
    fn enqueue(
        &self,
        q: &mut QueueInner,
        job: Job,
        opts: SubmitOptions,
        lease: Option<AdmissionLease>,
        trace: u64,
    ) -> JobTicket {
        let (ticket, state) = JobTicket::new();
        let enqueued = Instant::now();
        let pending = Pending {
            job,
            trace,
            priority: opts.priority,
            deadline: opts.deadline,
            // checked_add: an absurd deadline (e.g. Duration::MAX) must
            // not panic under the queue lock — an unrepresentable
            // instant just sorts after every finite deadline, like no
            // deadline at all.
            deadline_at: opts.deadline.and_then(|d| enqueued.checked_add(d)),
            enqueued,
            ticket: state,
            lease,
        };
        match opts.priority {
            Priority::Interactive => q.interactive.push_back(pending),
            Priority::Bulk => q.bulk.push_back(pending),
        }
        let depth = q.depth();
        let mut st = self.shared.stats.lock().unwrap();
        st.submitted += 1;
        if opts.deadline.is_some() {
            st.deadlines_set += 1;
        }
        if depth > st.max_queue_depth {
            st.max_queue_depth = depth;
        }
        ticket
    }

    pub(crate) fn try_submit(
        &self,
        job: Job,
        opts: SubmitOptions,
    ) -> Result<JobTicket, SubmitError> {
        self.try_submit_leased(job, opts, None, next_trace_id())
    }

    /// [`Admission::try_submit`] with an engine-layer quota lease and
    /// request trace id. On rejection the lease never enters the queue
    /// and is dropped here, releasing the quota slot immediately.
    pub(crate) fn try_submit_leased(
        &self,
        job: Job,
        opts: SubmitOptions,
        lease: Option<AdmissionLease>,
        trace: u64,
    ) -> Result<JobTicket, SubmitError> {
        let ticket = {
            let mut q = self.shared.queue.lock().unwrap();
            if q.shutdown {
                return Err(SubmitError::Shutdown(job));
            }
            if q.depth() >= self.shared.capacity {
                drop(q);
                self.shared.stats.lock().unwrap().rejected_full += 1;
                return Err(SubmitError::QueueFull(job));
            }
            self.enqueue(&mut q, job, opts, lease, trace)
        };
        self.shared.work.notify_all();
        self.ensure_scheduler();
        Ok(ticket)
    }

    pub(crate) fn submit(&self, job: Job, opts: SubmitOptions) -> Result<JobTicket, SubmitError> {
        self.submit_leased(job, opts, None, next_trace_id())
    }

    /// [`Admission::submit`] with an engine-layer quota lease and
    /// request trace id (see [`Admission::try_submit_leased`]).
    pub(crate) fn submit_leased(
        &self,
        job: Job,
        opts: SubmitOptions,
        lease: Option<AdmissionLease>,
        trace: u64,
    ) -> Result<JobTicket, SubmitError> {
        let give_up = opts.timeout.map(|t| Instant::now() + t);
        let ticket = {
            let mut q = self.shared.queue.lock().unwrap();
            loop {
                if q.shutdown {
                    return Err(SubmitError::Shutdown(job));
                }
                if q.depth() < self.shared.capacity {
                    break;
                }
                match give_up {
                    None => q = self.shared.space.wait(q).unwrap(),
                    Some(deadline) => {
                        let now = Instant::now();
                        if now >= deadline {
                            drop(q);
                            self.shared.stats.lock().unwrap().submit_timeouts += 1;
                            return Err(SubmitError::Timeout(job));
                        }
                        q = self.shared.space.wait_timeout(q, deadline - now).unwrap().0;
                    }
                }
            }
            self.enqueue(&mut q, job, opts, lease, trace)
        };
        self.shared.work.notify_all();
        self.ensure_scheduler();
        Ok(ticket)
    }

    pub(crate) fn pause(&self) {
        self.shared.queue.lock().unwrap().paused = true;
    }

    pub(crate) fn resume(&self) {
        self.shared.queue.lock().unwrap().paused = false;
        self.shared.work.notify_all();
    }

    pub(crate) fn stats(&self) -> ServiceStats {
        // Gauges first (queue → stats lock order); the two reads are
        // not atomic together, which a snapshot can tolerate.
        let (queue_depth, running) = {
            let q = self.shared.queue.lock().unwrap();
            (q.depth(), q.running)
        };
        let mut snapshot = *self.shared.stats.lock().unwrap();
        snapshot.queue_depth = queue_depth;
        snapshot.running = running;
        snapshot
    }
}

impl Drop for Admission {
    fn drop(&mut self) {
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.shutdown = true;
        }
        self.shared.work.notify_all();
        self.shared.space.notify_all();
        if let Some(handle) = self.scheduler.lock().unwrap().take() {
            let _ = handle.join();
        }
        // The scheduler has seen every in-flight job finish, but the
        // finished task closures may still hold clones of `shared` for
        // a few more instructions (and, through it, the pool Arc).
        // Wait for those to release so the final drop of `Shared` —
        // which may drop the pool and join its workers — runs on this
        // thread, never on a pool worker joining itself. No new clones
        // can appear: the scheduler has exited and submissions are
        // rejected with `Shutdown`.
        while Arc::strong_count(&self.shared) > 1 {
            std::thread::yield_now();
        }
    }
}

/// What the scheduler decided to do after inspecting the queue.
enum SchedulerStep {
    /// A concurrency slot was claimed for this job (boxed: `Pending`
    /// dwarfs the other variants).
    Dispatch(Box<Pending>),
    /// Jobs are queued but every lane is busy: lend the scheduler
    /// thread to the pool instead of idling (cooperative blocking).
    Help,
    /// Shutdown observed.
    Exit,
}

/// Drain loop: pop the highest-priority job whenever a concurrency slot
/// is free and hand it to the pool as a detached task (routed through
/// the pool's worker-local deques). While every lane is busy with more
/// jobs still queued, the scheduler *helps* the pool run queued tickets
/// — finishing in-flight jobs faster is the only way a lane frees — and
/// returns to dispatching the moment one does. On shutdown, cancel
/// everything still queued and wait for in-flight jobs so no ticket is
/// ever left unresolved.
fn scheduler_loop(shared: Arc<Shared>) {
    loop {
        let step = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if q.shutdown {
                    break SchedulerStep::Exit;
                }
                if !q.paused && q.depth() > 0 {
                    // Pool resolved lazily: an explicit-pool service
                    // must never touch the global pool, and a
                    // global-pool service only once a job actually
                    // exists.
                    //
                    // Admit up to `lanes` jobs: `workers` can execute
                    // at once, and one more sits staged in the pool
                    // queue so a freed worker starts its next job
                    // without a scheduler round-trip. While a lane is
                    // free the scheduler never executes jobs itself
                    // (except on a single-lane pool) — that keeps
                    // admission of later, possibly interactive, jobs
                    // responsive.
                    if q.running < shared.thread_pool().lanes() {
                        q.running += 1;
                        break SchedulerStep::Dispatch(Box::new(q.pop().expect("depth > 0")));
                    }
                    break SchedulerStep::Help;
                }
                q = shared.work.wait(q).unwrap();
            }
        };
        match step {
            SchedulerStep::Exit => break,
            SchedulerStep::Dispatch(pending) => {
                shared.space.notify_all();
                let seq = shared.next_seq.fetch_add(1, Ordering::SeqCst);
                dispatch_job(&shared, *pending, seq);
            }
            SchedulerStep::Help => {
                // All lanes busy, jobs still queued: run one queued
                // region ticket (a bounded step of an in-flight job —
                // never a whole detached job, which would stall
                // dispatch past the next lane becoming free). When
                // nothing is helpable, park briefly on the work
                // condvar — a finishing job notifies it, so the
                // timeout only bounds how late newly published region
                // tickets are noticed.
                if !shared.thread_pool().try_help_one() {
                    let q = shared.queue.lock().unwrap();
                    if !q.shutdown && q.running >= shared.thread_pool().lanes() {
                        drop(shared.work.wait_timeout(q, Duration::from_millis(5)).unwrap());
                    }
                }
            }
        }
    }

    cancel_queued(&shared);
    let mut q = shared.queue.lock().unwrap();
    while q.running > 0 {
        q = shared.work.wait(q).unwrap();
    }
}

/// Run `pending` as a detached pool task (routed onto a worker-local
/// deque), or inline on a single-lane pool — inline execution there
/// serializes jobs in admission order, which the
/// deterministic-ordering tests rely on.
fn dispatch_job(shared: &Arc<Shared>, pending: Pending, seq: u64) {
    let task_shared = shared.clone();
    let task = move || run_job(task_shared, pending, seq);
    let tp = shared.thread_pool();
    if tp.workers() == 0 {
        task();
    } else {
        tp.submit_task(Box::new(task));
    }
}

/// Execute one job's pipeline on the service pool, resolve its ticket,
/// account stats, and free the concurrency slot.
fn run_job(shared: Arc<Shared>, mut pending: Pending, seq: u64) {
    let start = Instant::now();
    let queue_wait = start.duration_since(pending.enqueued);
    let handle = PoolHandle::Explicit(shared.thread_pool());

    // Error text stays slot-agnostic: the seq lives in the JobReport,
    // and the batch wrapper re-labels errors with its own slot index.
    let job = &pending.job;
    let result: JobResult = if job.dq.shape != job.q.shape {
        Err(anyhow::anyhow!(
            "data shape {:?} != index shape {:?}",
            job.dq.shape.dims,
            job.q.shape.dims
        ))
    } else {
        // A panic below (defensive: the pipeline asserts on internal
        // invariants) must not take down the worker or sibling jobs.
        match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            run_pipeline(
                handle,
                ArenaHandle::Pooled(&shared.arena),
                &job.dq,
                &job.q,
                job.eb,
                &job.cfg,
            )
        })) {
            Ok(result) => result,
            Err(payload) => {
                let msg = payload
                    .downcast_ref::<String>()
                    .cloned()
                    .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                    .unwrap_or_else(|| "<non-string panic>".to_string());
                Err(anyhow::anyhow!("pipeline panicked: {msg}"))
            }
        }
    };

    let exec = start.elapsed();
    let deadline_missed = pending.deadline.is_some_and(|d| queue_wait + exec > d);
    {
        let mut st = shared.stats.lock().unwrap();
        if result.is_ok() {
            st.completed += 1;
        } else {
            st.failed += 1;
        }
        match pending.priority {
            Priority::Interactive => st.interactive_done += 1,
            Priority::Bulk => st.bulk_done += 1,
        }
        if deadline_missed {
            st.deadlines_missed += 1;
        }
        st.total_queue_wait_s += queue_wait.as_secs_f64();
        st.total_exec_s += exec.as_secs_f64();
        st.last_trace_id = pending.trace;
    }
    // Release the engine-layer quota slot *before* resolving the
    // ticket, so a client that waited on it can resubmit immediately
    // without a spurious QuotaExceeded.
    drop(pending.lease.take());
    fulfill(
        &pending.ticket,
        JobReport {
            result,
            seq,
            trace_id: pending.trace,
            priority: pending.priority,
            queue_wait,
            exec,
            deadline: pending.deadline,
            deadline_missed,
        },
    );
    {
        let mut q = shared.queue.lock().unwrap();
        q.running -= 1;
    }
    shared.work.notify_all();
}

/// Resolve every still-queued ticket with a shutdown error.
fn cancel_queued(shared: &Shared) {
    let drained: Vec<Pending> = {
        let mut q = shared.queue.lock().unwrap();
        let mut all: Vec<Pending> = q.interactive.drain(..).collect();
        all.extend(q.bulk.drain(..));
        all
    };
    if drained.is_empty() {
        return;
    }
    shared.stats.lock().unwrap().cancelled += drained.len() as u64;
    for mut p in drained {
        let queue_wait = p.enqueued.elapsed();
        // Quota slot freed before the ticket resolves (see run_job).
        drop(p.lease.take());
        fulfill(
            &p.ticket,
            JobReport {
                result: Err(anyhow::anyhow!("mitigation service shut down before the job ran")),
                seq: u64::MAX,
                trace_id: p.trace,
                priority: p.priority,
                queue_wait,
                exec: Duration::ZERO,
                deadline: p.deadline,
                deadline_missed: p.deadline.is_some_and(|d| queue_wait > d),
            },
        );
    }
    shared.space.notify_all();
}

fn fulfill(ticket: &Arc<TicketState>, report: JobReport) {
    let mut slot = ticket.slot.lock().unwrap();
    *slot = Some(report);
    ticket.done.notify_all();
}
