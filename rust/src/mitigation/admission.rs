//! Streaming admission for the mitigation service: a bounded two-class
//! submission queue with explicit backpressure, per-job completion
//! tickets, and deadline accounting.
//!
//! The ROADMAP's production scenario is a steady stream of independent
//! fields arriving from many users, not pre-assembled slices. This
//! module replaces the slice-in/vec-out batch front door with the
//! serving-layer primitives that scenario needs:
//!
//! * **Bounded queue + backpressure** — submissions beyond the
//!   configured capacity are rejected
//!   ([`SubmitError::QueueFull`], via
//!   [`try_submit`](crate::mitigation::service::MitigationService::try_submit))
//!   or block until space frees
//!   ([`submit`](crate::mitigation::service::MitigationService::submit),
//!   optionally bounded by a timeout). An unbounded queue would just
//!   move the overload into memory; a bounded one pushes it back to
//!   the caller, where load shedding and retry policy live.
//! * **Priority classes** — [`Priority::Interactive`] jobs are always
//!   dequeued before queued [`Priority::Bulk`] jobs, so latency-bound
//!   traffic overtakes backfill under contention.
//! * **EDF within a class** — inside one priority class, queued jobs
//!   that carry a deadline are dequeued earliest-deadline-first;
//!   deadline-less jobs drain FIFO after every deadline-carrying job
//!   of their class. (ROADMAP follow-up: deadline-aware scheduling
//!   instead of plain FIFO.) Arrival order still breaks ties, so
//!   deadline-free workloads behave exactly as before.
//! * **Completion tickets** — every accepted job yields a [`JobTicket`]
//!   the caller can block on, poll, or wait on with a timeout; the
//!   resolved [`JobReport`] carries the pipeline result plus queue-wait
//!   / execution durations and the deadline verdict.
//! * **Deadline accounting** — a submission may carry a completion
//!   budget; jobs that overrun are flagged in their report and counted
//!   in the [`ServiceStats`] snapshot.
//! * **SLO layer** — optional deadline-infeasibility shedding at
//!   admission ([`SubmitError::DeadlineInfeasible`]), driven by an EWMA
//!   service-time estimate per `(tenant, shape)` key: a request whose
//!   deadline provably cannot be met is rejected before it consumes a
//!   lane, which is cheaper than mitigating and missing. Completion
//!   latencies feed per-class and per-tenant log-bucketed histograms
//!   ([`LatencySnapshot`], queue-wait vs service-time split), and an
//!   adaptive mode scales the shard's lane cap: sustained deadline
//!   misses grow it into parked pool capacity, idleness shrinks it.
//!
//! A single scheduler thread (spawned lazily on first submission,
//! counted by [`crate::util::pool::os_thread_spawns`]) drains the
//! queue: each dequeued job is handed to the service's
//! [`ThreadPool`](crate::util::pool::ThreadPool) as a detached task,
//! routed through the pool's worker-local deques. Up to `lanes` jobs
//! are admitted in flight — `workers` (= lanes − 1) execute
//! concurrently and one more sits staged so a freed worker starts
//! immediately; while every lane is busy with jobs still queued, the
//! scheduler **helps the pool** run queued region tickets — bounded
//! steps of in-flight jobs, never whole detached jobs — instead of
//! idling (cooperative blocking — see
//! [`ThreadPool::try_help_one`](crate::util::pool::ThreadPool::try_help_one)),
//! and otherwise never executes jobs (except on a single-lane pool,
//! inline in admission order), keeping admission of later interactive
//! jobs responsive whenever a lane is free. Size the pool one lane
//! larger if you need exactly `n` jobs truly concurrent. A
//! job's *internal* steps A–E run on the **same pool**
//! through the [`PoolHandle`](crate::util::pool::PoolHandle) plumbing —
//! a service built with
//! [`with_pool`](crate::mitigation::service::MitigationService::with_pool)
//! confines everything it does, cross-job and intra-job, to that pool
//! (the confinement tests prove the global pool is never touched). On
//! a single-lane pool the scheduler runs jobs inline, strictly in
//! admission order.
//!
//! Execution remains bit-exact: the pipeline is schedule-independent,
//! so a job's output through the queue is identical to a standalone
//! [`mitigate_with_stats`](crate::mitigation::pipeline::mitigate_with_stats)
//! call, whatever the pool, priority, or contention.
//!
//! This layer is consumed through the typed front door,
//! [`crate::mitigation::engine`]:
//!
//! ```
//! use qai::data::synthetic::{generate, DatasetKind};
//! use qai::mitigation::engine::{Engine, MitigationRequest};
//! use qai::quant::{quantize_grid, ErrorBound};
//! use std::time::Duration;
//!
//! let orig = generate(DatasetKind::ClimateLike, &[16, 16], 1);
//! let eb = ErrorBound::relative(1e-2).resolve(&orig.data);
//! let (q, dq) = quantize_grid(&orig, eb);
//!
//! let engine = Engine::builder().build();
//! let request = MitigationRequest::new(dq, q, eb)
//!     .interactive()
//!     .deadline(Duration::from_secs(60));
//! let response = engine.run(request).unwrap();
//! assert!(!response.deadline_missed);
//! assert_eq!(engine.stats().aggregate().completed, 1);
//! ```

#![deny(missing_docs)]

use crate::data::grid::Grid;
use crate::mitigation::pipeline::{run_pipeline, PipelineStats};
use crate::mitigation::quality::{self, TunedEntry};
use crate::mitigation::service::{Job, JobResult};
use crate::mitigation::tiled::run_tiled;
use crate::util::arena::{Arena, ArenaHandle};
use crate::util::hist::LatencyPair;
use crate::util::pool::{self, PoolHandle, ThreadPool};
use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Scheduling class of a submitted job.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Priority {
    /// Latency-sensitive: dequeued before any queued [`Priority::Bulk`]
    /// job, whatever the arrival order.
    Interactive,
    /// Throughput traffic (the default): drained in FIFO order once no
    /// interactive job is waiting.
    #[default]
    Bulk,
}

/// Per-submission options: scheduling class, completion deadline, and
/// (for blocking submits) how long to wait for queue space.
#[derive(Debug, Clone, Copy, Default)]
pub struct SubmitOptions {
    /// Scheduling class.
    pub priority: Priority,
    /// Completion budget measured from submission. A job whose
    /// queue-wait plus execution exceeds it still completes, but is
    /// flagged in its [`JobReport`] and counted in
    /// [`ServiceStats::deadlines_missed`].
    pub deadline: Option<Duration>,
    /// Upper bound on how long a blocking submit may wait for queue
    /// space (`None` = wait indefinitely). Ignored by `try_submit`.
    pub timeout: Option<Duration>,
}

impl SubmitOptions {
    /// Interactive-class submission with no deadline or timeout.
    pub fn interactive() -> Self {
        SubmitOptions { priority: Priority::Interactive, ..Default::default() }
    }

    /// Bulk-class submission with no deadline or timeout (the default).
    pub fn bulk() -> Self {
        SubmitOptions::default()
    }

    /// Attach a completion deadline.
    pub fn with_deadline(mut self, deadline: Duration) -> Self {
        self.deadline = Some(deadline);
        self
    }

    /// Bound a blocking submit's wait for queue space.
    pub fn with_timeout(mut self, timeout: Duration) -> Self {
        self.timeout = Some(timeout);
        self
    }
}

/// Why a submission was not admitted. Every variant hands the job back
/// so the caller can shed, retry, or reroute it.
pub enum SubmitError {
    /// The bounded queue is at capacity (`try_submit` only; a blocking
    /// submit waits instead).
    QueueFull(Job),
    /// A blocking submit exhausted its [`SubmitOptions::timeout`]
    /// without space freeing.
    Timeout(Job),
    /// The service is shutting down and accepts nothing.
    Shutdown(Job),
    /// The request's tenant has no admission token available — it is at
    /// its concurrent-admission cap, or its token bucket is empty
    /// (engine-level admission control; see
    /// [`EngineBuilder::quota`](crate::mitigation::engine::EngineBuilder::quota)
    /// and
    /// [`EngineBuilder::quota_rate`](crate::mitigation::engine::EngineBuilder::quota_rate)).
    /// Resolves as soon as one of the tenant's in-flight jobs finishes
    /// (cap mode) or the bucket refills (rate mode).
    QuotaExceeded(Job),
    /// Shed at admission: the service's EWMA service-time estimate for
    /// this (tenant, shape) proves the request's deadline cannot be
    /// met even if admitted now. Only produced when shedding is
    /// enabled (see
    /// [`EngineBuilder::shed`](crate::mitigation::engine::EngineBuilder::shed));
    /// the job never enters the queue and never executes.
    DeadlineInfeasible(Job),
}

impl SubmitError {
    /// Recover the rejected job for a retry.
    pub fn into_job(self) -> Job {
        match self {
            SubmitError::QueueFull(job)
            | SubmitError::Timeout(job)
            | SubmitError::Shutdown(job)
            | SubmitError::QuotaExceeded(job)
            | SubmitError::DeadlineInfeasible(job) => job,
        }
    }
}

impl std::fmt::Debug for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        // Deliberately compact: the carried job references full grids.
        f.write_str(match self {
            SubmitError::QueueFull(_) => "QueueFull(..)",
            SubmitError::Timeout(_) => "Timeout(..)",
            SubmitError::Shutdown(_) => "Shutdown(..)",
            SubmitError::QuotaExceeded(_) => "QuotaExceeded(..)",
            SubmitError::DeadlineInfeasible(_) => "DeadlineInfeasible(..)",
        })
    }
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            SubmitError::QueueFull(_) => "admission queue is full",
            SubmitError::Timeout(_) => "timed out waiting for admission-queue space",
            SubmitError::Shutdown(_) => "mitigation service is shutting down",
            SubmitError::QuotaExceeded(_) => "per-tenant admission quota exceeded",
            SubmitError::DeadlineInfeasible(_) => {
                "deadline infeasible: projected completion exceeds the request deadline"
            }
        })
    }
}

impl std::error::Error for SubmitError {}

/// Source of the monotonically-assigned request trace ids. Starts at 1
/// so `0` can serve as "no trace yet" in stats snapshots.
static NEXT_TRACE: AtomicU64 = AtomicU64::new(1);

/// Allocate the next trace id. Called once per
/// [`MitigationRequest`](crate::mitigation::engine::MitigationRequest)
/// (and once per legacy `submit`/`try_submit`, which predate the typed
/// request), so a job can be followed across shard, queue, and lane.
pub(crate) fn next_trace_id() -> u64 {
    NEXT_TRACE.fetch_add(1, Ordering::SeqCst)
}

/// Completion record of one admitted job.
#[derive(Debug)]
pub struct JobReport {
    /// Pipeline outcome: the compensated grid plus per-step stats, or
    /// the error (shape mismatch, pipeline failure, captured panic, or
    /// cancellation at shutdown).
    pub result: JobResult,
    /// Global dequeue sequence number of this service, assigned when
    /// the scheduler pops the job — queued interactive jobs therefore
    /// always carry smaller numbers than the bulk jobs they overtook.
    /// `u64::MAX` for jobs cancelled before ever being scheduled.
    pub seq: u64,
    /// Process-wide monotonic trace id assigned at submission, threaded
    /// ticket → report → response so one job can be followed across
    /// shard, queue, and lane (the engine's `last_trace=` metrics
    /// token and `qai serve` failure lines print it).
    pub trace_id: u64,
    /// Class the job was submitted with.
    pub priority: Priority,
    /// Submission → start of pipeline execution.
    pub queue_wait: Duration,
    /// Pipeline execution duration.
    pub exec: Duration,
    /// Deadline the job was submitted with, if any.
    pub deadline: Option<Duration>,
    /// True iff a deadline was set and `queue_wait + exec` exceeded it.
    pub deadline_missed: bool,
    /// Output quality against the request's reference field, when one
    /// was attached: PSNR in dB for
    /// [`QualityTarget::Psnr`](crate::mitigation::QualityTarget::Psnr)
    /// requests, fused gaussian SSIM otherwise. `None` when the job
    /// carried no reference or failed.
    pub quality: Option<f64>,
}

/// Completion handle for one admitted job.
///
/// The ticket resolves exactly once — when the job completes, fails, or
/// is cancelled by service shutdown — so [`JobTicket::wait`] always
/// returns eventually on a *draining* service. On a paused service
/// nothing runs: `wait` blocks until some other thread resumes the
/// service (or drops it, which cancels the job).
pub struct JobTicket {
    state: Arc<TicketState>,
}

struct TicketState {
    slot: Mutex<Option<JobReport>>,
    done: Condvar,
    /// Trace id of the job this ticket tracks, kept outside the mutex
    /// so a poisoned-lock recovery can still identify the job.
    trace: u64,
    /// Class the job was submitted with (same reason as `trace`).
    priority: Priority,
}

impl TicketState {
    /// Report synthesized when the ticket mutex is found poisoned with
    /// no stored report: the fulfilling thread panicked between taking
    /// the lock and storing it. Shaped like a failed job — carrying
    /// the `trace_id` — so callers shed or retry instead of dying on
    /// an opaque propagated poison panic.
    fn poisoned_report(&self) -> JobReport {
        JobReport {
            result: Err(anyhow::anyhow!(
                "job ticket poisoned: a thread panicked while resolving trace {}",
                self.trace
            )),
            seq: u64::MAX,
            trace_id: self.trace,
            priority: self.priority,
            queue_wait: Duration::ZERO,
            exec: Duration::ZERO,
            deadline: None,
            deadline_missed: false,
            quality: None,
        }
    }
}

impl JobTicket {
    fn new(trace: u64, priority: Priority) -> (JobTicket, Arc<TicketState>) {
        let state = Arc::new(TicketState {
            slot: Mutex::new(None),
            done: Condvar::new(),
            trace,
            priority,
        });
        (JobTicket { state: state.clone() }, state)
    }

    /// Block until the job's report is available. A poisoned ticket
    /// (a thread panicked mid-fulfill) resolves immediately: the
    /// stored report if one made it in, otherwise a synthesized
    /// failed report carrying the trace id.
    pub fn wait(self) -> JobReport {
        let mut slot = match self.state.slot.lock() {
            Ok(guard) => guard,
            Err(poison) => {
                let mut guard = poison.into_inner();
                return guard.take().unwrap_or_else(|| self.state.poisoned_report());
            }
        };
        loop {
            if let Some(report) = slot.take() {
                return report;
            }
            slot = match self.state.done.wait(slot) {
                Ok(guard) => guard,
                Err(poison) => {
                    let mut guard = poison.into_inner();
                    return guard.take().unwrap_or_else(|| self.state.poisoned_report());
                }
            };
        }
    }

    /// Non-blocking poll: the report if the job finished, the ticket
    /// back otherwise. A poisoned ticket counts as finished (see
    /// [`JobTicket::wait`]).
    pub fn try_wait(self) -> Result<JobReport, JobTicket> {
        let taken = match self.state.slot.lock() {
            Ok(mut guard) => guard.take(),
            Err(poison) => {
                let mut guard = poison.into_inner();
                return Ok(guard.take().unwrap_or_else(|| self.state.poisoned_report()));
            }
        };
        match taken {
            Some(report) => Ok(report),
            None => Err(self),
        }
    }

    /// [`JobTicket::wait`] bounded by `timeout`; the ticket comes back
    /// if the job is still running.
    pub fn wait_timeout(self, timeout: Duration) -> Result<JobReport, JobTicket> {
        // checked_add: a timeout too large to represent as an Instant
        // just waits indefinitely, like `wait` — not a panic.
        let give_up = Instant::now().checked_add(timeout);
        let mut slot = match self.state.slot.lock() {
            Ok(guard) => guard,
            Err(poison) => {
                let mut guard = poison.into_inner();
                return Ok(guard.take().unwrap_or_else(|| self.state.poisoned_report()));
            }
        };
        loop {
            if let Some(report) = slot.take() {
                return Ok(report);
            }
            // checked_duration_since: a wakeup landing exactly at (or
            // past) the deadline takes the timeout path cleanly —
            // never an underflow panic or a zero-duration busy loop.
            let remaining = match give_up {
                Some(give_up) => match give_up.checked_duration_since(Instant::now()) {
                    Some(r) if r > Duration::ZERO => r,
                    _ => {
                        drop(slot);
                        return Err(self);
                    }
                },
                None => Duration::MAX,
            };
            slot = match self.state.done.wait_timeout(slot, remaining) {
                Ok((guard, _timed_out)) => guard,
                Err(poison) => {
                    let mut guard = poison.into_inner().0;
                    return Ok(guard.take().unwrap_or_else(|| self.state.poisoned_report()));
                }
            };
        }
    }

    /// True once the report is ready (a subsequent `wait` returns
    /// immediately). A poisoned ticket is complete by that definition.
    pub fn is_complete(&self) -> bool {
        self.state.slot.lock().map(|guard| guard.is_some()).unwrap_or(true)
    }
}

impl std::fmt::Debug for JobTicket {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("JobTicket").field("complete", &self.is_complete()).finish()
    }
}

/// Point-in-time snapshot of a service's admission counters.
///
/// All `u64` fields are monotonic totals since the service was built;
/// `queue_depth` / `running` are instantaneous gauges. Counter values
/// are deterministic functions of the submission history (timings are
/// not), which the stats-determinism tests rely on.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ServiceStats {
    /// Jobs accepted into the queue (rejections not included).
    pub submitted: u64,
    /// `try_submit` calls rejected because the queue was at capacity.
    pub rejected_full: u64,
    /// Blocking submits that gave up after their timeout.
    pub submit_timeouts: u64,
    /// Jobs whose pipeline ran to success.
    pub completed: u64,
    /// Jobs whose pipeline returned an error or panicked.
    pub failed: u64,
    /// Jobs cancelled at shutdown before they ever ran.
    pub cancelled: u64,
    /// Finished (completed or failed) interactive-class jobs.
    pub interactive_done: u64,
    /// Finished (completed or failed) bulk-class jobs.
    pub bulk_done: u64,
    /// Jobs submitted with a deadline.
    pub deadlines_set: u64,
    /// Jobs that finished after their deadline had already passed.
    pub deadlines_missed: u64,
    /// High-water mark of the queued-job count.
    pub max_queue_depth: usize,
    /// Jobs waiting in the queue right now.
    pub queue_depth: usize,
    /// Jobs executing right now.
    pub running: usize,
    /// Total seconds finished jobs spent waiting in the queue.
    pub total_queue_wait_s: f64,
    /// Total seconds finished jobs spent executing.
    pub total_exec_s: f64,
    /// Submissions shed at admission because the EWMA service-time
    /// estimate proved their deadline infeasible. Always `0` unless
    /// shedding is enabled; the estimate is measured wall time, so
    /// this counter is excluded from the determinism contract above.
    pub shed_infeasible: u64,
    /// Times the scheduler thread returned from one of its condvar
    /// waits. An idle service accumulates none — the regression test
    /// for the former 5 ms polling loop pins that. Timing-dependent,
    /// excluded from the determinism contract.
    pub sched_wakeups: u64,
    /// Adaptive lane-cap grow events (adaptive mode only;
    /// timing-dependent, excluded from the determinism contract).
    pub lanes_grown: u64,
    /// Adaptive lane-cap shrink events (adaptive mode only;
    /// timing-dependent, excluded from the determinism contract).
    pub lanes_shrunk: u64,
    /// Current dynamic lane cap (gauge). `0` until the scheduler
    /// thread first runs, and always `0` with adaptive scaling off —
    /// the cap is then statically the pool's lane count.
    pub lane_cap: usize,
    /// Quality-targeted jobs whose tuned parameters came from the
    /// learned cache — no search ran, just one closed-form mitigation
    /// plus one inline metric evaluation.
    pub quality_hits: u64,
    /// Quality-targeted jobs that ran the bounded parameter search
    /// (cache miss, or a cached winner that stopped meeting its
    /// target) and installed the winner in the cache.
    pub quality_misses: u64,
    /// Tuned-parameter cache entries evicted because the per-shard key
    /// bound ([`MAX_TUNED_KEYS`] internally) was reached.
    pub quality_evicted: u64,
    /// Trace id of the most recently finished (completed or failed)
    /// job, `0` before any job finishes. Trace ids are process-wide
    /// monotonic, so this is an ordering probe, not a counter — it is
    /// excluded from the determinism contract above.
    pub last_trace_id: u64,
}

/// An opaque token attached to a submission by the engine layer. It is
/// dropped exactly when the job leaves the service — on completion,
/// failure, or shutdown cancellation, in each case *before* the
/// ticket resolves, so a client that waited on the ticket can reuse
/// the slot immediately — which the engine uses (via a `Drop` impl) to
/// release the tenant's admission-quota slot. A failed admission never
/// stores the token, so the caller's copy drops immediately and the
/// quota slot frees with it.
pub(crate) type AdmissionLease = Box<dyn std::any::Any + Send>;

/// One queued submission.
struct Pending {
    job: Job,
    /// Trace id (see [`JobReport::trace_id`]).
    trace: u64,
    priority: Priority,
    deadline: Option<Duration>,
    /// Absolute deadline instant (enqueue time + deadline), the EDF
    /// sort key within a priority class. `None` sorts after every
    /// deadline-carrying job.
    deadline_at: Option<Instant>,
    enqueued: Instant,
    ticket: Arc<TicketState>,
    /// Engine-layer quota token; explicitly dropped just before the
    /// job's ticket is fulfilled (or the job is cancelled).
    lease: Option<AdmissionLease>,
    /// Engine-layer tenant, the first half of the service-time
    /// estimator key and the per-tenant latency histogram key.
    tenant: Option<String>,
}

struct QueueInner {
    interactive: VecDeque<Pending>,
    bulk: VecDeque<Pending>,
    /// Jobs dispatched but not yet finished.
    running: usize,
    paused: bool,
    shutdown: bool,
}

impl QueueInner {
    fn depth(&self) -> usize {
        self.interactive.len() + self.bulk.len()
    }

    /// Dequeue the next job: strict interactive-over-bulk, and
    /// earliest-deadline-first within a class (deadline-less jobs drain
    /// FIFO after all deadline-carrying jobs of their class; arrival
    /// order breaks ties).
    fn pop(&mut self) -> Option<Pending> {
        Self::pop_edf(&mut self.interactive).or_else(|| Self::pop_edf(&mut self.bulk))
    }

    fn pop_edf(queue: &mut VecDeque<Pending>) -> Option<Pending> {
        if queue.len() <= 1 {
            return queue.pop_front();
        }
        // O(depth) scan per dequeue — fine at serving-queue depths
        // (default capacity 256) and free for deadline-less workloads
        // (the first entry wins immediately).
        let mut best = 0usize;
        let mut best_deadline = queue[0].deadline_at;
        for (i, pending) in queue.iter().enumerate().skip(1) {
            if let Some(d) = pending.deadline_at {
                let earlier = match best_deadline {
                    None => true,
                    Some(b) => d < b,
                };
                if earlier {
                    best = i;
                    best_deadline = Some(d);
                }
            }
        }
        queue.remove(best)
    }
}

/// Service-time estimator key: engine tenant (if any) + grid dims.
/// Also the tuned-quality-parameter cache key.
type EstKey = (Option<String>, [usize; 3]);

/// EWMA smoothing factor for the per-(tenant, shape) service-time
/// estimate behind deadline shedding.
const EST_ALPHA: f64 = 0.3;
/// Bound on distinct estimator keys. At the cap, unseen keys are not
/// tracked — their requests are simply never shed.
const MAX_EST_KEYS: usize = 4096;
/// Bound on per-tenant latency histogram entries per shard.
const MAX_LATENCY_TENANTS: usize = 1024;
/// Bound on tuned-quality-parameter cache keys per shard. At the cap,
/// installing a new winner evicts an arbitrary existing entry (counted
/// in [`ServiceStats::quality_evicted`]).
const MAX_TUNED_KEYS: usize = 4096;

/// Per-class and per-tenant latency histograms, recorded at job
/// completion. Behind its own mutex, locked alone (never while
/// holding `queue` or `stats`).
#[derive(Default)]
struct LatencyTable {
    interactive: LatencyPair,
    bulk: LatencyPair,
    tenants: HashMap<String, LatencyPair>,
}

/// Point-in-time copy of a service's per-class latency histograms (see
/// [`LatencyPair`] for the queue-wait / service-time split each half
/// carries).
#[derive(Debug, Clone, Copy, Default)]
pub struct LatencySnapshot {
    /// Interactive-class queue-wait / service-time histograms.
    pub interactive: LatencyPair,
    /// Bulk-class queue-wait / service-time histograms.
    pub bulk: LatencyPair,
}

/// State shared between the service handle, the scheduler thread, and
/// in-flight job tasks. Lock order: `queue` before `stats`, and
/// `queue` before `est`, never the reverse; `lat` is always locked
/// alone.
struct Shared {
    queue: Mutex<QueueInner>,
    /// Wakes the scheduler: job arrival, unpause, slot freed, shutdown.
    work: Condvar,
    /// Wakes blocked submitters: job dequeued, shutdown.
    space: Condvar,
    /// Monotonic counters; the two gauge fields inside stay zero and
    /// are overwritten at snapshot time.
    stats: Mutex<ServiceStats>,
    capacity: usize,
    next_seq: AtomicU64,
    /// Explicit pool, or `None` for the global one (resolved lazily so
    /// an idle service never forces global-pool creation).
    pool: Option<Arc<ThreadPool>>,
    /// Per-service scratch-buffer arena: every job's full-grid
    /// temporaries and output buffer cycle through it, so warm
    /// same-shaped jobs allocate nothing.
    arena: Arena,
    /// Deadline-infeasibility shedding enabled
    /// ([`crate::mitigation::service::ServiceConfig::shed`]).
    shed: bool,
    /// Adaptive lane scaling enabled
    /// ([`crate::mitigation::service::ServiceConfig::adaptive_lanes`]).
    adaptive: bool,
    /// EWMA of pipeline execution seconds per (tenant, shape) — the
    /// service-time model behind deadline shedding. May be locked
    /// while holding `queue` (the admission-time check); never take
    /// `queue` while holding it.
    est: Mutex<HashMap<EstKey, f64>>,
    /// Completion-time latency histograms.
    lat: Mutex<LatencyTable>,
    /// Times the scheduler thread returned from a condvar wait — an
    /// event counter proving it sleeps instead of polling.
    sched_wakeups: AtomicU64,
    /// Adaptive lane-cap growth events.
    lanes_grown: AtomicU64,
    /// Adaptive lane-cap shrink events.
    lanes_shrunk: AtomicU64,
    /// Current dynamic lane cap; `0` until the scheduler first runs,
    /// and kept `0` when adaptive scaling is off.
    lane_cap: AtomicUsize,
    /// Learned quality-search winners per (tenant, shape) key (see
    /// [`crate::mitigation::quality`]). Locked alone, never while
    /// holding `queue`, `stats`, or `est`.
    tuned: Mutex<HashMap<EstKey, TunedEntry>>,
    /// Quality-targeted jobs served from the tuned cache.
    quality_hits: AtomicU64,
    /// Quality-targeted jobs that ran the bounded search.
    quality_misses: AtomicU64,
    /// Tuned-cache entries evicted at the key bound.
    quality_evicted: AtomicU64,
}

impl Shared {
    /// The pool this service runs everything on.
    fn thread_pool(&self) -> &ThreadPool {
        self.pool.as_deref().unwrap_or_else(pool::global)
    }
}

/// The admission queue plus its scheduler thread. Owned by the
/// `MitigationService`; dropping it cancels queued jobs (their tickets
/// resolve with an error), waits for in-flight jobs to finish, and
/// joins the scheduler.
pub(crate) struct Admission {
    shared: Arc<Shared>,
    scheduler: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl Admission {
    pub(crate) fn new(
        pool: Option<Arc<ThreadPool>>,
        capacity: usize,
        start_paused: bool,
        arena: Arena,
        shed: bool,
        adaptive: bool,
    ) -> Self {
        let shared = Arc::new(Shared {
            queue: Mutex::new(QueueInner {
                interactive: VecDeque::new(),
                bulk: VecDeque::new(),
                running: 0,
                paused: start_paused,
                shutdown: false,
            }),
            work: Condvar::new(),
            space: Condvar::new(),
            stats: Mutex::new(ServiceStats::default()),
            capacity: capacity.max(1),
            next_seq: AtomicU64::new(0),
            pool,
            arena,
            shed,
            adaptive,
            est: Mutex::new(HashMap::new()),
            lat: Mutex::new(LatencyTable::default()),
            sched_wakeups: AtomicU64::new(0),
            lanes_grown: AtomicU64::new(0),
            lanes_shrunk: AtomicU64::new(0),
            lane_cap: AtomicUsize::new(0),
            tuned: Mutex::new(HashMap::new()),
            quality_hits: AtomicU64::new(0),
            quality_misses: AtomicU64::new(0),
            quality_evicted: AtomicU64::new(0),
        });
        Admission { shared, scheduler: Mutex::new(None) }
    }

    /// The service's scratch-buffer arena.
    pub(crate) fn arena(&self) -> &Arena {
        &self.shared.arena
    }

    /// Spawn the scheduler thread on first use.
    fn ensure_scheduler(&self) {
        let mut slot = self.scheduler.lock().unwrap();
        if slot.is_none() {
            pool::note_os_thread_spawn();
            let shared = self.shared.clone();
            let handle = std::thread::Builder::new()
                .name("qai-admission".into())
                .spawn(move || scheduler_loop(shared))
                .expect("spawn admission scheduler");
            *slot = Some(handle);
        }
    }

    /// Append an accepted job to its class queue and bump counters.
    /// Caller holds the queue lock and has verified there is space.
    fn enqueue(
        &self,
        q: &mut QueueInner,
        job: Job,
        opts: SubmitOptions,
        lease: Option<AdmissionLease>,
        trace: u64,
        tenant: Option<String>,
    ) -> JobTicket {
        let (ticket, state) = JobTicket::new(trace, opts.priority);
        let enqueued = Instant::now();
        let pending = Pending {
            job,
            trace,
            priority: opts.priority,
            deadline: opts.deadline,
            // checked_add: an absurd deadline (e.g. Duration::MAX) must
            // not panic under the queue lock — an unrepresentable
            // instant just sorts after every finite deadline, like no
            // deadline at all.
            deadline_at: opts.deadline.and_then(|d| enqueued.checked_add(d)),
            enqueued,
            ticket: state,
            lease,
            tenant,
        };
        match opts.priority {
            Priority::Interactive => q.interactive.push_back(pending),
            Priority::Bulk => q.bulk.push_back(pending),
        }
        let depth = q.depth();
        let mut st = self.shared.stats.lock().unwrap();
        st.submitted += 1;
        if opts.deadline.is_some() {
            st.deadlines_set += 1;
        }
        if depth > st.max_queue_depth {
            st.max_queue_depth = depth;
        }
        ticket
    }

    pub(crate) fn try_submit(
        &self,
        job: Job,
        opts: SubmitOptions,
    ) -> Result<JobTicket, SubmitError> {
        self.try_submit_leased(job, opts, None, next_trace_id(), None)
    }

    /// Deadline-infeasibility check (shed mode only): using the EWMA
    /// service-time estimate for this (tenant, shape) key, project the
    /// job's completion as `est * (1 + depth / lanes)` — its own
    /// execution plus its share of the work already queued ahead —
    /// and shed when even that optimistic projection overruns the
    /// deadline. With no estimate yet (cold key) the job is admitted:
    /// infeasibility must be proven, never guessed. Called with the
    /// queue lock held (lock order `queue` → `est`).
    fn infeasible(
        &self,
        q: &QueueInner,
        job: &Job,
        tenant: &Option<String>,
        deadline: Option<Duration>,
    ) -> bool {
        if !self.shared.shed {
            return false;
        }
        let Some(deadline) = deadline else { return false };
        let est_s = {
            let est = self.shared.est.lock().unwrap();
            match est.get(&(tenant.clone(), job.dq.shape.dims)) {
                Some(&s) => s,
                None => return false,
            }
        };
        // Resolving the pool here cannot force early global-pool
        // creation: an estimate exists, so a job has already run.
        let lanes = self.shared.thread_pool().lanes().max(1) as f64;
        let projected = est_s * (1.0 + q.depth() as f64 / lanes);
        projected > deadline.as_secs_f64()
    }

    /// [`Admission::try_submit`] with an engine-layer quota lease,
    /// request trace id, and tenant. On rejection the lease never
    /// enters the queue and is dropped here, releasing the quota slot
    /// immediately.
    pub(crate) fn try_submit_leased(
        &self,
        job: Job,
        opts: SubmitOptions,
        lease: Option<AdmissionLease>,
        trace: u64,
        tenant: Option<String>,
    ) -> Result<JobTicket, SubmitError> {
        let ticket = {
            let mut q = self.shared.queue.lock().unwrap();
            if q.shutdown {
                return Err(SubmitError::Shutdown(job));
            }
            if q.depth() >= self.shared.capacity {
                drop(q);
                self.shared.stats.lock().unwrap().rejected_full += 1;
                return Err(SubmitError::QueueFull(job));
            }
            if self.infeasible(&q, &job, &tenant, opts.deadline) {
                drop(q);
                self.shared.stats.lock().unwrap().shed_infeasible += 1;
                return Err(SubmitError::DeadlineInfeasible(job));
            }
            self.enqueue(&mut q, job, opts, lease, trace, tenant)
        };
        self.shared.work.notify_all();
        self.ensure_scheduler();
        Ok(ticket)
    }

    pub(crate) fn submit(&self, job: Job, opts: SubmitOptions) -> Result<JobTicket, SubmitError> {
        self.submit_leased(job, opts, None, next_trace_id(), None)
    }

    /// [`Admission::submit`] with an engine-layer quota lease, request
    /// trace id, and tenant (see [`Admission::try_submit_leased`]).
    pub(crate) fn submit_leased(
        &self,
        job: Job,
        opts: SubmitOptions,
        lease: Option<AdmissionLease>,
        trace: u64,
        tenant: Option<String>,
    ) -> Result<JobTicket, SubmitError> {
        // checked_add: an unrepresentable give-up instant means the
        // bound can never be hit — wait indefinitely, like no timeout.
        let give_up = opts.timeout.and_then(|t| Instant::now().checked_add(t));
        let ticket = {
            let mut q = self.shared.queue.lock().unwrap();
            loop {
                if q.shutdown {
                    return Err(SubmitError::Shutdown(job));
                }
                if q.depth() < self.shared.capacity {
                    break;
                }
                match give_up {
                    None => q = self.shared.space.wait(q).unwrap(),
                    Some(deadline) => {
                        // checked_duration_since: a wakeup landing at
                        // or past the give-up instant takes the
                        // timeout path cleanly — never an underflow
                        // panic or a zero-duration busy loop.
                        let remaining = match deadline.checked_duration_since(Instant::now()) {
                            Some(r) if r > Duration::ZERO => r,
                            _ => {
                                drop(q);
                                self.shared.stats.lock().unwrap().submit_timeouts += 1;
                                return Err(SubmitError::Timeout(job));
                            }
                        };
                        q = self.shared.space.wait_timeout(q, remaining).unwrap().0;
                    }
                }
            }
            if self.infeasible(&q, &job, &tenant, opts.deadline) {
                drop(q);
                self.shared.stats.lock().unwrap().shed_infeasible += 1;
                return Err(SubmitError::DeadlineInfeasible(job));
            }
            self.enqueue(&mut q, job, opts, lease, trace, tenant)
        };
        self.shared.work.notify_all();
        self.ensure_scheduler();
        Ok(ticket)
    }

    pub(crate) fn pause(&self) {
        self.shared.queue.lock().unwrap().paused = true;
    }

    pub(crate) fn resume(&self) {
        self.shared.queue.lock().unwrap().paused = false;
        self.shared.work.notify_all();
    }

    pub(crate) fn stats(&self) -> ServiceStats {
        // Gauges first (queue → stats lock order); the two reads are
        // not atomic together, which a snapshot can tolerate.
        let (queue_depth, running) = {
            let q = self.shared.queue.lock().unwrap();
            (q.depth(), q.running)
        };
        let mut snapshot = *self.shared.stats.lock().unwrap();
        snapshot.queue_depth = queue_depth;
        snapshot.running = running;
        snapshot.sched_wakeups = self.shared.sched_wakeups.load(Ordering::SeqCst);
        snapshot.lanes_grown = self.shared.lanes_grown.load(Ordering::SeqCst);
        snapshot.lanes_shrunk = self.shared.lanes_shrunk.load(Ordering::SeqCst);
        snapshot.lane_cap = self.shared.lane_cap.load(Ordering::SeqCst);
        snapshot.quality_hits = self.shared.quality_hits.load(Ordering::SeqCst);
        snapshot.quality_misses = self.shared.quality_misses.load(Ordering::SeqCst);
        snapshot.quality_evicted = self.shared.quality_evicted.load(Ordering::SeqCst);
        snapshot
    }

    /// Snapshot of the per-class latency histograms.
    pub(crate) fn latency(&self) -> LatencySnapshot {
        let lat = self.shared.lat.lock().unwrap();
        LatencySnapshot { interactive: lat.interactive, bulk: lat.bulk }
    }

    /// Latency histogram pair for one tenant, if any of its jobs have
    /// completed on this service.
    pub(crate) fn tenant_latency(&self, tenant: &str) -> Option<LatencyPair> {
        self.shared.lat.lock().unwrap().tenants.get(tenant).copied()
    }
}

impl Drop for Admission {
    fn drop(&mut self) {
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.shutdown = true;
        }
        self.shared.work.notify_all();
        self.shared.space.notify_all();
        if let Some(handle) = self.scheduler.lock().unwrap().take() {
            let _ = handle.join();
        }
        // The scheduler has seen every in-flight job finish, but the
        // finished task closures may still hold clones of `shared` for
        // a few more instructions (and, through it, the pool Arc).
        // Wait for those to release so the final drop of `Shared` —
        // which may drop the pool and join its workers — runs on this
        // thread, never on a pool worker joining itself. No new clones
        // can appear: the scheduler has exited and submissions are
        // rejected with `Shutdown`.
        while Arc::strong_count(&self.shared) > 1 {
            std::thread::yield_now();
        }
    }
}

/// What the scheduler decided to do after inspecting the queue.
enum SchedulerStep {
    /// A concurrency slot was claimed for this job (boxed: `Pending`
    /// dwarfs the other variants).
    Dispatch(Box<Pending>),
    /// Jobs are queued but every lane is busy: lend the scheduler
    /// thread to the pool instead of idling (cooperative blocking).
    Help,
    /// Shutdown observed.
    Exit,
}

/// Drain loop: pop the highest-priority job whenever a concurrency slot
/// is free and hand it to the pool as a detached task (routed through
/// the pool's worker-local deques). While every lane is busy with more
/// jobs still queued, the scheduler *helps* the pool run queued tickets
/// — finishing in-flight jobs faster is the only way a lane frees — and
/// returns to dispatching the moment one does. On shutdown, cancel
/// everything still queued and wait for in-flight jobs so no ticket is
/// ever left unresolved.
fn scheduler_loop(shared: Arc<Shared>) {
    // Adaptive lane scaling state. Resolving the pool here is safe:
    // the scheduler only exists once a job has been submitted. The cap
    // starts at the full lane count and moves one lane at a time — an
    // idle shard shrinks it, a shard observing fresh deadline misses
    // with parked pool capacity grows it back.
    let full_lanes = shared.thread_pool().lanes();
    if shared.adaptive {
        shared.lane_cap.store(full_lanes, Ordering::SeqCst);
    }
    let effective_cap = |shared: &Shared| {
        if shared.adaptive {
            shared.lane_cap.load(Ordering::SeqCst).clamp(1, full_lanes)
        } else {
            full_lanes
        }
    };
    let mut last_missed = 0u64;
    loop {
        if shared.adaptive {
            let missed = shared.stats.lock().unwrap().deadlines_missed;
            if missed > last_missed {
                last_missed = missed;
                let cap = shared.lane_cap.load(Ordering::SeqCst);
                if cap < full_lanes && shared.thread_pool().parked_workers() > 0 {
                    shared.lane_cap.store(cap + 1, Ordering::SeqCst);
                    shared.lanes_grown.fetch_add(1, Ordering::SeqCst);
                }
            }
        }
        let step = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if q.shutdown {
                    break SchedulerStep::Exit;
                }
                if !q.paused && q.depth() > 0 {
                    // Pool resolved lazily: an explicit-pool service
                    // must never touch the global pool, and a
                    // global-pool service only once a job actually
                    // exists.
                    //
                    // Admit up to `lanes` jobs (the adaptive cap, or
                    // all of them): `workers` can execute at once,
                    // and one more sits staged in the pool queue so a
                    // freed worker starts its next job without a
                    // scheduler round-trip. While a lane is free the
                    // scheduler never executes jobs itself (except on
                    // a single-lane pool) — that keeps admission of
                    // later, possibly interactive, jobs responsive.
                    if q.running < effective_cap(&shared) {
                        q.running += 1;
                        break SchedulerStep::Dispatch(Box::new(q.pop().expect("depth > 0")));
                    }
                    break SchedulerStep::Help;
                }
                // Fully idle: shrink the adaptive cap one lane before
                // sleeping — a quiet shard gives capacity back.
                if shared.adaptive && q.running == 0 && q.depth() == 0 {
                    let cap = shared.lane_cap.load(Ordering::SeqCst);
                    if cap > 1 {
                        shared.lane_cap.store(cap - 1, Ordering::SeqCst);
                        shared.lanes_shrunk.fetch_add(1, Ordering::SeqCst);
                    }
                }
                q = shared.work.wait(q).unwrap();
                shared.sched_wakeups.fetch_add(1, Ordering::SeqCst);
            }
        };
        match step {
            SchedulerStep::Exit => break,
            SchedulerStep::Dispatch(pending) => {
                shared.space.notify_all();
                let seq = shared.next_seq.fetch_add(1, Ordering::SeqCst);
                dispatch_job(&shared, *pending, seq);
            }
            SchedulerStep::Help => {
                // All lanes busy, jobs still queued: run one queued
                // region ticket (a bounded step of an in-flight job —
                // never a whole detached job, which would stall
                // dispatch past the next lane becoming free). When
                // nothing is helpable, block on the work condvar —
                // every lane release (`run_job`), submission, resume,
                // and shutdown notifies it, so the scheduler wakes on
                // real events instead of the old 5 ms polling loop.
                if !shared.thread_pool().try_help_one() {
                    let q = shared.queue.lock().unwrap();
                    if !q.shutdown && q.running >= effective_cap(&shared) {
                        drop(shared.work.wait(q).unwrap());
                        shared.sched_wakeups.fetch_add(1, Ordering::SeqCst);
                    }
                }
            }
        }
    }

    cancel_queued(&shared);
    let mut q = shared.queue.lock().unwrap();
    while q.running > 0 {
        q = shared.work.wait(q).unwrap();
    }
}

/// Run `pending` as a detached pool task (routed onto a worker-local
/// deque), or inline on a single-lane pool — inline execution there
/// serializes jobs in admission order, which the
/// deterministic-ordering tests rely on.
fn dispatch_job(shared: &Arc<Shared>, pending: Pending, seq: u64) {
    let task_shared = shared.clone();
    let task = move || run_job(task_shared, pending, seq);
    let tp = shared.thread_pool();
    if tp.workers() == 0 {
        task();
    } else {
        tp.submit_task(Box::new(task));
    }
}

/// Execute one job's pipeline on the service pool, resolve its ticket,
/// account stats, and free the concurrency slot.
fn run_job(shared: Arc<Shared>, mut pending: Pending, seq: u64) {
    let start = Instant::now();
    let queue_wait = start.duration_since(pending.enqueued);

    // Error text stays slot-agnostic: the seq lives in the JobReport,
    // and the batch wrapper re-labels errors with its own slot index.
    let job = &pending.job;
    let mut quality_score: Option<f64> = None;
    let result: JobResult = if job.dq.shape != job.q.shape {
        Err(anyhow::anyhow!(
            "data shape {:?} != index shape {:?}",
            job.dq.shape.dims,
            job.q.shape.dims
        ))
    } else {
        // A panic below (defensive: the pipeline asserts on internal
        // invariants) must not take down the worker or sibling jobs.
        match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            execute_with_quality(&shared, job, pending.tenant.as_ref())
        })) {
            Ok(Ok((out, stats, quality))) => {
                quality_score = quality;
                Ok((out, stats))
            }
            Ok(Err(e)) => Err(e),
            Err(payload) => {
                let msg = payload
                    .downcast_ref::<String>()
                    .cloned()
                    .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                    .unwrap_or_else(|| "<non-string panic>".to_string());
                Err(anyhow::anyhow!("pipeline panicked: {msg}"))
            }
        }
    };

    let exec = start.elapsed();
    let deadline_missed = pending.deadline.is_some_and(|d| queue_wait + exec > d);
    {
        let mut st = shared.stats.lock().unwrap();
        if result.is_ok() {
            st.completed += 1;
        } else {
            st.failed += 1;
        }
        match pending.priority {
            Priority::Interactive => st.interactive_done += 1,
            Priority::Bulk => st.bulk_done += 1,
        }
        if deadline_missed {
            st.deadlines_missed += 1;
        }
        st.total_queue_wait_s += queue_wait.as_secs_f64();
        st.total_exec_s += exec.as_secs_f64();
        st.last_trace_id = pending.trace;
    }
    // Feed the SLO layer: the per-(tenant, shape) EWMA service-time
    // estimate behind deadline shedding, then the per-class and
    // per-tenant latency histograms. Separate locks, taken one at a
    // time, never while holding `queue` or `stats`.
    {
        let mut est = shared.est.lock().unwrap();
        let key = (pending.tenant.clone(), pending.job.dq.shape.dims);
        match est.get_mut(&key) {
            Some(e) => *e = EST_ALPHA * exec.as_secs_f64() + (1.0 - EST_ALPHA) * *e,
            None if est.len() < MAX_EST_KEYS => {
                est.insert(key, exec.as_secs_f64());
            }
            None => {}
        }
    }
    {
        let mut lat = shared.lat.lock().unwrap();
        let pair = match pending.priority {
            Priority::Interactive => &mut lat.interactive,
            Priority::Bulk => &mut lat.bulk,
        };
        pair.wait.record(queue_wait);
        pair.exec.record(exec);
        if let Some(tenant) = &pending.tenant {
            if let Some(pair) = lat.tenants.get_mut(tenant) {
                pair.wait.record(queue_wait);
                pair.exec.record(exec);
            } else if lat.tenants.len() < MAX_LATENCY_TENANTS {
                let mut pair = LatencyPair::default();
                pair.wait.record(queue_wait);
                pair.exec.record(exec);
                lat.tenants.insert(tenant.clone(), pair);
            }
        }
    }
    // Release the engine-layer quota slot *before* resolving the
    // ticket, so a client that waited on it can resubmit immediately
    // without a spurious QuotaExceeded.
    drop(pending.lease.take());
    fulfill(
        &pending.ticket,
        JobReport {
            result,
            seq,
            trace_id: pending.trace,
            priority: pending.priority,
            queue_wait,
            exec,
            deadline: pending.deadline,
            deadline_missed,
            quality: quality_score,
        },
    );
    {
        let mut q = shared.queue.lock().unwrap();
        q.running -= 1;
    }
    shared.work.notify_all();
}

/// Run one job's mitigation, quality-aware. Target-less jobs run the
/// pipeline with the request's own config (scoring the output inline
/// when a reference rode along); quality-targeted jobs first consult
/// the tuned-parameter cache for their (tenant, shape) key — a hit
/// replays the learned winner and re-scores it, a miss (or a cached
/// winner that stopped meeting the target — dataset drift) runs the
/// bounded search from [`crate::mitigation::quality`] and installs the
/// winner. Concurrent first requests for one cold key may each run the
/// search; the cache converges to a single winner either way.
fn execute_with_quality(
    shared: &Shared,
    job: &Job,
    tenant: Option<&String>,
) -> anyhow::Result<(Grid<f32>, PipelineStats, Option<f64>)> {
    let handle = PoolHandle::Explicit(shared.thread_pool());
    let arena = ArenaHandle::Pooled(&shared.arena);
    let Some(target) = job.target else {
        // Tiled jobs stream tile-by-tile with O(tile × lanes) arena
        // scratch; whole-field otherwise. Identical dispatch to the
        // queue-free `execute_on` path.
        let (out, stats) = match &job.tiled {
            Some(t) => run_tiled(handle, arena, &job.dq, &job.q, job.eb, &job.cfg, t)?,
            None => run_pipeline(handle, arena, &job.dq, &job.q, job.eb, &job.cfg)?,
        };
        let quality = job
            .reference
            .as_ref()
            .map(|r| quality::evaluate(handle, arena, r, &out, None, job.cfg.threads));
        return Ok((out, stats, quality));
    };
    let Some(reference) = job.reference.as_ref() else {
        anyhow::bail!("quality target {target:?} requires a reference field on the request");
    };
    let key: EstKey = (tenant.cloned(), job.dq.shape.dims);
    let cached = shared.tuned.lock().unwrap().get(&key).copied();
    if let Some(entry) = cached {
        shared.quality_hits.fetch_add(1, Ordering::SeqCst);
        let (out, stats) = quality::apply_params(handle, arena, job, entry.params)?;
        let quality = quality::evaluate(handle, arena, reference, &out, Some(target), job.cfg.threads);
        // Serve the closed-form result unless the cached winner met the
        // target when installed but no longer does (dataset drift) —
        // then fall through to a fresh search, counted as a miss, so
        // the cache self-heals.
        if target.met_by(quality) || !target.met_by(entry.quality) {
            return Ok((out, stats, Some(quality)));
        }
    }
    shared.quality_misses.fetch_add(1, Ordering::SeqCst);
    let outcome = quality::search(handle, arena, job, reference, target)?;
    {
        let mut tuned = shared.tuned.lock().unwrap();
        if !tuned.contains_key(&key) && tuned.len() >= MAX_TUNED_KEYS {
            // Evict an arbitrary entry to stay bounded; which one is
            // immaterial — a re-searched key just reinstalls itself.
            if let Some(evict) = tuned.keys().next().cloned() {
                tuned.remove(&evict);
                shared.quality_evicted.fetch_add(1, Ordering::SeqCst);
            }
        }
        tuned.insert(key, TunedEntry { params: outcome.params, quality: outcome.quality });
    }
    Ok((outcome.output, outcome.stats, Some(outcome.quality)))
}

/// Resolve every still-queued ticket with a shutdown error.
fn cancel_queued(shared: &Shared) {
    let drained: Vec<Pending> = {
        let mut q = shared.queue.lock().unwrap();
        let mut all: Vec<Pending> = q.interactive.drain(..).collect();
        all.extend(q.bulk.drain(..));
        all
    };
    if drained.is_empty() {
        return;
    }
    shared.stats.lock().unwrap().cancelled += drained.len() as u64;
    for mut p in drained {
        let queue_wait = p.enqueued.elapsed();
        // Quota slot freed before the ticket resolves (see run_job).
        drop(p.lease.take());
        fulfill(
            &p.ticket,
            JobReport {
                result: Err(anyhow::anyhow!("mitigation service shut down before the job ran")),
                seq: u64::MAX,
                trace_id: p.trace,
                priority: p.priority,
                queue_wait,
                exec: Duration::ZERO,
                deadline: p.deadline,
                deadline_missed: p.deadline.is_some_and(|d| queue_wait > d),
                quality: None,
            },
        );
    }
    shared.space.notify_all();
}

fn fulfill(ticket: &Arc<TicketState>, report: JobReport) {
    // A caller-side panic while holding the slot lock must not take
    // the worker down with it — store the report anyway.
    let mut slot = ticket.slot.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
    *slot = Some(report);
    ticket.done.notify_all();
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Poison the ticket's slot mutex by panicking a thread while it
    /// holds the lock.
    fn poison_slot(state: &Arc<TicketState>) {
        let state = state.clone();
        let result = std::thread::spawn(move || {
            let _guard = state.slot.lock().unwrap();
            panic!("poison the ticket slot");
        })
        .join();
        assert!(result.is_err(), "the poisoning thread must have panicked");
    }

    #[test]
    fn poisoned_ticket_wait_yields_failed_report_with_trace() {
        let (ticket, state) = JobTicket::new(42, Priority::Interactive);
        poison_slot(&state);
        assert!(ticket.is_complete(), "poison counts as complete");
        let report = ticket.wait();
        assert_eq!(report.trace_id, 42);
        assert_eq!(report.seq, u64::MAX);
        assert_eq!(report.priority, Priority::Interactive);
        let err = report.result.expect_err("poison maps to a failed report");
        assert!(err.to_string().contains("42"), "error names the trace: {err}");
    }

    #[test]
    fn poisoned_ticket_try_wait_and_wait_timeout_resolve() {
        let (ticket, state) = JobTicket::new(7, Priority::Bulk);
        poison_slot(&state);
        let report = ticket.try_wait().expect("poison resolves instead of handing back");
        assert!(report.result.is_err());
        assert_eq!(report.trace_id, 7);

        let (ticket, state) = JobTicket::new(8, Priority::Bulk);
        poison_slot(&state);
        let report = ticket.wait_timeout(Duration::from_secs(5)).expect("resolves immediately");
        assert!(report.result.is_err());
        assert_eq!(report.trace_id, 8);
    }

    #[test]
    fn poisoned_ticket_prefers_the_stored_report() {
        let (ticket, state) = JobTicket::new(11, Priority::Bulk);
        fulfill(
            &state,
            JobReport {
                result: Err(anyhow::anyhow!("real failure")),
                seq: 3,
                trace_id: 11,
                priority: Priority::Bulk,
                queue_wait: Duration::ZERO,
                exec: Duration::ZERO,
                deadline: None,
                deadline_missed: false,
                quality: None,
            },
        );
        poison_slot(&state);
        let report = ticket.wait();
        assert_eq!(report.seq, 3, "stored report wins over the synthesized one");
        assert_eq!(report.result.unwrap_err().to_string(), "real failure");
    }

    #[test]
    fn wait_timeout_zero_hands_the_ticket_back_immediately() {
        // Regression: the old arithmetic recomputed `now` after the
        // deadline check, so a zero remainder turned into a busy loop
        // (and an exactly-elapsed one could underflow).
        let (ticket, _state) = JobTicket::new(1, Priority::Bulk);
        let started = Instant::now();
        let ticket = ticket.wait_timeout(Duration::ZERO).expect_err("no report yet");
        assert!(started.elapsed() < Duration::from_secs(2));
        assert!(!ticket.is_complete());
    }
}
