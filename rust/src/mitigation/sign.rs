//! Step C: sign propagation and the sign-flipping boundary (paper
//! Alg. 3, `PropagateSignsAndConstructSignMap`).
//!
//! Every non-boundary point inherits the error sign of its *nearest*
//! quantization-boundary point, read off the feature transform `I₁`
//! computed in step B. The propagated sign map partitions the domain
//! into ± regions; the interface between them is the **sign-flipping
//! boundary** `B₂`, where the compensation error is assumed ≈ 0 (it lies
//! halfway between two quantization boundaries of opposite sign).

use crate::data::grid::Grid;
use crate::mitigation::boundary::boundary_mask_on;
use crate::util::arena::ArenaHandle;
use crate::util::pool::PoolHandle;

/// Propagate boundary signs to the whole domain and derive `B₂`
/// (parallel regions on the global pool, buffers freshly allocated).
///
/// * `b1` — quantization-boundary mask from step A;
/// * `sign_at_boundary` — sign map valid on `b1` points;
/// * `nearest` — feature transform from step B (`I₁`);
/// * returns `(S, B₂)`: the complete sign map and sign-flip boundary.
pub fn propagate_signs(
    b1: &Grid<bool>,
    sign_at_boundary: &Grid<i8>,
    nearest: &[u32],
    threads: usize,
) -> (Grid<i8>, Grid<bool>) {
    propagate_signs_on(
        PoolHandle::Global,
        ArenaHandle::Fresh,
        b1,
        sign_at_boundary,
        nearest,
        threads,
    )
}

/// [`propagate_signs`] with its parallel regions confined to `pool` and
/// both full-grid outputs acquired from `arena` (the caller gives them
/// back; the pipeline does).
pub fn propagate_signs_on(
    pool: PoolHandle<'_>,
    arena: ArenaHandle<'_>,
    b1: &Grid<bool>,
    sign_at_boundary: &Grid<i8>,
    nearest: &[u32],
    threads: usize,
) -> (Grid<i8>, Grid<bool>) {
    assert_eq!(b1.shape, sign_at_boundary.shape);
    assert_eq!(nearest.len(), b1.len());

    let mut s =
        Grid { shape: sign_at_boundary.shape, data: arena.take_copy(&sign_at_boundary.data) };
    {
        let b = &b1.data;
        let src = &sign_at_boundary.data;
        pool.chunks_mut(&mut s.data, threads, |start, chunk| {
            for (off, v) in chunk.iter_mut().enumerate() {
                let i = start + off;
                if !b[i] {
                    let nb = nearest[i];
                    *v = if nb == u32::MAX { 0 } else { src[nb as usize] };
                }
            }
        });
    }
    let b2 = boundary_mask_on(pool, arena, &s, threads);
    (s, b2)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mitigation::boundary::boundary_and_sign;
    use crate::mitigation::edt::edt;
    use crate::quant::QIndex;

    #[test]
    fn propagates_nearest_boundary_sign_1d() {
        // Index ramp 0,0,0,1,1,1 → boundaries at k=2 (+1) and k=3 (−1).
        let q = Grid::from_vec(vec![0i64, 0, 0, 1, 1, 1], &[6]);
        let b = boundary_and_sign(&q, 1);
        let r = edt(&b.mask, true, 1);
        let (s, b2) = propagate_signs(&b.mask, &b.sign, r.nearest.as_ref().unwrap(), 1);
        // left region takes +1 from k=2; right takes −1 from k=3
        assert_eq!(s.data[0], 1);
        assert_eq!(s.data[1], 1);
        assert_eq!(s.data[2], 1);
        assert_eq!(s.data[3], -1);
        assert_eq!(s.data[5], -1);
        // sign flip between k=2 and k=3 → B2 marks both interior sides
        assert!(b2.data[2] && b2.data[3]);
        assert!(!b2.data[1] || !b2.data[4] || true); // edges handled by mask fn
    }

    #[test]
    fn no_boundaries_propagates_zero() {
        let q = Grid::from_vec(vec![7i64; 16], &[4, 4]);
        let b = boundary_and_sign(&q, 1);
        let r = edt(&b.mask, true, 1);
        let (s, b2) = propagate_signs(&b.mask, &b.sign, r.nearest.as_ref().unwrap(), 1);
        assert!(s.data.iter().all(|&v| v == 0));
        assert!(b2.data.iter().all(|&v| !v));
    }

    #[test]
    fn boundary_points_keep_their_own_sign() {
        let q: Grid<QIndex> = Grid::from_vec(vec![0, 0, 1, 1, 2, 2], &[6]);
        let b = boundary_and_sign(&q, 1);
        let r = edt(&b.mask, true, 1);
        let (s, _b2) = propagate_signs(&b.mask, &b.sign, r.nearest.as_ref().unwrap(), 1);
        for i in 0..6 {
            if b.mask.data[i] {
                assert_eq!(s.data[i], b.sign.data[i], "boundary sign altered at {i}");
            }
        }
    }

    #[test]
    fn threaded_matches_sequential_2d() {
        let mut q = Grid::<QIndex>::zeros(&[12, 12]);
        for j in 0..12 {
            for k in 0..12 {
                *q.at_mut(0, j, k) = ((j + k) / 4) as i64;
            }
        }
        let b = boundary_and_sign(&q, 1);
        let r = edt(&b.mask, true, 1);
        let (s1, b2a) = propagate_signs(&b.mask, &b.sign, r.nearest.as_ref().unwrap(), 1);
        let (s4, b2b) = propagate_signs(&b.mask, &b.sign, r.nearest.as_ref().unwrap(), 4);
        assert_eq!(s1.data, s4.data);
        assert_eq!(b2a.data, b2b.data);
    }
}
