//! Step E: inverse-distance-weighted error estimation and compensation
//! (paper §VI, Alg. 4 line 8).
//!
//! For a point at distance `k₁` from its nearest quantization boundary
//! (error magnitude ≈ η·ε there) and `k₂` from the nearest sign-flipping
//! boundary (error ≈ 0 there), IDW interpolation gives
//!
//! ```text
//! C = (1/k₁) / (1/k₁ + 1/k₂) · S · η·ε  =  k₂/(k₁+k₂) · S · η·ε
//! ```
//!
//! Limits: on `B₁` (k₁=0) the full η·ε·S is applied; on `B₂` (k₂=0)
//! nothing is. If no sign-flipping boundary exists (k₂=∞) the weight's
//! limit is 1; if no quantization boundary exists at all the field is
//! homogeneous and no compensation is applied. Because `|C| ≤ η·ε` always
//! and the quantization error is ≤ ε, the compensated error is strictly
//! within the relaxed bound `(1+η)·ε` (Table II).

use crate::mitigation::edt::INF;
use crate::util::pool::PoolHandle;

/// IDW weight `k₂/(k₁+k₂)` from *squared* distances, with the limit
/// conventions above.
#[inline]
pub fn idw_weight(dist1_sq: i64, dist2_sq: i64) -> f64 {
    if dist1_sq >= INF {
        return 0.0; // no quantization boundary anywhere
    }
    if dist1_sq == 0 {
        return 1.0; // on B₁
    }
    if dist2_sq >= INF {
        return 1.0; // no sign-flip boundary: take the boundary value
    }
    if dist2_sq == 0 {
        return 0.0; // on B₂
    }
    let k1 = (dist1_sq as f64).sqrt();
    let k2 = (dist2_sq as f64).sqrt();
    k2 / (k1 + k2)
}

/// Add the interpolated compensation to `data` in place:
/// `data[i] += idw_weight(d1[i], d2[i]) · sign[i] · eta_eps`.
pub fn compensate(
    data: &mut [f32],
    dist1_sq: &[i64],
    dist2_sq: &[i64],
    sign: &[i8],
    eta_eps: f64,
    threads: usize,
) {
    compensate_adaptive(data, dist1_sq, dist2_sq, sign, eta_eps, None, threads)
}

/// [`compensate`] with the paper's §IX future-work extension: an
/// optional **homogeneous-region taper**. Deep inside a region of
/// uniform quantization index, the characterization's premise (error ≈
/// ±ε near boundaries, smoothly interpolable between them) weakens —
/// the true error there is simply `d − 2qε` with no boundary structure
/// to reconstruct, so compensating at full strength mostly injects
/// noise. With `taper_radius = Some(r)`, the compensation is scaled by
/// `exp(−(k₁/r)²)` so points farther than ~r cells from any
/// quantization boundary fade to no-op. `None` reproduces the paper's
/// published algorithm exactly.
pub fn compensate_adaptive(
    data: &mut [f32],
    dist1_sq: &[i64],
    dist2_sq: &[i64],
    sign: &[i8],
    eta_eps: f64,
    taper_radius: Option<f64>,
    threads: usize,
) {
    compensate_adaptive_on(
        PoolHandle::Global,
        data,
        dist1_sq,
        dist2_sq,
        sign,
        eta_eps,
        taper_radius,
        threads,
    )
}

/// [`compensate_adaptive`] with its parallel regions confined to
/// `pool`.
#[allow(clippy::too_many_arguments)]
pub fn compensate_adaptive_on(
    pool: PoolHandle<'_>,
    data: &mut [f32],
    dist1_sq: &[i64],
    dist2_sq: &[i64],
    sign: &[i8],
    eta_eps: f64,
    taper_radius: Option<f64>,
    threads: usize,
) {
    assert_eq!(data.len(), dist1_sq.len());
    assert_eq!(data.len(), dist2_sq.len());
    assert_eq!(data.len(), sign.len());
    let inv_r_sq = taper_radius.map(|r| {
        assert!(r > 0.0, "taper radius must be positive");
        1.0 / (r * r)
    });
    pool.chunks_mut(data, threads, |start, chunk| {
        let end = start + chunk.len();
        match inv_r_sq {
            None => {
                // Paper-exact path (no taper): the vectorized IDW kernel,
                // bit-identical to the scalar weight chain above.
                crate::util::simd::compensate(
                    chunk,
                    &dist1_sq[start..end],
                    &dist2_sq[start..end],
                    &sign[start..end],
                    eta_eps,
                    INF,
                );
            }
            Some(inv) => {
                // Taper path stays scalar: `exp` has no correctly-rounded
                // vector form, so a SIMD twin could not be bit-identical.
                for (off, v) in chunk.iter_mut().enumerate() {
                    let i = start + off;
                    let s = sign[i];
                    if s == 0 {
                        continue;
                    }
                    if dist1_sq[i] >= INF {
                        continue;
                    }
                    let w = idw_weight(dist1_sq[i], dist2_sq[i])
                        * (-(dist1_sq[i] as f64) * inv).exp();
                    *v += (w * s as f64 * eta_eps) as f32;
                }
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn weight_limits() {
        assert_eq!(idw_weight(0, 100), 1.0);
        assert_eq!(idw_weight(100, 0), 0.0);
        assert_eq!(idw_weight(INF, 5), 0.0);
        assert_eq!(idw_weight(5, INF), 1.0);
        // equidistant → 0.5
        assert!((idw_weight(9, 9) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn weight_monotone_in_dist1() {
        // farther from the quantization boundary → smaller weight
        let w_near = idw_weight(1, 16);
        let w_far = idw_weight(9, 16);
        assert!(w_near > w_far);
        assert!((0.0..=1.0).contains(&w_near) && (0.0..=1.0).contains(&w_far));
    }

    #[test]
    fn compensation_bounded_by_eta_eps() {
        let n = 100;
        let mut data = vec![0.0f32; n];
        let d1: Vec<i64> = (0..n as i64).collect();
        let d2: Vec<i64> = (0..n as i64).rev().collect();
        let sign: Vec<i8> = (0..n).map(|i| if i % 2 == 0 { 1 } else { -1 }).collect();
        let eta_eps = 0.9 * 0.01;
        compensate(&mut data, &d1, &d2, &sign, eta_eps, 1);
        for v in data {
            assert!(v.abs() as f64 <= eta_eps * (1.0 + 1e-6));
        }
    }

    #[test]
    fn zero_sign_means_untouched() {
        let mut data = vec![1.5f32; 8];
        let d1 = vec![1i64; 8];
        let d2 = vec![4i64; 8];
        let sign = vec![0i8; 8];
        compensate(&mut data, &d1, &d2, &sign, 0.5, 1);
        assert!(data.iter().all(|&v| v == 1.5));
    }

    #[test]
    fn threaded_matches_sequential() {
        let n = 1000;
        let d1: Vec<i64> = (0..n as i64).map(|i| (i * 7) % 50).collect();
        let d2: Vec<i64> = (0..n as i64).map(|i| (i * 13) % 60).collect();
        let sign: Vec<i8> = (0..n).map(|i| [(-1i8), 0, 1][i % 3]).collect();
        let mut a = vec![0.25f32; n];
        let mut b = a.clone();
        compensate(&mut a, &d1, &d2, &sign, 0.009, 1);
        compensate(&mut b, &d1, &d2, &sign, 0.009, 4);
        assert_eq!(a, b);
    }

    #[test]
    fn adaptive_taper_fades_deep_interior() {
        // Near the boundary the taper barely changes anything; far away
        // it suppresses the compensation (paper §IX homogeneous regions).
        let n = 4;
        let mut near = vec![0.0f32; n];
        let mut far = vec![0.0f32; n];
        let sign = vec![1i8; n];
        let d2 = vec![10_000i64; n];
        compensate_adaptive(&mut near, &[1; 4], &d2, &sign, 1.0, Some(8.0), 1);
        compensate_adaptive(&mut far, &[40 * 40; 4], &d2, &sign, 1.0, Some(8.0), 1);
        assert!(near[0] > 0.9, "near={}", near[0]);
        assert!(far[0] < 1e-5, "far={}", far[0]);
    }

    #[test]
    fn adaptive_none_matches_plain_compensate() {
        let n = 64;
        let d1: Vec<i64> = (0..n as i64).map(|i| (i * 5) % 37).collect();
        let d2: Vec<i64> = (0..n as i64).map(|i| (i * 11) % 23).collect();
        let sign: Vec<i8> = (0..n).map(|i| [(-1i8), 0, 1][i % 3]).collect();
        let mut a = vec![0.5f32; n];
        let mut b = a.clone();
        compensate(&mut a, &d1, &d2, &sign, 0.02, 1);
        compensate_adaptive(&mut b, &d1, &d2, &sign, 0.02, None, 1);
        assert_eq!(a, b);
    }

    #[test]
    fn adaptive_still_bounded_by_eta_eps() {
        let n = 100;
        let mut data = vec![0.0f32; n];
        let d1: Vec<i64> = (0..n as i64).collect();
        let d2: Vec<i64> = (0..n as i64).rev().collect();
        let sign = vec![-1i8; n];
        compensate_adaptive(&mut data, &d1, &d2, &sign, 0.5, Some(3.0), 1);
        for v in data {
            assert!(v.abs() <= 0.5 * (1.0 + 1e-6));
        }
    }

    #[test]
    fn sign_direction_applied() {
        let mut data = vec![0.0f32, 0.0];
        compensate(&mut data, &[0, 0], &[9, 9], &[1, -1], 0.9, 1);
        assert!(data[0] > 0.0 && data[1] < 0.0);
        assert!((data[0] - 0.9).abs() < 1e-6);
    }
}
