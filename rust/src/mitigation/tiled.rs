//! Tiled streaming executor: O(tile) scratch memory and first-tile
//! latency for the mitigation pipeline (ROADMAP item 2).
//!
//! The whole-field pipeline ([`run_pipeline`]) holds ~10 full-grid
//! intermediates at once, so a job's peak scratch footprint is
//! O(field) — untenable for fields much larger than memory and for
//! dense multi-tenant packing. This module decomposes a job into
//! fixed-size tiles and runs **decode → mitigate → deliver** per tile
//! on the work-stealing pool:
//!
//! * Each tile is expanded by a `halo`-wide ghost zone, **shrink-clamped
//!   to the domain** (the same clamped-window semantics the rank-level
//!   [`crate::coordinator::halo`] module implements with `pad` +
//!   `exchange` — at tile granularity inside one address space the
//!   "exchange" degenerates to reading the neighbor cells straight out
//!   of the shared input, so seams need no messages). Clamping instead
//!   of replicate-padding keeps domain-edge semantics exact: on a
//!   clamped side the window edge *is* the domain edge, so step A's
//!   "domain edges are never boundaries" rule applies identically.
//! * The window runs the unchanged pipeline substrate
//!   ([`run_pipeline`]) with `threads = 1` — windows are self-contained,
//!   which is what makes the output **independent of the lane count by
//!   construction**: parallelism lives across tiles, never inside one.
//! * Every window buffer (indices, data, and the pipeline's
//!   intermediates) is an [`crate::util::arena`] lease, so peak scratch
//!   is bounded by `window_elems × SCRATCH_BYTES_PER_ELEM × lanes` —
//!   provable from the arena's `bytes_peak` high-water counter, not
//!   from trusting this comment. The O(field) output buffer is the
//!   deliverable itself and is deliberately *not* arena-scratch.
//!
//! # Exactness
//!
//! A window computes bit-identical values for its tile interior
//! whenever every tile point's nearest `B₁` and `B₂` boundary points
//! (and the EDT influence cone that selects them) lie inside the
//! window: window boundary masks equal the global ones everywhere
//! except possibly the outermost window rim (a rim point may *miss* a
//! mark whose witness neighbor lies outside the window; it can never
//! gain one), and Maurer's EDT — squared offsets, scan-order
//! tie-breaking — is translation invariant. So with a halo wider than
//! the largest boundary-influence distance the tiled output bit-matches
//! the whole-field path; `halo ≥ max(dims)` makes every window the
//! whole field and the match unconditional. Whatever the halo, the
//! paper's relaxed bound `|out − d| ≤ (1+η)ε` holds per window exactly
//! as it does whole-field (step E never compensates past `η·ε`), so an
//! undersized halo degrades *seam agreement*, never correctness.
//! `rust/tests/tiled.rs` pins the interior-identity and halo matrices.
//!
//! # Latency
//!
//! [`run_tiled_szp`] fuses decoding into the loop: each tile decodes
//! only its own window out of the SZp stream
//! ([`SzpLike::decode_range_on`] seeks the block offset table), so the
//! first tile is mitigated and delivered (observable through the
//! [`TileDone`] callback) before the last tile's bytes are decoded —
//! first-tile latency replaces whole-field latency for streaming
//! consumers. SZ3's dependency cone spans the whole array, so its
//! range decoder is an honest full-replay fallback
//! ([`crate::compressors::sz3::Sz3Like::decode_range_on`]) and gains
//! memory bounds only, not latency.

#![deny(missing_docs)]

use crate::compressors::szp::SzpLike;
use crate::data::grid::{Grid, Shape};
use crate::mitigation::pipeline::{run_pipeline, Backend, MitigationConfig, PipelineStats};
use crate::quant::{dequantize_into, QIndex, ResolvedBound};
use crate::util::arena::ArenaHandle;
use crate::util::pool::{PoolHandle, UnsafeSlice};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Default ghost-zone width (cells per side). Wide enough that the
/// quantization-boundary influence cone fits the window on the dense
/// boundary fields pre-quantization actually produces; override with
/// [`TiledConfig::with_halo`] when a dataset's boundaries are sparse.
pub const DEFAULT_HALO: usize = 8;

/// Conservative per-element scratch bound (bytes) for one window's
/// pipeline run, counting every arena lease held concurrently at the
/// peak (step E): window indices (8) + window data (4) + B₁ mask (1) +
/// boundary signs (1) + Dist₁ (8) + I₁ (4) + propagated signs (1) +
/// B₂ (1) + Dist₂ (8) + leased output copy (4).
pub const SCRATCH_BYTES_PER_ELEM: usize = 40;

/// Tiling knobs: tile shape and ghost-zone width. Carried on a
/// [`Job`](crate::mitigation::service::Job) (set via
/// [`MitigationRequest::tile_shape`](crate::mitigation::engine::MitigationRequest::tile_shape))
/// or installed engine-wide with
/// [`EngineBuilder::tiled`](crate::mitigation::engine::EngineBuilder::tiled).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TiledConfig {
    /// Tile shape (1–3 user dims, like a field shape). A tile with
    /// fewer dims than the field spans the field's leading axes whole —
    /// `[64, 64]` on a 3D field means full-depth 64×64 pencils.
    pub tile: Shape,
    /// Ghost-zone width in cells on each side of a tile (shrink-clamped
    /// at domain edges).
    pub halo: usize,
}

impl TiledConfig {
    /// Tiling with the given tile dims and [`DEFAULT_HALO`].
    pub fn new(tile_dims: &[usize]) -> Self {
        TiledConfig { tile: Shape::new(tile_dims), halo: DEFAULT_HALO }
    }

    /// Replace the ghost-zone width. `halo ≥ max(field dims)` makes
    /// every window the whole field: bit-identical to the whole-field
    /// path unconditionally (and no memory savings — a test anchor, not
    /// a deployment setting).
    pub fn with_halo(mut self, halo: usize) -> Self {
        self.halo = halo;
        self
    }

    /// The tile shape this config uses against `field`, normalized and
    /// clamped: trailing tile dims map onto trailing field axes, leading
    /// field axes a lower-dimensional tile does not name span whole.
    fn effective_tile(&self, field: &Shape) -> [usize; 3] {
        let mut t = [1usize; 3];
        for a in 0..3 {
            t[a] = if a < 3 - self.tile.ndim { field.dims[a] } else { self.tile.dims[a] };
            t[a] = t[a].clamp(1, field.dims[a]);
        }
        t
    }

    /// Largest element count any single window (tile + clamped halo)
    /// can reach on `field` — the per-lane factor of the scratch budget.
    pub fn window_elems(&self, field: &Shape) -> usize {
        let t = self.effective_tile(field);
        (0..3).map(|a| (t[a] + 2 * self.halo).min(field.dims[a])).product()
    }

    /// The arena-scratch budget (bytes) a tiled run of `field` on
    /// `lanes` lanes must stay under:
    /// `window_elems × `[`SCRATCH_BYTES_PER_ELEM`]` × lanes`. Tests
    /// assert the arena's `bytes_peak` counter against this.
    pub fn scratch_budget_bytes(&self, field: &Shape, lanes: usize) -> u64 {
        (self.window_elems(field) * SCRATCH_BYTES_PER_ELEM) as u64 * lanes.max(1) as u64
    }
}

/// One tile of a [`plan`]: where the tile sits, and the halo window
/// around it that its pipeline run actually computes on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TilePlan {
    /// Tile origin in normalized field coordinates.
    pub lo: [usize; 3],
    /// Tile extent (edge tiles may be smaller than the configured
    /// shape).
    pub size: [usize; 3],
    /// Window origin (tile minus halo, clamped at 0).
    pub window_lo: [usize; 3],
    /// Window extent (tile plus halos, clamped to the domain).
    pub window_size: [usize; 3],
}

/// The tile decomposition a tiled run of `field` executes, in the flat
/// tile-index order workers claim from. Exposed so tests and benches
/// can classify tiles (interior vs domain-edge) and reason about
/// windows without re-deriving the geometry.
pub fn plan(field: &Shape, tiled: &TiledConfig) -> Vec<TilePlan> {
    let t = tiled.effective_tile(field);
    let counts = [0, 1, 2].map(|a| field.dims[a].div_ceil(t[a]));
    let mut tiles = Vec::with_capacity(counts[0] * counts[1] * counts[2]);
    for ti in 0..counts[0] {
        for tj in 0..counts[1] {
            for tk in 0..counts[2] {
                let idx = [ti, tj, tk];
                let mut lo = [0usize; 3];
                let mut size = [0usize; 3];
                let mut wlo = [0usize; 3];
                let mut wsize = [0usize; 3];
                for a in 0..3 {
                    lo[a] = idx[a] * t[a];
                    size[a] = t[a].min(field.dims[a] - lo[a]);
                    wlo[a] = lo[a].saturating_sub(tiled.halo);
                    wsize[a] = (lo[a] + size[a] + tiled.halo).min(field.dims[a]) - wlo[a];
                }
                tiles.push(TilePlan { lo, size, window_lo: wlo, window_size: wsize });
            }
        }
    }
    tiles
}

/// A completed tile, announced the instant its interior lands in the
/// output buffer — while other tiles may still be decoding. What a
/// streaming consumer (or the first-tile-latency bench) observes.
#[derive(Debug, Clone, Copy)]
pub struct TileDone {
    /// Flat index into the [`plan`] order.
    pub index: usize,
    /// Tile origin in normalized field coordinates.
    pub lo: [usize; 3],
    /// Tile extent.
    pub size: [usize; 3],
    /// Wall-clock since the tiled run started.
    pub since_start: Duration,
}

/// Copy the sub-block `[lo, lo+size)` of `src` into `out` (row-major,
/// `out.len() == size product`) without allocating — the arena-friendly
/// sibling of [`Grid::extract`].
fn extract_into<T: Copy>(src: &Grid<T>, lo: [usize; 3], size: [usize; 3], out: &mut [T]) {
    debug_assert_eq!(out.len(), size[0] * size[1] * size[2]);
    let mut w = 0usize;
    for i in 0..size[0] {
        for j in 0..size[1] {
            let s = src.shape.idx(lo[0] + i, lo[1] + j, lo[2]);
            out[w..w + size[2]].copy_from_slice(&src.data[s..s + size[2]]);
            w += size[2];
        }
    }
}

/// Write the `[ilo, ilo+size)` sub-block of `win` into the global
/// output at `glo` through disjoint row writes. SAFETY contract of the
/// caller: tiles cover disjoint global ranges, so no two workers ever
/// write the same row segment.
fn scatter_interior(
    out: &UnsafeSlice<'_, f32>,
    out_shape: &Shape,
    win: &Grid<f32>,
    ilo: [usize; 3],
    glo: [usize; 3],
    size: [usize; 3],
) {
    for i in 0..size[0] {
        for j in 0..size[1] {
            let src = win.shape.idx(ilo[0] + i, ilo[1] + j, ilo[2]);
            let dst = out_shape.idx(glo[0] + i, glo[1] + j, glo[2]);
            // SAFETY: per the function contract, [dst, dst+size[2]) is
            // touched by exactly one tile worker.
            let row = unsafe { out.slice_mut(dst, size[2]) };
            row.copy_from_slice(&win.data[src..src + size[2]]);
        }
    }
}

/// Hand a detached/foreign buffer back to a pooled arena (fresh handles
/// just drop it) so warm tiled runs stay allocation-free.
fn recycle(arena: ArenaHandle<'_>, buf: Vec<f32>) {
    if let ArenaHandle::Pooled(a) = arena {
        a.adopt(buf);
    }
}

/// Merge one window's pipeline stats into the run aggregate. Times sum
/// CPU seconds across lanes (they can exceed wall-clock, like any
/// per-core accounting); boundary counts sum over *windows*, so halo
/// overlap double-counts boundary points near seams — observability,
/// not an exactness surface.
fn merge_stats(agg: &Mutex<PipelineStats>, s: &PipelineStats) {
    let mut a = agg.lock().unwrap();
    a.t_boundary += s.t_boundary;
    a.t_edt1 += s.t_edt1;
    a.t_sign += s.t_sign;
    a.t_edt2 += s.t_edt2;
    a.t_compensate += s.t_compensate;
    a.n_boundary1 += s.n_boundary1;
    a.n_boundary2 += s.n_boundary2;
}

/// Run the mitigation pipeline tiled: decompose `dq`/`q` into halo
/// windows, mitigate each on the pool (`cfg.threads` lanes *across*
/// tiles, every window sequential inside), scatter tile interiors into
/// the output. Drop-in for [`run_pipeline`] wherever the inputs are
/// already decoded — the engine dispatches here when a job carries a
/// [`TiledConfig`].
pub(crate) fn run_tiled(
    pool: PoolHandle<'_>,
    arena: ArenaHandle<'_>,
    dq: &Grid<f32>,
    q: &Grid<QIndex>,
    eb: ResolvedBound,
    cfg: &MitigationConfig,
    tiled: &TiledConfig,
) -> anyhow::Result<(Grid<f32>, PipelineStats)> {
    run_tiled_observed(pool, arena, dq, q, eb, cfg, tiled, &|_| {})
}

/// [`run_tiled`] with a per-tile completion callback — the streaming
/// observability hook ([`TileDone`]) the first-tile-latency bench and
/// streaming consumers use. The callback runs on pool workers and must
/// be cheap.
#[allow(clippy::too_many_arguments)]
pub fn run_tiled_observed(
    pool: PoolHandle<'_>,
    arena: ArenaHandle<'_>,
    dq: &Grid<f32>,
    q: &Grid<QIndex>,
    eb: ResolvedBound,
    cfg: &MitigationConfig,
    tiled: &TiledConfig,
    on_tile: &(dyn Fn(TileDone) + Sync),
) -> anyhow::Result<(Grid<f32>, PipelineStats)> {
    assert_eq!(dq.shape, q.shape, "data/index shape mismatch");
    anyhow::ensure!(
        cfg.backend == Backend::Native,
        "the tiled executor is native-only (PJRT windows would round-trip the device per tile)"
    );
    let shape = dq.shape;
    let tiles = plan(&shape, tiled);
    let lanes = cfg.threads.max(1);
    // Sequential inside a window: tile outputs are lane-count-invariant
    // by construction, so interior exactness never depends on threads.
    let wcfg = MitigationConfig { threads: 1, ..*cfg };
    let start = Instant::now();

    // The deliverable is a plain owned allocation, not arena scratch —
    // the whole point is that only *scratch* stays O(tile × lanes).
    let mut out_data = vec![0.0f32; shape.len()];
    let agg = Mutex::new(PipelineStats::default());
    let errors = Mutex::new(Vec::<String>::new());
    {
        let out = UnsafeSlice::new(&mut out_data);
        let tiles = &tiles;
        let agg = &agg;
        let errors = &errors;
        pool.for_range(tiles.len(), lanes, 1, |t| {
            let tp = tiles[t];
            let wn = tp.window_size[0] * tp.window_size[1] * tp.window_size[2];
            let wshape = Shape { dims: tp.window_size, ndim: shape.ndim };
            // Leased window copies of the inputs; RAII-returned even if
            // the pipeline below errors or panics.
            let mut qbuf: Vec<QIndex> = arena.take_stale(wn);
            extract_into(q, tp.window_lo, tp.window_size, &mut qbuf);
            let qwin = arena.relend_grid(Grid { shape: wshape, data: qbuf });
            let mut dbuf: Vec<f32> = arena.take_stale(wn);
            extract_into(dq, tp.window_lo, tp.window_size, &mut dbuf);
            let dwin = arena.relend_grid(Grid { shape: wshape, data: dbuf });

            match run_pipeline(pool, arena, &dwin, &qwin, eb, &wcfg) {
                Ok((wout, wstats)) => {
                    let ilo = [0, 1, 2].map(|a| tp.lo[a] - tp.window_lo[a]);
                    scatter_interior(&out, &shape, &wout, ilo, tp.lo, tp.size);
                    recycle(arena, wout.data);
                    merge_stats(agg, &wstats);
                    on_tile(TileDone {
                        index: t,
                        lo: tp.lo,
                        size: tp.size,
                        since_start: start.elapsed(),
                    });
                }
                Err(e) => errors.lock().unwrap().push(format!("tile {t}: {e:#}")),
            }
        });
    }
    let errs = errors.into_inner().unwrap();
    anyhow::ensure!(errs.is_empty(), "tiled run failed: {}", errs.join("; "));
    Ok((Grid { shape, data: out_data }, agg.into_inner().unwrap()))
}

/// Outcome of a fused streaming run ([`run_tiled_szp`]).
#[derive(Debug)]
pub struct TiledStreamOutcome {
    /// The mitigated field.
    pub output: Grid<f32>,
    /// Aggregated window stats (see the caveats on
    /// [`run_tiled_observed`]'s stats merging).
    pub stats: PipelineStats,
    /// The bound the stream was encoded with.
    pub bound: ResolvedBound,
    /// Number of tiles executed.
    pub tiles: usize,
    /// Wall-clock until the *first* tile was delivered.
    pub first_tile: Duration,
    /// Wall-clock for the whole run.
    pub total: Duration,
}

/// The fused streaming form: decode each tile's window straight out of
/// an SZp stream ([`SzpLike::decode_range_on`] seeks the block offset
/// table, so a window decode is O(window)), dequantize, mitigate, and
/// deliver — the first tile completes before the last tile's bytes are
/// decoded. `codec.threads` parallelizes *within* one range decode and
/// should stay 1 here; lane parallelism across tiles comes from
/// `cfg.threads` exactly as in [`run_tiled`].
pub fn run_tiled_szp(
    pool: PoolHandle<'_>,
    arena: ArenaHandle<'_>,
    codec: &SzpLike,
    stream: &[u8],
    cfg: &MitigationConfig,
    tiled: &TiledConfig,
    on_tile: &(dyn Fn(TileDone) + Sync),
) -> anyhow::Result<TiledStreamOutcome> {
    anyhow::ensure!(
        cfg.backend == Backend::Native,
        "the tiled executor is native-only (PJRT windows would round-trip the device per tile)"
    );
    let (shape, eb) = SzpLike::stream_info(stream)?;
    let tiles = plan(&shape, tiled);
    let lanes = cfg.threads.max(1);
    let wcfg = MitigationConfig { threads: 1, ..*cfg };
    let start = Instant::now();

    let mut out_data = vec![0.0f32; shape.len()];
    let agg = Mutex::new(PipelineStats::default());
    let errors = Mutex::new(Vec::<String>::new());
    let first = Mutex::new(None::<Duration>);
    {
        let out = UnsafeSlice::new(&mut out_data);
        let tiles = &tiles;
        let agg = &agg;
        let errors = &errors;
        let first = &first;
        pool.for_range(tiles.len(), lanes, 1, |t| {
            let tp = tiles[t];
            let wn = tp.window_size[0] * tp.window_size[1] * tp.window_size[2];
            let wshape = Shape { dims: tp.window_size, ndim: shape.ndim };
            let run = || -> anyhow::Result<(Grid<f32>, PipelineStats)> {
                // Decode the window out of the stream: one seeking range
                // decode per maximal contiguous row run of the window.
                let mut qbuf: Vec<QIndex> = arena.take_stale(wn);
                let mut w = 0usize;
                for i in 0..tp.window_size[0] {
                    for j in 0..tp.window_size[1] {
                        let s = shape.idx(tp.window_lo[0] + i, tp.window_lo[1] + j, tp.window_lo[2]);
                        let len = tp.window_size[2];
                        let part = codec.decode_range_on(pool, arena, stream, s..s + len)?;
                        qbuf[w..w + len].copy_from_slice(&part);
                        w += len;
                        recycle_q(arena, part);
                    }
                }
                let qwin = arena.relend_grid(Grid { shape: wshape, data: qbuf });
                let mut dbuf: Vec<f32> = arena.take_stale(wn);
                dequantize_into(&qwin.data, eb, &mut dbuf);
                let dwin = arena.relend_grid(Grid { shape: wshape, data: dbuf });
                run_pipeline(pool, arena, &dwin, &qwin, eb, &wcfg)
            };
            match run() {
                Ok((wout, wstats)) => {
                    let ilo = [0, 1, 2].map(|a| tp.lo[a] - tp.window_lo[a]);
                    scatter_interior(&out, &shape, &wout, ilo, tp.lo, tp.size);
                    recycle(arena, wout.data);
                    merge_stats(agg, &wstats);
                    let done = start.elapsed();
                    {
                        let mut f = first.lock().unwrap();
                        if f.map_or(true, |prev| done < prev) {
                            *f = Some(done);
                        }
                    }
                    on_tile(TileDone { index: t, lo: tp.lo, size: tp.size, since_start: done });
                }
                Err(e) => errors.lock().unwrap().push(format!("tile {t}: {e:#}")),
            }
        });
    }
    let errs = errors.into_inner().unwrap();
    anyhow::ensure!(errs.is_empty(), "tiled streaming run failed: {}", errs.join("; "));
    let total = start.elapsed();
    let n_tiles = tiles.len();
    Ok(TiledStreamOutcome {
        output: Grid { shape, data: out_data },
        stats: agg.into_inner().unwrap(),
        bound: eb,
        tiles: n_tiles,
        first_tile: first.into_inner().unwrap().unwrap_or(total),
        total,
    })
}

/// [`recycle`] for index buffers (the range decoder detaches them).
fn recycle_q(arena: ArenaHandle<'_>, buf: Vec<QIndex>) {
    if let ArenaHandle::Pooled(a) = arena {
        a.adopt(buf);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, DatasetKind};
    use crate::quant::{quantize_grid, ErrorBound};

    #[test]
    fn plan_covers_the_field_exactly_once() {
        let field = Shape::new(&[50, 30, 7]);
        let tiled = TiledConfig::new(&[16, 16, 4]).with_halo(3);
        let tiles = plan(&field, &tiled);
        let mut seen = vec![0u8; field.len()];
        for tp in &tiles {
            for i in 0..tp.size[0] {
                for j in 0..tp.size[1] {
                    for k in 0..tp.size[2] {
                        seen[field.idx(tp.lo[0] + i, tp.lo[1] + j, tp.lo[2] + k)] += 1;
                    }
                }
            }
            for a in 0..3 {
                assert!(tp.window_lo[a] <= tp.lo[a]);
                assert!(
                    tp.window_lo[a] + tp.window_size[a] <= field.dims[a],
                    "window out of bounds"
                );
                assert!(
                    tp.window_lo[a] + tp.window_size[a] >= tp.lo[a] + tp.size[a],
                    "window must contain its tile"
                );
            }
        }
        assert!(seen.iter().all(|&c| c == 1), "tiles must partition the field");
        assert_eq!(tiles.len(), 4 * 2 * 2);
    }

    #[test]
    fn lower_dimensional_tile_spans_leading_axes() {
        let field = Shape::new(&[12, 40, 40]);
        let tiled = TiledConfig::new(&[16, 16]).with_halo(2);
        let tiles = plan(&field, &tiled);
        // Tile axis 0 defaults to the full extent: 3×3 pencils.
        assert_eq!(tiles.len(), 9);
        assert!(tiles.iter().all(|tp| tp.size[0] == 12));
    }

    #[test]
    fn window_elems_and_budget_clamp_to_the_field() {
        let field = Shape::new(&[64, 64]);
        let tiled = TiledConfig::new(&[32, 32]).with_halo(100);
        assert_eq!(tiled.window_elems(&field), 64 * 64);
        assert_eq!(
            tiled.scratch_budget_bytes(&field, 4),
            (64 * 64 * SCRATCH_BYTES_PER_ELEM * 4) as u64
        );
    }

    #[test]
    fn whole_field_halo_is_bit_identical_to_run_pipeline() {
        let orig = generate(DatasetKind::ClimateLike, &[60, 60], 11);
        let eb = ErrorBound::relative(1e-2).resolve(&orig.data);
        let (q, dq) = quantize_grid(&orig, eb);
        let cfg = MitigationConfig::default();
        let whole =
            run_pipeline(PoolHandle::Global, ArenaHandle::Fresh, &dq, &q, eb, &cfg).unwrap().0;
        // halo ≥ max(dims) ⇒ every window is the whole field.
        let tiled = TiledConfig::new(&[16, 16]).with_halo(60);
        let got = run_tiled(PoolHandle::Global, ArenaHandle::Fresh, &dq, &q, eb, &cfg, &tiled)
            .unwrap()
            .0;
        assert_eq!(got.data, whole.data);
        assert_eq!(got.shape, whole.shape);
    }

    #[test]
    fn pjrt_backend_is_rejected() {
        let orig = generate(DatasetKind::ClimateLike, &[16, 16], 1);
        let eb = ErrorBound::relative(1e-2).resolve(&orig.data);
        let (q, dq) = quantize_grid(&orig, eb);
        let cfg = MitigationConfig { backend: Backend::Pjrt, ..Default::default() };
        let tiled = TiledConfig::new(&[8, 8]);
        let err = run_tiled(PoolHandle::Global, ArenaHandle::Fresh, &dq, &q, eb, &cfg, &tiled);
        assert!(err.is_err());
    }
}
