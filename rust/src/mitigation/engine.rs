//! The one front door for mitigation work: a typed request/response
//! API over sharded admission queues, with tenant-aware routing and
//! per-tenant admission quotas.
//!
//! Three PRs of organic growth scattered the entry points across
//! layers — `mitigate` / `mitigate_with_stats` / `mitigate_with_stats_on`,
//! three `MitigationService` constructors, `SubmitOptions`, and two
//! `mitigate_batch*` variants — and every new capability (pool
//! confinement, arenas, deadlines) added another positional-argument
//! variant. This module collapses that combinatorics into two types:
//!
//! * [`MitigationRequest`] — a builder carrying the payload
//!   (`Arc`-backed [`SharedGrid`]s, so building and submitting never
//!   copy field data), the [`MitigationConfig`], the scheduling class,
//!   an optional completion deadline, an optional blocking-submit
//!   timeout, an optional tenant id, and a per-step-stats opt-in.
//! * [`Engine`] — `N` admission-queue shards behind a router (built via
//!   [`EngineBuilder`]: shard count, per-shard queue/pool config,
//!   shared-vs-per-shard arena, quota table). Requests with a tenant id
//!   route by consistent hash, so one tenant's jobs always land on the
//!   same shard (cache-warm arenas, per-tenant ordering); tenant-less
//!   requests fall back to the least-loaded shard. Per-tenant quotas
//!   bound how many of a tenant's jobs may be in flight at once
//!   ([`SubmitError::QuotaExceeded`]); within each shard, dispatch is
//!   earliest-deadline-first inside a priority class.
//!
//! Execution is **bit-identical** to the legacy entry points: every
//! path runs the same pipeline substrate, so sharding, routing, and
//! quotas are pure scheduling/throughput knobs (the engine exactness
//! matrix in `rust/tests/engine.rs` proves it against the legacy
//! paths). The legacy functions survive as `#[deprecated]` thin
//! wrappers; `docs/SERVING.md` has the old-call → new-request migration
//! table.
//!
//! For one-off synchronous work with no queue at all, use the free
//! functions: [`execute`] (caller thread, global pool, fresh arena —
//! the exact legacy `mitigate_with_stats` behavior) or [`execute_on`]
//! (explicit pool + arena — the legacy `mitigate_with_stats_on`).
//!
//! # Examples
//!
//! ```
//! use qai::data::synthetic::{generate, DatasetKind};
//! use qai::mitigation::engine::{Engine, MitigationRequest};
//! use qai::quant::{quantize_grid, ErrorBound};
//!
//! let orig = generate(DatasetKind::ClimateLike, &[16, 16], 7);
//! let eb = ErrorBound::relative(1e-2).resolve(&orig.data);
//! let (q, dq) = quantize_grid(&orig, eb);
//!
//! let engine = Engine::builder().shards(2).quota("acme", 4).build();
//! let response = engine
//!     .run(MitigationRequest::new(dq, q, eb).tenant("acme").with_stats(true))
//!     .unwrap();
//! assert_eq!(response.output.len(), 16 * 16);
//! assert!(response.stats.unwrap().total() >= 0.0);
//! ```

#![deny(missing_docs)]

use crate::data::grid::{Grid, SharedGrid};
use crate::mitigation::admission::{
    Admission, AdmissionLease, JobReport, LatencySnapshot, Priority, ServiceStats, SubmitError,
    SubmitOptions,
};
use crate::mitigation::pipeline::{run_pipeline, MitigationConfig, PipelineStats};
use crate::mitigation::quality::{self, QualityTarget};
use crate::mitigation::service::{
    render_latency_labeled, render_metrics_labeled, render_transport_labeled, Job, ServiceConfig,
};
use crate::mitigation::tiled::{run_tiled, TiledConfig};
use crate::quant::{QIndex, ResolvedBound};
use crate::util::arena::{Arena, ArenaHandle, ArenaStats};
use crate::util::hist::LatencyPair;
use crate::util::pool::{PoolHandle, ThreadPool};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// One typed unit of mitigation work: payload, pipeline configuration,
/// and scheduling metadata, assembled with chainable builder methods.
///
/// The payload is held as `Arc`-backed [`SharedGrid`]s — building a
/// request from pre-shared grids is a pointer bump, and a rejected
/// submission hands the same allocation back inside the
/// [`SubmitError`] (recover it with [`SubmitError::into_job`] and
/// [`MitigationRequest::from_job`]).
#[derive(Clone)]
pub struct MitigationRequest {
    pub(crate) job: Job,
    pub(crate) priority: Priority,
    pub(crate) deadline: Option<Duration>,
    pub(crate) timeout: Option<Duration>,
    pub(crate) tenant: Option<String>,
    pub(crate) collect_stats: bool,
    /// Process-wide monotonic trace id, assigned at construction and
    /// threaded ticket → report → response (cloning a request — e.g. a
    /// retry of the same logical work — keeps the id).
    pub(crate) trace_id: u64,
}

impl MitigationRequest {
    /// A bulk-priority request with the default pipeline configuration,
    /// no deadline, no tenant, and per-step stats off. Accepts owned
    /// [`Grid`]s or pre-shared [`SharedGrid`]s.
    pub fn new(
        dq: impl Into<SharedGrid<f32>>,
        q: impl Into<SharedGrid<QIndex>>,
        eb: ResolvedBound,
    ) -> Self {
        MitigationRequest::from_job(Job::new(dq, q, eb))
    }

    /// Wrap an existing [`Job`] (payload + pipeline config) with
    /// default scheduling metadata — the bridge from the legacy API and
    /// from jobs recovered out of a [`SubmitError`].
    pub fn from_job(job: Job) -> Self {
        MitigationRequest {
            job,
            priority: Priority::Bulk,
            deadline: None,
            timeout: None,
            tenant: None,
            collect_stats: false,
            trace_id: crate::mitigation::admission::next_trace_id(),
        }
    }

    /// Replace the pipeline configuration (η, per-job threads, backend,
    /// taper).
    pub fn config(mut self, cfg: MitigationConfig) -> Self {
        self.job.cfg = cfg;
        self
    }

    /// Set the scheduling class explicitly.
    pub fn priority(mut self, priority: Priority) -> Self {
        self.priority = priority;
        self
    }

    /// Shorthand for [`Priority::Interactive`].
    pub fn interactive(self) -> Self {
        self.priority(Priority::Interactive)
    }

    /// Shorthand for [`Priority::Bulk`] (the default).
    pub fn bulk(self) -> Self {
        self.priority(Priority::Bulk)
    }

    /// Attach a completion budget measured from submission. Jobs with
    /// deadlines are dispatched earliest-deadline-first within their
    /// priority class; an overrun job still completes but is flagged in
    /// its [`MitigationResponse`] and in the shard stats.
    pub fn deadline(mut self, deadline: Duration) -> Self {
        self.deadline = Some(deadline);
        self
    }

    /// Bound how long a blocking [`Engine::submit`] may wait for queue
    /// space (ignored by [`Engine::try_submit`]).
    pub fn submit_timeout(mut self, timeout: Duration) -> Self {
        self.timeout = Some(timeout);
        self
    }

    /// Tag the request with a tenant id: routes it to the tenant's
    /// consistent-hash shard and subjects it to the tenant's admission
    /// quota (if one is configured).
    pub fn tenant(mut self, tenant: impl Into<String>) -> Self {
        self.tenant = Some(tenant.into());
        self
    }

    /// Opt into per-step pipeline stats on the response (off by
    /// default; the stats are cheap but rarely needed in production).
    pub fn with_stats(mut self, collect: bool) -> Self {
        self.collect_stats = collect;
        self
    }

    /// Attach the original (pre-compression) field. The serving layer
    /// scores the output against it with the fused metric kernels and
    /// reports the score in [`MitigationResponse::quality`]; required
    /// by [`MitigationRequest::quality_target`]. Accepts an owned
    /// [`Grid`] or a pre-shared [`SharedGrid`] (a pointer bump).
    pub fn reference(mut self, reference: impl Into<SharedGrid<f32>>) -> Self {
        self.job.reference = Some(reference.into());
        self
    }

    /// Ask the engine to *meet* a quality floor instead of trusting the
    /// request's fixed config: on the first job for this
    /// (tenant, shape) the shard runs a bounded online search over
    /// (η, taper, filter) candidates and caches the winner, so
    /// steady-state traffic pays one closed-form mitigation plus one
    /// inline metric evaluation (see [`crate::mitigation::quality`]).
    /// Requires [`MitigationRequest::reference`]; without it the job
    /// fails with an error naming the missing field.
    pub fn quality_target(mut self, target: QualityTarget) -> Self {
        self.job.target = Some(target);
        self
    }

    /// Run this request tiled with the given tile shape (1–3 dims, like
    /// a field shape; fewer dims than the field span the leading axes
    /// whole) and the default halo: the targetless execution path
    /// streams tile-by-tile through [`crate::mitigation::tiled`] with
    /// O(tile × lanes) arena scratch instead of O(field). Shorthand for
    /// [`MitigationRequest::tiled`] with
    /// [`TiledConfig::new`](crate::mitigation::tiled::TiledConfig::new).
    pub fn tile_shape(self, tile_dims: &[usize]) -> Self {
        self.tiled(TiledConfig::new(tile_dims))
    }

    /// Run this request through the tiled streaming executor with an
    /// explicit [`TiledConfig`] (tile shape + halo width). Ignored by
    /// quality-targeted requests — the auto-tuner's candidate search
    /// stays on the whole-field path.
    pub fn tiled(mut self, tiled: TiledConfig) -> Self {
        self.job.tiled = Some(tiled);
        self
    }

    /// The payload + pipeline config this request carries.
    pub fn job(&self) -> &Job {
        &self.job
    }

    /// The tenant id, if any.
    pub fn tenant_id(&self) -> Option<&str> {
        self.tenant.as_deref()
    }

    /// The request's process-wide monotonic trace id (assigned at
    /// construction). Follows the job across shard, queue, and lane:
    /// it reappears on the [`ResponseTicket`], the
    /// [`MitigationResponse`], the admission-layer
    /// [`JobReport`](crate::mitigation::admission::JobReport), and the
    /// `last_trace=` token of the metrics lines.
    pub fn trace_id(&self) -> u64 {
        self.trace_id
    }

    /// Recover the payload, dropping the scheduling metadata.
    pub fn into_job(self) -> Job {
        self.job
    }

    fn submit_options(&self) -> SubmitOptions {
        SubmitOptions {
            priority: self.priority,
            deadline: self.deadline,
            timeout: self.timeout,
        }
    }
}

impl std::fmt::Debug for MitigationRequest {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("MitigationRequest")
            .field("trace_id", &self.trace_id)
            .field("dims", &self.job.dq.shape.user_dims())
            .field("priority", &self.priority)
            .field("deadline", &self.deadline)
            .field("tenant", &self.tenant)
            .field("collect_stats", &self.collect_stats)
            .finish()
    }
}

/// The outcome of one successfully executed request.
#[derive(Debug)]
pub struct MitigationResponse {
    /// The compensated field (freshly owned; hand its buffer back via
    /// [`Engine::recycle`] to make warm outputs allocation-free).
    pub output: Grid<f32>,
    /// Per-step pipeline stats — `Some` iff the request opted in with
    /// [`MitigationRequest::with_stats`].
    pub stats: Option<PipelineStats>,
    /// Shard the job ran on (`None` for the synchronous [`execute`] /
    /// [`execute_on`] paths, which bypass the queue).
    pub shard: Option<usize>,
    /// Tenant the request was tagged with.
    pub tenant: Option<String>,
    /// The shard's dequeue sequence number (`None` off-queue).
    pub seq: Option<u64>,
    /// The request's trace id (see [`MitigationRequest::trace_id`]).
    pub trace_id: u64,
    /// Scheduling class the request ran as.
    pub priority: Priority,
    /// Submission → start of pipeline execution (zero off-queue).
    pub queue_wait: Duration,
    /// Pipeline execution duration.
    pub exec: Duration,
    /// Deadline the request carried, if any.
    pub deadline: Option<Duration>,
    /// True iff a deadline was set and `queue_wait + exec` exceeded it.
    pub deadline_missed: bool,
    /// Output quality against the request's reference field
    /// ([`MitigationRequest::reference`]): PSNR in dB for
    /// [`QualityTarget::Psnr`] requests, fused gaussian SSIM otherwise.
    /// `None` when the request carried no reference.
    pub quality: Option<f64>,
}

/// Completion handle for one admitted request. Resolves exactly once;
/// [`ResponseTicket::wait`] always returns eventually on a draining
/// engine (see the admission-layer ticket contract).
pub struct ResponseTicket {
    inner: crate::mitigation::admission::JobTicket,
    shard: usize,
    tenant: Option<String>,
    collect_stats: bool,
    trace_id: u64,
}

impl ResponseTicket {
    /// Shard the request was routed to.
    pub fn shard(&self) -> usize {
        self.shard
    }

    /// Tenant the request was tagged with.
    pub fn tenant(&self) -> Option<&str> {
        self.tenant.as_deref()
    }

    /// The request's trace id (see [`MitigationRequest::trace_id`]) —
    /// readable before the job completes, so a caller can log the id
    /// it is about to wait on.
    pub fn trace_id(&self) -> u64 {
        self.trace_id
    }

    /// True once the response is ready (a subsequent `wait` returns
    /// immediately).
    pub fn is_complete(&self) -> bool {
        self.inner.is_complete()
    }

    /// Block until the job finishes and convert its report.
    pub fn wait(self) -> anyhow::Result<MitigationResponse> {
        let ResponseTicket { inner, shard, tenant, collect_stats, trace_id: _ } = self;
        into_response(inner.wait(), Some(shard), tenant, collect_stats)
    }

    /// Non-blocking poll: the response if the job finished, the ticket
    /// back otherwise.
    pub fn try_wait(self) -> Result<anyhow::Result<MitigationResponse>, ResponseTicket> {
        let ResponseTicket { inner, shard, tenant, collect_stats, trace_id } = self;
        match inner.try_wait() {
            Ok(report) => Ok(into_response(report, Some(shard), tenant, collect_stats)),
            Err(inner) => Err(ResponseTicket { inner, shard, tenant, collect_stats, trace_id }),
        }
    }

    /// [`ResponseTicket::wait`] bounded by `timeout`; the ticket comes
    /// back if the job is still running.
    pub fn wait_timeout(
        self,
        timeout: Duration,
    ) -> Result<anyhow::Result<MitigationResponse>, ResponseTicket> {
        let ResponseTicket { inner, shard, tenant, collect_stats, trace_id } = self;
        match inner.wait_timeout(timeout) {
            Ok(report) => Ok(into_response(report, Some(shard), tenant, collect_stats)),
            Err(inner) => Err(ResponseTicket { inner, shard, tenant, collect_stats, trace_id }),
        }
    }
}

impl std::fmt::Debug for ResponseTicket {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ResponseTicket")
            .field("trace_id", &self.trace_id)
            .field("shard", &self.shard)
            .field("tenant", &self.tenant)
            .field("complete", &self.is_complete())
            .finish()
    }
}

fn into_response(
    report: JobReport,
    shard: Option<usize>,
    tenant: Option<String>,
    collect_stats: bool,
) -> anyhow::Result<MitigationResponse> {
    let trace_id = report.trace_id;
    let (output, stats) = report.result?;
    Ok(MitigationResponse {
        output,
        stats: if collect_stats { Some(stats) } else { None },
        shard,
        tenant,
        seq: Some(report.seq),
        trace_id,
        priority: report.priority,
        queue_wait: report.queue_wait,
        exec: report.exec,
        deadline: report.deadline,
        deadline_missed: report.deadline_missed,
        quality: report.quality,
    })
}

/// Run a request synchronously on the caller thread — global pool,
/// fresh (non-recycling) arena — bypassing every queue. Bit-identical
/// to the legacy `mitigate_with_stats` free function, which is now a
/// wrapper over this substrate.
pub fn execute(request: &MitigationRequest) -> anyhow::Result<MitigationResponse> {
    execute_on(PoolHandle::Global, ArenaHandle::Fresh, request)
}

/// [`execute`] with the pipeline's parallel regions confined to `pool`
/// and its full-grid buffers acquired through `arena` — the single
/// replacement for the legacy `*_on` variant combinatorics.
pub fn execute_on(
    pool: PoolHandle<'_>,
    arena: ArenaHandle<'_>,
    request: &MitigationRequest,
) -> anyhow::Result<MitigationResponse> {
    let job = &request.job;
    let start = Instant::now();
    // Queue-free path: quality targets run the bounded search inline on
    // every call — there is no shard, hence no tuned-parameter cache.
    let (output, stats, quality) = match job.target {
        Some(target) => {
            let Some(reference) = job.reference.as_ref() else {
                anyhow::bail!(
                    "quality target {target:?} requires a reference field on the request"
                );
            };
            let outcome = quality::search(pool, arena, job, reference, target)?;
            (outcome.output, outcome.stats, Some(outcome.quality))
        }
        None => {
            let (output, stats) = match &job.tiled {
                Some(t) => run_tiled(pool, arena, &job.dq, &job.q, job.eb, &job.cfg, t)?,
                None => run_pipeline(pool, arena, &job.dq, &job.q, job.eb, &job.cfg)?,
            };
            let quality = job
                .reference
                .as_ref()
                .map(|r| quality::evaluate(pool, arena, r, &output, None, job.cfg.threads));
            (output, stats, quality)
        }
    };
    let exec = start.elapsed();
    Ok(MitigationResponse {
        output,
        stats: if request.collect_stats { Some(stats) } else { None },
        shard: None,
        tenant: request.tenant.clone(),
        seq: None,
        trace_id: request.trace_id,
        priority: request.priority,
        queue_wait: Duration::ZERO,
        exec,
        deadline: request.deadline,
        deadline_missed: request.deadline.is_some_and(|d| exec > d),
        quality,
    })
}

/// Point-in-time snapshot of one tenant's engine-level accounting.
#[derive(Debug, Clone, PartialEq)]
pub struct TenantStats {
    /// Tenant id.
    pub tenant: String,
    /// In cap mode (`rate == 0`): configured max in-flight admissions
    /// (`None` = unlimited). In token-bucket mode (`rate > 0`): the
    /// bucket size (burst tolerance).
    pub quota: Option<u64>,
    /// Token refill rate in admissions/second; `0.0` means the legacy
    /// concurrency-cap mode.
    pub rate: f64,
    /// Current token balance (gauge; refilled lazily at snapshot
    /// time). Always `0.0` in cap mode.
    pub tokens: f64,
    /// Requests admitted for this tenant.
    pub submitted: u64,
    /// Requests rejected with [`SubmitError::QuotaExceeded`].
    pub rejected_quota: u64,
    /// Requests currently admitted and not yet finished (gauge).
    pub in_flight: u64,
}

/// Point-in-time snapshot of a whole engine: per-shard admission
/// counters plus per-tenant quota accounting.
#[derive(Debug, Clone)]
pub struct EngineStats {
    /// One [`ServiceStats`] per shard, indexed by shard id.
    pub shards: Vec<ServiceStats>,
    /// Per-tenant accounting, sorted by tenant id.
    pub tenants: Vec<TenantStats>,
}

impl EngineStats {
    /// Sum the per-shard counters into one engine-wide view. Gauges
    /// (`queue_depth`, `running`) and duration totals add across
    /// shards; `max_queue_depth` is the max over per-shard high-water
    /// marks (per-shard peaks need not coincide in time, so a sum would
    /// overstate the global peak).
    pub fn aggregate(&self) -> ServiceStats {
        let mut agg = ServiceStats::default();
        for s in &self.shards {
            agg.submitted += s.submitted;
            agg.rejected_full += s.rejected_full;
            agg.submit_timeouts += s.submit_timeouts;
            agg.completed += s.completed;
            agg.failed += s.failed;
            agg.cancelled += s.cancelled;
            agg.interactive_done += s.interactive_done;
            agg.bulk_done += s.bulk_done;
            agg.deadlines_set += s.deadlines_set;
            agg.deadlines_missed += s.deadlines_missed;
            agg.max_queue_depth = agg.max_queue_depth.max(s.max_queue_depth);
            agg.queue_depth += s.queue_depth;
            agg.running += s.running;
            agg.total_queue_wait_s += s.total_queue_wait_s;
            agg.total_exec_s += s.total_exec_s;
            agg.shed_infeasible += s.shed_infeasible;
            agg.sched_wakeups += s.sched_wakeups;
            agg.lanes_grown += s.lanes_grown;
            agg.lanes_shrunk += s.lanes_shrunk;
            agg.lane_cap += s.lane_cap;
            agg.quality_hits += s.quality_hits;
            agg.quality_misses += s.quality_misses;
            agg.quality_evicted += s.quality_evicted;
            // Trace ids are process-wide monotonic: the engine-wide
            // "most recent" is the max over shards.
            agg.last_trace_id = agg.last_trace_id.max(s.last_trace_id);
        }
        agg
    }

    /// Total quota rejections across all tenants.
    pub fn quota_rejections(&self) -> u64 {
        self.tenants.iter().map(|t| t.rejected_quota).sum()
    }
}

/// Soft cap on dynamically-tracked tenants. When the table is at the
/// cap and an unseen tenant id arrives, one idle (zero in-flight)
/// dynamically-created entry is evicted — its counters reset if the
/// tenant returns — so high-cardinality tenant ids (per-user,
/// per-session) cannot grow the table, the stats snapshot, or the
/// metrics output without bound. Entries configured with
/// [`EngineBuilder::quota`] are never evicted, and entries with jobs
/// in flight are kept (quota correctness wins over the cap, so the
/// table can transiently exceed it — bounded by the number of
/// distinct tenants simultaneously in flight).
pub const MAX_TRACKED_TENANTS: usize = 4096;

/// Per-tenant engine-level accounting: either a legacy concurrency
/// cap (`rate == 0`, admission gated on in-flight count) or a weighted
/// token bucket (`rate > 0`, admission consumes one token; the bucket
/// refills lazily from elapsed time — no refill thread exists).
struct TenantEntry {
    /// Cap mode: max in-flight (`None` = unlimited). Bucket mode: the
    /// bucket size (burst tolerance), always `Some`.
    quota: Option<u64>,
    /// Token refill rate in admissions/second; `0.0` selects cap mode.
    rate: f64,
    /// Current token balance (bucket mode; starts full).
    tokens: f64,
    /// Instant of the last lazy refill (bucket mode).
    last_refill: Instant,
    /// True for tenants pre-configured via [`EngineBuilder::quota`] /
    /// [`EngineBuilder::quota_rate`] / [`EngineBuilder::quota_weight`]
    /// (never evicted from the tracking table).
    configured: bool,
    /// Shared with the [`QuotaLease`]s attached to this tenant's
    /// in-flight jobs, which decrement it on drop.
    in_flight: Arc<AtomicU64>,
    submitted: u64,
    rejected_quota: u64,
}

impl TenantEntry {
    /// A cap-mode entry (the legacy in-flight quota).
    fn cap(quota: Option<u64>, configured: bool) -> Self {
        TenantEntry {
            quota,
            rate: 0.0,
            tokens: 0.0,
            last_refill: Instant::now(),
            configured,
            in_flight: Arc::new(AtomicU64::new(0)),
            submitted: 0,
            rejected_quota: 0,
        }
    }

    /// A token-bucket entry; the bucket starts full (burst-tolerant
    /// from the first request).
    fn bucket(rate: f64, burst: u64, configured: bool) -> Self {
        let burst = burst.max(1);
        TenantEntry {
            quota: Some(burst),
            rate: rate.max(0.0),
            tokens: burst as f64,
            last_refill: Instant::now(),
            configured,
            in_flight: Arc::new(AtomicU64::new(0)),
            submitted: 0,
            rejected_quota: 0,
        }
    }

    /// Lazy elapsed-time refill (bucket mode; a no-op in cap mode).
    /// Called on every admission attempt and on stats snapshots, so
    /// the bucket never needs its own timer thread.
    fn refill(&mut self, now: Instant) {
        if self.rate > 0.0 {
            let elapsed = now.saturating_duration_since(self.last_refill).as_secs_f64();
            let burst = self.quota.unwrap_or(1).max(1) as f64;
            self.tokens = (self.tokens + elapsed * self.rate).min(burst);
            self.last_refill = now;
        }
    }
}

/// Dropped by the admission layer exactly when the job leaves the
/// service (completion, failure, cancellation, or failed admission) —
/// releasing the tenant's quota slot.
struct QuotaLease {
    in_flight: Arc<AtomicU64>,
}

impl Drop for QuotaLease {
    fn drop(&mut self) {
        self.in_flight.fetch_sub(1, Ordering::SeqCst);
    }
}

/// Stable (cross-run, cross-platform) 64-bit FNV-1a — the consistent
/// tenant → shard hash, also reused by the cluster's rendezvous
/// node routing (`cluster::registry`). `std`'s `DefaultHasher` is
/// randomized per process, which would break router determinism.
pub(crate) fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Make a tenant id safe to embed as a `key=value` metrics token.
fn metrics_safe(tenant: &str) -> String {
    tenant
        .chars()
        .map(|c| if c.is_whitespace() || c == '=' { '_' } else { c })
        .collect()
}

/// Builder for an [`Engine`]: shard count, per-shard queue/pool
/// configuration, arena sharing policy, and the per-tenant quota
/// table.
///
/// # Examples
///
/// ```
/// use qai::mitigation::engine::Engine;
/// use qai::util::pool::ThreadPool;
/// use std::sync::Arc;
///
/// // Two shards confined to one shared 4-lane pool, a shared arena,
/// // 32 queued jobs per shard, "acme" capped at 8 in-flight jobs and
/// // everyone else at 16.
/// let engine = Engine::builder()
///     .shards(2)
///     .capacity(32)
///     .pool(Arc::new(ThreadPool::new(4)))
///     .shared_arena(true)
///     .quota("acme", 8)
///     .default_quota(16)
///     .build();
/// assert_eq!(engine.shards(), 2);
/// let acme = engine.tenant_stats("acme").unwrap();
/// assert_eq!((acme.quota, acme.submitted, acme.in_flight), (Some(8), 0, 0));
/// ```
#[derive(Clone, Default)]
pub struct EngineBuilder {
    shards: usize,
    template: ServiceConfig,
    lanes_per_shard: Option<usize>,
    shared_arena: bool,
    quotas: Vec<(String, u64)>,
    quota_rates: Vec<(String, f64, u64)>,
    quota_weights: Vec<(String, f64)>,
    default_quota: Option<u64>,
    default_rate: f64,
    default_burst: Option<u64>,
    tiled: Option<TiledConfig>,
    pin_workers: Option<bool>,
}

impl EngineBuilder {
    /// Number of admission-queue shards (minimum 1; the default).
    /// Each shard has its own bounded queue and scheduler; a tenant's
    /// requests consistently hash to one shard.
    pub fn shards(mut self, shards: usize) -> Self {
        self.shards = shards;
        self
    }

    /// Per-shard bounded queue capacity (default
    /// [`DEFAULT_QUEUE_CAPACITY`](crate::mitigation::service::DEFAULT_QUEUE_CAPACITY)).
    pub fn capacity(mut self, capacity: usize) -> Self {
        self.template.capacity = capacity;
        self
    }

    /// Confine every shard (cross-job fan-out and each job's internal
    /// steps A–E) to one shared explicit pool. Mutually exclusive with
    /// [`EngineBuilder::lanes_per_shard`], which takes precedence.
    pub fn pool(mut self, pool: Arc<ThreadPool>) -> Self {
        self.template.pool = Some(pool);
        self
    }

    /// Give each shard its own private pool of `lanes` lanes — hard
    /// CPU isolation between shards. Overrides
    /// [`EngineBuilder::pool`].
    pub fn lanes_per_shard(mut self, lanes: usize) -> Self {
        self.lanes_per_shard = Some(lanes.max(1));
        self
    }

    /// Share one scratch-buffer arena across all shards (default:
    /// one arena per shard). Sharing lets same-shaped jobs recycle
    /// buffers across shard boundaries — the right choice when shards
    /// exist for queue isolation rather than memory isolation.
    pub fn shared_arena(mut self, shared: bool) -> Self {
        self.shared_arena = shared;
        self
    }

    /// Start with all shards paused (nothing runs until
    /// [`Engine::resume`]); used by maintenance drains and the
    /// deterministic ordering tests.
    pub fn start_paused(mut self, paused: bool) -> Self {
        self.template.start_paused = paused;
        self
    }

    /// Apply a full per-shard [`ServiceConfig`] template (queue
    /// capacity, pool, paused start, and — if set — an explicit arena,
    /// which implies [`EngineBuilder::shared_arena`] across shards).
    pub fn shard_config(mut self, cfg: ServiceConfig) -> Self {
        self.template = cfg;
        self
    }

    /// Cap `tenant` at `max_in_flight` concurrently admitted requests.
    /// The `max_in_flight + 1`-th submission while all are in flight is
    /// rejected with [`SubmitError::QuotaExceeded`] and hands the job
    /// back for retry; the slot frees as soon as one of the tenant's
    /// jobs finishes (or is cancelled).
    pub fn quota(mut self, tenant: impl Into<String>, max_in_flight: u64) -> Self {
        self.quotas.push((tenant.into(), max_in_flight));
        self
    }

    /// Quota applied to tenants without an explicit
    /// [`EngineBuilder::quota`] entry (default: unlimited).
    pub fn default_quota(mut self, max_in_flight: u64) -> Self {
        self.default_quota = Some(max_in_flight);
        self
    }

    /// Give `tenant` a token-bucket quota: admissions consume one
    /// token each, the bucket holds at most `burst` tokens (so a
    /// quiet tenant can absorb a burst of that size), and it refills
    /// at `rate` tokens/second — computed lazily from elapsed time at
    /// each admission attempt, so no refill thread exists. An empty
    /// bucket rejects with [`SubmitError::QuotaExceeded`], exactly
    /// like the cap mode. Overrides any [`EngineBuilder::quota`]
    /// entry for the same tenant.
    pub fn quota_rate(mut self, tenant: impl Into<String>, rate: f64, burst: u64) -> Self {
        self.quota_rates.push((tenant.into(), rate, burst));
        self
    }

    /// Weighted fair share: give `tenant` a token bucket refilling at
    /// `weight` × the [`EngineBuilder::default_quota_rate`] (so a
    /// weight-2 tenant sustains twice the default admission rate),
    /// with the default burst. A no-op unless a default rate is set;
    /// explicit [`EngineBuilder::quota`] / [`EngineBuilder::quota_rate`]
    /// entries for the same tenant win.
    pub fn quota_weight(mut self, tenant: impl Into<String>, weight: f64) -> Self {
        self.quota_weights.push((tenant.into(), weight));
        self
    }

    /// Token-bucket refill rate (tokens/second) applied to tenants
    /// without an explicit entry. Setting this switches dynamically
    /// seen tenants from cap mode to token-bucket mode (bucket size:
    /// [`EngineBuilder::default_quota_burst`], else
    /// [`EngineBuilder::default_quota`], else 1).
    pub fn default_quota_rate(mut self, rate: f64) -> Self {
        self.default_rate = rate.max(0.0);
        self
    }

    /// Bucket size used with [`EngineBuilder::default_quota_rate`] for
    /// tenants without an explicit entry.
    pub fn default_quota_burst(mut self, burst: u64) -> Self {
        self.default_burst = Some(burst.max(1));
        self
    }

    /// Enable deadline-infeasibility shedding on every shard: a
    /// deadline-carrying request whose projected completion (EWMA
    /// service time per (tenant, shape), scaled by queue depth over
    /// lanes) provably overruns its deadline is rejected at admission
    /// with [`SubmitError::DeadlineInfeasible`] instead of executing
    /// and missing. Requests with no history are always admitted.
    pub fn shed(mut self, shed: bool) -> Self {
        self.template.shed = shed;
        self
    }

    /// Enable adaptive lane scaling on every shard: a shard observing
    /// fresh deadline misses grows its dynamic lane cap into parked
    /// pool capacity, an idle shard shrinks it (observable via the
    /// `lanes_grown` / `lanes_shrunk` / `lane_cap` counters in
    /// [`ServiceStats`]). Off by default — the cap is then statically
    /// the pool's lane count.
    pub fn adaptive_lanes(mut self, adaptive: bool) -> Self {
        self.template.adaptive_lanes = adaptive;
        self
    }

    /// Pin the workers of every per-shard private pool created by
    /// [`EngineBuilder::lanes_per_shard`] to distinct CPUs
    /// (round-robin over the machine, Linux only — a no-op elsewhere).
    /// Defaults to the process-wide `QAI_POOL_PIN` knob
    /// ([`pin_workers_default`](crate::util::pool::pin_workers_default)).
    /// Has no effect on an explicitly supplied
    /// [`EngineBuilder::pool`], whose pinning was decided when that
    /// pool was built.
    pub fn pin_workers(mut self, pin: bool) -> Self {
        self.pin_workers = Some(pin);
        self
    }

    /// Default tiling for every submitted request that does not carry
    /// its own [`MitigationRequest::tiled`] / `tile_shape` setting: the
    /// engine-wide memory-bounding policy knob (`qai serve --tile`).
    /// Per-request settings win; quality-targeted requests stay on the
    /// whole-field path either way.
    pub fn tiled(mut self, tiled: TiledConfig) -> Self {
        self.tiled = Some(tiled);
        self
    }

    /// Build the engine: spawn-ready shards (schedulers start lazily on
    /// first submission), the router, and the pre-populated quota
    /// table.
    pub fn build(self) -> Engine {
        let n = self.shards.max(1);
        // An explicit arena in the template is shared by definition;
        // otherwise the policy knob decides shared-vs-per-shard.
        let shared_arena = match self.template.arena.clone() {
            Some(arena) => Some(arena),
            None if self.shared_arena => Some(Arena::new()),
            None => None,
        };
        let shards: Vec<Admission> = (0..n)
            .map(|_| {
                let pool = match self.lanes_per_shard {
                    Some(lanes) => {
                        let pin = self
                            .pin_workers
                            .unwrap_or_else(crate::util::pool::pin_workers_default);
                        Some(Arc::new(ThreadPool::with_pinning(lanes, pin)))
                    }
                    None => self.template.pool.clone(),
                };
                let arena = shared_arena.clone().unwrap_or_default();
                Admission::new(
                    pool,
                    self.template.capacity,
                    self.template.start_paused,
                    arena,
                    self.template.shed,
                    self.template.adaptive_lanes,
                )
            })
            .collect();
        let mut tenants = BTreeMap::new();
        for (tenant, max) in self.quotas {
            tenants.insert(tenant, TenantEntry::cap(Some(max), true));
        }
        // Explicit token buckets override cap entries for the same
        // tenant; weights only fill gaps (and need a default rate).
        for (tenant, rate, burst) in self.quota_rates {
            tenants.insert(tenant, TenantEntry::bucket(rate, burst, true));
        }
        if self.default_rate > 0.0 {
            let burst = self.default_burst.or(self.default_quota).unwrap_or(1);
            for (tenant, weight) in self.quota_weights {
                let rate = self.default_rate * weight.max(0.0);
                tenants.entry(tenant).or_insert_with(|| TenantEntry::bucket(rate, burst, true));
            }
        }
        Engine {
            shards,
            tenants: Mutex::new(tenants),
            default_quota: self.default_quota,
            default_rate: self.default_rate,
            default_burst: self.default_burst,
            shared_arena,
            default_tiled: self.tiled,
            transport: Mutex::new(Vec::new()),
        }
    }
}

/// A source of cluster/fabric traffic counters the engine surfaces in
/// [`Engine::metrics_text`] as `scope=transport` lines. Implemented by
/// the cluster's per-node counter set
/// (`cluster::node::ClusterTransportStats`) and the in-process
/// fabric's snapshot handle (`coordinator::transport::FabricTransportStats`).
pub trait TransportStatsSource: Send + Sync {
    /// The node id these counters belong to.
    fn transport_node(&self) -> u64;
    /// One counter snapshot per peer.
    fn transport_counters(&self) -> Vec<crate::cluster::transport::PeerCounters>;
}

/// A sharded mitigation engine: `N` bounded admission queues behind a
/// consistent-hash router, with per-tenant admission quotas and
/// EDF-within-priority dispatch. See the [module docs](self) for the
/// full model and [`EngineBuilder`] for construction.
pub struct Engine {
    shards: Vec<Admission>,
    tenants: Mutex<BTreeMap<String, TenantEntry>>,
    default_quota: Option<u64>,
    /// Default token-bucket refill rate for dynamically seen tenants
    /// (`0.0` = cap mode).
    default_rate: f64,
    /// Default bucket size accompanying `default_rate`.
    default_burst: Option<u64>,
    /// `Some` when all shards share one arena (for aggregate stats
    /// that must not double-count).
    shared_arena: Option<Arena>,
    /// Engine-wide default tiling ([`EngineBuilder::tiled`]); applied
    /// at submission to requests without their own setting.
    default_tiled: Option<TiledConfig>,
    /// Attached transport counter sources ([`Engine::attach_transport`])
    /// rendered as `scope=transport` metrics lines.
    transport: Mutex<Vec<Arc<dyn TransportStatsSource>>>,
}

impl Default for Engine {
    fn default() -> Self {
        Engine::builder().build()
    }
}

impl Engine {
    /// Start building an engine.
    pub fn builder() -> EngineBuilder {
        EngineBuilder::default()
    }

    /// A single-shard engine from a legacy [`ServiceConfig`] — the
    /// substrate under the deprecated `MitigationService`
    /// constructors.
    pub(crate) fn single(cfg: ServiceConfig) -> Engine {
        Engine::builder().shard_config(cfg).build()
    }

    /// Direct admission-layer access for the legacy service wrapper.
    pub(crate) fn admission(&self, shard: usize) -> &Admission {
        &self.shards[shard]
    }

    /// Number of shards.
    pub fn shards(&self) -> usize {
        self.shards.len()
    }

    /// The shard a tenant's requests consistently hash to. Stable
    /// across runs and platforms (FNV-1a), so a deployment can predict
    /// placement.
    pub fn shard_for_tenant(&self, tenant: &str) -> usize {
        (fnv1a(tenant.as_bytes()) % self.shards.len() as u64) as usize
    }

    /// Route a request: tenants hash consistently; tenant-less
    /// requests go to the least-loaded shard (fewest queued + running,
    /// ties to the lowest index).
    fn route(&self, tenant: Option<&str>) -> usize {
        if self.shards.len() == 1 {
            return 0;
        }
        match tenant {
            Some(t) => self.shard_for_tenant(t),
            None => {
                let mut best = 0usize;
                let mut best_load = usize::MAX;
                for (i, shard) in self.shards.iter().enumerate() {
                    let st = shard.stats();
                    let load = st.queue_depth + st.running;
                    if load < best_load {
                        best = i;
                        best_load = load;
                    }
                }
                best
            }
        }
    }

    /// Check the tenant's quota and claim a slot. `Err` means at
    /// quota; `Ok` carries the lease whose drop releases the slot.
    fn admit_tenant(&self, tenant: &str) -> Result<AdmissionLease, ()> {
        let mut table = self.tenants.lock().unwrap();
        if !table.contains_key(tenant) && table.len() >= MAX_TRACKED_TENANTS {
            // Make room: evict the first idle dynamically-created
            // entry (deterministic: BTreeMap order). See
            // [`MAX_TRACKED_TENANTS`].
            let victim = table
                .iter()
                .find(|(_, e)| !e.configured && e.in_flight.load(Ordering::SeqCst) == 0)
                .map(|(id, _)| id.clone());
            if let Some(id) = victim {
                table.remove(&id);
            }
        }
        let default_quota = self.default_quota;
        let default_rate = self.default_rate;
        let default_burst = self.default_burst;
        let entry = table.entry(tenant.to_string()).or_insert_with(|| {
            if default_rate > 0.0 {
                let burst = default_burst.or(default_quota).unwrap_or(1);
                TenantEntry::bucket(default_rate, burst, false)
            } else {
                TenantEntry::cap(default_quota, false)
            }
        });
        if entry.rate > 0.0 {
            // Token-bucket mode: lazy refill, then consume one token.
            entry.refill(Instant::now());
            if entry.tokens < 1.0 {
                entry.rejected_quota += 1;
                return Err(());
            }
            entry.tokens -= 1.0;
        } else if let Some(max) = entry.quota {
            if entry.in_flight.load(Ordering::SeqCst) >= max {
                entry.rejected_quota += 1;
                return Err(());
            }
        }
        entry.in_flight.fetch_add(1, Ordering::SeqCst);
        entry.submitted += 1;
        Ok(Box::new(QuotaLease { in_flight: entry.in_flight.clone() }))
    }

    fn submit_inner(
        &self,
        request: MitigationRequest,
        blocking: bool,
    ) -> Result<ResponseTicket, SubmitError> {
        let opts = request.submit_options();
        let MitigationRequest { mut job, tenant, collect_stats, trace_id, .. } = request;
        // Engine-wide default tiling; a request's own setting wins.
        if job.tiled.is_none() {
            job.tiled = self.default_tiled;
        }
        let lease = match tenant.as_deref() {
            Some(t) => match self.admit_tenant(t) {
                Ok(lease) => Some(lease),
                Err(()) => return Err(SubmitError::QuotaExceeded(job)),
            },
            None => None,
        };
        let shard = self.route(tenant.as_deref());
        // On rejection the admission layer drops the lease before
        // returning, so the quota slot frees with the error.
        let admitted = if blocking {
            self.shards[shard].submit_leased(job, opts, lease, trace_id, tenant.clone())
        } else {
            self.shards[shard].try_submit_leased(job, opts, lease, trace_id, tenant.clone())
        };
        match admitted {
            Ok(inner) => Ok(ResponseTicket { inner, shard, tenant, collect_stats, trace_id }),
            Err(e) => {
                // The queue pushed back (full/timeout/shutdown): undo
                // the tenant's `submitted` bump so the counter reports
                // only requests actually admitted.
                if let Some(t) = tenant.as_deref() {
                    if let Some(entry) = self.tenants.lock().unwrap().get_mut(t) {
                        // Saturating: the entry could in principle have
                        // been evicted and recreated in between.
                        entry.submitted = entry.submitted.saturating_sub(1);
                    }
                }
                Err(e)
            }
        }
    }

    /// Non-blocking admission: route and enqueue the request, or fail
    /// immediately — [`SubmitError::QuotaExceeded`] when the tenant is
    /// at quota, [`SubmitError::QueueFull`] when the routed shard's
    /// queue is at capacity. Every error hands the job back.
    pub fn try_submit(&self, request: MitigationRequest) -> Result<ResponseTicket, SubmitError> {
        self.submit_inner(request, false)
    }

    /// Blocking admission: wait for queue space on the routed shard,
    /// bounded by [`MitigationRequest::submit_timeout`] if set. Quota
    /// rejection is immediate (it does not wait for a slot): shed or
    /// retry on [`SubmitError::QuotaExceeded`].
    pub fn submit(&self, request: MitigationRequest) -> Result<ResponseTicket, SubmitError> {
        self.submit_inner(request, true)
    }

    /// Submit (blocking) and wait: the one-call request → response
    /// path.
    pub fn run(&self, request: MitigationRequest) -> anyhow::Result<MitigationResponse> {
        match self.submit(request) {
            Ok(ticket) => ticket.wait(),
            Err(e) => Err(anyhow::anyhow!("submission failed: {e}")),
        }
    }

    /// Run every request and return slot `i` of the output for
    /// `requests[i]` — the typed replacement for the legacy
    /// `mitigate_batch*` wrappers (which now delegate here).
    /// Submissions block for queue space; per-request failures
    /// (including quota rejections and pipeline panics) land in their
    /// own slot and cannot poison siblings.
    pub fn run_batch(
        &self,
        requests: Vec<MitigationRequest>,
    ) -> Vec<anyhow::Result<MitigationResponse>> {
        if requests.is_empty() {
            return Vec::new();
        }
        let submitted: Vec<anyhow::Result<ResponseTicket>> = requests
            .into_iter()
            .map(|request| {
                self.submit(request).map_err(|e| anyhow::anyhow!("batch admission failed: {e}"))
            })
            .collect();
        submitted
            .into_iter()
            .map(|ticket| match ticket {
                Ok(ticket) => ticket.wait(),
                Err(e) => Err(e),
            })
            .collect()
    }

    /// Stop draining every shard queue (submissions are still accepted
    /// until each queue fills; running jobs finish normally).
    pub fn pause(&self) {
        for shard in &self.shards {
            shard.pause();
        }
    }

    /// Resume draining after [`Engine::pause`] (or a paused build).
    pub fn resume(&self) {
        for shard in &self.shards {
            shard.resume();
        }
    }

    /// Admission counters of one shard.
    pub fn shard_stats(&self, shard: usize) -> ServiceStats {
        self.shards[shard].stats()
    }

    /// One tenant's engine-level accounting. `Some` for tenants
    /// pre-configured with [`EngineBuilder::quota`] and for any tenant
    /// that has attempted a submission; `None` for ids the engine has
    /// never seen.
    pub fn tenant_stats(&self, tenant: &str) -> Option<TenantStats> {
        let mut table = self.tenants.lock().unwrap();
        table.get_mut(tenant).map(|e| {
            // Refill-on-read so the token gauge is live, not stale
            // since the last admission attempt.
            e.refill(Instant::now());
            TenantStats {
                tenant: tenant.to_string(),
                quota: e.quota,
                rate: e.rate,
                tokens: e.tokens,
                submitted: e.submitted,
                rejected_quota: e.rejected_quota,
                in_flight: e.in_flight.load(Ordering::SeqCst),
            }
        })
    }

    /// Full engine snapshot: per-shard admission counters plus
    /// per-tenant quota accounting (sorted by tenant id).
    pub fn stats(&self) -> EngineStats {
        let shards = self.shards.iter().map(|s| s.stats()).collect();
        let tenants = {
            let mut table = self.tenants.lock().unwrap();
            let now = Instant::now();
            table
                .iter_mut()
                .map(|(tenant, e)| {
                    e.refill(now);
                    TenantStats {
                        tenant: tenant.clone(),
                        quota: e.quota,
                        rate: e.rate,
                        tokens: e.tokens,
                        submitted: e.submitted,
                        rejected_quota: e.rejected_quota,
                        in_flight: e.in_flight.load(Ordering::SeqCst),
                    }
                })
                .collect()
        };
        EngineStats { shards, tenants }
    }

    /// Per-class latency histogram snapshot of one shard (queue-wait /
    /// service-time split; see [`LatencySnapshot`]).
    pub fn shard_latency(&self, shard: usize) -> LatencySnapshot {
        self.shards[shard].latency()
    }

    /// Queue-wait / service-time histograms for one tenant, recorded
    /// on its consistent-hash shard. `None` before any of the tenant's
    /// jobs has completed.
    pub fn tenant_latency(&self, tenant: &str) -> Option<LatencyPair> {
        self.shards[self.shard_for_tenant(tenant)].tenant_latency(tenant)
    }

    /// A handle to one shard's scratch-buffer arena (with a shared
    /// arena, every shard returns the same one).
    pub fn shard_arena(&self, shard: usize) -> Arena {
        self.shards[shard].arena().clone()
    }

    /// Aggregate arena counters: the shared arena's stats when shards
    /// share one, otherwise the field-wise sum over per-shard arenas.
    pub fn arena_stats(&self) -> ArenaStats {
        if let Some(arena) = &self.shared_arena {
            return arena.stats();
        }
        let mut agg = ArenaStats::default();
        for shard in &self.shards {
            let s = shard.arena().stats();
            agg.hits += s.hits;
            agg.misses += s.misses;
            agg.returns += s.returns;
            agg.detached += s.detached;
            agg.adopted += s.adopted;
            agg.dropped += s.dropped;
            agg.bytes_outstanding += s.bytes_outstanding;
            agg.bytes_pooled += s.bytes_pooled;
            // Per-shard high-water marks need not coincide in time, so
            // their sum is an upper bound on the true aggregate peak
            // (exact when shards share one arena — handled above).
            agg.bytes_peak += s.bytes_peak;
        }
        agg
    }

    /// Hand a finished output grid's buffer back for reuse — into the
    /// shared arena when one exists, shard 0's otherwise. (To target a
    /// specific shard's arena under per-shard isolation, go through
    /// [`Engine::shard_arena`] and [`Arena::adopt`].)
    pub fn recycle(&self, grid: Grid<f32>) {
        match &self.shared_arena {
            Some(arena) => arena.adopt(grid.data),
            None => self.shards[0].arena().adopt(grid.data),
        }
    }

    /// Attach a transport counter source; its per-peer traffic appears
    /// in [`Engine::metrics_text`] as `scope=transport` lines. Used by
    /// the cluster layer (`ClusterEngine`/`ClusterServer` node
    /// counters) and the distributed driver's fabric snapshot.
    pub fn attach_transport(&self, source: Arc<dyn TransportStatsSource>) {
        self.transport.lock().unwrap().push(source);
    }

    /// Engine counters rendered as scrapeable `key=value` text, one
    /// line per scope: an aggregate `scope=engine` line, one
    /// `shard=<i>` line per shard, one `scope=latency` line per shard
    /// and priority class with completions (p50/p99/mean, queue-wait
    /// vs service-time split), one `tenant=<id>` line per tenant
    /// (quota/bucket state plus the tenant's latency quantiles once
    /// jobs have completed), and — when a transport source is attached
    /// ([`Engine::attach_transport`]) — one aggregate
    /// `scope=transport` line per source plus one `peer=<id>` line per
    /// peer. Every line is independently parseable `key=value` tokens
    /// (the `qai serve --metrics` format).
    pub fn metrics_text(&self) -> String {
        let stats = self.stats();
        let agg = stats.aggregate();
        let arena_agg = self.arena_stats();
        let nshards = self.shards.len().to_string();
        let quota_rejections = stats.quota_rejections().to_string();
        let mut out = render_metrics_labeled(
            &[
                ("scope", "engine"),
                ("shards", nshards.as_str()),
                ("quota_rejections", quota_rejections.as_str()),
            ],
            &agg,
            &arena_agg,
        );
        for (i, shard) in self.shards.iter().enumerate() {
            out.push('\n');
            let idx = i.to_string();
            out.push_str(&render_metrics_labeled(
                &[("shard", idx.as_str())],
                &shard.stats(),
                &shard.arena().stats(),
            ));
        }
        for (i, shard) in self.shards.iter().enumerate() {
            let lat = shard.latency();
            let idx = i.to_string();
            for (class, pair) in [("interactive", &lat.interactive), ("bulk", &lat.bulk)] {
                if pair.wait.count() == 0 {
                    continue;
                }
                out.push('\n');
                out.push_str(&render_latency_labeled(
                    &[("scope", "latency"), ("shard", idx.as_str()), ("class", class)],
                    pair,
                ));
            }
        }
        for t in &stats.tenants {
            out.push('\n');
            out.push_str(&format!(
                "tenant={} quota={} rate={:.3} tokens={:.3} submitted={} rejected_quota={} in_flight={}",
                metrics_safe(&t.tenant),
                t.quota.map_or_else(|| "unlimited".to_string(), |q| q.to_string()),
                t.rate,
                t.tokens,
                t.submitted,
                t.rejected_quota,
                t.in_flight,
            ));
            if let Some(pair) = self.tenant_latency(&t.tenant) {
                out.push_str(&format!(
                    " wait_p50_ms={:.3} wait_p99_ms={:.3} exec_p50_ms={:.3} exec_p99_ms={:.3}",
                    pair.wait.quantile_ms(0.50),
                    pair.wait.quantile_ms(0.99),
                    pair.exec.quantile_ms(0.50),
                    pair.exec.quantile_ms(0.99),
                ));
            }
        }
        for source in self.transport.lock().unwrap().iter() {
            let node = source.transport_node().to_string();
            let counters = source.transport_counters();
            let totals = counters.iter().fold((0u64, 0u64, 0u64, 0u64), |acc, c| {
                (
                    acc.0 + c.sent_bytes,
                    acc.1 + c.sent_msgs,
                    acc.2 + c.recv_bytes,
                    acc.3 + c.recv_msgs,
                )
            });
            out.push('\n');
            out.push_str(&format!(
                "scope=transport node={} peers={} total_sent_bytes={} total_sent_msgs={} total_recv_bytes={} total_recv_msgs={}",
                node,
                counters.len(),
                totals.0,
                totals.1,
                totals.2,
                totals.3,
            ));
            for c in &counters {
                out.push('\n');
                out.push_str(&render_transport_labeled(
                    &[("scope", "transport"), ("node", node.as_str())],
                    c,
                ));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv1a_is_stable() {
        // Pinned values: the router hash must never drift across
        // refactors, or tenants silently migrate between shards.
        assert_eq!(fnv1a(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a(b"a"), 0xaf63_dc4c_8601_ec8c);
    }

    #[test]
    fn builder_clamps_shards_to_one() {
        let engine = Engine::builder().shards(0).build();
        assert_eq!(engine.shards(), 1);
    }

    #[test]
    fn tenant_routing_is_deterministic() {
        let engine = Engine::builder().shards(4).build();
        for tenant in ["alpha", "beta", "gamma", ""] {
            let first = engine.shard_for_tenant(tenant);
            assert!(first < 4);
            for _ in 0..3 {
                assert_eq!(engine.shard_for_tenant(tenant), first);
            }
        }
    }

    #[test]
    fn metrics_safe_escapes_token_breakers() {
        assert_eq!(metrics_safe("a b=c"), "a_b_c");
        assert_eq!(metrics_safe("plain"), "plain");
    }
}
