//! The mitigation serving layer: jobs, the service façade, and its
//! configuration.
//!
//! [`MitigationService`] is the front door the ROADMAP's
//! production scenario talks to: many independent fields arriving
//! concurrently (one per user request, ensemble member, or timestep).
//! Jobs stream in through the bounded admission queue
//! ([`crate::mitigation::admission`]) via [`MitigationService::submit`]
//! / [`MitigationService::try_submit`], execute on a persistent
//! [`pool`](crate::util::pool) — the process-global one by default, or
//! the pool given to [`MitigationService::with_pool`] — and resolve
//! per-job [`JobTicket`]s. The legacy slice-in/vec-out
//! [`MitigationService::mitigate_batch`] survives as a thin wrapper
//! over the same queue.
//!
//! Pool confinement: a service built [`with_pool`] runs **everything**
//! on that pool — the cross-job fan-out *and* each job's internal steps
//! A–E, via the [`PoolHandle`](crate::util::pool::PoolHandle) plumbing
//! through the pipeline. The global pool is never touched, which the
//! confinement test suite asserts.
//!
//! Guarantees:
//!
//! * **Exactness** — each job's output is bit-identical to a standalone
//!   [`mitigate_with_stats`](crate::mitigation::pipeline::mitigate_with_stats)
//!   call with the same inputs (the pipeline is schedule-independent),
//!   so batching and queueing are pure throughput knobs.
//! * **Isolation** — a failing job (error *or* panic, e.g. a shape
//!   mismatch) resolves only its own ticket with an `Err` and cannot
//!   poison sibling jobs.
//! * **Determinism** — outputs depend only on job inputs, never on
//!   queue order, concurrency, priorities, or pool sizing.
//!
//! [`with_pool`]: MitigationService::with_pool
//!
//! # Examples
//!
//! ```
//! use qai::data::synthetic::{generate, DatasetKind};
//! use qai::mitigation::admission::SubmitOptions;
//! use qai::mitigation::{Job, MitigationService};
//! use qai::quant::{quantize_grid, ErrorBound};
//!
//! let orig = generate(DatasetKind::ClimateLike, &[16, 16], 7);
//! let eb = ErrorBound::relative(1e-2).resolve(&orig.data);
//! let (q, dq) = quantize_grid(&orig, eb);
//!
//! let service = MitigationService::new();
//! let ticket = service.submit(Job::new(dq, q, eb), SubmitOptions::bulk()).unwrap();
//! let (grid, stats) = ticket.wait().result.unwrap();
//! assert_eq!(grid.len(), 16 * 16);
//! assert!(stats.total() >= 0.0);
//! ```

#![deny(missing_docs)]

use crate::data::grid::Grid;
use crate::mitigation::admission::{Admission, JobTicket, ServiceStats, SubmitError, SubmitOptions};
use crate::mitigation::pipeline::{MitigationConfig, PipelineStats};
use crate::quant::{QIndex, ResolvedBound};
use crate::util::pool::ThreadPool;
use std::sync::Arc;

/// One unit of served work: a decompressed field, its quantization
/// indices, the resolved bound, and the per-job pipeline configuration.
#[derive(Clone)]
pub struct Job {
    /// Decompressed data `d'`.
    pub dq: Grid<f32>,
    /// Quantization-index field.
    pub q: Grid<QIndex>,
    /// Resolved error bound the field was compressed with.
    pub eb: ResolvedBound,
    /// Pipeline configuration (η, per-job threads, backend, taper).
    pub cfg: MitigationConfig,
}

impl Job {
    /// Convenience constructor with the default pipeline configuration.
    pub fn new(dq: Grid<f32>, q: Grid<QIndex>, eb: ResolvedBound) -> Self {
        Job { dq, q, eb, cfg: MitigationConfig::default() }
    }
}

/// Result of one served job.
pub type JobResult = anyhow::Result<(Grid<f32>, PipelineStats)>;

/// Default bound on the number of queued (not yet running) jobs.
pub const DEFAULT_QUEUE_CAPACITY: usize = 256;

/// Construction-time knobs of a [`MitigationService`].
#[derive(Clone)]
pub struct ServiceConfig {
    /// Pool that carries the cross-job fan-out **and** every job's
    /// internal steps; `None` uses the process-global pool.
    pub pool: Option<Arc<ThreadPool>>,
    /// Bounded admission-queue capacity (values below 1 are clamped
    /// to 1). See [`DEFAULT_QUEUE_CAPACITY`].
    pub capacity: usize,
    /// Start with draining paused: submissions are accepted (up to
    /// capacity) but nothing runs until
    /// [`MitigationService::resume`]. Used by maintenance drains and
    /// the deterministic ordering tests.
    pub start_paused: bool,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig { pool: None, capacity: DEFAULT_QUEUE_CAPACITY, start_paused: false }
    }
}

/// A mitigation server: a bounded streaming admission queue over a
/// persistent thread pool (the process-wide
/// [`pool::global`](crate::util::pool::global) by default, or an
/// explicitly sized pool for isolation).
pub struct MitigationService {
    admission: Admission,
}

impl Default for MitigationService {
    fn default() -> Self {
        MitigationService::new()
    }
}

impl MitigationService {
    /// Service over the process-wide global pool with default settings.
    pub fn new() -> Self {
        MitigationService::with_config(ServiceConfig::default())
    }

    /// Service confined to an explicit pool: the cross-job fan-out and
    /// each job's internal steps A–E all run on `pool`, never the
    /// global one.
    pub fn with_pool(pool: Arc<ThreadPool>) -> Self {
        MitigationService::with_config(ServiceConfig { pool: Some(pool), ..Default::default() })
    }

    /// Service with explicit [`ServiceConfig`] knobs.
    pub fn with_config(cfg: ServiceConfig) -> Self {
        MitigationService { admission: Admission::new(cfg.pool, cfg.capacity, cfg.start_paused) }
    }

    /// Non-blocking admission: enqueue `job` or fail immediately with
    /// [`SubmitError::QueueFull`] (carrying the job back) when the
    /// queue is at capacity.
    pub fn try_submit(&self, job: Job, opts: SubmitOptions) -> Result<JobTicket, SubmitError> {
        self.admission.try_submit(job, opts)
    }

    /// Blocking admission: wait for queue space, bounded by
    /// `opts.timeout` if set ([`SubmitError::Timeout`] on expiry).
    pub fn submit(&self, job: Job, opts: SubmitOptions) -> Result<JobTicket, SubmitError> {
        self.admission.submit(job, opts)
    }

    /// Stop draining the queue. Submissions are still accepted until
    /// the queue fills; jobs already running finish normally.
    pub fn pause(&self) {
        self.admission.pause();
    }

    /// Resume draining after [`MitigationService::pause`] (or a
    /// [`ServiceConfig::start_paused`] construction).
    pub fn resume(&self) {
        self.admission.resume();
    }

    /// Snapshot of the admission counters and gauges.
    pub fn stats(&self) -> ServiceStats {
        self.admission.stats()
    }

    /// Compatibility wrapper over the queue: run every job and return
    /// slot `i` of the output for `jobs[i]`, exactly like the original
    /// slice-in/vec-out batch API. Per-job failures (including panics
    /// out of the pipeline) are captured in their own slot, and outputs
    /// are bit-identical to per-field
    /// [`mitigate_with_stats`](crate::mitigation::pipeline::mitigate_with_stats)
    /// calls.
    ///
    /// Jobs are cloned into the queue (the streaming API takes
    /// ownership; this borrowed-slice shim predates it) and submitted
    /// as [`Priority::Bulk`](crate::mitigation::admission::Priority),
    /// blocking for space when the batch exceeds the queue capacity —
    /// so do not call it on a paused service with a batch larger than
    /// the capacity.
    pub fn mitigate_batch(&self, jobs: &[Job]) -> Vec<JobResult> {
        if jobs.is_empty() {
            return Vec::new();
        }
        let tickets: Vec<JobTicket> = jobs
            .iter()
            .map(|job| {
                // Infallible while `&self` is alive: shutdown only
                // happens in drop, and no timeout is set.
                self.submit(job.clone(), SubmitOptions::bulk())
                    .unwrap_or_else(|e| panic!("batch admission failed: {e}"))
            })
            .collect();
        tickets
            .into_iter()
            .enumerate()
            .map(|(i, ticket)| {
                // Re-label errors with the batch slot (the queue's own
                // messages are slot-agnostic), matching the original
                // slice-in/vec-out API.
                ticket.wait().result.map_err(|e| anyhow::anyhow!("job {i}: {e:#}"))
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, DatasetKind};
    use crate::mitigation::pipeline::mitigate_with_stats;
    use crate::quant::{quantize_grid, ErrorBound};

    fn job(kind: DatasetKind, dims: &[usize], seed: u64) -> Job {
        let orig = generate(kind, dims, seed);
        let eb = ErrorBound::relative(1e-2).resolve(&orig.data);
        let (q, dq) = quantize_grid(&orig, eb);
        Job::new(dq, q, eb)
    }

    #[test]
    fn empty_batch_is_empty() {
        assert!(MitigationService::new().mitigate_batch(&[]).is_empty());
    }

    #[test]
    fn single_job_matches_direct_call() {
        // Serialized: first submit spawns a counted scheduler thread
        // (see `pool::test_guard`).
        let _g = crate::util::pool::test_guard();
        let j = job(DatasetKind::ClimateLike, &[48, 48], 3);
        let direct = mitigate_with_stats(&j.dq, &j.q, j.eb, &j.cfg).unwrap();
        let service = MitigationService::new();
        let got = service.mitigate_batch(std::slice::from_ref(&j));
        let (out, stats) = got.into_iter().next().unwrap().unwrap();
        assert_eq!(out.data, direct.0.data);
        assert_eq!(stats.n_boundary1, direct.1.n_boundary1);
        assert_eq!(stats.n_boundary2, direct.1.n_boundary2);
    }

    #[test]
    fn shape_mismatch_is_an_error_not_a_panic() {
        let _g = crate::util::pool::test_guard();
        let mut j = job(DatasetKind::ClimateLike, &[16, 16], 1);
        j.q = Grid::from_vec(vec![0i64; 64], &[8, 8]);
        let got = MitigationService::new().mitigate_batch(&[j]);
        assert!(got[0].is_err());
        let msg = got[0].as_ref().unwrap_err().to_string();
        assert!(msg.contains("shape"), "msg={msg}");
    }

    #[test]
    fn batch_updates_service_stats() {
        let _g = crate::util::pool::test_guard();
        let service = MitigationService::new();
        let jobs = vec![
            job(DatasetKind::ClimateLike, &[24, 24], 2),
            job(DatasetKind::ClimateLike, &[24, 24], 3),
        ];
        let results = service.mitigate_batch(&jobs);
        assert!(results.iter().all(|r| r.is_ok()));
        let st = service.stats();
        assert_eq!(st.submitted, 2);
        assert_eq!(st.completed, 2);
        assert_eq!(st.bulk_done, 2);
        assert_eq!(st.failed, 0);
        assert_eq!(st.queue_depth, 0);
    }
}
