//! The mitigation serving layer: jobs, the service façade, and its
//! configuration.
//!
//! [`MitigationService`] is the front door the ROADMAP's
//! production scenario talks to: many independent fields arriving
//! concurrently (one per user request, ensemble member, or timestep).
//! Jobs stream in through the bounded admission queue
//! ([`crate::mitigation::admission`]) via [`MitigationService::submit`]
//! / [`MitigationService::try_submit`], execute on a persistent
//! [`pool`](crate::util::pool) — the process-global one by default, or
//! the pool given to [`MitigationService::with_pool`] — and resolve
//! per-job [`JobTicket`]s. The legacy slice-in/vec-out
//! [`MitigationService::mitigate_batch`] survives as a thin wrapper
//! over the same queue (with an owning
//! [`mitigate_batch_owned`](MitigationService::mitigate_batch_owned)
//! sibling that skips even the pointer clones).
//!
//! The data plane is zero-copy: [`Job`] payloads are `Arc`-backed
//! [`SharedGrid`]s, so submission and queueing move pointers, and every
//! full-grid scratch buffer plus the output of each job cycles through
//! the service's per-service [`Arena`] — a warm same-shaped job
//! allocates no full-grid buffers at all (see
//! [`MitigationService::arena_stats`] and the [`Job`] ownership
//! contract).
//!
//! Pool confinement: a service built [`with_pool`] runs **everything**
//! on that pool — the cross-job fan-out *and* each job's internal steps
//! A–E, via the [`PoolHandle`](crate::util::pool::PoolHandle) plumbing
//! through the pipeline. The global pool is never touched, which the
//! confinement test suite asserts.
//!
//! Guarantees:
//!
//! * **Exactness** — each job's output is bit-identical to a standalone
//!   [`mitigate_with_stats`](crate::mitigation::pipeline::mitigate_with_stats)
//!   call with the same inputs (the pipeline is schedule-independent),
//!   so batching and queueing are pure throughput knobs.
//! * **Isolation** — a failing job (error *or* panic, e.g. a shape
//!   mismatch) resolves only its own ticket with an `Err` and cannot
//!   poison sibling jobs.
//! * **Determinism** — outputs depend only on job inputs, never on
//!   queue order, concurrency, priorities, or pool sizing.
//!
//! [`with_pool`]: MitigationService::with_pool
//!
//! # Examples
//!
//! ```
//! use qai::data::synthetic::{generate, DatasetKind};
//! use qai::mitigation::admission::SubmitOptions;
//! use qai::mitigation::{Job, MitigationService};
//! use qai::quant::{quantize_grid, ErrorBound};
//!
//! let orig = generate(DatasetKind::ClimateLike, &[16, 16], 7);
//! let eb = ErrorBound::relative(1e-2).resolve(&orig.data);
//! let (q, dq) = quantize_grid(&orig, eb);
//!
//! let service = MitigationService::new();
//! let ticket = service.submit(Job::new(dq, q, eb), SubmitOptions::bulk()).unwrap();
//! let (grid, stats) = ticket.wait().result.unwrap();
//! assert_eq!(grid.len(), 16 * 16);
//! assert!(stats.total() >= 0.0);
//! ```

#![deny(missing_docs)]

use crate::data::grid::{Grid, SharedGrid};
use crate::mitigation::admission::{Admission, JobTicket, ServiceStats, SubmitError, SubmitOptions};
use crate::mitigation::pipeline::{MitigationConfig, PipelineStats};
use crate::quant::{QIndex, ResolvedBound};
use crate::util::arena::{Arena, ArenaStats};
use crate::util::pool::ThreadPool;
use std::sync::Arc;

/// One unit of served work: a decompressed field, its quantization
/// indices, the resolved bound, and the per-job pipeline configuration.
///
/// # Sharing & ownership contract
///
/// The grids are held as [`SharedGrid`]s — immutable, `Arc`-backed
/// payloads. Cloning a `Job` (and everything the service does with one:
/// [`MitigationService::submit`], the admission queue, the
/// [`mitigate_batch`](MitigationService::mitigate_batch) compat
/// wrapper) is a pointer bump; grid data is **never copied** on the
/// submission path, which [`SharedGrid::ptr_eq`] makes observable. A
/// caller may keep clones of the inputs while the job is queued or
/// running, and may mutate its copy only through the copy-on-write
/// escape hatch ([`SharedGrid::make_mut`]), which cannot affect a job
/// already submitted. Outputs are freshly-owned [`Grid`]s: the service
/// allocates them (from its arena), the caller owns them, and
/// [`MitigationService::recycle`] optionally hands their buffers back.
#[derive(Clone)]
pub struct Job {
    /// Decompressed data `d'` (shared, immutable).
    pub dq: SharedGrid<f32>,
    /// Quantization-index field (shared, immutable).
    pub q: SharedGrid<QIndex>,
    /// Resolved error bound the field was compressed with.
    pub eb: ResolvedBound,
    /// Pipeline configuration (η, per-job threads, backend, taper).
    pub cfg: MitigationConfig,
}

impl Job {
    /// Convenience constructor with the default pipeline configuration.
    /// Accepts owned [`Grid`]s or pre-shared [`SharedGrid`]s.
    pub fn new(
        dq: impl Into<SharedGrid<f32>>,
        q: impl Into<SharedGrid<QIndex>>,
        eb: ResolvedBound,
    ) -> Self {
        Job::with_config(dq, q, eb, MitigationConfig::default())
    }

    /// [`Job::new`] with an explicit pipeline configuration.
    pub fn with_config(
        dq: impl Into<SharedGrid<f32>>,
        q: impl Into<SharedGrid<QIndex>>,
        eb: ResolvedBound,
        cfg: MitigationConfig,
    ) -> Self {
        Job { dq: dq.into(), q: q.into(), eb, cfg }
    }
}

/// Result of one served job.
pub type JobResult = anyhow::Result<(Grid<f32>, PipelineStats)>;

/// Default bound on the number of queued (not yet running) jobs.
pub const DEFAULT_QUEUE_CAPACITY: usize = 256;

/// Construction-time knobs of a [`MitigationService`].
#[derive(Clone)]
pub struct ServiceConfig {
    /// Pool that carries the cross-job fan-out **and** every job's
    /// internal steps; `None` uses the process-global pool.
    pub pool: Option<Arc<ThreadPool>>,
    /// Bounded admission-queue capacity (values below 1 are clamped
    /// to 1). See [`DEFAULT_QUEUE_CAPACITY`].
    pub capacity: usize,
    /// Start with draining paused: submissions are accepted (up to
    /// capacity) but nothing runs until
    /// [`MitigationService::resume`]. Used by maintenance drains and
    /// the deterministic ordering tests.
    pub start_paused: bool,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig { pool: None, capacity: DEFAULT_QUEUE_CAPACITY, start_paused: false }
    }
}

/// A mitigation server: a bounded streaming admission queue over a
/// persistent thread pool (the process-wide
/// [`pool::global`](crate::util::pool::global) by default, or an
/// explicitly sized pool for isolation).
pub struct MitigationService {
    admission: Admission,
}

impl Default for MitigationService {
    fn default() -> Self {
        MitigationService::new()
    }
}

impl MitigationService {
    /// Service over the process-wide global pool with default settings.
    pub fn new() -> Self {
        MitigationService::with_config(ServiceConfig::default())
    }

    /// Service confined to an explicit pool: the cross-job fan-out and
    /// each job's internal steps A–E all run on `pool`, never the
    /// global one.
    pub fn with_pool(pool: Arc<ThreadPool>) -> Self {
        MitigationService::with_config(ServiceConfig { pool: Some(pool), ..Default::default() })
    }

    /// Service with explicit [`ServiceConfig`] knobs.
    pub fn with_config(cfg: ServiceConfig) -> Self {
        MitigationService { admission: Admission::new(cfg.pool, cfg.capacity, cfg.start_paused) }
    }

    /// Non-blocking admission: enqueue `job` or fail immediately with
    /// [`SubmitError::QueueFull`] (carrying the job back) when the
    /// queue is at capacity.
    pub fn try_submit(&self, job: Job, opts: SubmitOptions) -> Result<JobTicket, SubmitError> {
        self.admission.try_submit(job, opts)
    }

    /// Blocking admission: wait for queue space, bounded by
    /// `opts.timeout` if set ([`SubmitError::Timeout`] on expiry).
    pub fn submit(&self, job: Job, opts: SubmitOptions) -> Result<JobTicket, SubmitError> {
        self.admission.submit(job, opts)
    }

    /// Stop draining the queue. Submissions are still accepted until
    /// the queue fills; jobs already running finish normally.
    pub fn pause(&self) {
        self.admission.pause();
    }

    /// Resume draining after [`MitigationService::pause`] (or a
    /// [`ServiceConfig::start_paused`] construction).
    pub fn resume(&self) {
        self.admission.resume();
    }

    /// Snapshot of the admission counters and gauges.
    pub fn stats(&self) -> ServiceStats {
        self.admission.stats()
    }

    /// A handle to this service's scratch-buffer arena (every job's
    /// full-grid temporaries and output buffers cycle through it).
    /// Handles share state, so one kept by a test or an operator
    /// dashboard observes the live counters — including after the
    /// service itself is dropped.
    pub fn arena(&self) -> Arena {
        self.admission.arena().clone()
    }

    /// Snapshot of the arena's reuse counters and gauges.
    pub fn arena_stats(&self) -> ArenaStats {
        self.admission.arena().stats()
    }

    /// Hand a finished output grid's buffer back to the service arena,
    /// so the next same-shaped job's output is allocation-free too.
    /// Entirely optional — outputs are plain owned [`Grid`]s and may
    /// simply be dropped.
    pub fn recycle(&self, grid: Grid<f32>) {
        self.admission.arena().adopt(grid.data);
    }

    /// Queue and arena counters rendered as one scrapeable
    /// `key=value …` text line (the `qai serve --metrics` format). See
    /// [`render_metrics`].
    pub fn metrics_text(&self) -> String {
        render_metrics(&self.stats(), &self.arena_stats())
    }

    /// Compatibility wrapper over the queue: run every job and return
    /// slot `i` of the output for `jobs[i]`, exactly like the original
    /// slice-in/vec-out batch API. Per-job failures (including panics
    /// out of the pipeline) are captured in their own slot, and outputs
    /// are bit-identical to per-field
    /// [`mitigate_with_stats`](crate::mitigation::pipeline::mitigate_with_stats)
    /// calls.
    ///
    /// Cloning a [`Job`] into the queue is an `Arc` pointer bump — grid
    /// data is shared with the caller's slice, never copied (see the
    /// [`Job`] ownership contract). Jobs are submitted as
    /// [`Priority::Bulk`](crate::mitigation::admission::Priority),
    /// blocking for space when the batch exceeds the queue capacity —
    /// so do not call it on a paused service with a batch larger than
    /// the capacity.
    pub fn mitigate_batch(&self, jobs: &[Job]) -> Vec<JobResult> {
        self.mitigate_batch_owned(jobs.to_vec())
    }

    /// Owning form of [`mitigate_batch`](MitigationService::mitigate_batch):
    /// takes the jobs by value and moves them straight into the queue —
    /// no per-job clone at all, not even of the `Arc` pointers.
    /// Identical semantics otherwise (bulk class, per-slot error
    /// labeling, bit-identical outputs).
    pub fn mitigate_batch_owned(&self, jobs: Vec<Job>) -> Vec<JobResult> {
        if jobs.is_empty() {
            return Vec::new();
        }
        let tickets: Vec<JobTicket> = jobs
            .into_iter()
            .map(|job| {
                // Infallible while `&self` is alive: shutdown only
                // happens in drop, and no timeout is set.
                self.submit(job, SubmitOptions::bulk())
                    .unwrap_or_else(|e| panic!("batch admission failed: {e}"))
            })
            .collect();
        tickets
            .into_iter()
            .enumerate()
            .map(|(i, ticket)| {
                // Re-label errors with the batch slot (the queue's own
                // messages are slot-agnostic), matching the original
                // slice-in/vec-out API.
                ticket.wait().result.map_err(|e| anyhow::anyhow!("job {i}: {e:#}"))
            })
            .collect()
    }
}

/// Render service and arena counters as one space-separated
/// `key=value` line — stable keys, no units, floats in seconds — for
/// scraping from `qai serve --metrics` output.
pub fn render_metrics(stats: &ServiceStats, arena: &ArenaStats) -> String {
    format!(
        "submitted={} rejected_full={} submit_timeouts={} completed={} failed={} \
         cancelled={} interactive_done={} bulk_done={} deadlines_set={} \
         deadlines_missed={} max_queue_depth={} queue_depth={} running={} \
         total_queue_wait_s={:.6} total_exec_s={:.6} arena_hits={} arena_misses={} \
         arena_returns={} arena_detached={} arena_adopted={} arena_dropped={} \
         arena_bytes_outstanding={} arena_bytes_pooled={}",
        stats.submitted,
        stats.rejected_full,
        stats.submit_timeouts,
        stats.completed,
        stats.failed,
        stats.cancelled,
        stats.interactive_done,
        stats.bulk_done,
        stats.deadlines_set,
        stats.deadlines_missed,
        stats.max_queue_depth,
        stats.queue_depth,
        stats.running,
        stats.total_queue_wait_s,
        stats.total_exec_s,
        arena.hits,
        arena.misses,
        arena.returns,
        arena.detached,
        arena.adopted,
        arena.dropped,
        arena.bytes_outstanding,
        arena.bytes_pooled,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, DatasetKind};
    use crate::mitigation::pipeline::mitigate_with_stats;
    use crate::quant::{quantize_grid, ErrorBound};

    fn job(kind: DatasetKind, dims: &[usize], seed: u64) -> Job {
        let orig = generate(kind, dims, seed);
        let eb = ErrorBound::relative(1e-2).resolve(&orig.data);
        let (q, dq) = quantize_grid(&orig, eb);
        Job::new(dq, q, eb)
    }

    #[test]
    fn empty_batch_is_empty() {
        assert!(MitigationService::new().mitigate_batch(&[]).is_empty());
    }

    #[test]
    fn single_job_matches_direct_call() {
        // Serialized: first submit spawns a counted scheduler thread
        // (see `pool::test_guard`).
        let _g = crate::util::pool::test_guard();
        let j = job(DatasetKind::ClimateLike, &[48, 48], 3);
        let direct = mitigate_with_stats(&j.dq, &j.q, j.eb, &j.cfg).unwrap();
        let service = MitigationService::new();
        let got = service.mitigate_batch(std::slice::from_ref(&j));
        let (out, stats) = got.into_iter().next().unwrap().unwrap();
        assert_eq!(out.data, direct.0.data);
        assert_eq!(stats.n_boundary1, direct.1.n_boundary1);
        assert_eq!(stats.n_boundary2, direct.1.n_boundary2);
    }

    #[test]
    fn shape_mismatch_is_an_error_not_a_panic() {
        let _g = crate::util::pool::test_guard();
        let mut j = job(DatasetKind::ClimateLike, &[16, 16], 1);
        j.q = Grid::from_vec(vec![0i64; 64], &[8, 8]).into();
        let got = MitigationService::new().mitigate_batch(&[j]);
        assert!(got[0].is_err());
        let msg = got[0].as_ref().unwrap_err().to_string();
        assert!(msg.contains("shape"), "msg={msg}");
    }

    #[test]
    fn batch_updates_service_stats() {
        let _g = crate::util::pool::test_guard();
        let service = MitigationService::new();
        let jobs = vec![
            job(DatasetKind::ClimateLike, &[24, 24], 2),
            job(DatasetKind::ClimateLike, &[24, 24], 3),
        ];
        let results = service.mitigate_batch(&jobs);
        assert!(results.iter().all(|r| r.is_ok()));
        let st = service.stats();
        assert_eq!(st.submitted, 2);
        assert_eq!(st.completed, 2);
        assert_eq!(st.bulk_done, 2);
        assert_eq!(st.failed, 0);
        assert_eq!(st.queue_depth, 0);
    }
}
