//! The legacy mitigation serving façade: jobs, the single-queue
//! service, and its configuration.
//!
//! [`MitigationService`] predates the typed engine front door and
//! survives as a thin, bit-identical wrapper over a **single-shard**
//! [`Engine`](crate::mitigation::engine::Engine): its constructors are
//! `#[deprecated]`, and new code should build an
//! [`EngineBuilder`](crate::mitigation::engine::EngineBuilder) and
//! submit [`MitigationRequest`](crate::mitigation::engine::MitigationRequest)s
//! instead (`docs/SERVING.md` has the migration table). Everything the
//! service did — bounded admission with backpressure, priorities,
//! tickets, deadlines, pool confinement, the per-service arena — is
//! the engine's single-shard special case, so wrapped behavior is
//! identical by construction.
//!
//! [`Job`] remains the payload type both APIs share: a decompressed
//! field, its quantization indices, the resolved bound, and the
//! pipeline configuration, with `Arc`-backed zero-copy grids (see the
//! ownership contract below).
//!
//! # Examples
//!
//! ```
//! use qai::data::synthetic::{generate, DatasetKind};
//! use qai::mitigation::engine::{Engine, MitigationRequest};
//! use qai::quant::{quantize_grid, ErrorBound};
//!
//! let orig = generate(DatasetKind::ClimateLike, &[16, 16], 7);
//! let eb = ErrorBound::relative(1e-2).resolve(&orig.data);
//! let (q, dq) = quantize_grid(&orig, eb);
//!
//! let engine = Engine::builder().build();
//! let response = engine
//!     .run(MitigationRequest::new(dq, q, eb).with_stats(true))
//!     .unwrap();
//! assert_eq!(response.output.len(), 16 * 16);
//! assert!(response.stats.unwrap().total() >= 0.0);
//! ```

#![deny(missing_docs)]

use crate::data::grid::{Grid, SharedGrid};
use crate::mitigation::admission::{JobTicket, ServiceStats, SubmitError, SubmitOptions};
use crate::mitigation::engine::{Engine, MitigationRequest};
use crate::mitigation::pipeline::{MitigationConfig, PipelineStats};
use crate::mitigation::quality::QualityTarget;
use crate::mitigation::tiled::TiledConfig;
use crate::quant::{QIndex, ResolvedBound};
use crate::util::arena::{Arena, ArenaStats};
use crate::util::hist::LatencyPair;
use crate::util::pool::ThreadPool;
use std::sync::Arc;

/// One unit of served work: a decompressed field, its quantization
/// indices, the resolved bound, and the per-job pipeline configuration.
///
/// # Sharing & ownership contract
///
/// The grids are held as [`SharedGrid`]s — immutable, `Arc`-backed
/// payloads. Cloning a `Job` (and everything the serving layer does
/// with one: engine submission, the admission queue, the
/// [`mitigate_batch`](MitigationService::mitigate_batch) compat
/// wrapper) is a pointer bump; grid data is **never copied** on the
/// submission path, which [`SharedGrid::ptr_eq`] makes observable. A
/// caller may keep clones of the inputs while the job is queued or
/// running, and may mutate its copy only through the copy-on-write
/// escape hatch ([`SharedGrid::make_mut`]), which cannot affect a job
/// already submitted. Outputs are freshly-owned [`Grid`]s: the serving
/// layer allocates them (from its arena), the caller owns them, and
/// [`MitigationService::recycle`] /
/// [`Engine::recycle`](crate::mitigation::engine::Engine::recycle)
/// optionally hand their buffers back.
#[derive(Clone)]
pub struct Job {
    /// Decompressed data `d'` (shared, immutable).
    pub dq: SharedGrid<f32>,
    /// Quantization-index field (shared, immutable).
    pub q: SharedGrid<QIndex>,
    /// Resolved error bound the field was compressed with.
    pub eb: ResolvedBound,
    /// Pipeline configuration (η, per-job threads, backend, taper).
    pub cfg: MitigationConfig,
    /// Optional original (pre-compression) field. When present, the
    /// serving layer scores the output against it with the fused
    /// metric kernels and reports the score as `quality` in
    /// [`JobReport`](crate::mitigation::admission::JobReport) /
    /// [`MitigationResponse`](crate::mitigation::engine::MitigationResponse).
    pub reference: Option<SharedGrid<f32>>,
    /// Optional per-request quality floor; requires `reference`. When
    /// set, the engine auto-tunes mitigation parameters to meet it
    /// (see [`QualityTarget`] and the quality module docs).
    pub target: Option<QualityTarget>,
    /// When set, the targetless execution path runs the tiled streaming
    /// executor ([`crate::mitigation::tiled`]) instead of the
    /// whole-field pipeline: O(tile × lanes) scratch, same output for
    /// any halo wide enough (bit-identical interiors). Quality-targeted
    /// jobs (`target` set) ignore it — the auto-tuner re-runs the whole
    /// field per candidate and stays on the dense path.
    pub tiled: Option<TiledConfig>,
}

impl Job {
    /// Convenience constructor with the default pipeline configuration.
    /// Accepts owned [`Grid`]s or pre-shared [`SharedGrid`]s.
    pub fn new(
        dq: impl Into<SharedGrid<f32>>,
        q: impl Into<SharedGrid<QIndex>>,
        eb: ResolvedBound,
    ) -> Self {
        Job::with_config(dq, q, eb, MitigationConfig::default())
    }

    /// [`Job::new`] with an explicit pipeline configuration.
    pub fn with_config(
        dq: impl Into<SharedGrid<f32>>,
        q: impl Into<SharedGrid<QIndex>>,
        eb: ResolvedBound,
        cfg: MitigationConfig,
    ) -> Self {
        Job { dq: dq.into(), q: q.into(), eb, cfg, reference: None, target: None, tiled: None }
    }
}

/// Result of one served job.
pub type JobResult = anyhow::Result<(Grid<f32>, PipelineStats)>;

/// Default bound on the number of queued (not yet running) jobs.
pub const DEFAULT_QUEUE_CAPACITY: usize = 256;

/// Construction-time knobs of a [`MitigationService`] (and the
/// per-shard template inside
/// [`EngineBuilder::shard_config`](crate::mitigation::engine::EngineBuilder::shard_config)).
#[derive(Clone)]
pub struct ServiceConfig {
    /// Pool that carries the cross-job fan-out **and** every job's
    /// internal steps; `None` uses the process-global pool.
    pub pool: Option<Arc<ThreadPool>>,
    /// Bounded admission-queue capacity (values below 1 are clamped
    /// to 1). See [`DEFAULT_QUEUE_CAPACITY`].
    pub capacity: usize,
    /// Start with draining paused: submissions are accepted (up to
    /// capacity) but nothing runs until
    /// [`MitigationService::resume`]. Used by maintenance drains and
    /// the deterministic ordering tests.
    pub start_paused: bool,
    /// Scratch-buffer arena to lease full-grid buffers from; `None`
    /// creates a fresh arena per service. Pass a shared [`Arena`] to
    /// recycle buffers across services/shards (multi-tenant
    /// deployments with many same-shaped fields).
    pub arena: Option<Arena>,
    /// Shed deadline-infeasible work at admission: when a submission
    /// carries a deadline and the per-(tenant, shape) service-time
    /// estimator proves the deadline unmeetable given the current queue
    /// depth, reject it with
    /// [`SubmitError::DeadlineInfeasible`] instead of enqueueing a job
    /// that is doomed to miss. Off by default — sheds never happen
    /// unless an operator opts in (`qai serve --shed`).
    pub shed: bool,
    /// Adaptive lane scaling: let the admission scheduler grow its
    /// dispatch-lane cap (up to the pool width) when deadlines are
    /// being missed and parked workers are available, and shrink it
    /// while the shard idles. Off by default: the scheduler uses the
    /// full pool width statically, exactly as before.
    pub adaptive_lanes: bool,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            pool: None,
            capacity: DEFAULT_QUEUE_CAPACITY,
            start_paused: false,
            arena: None,
            shed: false,
            adaptive_lanes: false,
        }
    }
}

/// A mitigation server: a bounded streaming admission queue over a
/// persistent thread pool — now a thin wrapper over a single-shard
/// [`Engine`](crate::mitigation::engine::Engine). Deprecated for new
/// code; see the [module docs](self).
pub struct MitigationService {
    engine: Engine,
}

impl Default for MitigationService {
    fn default() -> Self {
        MitigationService::from_config(ServiceConfig::default())
    }
}

impl MitigationService {
    /// The non-deprecated substrate shared by every constructor: a
    /// single-shard engine with the given per-shard config.
    fn from_config(cfg: ServiceConfig) -> Self {
        MitigationService { engine: Engine::single(cfg) }
    }

    /// Service over the process-wide global pool with default settings.
    #[deprecated(
        note = "use `mitigation::engine::Engine::builder().build()` and submit \
                `MitigationRequest`s; see docs/SERVING.md for the migration table"
    )]
    pub fn new() -> Self {
        MitigationService::from_config(ServiceConfig::default())
    }

    /// Service confined to an explicit pool: the cross-job fan-out and
    /// each job's internal steps A–E all run on `pool`, never the
    /// global one.
    #[deprecated(
        note = "use `mitigation::engine::Engine::builder().pool(pool).build()`; see \
                docs/SERVING.md for the migration table"
    )]
    pub fn with_pool(pool: Arc<ThreadPool>) -> Self {
        MitigationService::from_config(ServiceConfig { pool: Some(pool), ..Default::default() })
    }

    /// Service with explicit [`ServiceConfig`] knobs.
    #[deprecated(
        note = "use `mitigation::engine::Engine::builder().shard_config(cfg).build()`; see \
                docs/SERVING.md for the migration table"
    )]
    pub fn with_config(cfg: ServiceConfig) -> Self {
        MitigationService::from_config(cfg)
    }

    /// Non-blocking admission: enqueue `job` or fail immediately with
    /// [`SubmitError::QueueFull`] (carrying the job back) when the
    /// queue is at capacity.
    pub fn try_submit(&self, job: Job, opts: SubmitOptions) -> Result<JobTicket, SubmitError> {
        self.engine.admission(0).try_submit(job, opts)
    }

    /// Blocking admission: wait for queue space, bounded by
    /// `opts.timeout` if set ([`SubmitError::Timeout`] on expiry).
    pub fn submit(&self, job: Job, opts: SubmitOptions) -> Result<JobTicket, SubmitError> {
        self.engine.admission(0).submit(job, opts)
    }

    /// Stop draining the queue. Submissions are still accepted until
    /// the queue fills; jobs already running finish normally.
    pub fn pause(&self) {
        self.engine.pause();
    }

    /// Resume draining after [`MitigationService::pause`] (or a
    /// [`ServiceConfig::start_paused`] construction).
    pub fn resume(&self) {
        self.engine.resume();
    }

    /// Snapshot of the admission counters and gauges.
    pub fn stats(&self) -> ServiceStats {
        self.engine.shard_stats(0)
    }

    /// A handle to this service's scratch-buffer arena (every job's
    /// full-grid temporaries and output buffers cycle through it).
    /// Handles share state, so one kept by a test or an operator
    /// dashboard observes the live counters — including after the
    /// service itself is dropped.
    pub fn arena(&self) -> Arena {
        self.engine.shard_arena(0)
    }

    /// Snapshot of the arena's reuse counters and gauges.
    pub fn arena_stats(&self) -> ArenaStats {
        self.engine.shard_arena(0).stats()
    }

    /// Hand a finished output grid's buffer back to the service arena,
    /// so the next same-shaped job's output is allocation-free too.
    /// Entirely optional — outputs are plain owned [`Grid`]s and may
    /// simply be dropped.
    pub fn recycle(&self, grid: Grid<f32>) {
        self.engine.recycle(grid);
    }

    /// Queue and arena counters rendered as one scrapeable
    /// `key=value …` text line (the single-service metrics format; the
    /// engine's multi-scope format is
    /// [`Engine::metrics_text`](crate::mitigation::engine::Engine::metrics_text)).
    /// See [`render_metrics`].
    pub fn metrics_text(&self) -> String {
        render_metrics(&self.stats(), &self.arena_stats())
    }

    /// Compatibility wrapper over the engine batch path: run every job
    /// and return slot `i` of the output for `jobs[i]`, exactly like
    /// the original slice-in/vec-out batch API. Per-job failures
    /// (including panics out of the pipeline) are captured in their own
    /// slot, and outputs are bit-identical to per-field direct calls.
    ///
    /// Cloning a [`Job`] into the queue is an `Arc` pointer bump — grid
    /// data is shared with the caller's slice, never copied (see the
    /// [`Job`] ownership contract). Jobs are submitted as
    /// [`Priority::Bulk`](crate::mitigation::admission::Priority),
    /// blocking for space when the batch exceeds the queue capacity —
    /// so do not call it on a paused service with a batch larger than
    /// the capacity.
    #[deprecated(
        note = "build `MitigationRequest`s and call \
                `mitigation::engine::Engine::run_batch`; see docs/SERVING.md"
    )]
    pub fn mitigate_batch(&self, jobs: &[Job]) -> Vec<JobResult> {
        self.batch_owned(jobs.to_vec())
    }

    /// Owning form of [`mitigate_batch`](MitigationService::mitigate_batch):
    /// takes the jobs by value and moves them straight into the queue —
    /// no per-job clone at all, not even of the `Arc` pointers.
    /// Identical semantics otherwise (bulk class, per-slot error
    /// labeling, bit-identical outputs).
    #[deprecated(
        note = "build `MitigationRequest`s and call \
                `mitigation::engine::Engine::run_batch`; see docs/SERVING.md"
    )]
    pub fn mitigate_batch_owned(&self, jobs: Vec<Job>) -> Vec<JobResult> {
        self.batch_owned(jobs)
    }

    /// Shared non-deprecated body of the two batch wrappers.
    fn batch_owned(&self, jobs: Vec<Job>) -> Vec<JobResult> {
        let requests: Vec<MitigationRequest> =
            jobs.into_iter().map(|job| MitigationRequest::from_job(job).with_stats(true)).collect();
        self.engine
            .run_batch(requests)
            .into_iter()
            .enumerate()
            .map(|(i, result)| {
                // Re-label errors with the batch slot (the queue's own
                // messages are slot-agnostic), matching the original
                // slice-in/vec-out API.
                result
                    .map(|resp| {
                        let stats = resp.stats.expect("batch requests opt into stats");
                        (resp.output, stats)
                    })
                    .map_err(|e| anyhow::anyhow!("job {i}: {e:#}"))
            })
            .collect()
    }
}

/// Render service and arena counters as one space-separated
/// `key=value` line — stable keys, no units, floats in seconds — for
/// scraping from `qai serve --metrics` output.
pub fn render_metrics(stats: &ServiceStats, arena: &ArenaStats) -> String {
    format!(
        "submitted={} rejected_full={} submit_timeouts={} completed={} failed={} \
         cancelled={} interactive_done={} bulk_done={} deadlines_set={} \
         deadlines_missed={} max_queue_depth={} queue_depth={} running={} \
         total_queue_wait_s={:.6} total_exec_s={:.6} arena_hits={} arena_misses={} \
         arena_returns={} arena_detached={} arena_adopted={} arena_dropped={} \
         arena_bytes_outstanding={} arena_bytes_pooled={} arena_bytes_peak={} \
         shed_infeasible={} \
         sched_wakeups={} lanes_grown={} lanes_shrunk={} lane_cap={} \
         quality_hits={} quality_misses={} quality_evicted={} simd={} last_trace={}",
        stats.submitted,
        stats.rejected_full,
        stats.submit_timeouts,
        stats.completed,
        stats.failed,
        stats.cancelled,
        stats.interactive_done,
        stats.bulk_done,
        stats.deadlines_set,
        stats.deadlines_missed,
        stats.max_queue_depth,
        stats.queue_depth,
        stats.running,
        stats.total_queue_wait_s,
        stats.total_exec_s,
        arena.hits,
        arena.misses,
        arena.returns,
        arena.detached,
        arena.adopted,
        arena.dropped,
        arena.bytes_outstanding,
        arena.bytes_pooled,
        arena.bytes_peak,
        stats.shed_infeasible,
        stats.sched_wakeups,
        stats.lanes_grown,
        stats.lanes_shrunk,
        stats.lane_cap,
        stats.quality_hits,
        stats.quality_misses,
        stats.quality_evicted,
        crate::util::simd::token(),
        stats.last_trace_id,
    )
}

/// [`render_metrics`] with leading label tokens (`shard=0`,
/// `tenant=acme`, …) prepended to the line — the per-scope format of
/// [`Engine::metrics_text`](crate::mitigation::engine::Engine::metrics_text).
/// Labels must be token-safe (no spaces, no `=` in values).
pub fn render_metrics_labeled(
    labels: &[(&str, &str)],
    stats: &ServiceStats,
    arena: &ArenaStats,
) -> String {
    let mut line = String::new();
    for (key, value) in labels {
        line.push_str(key);
        line.push('=');
        line.push_str(value);
        line.push(' ');
    }
    line.push_str(&render_metrics(stats, arena));
    line
}

/// Render a queue-wait / service-time histogram pair as one scrapeable
/// `key=value` line with leading label tokens — the `scope=latency`
/// format of
/// [`Engine::metrics_text`](crate::mitigation::engine::Engine::metrics_text).
/// Milliseconds throughout; quantiles are bucket upper edges (see
/// [`crate::util::hist`]), so reported p99s are conservative upper
/// bounds. Labels must be token-safe (no spaces, no `=` in values).
pub fn render_latency_labeled(labels: &[(&str, &str)], pair: &LatencyPair) -> String {
    let mut line = String::new();
    for (key, value) in labels {
        line.push_str(key);
        line.push('=');
        line.push_str(value);
        line.push(' ');
    }
    line.push_str(&format!(
        "count={} wait_p50_ms={:.3} wait_p99_ms={:.3} wait_mean_ms={:.3} \
         exec_p50_ms={:.3} exec_p99_ms={:.3} exec_mean_ms={:.3}",
        pair.wait.count(),
        pair.wait.quantile_ms(0.50),
        pair.wait.quantile_ms(0.99),
        pair.wait.mean_ms(),
        pair.exec.quantile_ms(0.50),
        pair.exec.quantile_ms(0.99),
        pair.exec.mean_ms(),
    ));
    line
}

/// Render one peer's transport counters as a scrapeable `key=value`
/// line with leading label tokens — the `scope=transport` format of
/// [`Engine::metrics_text`](crate::mitigation::engine::Engine::metrics_text).
/// Byte counts are wire bytes (frame payload + length prefix). Labels
/// must be token-safe (no spaces, no `=` in values).
pub fn render_transport_labeled(
    labels: &[(&str, &str)],
    counters: &crate::cluster::transport::PeerCounters,
) -> String {
    let mut line = String::new();
    for (key, value) in labels {
        line.push_str(key);
        line.push('=');
        line.push_str(value);
        line.push(' ');
    }
    line.push_str(&format!(
        "peer={} sent_bytes={} sent_msgs={} recv_bytes={} recv_msgs={}",
        counters.peer,
        counters.sent_bytes,
        counters.sent_msgs,
        counters.recv_bytes,
        counters.recv_msgs,
    ));
    line
}

#[cfg(test)]
mod tests {
    // The deprecated constructors/batch wrappers are exercised
    // deliberately: this suite pins their bit-identical-wrapper
    // contract over the engine.
    #![allow(deprecated)]

    use super::*;
    use crate::data::synthetic::{generate, DatasetKind};
    use crate::mitigation::pipeline::mitigate_with_stats;
    use crate::quant::{quantize_grid, ErrorBound};

    fn job(kind: DatasetKind, dims: &[usize], seed: u64) -> Job {
        let orig = generate(kind, dims, seed);
        let eb = ErrorBound::relative(1e-2).resolve(&orig.data);
        let (q, dq) = quantize_grid(&orig, eb);
        Job::new(dq, q, eb)
    }

    #[test]
    fn empty_batch_is_empty() {
        assert!(MitigationService::new().mitigate_batch(&[]).is_empty());
    }

    #[test]
    fn single_job_matches_direct_call() {
        // Serialized: first submit spawns a counted scheduler thread
        // (see `pool::test_guard`).
        let _g = crate::util::pool::test_guard();
        let j = job(DatasetKind::ClimateLike, &[48, 48], 3);
        let direct = mitigate_with_stats(&j.dq, &j.q, j.eb, &j.cfg).unwrap();
        let service = MitigationService::new();
        let got = service.mitigate_batch(std::slice::from_ref(&j));
        let (out, stats) = got.into_iter().next().unwrap().unwrap();
        assert_eq!(out.data, direct.0.data);
        assert_eq!(stats.n_boundary1, direct.1.n_boundary1);
        assert_eq!(stats.n_boundary2, direct.1.n_boundary2);
    }

    #[test]
    fn shape_mismatch_is_an_error_not_a_panic() {
        let _g = crate::util::pool::test_guard();
        let mut j = job(DatasetKind::ClimateLike, &[16, 16], 1);
        j.q = Grid::from_vec(vec![0i64; 64], &[8, 8]).into();
        let got = MitigationService::new().mitigate_batch(&[j]);
        assert!(got[0].is_err());
        let msg = got[0].as_ref().unwrap_err().to_string();
        assert!(msg.contains("shape"), "msg={msg}");
    }

    #[test]
    fn batch_updates_service_stats() {
        let _g = crate::util::pool::test_guard();
        let service = MitigationService::new();
        let jobs = vec![
            job(DatasetKind::ClimateLike, &[24, 24], 2),
            job(DatasetKind::ClimateLike, &[24, 24], 3),
        ];
        let results = service.mitigate_batch(&jobs);
        assert!(results.iter().all(|r| r.is_ok()));
        let st = service.stats();
        assert_eq!(st.submitted, 2);
        assert_eq!(st.completed, 2);
        assert_eq!(st.bulk_done, 2);
        assert_eq!(st.failed, 0);
        assert_eq!(st.queue_depth, 0);
    }

    #[test]
    fn labeled_metrics_prepend_tokens() {
        let stats = ServiceStats::default();
        let arena = ArenaStats::default();
        let line = render_metrics_labeled(&[("shard", "3"), ("tenant", "acme")], &stats, &arena);
        assert!(line.starts_with("shard=3 tenant=acme submitted=0 "), "line={line}");
        assert!(line.ends_with("last_trace=0"), "line={line}");
        assert_eq!(line.matches('\n').count(), 0);
    }

    #[test]
    fn latency_line_reports_wait_and_exec_split() {
        let mut pair = LatencyPair::default();
        pair.wait.record(std::time::Duration::from_micros(100));
        pair.exec.record(std::time::Duration::from_millis(4));
        let line = render_latency_labeled(&[("scope", "latency"), ("class", "interactive")], &pair);
        assert!(line.starts_with("scope=latency class=interactive count=1 "), "line={line}");
        assert!(line.contains(" wait_p50_ms="), "line={line}");
        assert!(line.contains(" exec_p99_ms="), "line={line}");
        // Every token is key=value with a non-empty value.
        for token in line.split_whitespace() {
            let (k, v) = token.split_once('=').expect("key=value token");
            assert!(!k.is_empty() && !v.is_empty(), "token={token}");
        }
    }
}
