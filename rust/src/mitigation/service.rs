//! Batched mitigation serving layer.
//!
//! The ROADMAP's production scenario is many independent fields arriving
//! concurrently (one per user request, ensemble member, or timestep).
//! [`MitigationService`] runs such batches on a persistent
//! [`pool`](crate::util::pool): jobs execute concurrently as tasks on
//! the service's pool (the process-global one by default, or the pool
//! given to [`MitigationService::with_pool`]), while each job's
//! *internal* steps (A–E) fan out at its own `MitigationConfig::threads`
//! setting on the **process-global** pool — the pipeline's parallel
//! substrate is the global pool regardless of which pool carries the
//! cross-job fan-out (per-step pool-handle plumbing is a ROADMAP
//! follow-up). Nested regions are safe either way: every region's
//! opener participates in draining it, so no spawns and no deadlock.
//!
//! Guarantees:
//!
//! * **Exactness** — each job's output is bit-identical to a standalone
//!   [`mitigate_with_stats`] call with the same inputs (the pipeline is
//!   schedule-independent), so batching is a pure throughput knob.
//! * **Isolation** — a failing job (error *or* panic, e.g. a shape
//!   mismatch) yields an `Err` in its own slot and cannot poison the
//!   rest of the batch.
//! * **Determinism** — outputs depend only on job inputs, never on
//!   batch order, batch concurrency, or pool sizing.

use crate::data::grid::Grid;
use crate::mitigation::pipeline::{mitigate_with_stats, MitigationConfig, PipelineStats};
use crate::quant::{QIndex, ResolvedBound};
use crate::util::pool::{self, ThreadPool};
use std::sync::{Arc, Mutex};

/// One unit of batched work: a decompressed field, its quantization
/// indices, the resolved bound, and the per-job pipeline configuration.
pub struct Job {
    /// Decompressed data `d'`.
    pub dq: Grid<f32>,
    /// Quantization-index field.
    pub q: Grid<QIndex>,
    /// Resolved error bound the field was compressed with.
    pub eb: ResolvedBound,
    /// Pipeline configuration (η, per-job threads, backend, taper).
    pub cfg: MitigationConfig,
}

impl Job {
    /// Convenience constructor with the default pipeline configuration.
    pub fn new(dq: Grid<f32>, q: Grid<QIndex>, eb: ResolvedBound) -> Self {
        Job { dq, q, eb, cfg: MitigationConfig::default() }
    }
}

/// Result slot of one batched job.
pub type JobResult = anyhow::Result<(Grid<f32>, PipelineStats)>;

/// A mitigation server over a persistent thread pool (the process-wide
/// [`pool::global`] by default, or an explicitly sized pool for
/// isolation / sweep experiments).
#[derive(Default)]
pub struct MitigationService {
    pool: Option<Arc<ThreadPool>>,
}

impl MitigationService {
    /// Service over the process-wide global pool.
    pub fn new() -> Self {
        MitigationService { pool: None }
    }

    /// Service whose *cross-job* fan-out runs on an explicit pool.
    /// Note: jobs' internal steps still parallelize on the global pool
    /// (see the module docs), so this bounds batch-level concurrency,
    /// not total CPU use.
    pub fn with_pool(pool: Arc<ThreadPool>) -> Self {
        MitigationService { pool: Some(pool) }
    }

    fn pool(&self) -> &ThreadPool {
        self.pool.as_deref().unwrap_or_else(pool::global)
    }

    /// Run every job, concurrently, on the shared pool; slot `i` of the
    /// output corresponds to `jobs[i]`. Per-job failures (including
    /// panics out of the pipeline) are captured in their own slot.
    pub fn mitigate_batch(&self, jobs: &[Job]) -> Vec<JobResult> {
        if jobs.is_empty() {
            return Vec::new();
        }
        let pool = self.pool();
        let slots: Vec<Mutex<Option<JobResult>>> =
            jobs.iter().map(|_| Mutex::new(None)).collect();
        pool.for_range(jobs.len(), pool.lanes(), 1, |i| {
            let job = &jobs[i];
            let outcome = if job.dq.shape != job.q.shape {
                Err(anyhow::anyhow!(
                    "job {i}: data shape {:?} != index shape {:?}",
                    job.dq.shape.dims,
                    job.q.shape.dims
                ))
            } else {
                // A panic below (defensive: the pipeline asserts on
                // internal invariants) must not take down sibling jobs.
                match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    mitigate_with_stats(&job.dq, &job.q, job.eb, &job.cfg)
                })) {
                    Ok(result) => result,
                    Err(payload) => {
                        let msg = payload
                            .downcast_ref::<String>()
                            .cloned()
                            .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                            .unwrap_or_else(|| "<non-string panic>".to_string());
                        Err(anyhow::anyhow!("job {i} panicked: {msg}"))
                    }
                }
            };
            *slots[i].lock().unwrap() = Some(outcome);
        });
        slots
            .into_iter()
            .map(|slot| slot.into_inner().unwrap().expect("every job slot is filled"))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, DatasetKind};
    use crate::quant::{quantize_grid, ErrorBound};

    fn job(kind: DatasetKind, dims: &[usize], seed: u64) -> Job {
        let orig = generate(kind, dims, seed);
        let eb = ErrorBound::relative(1e-2).resolve(&orig.data);
        let (q, dq) = quantize_grid(&orig, eb);
        Job::new(dq, q, eb)
    }

    #[test]
    fn empty_batch_is_empty() {
        assert!(MitigationService::new().mitigate_batch(&[]).is_empty());
    }

    #[test]
    fn single_job_matches_direct_call() {
        let j = job(DatasetKind::ClimateLike, &[48, 48], 3);
        let direct = mitigate_with_stats(&j.dq, &j.q, j.eb, &j.cfg).unwrap();
        let service = MitigationService::new();
        let got = service.mitigate_batch(std::slice::from_ref(&j));
        let (out, stats) = got.into_iter().next().unwrap().unwrap();
        assert_eq!(out.data, direct.0.data);
        assert_eq!(stats.n_boundary1, direct.1.n_boundary1);
        assert_eq!(stats.n_boundary2, direct.1.n_boundary2);
    }

    #[test]
    fn shape_mismatch_is_an_error_not_a_panic() {
        let mut j = job(DatasetKind::ClimateLike, &[16, 16], 1);
        j.q = Grid::from_vec(vec![0i64; 64], &[8, 8]);
        let got = MitigationService::new().mitigate_batch(&[j]);
        assert!(got[0].is_err());
        let msg = got[0].as_ref().unwrap_err().to_string();
        assert!(msg.contains("shape"), "msg={msg}");
    }
}
