//! Quality-aware serving: per-request quality targets and the bounded
//! online parameter search behind them.
//!
//! A request that carries a [`QualityTarget`] (and a reference field,
//! see [`MitigationRequest::quality_target`](super::MitigationRequest::quality_target))
//! asks the engine to *pick* mitigation parameters instead of trusting
//! the request's fixed [`MitigationConfig`]: the admission worker runs
//! a small bounded search over (η, taper, filter) candidates — the same
//! grids the `ablation_eta`/`ablation_taper` benches sweep — evaluates
//! each candidate's output against the reference with the fused metric
//! kernels ([`psnr`] / [`ssim_gaussian_on`]), and stops at the first
//! candidate meeting the target (falling back to the best seen). The
//! winner is installed in a bounded per-shard cache keyed by
//! (tenant, dataset shape), so steady-state traffic pays one
//! closed-form mitigation plus one inline metric evaluation — no
//! search. Cache behavior is observable through the
//! `quality_hits`/`quality_misses`/`quality_evicted` counters in
//! [`ServiceStats`](super::ServiceStats).

use crate::data::grid::Grid;
use crate::filters::wiener::quantization_noise_power;
use crate::filters::{gaussian_filter_on, uniform_filter_sized_on, wiener_filter_sized_on};
use crate::metrics::psnr::psnr;
use crate::metrics::ssim_fast::ssim_gaussian_on;
use crate::util::arena::ArenaHandle;
use crate::util::pool::PoolHandle;

use super::pipeline::{run_pipeline, MitigationConfig, PipelineStats};
use super::service::Job;

/// A per-request quality floor: the engine searches mitigation
/// parameters until the output scores at least this well against the
/// request's reference field.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum QualityTarget {
    /// Range-based peak signal-to-noise ratio in dB (paper Eq. 4),
    /// measured by [`metrics::psnr`](crate::metrics::psnr).
    Psnr(f64),
    /// Gaussian-window SSIM in `[0, 1]`, measured by
    /// [`metrics::ssim_gaussian`](crate::metrics::ssim_gaussian).
    Ssim(f64),
}

impl QualityTarget {
    /// The numeric floor requested.
    pub fn threshold(&self) -> f64 {
        match *self {
            QualityTarget::Psnr(db) => db,
            QualityTarget::Ssim(v) => v,
        }
    }

    /// Whether a measured `value` satisfies the target.
    pub fn met_by(&self, value: f64) -> bool {
        value >= self.threshold()
    }
}

/// η values the bounded search sweeps — the same grid the
/// `ablation_eta` bench reports.
pub const ETA_CANDIDATES: [f64; 6] = [0.0, 0.5, 0.7, 0.8, 0.9, 1.0];

/// Taper radii the bounded search sweeps — the same grid the
/// `ablation_taper` bench reports.
pub const TAPER_CANDIDATES: [Option<f64>; 4] = [None, Some(32.0), Some(12.0), Some(5.0)];

/// One point in the search space: the paper's quantization-aware
/// mitigation with explicit knobs, or a classical filter baseline.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum TunedParams {
    /// Quantization-aware interpolation (steps A–E).
    Qai {
        /// Interpolation weight η.
        eta: f64,
        /// Optional boundary taper radius.
        taper_radius: Option<f64>,
    },
    /// Separable gaussian smoothing with the given σ.
    Gaussian {
        /// Filter standard deviation.
        sigma: f64,
    },
    /// 3-tap uniform (mean) smoothing.
    Uniform,
    /// 3-tap Wiener filter with quantization noise power `ε²/3`.
    Wiener,
}

/// A learned winner installed in the tuned-parameter cache, together
/// with the quality it achieved when it won.
#[derive(Debug, Clone, Copy)]
pub(crate) struct TunedEntry {
    /// The winning parameters.
    pub params: TunedParams,
    /// Quality measured when the search installed this entry.
    pub quality: f64,
}

/// The result of one bounded search.
pub(crate) struct SearchOutcome {
    /// Winning parameters (first candidate meeting the target, else the
    /// best seen).
    pub params: TunedParams,
    /// The winning candidate's output field.
    pub output: Grid<f32>,
    /// Pipeline stats of the winning run (zeros for filter baselines).
    pub stats: PipelineStats,
    /// Quality of `output` under the target's metric.
    pub quality: f64,
    /// How many candidates were evaluated before stopping.
    pub evaluated: usize,
}

/// The deterministic candidate order: the request's own (η, taper)
/// first (a well-configured request early-exits after one evaluation),
/// then the η grid strongest-first at the request's taper, then the
/// taper grid at the default η, then the filter baselines. Duplicates
/// are dropped, so the search is bounded by ~13 evaluations.
pub(crate) fn candidates(base: &MitigationConfig) -> Vec<TunedParams> {
    let mut v: Vec<TunedParams> = Vec::new();
    let mut push = |p: TunedParams, v: &mut Vec<TunedParams>| {
        if !v.contains(&p) {
            v.push(p);
        }
    };
    push(TunedParams::Qai { eta: base.eta, taper_radius: base.taper_radius }, &mut v);
    for &eta in ETA_CANDIDATES.iter().rev() {
        push(TunedParams::Qai { eta, taper_radius: base.taper_radius }, &mut v);
    }
    for &taper_radius in TAPER_CANDIDATES.iter() {
        push(TunedParams::Qai { eta: 0.9, taper_radius }, &mut v);
    }
    push(TunedParams::Gaussian { sigma: 1.0 }, &mut v);
    push(TunedParams::Uniform, &mut v);
    push(TunedParams::Wiener, &mut v);
    v
}

/// Execute one candidate on `job`'s payload. QAI candidates run the
/// full pipeline with the candidate's knobs (threads/backend from the
/// request); filter baselines smooth the decompressed field directly
/// and report zeroed pipeline stats.
pub(crate) fn apply_params(
    pool: PoolHandle<'_>,
    arena: ArenaHandle<'_>,
    job: &Job,
    params: TunedParams,
) -> anyhow::Result<(Grid<f32>, PipelineStats)> {
    match params {
        TunedParams::Qai { eta, taper_radius } => {
            let cfg = MitigationConfig { eta, taper_radius, ..job.cfg };
            run_pipeline(pool, arena, &job.dq, &job.q, job.eb, &cfg)
        }
        TunedParams::Gaussian { sigma } => {
            Ok((gaussian_filter_on(pool, &job.dq, sigma, job.cfg.threads), PipelineStats::default()))
        }
        TunedParams::Uniform => {
            Ok((uniform_filter_sized_on(pool, &job.dq, 3, job.cfg.threads), PipelineStats::default()))
        }
        TunedParams::Wiener => {
            let noise = quantization_noise_power(job.eb.abs);
            Ok((
                wiener_filter_sized_on(pool, &job.dq, 3, noise, job.cfg.threads),
                PipelineStats::default(),
            ))
        }
    }
}

/// Score `out` against `reference` under the target's metric: PSNR for
/// [`QualityTarget::Psnr`], fused gaussian SSIM otherwise (also the
/// default score for target-less requests that carry a reference).
pub(crate) fn evaluate(
    pool: PoolHandle<'_>,
    arena: ArenaHandle<'_>,
    reference: &Grid<f32>,
    out: &Grid<f32>,
    target: Option<QualityTarget>,
    threads: usize,
) -> f64 {
    match target {
        Some(QualityTarget::Psnr(_)) => psnr(&reference.data, &out.data),
        _ => ssim_gaussian_on(pool, arena, reference, out, threads),
    }
}

/// Run the bounded search: evaluate candidates in [`candidates`] order,
/// return at the first one meeting `target`, else the best seen.
pub(crate) fn search(
    pool: PoolHandle<'_>,
    arena: ArenaHandle<'_>,
    job: &Job,
    reference: &Grid<f32>,
    target: QualityTarget,
) -> anyhow::Result<SearchOutcome> {
    let mut best: Option<SearchOutcome> = None;
    let mut evaluated = 0usize;
    for params in candidates(&job.cfg) {
        let (output, stats) = apply_params(pool, arena, job, params)?;
        evaluated += 1;
        let quality = evaluate(pool, arena, reference, &output, Some(target), job.cfg.threads);
        let outcome = SearchOutcome { params, output, stats, quality, evaluated };
        if target.met_by(quality) {
            return Ok(outcome);
        }
        let better = match &best {
            None => true,
            Some(b) => quality > b.quality,
        };
        if better {
            best = Some(outcome);
        }
    }
    let mut b = best.expect("candidate list is never empty");
    b.evaluated = evaluated;
    Ok(b)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, DatasetKind};
    use crate::quant::{quantize_grid, ErrorBound};

    fn make_job(dims: &[usize]) -> (Grid<f32>, Job) {
        let orig = generate(DatasetKind::ClimateLike, dims, 7);
        let eb = ErrorBound::relative(1e-2).resolve(&orig.data);
        let (q, dq) = quantize_grid(&orig, eb);
        let job = Job::new(dq, q, eb);
        (orig, job)
    }

    #[test]
    fn candidate_order_starts_with_request_config() {
        let cfg = MitigationConfig { eta: 0.7, taper_radius: Some(12.0), ..Default::default() };
        let c = candidates(&cfg);
        assert_eq!(c[0], TunedParams::Qai { eta: 0.7, taper_radius: Some(12.0) });
        assert!(c.len() <= 14, "search space must stay small, got {}", c.len());
        // Deduped: the base config appears exactly once.
        assert_eq!(c.iter().filter(|&&p| p == c[0]).count(), 1);
    }

    #[test]
    fn met_by_respects_threshold() {
        assert!(QualityTarget::Psnr(60.0).met_by(60.0));
        assert!(QualityTarget::Psnr(60.0).met_by(f64::INFINITY));
        assert!(!QualityTarget::Psnr(60.0).met_by(59.9));
        assert!(QualityTarget::Ssim(0.9).met_by(0.95));
        assert!(!QualityTarget::Ssim(0.9).met_by(0.85));
    }

    #[test]
    fn search_meets_reachable_psnr_target() {
        let (orig, job) = make_job(&[40, 40]);
        // Measure what the default config achieves, then ask for
        // slightly less: the first candidate must already satisfy it.
        let (out, _) = apply_params(PoolHandle::Global, ArenaHandle::Fresh, &job, candidates(&job.cfg)[0])
            .unwrap();
        let reachable = psnr(&orig.data, &out.data);
        let target = QualityTarget::Psnr(reachable - 1.0);
        let got = search(PoolHandle::Global, ArenaHandle::Fresh, &job, &orig, target).unwrap();
        assert!(target.met_by(got.quality), "quality={} target={:?}", got.quality, target);
        assert_eq!(got.evaluated, 1, "first candidate should early-exit");
    }

    #[test]
    fn unreachable_target_returns_best_seen() {
        let (orig, job) = make_job(&[24, 24]);
        let target = QualityTarget::Psnr(f64::INFINITY);
        let got = search(PoolHandle::Global, ArenaHandle::Fresh, &job, &orig, target).unwrap();
        assert!(!target.met_by(got.quality));
        assert_eq!(got.evaluated, candidates(&job.cfg).len(), "must exhaust the space");
        assert!(got.quality.is_finite());
    }

    #[test]
    fn filter_candidates_execute() {
        let (orig, job) = make_job(&[16, 16]);
        for params in [TunedParams::Gaussian { sigma: 1.0 }, TunedParams::Uniform, TunedParams::Wiener]
        {
            let (out, stats) =
                apply_params(PoolHandle::Global, ArenaHandle::Fresh, &job, params).unwrap();
            assert_eq!(out.shape, orig.shape);
            assert_eq!(stats.n_boundary1, 0, "filter baselines report zeroed stats");
        }
    }
}
