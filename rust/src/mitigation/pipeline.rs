//! The full distance-based compensation pipeline (paper Alg. 4),
//! sequential or shared-memory parallel (§VII-A), with an optional PJRT
//! backend that runs steps A and E in the AOT-compiled JAX/Pallas
//! executables (see `crate::runtime`).
//!
//! The public entry point for running the pipeline is
//! [`crate::mitigation::engine`] (`MitigationRequest` → `execute` /
//! `Engine::run`); the free functions here survive as deprecated
//! bit-identical wrappers over the same substrate.

use crate::data::grid::Grid;
use crate::mitigation::boundary::boundary_and_sign_on;
use crate::mitigation::edt::edt_on;
use crate::mitigation::sign::propagate_signs_on;
use crate::quant::{QIndex, ResolvedBound};
use crate::util::arena::ArenaHandle;
use crate::util::pool::PoolHandle;
use crate::util::timer::Stopwatch;

/// Which engine executes steps A (boundary/sign) and E (IDW compensate).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Backend {
    /// Pure-Rust implementation (default).
    #[default]
    Native,
    /// AOT-compiled JAX/Pallas executables through PJRT
    /// (`artifacts/*.hlo.txt` must have been built by `make artifacts`).
    Pjrt,
}

/// Pipeline configuration.
#[derive(Debug, Clone, Copy)]
pub struct MitigationConfig {
    /// Compensation factor η: assumed error magnitude at quantization
    /// boundaries as a fraction of ε. The paper uses 0.9.
    pub eta: f64,
    /// Shared-memory threads for every step (1 = sequential).
    pub threads: usize,
    /// Execution backend for steps A/E.
    pub backend: Backend,
    /// Optional homogeneous-region taper radius in cells (paper §IX
    /// future work, see `interpolate::compensate_adaptive`). `None`
    /// reproduces the published algorithm. Native backend only.
    pub taper_radius: Option<f64>,
}

impl Default for MitigationConfig {
    fn default() -> Self {
        MitigationConfig { eta: 0.9, threads: 1, backend: Backend::Native, taper_radius: None }
    }
}

/// Per-step wall-clock and boundary statistics of one pipeline run.
#[derive(Debug, Clone, Default)]
pub struct PipelineStats {
    /// Step A seconds (boundary + sign map).
    pub t_boundary: f64,
    /// Step B seconds (first EDT, with feature transform).
    pub t_edt1: f64,
    /// Step C seconds (sign propagation + B₂).
    pub t_sign: f64,
    /// Step D seconds (second EDT).
    pub t_edt2: f64,
    /// Step E seconds (IDW compensation).
    pub t_compensate: f64,
    /// Number of quantization-boundary points (|B₁|).
    pub n_boundary1: usize,
    /// Number of sign-flip boundary points (|B₂|).
    pub n_boundary2: usize,
}

impl PipelineStats {
    /// Total pipeline seconds.
    pub fn total(&self) -> f64 {
        self.t_boundary + self.t_edt1 + self.t_sign + self.t_edt2 + self.t_compensate
    }

    /// Throughput in MB/s for `n` f32 elements.
    pub fn throughput_mbs(&self, n: usize) -> f64 {
        (n * 4) as f64 / 1e6 / self.total().max(1e-12)
    }
}

/// Run Algorithm 4 on decompressed data `dq` with quantization indices
/// `q` and resolved bound `eb`; returns the compensated field.
///
/// Native backend only — use the stats opt-in on the request for the
/// PJRT path.
#[deprecated(
    note = "build a `mitigation::engine::MitigationRequest` and call `engine::execute` \
            (or `Engine::run` for the queued path); see docs/SERVING.md for the migration table"
)]
pub fn mitigate(
    dq: &Grid<f32>,
    q: &Grid<QIndex>,
    eb: ResolvedBound,
    cfg: &MitigationConfig,
) -> Grid<f32> {
    // Bit-identical to the engine front door: `engine::execute` runs
    // this exact substrate (global pool, fresh arena).
    run_pipeline(PoolHandle::Global, ArenaHandle::Fresh, dq, q, eb, cfg)
        .expect("mitigation failed")
        .0
}

/// Like [`mitigate`] but returns per-step stats, and supports
/// [`Backend::Pjrt`] (which can fail if artifacts are missing).
#[deprecated(
    note = "build a `mitigation::engine::MitigationRequest` with `.with_stats(true)` and call \
            `engine::execute`; see docs/SERVING.md for the migration table"
)]
pub fn mitigate_with_stats(
    dq: &Grid<f32>,
    q: &Grid<QIndex>,
    eb: ResolvedBound,
    cfg: &MitigationConfig,
) -> anyhow::Result<(Grid<f32>, PipelineStats)> {
    run_pipeline(PoolHandle::Global, ArenaHandle::Fresh, dq, q, eb, cfg)
}

/// [`mitigate_with_stats`] with every parallel region of steps A–E
/// confined to `pool` and every full-grid buffer acquired through
/// `arena`.
#[deprecated(
    note = "use `mitigation::engine::execute_on(pool, arena, &request)` — one entry point \
            instead of the `*_on` variant combinatorics"
)]
pub fn mitigate_with_stats_on(
    pool: PoolHandle<'_>,
    arena: ArenaHandle<'_>,
    dq: &Grid<f32>,
    q: &Grid<QIndex>,
    eb: ResolvedBound,
    cfg: &MitigationConfig,
) -> anyhow::Result<(Grid<f32>, PipelineStats)> {
    run_pipeline(pool, arena, dq, q, eb, cfg)
}

/// The pipeline substrate: steps A–E with every parallel region
/// confined to `pool` and every full-grid buffer acquired through
/// `arena` — what the engine front door
/// ([`crate::mitigation::engine::execute_on`]) and the admission
/// queue's job runner execute. The PJRT backend hands steps A/E to the
/// device runtime, which `pool` does not govern (and whose step-A
/// outputs are device buffers the arena never sees); steps B–D still
/// honor both.
///
/// Buffer lifecycle with a pooled arena: the seven intermediate
/// full-grid buffers (B₁ mask, boundary signs, Dist₁, I₁, propagated
/// signs, B₂, Dist₂) are held by RAII leases (`ArenaLease` /
/// `GridLease`) that give them back when they drop — on every exit
/// path, including unwinds; the output buffer is leased, then
/// **detached** — it escapes inside the returned grid, which the caller
/// owns (and may hand back via
/// [`MitigationService::recycle`](crate::mitigation::service::MitigationService::recycle)).
/// A warm same-shaped call therefore allocates zero full-grid buffers,
/// which the arena test suite proves through the miss counter.
pub(crate) fn run_pipeline(
    pool: PoolHandle<'_>,
    arena: ArenaHandle<'_>,
    dq: &Grid<f32>,
    q: &Grid<QIndex>,
    eb: ResolvedBound,
    cfg: &MitigationConfig,
) -> anyhow::Result<(Grid<f32>, PipelineStats)> {
    assert_eq!(dq.shape, q.shape, "data/index shape mismatch");
    anyhow::ensure!(
        cfg.taper_radius.is_none() || cfg.backend == Backend::Native,
        "the homogeneous-region taper is implemented in the native backend only"
    );
    let threads = cfg.threads.max(1);
    let mut stats = PipelineStats::default();
    let mut sw = Stopwatch::new();

    // Step A: quantization boundaries + signs. The PJRT path returns
    // device-allocated grids the arena never leased, so only the native
    // path's buffers go back to it.
    let (bres, bres_pooled) = match cfg.backend {
        Backend::Native => (sw.time(|| boundary_and_sign_on(pool, arena, q, threads)), true),
        Backend::Pjrt => (sw.time(|| crate::runtime::ops::boundary_and_sign_pjrt(q))?, false),
    };
    stats.t_boundary = std::mem::take(&mut sw).secs();

    // Wrap the step-A grids in RAII leases immediately: every exit path
    // from here on — the homogeneous early return, step errors, panics —
    // gives the buffers back when the leases drop. The PJRT variant's
    // grids are device-allocated and were never leased, so they get the
    // plain-drop (`Fresh`) wrapper instead of corrupting the gauges.
    let step_a = if bres_pooled { arena } else { ArenaHandle::Fresh };
    let mask = step_a.relend_grid(bres.mask);
    let sign = step_a.relend_grid(bres.sign);
    stats.n_boundary1 = mask.data.iter().filter(|&&b| b).count();

    if stats.n_boundary1 == 0 {
        // Homogeneous index field (paper §IX future work): nothing to do.
        let out = arena.take_copy(&dq.data);
        arena.detach(&out);
        return Ok((Grid { shape: dq.shape, data: out }, stats));
    }

    // Step B: EDT to B₁ with feature transform.
    let mut sw = Stopwatch::new();
    let edt1 = sw.time(|| edt_on(pool, arena, &mask, true, threads));
    stats.t_edt1 = std::mem::take(&mut sw).secs();
    let d1 = arena.relend(edt1.dist_sq);
    let near1 = arena.relend(edt1.nearest.expect("step B runs with the feature transform"));

    // Step C: propagate signs, build B₂.
    let mut sw = Stopwatch::new();
    let (s, b2) = sw.time(|| propagate_signs_on(pool, arena, &mask, &sign, &near1, threads));
    let s = arena.relend_grid(s);
    let b2 = arena.relend_grid(b2);
    stats.t_sign = std::mem::take(&mut sw).secs();
    stats.n_boundary2 = b2.data.iter().filter(|&&b| b).count();

    // Step D: EDT to B₂ (distances only — indices unused, paper §VI-D).
    let mut sw = Stopwatch::new();
    let edt2 = sw.time(|| edt_on(pool, arena, &b2, false, threads));
    stats.t_edt2 = std::mem::take(&mut sw).secs();
    let d2 = arena.relend(edt2.dist_sq);
    if let Some(nearest) = edt2.nearest {
        arena.give(nearest);
    }

    // Step E: interpolate and compensate, into an RAII-leased output
    // buffer seeded with the decompressed data. The lease (not a raw
    // take/give pair) is what keeps the arena's outstanding-bytes
    // accounting exact if the compensation kernel panics: the buffer
    // returns to its size class during unwind, before the service's
    // per-job panic catch ever sees the payload.
    let eta_eps = cfg.eta * eb.abs;
    let mut out = arena.lease_copy(&dq.data);
    let mut sw = Stopwatch::new();
    let compensated = match cfg.backend {
        Backend::Native => {
            sw.time(|| {
                crate::mitigation::interpolate::compensate_adaptive_on(
                    pool,
                    &mut out,
                    &d1,
                    &d2,
                    &s.data,
                    eta_eps,
                    cfg.taper_radius,
                    threads,
                );
            });
            Ok(())
        }
        Backend::Pjrt => {
            sw.time(|| crate::runtime::ops::compensate_pjrt(&mut out, &d1, &d2, &s.data, eta_eps))
        }
    };
    stats.t_compensate = std::mem::take(&mut sw).secs();

    // Every intermediate full-grid buffer (mask, sign, Dist₁, I₁, s,
    // B₂, Dist₂) is held by an RAII lease and goes back to the arena
    // when it drops below — a fresh handle just drops them — making the
    // next same-shaped call allocation-free.
    match compensated {
        Ok(()) => Ok((Grid { shape: dq.shape, data: out.detach() }, stats)),
        // The lease gives the buffer back when it drops with the error.
        Err(e) => Err(e),
    }
}

#[cfg(test)]
mod tests {
    // The deprecated free functions are exercised deliberately: their
    // bit-identical-wrapper contract is part of what these tests pin.
    #![allow(deprecated)]

    use super::*;
    use crate::data::synthetic::{generate, DatasetKind};
    use crate::metrics::{max_abs_error, ssim};
    use crate::quant::{quantize_grid, ErrorBound};
    use crate::util::prop::prop_check;

    fn roundtrip(kind: DatasetKind, dims: &[usize], rel: f64) -> (Grid<f32>, Grid<f32>, Grid<QIndex>, ResolvedBound) {
        let orig = generate(kind, dims, 7);
        let eb = ErrorBound::relative(rel).resolve(&orig.data);
        let (q, dq) = quantize_grid(&orig, eb);
        (orig, dq, q, eb)
    }

    #[test]
    fn improves_ssim_on_smooth_2d_field() {
        let (orig, dq, q, eb) = roundtrip(DatasetKind::ClimateLike, &[256, 256], 1e-2);
        let out = mitigate(&dq, &q, eb, &MitigationConfig::default());
        let before = ssim(&orig, &dq, 7, 2);
        let after = ssim(&orig, &out, 7, 2);
        assert!(after > before, "SSIM before={before:.4} after={after:.4}");
    }

    #[test]
    fn improves_ssim_on_3d_field() {
        let (orig, dq, q, eb) = roundtrip(DatasetKind::MirandaLike, &[48, 48, 48], 1e-2);
        let out = mitigate(&dq, &q, eb, &MitigationConfig::default());
        let before = ssim(&orig, &dq, 7, 2);
        let after = ssim(&orig, &out, 7, 2);
        assert!(after > before, "SSIM before={before:.4} after={after:.4}");
    }

    #[test]
    fn improves_psnr_on_3d_field() {
        let (orig, dq, q, eb) = roundtrip(DatasetKind::CombustionLike, &[48, 48, 48], 1e-2);
        let out = mitigate(&dq, &q, eb, &MitigationConfig::default());
        let before = crate::metrics::psnr(&orig.data, &dq.data);
        let after = crate::metrics::psnr(&orig.data, &out.data);
        assert!(after > before + 1.0, "PSNR before={before:.2} after={after:.2}");
    }

    #[test]
    fn canonical_ramp_improves_dramatically() {
        // The textbook banding case: a tilted linear ramp.
        let n = 96;
        let mut g = Grid::<f32>::zeros(&[n, n]);
        for j in 0..n {
            for k in 0..n {
                *g.at_mut(0, j, k) = 0.013 * j as f32 + 0.007 * k as f32;
            }
        }
        let eb = ErrorBound::relative(2e-2).resolve(&g.data);
        let (q, dq) = quantize_grid(&g, eb);
        let out = mitigate(&dq, &q, eb, &MitigationConfig::default());
        let s0 = ssim(&g, &dq, 7, 2);
        let s1 = ssim(&g, &out, 7, 2);
        let p0 = crate::metrics::psnr(&g.data, &dq.data);
        let p1 = crate::metrics::psnr(&g.data, &out.data);
        assert!(s1 > s0 + 0.03, "SSIM {s0:.4} -> {s1:.4}");
        assert!(p1 > p0 + 6.0, "PSNR {p0:.2} -> {p1:.2}");
    }

    #[test]
    fn respects_relaxed_error_bound_property() {
        prop_check("|d - d''| <= (1+eta)eps", 25, |g| {
            let d0 = g.usize_in(8, 24);
            let d1 = g.usize_in(8, 24);
            let n = d0 * d1;
            let data = {
                let row = g.smooth_field(n, 0.05);
                Grid::from_vec(row, &[d0, d1])
            };
            let rel = *g.choose(&[1e-3, 5e-3, 1e-2, 5e-2]);
            let eb = ErrorBound::relative(rel).resolve(&data.data);
            let (q, dq) = quantize_grid(&data, eb);
            let cfg = MitigationConfig { eta: 0.9, ..Default::default() };
            let out = mitigate(&dq, &q, eb, &cfg);
            let bound = (1.0 + cfg.eta) * eb.abs;
            let err = max_abs_error(&data.data, &out.data);
            assert!(err <= bound * (1.0 + 1e-5), "err={err} bound={bound}");
        });
    }

    #[test]
    fn homogeneous_field_is_identity() {
        let dq = Grid::from_vec(vec![1.0f32; 64], &[8, 8]);
        let q = Grid::from_vec(vec![5i64; 64], &[8, 8]);
        let eb = ErrorBound::absolute(0.1).resolve(&dq.data);
        let (out, stats) =
            mitigate_with_stats(&dq, &q, eb, &MitigationConfig::default()).unwrap();
        assert_eq!(out.data, dq.data);
        assert_eq!(stats.n_boundary1, 0);
    }

    #[test]
    fn threaded_matches_sequential() {
        let (_, dq, q, eb) = roundtrip(DatasetKind::HurricaneLike, &[24, 24, 24], 1e-2);
        let seq = mitigate(&dq, &q, eb, &MitigationConfig { threads: 1, ..Default::default() });
        let par = mitigate(&dq, &q, eb, &MitigationConfig { threads: 4, ..Default::default() });
        assert_eq!(seq.data, par.data);
    }

    #[test]
    fn stats_are_populated() {
        let (_, dq, q, eb) = roundtrip(DatasetKind::CombustionLike, &[16, 16, 16], 1e-2);
        let (_, stats) =
            mitigate_with_stats(&dq, &q, eb, &MitigationConfig::default()).unwrap();
        assert!(stats.n_boundary1 > 0);
        assert!(stats.total() > 0.0);
        assert!(stats.throughput_mbs(16 * 16 * 16) > 0.0);
    }

    #[test]
    fn eta_zero_is_identity() {
        let (_, dq, q, eb) = roundtrip(DatasetKind::ClimateLike, &[32, 32], 1e-2);
        let cfg = MitigationConfig { eta: 0.0, ..Default::default() };
        let out = mitigate(&dq, &q, eb, &cfg);
        assert_eq!(out.data, dq.data);
    }
}
