//! Leader driver: decompose → spawn rank workers over the fabric →
//! gather compensated blocks → report timing/traffic (Figs. 9–11).
//!
//! Per-rank compute cost is measured as *thread CPU time* (so many rank
//! threads can share this host's cores without polluting each other's
//! numbers), and communication cost is *modeled* from the recorded
//! per-message traffic via [`CommModel`] — the substitution for real MPI
//! documented in DESIGN.md §5. Wall-clock of the whole run is also
//! reported for sanity.

use crate::coordinator::strategy::{mitigate_rank, Strategy};
use crate::coordinator::topology::Topology;
use crate::coordinator::transport::{CommModel, Fabric};
use crate::data::grid::Grid;
use crate::quant::{QIndex, ResolvedBound};
use crate::util::timer::thread_cpu_time;
use anyhow::Result;
use std::sync::atomic::Ordering;

/// Distributed run configuration.
#[derive(Debug, Clone)]
pub struct DistributedConfig {
    /// Requested rank count (the topology may round down if the domain
    /// cannot host that many blocks).
    pub ranks: usize,
    /// Parallelization strategy.
    pub strategy: Strategy,
    /// Compensation factor η.
    pub eta: f64,
    /// Shared-memory threads *within* each rank (the paper uses 1).
    pub threads_per_rank: usize,
    /// Cost model for the scaling reports.
    pub comm_model: CommModel,
}

impl Default for DistributedConfig {
    fn default() -> Self {
        DistributedConfig {
            ranks: 8,
            strategy: Strategy::Approximate,
            eta: 0.9,
            threads_per_rank: 1,
            comm_model: CommModel::default(),
        }
    }
}

/// Timing/traffic report of one distributed run.
#[derive(Debug, Clone)]
pub struct DistributedReport {
    /// Actual rank count used.
    pub ranks: usize,
    /// Per-rank compute seconds (thread CPU time).
    pub compute_s: Vec<f64>,
    /// Per-rank modeled communication seconds.
    pub comm_s: Vec<f64>,
    /// Per-rank measured receive-wait seconds (synchronization).
    pub wait_s: Vec<f64>,
    /// Per-rank bytes sent.
    pub bytes_sent: Vec<u64>,
    /// Whole-run wall clock (all ranks multiplexed on this host).
    pub wall_s: f64,
}

impl DistributedReport {
    /// Modeled makespan: slowest rank's compute + its modeled comm
    /// (barrier-synchronized, like the paper's wall-clock definition).
    pub fn modeled_makespan(&self) -> f64 {
        self.compute_s
            .iter()
            .zip(&self.comm_s)
            .map(|(&c, &m)| c + m)
            .fold(0.0, f64::max)
    }

    /// Modeled throughput in MB/s for `n` f32 elements.
    pub fn modeled_throughput_mbs(&self, n: usize) -> f64 {
        (n * 4) as f64 / 1e6 / self.modeled_makespan().max(1e-12)
    }

    /// Total communication volume (bytes).
    pub fn total_bytes(&self) -> u64 {
        self.bytes_sent.iter().sum()
    }

    /// Fraction of modeled time spent communicating, for the slowest
    /// rank (Fig. 11's breakdown).
    pub fn comm_fraction(&self) -> f64 {
        let total = self.modeled_makespan();
        if total <= 0.0 {
            return 0.0;
        }
        let slowest = self
            .compute_s
            .iter()
            .zip(&self.comm_s)
            .map(|(&c, &m)| (c + m, m))
            .fold((0.0, 0.0), |acc, x| if x.0 > acc.0 { x } else { acc });
        slowest.1 / total
    }
}

/// Run the distributed mitigation over `cfg.ranks` simulated ranks.
/// Returns the compensated global field and the report.
pub fn run_distributed(
    dq: &Grid<f32>,
    q: &Grid<QIndex>,
    eb: ResolvedBound,
    cfg: &DistributedConfig,
) -> Result<(Grid<f32>, DistributedReport)> {
    assert_eq!(dq.shape, q.shape);
    let topo = Topology::new(cfg.ranks, dq.shape);
    let n_ranks = topo.n_ranks();
    let (fabric, endpoints) = Fabric::new(n_ranks);

    let t_wall = std::time::Instant::now();
    // Ranks block on each other's sends/recvs, so each needs a thread
    // it shares with no other rank for its whole lifetime.
    // `pool::scope_blocking` provides that cooperatively: it pins ranks
    // to currently-parked global-pool workers (zero OS-thread spawns
    // when pool capacity suffices — the warm steady state of repeated
    // distributed runs), spawns scoped threads only for the overflow,
    // and runs rank 0 on this thread, which then helps the pool while
    // the pinned ranks drain. The compute *inside* each rank
    // (`threads_per_rank`) runs on the shared persistent pool as
    // ordinary stealable regions.
    let tasks: Vec<_> = endpoints
        .into_iter()
        .map(|mut ep| {
            let topo = &topo;
            move || {
                let (lo, size) = topo.block(ep.rank);
                // Shared handles: the embarrassing strategy passes the
                // blocks into an engine request without copying them.
                let block_dq: crate::data::grid::SharedGrid<f32> = dq.extract(lo, size).into();
                let block_q: crate::data::grid::SharedGrid<crate::quant::QIndex> =
                    q.extract(lo, size).into();
                let cpu0 = thread_cpu_time();
                let out = mitigate_rank(
                    cfg.strategy,
                    topo,
                    &mut ep,
                    &block_dq,
                    &block_q,
                    eb,
                    cfg.eta,
                    cfg.threads_per_rank,
                );
                let cpu = thread_cpu_time() - cpu0;
                (ep.rank, out, cpu)
            }
        })
        .collect();
    let results: Vec<(usize, Grid<f32>, f64)> = crate::util::pool::scope_blocking(tasks);
    let wall_s = t_wall.elapsed().as_secs_f64();

    let mut out = Grid::<f32>::like(dq);
    out.shape.ndim = dq.shape.ndim;
    let mut compute_s = vec![0.0; n_ranks];
    for (rank, block, cpu) in results {
        let (lo, _) = topo.block(rank);
        out.insert(lo, &block);
        compute_s[rank] = cpu;
    }

    let comm_s: Vec<f64> = (0..n_ranks)
        .map(|r| fabric.stats[r].modeled_send_time(r, &cfg.comm_model))
        .collect();
    let wait_s: Vec<f64> = (0..n_ranks)
        .map(|r| fabric.stats[r].recv_wait_ns.load(Ordering::Relaxed) as f64 * 1e-9)
        .collect();
    let bytes_sent: Vec<u64> =
        (0..n_ranks).map(|r| fabric.stats[r].bytes_sent.load(Ordering::Relaxed)).collect();

    Ok((out, DistributedReport { ranks: n_ranks, compute_s, comm_s, wait_s, bytes_sent, wall_s }))
}

#[cfg(test)]
mod tests {
    // The deprecated `mitigate` wrapper is the sequential reference
    // these tests compare the distributed strategies against.
    #![allow(deprecated)]

    use super::*;
    use crate::data::synthetic::{generate, DatasetKind};
    use crate::metrics::{max_abs_error, ssim};
    use crate::mitigation::pipeline::{mitigate, MitigationConfig};
    use crate::quant::{quantize_grid, ErrorBound};

    fn setup(dims: &[usize], rel: f64) -> (Grid<f32>, Grid<f32>, Grid<QIndex>, ResolvedBound) {
        let orig = generate(DatasetKind::MirandaLike, dims, 33);
        let eb = ErrorBound::relative(rel).resolve(&orig.data);
        let (q, dq) = quantize_grid(&orig, eb);
        (orig, dq, q, eb)
    }

    #[test]
    fn exact_matches_sequential_bitwise() {
        let (_orig, dq, q, eb) = setup(&[20, 20, 20], 1e-2);
        let seq = mitigate(&dq, &q, eb, &MitigationConfig::default());
        for ranks in [2usize, 4, 8] {
            let cfg = DistributedConfig { ranks, strategy: Strategy::Exact, ..Default::default() };
            let (out, rep) = run_distributed(&dq, &q, eb, &cfg).unwrap();
            assert_eq!(out.data, seq.data, "ranks={ranks}");
            assert!(rep.total_bytes() > 0);
        }
    }

    #[test]
    fn approximate_matches_sequential_away_from_rank_faces() {
        let (_orig, dq, q, eb) = setup(&[24, 24, 24], 1e-2);
        let seq = mitigate(&dq, &q, eb, &MitigationConfig::default());
        let cfg =
            DistributedConfig { ranks: 8, strategy: Strategy::Approximate, ..Default::default() };
        let (out, _) = run_distributed(&dq, &q, eb, &cfg).unwrap();
        // Blocks are 12³; cells ≥ 4 away from every rank face see only
        // local geometry... except distant-boundary effects. Check the
        // deep interior of the first block exactly.
        let shape = dq.shape;
        let mut checked = 0;
        for i in 2..5 {
            for j in 2..5 {
                for k in 2..5 {
                    let idx = shape.idx(i, j, k);
                    // Allow tiny fp difference (same formula, same order).
                    assert!(
                        (out.data[idx] - seq.data[idx]).abs() < 1e-6,
                        "interior mismatch at {i},{j},{k}"
                    );
                    checked += 1;
                }
            }
        }
        assert!(checked > 0);
    }

    #[test]
    fn all_strategies_respect_relaxed_bound() {
        let (orig, dq, q, eb) = setup(&[16, 16, 16], 1e-2);
        for strategy in [Strategy::Embarrassing, Strategy::Exact, Strategy::Approximate] {
            let cfg = DistributedConfig { ranks: 8, strategy, ..Default::default() };
            let (out, _) = run_distributed(&dq, &q, eb, &cfg).unwrap();
            let bound = (1.0 + 0.9) * eb.abs;
            let err = max_abs_error(&orig.data, &out.data);
            assert!(err <= bound * (1.0 + 1e-5), "{strategy:?}: err={err} bound={bound}");
        }
    }

    #[test]
    fn embarrassing_sends_no_bytes() {
        let (_orig, dq, q, eb) = setup(&[16, 16, 16], 1e-2);
        let cfg =
            DistributedConfig { ranks: 8, strategy: Strategy::Embarrassing, ..Default::default() };
        let (_, rep) = run_distributed(&dq, &q, eb, &cfg).unwrap();
        assert_eq!(rep.total_bytes(), 0);
        assert!(rep.comm_s.iter().all(|&c| c == 0.0));
    }

    #[test]
    fn approximate_beats_embarrassing_on_quality() {
        let (orig, dq, q, eb) = setup(&[32, 32, 32], 1e-2);
        let run = |strategy| {
            let cfg = DistributedConfig { ranks: 8, strategy, ..Default::default() };
            let (out, _) = run_distributed(&dq, &q, eb, &cfg).unwrap();
            ssim(&orig, &out, 7, 2)
        };
        let s_approx = run(Strategy::Approximate);
        let s_embar = run(Strategy::Embarrassing);
        assert!(
            s_approx >= s_embar,
            "approx={s_approx:.4} embarrassing={s_embar:.4}"
        );
    }

    #[test]
    fn single_rank_matches_sequential_for_all_strategies() {
        let (_orig, dq, q, eb) = setup(&[12, 12, 12], 1e-2);
        let seq = mitigate(&dq, &q, eb, &MitigationConfig::default());
        for strategy in [Strategy::Embarrassing, Strategy::Exact, Strategy::Approximate] {
            let cfg = DistributedConfig { ranks: 1, strategy, ..Default::default() };
            let (out, rep) = run_distributed(&dq, &q, eb, &cfg).unwrap();
            assert_eq!(rep.ranks, 1);
            assert_eq!(out.data, seq.data, "{strategy:?}");
        }
    }

    #[test]
    fn report_metrics_are_consistent() {
        let (_orig, dq, q, eb) = setup(&[16, 16, 16], 1e-2);
        let cfg =
            DistributedConfig { ranks: 4, strategy: Strategy::Approximate, ..Default::default() };
        let (_, rep) = run_distributed(&dq, &q, eb, &cfg).unwrap();
        assert_eq!(rep.compute_s.len(), rep.ranks);
        assert!(rep.modeled_makespan() > 0.0);
        assert!(rep.modeled_throughput_mbs(16 * 16 * 16) > 0.0);
        assert!((0.0..=1.0).contains(&rep.comm_fraction()));
        assert!(rep.wall_s > 0.0);
    }
}
