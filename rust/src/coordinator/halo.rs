//! Ghost (halo) layer management: pad rank-local blocks with a 1-wide
//! ghost shell, fill it by stencil communication with face neighbors
//! (§VII-B "exchange ghost elements ... which only involves stencil
//! communication"), replicate at true domain edges.
//!
//! Axes are exchanged sequentially and each face plane includes the
//! ghost cells of previously exchanged axes, so edge/corner ghosts end
//! up correct after the last axis — the standard trick that keeps halo
//! exchange to 2 messages per axis.

use crate::cluster::transport::{Transport, TransportExt};
use crate::coordinator::topology::Topology;
use crate::coordinator::transport::Pod;
use crate::data::grid::Grid;

/// Which axes carry a ghost shell (the topology's active axes).
pub fn ghosted_axes(topo: &Topology) -> [bool; 3] {
    let mut g = [false; 3];
    for a in 0..3 {
        g[a] = topo.data.dims[a] > 1;
    }
    g
}

/// Padded dims for a local block of `size`.
pub fn padded_dims(size: [usize; 3], ghosted: [bool; 3]) -> [usize; 3] {
    let mut d = size;
    for a in 0..3 {
        if ghosted[a] {
            d[a] += 2;
        }
    }
    d
}

/// Embed a block into a fresh padded grid; ghosts are replicate-filled
/// from the block's own faces (correct for true domain edges; interior
/// faces are overwritten by [`exchange`]).
pub fn pad<T: Copy + Default>(block: &Grid<T>, ghosted: [bool; 3]) -> Grid<T> {
    let size = block.shape.dims;
    let pd = padded_dims(size, ghosted);
    let mut padded = Grid::<T>::zeros(&[pd[0], pd[1], pd[2]]);
    padded.shape.ndim = block.shape.ndim;
    let off = |a: usize| usize::from(ghosted[a]);
    // Replicate-fill by clamped gather (simple, runs once per pipeline).
    for i in 0..pd[0] {
        for j in 0..pd[1] {
            for k in 0..pd[2] {
                let src = [
                    (i as isize - off(0) as isize).clamp(0, size[0] as isize - 1) as usize,
                    (j as isize - off(1) as isize).clamp(0, size[1] as isize - 1) as usize,
                    (k as isize - off(2) as isize).clamp(0, size[2] as isize - 1) as usize,
                ];
                *padded.at_mut(i, j, k) = block.at(src[0], src[1], src[2]);
            }
        }
    }
    padded
}

/// Extract the interior (inverse of [`pad`]).
pub fn unpad<T: Copy + Default>(padded: &Grid<T>, ghosted: [bool; 3]) -> Grid<T> {
    let lo = [usize::from(ghosted[0]), usize::from(ghosted[1]), usize::from(ghosted[2])];
    trim(padded, lo, lo)
}

/// Strip asymmetric ghost margins: drop `lo_margin[a]` cells from the
/// low side and `hi_margin[a]` from the high side of each axis. The
/// generalized form of [`unpad`] (which strips 1-cell symmetric
/// margins): the tiled executor's shrink-clamped halo windows
/// (`crate::mitigation::tiled`) have margins of `0..=halo` per side
/// depending on how the window met the domain edge.
pub fn trim<T: Copy + Default>(
    padded: &Grid<T>,
    lo_margin: [usize; 3],
    hi_margin: [usize; 3],
) -> Grid<T> {
    let pd = padded.shape.dims;
    let mut size = [0usize; 3];
    for a in 0..3 {
        assert!(
            lo_margin[a] + hi_margin[a] < pd[a],
            "margins consume the whole axis {a}: {} + {} >= {}",
            lo_margin[a],
            hi_margin[a],
            pd[a]
        );
        size[a] = pd[a] - lo_margin[a] - hi_margin[a];
    }
    let mut out = padded.extract(lo_margin, size);
    out.shape.ndim = padded.shape.ndim;
    out
}

/// Gather the cross-section plane `coord` along `axis` (full extent of
/// the other axes, ghosts included).
fn gather_plane<T: Copy>(g: &Grid<T>, axis: usize, coord: usize) -> Vec<T> {
    let d = g.shape.dims;
    let mut out = Vec::with_capacity(d[(axis + 1) % 3] * d[(axis + 2) % 3]);
    let (oa, ob) = match axis {
        0 => (1, 2),
        1 => (0, 2),
        _ => (0, 1),
    };
    for a in 0..d[oa] {
        for b in 0..d[ob] {
            let (i, j, k) = match axis {
                0 => (coord, a, b),
                1 => (a, coord, b),
                _ => (a, b, coord),
            };
            out.push(g.at(i, j, k));
        }
    }
    out
}

/// Scatter a plane gathered by [`gather_plane`] back at `coord`.
fn scatter_plane<T: Copy + Default>(g: &mut Grid<T>, axis: usize, coord: usize, plane: &[T]) {
    let d = g.shape.dims;
    let (oa, ob) = match axis {
        0 => (1, 2),
        1 => (0, 2),
        _ => (0, 1),
    };
    assert_eq!(plane.len(), d[oa] * d[ob], "plane size mismatch");
    let mut it = plane.iter();
    for a in 0..d[oa] {
        for b in 0..d[ob] {
            let (i, j, k) = match axis {
                0 => (coord, a, b),
                1 => (a, coord, b),
                _ => (a, b, coord),
            };
            *g.at_mut(i, j, k) = *it.next().unwrap();
        }
    }
}

/// One round of ghost exchange over all ghosted axes. `tag_base`
/// namespaces this round's messages (steps A and C use distinct bases).
/// `ep` is any [`Transport`]: the in-process fabric endpoint and the
/// cluster's socket transport exchange the identical planes.
pub fn exchange<T: Pod + Default>(
    padded: &mut Grid<T>,
    ghosted: [bool; 3],
    ep: &mut dyn Transport,
    topo: &Topology,
    tag_base: u64,
) {
    for axis in 0..3 {
        if !ghosted[axis] {
            continue;
        }
        let d = padded.shape.dims[axis];
        let lo_nb = topo.neighbor(ep.rank(), axis, -1);
        let hi_nb = topo.neighbor(ep.rank(), axis, 1);
        let tag_lo = tag_base + axis as u64 * 2; // toward lower ranks
        let tag_hi = tag_base + axis as u64 * 2 + 1; // toward higher ranks

        // Post sends first (eager) to avoid deadlock.
        if let Some(nb) = lo_nb {
            let plane = gather_plane(padded, axis, 1); // first interior plane
            ep.send_slice(nb, tag_lo, &plane);
        }
        if let Some(nb) = hi_nb {
            let plane = gather_plane(padded, axis, d - 2); // last interior plane
            ep.send_slice(nb, tag_hi, &plane);
        }
        if let Some(nb) = lo_nb {
            let plane: Vec<T> = ep.recv_slice(nb, tag_hi);
            scatter_plane(padded, axis, 0, &plane);
        }
        if let Some(nb) = hi_nb {
            let plane: Vec<T> = ep.recv_slice(nb, tag_lo);
            scatter_plane(padded, axis, d - 1, &plane);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::transport::Fabric;
    use crate::data::grid::Shape;

    #[test]
    fn pad_unpad_roundtrip_and_replication() {
        let block = Grid::from_vec((0..8).map(|x| x as f32).collect(), &[2, 2, 2]);
        let g = pad(&block, [true, true, true]);
        assert_eq!(g.shape.dims, [4, 4, 4]);
        assert_eq!(unpad(&g, [true, true, true]).data, block.data);
        // corner ghost replicates nearest block corner
        assert_eq!(g.at(0, 0, 0), block.at(0, 0, 0));
        assert_eq!(g.at(3, 3, 3), block.at(1, 1, 1));
    }

    #[test]
    fn trim_strips_asymmetric_margins() {
        let g = Grid::from_vec((0..60).map(|x| x as i64).collect(), &[3, 4, 5]);
        let t = trim(&g, [1, 0, 2], [0, 1, 1]);
        assert_eq!(t.shape.dims, [2, 3, 2]);
        assert_eq!(t.shape.ndim, 3);
        for i in 0..2 {
            for j in 0..3 {
                for k in 0..2 {
                    assert_eq!(t.at(i, j, k), g.at(i + 1, j, k + 2));
                }
            }
        }
        // Zero margins are the identity.
        assert_eq!(trim(&g, [0; 3], [0; 3]).data, g.data);
    }

    #[test]
    fn pad_2d_only_active_axes() {
        let block = Grid::from_vec((0..6).map(|x| x as i64).collect(), &[2, 3]);
        let g = pad(&block, [false, true, true]);
        assert_eq!(g.shape.dims, [1, 4, 5]);
        assert_eq!(unpad(&g, [false, true, true]).data, block.data);
    }

    #[test]
    fn exchange_fills_ghosts_from_neighbors() {
        // Global 2D 4x4 grid of values = flat index; 2x2 rank grid.
        let shape = Shape::new(&[4, 4]);
        let global = Grid::from_vec((0..16).map(|x| x as i64).collect(), &[4, 4]);
        let topo = Topology::new(4, shape);
        assert_eq!(topo.rank_grid, [1, 2, 2]);
        let ghosted = ghosted_axes(&topo);
        let (_fabric, endpoints) = Fabric::new(4);

        let results: Vec<Grid<i64>> = std::thread::scope(|s| {
            let handles: Vec<_> = endpoints
                .into_iter()
                .map(|mut ep| {
                    let topo = &topo;
                    let global = &global;
                    s.spawn(move || {
                        let (lo, size) = topo.block(ep.rank);
                        let block = global.extract(lo, size);
                        let mut padded = pad(&block, ghosted);
                        exchange(&mut padded, ghosted, &mut ep, topo, 100);
                        padded
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });

        // Rank 0 owns rows 0..2, cols 0..2. Its high-col ghost must hold
        // neighbor values 2 and 6; high-row ghost holds 8, 9.
        let r0 = &results[0];
        assert_eq!(r0.at(0, 1, 3), global.at(0, 0, 2));
        assert_eq!(r0.at(0, 2, 3), global.at(0, 1, 2));
        assert_eq!(r0.at(0, 3, 1), global.at(0, 2, 0));
        // Diagonal corner ghost correct thanks to sequential axes.
        assert_eq!(r0.at(0, 3, 3), global.at(0, 2, 2));
        // Domain-edge ghosts keep replication.
        assert_eq!(r0.at(0, 0, 0), global.at(0, 0, 0));
    }

    #[test]
    fn exchange_matches_global_extraction_3d() {
        // Every rank's padded block must equal the clamped global window.
        let shape = Shape::new(&[6, 6, 6]);
        let global = Grid::from_vec((0..216).map(|x| (x * 7 % 31) as i64).collect(), &[6, 6, 6]);
        let topo = Topology::new(8, shape);
        let ghosted = ghosted_axes(&topo);
        let (_fabric, endpoints) = Fabric::new(topo.n_ranks());

        let results: Vec<(usize, Grid<i64>)> = std::thread::scope(|s| {
            let handles: Vec<_> = endpoints
                .into_iter()
                .map(|mut ep| {
                    let topo = &topo;
                    let global = &global;
                    s.spawn(move || {
                        let (lo, size) = topo.block(ep.rank);
                        let block = global.extract(lo, size);
                        let mut padded = pad(&block, ghosted);
                        exchange(&mut padded, ghosted, &mut ep, topo, 0);
                        (ep.rank, padded)
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });

        for (rank, padded) in results {
            let (lo, size) = topo.block(rank);
            for i in 0..size[0] + 2 {
                for j in 0..size[1] + 2 {
                    for k in 0..size[2] + 2 {
                        let gi = (lo[0] as isize + i as isize - 1).clamp(0, 5) as usize;
                        let gj = (lo[1] as isize + j as isize - 1).clamp(0, 5) as usize;
                        let gk = (lo[2] as isize + k as isize - 1).clamp(0, 5) as usize;
                        assert_eq!(
                            padded.at(i, j, k),
                            global.at(gi, gj, gk),
                            "rank {rank} ghost mismatch at {i},{j},{k}"
                        );
                    }
                }
            }
        }
    }
}
