//! The three distributed parallelization strategies (paper §VII-B),
//! executed per rank over the simulated-MPI fabric.
//!
//! * [`Strategy::Embarrassing`] — no communication at all; each rank
//!   runs the full pipeline on its local block. Maximal scalability,
//!   but rank-boundary cells are treated as domain edges, producing the
//!   striping artifacts of Fig. 4.
//! * [`Strategy::Exact`] — sequentially-compliant: ghost exchange makes
//!   boundary detection globally correct, then the EDT rounds run
//!   *globally* (gathered to the leader — the sequential dependence the
//!   paper describes, taken to its serialization limit), and results are
//!   scattered back. Bit-identical to the sequential pipeline; worst
//!   scalability.
//! * [`Strategy::Approximate`] — two rounds of stencil communication
//!   (ghosts of the index field for step A, ghosts of the sign map for
//!   step C); EDT stays rank-local. Near-embarrassing scalability with
//!   near-exact quality.

use crate::cluster::transport::{Transport, TransportExt};
use crate::coordinator::halo::{exchange, ghosted_axes, pad, unpad};
use crate::coordinator::topology::Topology;
use crate::data::grid::{Grid, SharedGrid};
use crate::mitigation::boundary::{boundary_and_sign, boundary_mask, BoundaryResult};
use crate::mitigation::edt::edt;
use crate::mitigation::engine::{self, MitigationRequest};
use crate::mitigation::interpolate::compensate;
use crate::mitigation::pipeline::MitigationConfig;
use crate::mitigation::sign::propagate_signs;
use crate::quant::{QIndex, ResolvedBound};

/// Tag bases: one namespace per communication round.
const TAG_HALO_Q: u64 = 1_000;
const TAG_HALO_S: u64 = 2_000;
const TAG_GATHER_MASK: u64 = 3_000;
const TAG_GATHER_SIGN: u64 = 3_001;
const TAG_SCATTER_D1: u64 = 4_000;
const TAG_SCATTER_D2: u64 = 4_001;
const TAG_SCATTER_S: u64 = 4_002;

/// Distributed parallelization strategy (§VII-B).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Strategy {
    /// Communication-free local computation.
    Embarrassing,
    /// Sequentially-compliant global computation.
    Exact,
    /// Ghost-exchange approximation (two stencil rounds).
    Approximate,
}

impl Strategy {
    /// Parse from a CLI string.
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "embarrassing" | "ep" => Ok(Strategy::Embarrassing),
            "exact" => Ok(Strategy::Exact),
            "approximate" | "approx" => Ok(Strategy::Approximate),
            other => anyhow::bail!("unknown strategy {other:?} (embarrassing|exact|approximate)"),
        }
    }

    /// Display name matching the paper.
    pub fn name(&self) -> &'static str {
        match self {
            Strategy::Embarrassing => "Embarrassingly Parallel",
            Strategy::Exact => "Exact Parallelization",
            Strategy::Approximate => "Approximate Parallelization",
        }
    }
}

/// Run one rank's share of the mitigation. `block_dq`/`block_q` are the
/// rank's local blocks (shared handles, so the embarrassing strategy's
/// request payload is a pointer bump); returns the compensated local
/// block. `ep` is any [`Transport`] — the in-process fabric endpoint
/// and the cluster's socket transport run the identical code path.
#[allow(clippy::too_many_arguments)]
pub fn mitigate_rank(
    strategy: Strategy,
    topo: &Topology,
    ep: &mut dyn Transport,
    block_dq: &SharedGrid<f32>,
    block_q: &SharedGrid<QIndex>,
    eb: ResolvedBound,
    eta: f64,
    threads: usize,
) -> Grid<f32> {
    match strategy {
        Strategy::Embarrassing => {
            let cfg = MitigationConfig { eta, threads, ..Default::default() };
            let request =
                MitigationRequest::new(block_dq.clone(), block_q.clone(), eb).config(cfg);
            engine::execute(&request).expect("mitigation failed").output
        }
        Strategy::Approximate => {
            mitigate_rank_approximate(topo, ep, block_dq, block_q, eb, eta, threads)
        }
        Strategy::Exact => mitigate_rank_exact(topo, ep, block_dq, block_q, eb, eta, threads),
    }
}

/// Step A with ghosts: boundary/sign over the ghost-exchanged index
/// block, with marks cleared on *global* domain edges (Alg. 2 bounds).
fn boundary_with_ghosts(
    topo: &Topology,
    ep: &mut dyn Transport,
    block_q: &Grid<QIndex>,
    threads: usize,
) -> (BoundaryResult, [bool; 3]) {
    let ghosted = ghosted_axes(topo);
    let mut padded_q = pad(block_q, ghosted);
    exchange(&mut padded_q, ghosted, ep, topo, TAG_HALO_Q);
    let mut bres = boundary_and_sign(&padded_q, threads);
    clear_global_edges(topo, ep.rank(), ghosted, &mut bres.mask, Some(&mut bres.sign));
    (bres, ghosted)
}

/// Approximate strategy: two stencil rounds, local EDTs.
fn mitigate_rank_approximate(
    topo: &Topology,
    ep: &mut dyn Transport,
    block_dq: &Grid<f32>,
    block_q: &Grid<QIndex>,
    eb: ResolvedBound,
    eta: f64,
    threads: usize,
) -> Grid<f32> {
    let (bres, ghosted) = boundary_with_ghosts(topo, ep, block_q, threads);
    if bres.mask.data.iter().all(|&b| !b) {
        // Even without local boundaries the sign-halo round must still
        // run (neighbors block on it), after which compensation may
        // still be zero everywhere locally.
        let mut s = pad(&Grid::<i8>::like(block_q), ghosted);
        exchange(&mut s, ghosted, ep, topo, TAG_HALO_S);
        return block_dq.clone();
    }

    // Steps B/C over the padded block.
    let edt1 = edt(&bres.mask, true, threads);
    let (mut s, _local_b2) =
        propagate_signs(&bres.mask, &bres.sign, edt1.nearest.as_ref().unwrap(), threads);

    // Second stencil round: neighbor signs, then recompute B₂ with them.
    exchange(&mut s, ghosted, ep, topo, TAG_HALO_S);
    let mut b2 = boundary_mask(&s, threads);
    clear_global_edges(topo, ep.rank(), ghosted, &mut b2, None);

    // Step D local, step E on the padded block, then drop ghosts.
    let edt2 = edt(&b2, false, threads);
    let mut padded_out = pad(block_dq, ghosted);
    compensate(&mut padded_out.data, &edt1.dist_sq, &edt2.dist_sq, &s.data, eta * eb.abs, threads);
    let mut out = unpad(&padded_out, ghosted);
    out.shape.ndim = block_dq.shape.ndim;
    out
}

/// Exact strategy: ghost-correct step A, then leader-global EDT rounds.
fn mitigate_rank_exact(
    topo: &Topology,
    ep: &mut dyn Transport,
    block_dq: &Grid<f32>,
    block_q: &Grid<QIndex>,
    eb: ResolvedBound,
    eta: f64,
    threads: usize,
) -> Grid<f32> {
    let (bres, ghosted) = boundary_with_ghosts(topo, ep, block_q, threads);
    // Interior (unpadded) mask + sign for the gather.
    let mask_local = unpad(&map_bool_to_i8(&bres.mask), ghosted);
    let sign_local = unpad(&bres.sign, ghosted);

    let leader = 0usize;
    ep.send_slice(leader, TAG_GATHER_MASK, &mask_local.data);
    ep.send_slice(leader, TAG_GATHER_SIGN, &sign_local.data);

    let (d1, d2, s) = if ep.rank() == leader {
        // Assemble global mask/sign, run the global sequential steps.
        let shape = topo.data;
        let mut gmask = Grid::<bool>::zeros(&[shape.dims[0], shape.dims[1], shape.dims[2]]);
        let mut gsign = Grid::<i8>::zeros(&[shape.dims[0], shape.dims[1], shape.dims[2]]);
        for r in 0..topo.n_ranks() {
            let (lo, size) = topo.block(r);
            let m: Vec<i8> = ep.recv_slice(r, TAG_GATHER_MASK);
            let sg: Vec<i8> = ep.recv_slice(r, TAG_GATHER_SIGN);
            let mblock = Grid::from_vec(m.iter().map(|&v| v != 0).collect(), &size);
            let sblock = Grid::from_vec(sg, &size);
            gmask.insert(lo, &mblock);
            gsign.insert(lo, &sblock);
        }
        let edt1 = edt(&gmask, true, threads);
        let (gs, gb2) = propagate_signs(&gmask, &gsign, edt1.nearest.as_ref().unwrap(), threads);
        let edt2 = edt(&gb2, false, threads);
        let gd1 = Grid::from_vec(edt1.dist_sq, &shape.dims);
        let gd2 = Grid::from_vec(edt2.dist_sq, &shape.dims);
        // Scatter each rank's sub-blocks.
        for r in 0..topo.n_ranks() {
            let (lo, size) = topo.block(r);
            if r == leader {
                continue;
            }
            ep.send_slice(r, TAG_SCATTER_D1, &gd1.extract(lo, size).data);
            ep.send_slice(r, TAG_SCATTER_D2, &gd2.extract(lo, size).data);
            ep.send_slice(r, TAG_SCATTER_S, &gs.extract(lo, size).data);
        }
        let (lo, size) = topo.block(leader);
        (
            gd1.extract(lo, size).data,
            gd2.extract(lo, size).data,
            gs.extract(lo, size).data,
        )
    } else {
        (
            ep.recv_slice::<i64>(leader, TAG_SCATTER_D1),
            ep.recv_slice::<i64>(leader, TAG_SCATTER_D2),
            ep.recv_slice::<i8>(leader, TAG_SCATTER_S),
        )
    };

    let mut out = block_dq.clone();
    compensate(&mut out.data, &d1, &d2, &s, eta * eb.abs, threads);
    out
}

fn map_bool_to_i8(g: &Grid<bool>) -> Grid<i8> {
    let mut out = Grid::<i8>::like(g);
    for (dst, &b) in out.data.iter_mut().zip(&g.data) {
        *dst = b as i8;
    }
    out
}

/// Clear boundary marks (and signs) at cells lying on the *global*
/// domain edge of any active axis — Alg. 2 never marks those. Grids here
/// are ghost-padded; padded coordinate `c` maps to global `lo + c − 1`
/// on ghosted axes.
fn clear_global_edges(
    topo: &Topology,
    rank: usize,
    ghosted: [bool; 3],
    mask: &mut Grid<bool>,
    mut sign: Option<&mut Grid<i8>>,
) {
    let (lo, _) = topo.block(rank);
    let gdims = topo.data.dims;
    let pd = mask.shape.dims;
    for i in 0..pd[0] {
        for j in 0..pd[1] {
            for k in 0..pd[2] {
                let p = [i, j, k];
                let mut on_edge = false;
                for a in 0..3 {
                    if gdims[a] == 1 {
                        continue;
                    }
                    let off = usize::from(ghosted[a]);
                    let g = lo[a] as isize + p[a] as isize - off as isize;
                    if g <= 0 || g >= gdims[a] as isize - 1 {
                        on_edge = true;
                        break;
                    }
                }
                if on_edge {
                    let idx = mask.shape.idx(i, j, k);
                    mask.data[idx] = false;
                    if let Some(sg) = sign.as_deref_mut() {
                        sg.data[idx] = 0;
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strategy_parsing() {
        assert_eq!(Strategy::parse("approx").unwrap(), Strategy::Approximate);
        assert_eq!(Strategy::parse("ep").unwrap(), Strategy::Embarrassing);
        assert_eq!(Strategy::parse("exact").unwrap(), Strategy::Exact);
        assert!(Strategy::parse("nope").is_err());
    }

    // End-to-end strategy behaviour (exact ≡ sequential, approximate ≈
    // sequential, embarrassing shows edge artifacts) is covered in
    // `driver.rs` tests and `rust/tests/distributed.rs`, where the full
    // fabric is in play.
}
