//! Rank topology and domain decomposition: a 3D Cartesian process grid
//! over the data domain, mirroring `MPI_Cart_create` usage in the paper's
//! distributed runs (e.g. 512 ranks over a 4096³ JHTDB volume).

use crate::data::grid::Shape;

/// A Cartesian decomposition of `n_ranks` over a data shape.
#[derive(Debug, Clone)]
pub struct Topology {
    /// Ranks per (normalized) axis; 1 on unit axes.
    pub rank_grid: [usize; 3],
    /// The global data shape.
    pub data: Shape,
}

impl Topology {
    /// Choose a near-balanced rank grid: factorize `n_ranks` across the
    /// active axes to minimize block surface (max comm locality), never
    /// splitting an axis finer than its extent.
    pub fn new(n_ranks: usize, data: Shape) -> Self {
        assert!(n_ranks > 0);
        let mut rank_grid = [1usize; 3];
        let mut remaining = n_ranks;
        // Greedy: repeatedly give the smallest prime factor to the axis
        // with the largest per-rank extent.
        let mut factors = prime_factors(remaining);
        factors.sort_unstable_by(|a, b| b.cmp(a)); // large factors first
        for f in factors {
            let axis = (0..3)
                .filter(|&a| data.dims[a] / (rank_grid[a] * f) >= 1 && data.dims[a] > 1)
                .max_by_key(|&a| data.dims[a] / rank_grid[a]);
            match axis {
                Some(a) => rank_grid[a] *= f,
                None => {
                    // Cannot place this factor anywhere; drop it (fewer
                    // ranks than requested — caller can check capacity).
                    remaining /= f;
                }
            }
        }
        let _ = remaining;
        Topology { rank_grid, data }
    }

    /// Actual number of ranks in the decomposition.
    pub fn n_ranks(&self) -> usize {
        self.rank_grid.iter().product()
    }

    /// Rank id → grid coordinates.
    pub fn coords(&self, rank: usize) -> [usize; 3] {
        let g = self.rank_grid;
        [rank / (g[1] * g[2]), (rank / g[2]) % g[1], rank % g[2]]
    }

    /// Grid coordinates → rank id.
    pub fn rank_of(&self, c: [usize; 3]) -> usize {
        (c[0] * self.rank_grid[1] + c[1]) * self.rank_grid[2] + c[2]
    }

    /// The data sub-block `(lo, size)` owned by `rank` (balanced split:
    /// first `extra` ranks along an axis get one extra element).
    pub fn block(&self, rank: usize) -> ([usize; 3], [usize; 3]) {
        let c = self.coords(rank);
        let mut lo = [0usize; 3];
        let mut size = [0usize; 3];
        for a in 0..3 {
            let n = self.data.dims[a];
            let p = self.rank_grid[a];
            let base = n / p;
            let extra = n % p;
            let start = c[a] * base + c[a].min(extra);
            let len = base + usize::from(c[a] < extra);
            lo[a] = start;
            size[a] = len;
        }
        (lo, size)
    }

    /// Face neighbor of `rank` along `axis` in direction `dir` (−1/+1),
    /// or `None` at the domain boundary.
    pub fn neighbor(&self, rank: usize, axis: usize, dir: isize) -> Option<usize> {
        let mut c = self.coords(rank);
        let next = c[axis] as isize + dir;
        if next < 0 || next >= self.rank_grid[axis] as isize {
            return None;
        }
        c[axis] = next as usize;
        Some(self.rank_of(c))
    }
}

fn prime_factors(mut n: usize) -> Vec<usize> {
    let mut out = Vec::new();
    let mut d = 2;
    while d * d <= n {
        while n % d == 0 {
            out.push(d);
            n /= d;
        }
        d += 1;
    }
    if n > 1 {
        out.push(n);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn blocks_tile_the_domain_exactly() {
        for n_ranks in [1usize, 2, 3, 4, 8, 12, 64] {
            let shape = Shape::new(&[20, 30, 40]);
            let t = Topology::new(n_ranks, shape);
            let mut covered = vec![false; shape.len()];
            for r in 0..t.n_ranks() {
                let (lo, size) = t.block(r);
                for i in lo[0]..lo[0] + size[0] {
                    for j in lo[1]..lo[1] + size[1] {
                        for k in lo[2]..lo[2] + size[2] {
                            let idx = shape.idx(i, j, k);
                            assert!(!covered[idx], "overlap at {i},{j},{k} ranks={n_ranks}");
                            covered[idx] = true;
                        }
                    }
                }
            }
            assert!(covered.iter().all(|&c| c), "gap with ranks={n_ranks}");
        }
    }

    #[test]
    fn rank_coord_roundtrip() {
        let t = Topology::new(12, Shape::new(&[24, 24, 24]));
        for r in 0..t.n_ranks() {
            assert_eq!(t.rank_of(t.coords(r)), r);
        }
    }

    #[test]
    fn unit_axes_never_split() {
        let t = Topology::new(8, Shape::new(&[64, 64])); // 2D → dims [1,64,64]
        assert_eq!(t.rank_grid[0], 1);
        assert_eq!(t.n_ranks(), 8);
    }

    #[test]
    fn neighbors() {
        let t = Topology::new(8, Shape::new(&[16, 16, 16]));
        assert_eq!(t.rank_grid, [2, 2, 2]);
        let r = t.rank_of([0, 0, 0]);
        assert_eq!(t.neighbor(r, 0, -1), None);
        assert_eq!(t.neighbor(r, 0, 1), Some(t.rank_of([1, 0, 0])));
        assert_eq!(t.neighbor(r, 2, 1), Some(t.rank_of([0, 0, 1])));
    }

    #[test]
    fn cubic_rank_counts_split_cubically() {
        let t = Topology::new(64, Shape::new(&[512, 512, 512]));
        assert_eq!(t.rank_grid, [4, 4, 4]);
    }

    #[test]
    fn more_ranks_than_elements_degrades_gracefully() {
        let t = Topology::new(64, Shape::new(&[2, 2]));
        assert!(t.n_ranks() <= 4);
        // still tiles
        let total: usize = (0..t.n_ranks()).map(|r| t.block(r).1.iter().product::<usize>()).sum();
        assert_eq!(total, 4);
    }
}
