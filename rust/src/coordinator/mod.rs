//! Distributed-memory coordinator (paper §VII-B): domain decomposition,
//! rank topology, ghost exchange and the three parallelization
//! strategies over a simulated-MPI transport (DESIGN.md §5).

pub mod driver;
pub mod halo;
pub mod strategy;
pub mod topology;
pub mod transport;

pub use driver::{run_distributed, DistributedConfig, DistributedReport};
pub use strategy::Strategy;
