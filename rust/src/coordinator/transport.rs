//! Simulated-MPI transport (DESIGN.md §5): an in-process message-passing
//! fabric with per-rank instrumentation, plus an analytic communication
//! cost model used to report scaling beyond the host's physical cores.
//!
//! The fabric reproduces the *communication pattern* (who sends how many
//! bytes to whom, and which receives block on which sends) exactly; the
//! cost model turns the recorded traffic into modeled wall-clock using
//! the standard `α + β·bytes` (latency + inverse-bandwidth) form, with a
//! cheaper intra-node β — the same first-order model used to reason
//! about halo exchanges on real clusters.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;

/// One message on the fabric.
struct Message {
    from: usize,
    tag: u64,
    payload: Vec<u8>,
}

/// Per-rank traffic counters (atomics: written by the rank's thread,
/// read by the driver afterwards).
#[derive(Debug, Default)]
pub struct RankStats {
    /// Bytes sent by this rank.
    pub bytes_sent: AtomicU64,
    /// Messages sent by this rank.
    pub msgs_sent: AtomicU64,
    /// Nanoseconds spent blocked in `recv`.
    pub recv_wait_ns: AtomicU64,
    /// Per-message log `(destination, bytes)` — feeds the cost model.
    pub sent_log: std::sync::Mutex<Vec<(usize, u64)>>,
}

impl RankStats {
    /// Modeled communication seconds for everything this rank sent.
    pub fn modeled_send_time(&self, rank: usize, model: &CommModel) -> f64 {
        self.sent_log
            .lock()
            .unwrap()
            .iter()
            .map(|&(to, bytes)| model.message_time(rank, to, bytes))
            .sum()
    }
}

/// Analytic cost model for one point-to-point message.
#[derive(Debug, Clone, Copy)]
pub struct CommModel {
    /// Per-message latency (seconds) between nodes.
    pub latency: f64,
    /// Inter-node bandwidth (bytes/second).
    pub bandwidth: f64,
    /// Ranks per node (messages within a node use `intra_factor`).
    pub ranks_per_node: usize,
    /// Intra-node latency/bandwidth advantage factor (≥ 1).
    pub intra_factor: f64,
}

impl Default for CommModel {
    fn default() -> Self {
        // HDR InfiniBand-class defaults: 2 µs latency, 12 GB/s per rank
        // pair, 64 ranks/node (the paper's 2×64-core EPYC nodes), 8×
        // faster intra-node.
        CommModel { latency: 2e-6, bandwidth: 12e9, ranks_per_node: 64, intra_factor: 8.0 }
    }
}

impl CommModel {
    /// Modeled seconds for one message of `bytes` between two ranks.
    pub fn message_time(&self, from: usize, to: usize, bytes: u64) -> f64 {
        let same_node = from / self.ranks_per_node.max(1) == to / self.ranks_per_node.max(1);
        let t = self.latency + bytes as f64 / self.bandwidth;
        if same_node {
            t / self.intra_factor
        } else {
            t
        }
    }
}

/// The shared fabric: one mailbox per rank.
pub struct Fabric {
    senders: Vec<Sender<Message>>,
    /// Per-rank counters, indexable by rank id.
    pub stats: Vec<Arc<RankStats>>,
}

impl Fabric {
    /// Build a fabric for `n` ranks; returns (fabric, per-rank endpoints).
    pub fn new(n: usize) -> (Arc<Fabric>, Vec<Endpoint>) {
        let mut senders = Vec::with_capacity(n);
        let mut receivers = VecDeque::with_capacity(n);
        for _ in 0..n {
            let (tx, rx) = channel();
            senders.push(tx);
            receivers.push_back(rx);
        }
        let stats: Vec<Arc<RankStats>> = (0..n).map(|_| Arc::new(RankStats::default())).collect();
        let fabric = Arc::new(Fabric { senders, stats: stats.clone() });
        let endpoints = (0..n)
            .map(|rank| Endpoint {
                rank,
                n_ranks: n,
                fabric: fabric.clone(),
                rx: receivers.pop_front().unwrap(),
                pending: Vec::new(),
                stats: stats[rank].clone(),
            })
            .collect();
        (fabric, endpoints)
    }

    /// Total bytes sent across all ranks.
    pub fn total_bytes(&self) -> u64 {
        self.stats.iter().map(|s| s.bytes_sent.load(Ordering::Relaxed)).sum()
    }

    /// Total messages sent across all ranks.
    pub fn total_msgs(&self) -> u64 {
        self.stats.iter().map(|s| s.msgs_sent.load(Ordering::Relaxed)).sum()
    }
}

/// A rank's handle on the fabric: MPI-style tagged send/recv with
/// out-of-order buffering (matching on `(from, tag)`).
pub struct Endpoint {
    /// This rank's id.
    pub rank: usize,
    /// World size.
    pub n_ranks: usize,
    fabric: Arc<Fabric>,
    rx: Receiver<Message>,
    pending: Vec<Message>,
    stats: Arc<RankStats>,
}

impl Endpoint {
    /// Send `payload` to `to` with `tag` (non-blocking, like an eager
    /// MPI_Isend — the receiving mailbox is unbounded).
    pub fn send(&self, to: usize, tag: u64, payload: Vec<u8>) {
        self.stats.bytes_sent.fetch_add(payload.len() as u64, Ordering::Relaxed);
        self.stats.msgs_sent.fetch_add(1, Ordering::Relaxed);
        self.stats.sent_log.lock().unwrap().push((to, payload.len() as u64));
        self.fabric.senders[to]
            .send(Message { from: self.rank, tag, payload })
            .expect("receiver endpoint dropped before communication finished");
    }

    /// Blocking receive of the message `(from, tag)`; other messages are
    /// buffered until their own matching `recv`.
    pub fn recv(&mut self, from: usize, tag: u64) -> Vec<u8> {
        if let Some(pos) =
            self.pending.iter().position(|m| m.from == from && m.tag == tag)
        {
            return self.pending.swap_remove(pos).payload;
        }
        let t0 = std::time::Instant::now();
        loop {
            let msg = self.rx.recv().expect("fabric closed while waiting for message");
            if msg.from == from && msg.tag == tag {
                self.stats
                    .recv_wait_ns
                    .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
                return msg.payload;
            }
            self.pending.push(msg);
        }
    }

    /// Typed send of a `&[T]` slice (plain-old-data only).
    pub fn send_slice<T: Pod>(&self, to: usize, tag: u64, data: &[T]) {
        self.send(to, tag, T::encode(data));
    }

    /// Typed receive into a `Vec<T>`.
    pub fn recv_slice<T: Pod>(&mut self, from: usize, tag: u64) -> Vec<T> {
        T::decode(&self.recv(from, tag))
    }
}

/// The in-process loopback adapted behind the cluster's pluggable
/// transport trait. Delegation is direct — `send`/`recv` call the
/// inherent methods above unchanged, so behavior (counters, blocking,
/// out-of-order buffering, panic-on-teardown) is bit-identical whether
/// a rank runs through `&mut Endpoint` or `&mut dyn Transport`.
impl crate::cluster::transport::Transport for Endpoint {
    fn rank(&self) -> usize {
        self.rank
    }

    fn n_ranks(&self) -> usize {
        self.n_ranks
    }

    fn send(
        &mut self,
        to: usize,
        tag: u64,
        payload: Vec<u8>,
    ) -> Result<(), crate::cluster::transport::TransportError> {
        Endpoint::send(self, to, tag, payload);
        Ok(())
    }

    fn recv(
        &mut self,
        from: usize,
        tag: u64,
    ) -> Result<Vec<u8>, crate::cluster::transport::TransportError> {
        Ok(Endpoint::recv(self, from, tag))
    }

    fn counters(&self) -> Vec<crate::cluster::transport::PeerCounters> {
        // This rank's sent traffic, bucketed by destination.
        let log = self.stats.sent_log.lock().unwrap();
        let mut out: Vec<crate::cluster::transport::PeerCounters> = (0..self.n_ranks)
            .filter(|&p| p != self.rank)
            .map(|p| crate::cluster::transport::PeerCounters {
                peer: p as u64,
                sent_bytes: 0,
                sent_msgs: 0,
                recv_bytes: 0,
                recv_msgs: 0,
            })
            .collect();
        for &(to, bytes) in log.iter() {
            if let Some(c) = out.iter_mut().find(|c| c.peer == to as u64) {
                c.sent_bytes += bytes;
                c.sent_msgs += 1;
            }
        }
        out
    }

    fn comm_nanos(&self) -> u64 {
        self.stats.recv_wait_ns.load(Ordering::Relaxed)
    }
}

/// A `Send + Sync` snapshot handle over a fabric's per-rank counters,
/// attachable to an engine as a transport metrics source (the fabric
/// itself holds channel senders, so the handle carries only the
/// `RankStats` arcs).
pub struct FabricTransportStats {
    node: u64,
    stats: Vec<Arc<RankStats>>,
}

impl Fabric {
    /// A metrics source reporting this fabric's per-rank traffic under
    /// node id `node` (one `peer=<rank>` line per rank: bytes/messages
    /// sent by that rank, and received by it per the other ranks'
    /// send logs).
    pub fn stats_source(&self, node: u64) -> Arc<FabricTransportStats> {
        Arc::new(FabricTransportStats { node, stats: self.stats.clone() })
    }
}

impl crate::mitigation::engine::TransportStatsSource for FabricTransportStats {
    fn transport_node(&self) -> u64 {
        self.node
    }

    fn transport_counters(&self) -> Vec<crate::cluster::transport::PeerCounters> {
        let n = self.stats.len();
        let mut out: Vec<crate::cluster::transport::PeerCounters> = (0..n)
            .map(|r| crate::cluster::transport::PeerCounters {
                peer: r as u64,
                sent_bytes: self.stats[r].bytes_sent.load(Ordering::Relaxed),
                sent_msgs: self.stats[r].msgs_sent.load(Ordering::Relaxed),
                recv_bytes: 0,
                recv_msgs: 0,
            })
            .collect();
        for stats in &self.stats {
            for &(to, bytes) in stats.sent_log.lock().unwrap().iter() {
                if to < n {
                    out[to].recv_bytes += bytes;
                    out[to].recv_msgs += 1;
                }
            }
        }
        out
    }
}

/// Plain-old-data element types that can cross the fabric.
pub trait Pod: Copy {
    /// Serialize a slice little-endian.
    fn encode(data: &[Self]) -> Vec<u8>;
    /// Inverse of `encode`.
    fn decode(bytes: &[u8]) -> Vec<Self>;
}

macro_rules! impl_pod {
    ($ty:ty, $size:expr) => {
        impl Pod for $ty {
            fn encode(data: &[Self]) -> Vec<u8> {
                let mut out = Vec::with_capacity(data.len() * $size);
                for v in data {
                    out.extend_from_slice(&v.to_le_bytes());
                }
                out
            }
            fn decode(bytes: &[u8]) -> Vec<Self> {
                assert_eq!(bytes.len() % $size, 0, "payload size not a multiple of element");
                bytes
                    .chunks_exact($size)
                    .map(|c| <$ty>::from_le_bytes(c.try_into().unwrap()))
                    .collect()
            }
        }
    };
}

impl_pod!(f32, 4);
impl_pod!(f64, 8);
impl_pod!(i64, 8);
impl_pod!(u64, 8);
impl_pod!(i32, 4);
impl_pod!(i8, 1);

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_pass_works() {
        let n = 4;
        let (fabric, endpoints) = Fabric::new(n);
        std::thread::scope(|s| {
            for mut ep in endpoints {
                s.spawn(move || {
                    let next = (ep.rank + 1) % ep.n_ranks;
                    let prev = (ep.rank + ep.n_ranks - 1) % ep.n_ranks;
                    ep.send_slice::<i64>(next, 7, &[ep.rank as i64]);
                    let got = ep.recv_slice::<i64>(prev, 7);
                    assert_eq!(got, vec![prev as i64]);
                });
            }
        });
        assert_eq!(fabric.total_msgs(), n as u64);
        assert_eq!(fabric.total_bytes(), n as u64 * 8);
    }

    #[test]
    fn out_of_order_tags_are_buffered() {
        let (_fabric, mut endpoints) = Fabric::new(2);
        let ep1 = endpoints.pop().unwrap();
        let mut ep0 = endpoints.pop().unwrap();
        ep1.send(0, 2, vec![2]);
        ep1.send(0, 1, vec![1]);
        // Receive in tag order 1 then 2 despite arrival order 2 then 1.
        assert_eq!(ep0.recv(1, 1), vec![1]);
        assert_eq!(ep0.recv(1, 2), vec![2]);
    }

    #[test]
    fn endpoint_behind_transport_trait_is_bit_identical() {
        use crate::cluster::transport::{Transport, TransportExt};
        let (fabric, mut endpoints) = Fabric::new(2);
        let mut ep1 = endpoints.pop().unwrap();
        let mut ep0 = endpoints.pop().unwrap();
        {
            let t1: &mut dyn Transport = &mut ep1;
            t1.send_slice::<i64>(0, 5, &[10, 20]);
            t1.send(0, 9, vec![3, 1, 4]).unwrap();
        }
        {
            let t0: &mut dyn Transport = &mut ep0;
            assert_eq!(t0.recv(1, 9).unwrap(), vec![3, 1, 4]);
            assert_eq!(t0.recv_slice::<i64>(1, 5), vec![10, 20]);
            assert_eq!(t0.rank(), 0);
            assert_eq!(t0.n_ranks(), 2);
        }
        // Counters agree between the trait view and the fabric stats.
        let c1 = Transport::counters(&ep1);
        assert_eq!(c1.len(), 1);
        assert_eq!(c1[0].peer, 0);
        assert_eq!(c1[0].sent_msgs, 2);
        assert_eq!(c1[0].sent_bytes, fabric.total_bytes());
        let source = fabric.stats_source(7);
        use crate::mitigation::engine::TransportStatsSource;
        assert_eq!(source.transport_node(), 7);
        let per_rank = source.transport_counters();
        assert_eq!(per_rank.len(), 2);
        assert_eq!(per_rank[1].sent_bytes, fabric.total_bytes());
        assert_eq!(per_rank[0].recv_msgs, 2);
        assert_eq!(per_rank[0].recv_bytes, fabric.total_bytes());
    }

    #[test]
    fn pod_roundtrip() {
        let xs = vec![-1.5f32, 0.0, 3.25];
        assert_eq!(f32::decode(&f32::encode(&xs)), xs);
        let ys = vec![-5i8, 0, 7];
        assert_eq!(i8::decode(&i8::encode(&ys)), ys);
        let zs = vec![i64::MIN, 0, i64::MAX];
        assert_eq!(i64::decode(&i64::encode(&zs)), zs);
    }

    #[test]
    fn comm_model_intra_vs_inter() {
        let m = CommModel { latency: 1e-6, bandwidth: 1e9, ranks_per_node: 4, intra_factor: 10.0 };
        let intra = m.message_time(0, 3, 1000);
        let inter = m.message_time(0, 4, 1000);
        assert!(inter > intra * 9.0);
    }
}
