//! `qai` — CLI for the quantization-aware-interpolation artifact
//! mitigation stack.
//!
//! Subcommands:
//!
//! * `compress`   — compress a raw f32 field with a pre-quantization codec
//! * `decompress` — decompress, optionally mitigating artifacts
//! * `demo`       — full synthetic round trip with quality metrics
//! * `batch`      — many independent fields through the batched
//!                  mitigation service on the shared thread pool
//! * `serve`      — stream jobs through the bounded admission queue
//!                  (priorities, backpressure, deadlines; see
//!                  docs/SERVING.md), or form a multi-node cluster
//!                  with `--listen`/`--join` (docs/SERVING.md §
//!                  Multi-node serving)
//! * `distributed`— run the MPI-analog coordinator on a synthetic field
//! * `rank-worker`— internal child process for real multi-process
//!                  distributed runs (spawned by the fig9/fig11
//!                  benches; never invoked by hand)
//! * `info`       — PJRT platform + artifact inventory
//!
//! Run `qai help` for flag details.

use anyhow::Result;
use qai::cli::{parse_dims, Args};
use qai::cluster::node::{ClusterEngine, ClusterError, ClusterServer, ClusterTransportStats};
use qai::cluster::registry::auto_node_id;
use qai::cluster::wire::RejectKind;
use qai::compressors::{cusz::CuszLike, cuszp::CuszpLike, szp::SzpLike, Compressor};
use qai::coordinator::{run_distributed, DistributedConfig, Strategy};
use qai::data::io;
use qai::data::synthetic::{generate, DatasetKind};
use qai::metrics::{bit_rate, max_rel_error, psnr, ssim};
use qai::mitigation::engine::{self, Engine, MitigationRequest};
use qai::mitigation::{Backend, Job, MitigationConfig, QualityTarget, SubmitError, TiledConfig};
use qai::quant::ErrorBound;
use qai::util::pool;
use qai::SharedGrid;
use std::io::Write;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

fn main() {
    let args = match Args::parse(std::env::args().skip(1)) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("argument error: {e:#}");
            std::process::exit(2);
        }
    };
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn run(args: &Args) -> Result<()> {
    // `qai --version` parses as a bare flag (no subcommand).
    if args.command.is_none() && args.get_bool("version").unwrap_or(false) {
        cmd_version();
        return Ok(());
    }
    match args.command.as_deref() {
        Some("compress") => cmd_compress(args),
        Some("decompress") => cmd_decompress(args),
        Some("demo") => cmd_demo(args),
        Some("batch") => cmd_batch(args),
        Some("serve") => cmd_serve(args),
        Some("distributed") => cmd_distributed(args),
        Some("rank-worker") => cmd_rank_worker(args),
        Some("info") => cmd_info(args),
        Some("version") => {
            cmd_version();
            Ok(())
        }
        Some("help") | None => {
            print_help();
            Ok(())
        }
        Some(other) => anyhow::bail!("unknown subcommand {other:?} — try `qai help`"),
    }
}

fn print_help() {
    println!(
        "qai — artifact mitigation for pre-quantization based compressors

USAGE: qai <SUBCOMMAND> [FLAGS]

SUBCOMMANDS
  compress    --input F --dims AxBxC --output F [--codec cusz|cuszp|szp]
              [--rel 1e-3 | --abs 0.5]
  decompress  --input F --output F [--codec cusz|cuszp|szp]
              [--mitigate] [--eta 0.9] [--threads N] [--backend native|pjrt]
              [--taper R]   (homogeneous-region taper radius, paper §IX ext.)
  demo        [--dataset climate|hurricane|cosmology|combustion|turbulence|miranda]
              [--dims AxBxC] [--rel 1e-2] [--codec cusz|cuszp|szp]
              [--eta 0.9] [--threads N] [--backend native|pjrt] [--seed N]
              [--taper R] [--tile AxBxC] [--halo H]
              (--tile streams mitigation tile-by-tile with O(tile)
               scratch; --halo sets the ghost-zone width, default 8)
  batch       --jobs N [--dataset ...] [--dims AxBxC] [--rel 1e-2]
              [--codec cusz|cuszp|szp] [--eta 0.9] [--threads N] [--seed N]
              (N independent fields through the engine's batch path on
               the shared persistent thread pool; --threads is the
               per-job pipeline parallelism)
  serve       --jobs N [--shards S] [--capacity C] [--tenants T]
              [--quota Q] [--quota-rate R] [--quota-burst B] [--shed]
              [--adaptive-lanes] [--interactive-every K]
              [--deadline-ms D] [--lanes L] [--metrics]
              [--quality-target psnr:N|ssim:V] [--tile AxBxC] [--halo H]
              [--dataset ...]
              [--dims AxBxC] [--rel 1e-2] [--eta 0.9] [--threads N]
              [--seed N]
              (stream N fields through the sharded engine: --shards
               admission-queue shards behind the tenant router,
               --tenants > 0 tags jobs round-robin with tenant ids
               t0..t{T-1}, --quota > 0 caps each tenant's in-flight
               jobs, --quota-rate > 0 switches tenants to token-bucket
               admission at R tokens/s (burst B, default 1; --quota
               then only seeds the burst), --shed rejects
               deadline-infeasible submissions at admission,
               --adaptive-lanes lets shard schedulers grow/shrink their
               dispatch-lane cap with load, every K-th job is
               interactive-class, --capacity bounds each shard's queue
               and exercises backpressure, --deadline-ms tags jobs with
               a completion budget (dispatched EDF within a class),
               --lanes > 0 gives each shard a private L-lane pool,
               --metrics appends the scrapeable per-shard/per-tenant
               key=value stats and latency-histogram lines,
               --quality-target psnr:60 (dB) or ssim:0.98 attaches the
               original field to every request and lets the engine
               auto-tune mitigation parameters per (tenant, shape) to
               meet the floor — one bounded search per key, then
               cache hits, --tile AxBxC makes every targetless job run
               the tiled streaming executor (O(tile) arena scratch,
               bounded by the arena_bytes_peak metrics token; --halo
               sets the ghost width, default 8); see docs/SERVING.md)
              [--listen ADDR | --join ADDR] [--node-id N]
              (cluster mode: --listen HOST:PORT or
               unix:/path/sock turns this process into a remotely
               addressable engine node that serves requests until a
               peer sends Shutdown; --join ADDR connects to a
               listening node, forms a 2-node rendezvous-hashed
               registry, and routes the generated workload per tenant
               — some jobs execute locally (zero-copy), the rest are
               framed over the socket; --node-id overrides the
               auto-derived 64-bit node id; see docs/SERVING.md §
               Multi-node serving)
  distributed [--dataset ...] [--dims AxBxC] [--rel 1e-2] [--ranks N]
              [--strategy embarrassing|exact|approximate] [--seed N]
  rank-worker --connect ADDR [--rank R]
              (internal: child process for real multi-process
               distributed runs; spawned by run_distributed_procs)
  info        (PJRT platform + artifacts present)
  version     (package version + active SIMD dispatch level; also
               `qai --version`. QAI_SIMD=scalar|sse2|avx2 forces the
               level, clamped to what the CPU supports)
"
    );
}

fn cmd_version() {
    println!(
        "qai {} simd={} (best={})",
        env!("CARGO_PKG_VERSION"),
        qai::util::simd::token(),
        qai::util::simd::best_supported().token(),
    );
}

fn codec(name: &str) -> Result<Box<dyn Compressor>> {
    match name {
        "cusz" => Ok(Box::new(CuszLike)),
        "cuszp" => Ok(Box::new(CuszpLike)),
        "szp" => Ok(Box::new(SzpLike::default())),
        other => anyhow::bail!("unknown codec {other:?} (cusz|cuszp|szp)"),
    }
}

fn dataset(name: &str) -> Result<DatasetKind> {
    Ok(match name {
        "climate" => DatasetKind::ClimateLike,
        "hurricane" => DatasetKind::HurricaneLike,
        "cosmology" => DatasetKind::CosmologyLike,
        "combustion" => DatasetKind::CombustionLike,
        "turbulence" => DatasetKind::TurbulenceLike,
        "miranda" => DatasetKind::MirandaLike,
        other => anyhow::bail!("unknown dataset {other:?}"),
    })
}

fn bound_from(args: &Args) -> Result<ErrorBound> {
    let rel = args.get("rel");
    let abs = args.get("abs");
    match (rel, abs) {
        (Some(r), None) => Ok(ErrorBound::relative(r.parse()?)),
        (None, Some(a)) => Ok(ErrorBound::absolute(a.parse()?)),
        (None, None) => Ok(ErrorBound::relative(1e-2)),
        (Some(_), Some(_)) => anyhow::bail!("--rel and --abs are mutually exclusive"),
    }
}

fn backend_from(args: &Args) -> Result<Backend> {
    match args.get_or("backend", "native").as_str() {
        "native" => Ok(Backend::Native),
        "pjrt" => Ok(Backend::Pjrt),
        other => anyhow::bail!("unknown backend {other:?} (native|pjrt)"),
    }
}

fn cmd_compress(args: &Args) -> Result<()> {
    let input = PathBuf::from(args.require("input")?);
    let output = PathBuf::from(args.require("output")?);
    let dims = parse_dims(&args.require("dims")?)?;
    let codec = codec(&args.get_or("codec", "cusz"))?;
    let bound = bound_from(args)?;
    args.finish()?;

    let grid = io::read_f32(&input, &dims)?;
    let eb = bound.resolve(&grid.data);
    let stream = codec.compress(&grid, eb)?;
    io::write_bytes(&output, &stream)?;
    println!(
        "{}: {} -> {} bytes (ratio {:.2}x, {:.3} bits/val, eps_abs={:.3e})",
        codec.name(),
        grid.len() * 4,
        stream.len(),
        (grid.len() * 4) as f64 / stream.len() as f64,
        bit_rate(stream.len(), grid.len()),
        eb.abs
    );
    Ok(())
}

fn cmd_decompress(args: &Args) -> Result<()> {
    let input = PathBuf::from(args.require("input")?);
    let output = PathBuf::from(args.require("output")?);
    let codec = codec(&args.get_or("codec", "cusz"))?;
    let do_mitigate = args.get_bool("mitigate")?;
    let cfg = MitigationConfig {
        eta: args.get_parse("eta", 0.9)?,
        threads: args.get_parse("threads", 1)?,
        backend: backend_from(args)?,
        taper_radius: args.get("taper").map(|s| s.parse()).transpose()?,
    };
    args.finish()?;

    let stream = io::read_bytes(&input)?;
    let dec = codec.decompress(&stream)?;
    let out = if do_mitigate {
        let n = dec.grid.len();
        let request = MitigationRequest::new(dec.grid, dec.quant_indices, dec.bound)
            .config(cfg)
            .with_stats(true);
        let resp = engine::execute(&request)?;
        let stats = resp.stats.expect("stats requested");
        println!(
            "mitigated in {:.3}s ({:.1} MB/s, |B1|={}, |B2|={})",
            stats.total(),
            stats.throughput_mbs(n),
            stats.n_boundary1,
            stats.n_boundary2
        );
        resp.output
    } else {
        dec.grid
    };
    io::write_f32(&output, &out)?;
    println!("wrote {output:?}");
    Ok(())
}

fn cmd_demo(args: &Args) -> Result<()> {
    let kind = dataset(&args.get_or("dataset", "miranda"))?;
    let default_dims = if kind == DatasetKind::ClimateLike { "256x256" } else { "64x64x64" };
    let dims = parse_dims(&args.get_or("dims", default_dims))?;
    let codec = codec(&args.get_or("codec", "cusz"))?;
    let bound = bound_from(args)?;
    let seed: u64 = args.get_parse("seed", 42)?;
    let cfg = MitigationConfig {
        eta: args.get_parse("eta", 0.9)?,
        threads: args.get_parse("threads", 1)?,
        backend: backend_from(args)?,
        taper_radius: args.get("taper").map(|s| s.parse()).transpose()?,
    };
    let tiled = tile_from(args)?;
    args.finish()?;

    let orig = generate(kind, &dims, seed);
    let eb = bound.resolve(&orig.data);
    let stream = codec.compress(&orig, eb)?;
    let dec = codec.decompress(&stream)?;
    // Keep a zero-copy handle on the decompressed field for the
    // before/after metrics; the request shares the same allocation.
    let dq: SharedGrid<f32> = dec.grid.into();
    let mut request = MitigationRequest::new(dq.clone(), dec.quant_indices, dec.bound)
        .config(cfg)
        .with_stats(true);
    if let Some(t) = tiled {
        request = request.tiled(t);
        println!(
            "tiled: tile {:?}, halo {}, scratch budget {} B x {} lane(s)",
            t.tile.user_dims(),
            t.halo,
            t.scratch_budget_bytes(&dq.shape, 1),
            cfg.threads.max(1)
        );
    }
    let resp = engine::execute(&request)?;
    let (fixed, stats) = (resp.output, resp.stats.expect("stats requested"));

    println!(
        "dataset={} dims={dims:?} codec={} eps_abs={:.3e}",
        kind.paper_name(),
        codec.name(),
        eb.abs
    );
    println!(
        "compressed: {} bytes (ratio {:.2}x, {:.3} bits/val)",
        stream.len(),
        (orig.len() * 4) as f64 / stream.len() as f64,
        bit_rate(stream.len(), orig.len())
    );
    let (s0, s1) = (ssim(&orig, &dq, 7, 2), ssim(&orig, &fixed, 7, 2));
    let (p0, p1) = (psnr(&orig.data, &dq.data), psnr(&orig.data, &fixed.data));
    println!("SSIM: {s0:.4} -> {s1:.4} ({:+.2}%)", (s1 - s0) / s0.abs().max(1e-12) * 100.0);
    println!("PSNR: {p0:.2} dB -> {p1:.2} dB");
    println!(
        "max rel err: {:.5} -> {:.5} (relaxed bound {:.5})",
        max_rel_error(&orig.data, &dq.data),
        max_rel_error(&orig.data, &fixed.data),
        (1.0 + cfg.eta) * eb.rel.unwrap_or(eb.abs / orig.value_range() as f64)
    );
    println!(
        "mitigation: {:.3}s total ({:.1} MB/s) — A {:.3}s, B {:.3}s, C {:.3}s, D {:.3}s, E {:.3}s",
        stats.total(),
        stats.throughput_mbs(orig.len()),
        stats.t_boundary,
        stats.t_edt1,
        stats.t_sign,
        stats.t_edt2,
        stats.t_compensate
    );
    Ok(())
}

fn cmd_batch(args: &Args) -> Result<()> {
    let jobs_n: usize = args.get_parse("jobs", 8)?;
    anyhow::ensure!(jobs_n > 0, "--jobs must be positive");
    let kind = dataset(&args.get_or("dataset", "miranda"))?;
    let default_dims = if kind == DatasetKind::ClimateLike { "128x128" } else { "48x48x48" };
    let dims = parse_dims(&args.get_or("dims", default_dims))?;
    let codec = codec(&args.get_or("codec", "cusz"))?;
    let bound = bound_from(args)?;
    let seed: u64 = args.get_parse("seed", 42)?;
    let cfg = MitigationConfig {
        eta: args.get_parse("eta", 0.9)?,
        threads: args.get_parse("threads", 1)?,
        ..Default::default()
    };
    args.finish()?;

    // Full ingest path per job: synthesize → compress → decompress.
    let mut originals = Vec::with_capacity(jobs_n);
    let mut dqs: Vec<SharedGrid<f32>> = Vec::with_capacity(jobs_n);
    let mut requests = Vec::with_capacity(jobs_n);
    let mut total_stream = 0usize;
    for i in 0..jobs_n {
        let orig = generate(kind, &dims, seed + i as u64);
        let eb = bound.resolve(&orig.data);
        let stream = codec.compress(&orig, eb)?;
        total_stream += stream.len();
        let dec = codec.decompress(&stream)?;
        let dq: SharedGrid<f32> = dec.grid.into();
        dqs.push(dq.clone());
        requests.push(
            MitigationRequest::new(dq, dec.quant_indices, dec.bound).config(cfg),
        );
        originals.push(orig);
    }

    let engine = Engine::builder().build();
    let t0 = std::time::Instant::now();
    let results = engine.run_batch(requests);
    let wall = t0.elapsed().as_secs_f64();

    let n_elems: usize = dqs.iter().map(|g| g.len()).sum();
    let mut failures = 0usize;
    let mut psnr_before = 0.0f64;
    let mut psnr_after = 0.0f64;
    for (i, result) in results.iter().enumerate() {
        match result {
            Ok(resp) => {
                psnr_before += psnr(&originals[i].data, &dqs[i].data);
                psnr_after += psnr(&originals[i].data, &resp.output.data);
            }
            Err(e) => {
                failures += 1;
                eprintln!("job {i} failed: {e:#}");
            }
        }
    }
    let ok = jobs_n - failures;
    println!(
        "batch: {jobs_n} x {} {:?} jobs via {} (engine pool lanes = {}, per-job threads = {})",
        kind.paper_name(),
        dims,
        codec.name(),
        pool::parallelism(),
        cfg.threads
    );
    println!(
        "ingest: {total_stream} compressed bytes total ({:.3} bits/val)",
        bit_rate(total_stream, n_elems)
    );
    println!(
        "mitigated {ok}/{jobs_n} jobs in {wall:.3}s — {:.1} fields/s, {:.1} MB/s aggregate",
        ok as f64 / wall.max(1e-12),
        (n_elems * 4) as f64 / 1e6 / wall.max(1e-12)
    );
    if ok > 0 {
        println!(
            "mean PSNR: {:.2} dB -> {:.2} dB",
            psnr_before / ok as f64,
            psnr_after / ok as f64
        );
    }
    anyhow::ensure!(failures == 0, "{failures} job(s) failed");
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let jobs_n: usize = args.get_parse("jobs", 32)?;
    anyhow::ensure!(jobs_n > 0, "--jobs must be positive");
    let kind = dataset(&args.get_or("dataset", "miranda"))?;
    let default_dims = if kind == DatasetKind::ClimateLike { "128x128" } else { "32x32x32" };
    let dims = parse_dims(&args.get_or("dims", default_dims))?;
    let bound = bound_from(args)?;
    let seed: u64 = args.get_parse("seed", 42)?;
    let shards: usize = args.get_parse("shards", 1)?;
    anyhow::ensure!(shards > 0, "--shards must be positive");
    let capacity: usize = args.get_parse("capacity", 16)?;
    let tenants_n: usize = args.get_parse("tenants", 0)?;
    let quota: u64 = args.get_parse("quota", 0)?;
    let quota_rate: f64 = args.get_parse("quota-rate", 0.0)?;
    let quota_burst: u64 = args.get_parse("quota-burst", 0)?;
    let shed = args.get_bool("shed")?;
    let adaptive = args.get_bool("adaptive-lanes")?;
    let interactive_every: usize = args.get_parse("interactive-every", 4)?;
    let deadline_ms: u64 = args.get_parse("deadline-ms", 0)?;
    let lanes: usize = args.get_parse("lanes", 0)?;
    let metrics = args.get_bool("metrics")?;
    let quality_target = args.get("quality-target").map(|s| parse_quality_target(&s)).transpose()?;
    let tiled = tile_from(args)?;
    let cfg = MitigationConfig {
        eta: args.get_parse("eta", 0.9)?,
        threads: args.get_parse("threads", 1)?,
        ..Default::default()
    };
    let listen = args.get("listen");
    let join = args.get("join");
    let node_id_flag: u64 = args.get_parse("node-id", 0)?;
    anyhow::ensure!(
        listen.is_none() || join.is_none(),
        "--listen and --join are mutually exclusive"
    );
    args.finish()?;
    if tiled.is_some() && quality_target.is_some() {
        eprintln!(
            "note: --tile is ignored for quality-targeted jobs (the auto-tuner \
             searches on the whole-field path)"
        );
    }

    let mut builder = Engine::builder().shards(shards).capacity(capacity);
    if let Some(t) = tiled {
        // Engine-wide default: every targetless request streams tiled.
        builder = builder.tiled(t);
    }
    if lanes > 0 {
        builder = builder.lanes_per_shard(lanes);
    }
    if quota > 0 {
        builder = builder.default_quota(quota);
    }
    if quota_rate > 0.0 {
        builder = builder.default_quota_rate(quota_rate);
        if quota_burst > 0 {
            builder = builder.default_quota_burst(quota_burst);
        }
    }
    if shed {
        builder = builder.shed(true);
    }
    if adaptive {
        builder = builder.adaptive_lanes(true);
    }
    let engine = Arc::new(builder.build());

    if let Some(addr) = listen {
        // Listener mode: this process *is* an engine node. It serves
        // remote mitigation requests until a peer sends Shutdown; the
        // workload-generation flags below only shape the engine it
        // hosts, not a local job stream.
        let node_id = if node_id_flag != 0 {
            node_id_flag
        } else {
            auto_node_id(&format!("listen:{addr}"))
        };
        let stats = ClusterTransportStats::new(node_id);
        engine.attach_transport(stats.clone());
        let mut server = ClusterServer::start(Arc::clone(&engine), node_id, &addr, stats)?;
        // The exact "listening on" line (with the kernel-chosen port
        // for HOST:0) is what joiners and the multi-process tests
        // parse — keep it stable and flushed.
        println!("cluster node {node_id} listening on {}", server.addr());
        std::io::stdout().flush()?;
        server.wait();
        if metrics {
            println!("{}", engine.metrics_text());
        }
        return Ok(());
    }

    // Quantize-only ingest — `qai batch` exercises the codec path; this
    // subcommand is about the serving engine itself.
    let mut inputs = Vec::with_capacity(jobs_n);
    for i in 0..jobs_n {
        let orig = generate(kind, &dims, seed + i as u64);
        let eb = bound.resolve(&orig.data);
        let (q, dq) = qai::quant::quantize_grid(&orig, eb);
        let mut job = Job::with_config(dq, q, eb, cfg);
        if quality_target.is_some() {
            // Quality-targeted serving scores (and tunes) against the
            // original field; the Arc-backed grid makes this a pointer
            // bump per request, not a copy.
            job.reference = Some(orig.into());
            job.target = quality_target;
        }
        inputs.push(job);
    }
    let n_elems: usize = inputs.iter().map(|j| j.dq.len()).sum();

    // A rejected submission hands the Job back; this rebuilds the full
    // request (class, deadline, tenant) for slot `i` around it.
    let request_for = |job: Job, i: usize| {
        let mut req = MitigationRequest::from_job(job);
        if interactive_every > 0 && i % interactive_every == 0 {
            req = req.interactive();
        }
        if deadline_ms > 0 {
            req = req.deadline(Duration::from_millis(deadline_ms));
        }
        if tenants_n > 0 {
            req = req.tenant(format!("t{}", i % tenants_n));
        }
        req
    };

    if let Some(addr) = join {
        // Joiner mode: form a 2-node registry with the listener and
        // route the whole workload through the cluster engine. The
        // tenant id decides the node (rendezvous hashing), so with
        // --tenants > 1 part of the stream executes locally
        // (zero-copy) and part is framed over the socket.
        let node_id = if node_id_flag != 0 {
            node_id_flag
        } else {
            auto_node_id(&format!("join:{addr}:{}", std::process::id()))
        };
        let cluster = ClusterEngine::new(node_id, Arc::clone(&engine));
        let peer = cluster.join(&addr)?;
        println!("cluster node {node_id} joined peer {peer} at {addr}");
        let t0 = std::time::Instant::now();
        let mut tickets = Vec::with_capacity(jobs_n);
        let mut shed_jobs = 0usize;
        let mut rejected = 0usize;
        for (i, job) in inputs.into_iter().enumerate() {
            match cluster.submit(request_for(job, i)) {
                Ok(t) => tickets.push((i, t)),
                Err(ClusterError::Local(SubmitError::DeadlineInfeasible(_)))
                | Err(ClusterError::Rejected { kind: RejectKind::DeadlineInfeasible, .. }) => {
                    shed_jobs += 1;
                }
                Err(e) => {
                    rejected += 1;
                    eprintln!("job {i} rejected: {e}");
                }
            }
        }
        let mut local = 0usize;
        let mut remote = 0usize;
        let mut failures = 0usize;
        let mut max_wait = Duration::ZERO;
        for (i, ticket) in tickets {
            if ticket.is_remote() {
                remote += 1;
            } else {
                local += 1;
            }
            match ticket.wait() {
                Ok(resp) => max_wait = max_wait.max(resp.queue_wait),
                Err(ClusterError::Rejected {
                    kind: RejectKind::DeadlineInfeasible, ..
                }) => shed_jobs += 1,
                Err(e) => {
                    failures += 1;
                    eprintln!("job {i} failed: {e}");
                }
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        println!(
            "cluster: {local} local / {remote} remote of {jobs_n} jobs across nodes {:?}; {shed_jobs} shed",
            cluster.nodes()
        );
        println!(
            "throughput: {:.1} fields/s, {:.1} MB/s aggregate ({wall:.3}s wall); max queue wait {:.1} ms",
            (local + remote) as f64 / wall.max(1e-12),
            (n_elems * 4) as f64 / 1e6 / wall.max(1e-12),
            max_wait.as_secs_f64() * 1e3
        );
        if metrics {
            println!("{}", engine.metrics_text());
        }
        anyhow::ensure!(
            failures == 0 && rejected == 0,
            "{failures} job(s) failed, {rejected} rejected"
        );
        return Ok(());
    }

    // Stream the jobs in: try_submit first; on backpressure fall back
    // to a blocking submit, and on a quota rejection back off briefly
    // and retry (counting both). A deadline-infeasible shed is final:
    // the admission layer has proven the deadline unmeetable, so
    // retrying the same request is pointless — drop the job.
    let t0 = std::time::Instant::now();
    let mut tickets = Vec::with_capacity(jobs_n);
    let mut backpressure_hits = 0usize;
    let mut quota_hits = 0usize;
    let mut shed_jobs = 0usize;
    for i in 0..jobs_n {
        let mut request = request_for(inputs[i].clone(), i);
        let ticket = loop {
            match engine.try_submit(request) {
                Ok(t) => break Some(t),
                Err(SubmitError::DeadlineInfeasible(_)) => {
                    shed_jobs += 1;
                    break None;
                }
                Err(SubmitError::QueueFull(job)) => {
                    backpressure_hits += 1;
                    match engine.submit(request_for(job, i)) {
                        Ok(t) => break Some(t),
                        Err(SubmitError::DeadlineInfeasible(_)) => {
                            shed_jobs += 1;
                            break None;
                        }
                        Err(SubmitError::QuotaExceeded(job)) => {
                            quota_hits += 1;
                            request = request_for(job, i);
                            std::thread::sleep(Duration::from_millis(1));
                        }
                        Err(e) => anyhow::bail!("blocking submit failed: {e}"),
                    }
                }
                Err(SubmitError::QuotaExceeded(job)) => {
                    quota_hits += 1;
                    request = request_for(job, i);
                    std::thread::sleep(Duration::from_millis(1));
                }
                Err(e) => anyhow::bail!("submission failed: {e}"),
            }
        };
        if let Some(ticket) = ticket {
            tickets.push((i, ticket));
        }
    }
    drop(inputs);

    let mut failures = 0usize;
    let mut missed = 0usize;
    let mut max_wait = Duration::ZERO;
    let mut quality_sum = 0.0f64;
    let mut quality_min = f64::INFINITY;
    let mut quality_n = 0usize;
    for (i, ticket) in tickets {
        // The trace id follows the job across shard, queue, and lane —
        // it is what the metrics lines' `last_trace=` token refers to.
        let trace = ticket.trace_id();
        match ticket.wait() {
            Ok(resp) => {
                max_wait = max_wait.max(resp.queue_wait);
                if resp.deadline_missed {
                    missed += 1;
                }
                if let Some(q) = resp.quality {
                    quality_sum += q;
                    quality_min = quality_min.min(q);
                    quality_n += 1;
                }
            }
            Err(e) => {
                failures += 1;
                eprintln!("job {i} (trace {trace}) failed: {e:#}");
            }
        }
    }
    let wall = t0.elapsed().as_secs_f64();

    let stats = engine.stats();
    let st = stats.aggregate();
    println!(
        "serve: {jobs_n} x {} {:?} jobs, {shards} shard(s), capacity {capacity}/shard, pool lanes = {}",
        kind.paper_name(),
        dims,
        if lanes > 0 { lanes } else { pool::parallelism() }
    );
    if tenants_n > 0 {
        println!(
            "tenants: {tenants_n} round-robin, quota {} in-flight each, {} quota rejections (retried {quota_hits})",
            if quota > 0 { quota.to_string() } else { "unlimited".to_string() },
            stats.quota_rejections()
        );
    }
    println!(
        "admitted {} (rejected-then-blocked {backpressure_hits}), completed {}, failed {}",
        st.submitted, st.completed, st.failed
    );
    println!(
        "priorities: interactive {} / bulk {}; max shard queue depth {}; max queue wait {:.1} ms",
        st.interactive_done,
        st.bulk_done,
        st.max_queue_depth,
        max_wait.as_secs_f64() * 1e3
    );
    if deadline_ms > 0 {
        println!(
            "deadlines: {} set, {} missed ({missed} observed on tickets; EDF within class)",
            st.deadlines_set, st.deadlines_missed
        );
    }
    if shed {
        println!(
            "shed: {shed_jobs} infeasible-deadline submissions rejected at admission \
             ({} counted across shards)",
            st.shed_infeasible
        );
    }
    let done = st.completed.max(1) as f64;
    println!(
        "throughput: {:.1} fields/s, {:.1} MB/s aggregate ({:.3}s wall); mean queue wait {:.1} ms, mean exec {:.1} ms",
        st.completed as f64 / wall.max(1e-12),
        (n_elems * 4) as f64 / 1e6 / wall.max(1e-12),
        wall,
        st.total_queue_wait_s * 1e3 / done,
        st.total_exec_s * 1e3 / done
    );
    if let Some(target) = quality_target {
        println!(
            "quality: target {target:?}, min {quality_min:.4}, mean {:.4} over {quality_n} scored jobs; \
             searches {} / cache hits {} (evicted {})",
            quality_sum / quality_n.max(1) as f64,
            st.quality_misses,
            st.quality_hits,
            st.quality_evicted
        );
    }
    let ast = engine.arena_stats();
    println!(
        "arena: {:.0}% buffer reuse ({} hits / {} misses), {} B pooled, {} B peak",
        ast.reuse_fraction() * 100.0,
        ast.hits,
        ast.misses,
        ast.bytes_pooled,
        ast.bytes_peak
    );
    if let (Some(t), None) = (tiled, quality_target) {
        let field = qai::data::grid::Shape::new(&dims);
        let budget = t.scratch_budget_bytes(&field, if lanes > 0 { lanes } else { pool::parallelism() });
        println!(
            "tiled: tile {:?}, halo {} — peak scratch {} B of {} B budget",
            t.tile.user_dims(),
            t.halo,
            ast.bytes_peak,
            budget
        );
    }
    if metrics {
        println!("{}", engine.metrics_text());
    }
    anyhow::ensure!(failures == 0, "{failures} job(s) failed");
    Ok(())
}

/// Parse `--tile AxBxC` (plus optional `--halo H`) into a
/// [`TiledConfig`] for the streaming tiled executor; `None` when the
/// flag is absent.
fn tile_from(args: &Args) -> Result<Option<TiledConfig>> {
    let Some(spec) = args.get("tile") else {
        anyhow::ensure!(args.get("halo").is_none(), "--halo requires --tile");
        return Ok(None);
    };
    let dims = parse_dims(&spec)?;
    let mut tiled = TiledConfig::new(&dims);
    if let Some(h) = args.get("halo") {
        tiled = tiled.with_halo(h.parse()?);
    }
    Ok(Some(tiled))
}

/// Parse a `--quality-target` spec: `psnr:<dB>` or `ssim:<value>`.
fn parse_quality_target(spec: &str) -> Result<QualityTarget> {
    let (metric, value) = spec.split_once(':').ok_or_else(|| {
        anyhow::anyhow!("--quality-target expects metric:value, e.g. psnr:60 or ssim:0.98")
    })?;
    let value: f64 = value.parse()?;
    match metric {
        "psnr" => Ok(QualityTarget::Psnr(value)),
        "ssim" => Ok(QualityTarget::Ssim(value)),
        other => anyhow::bail!("unknown quality metric {other:?} (psnr|ssim)"),
    }
}

fn cmd_distributed(args: &Args) -> Result<()> {
    let kind = dataset(&args.get_or("dataset", "turbulence"))?;
    let dims = parse_dims(&args.get_or("dims", "96x96x96"))?;
    let bound = bound_from(args)?;
    let seed: u64 = args.get_parse("seed", 42)?;
    let cfg = DistributedConfig {
        ranks: args.get_parse("ranks", 8)?,
        strategy: Strategy::parse(&args.get_or("strategy", "approximate"))?,
        eta: args.get_parse("eta", 0.9)?,
        ..Default::default()
    };
    args.finish()?;

    let orig = generate(kind, &dims, seed);
    let eb = bound.resolve(&orig.data);
    let (qg, dqg) = qai::quant::quantize_grid(&orig, eb);

    let (out, rep) = run_distributed(&dqg, &qg, eb, &cfg)?;
    println!("strategy={} ranks={}", cfg.strategy.name(), rep.ranks);
    println!(
        "SSIM: {:.4} -> {:.4}   PSNR: {:.2} -> {:.2} dB",
        ssim(&orig, &dqg, 7, 2),
        ssim(&orig, &out, 7, 2),
        psnr(&orig.data, &dqg.data),
        psnr(&orig.data, &out.data)
    );
    println!(
        "modeled makespan {:.4}s ({:.1} MB/s), comm {:.2}% of slowest rank, {} bytes on fabric, wall {:.3}s",
        rep.modeled_makespan(),
        rep.modeled_throughput_mbs(orig.len()),
        rep.comm_fraction() * 100.0,
        rep.total_bytes(),
        rep.wall_s
    );
    Ok(())
}

/// Internal: one rank of a real multi-process distributed run.
///
/// Spawned by [`qai::cluster::procs::run_distributed_procs`] — connects
/// back to the driver's control socket, forms the rank mesh over
/// localhost, runs its block through `mitigate_rank`, and ships the
/// result (plus measured transport counters) back. Never useful to
/// invoke by hand.
fn cmd_rank_worker(args: &Args) -> Result<()> {
    let connect = args.require("connect")?;
    let rank: usize = args.get_parse("rank", 0)?;
    args.finish()?;
    qai::cluster::procs::rank_worker(&connect, rank)
}

fn cmd_info(args: &Args) -> Result<()> {
    args.finish()?;
    let dir = std::env::var("QAI_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    println!("artifacts dir: {dir}");
    match std::fs::read_dir(&dir) {
        Ok(entries) => {
            for e in entries.flatten() {
                let name = e.file_name();
                let name = name.to_string_lossy();
                if name.ends_with(".hlo.txt") || name == "manifest.txt" {
                    println!("  {name}");
                }
            }
        }
        Err(_) => println!("  (missing — run `make artifacts`)"),
    }
    match qai::runtime::engine::global() {
        Ok(engine) => println!("PJRT platform: {}", engine.platform()),
        Err(e) => println!("PJRT unavailable: {e:#}"),
    }
    Ok(())
}
