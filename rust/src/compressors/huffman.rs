//! Canonical Huffman codec over u32 symbols — the entropy stage of the
//! cuSZ-like pipeline (cuSZ couples Lorenzo-predicted quantization codes
//! with a Huffman coder [5], [51]).
//!
//! The code is *canonical*: only the per-symbol code lengths are stored
//! in the stream; both sides rebuild identical codebooks from lengths.
//! Decoding uses the canonical first-code/offset method (O(1) table
//! walk per bit-length group), which is plenty fast for the bench
//! workloads while staying simple enough to verify.

use crate::compressors::bitio::{bytes, BitReader, BitWriter};
use anyhow::{Context, Result};
use std::collections::HashMap;

/// Maximum code length we allow. 32 is ample for the index-delta
/// alphabets seen here; the builder enforces it by frequency flooring.
const MAX_LEN: u32 = 32;

/// Build canonical code lengths for `freqs` (symbol → count, counts > 0).
fn code_lengths(freqs: &HashMap<u32, u64>) -> HashMap<u32, u32> {
    assert!(!freqs.is_empty());
    if freqs.len() == 1 {
        // Single-symbol alphabet: 1-bit code by convention.
        return freqs.keys().map(|&s| (s, 1)).collect();
    }
    // Standard heap-free Huffman on sorted leaf weights (two-queue).
    let mut leaves: Vec<(u64, u32)> = freqs.iter().map(|(&s, &f)| (f, s)).collect();
    leaves.sort_unstable();
    // Tree nodes: (weight, id); children tracked for depth computation.
    #[derive(Clone)]
    struct Node {
        weight: u64,
        kids: Option<(usize, usize)>,
        symbol: Option<u32>,
    }
    let mut nodes: Vec<Node> = leaves
        .iter()
        .map(|&(w, s)| Node { weight: w, kids: None, symbol: Some(s) })
        .collect();
    let mut q1: std::collections::VecDeque<usize> = (0..nodes.len()).collect();
    let mut q2: std::collections::VecDeque<usize> = std::collections::VecDeque::new();
    let pop_min = |q1: &mut std::collections::VecDeque<usize>,
                   q2: &mut std::collections::VecDeque<usize>,
                   nodes: &Vec<Node>| {
        match (q1.front(), q2.front()) {
            (Some(&a), Some(&b)) => {
                if nodes[a].weight <= nodes[b].weight {
                    q1.pop_front().unwrap()
                } else {
                    q2.pop_front().unwrap()
                }
            }
            (Some(_), None) => q1.pop_front().unwrap(),
            (None, Some(_)) => q2.pop_front().unwrap(),
            (None, None) => unreachable!(),
        }
    };
    while q1.len() + q2.len() > 1 {
        let a = pop_min(&mut q1, &mut q2, &nodes);
        let b = pop_min(&mut q1, &mut q2, &nodes);
        let w = nodes[a].weight + nodes[b].weight;
        nodes.push(Node { weight: w, kids: Some((a, b)), symbol: None });
        q2.push_back(nodes.len() - 1);
    }
    let root = pop_min(&mut q1, &mut q2, &nodes);
    // BFS depths.
    let mut lens = HashMap::new();
    let mut stack = vec![(root, 0u32)];
    while let Some((id, depth)) = stack.pop() {
        let node = nodes[id].clone();
        match node.kids {
            Some((a, b)) => {
                stack.push((a, depth + 1));
                stack.push((b, depth + 1));
            }
            None => {
                lens.insert(node.symbol.unwrap(), depth.max(1).min(MAX_LEN));
            }
        }
    }
    lens
}

/// Canonical codes from lengths: symbols sorted by (length, symbol).
fn canonical_codes(lens: &HashMap<u32, u32>) -> Vec<(u32, u32, u64)> {
    // (symbol, len, code)
    let mut order: Vec<(u32, u32)> = lens.iter().map(|(&s, &l)| (s, l)).collect();
    order.sort_unstable_by_key(|&(s, l)| (l, s));
    let mut out = Vec::with_capacity(order.len());
    let mut code = 0u64;
    let mut prev_len = 0u32;
    for (s, l) in order {
        code <<= l - prev_len;
        out.push((s, l, code));
        code += 1;
        prev_len = l;
    }
    out
}

/// Encode `symbols` into a self-describing byte stream.
pub fn encode(symbols: &[u32]) -> Vec<u8> {
    let mut out = Vec::new();
    bytes::put_u64(&mut out, symbols.len() as u64);
    if symbols.is_empty() {
        return out;
    }
    let mut freqs = HashMap::new();
    for &s in symbols {
        *freqs.entry(s).or_insert(0u64) += 1;
    }
    let lens = code_lengths(&freqs);
    let codes = canonical_codes(&lens);

    // Header: alphabet size, then (symbol, len) pairs.
    bytes::put_u32(&mut out, codes.len() as u32);
    for &(s, l, _) in &codes {
        bytes::put_u32(&mut out, s);
        out.push(l as u8);
    }

    let table: HashMap<u32, (u32, u64)> =
        codes.iter().map(|&(s, l, c)| (s, (l, c))).collect();
    let mut w = BitWriter::new();
    for &s in symbols {
        let &(l, c) = table.get(&s).unwrap();
        w.write_bits(c, l);
    }
    let payload = w.into_bytes();
    bytes::put_u64(&mut out, payload.len() as u64);
    out.extend_from_slice(&payload);
    out
}

/// Number of symbols a stream produced by [`encode`] decodes to, read
/// from the stream header without touching the payload. Lets a caller
/// lease an exactly-sized output buffer before [`decode_into`].
pub fn decoded_len(buf: &[u8]) -> Result<usize> {
    let mut off = 0usize;
    Ok(bytes::get_u64(buf, &mut off)? as usize)
}

/// Decode a stream produced by [`encode`] into a freshly-allocated
/// vector. Allocation-sensitive callers (the SZ3 decoder's warm path)
/// use [`decoded_len`] + [`decode_into`] with an arena-leased buffer
/// instead.
pub fn decode(buf: &[u8]) -> Result<Vec<u32>> {
    let mut out = vec![0u32; decoded_len(buf)?];
    decode_into(buf, &mut out)?;
    Ok(out)
}

/// First-level decode-table width cap: codes up to this many bits
/// resolve in one table lookup (one peek + one skip instead of one
/// branchy loop iteration per bit); longer codes — rare by
/// construction, Huffman assigns them to rare symbols — fall back to
/// the bit-serial reference walk.
const TABLE_BITS: u32 = 10;

/// Decode a stream produced by [`encode`] into a caller-provided
/// buffer of exactly [`decoded_len`] elements. Every element of `out`
/// is overwritten on success; on error its contents are unspecified.
///
/// Uses the flat table-driven fast path unless the process runs forced
/// scalar (`QAI_SIMD=scalar`), in which case the bit-serial reference
/// decoder runs — the two are output-identical on every stream (same
/// canonical code, same bit consumption), pinned by tests here and in
/// `rust/tests/simd.rs`.
pub fn decode_into(buf: &[u8], out: &mut [u32]) -> Result<()> {
    let fast = crate::util::simd::level() != crate::util::simd::SimdLevel::Scalar;
    decode_into_with(buf, out, fast)
}

/// [`decode_into`] with the table fast path forced on (`true`) or off
/// (`false`, the bit-serial reference) — the parity hook for tests and
/// the microbench.
pub fn decode_into_with(buf: &[u8], out: &mut [u32], fast: bool) -> Result<()> {
    let mut off = 0usize;
    let n = bytes::get_u64(buf, &mut off)? as usize;
    anyhow::ensure!(
        out.len() == n,
        "output buffer holds {} elements, stream decodes to {n}",
        out.len()
    );
    if n == 0 {
        return Ok(());
    }
    let alpha = bytes::get_u32(buf, &mut off)? as usize;
    anyhow::ensure!(alpha > 0, "empty alphabet for nonempty stream");
    let mut lens = Vec::with_capacity(alpha);
    for _ in 0..alpha {
        let s = bytes::get_u32(buf, &mut off)?;
        anyhow::ensure!(off < buf.len(), "stream truncated in codebook");
        let l = buf[off] as u32;
        off += 1;
        anyhow::ensure!((1..=MAX_LEN).contains(&l), "invalid code length {l}");
        lens.push((s, l));
    }
    let lens_map: HashMap<u32, u32> = lens.iter().copied().collect();
    let codes = canonical_codes(&lens_map);

    // Group by length for canonical decoding: first_code[len], symbols in
    // canonical order per length.
    let max_len = codes.iter().map(|&(_, l, _)| l).max().unwrap();
    let mut first_code = vec![0u64; (max_len + 2) as usize];
    let mut first_index = vec![0usize; (max_len + 2) as usize];
    let mut count = vec![0usize; (max_len + 1) as usize];
    for &(_, l, _) in &codes {
        count[l as usize] += 1;
    }
    {
        let mut code = 0u64;
        let mut index = 0usize;
        for l in 1..=max_len {
            first_code[l as usize] = code;
            first_index[l as usize] = index;
            code = (code + count[l as usize] as u64) << 1;
            index += count[l as usize];
        }
    }
    let symbols_in_order: Vec<u32> = codes.iter().map(|&(s, _, _)| s).collect();

    // Flat first-level table: index = next `tb` bits of the stream,
    // entry = `(len << 24) | symbol_index` for every code of length ≤
    // tb (each code fills all 2^(tb−len) slots sharing its prefix),
    // `u32::MAX` = miss (code longer than tb). Prefix-freeness makes
    // the top `len` bits of any hit real stream bits, so the lookup
    // consumes exactly what the bit-serial walk would.
    // A corrupt codebook can yield out-of-range canonical codes
    // (c ≥ 2^len); skip the table then — the bit-serial walk below
    // reports such streams as errors instead of indexing out of range.
    let valid = codes.iter().all(|&(_, l, c)| c >> l == 0);
    let table: Option<(u32, Vec<u32>)> = if fast && valid && codes.len() < (1 << 24) {
        let tb = max_len.min(TABLE_BITS);
        let mut t = vec![u32::MAX; 1usize << tb];
        for (idx, &(_, l, c)) in codes.iter().enumerate() {
            if l <= tb {
                let base = (c << (tb - l)) as usize;
                let span = 1usize << (tb - l);
                let entry = (l << 24) | idx as u32;
                for e in &mut t[base..base + span] {
                    *e = entry;
                }
            }
        }
        Some((tb, t))
    } else {
        None
    };

    let payload_len = bytes::get_u64(buf, &mut off)? as usize;
    anyhow::ensure!(off + payload_len <= buf.len(), "stream truncated in payload");
    let mut r = BitReader::new(&buf[off..off + payload_len]);
    for slot in out.iter_mut() {
        if let Some((tb, t)) = &table {
            let entry = t[r.peek_bits_lenient(*tb) as usize];
            if entry != u32::MAX {
                let l = entry >> 24;
                // Near the tail the peek is zero-padded; only take the
                // hit when all `l` code bits are real. Otherwise the
                // reference walk below reports exhaustion exactly as
                // the scalar decoder would.
                if (l as usize) <= r.bits_remaining() {
                    r.skip_bits(l);
                    *slot = symbols_in_order[(entry & 0x00FF_FFFF) as usize];
                    continue;
                }
            }
        }
        // Bit-serial reference walk (the scalar twin): also handles
        // codes longer than the table and the stream tail.
        let mut code = 0u64;
        let mut l = 0u32;
        loop {
            let bit = r.read_bit().context("huffman payload exhausted")?;
            code = (code << 1) | bit as u64;
            l += 1;
            anyhow::ensure!(l <= max_len, "code length overflow — corrupt stream");
            if count[l as usize] > 0 {
                let fc = first_code[l as usize];
                if code >= fc && code - fc < count[l as usize] as u64 {
                    let idx = first_index[l as usize] + (code - fc) as usize;
                    *slot = symbols_in_order[idx];
                    break;
                }
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::prop_check;

    #[test]
    fn empty_roundtrip() {
        let enc = encode(&[]);
        assert_eq!(decode(&enc).unwrap(), Vec::<u32>::new());
    }

    #[test]
    fn single_symbol_roundtrip() {
        let data = vec![7u32; 100];
        let enc = encode(&data);
        assert_eq!(decode(&enc).unwrap(), data);
        // ~1 bit per symbol + header
        assert!(enc.len() < 40 + 100 / 8 + 8);
    }

    #[test]
    fn skewed_distribution_compresses() {
        // 90% zeros → strongly compressible
        let mut data = vec![0u32; 9000];
        data.extend((0..1000).map(|i| (i % 17) as u32 + 1));
        let enc = encode(&data);
        assert_eq!(decode(&enc).unwrap(), data);
        assert!(enc.len() < data.len(), "len={} raw(u8-equivalent)={}", enc.len(), data.len());
    }

    #[test]
    fn two_symbols() {
        let data = vec![1u32, 2, 1, 1, 2, 1];
        let enc = encode(&data);
        assert_eq!(decode(&enc).unwrap(), data);
    }

    #[test]
    fn random_roundtrip_property() {
        prop_check("huffman roundtrip", 50, |g| {
            let n = g.usize_in(1, 2000);
            let alpha = g.usize_in(1, 64) as u32;
            let data: Vec<u32> = (0..n).map(|_| g.usize_in(0, alpha as usize) as u32).collect();
            let enc = encode(&data);
            let dec = decode(&enc).unwrap();
            assert_eq!(dec, data);
        });
    }

    #[test]
    fn geometric_distribution_roundtrip() {
        // Lorenzo residuals are geometric-ish around 0 (after zigzag).
        prop_check("huffman geometric", 20, |g| {
            let n = g.usize_in(100, 3000);
            let data: Vec<u32> = (0..n)
                .map(|_| {
                    let mut v = 0u32;
                    while g.bool_with(0.5) && v < 30 {
                        v += 1;
                    }
                    v
                })
                .collect();
            let enc = encode(&data);
            assert_eq!(decode(&enc).unwrap(), data);
        });
    }

    #[test]
    fn decode_into_matches_decode_and_checks_length() {
        let data: Vec<u32> = (0..500).map(|i| (i * 7 % 23) as u32).collect();
        let enc = encode(&data);
        assert_eq!(decoded_len(&enc).unwrap(), 500);
        let mut out = vec![u32::MAX; 500];
        decode_into(&enc, &mut out).unwrap();
        assert_eq!(out, data);
        let mut short = vec![0u32; 499];
        assert!(decode_into(&enc, &mut short).is_err(), "length mismatch must error");
        let mut empty_out: [u32; 0] = [];
        let empty = encode(&[]);
        assert_eq!(decoded_len(&empty).unwrap(), 0);
        decode_into(&empty, &mut empty_out).unwrap();
    }

    #[test]
    fn table_fast_path_matches_bit_serial_reference() {
        prop_check("huffman table parity", 40, |g| {
            let n = g.usize_in(1, 1500);
            // Geometric-ish alphabet: mixes short table-hit codes with
            // long table-miss codes in one stream.
            let data: Vec<u32> = (0..n)
                .map(|_| {
                    let mut v = 0u32;
                    while g.bool_with(0.6) && v < 200 {
                        v += 1;
                    }
                    v
                })
                .collect();
            let enc = encode(&data);
            let mut fast = vec![0u32; n];
            let mut slow = vec![u32::MAX; n];
            decode_into_with(&enc, &mut fast, true).unwrap();
            decode_into_with(&enc, &mut slow, false).unwrap();
            assert_eq!(fast, slow);
            assert_eq!(fast, data);
        });
    }

    #[test]
    fn corrupt_stream_errors_not_panics() {
        let data = vec![1u32, 2, 3, 4, 5, 1, 2, 3];
        let mut enc = encode(&data);
        // Truncate payload.
        enc.truncate(enc.len() - 1);
        assert!(decode(&enc).is_err());
        // Garbage header.
        assert!(decode(&[1, 0, 0]).is_err());
    }

    #[test]
    fn kraft_inequality_holds() {
        let mut freqs = HashMap::new();
        for (s, f) in [(0u32, 1000u64), (1, 500), (2, 250), (3, 125), (4, 60), (5, 30), (6, 2)] {
            freqs.insert(s, f);
        }
        let lens = code_lengths(&freqs);
        let kraft: f64 = lens.values().map(|&l| 2f64.powi(-(l as i32))).sum();
        assert!(kraft <= 1.0 + 1e-12, "kraft={kraft}");
        // Higher frequency → not-longer code.
        assert!(lens[&0] <= lens[&6]);
    }
}
