//! cuSZp2-like compressor [8,9]: pre-quantization → one-prior delta
//! prediction → per-block fixed-length bit-packing.
//!
//! cuSZp trades ratio for extreme throughput: each fixed-size block is
//! encoded independently (GPU thread-block granularity) with the block's
//! first index stored raw, the remaining indices as zigzagged deltas
//! packed at the block's maximal bit width. Constant blocks collapse to
//! a single width-0 marker. Decompression is trivially block-parallel.

use crate::compressors::bitio::{bytes, unzigzag, zigzag, BitReader, BitWriter};
use crate::compressors::cusz::{read_header, write_header};
use crate::compressors::{Compressor, Decompressed};
use crate::data::grid::Grid;
use crate::quant::{dequantize, quantize, QIndex, ResolvedBound};
use anyhow::Result;

/// Elements per independent block (cuSZp uses 32-thread × multi-element
/// chunks; 256 keeps header overhead ≲ 3%).
pub const BLOCK: usize = 256;

/// Stream magic.
const MAGIC: u32 = 0x6355_5A50; // "cUZP"

/// The cuSZp2-like codec.
#[derive(Debug, Clone, Default)]
pub struct CuszpLike;

impl Compressor for CuszpLike {
    fn name(&self) -> &'static str {
        "cuSZp2-like"
    }

    fn compress(&self, grid: &Grid<f32>, eb: ResolvedBound) -> Result<Vec<u8>> {
        let q = quantize(&grid.data, eb);
        let mut out = Vec::new();
        bytes::put_u32(&mut out, MAGIC);
        write_header(&mut out, grid.shape, eb);

        let mut w = BitWriter::new();
        for block in q.chunks(BLOCK) {
            encode_block(block, &mut w);
        }
        let payload = w.into_bytes();
        bytes::put_u64(&mut out, payload.len() as u64);
        out.extend_from_slice(&payload);
        Ok(out)
    }

    fn decompress(&self, buf: &[u8]) -> Result<Decompressed> {
        let mut off = 0usize;
        let magic = bytes::get_u32(buf, &mut off)?;
        anyhow::ensure!(magic == MAGIC, "not a cuSZp2-like stream");
        let (shape, eb) = read_header(buf, &mut off)?;
        let payload_len = bytes::get_u64(buf, &mut off)? as usize;
        anyhow::ensure!(off + payload_len <= buf.len(), "stream truncated");
        let mut r = BitReader::new(&buf[off..off + payload_len]);

        let n = shape.len();
        let mut q = Vec::with_capacity(n);
        while q.len() < n {
            let len = (n - q.len()).min(BLOCK);
            decode_block(&mut r, len, &mut q)?;
        }
        let data = dequantize(&q, eb);
        let mut grid = Grid::from_vec(data, shape.user_dims());
        grid.shape.ndim = shape.ndim;
        let mut qg = Grid::from_vec(q, shape.user_dims());
        qg.shape.ndim = shape.ndim;
        Ok(Decompressed { grid, quant_indices: qg, bound: eb })
    }
}

/// Encode one block: `[first:i64][width:6][deltas: width bits each]`.
/// A width of 0 means all deltas are 0 (constant block).
fn encode_block(block: &[QIndex], w: &mut BitWriter) {
    debug_assert!(!block.is_empty());
    w.write_bits(block[0] as u64, 64);
    let mut width = 0u32;
    for t in 1..block.len() {
        let zz = zigzag(block[t] - block[t - 1]);
        width = width.max(64 - zz.leading_zeros());
    }
    w.write_bits(width as u64, 6);
    if width == 0 {
        return;
    }
    for t in 1..block.len() {
        let zz = zigzag(block[t] - block[t - 1]);
        w.write_bits(zz, width);
    }
}

/// Decode one block of `len` indices, appending to `q`.
fn decode_block(r: &mut BitReader<'_>, len: usize, q: &mut Vec<QIndex>) -> Result<()> {
    let first = r.read_bits(64).ok_or_else(|| anyhow::anyhow!("truncated block header"))?;
    let mut prev = first as i64;
    q.push(prev);
    let width = r.read_bits(6).ok_or_else(|| anyhow::anyhow!("truncated block width"))? as u32;
    anyhow::ensure!(width <= 63, "invalid block width {width}");
    for _ in 1..len {
        let delta = if width == 0 {
            0
        } else {
            let zz = r.read_bits(width).ok_or_else(|| anyhow::anyhow!("truncated deltas"))?;
            unzigzag(zz)
        };
        prev += delta;
        q.push(prev);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, DatasetKind};
    use crate::metrics::max_abs_error;
    use crate::quant::ErrorBound;
    use crate::util::prop::prop_check;

    #[test]
    fn roundtrip_exact_indices() {
        let g = generate(DatasetKind::HurricaneLike, &[24, 24, 24], 9);
        let eb = ErrorBound::relative(1e-3).resolve(&g.data);
        let c = CuszpLike;
        let stream = c.compress(&g, eb).unwrap();
        let d = c.decompress(&stream).unwrap();
        assert_eq!(d.quant_indices.data, quantize(&g.data, eb));
        assert!(max_abs_error(&g.data, &d.grid.data) <= eb.abs * (1.0 + 1e-9));
    }

    #[test]
    fn constant_blocks_collapse() {
        let g = Grid::from_vec(vec![1.0f32; 4096], &[64, 64]);
        let eb = ErrorBound::absolute(0.1).resolve(&g.data);
        let stream = CuszpLike.compress(&g, eb).unwrap();
        // 16 blocks × (64-bit first + 6-bit width) ≈ 140 bytes + header
        assert!(stream.len() < 250, "len={}", stream.len());
        let d = CuszpLike.decompress(&stream).unwrap();
        assert!(d.quant_indices.data.iter().all(|&v| v == d.quant_indices.data[0]));
    }

    #[test]
    fn ratio_lower_than_cusz_on_smooth_data() {
        // Fixed-length packing cannot beat entropy coding on smooth data —
        // the paper's Fig. 5 bit-rate gap between cuSZ and cuSZp2.
        use crate::compressors::cusz::CuszLike;
        let g = generate(DatasetKind::CombustionLike, &[32, 32, 32], 2);
        let eb = ErrorBound::relative(1e-2).resolve(&g.data);
        let a = CuszLike.compress(&g, eb).unwrap().len();
        let b = CuszpLike.compress(&g, eb).unwrap().len();
        assert!(b >= a, "cusz={a} cuszp={b}");
    }

    #[test]
    fn tail_block_shorter_than_block_size() {
        let n = BLOCK * 2 + 37;
        let g = Grid::from_vec((0..n).map(|i| (i as f32).sin()).collect(), &[n]);
        let eb = ErrorBound::relative(1e-2).resolve(&g.data);
        let stream = CuszpLike.compress(&g, eb).unwrap();
        let d = CuszpLike.decompress(&stream).unwrap();
        assert_eq!(d.quant_indices.data, quantize(&g.data, eb));
    }

    #[test]
    fn roundtrip_property() {
        prop_check("cuszp roundtrip", 25, |g| {
            let n = g.usize_in(1, 1500);
            let field = Grid::from_vec(g.smooth_field(n, 0.4), &[n]);
            let rel = *g.choose(&[1e-3, 1e-2]);
            let eb = ErrorBound::relative(rel).resolve(&field.data);
            let stream = CuszpLike.compress(&field, eb).unwrap();
            let d = CuszpLike.decompress(&stream).unwrap();
            assert_eq!(d.quant_indices.data, quantize(&field.data, eb));
        });
    }

    #[test]
    fn truncated_stream_errors() {
        let g = generate(DatasetKind::ClimateLike, &[16, 16], 4);
        let eb = ErrorBound::relative(1e-2).resolve(&g.data);
        let stream = CuszpLike.compress(&g, eb).unwrap();
        assert!(CuszpLike.decompress(&stream[..stream.len() / 2]).is_err());
    }
}
