//! cuSZ-like compressor [5]: pre-quantization → multidimensional Lorenzo
//! prediction (lossless, over indices) → canonical Huffman coding with
//! an outlier escape channel.
//!
//! This is a faithful CPU implementation of the cuSZ *pipeline*; the
//! quantization-index field it produces is bit-identical to what the GPU
//! version would produce for the same input and bound (pre-quantization
//! decouples the stages — DESIGN.md §5), which is all the mitigation
//! study depends on.

use crate::compressors::bitio::{bytes, unzigzag, zigzag};
use crate::compressors::{huffman, lorenzo, Compressor, Decompressed};
use crate::data::grid::{Grid, Shape};
use crate::quant::{dequantize, quantize, ResolvedBound};
use anyhow::{Context, Result};

/// Residual symbols at or beyond this value escape to the outlier array
/// (cuSZ's "quant code radius" mechanism).
const ESCAPE: u64 = 1 << 16;

/// Stream magic.
const MAGIC: u32 = 0x6355_535A; // "cUSZ"

/// The cuSZ-like codec.
#[derive(Debug, Clone, Default)]
pub struct CuszLike;

impl Compressor for CuszLike {
    fn name(&self) -> &'static str {
        "cuSZ-like"
    }

    fn compress(&self, grid: &Grid<f32>, eb: ResolvedBound) -> Result<Vec<u8>> {
        let q = quantize(&grid.data, eb);
        let qg = Grid::<i64> { shape: grid.shape, data: q };
        let residuals = lorenzo::forward(&qg);

        let mut symbols = Vec::with_capacity(residuals.len());
        let mut outliers = Vec::new();
        for &r in &residuals {
            let zz = zigzag(r);
            if zz < ESCAPE {
                symbols.push(zz as u32);
            } else {
                symbols.push(ESCAPE as u32);
                outliers.push(zz);
            }
        }
        let payload = huffman::encode(&symbols);

        let mut out = Vec::with_capacity(payload.len() + 64);
        bytes::put_u32(&mut out, MAGIC);
        write_header(&mut out, grid.shape, eb);
        bytes::put_u64(&mut out, outliers.len() as u64);
        for &o in &outliers {
            bytes::put_u64(&mut out, o);
        }
        out.extend_from_slice(&payload);
        Ok(out)
    }

    fn decompress(&self, buf: &[u8]) -> Result<Decompressed> {
        let mut off = 0usize;
        let magic = bytes::get_u32(buf, &mut off)?;
        anyhow::ensure!(magic == MAGIC, "not a cuSZ-like stream");
        let (shape, eb) = read_header(buf, &mut off)?;
        let n_out = bytes::get_u64(buf, &mut off)? as usize;
        anyhow::ensure!(n_out <= shape.len(), "outlier count exceeds data size");
        let mut outliers = Vec::with_capacity(n_out);
        for _ in 0..n_out {
            outliers.push(bytes::get_u64(buf, &mut off)?);
        }
        let symbols = huffman::decode(&buf[off..]).context("huffman payload")?;
        anyhow::ensure!(symbols.len() == shape.len(), "symbol count mismatch");

        let mut next_outlier = 0usize;
        let mut residuals = Vec::with_capacity(symbols.len());
        for &s in &symbols {
            let zz = if s as u64 == ESCAPE {
                anyhow::ensure!(next_outlier < outliers.len(), "missing outlier");
                let v = outliers[next_outlier];
                next_outlier += 1;
                v
            } else {
                s as u64
            };
            residuals.push(unzigzag(zz));
        }
        let qg = lorenzo::inverse(&residuals, shape);
        let data = dequantize(&qg.data, eb);
        let mut grid = Grid::from_vec(data, shape.user_dims());
        grid.shape.ndim = shape.ndim;
        Ok(Decompressed { grid, quant_indices: qg, bound: eb })
    }
}

/// Serialize shape + bound (shared by the pre-quantization codecs).
pub(crate) fn write_header(out: &mut Vec<u8>, shape: Shape, eb: ResolvedBound) {
    out.push(shape.ndim as u8);
    for &d in shape.user_dims() {
        bytes::put_u64(out, d as u64);
    }
    bytes::put_f64(out, eb.abs);
    match eb.rel {
        Some(r) => {
            out.push(1);
            bytes::put_f64(out, r);
        }
        None => out.push(0),
    }
}

/// Inverse of [`write_header`].
pub(crate) fn read_header(buf: &[u8], off: &mut usize) -> Result<(Shape, ResolvedBound)> {
    anyhow::ensure!(*off < buf.len(), "stream truncated at ndim");
    let ndim = buf[*off] as usize;
    *off += 1;
    anyhow::ensure!((1..=3).contains(&ndim), "bad ndim {ndim}");
    let mut dims = Vec::with_capacity(ndim);
    for _ in 0..ndim {
        let d = bytes::get_u64(buf, off)? as usize;
        anyhow::ensure!(d > 0 && d < (1 << 40), "bad dim {d}");
        dims.push(d);
    }
    let abs = bytes::get_f64(buf, off)?;
    anyhow::ensure!(abs > 0.0 && abs.is_finite(), "bad bound {abs}");
    anyhow::ensure!(*off < buf.len(), "stream truncated at rel flag");
    let has_rel = buf[*off] == 1;
    *off += 1;
    let rel = if has_rel { Some(bytes::get_f64(buf, off)?) } else { None };
    Ok((Shape::new(&dims), ResolvedBound { abs, rel }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, DatasetKind};
    use crate::metrics::max_abs_error;
    use crate::quant::ErrorBound;
    use crate::util::prop::prop_check;

    #[test]
    fn roundtrip_preserves_indices_and_bound() {
        let g = generate(DatasetKind::ClimateLike, &[64, 64], 3);
        let eb = ErrorBound::relative(1e-3).resolve(&g.data);
        let c = CuszLike;
        let stream = c.compress(&g, eb).unwrap();
        let d = c.decompress(&stream).unwrap();
        assert_eq!(d.grid.shape, g.shape);
        assert!(max_abs_error(&g.data, &d.grid.data) <= eb.abs * (1.0 + 1e-9));
        // indices must be exactly what quantize() produces
        assert_eq!(d.quant_indices.data, quantize(&g.data, eb));
        assert_eq!(d.bound.abs, eb.abs);
        assert_eq!(d.bound.rel, Some(1e-3));
    }

    #[test]
    fn smooth_fields_compress_well() {
        let g = generate(DatasetKind::CombustionLike, &[32, 32, 32], 5);
        let eb = ErrorBound::relative(1e-2).resolve(&g.data);
        let stream = CuszLike.compress(&g, eb).unwrap();
        let ratio = (g.len() * 4) as f64 / stream.len() as f64;
        assert!(ratio > 4.0, "ratio={ratio}");
    }

    #[test]
    fn outlier_escape_path_roundtrips() {
        // Spiky field forces residuals past the escape threshold.
        let mut data = vec![0.0f32; 256];
        for (i, v) in data.iter_mut().enumerate() {
            if i % 7 == 0 {
                *v = (i as f32) * 1e6;
            }
        }
        let g = Grid::from_vec(data, &[16, 16]);
        let eb = ErrorBound::absolute(0.5).resolve(&g.data);
        let stream = CuszLike.compress(&g, eb).unwrap();
        let d = CuszLike.decompress(&stream).unwrap();
        assert_eq!(d.quant_indices.data, quantize(&g.data, eb));
    }

    #[test]
    fn corrupt_stream_is_an_error() {
        let g = generate(DatasetKind::ClimateLike, &[16, 16], 1);
        let eb = ErrorBound::relative(1e-2).resolve(&g.data);
        let mut stream = CuszLike.compress(&g, eb).unwrap();
        assert!(CuszLike.decompress(&stream[..8]).is_err());
        stream[0] ^= 0xFF; // break magic
        assert!(CuszLike.decompress(&stream).is_err());
    }

    #[test]
    fn roundtrip_property_random_fields() {
        prop_check("cusz roundtrip", 25, |g| {
            let ndim = g.usize_in(1, 3);
            let dims: Vec<usize> = (0..ndim).map(|_| g.usize_in(2, 12)).collect();
            let n: usize = dims.iter().product();
            let field = Grid::from_vec(g.smooth_field(n, 0.2), &dims);
            let rel = *g.choose(&[1e-3, 1e-2, 1e-1]);
            let eb = ErrorBound::relative(rel).resolve(&field.data);
            let stream = CuszLike.compress(&field, eb).unwrap();
            let d = CuszLike.decompress(&stream).unwrap();
            assert_eq!(d.quant_indices.data, quantize(&field.data, eb));
            assert!(max_abs_error(&field.data, &d.grid.data) <= eb.abs * (1.0 + 1e-9));
        });
    }
}
