//! Bit-granular I/O over byte buffers: the substrate for Huffman coding
//! and the fixed-length bit-packing in cuSZp/SZp.
//!
//! Bits are written MSB-first within each byte, matching the order most
//! entropy-coder literature (and the SZ family) uses.

/// MSB-first bit writer over a growable byte buffer.
#[derive(Debug, Default)]
pub struct BitWriter {
    buf: Vec<u8>,
    /// Bits already used in the trailing partial byte (0..8).
    nbits: u32,
}

impl BitWriter {
    /// Empty writer.
    pub fn new() -> Self {
        Self::default()
    }

    /// Append the low `n` bits of `value` (n ≤ 64), MSB of the field first.
    #[inline]
    pub fn write_bits(&mut self, value: u64, n: u32) {
        debug_assert!(n <= 64);
        debug_assert!(n == 64 || value < (1u64 << n), "value {value} wider than {n} bits");
        let mut rem = n;
        while rem > 0 {
            if self.nbits == 0 {
                self.buf.push(0);
            }
            let free = 8 - self.nbits;
            let take = free.min(rem);
            let shift = rem - take;
            let bits = ((value >> shift) & ((1u64 << take) - 1)) as u8;
            let last = self.buf.last_mut().unwrap();
            *last |= bits << (free - take);
            self.nbits = (self.nbits + take) % 8;
            rem -= take;
        }
    }

    /// Append one bit.
    #[inline]
    pub fn write_bit(&mut self, bit: bool) {
        self.write_bits(bit as u64, 1);
    }

    /// Number of bits written so far.
    pub fn bit_len(&self) -> usize {
        self.buf.len() * 8 - if self.nbits == 0 { 0 } else { (8 - self.nbits) as usize }
    }

    /// Finish and return the padded byte buffer.
    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }
}

/// MSB-first bit reader over a byte slice.
#[derive(Debug)]
pub struct BitReader<'a> {
    buf: &'a [u8],
    /// Next bit position.
    pos: usize,
}

impl<'a> BitReader<'a> {
    /// Reader over `buf`.
    pub fn new(buf: &'a [u8]) -> Self {
        BitReader { buf, pos: 0 }
    }

    /// Read `n` bits (n ≤ 64) as the low bits of the result.
    /// Returns `None` past the end of the buffer.
    #[inline]
    pub fn read_bits(&mut self, n: u32) -> Option<u64> {
        debug_assert!(n <= 64);
        if self.pos + n as usize > self.buf.len() * 8 {
            return None;
        }
        let mut out = 0u64;
        let mut rem = n;
        while rem > 0 {
            let byte = self.buf[self.pos / 8];
            let bit_off = (self.pos % 8) as u32;
            let avail = 8 - bit_off;
            let take = avail.min(rem);
            let bits = (byte >> (avail - take)) & ((1u16 << take) - 1) as u8;
            out = (out << take) | bits as u64;
            self.pos += take as usize;
            rem -= take;
        }
        Some(out)
    }

    /// Read one bit.
    #[inline]
    pub fn read_bit(&mut self) -> Option<bool> {
        self.read_bits(1).map(|b| b != 0)
    }

    /// Peek `n` bits (n ≤ 64) without advancing, left-aligned into an
    /// `n`-bit field: if fewer than `n` bits remain, the available bits
    /// occupy the *high* end of the field and the missing low bits read
    /// as zero. This is the lookahead primitive of table-driven Huffman
    /// decoding — near the stream tail the table lookup still sees the
    /// remaining prefix in the right position.
    #[inline]
    pub fn peek_bits_lenient(&self, n: u32) -> u64 {
        debug_assert!(n <= 64);
        let total = self.buf.len() * 8;
        let avail = (total - self.pos).min(n as usize) as u32;
        if avail == 0 {
            return 0;
        }
        let mut out = 0u64;
        let mut pos = self.pos;
        let mut rem = avail;
        while rem > 0 {
            let byte = self.buf[pos / 8];
            let bit_off = (pos % 8) as u32;
            let have = 8 - bit_off;
            let take = have.min(rem);
            let bits = (byte >> (have - take)) & ((1u16 << take) - 1) as u8;
            out = (out << take) | bits as u64;
            pos += take as usize;
            rem -= take;
        }
        out << (n - avail)
    }

    /// Advance by `n` bits without reading them (caller already
    /// inspected them via [`BitReader::peek_bits_lenient`]). Must not
    /// move past the end of the buffer.
    #[inline]
    pub fn skip_bits(&mut self, n: u32) {
        debug_assert!(self.pos + n as usize <= self.buf.len() * 8);
        self.pos += n as usize;
    }

    /// Bits remaining before the end of the buffer.
    #[inline]
    pub fn bits_remaining(&self) -> usize {
        self.buf.len() * 8 - self.pos
    }

    /// Current bit position.
    pub fn bit_pos(&self) -> usize {
        self.pos
    }

    /// Skip to the next byte boundary.
    pub fn align_byte(&mut self) {
        self.pos = self.pos.div_ceil(8) * 8;
    }
}

/// Little-endian varint-free fixed encodings used in stream headers.
pub mod bytes {
    /// Append a u64 little-endian.
    pub fn put_u64(buf: &mut Vec<u8>, v: u64) {
        buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Append a u32 little-endian.
    pub fn put_u32(buf: &mut Vec<u8>, v: u32) {
        buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Append an f64 little-endian.
    pub fn put_f64(buf: &mut Vec<u8>, v: f64) {
        buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Read a u64 at `*off`, advancing it.
    pub fn get_u64(buf: &[u8], off: &mut usize) -> anyhow::Result<u64> {
        anyhow::ensure!(*off + 8 <= buf.len(), "stream truncated at u64");
        let v = u64::from_le_bytes(buf[*off..*off + 8].try_into().unwrap());
        *off += 8;
        Ok(v)
    }

    /// Read a u32 at `*off`, advancing it.
    pub fn get_u32(buf: &[u8], off: &mut usize) -> anyhow::Result<u32> {
        anyhow::ensure!(*off + 4 <= buf.len(), "stream truncated at u32");
        let v = u32::from_le_bytes(buf[*off..*off + 4].try_into().unwrap());
        *off += 4;
        Ok(v)
    }

    /// Read an f64 at `*off`, advancing it.
    pub fn get_f64(buf: &[u8], off: &mut usize) -> anyhow::Result<f64> {
        anyhow::ensure!(*off + 8 <= buf.len(), "stream truncated at f64");
        let v = f64::from_le_bytes(buf[*off..*off + 8].try_into().unwrap());
        *off += 8;
        Ok(v)
    }
}

/// ZigZag mapping: signed ↔ unsigned with small magnitudes staying small.
#[inline]
pub fn zigzag(v: i64) -> u64 {
    ((v << 1) ^ (v >> 63)) as u64
}

/// Inverse of [`zigzag`].
#[inline]
pub fn unzigzag(v: u64) -> i64 {
    ((v >> 1) as i64) ^ -((v & 1) as i64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::prop_check;

    #[test]
    fn single_bits_roundtrip() {
        let mut w = BitWriter::new();
        let pattern = [true, false, true, true, false, false, true, false, true, true];
        for &b in &pattern {
            w.write_bit(b);
        }
        assert_eq!(w.bit_len(), 10);
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        for &b in &pattern {
            assert_eq!(r.read_bit(), Some(b));
        }
    }

    #[test]
    fn multi_width_roundtrip() {
        let mut w = BitWriter::new();
        w.write_bits(0b101, 3);
        w.write_bits(0xFFFF, 16);
        w.write_bits(0, 5);
        w.write_bits(u64::MAX, 64);
        w.write_bits(42, 7);
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.read_bits(3), Some(0b101));
        assert_eq!(r.read_bits(16), Some(0xFFFF));
        assert_eq!(r.read_bits(5), Some(0));
        assert_eq!(r.read_bits(64), Some(u64::MAX));
        assert_eq!(r.read_bits(7), Some(42));
    }

    #[test]
    fn read_past_end_is_none() {
        let mut w = BitWriter::new();
        w.write_bits(0b11, 2);
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.read_bits(8), Some(0b11000000));
        assert_eq!(r.read_bits(1), None);
    }

    #[test]
    fn msb_first_layout() {
        let mut w = BitWriter::new();
        w.write_bit(true); // bit 7 of byte 0
        w.write_bits(0, 6);
        w.write_bit(true); // bit 0 of byte 0
        assert_eq!(w.into_bytes(), vec![0b1000_0001]);
    }

    #[test]
    fn align_byte_skips_padding() {
        let mut w = BitWriter::new();
        w.write_bits(0b1, 1);
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        r.read_bit();
        r.align_byte();
        assert_eq!(r.bit_pos(), 8);
    }

    #[test]
    fn zigzag_roundtrip_property() {
        prop_check("zigzag roundtrip", 200, |g| {
            let v = (g.rng().next_u64() as i64) >> g.usize_in(0, 40);
            assert_eq!(unzigzag(zigzag(v)), v);
        });
        assert_eq!(zigzag(0), 0);
        assert_eq!(zigzag(-1), 1);
        assert_eq!(zigzag(1), 2);
        assert_eq!(zigzag(-2), 3);
    }

    #[test]
    fn random_streams_roundtrip_property() {
        prop_check("bitio roundtrip", 60, |g| {
            let n = g.usize_in(1, 200);
            let items: Vec<(u64, u32)> = (0..n)
                .map(|_| {
                    let w = g.usize_in(1, 64) as u32;
                    let mask = if w == 64 { u64::MAX } else { (1u64 << w) - 1 };
                    (g.rng().next_u64() & mask, w)
                })
                .collect();
            let mut wtr = BitWriter::new();
            for &(v, w) in &items {
                wtr.write_bits(v, w);
            }
            let bytes = wtr.into_bytes();
            let mut r = BitReader::new(&bytes);
            for &(v, w) in &items {
                assert_eq!(r.read_bits(w), Some(v));
            }
        });
    }
}
