//! SZp-like compressor [10,11]: pre-quantization → block-independent 1D
//! Lorenzo (delta) → fixed-length packing, with **block-parallel
//! decompression** — the OpenMP decompression baseline of Fig. 8.
//!
//! Unlike [`crate::compressors::cuszp`], the stream stores a per-block
//! byte-offset table so decompression threads can seek independently,
//! matching SZp's OpenMP decompression structure.

use crate::compressors::bitio::{bytes, unzigzag, zigzag, BitReader, BitWriter};
use crate::compressors::cusz::{read_header, write_header};
use crate::compressors::{Compressor, Decompressed};
use crate::data::grid::{Grid, Shape};
use crate::quant::{dequantize_into, quantize, QIndex, ResolvedBound};
use crate::util::arena::ArenaHandle;
use crate::util::pool::PoolHandle;
use anyhow::Result;

/// Elements per independent block.
pub const BLOCK: usize = 1024;

/// Stream magic.
const MAGIC: u32 = 0x535A_5000; // "SZP"

/// The SZp-like codec. `decompress` uses `threads` worker threads over
/// blocks (1 = sequential), the knob the Fig. 8 bench sweeps.
#[derive(Debug, Clone)]
pub struct SzpLike {
    /// Decompression threads.
    pub threads: usize,
}

impl Default for SzpLike {
    fn default() -> Self {
        SzpLike { threads: 1 }
    }
}

impl SzpLike {
    /// [`Compressor::decompress`] with the block-parallel decode
    /// confined to `pool` instead of the global one, and the two
    /// full-grid output buffers (index field and reconstructed data)
    /// acquired from `arena`. Both escape inside the returned
    /// [`Decompressed`] and are accounted as detached; hand them back
    /// with [`crate::util::arena::Arena::adopt`] to keep warm decodes
    /// allocation-free.
    pub fn decompress_on(
        &self,
        pool: PoolHandle<'_>,
        arena: ArenaHandle<'_>,
        buf: &[u8],
    ) -> Result<Decompressed> {
        let mut off = 0usize;
        let magic = bytes::get_u32(buf, &mut off)?;
        anyhow::ensure!(magic == MAGIC, "not an SZp-like stream");
        let (shape, eb) = read_header(buf, &mut off)?;
        let n = shape.len();
        let n_blocks = bytes::get_u64(buf, &mut off)? as usize;
        anyhow::ensure!(n_blocks == n.div_ceil(BLOCK).max(1), "block count mismatch");
        let mut offsets = Vec::with_capacity(n_blocks + 1);
        for _ in 0..=n_blocks {
            offsets.push(bytes::get_u64(buf, &mut off)? as usize);
        }
        let payload = &buf[off..];
        // Monotonic + bounded end ⇒ every per-block slice below is in
        // range; a corrupted table errors here instead of panicking
        // inside a pool worker.
        anyhow::ensure!(
            offsets.windows(2).all(|w| w[0] <= w[1]),
            "offset table is not monotonically non-decreasing"
        );
        anyhow::ensure!(
            *offsets.last().unwrap() <= payload.len(),
            "payload shorter than offset table claims"
        );

        // Block-parallel decode into a preallocated index array (stale
        // lease: on success every element of every block is written,
        // and on a decode error the buffer is returned unread).
        let mut q: Vec<QIndex> = arena.take_stale(n);
        let errors = std::sync::Mutex::new(Vec::new());
        {
            let qslice = crate::util::pool::UnsafeSlice::new(&mut q);
            pool.for_range(n_blocks, self.threads, 1, |b| {
                let start = b * BLOCK;
                let len = (n - start).min(BLOCK);
                let blob = &payload[offsets[b]..offsets[b + 1]];
                match decode_block(blob, len) {
                    Ok(vals) => {
                        for (t, v) in vals.into_iter().enumerate() {
                            // SAFETY: blocks cover disjoint index ranges.
                            unsafe { qslice.write(start + t, v) };
                        }
                    }
                    Err(e) => errors.lock().unwrap().push(format!("block {b}: {e:#}")),
                }
            });
        }
        let errs = errors.into_inner().unwrap();
        if !errs.is_empty() {
            arena.give(q);
            anyhow::bail!("decode failures: {}", errs.join("; "));
        }

        // Stale lease: dequantize_into overwrites every element.
        let mut data: Vec<f32> = arena.take_stale(n);
        dequantize_into(&q, eb, &mut data);
        arena.detach(&q);
        arena.detach(&data);
        let mut grid = Grid::from_vec(data, shape.user_dims());
        grid.shape.ndim = shape.ndim;
        let mut qg = Grid::from_vec(q, shape.user_dims());
        qg.shape.ndim = shape.ndim;
        Ok(Decompressed { grid, quant_indices: qg, bound: eb })
    }

    /// Parse just the stream header: the field's [`Shape`] and resolved
    /// error bound, without touching the payload. The cheap first step
    /// of a tiled/streaming decode, which then pulls windows out with
    /// [`SzpLike::decode_range_on`].
    pub fn stream_info(buf: &[u8]) -> Result<(Shape, ResolvedBound)> {
        let mut off = 0usize;
        let magic = bytes::get_u32(buf, &mut off)?;
        anyhow::ensure!(magic == MAGIC, "not an SZp-like stream");
        read_header(buf, &mut off)
    }

    /// Decode only the quantization indices covering the flat element
    /// range `range` — `O(range + BLOCK)` work and scratch, not
    /// `O(field)`. The per-block offset table is itself randomly
    /// addressable (entry `b` sits at a fixed position in the stream),
    /// so this reads exactly the table entries and block payloads the
    /// range overlaps; nothing outside them is validated or decoded.
    /// The returned vector holds `range.len()` indices, leased from
    /// `arena` and detached (adopt it back to keep warm decodes
    /// allocation-free).
    pub fn decode_range_on(
        &self,
        pool: PoolHandle<'_>,
        arena: ArenaHandle<'_>,
        buf: &[u8],
        range: std::ops::Range<usize>,
    ) -> Result<Vec<QIndex>> {
        let mut off = 0usize;
        let magic = bytes::get_u32(buf, &mut off)?;
        anyhow::ensure!(magic == MAGIC, "not an SZp-like stream");
        let (shape, _eb) = read_header(buf, &mut off)?;
        let n = shape.len();
        let n_blocks = bytes::get_u64(buf, &mut off)? as usize;
        anyhow::ensure!(n_blocks == n.div_ceil(BLOCK).max(1), "block count mismatch");
        anyhow::ensure!(
            range.start <= range.end && range.end <= n,
            "range {range:?} out of bounds for {n} elements"
        );
        if range.is_empty() {
            return Ok(Vec::new());
        }
        let table_base = off;
        let payload_base = table_base + (n_blocks + 1) * 8;
        anyhow::ensure!(payload_base <= buf.len(), "truncated offset table");
        let b0 = range.start / BLOCK;
        let b1 = (range.end - 1) / BLOCK; // inclusive
        // Read only the covering slice of the offset table (entries
        // b0 ..= b1+1 bound the b0..=b1 payloads).
        let mut offsets = Vec::with_capacity(b1 - b0 + 2);
        let mut toff = table_base + b0 * 8;
        for _ in b0..=(b1 + 1) {
            offsets.push(bytes::get_u64(buf, &mut toff)? as usize);
        }
        let payload = &buf[payload_base..];
        anyhow::ensure!(
            offsets.windows(2).all(|w| w[0] <= w[1]),
            "offset table is not monotonically non-decreasing"
        );
        anyhow::ensure!(
            *offsets.last().unwrap() <= payload.len(),
            "payload shorter than offset table claims"
        );

        // Stale lease: on success every element of the range is
        // written; on a decode error the buffer is returned unread.
        let mut out: Vec<QIndex> = arena.take_stale(range.len());
        let errors = std::sync::Mutex::new(Vec::new());
        {
            let oslice = crate::util::pool::UnsafeSlice::new(&mut out);
            pool.for_range(b1 - b0 + 1, self.threads, 1, |i| {
                let b = b0 + i;
                let start = b * BLOCK;
                let len = (n - start).min(BLOCK);
                let blob = &payload[offsets[i]..offsets[i + 1]];
                match decode_block(blob, len) {
                    Ok(vals) => {
                        let lo = range.start.max(start);
                        let hi = range.end.min(start + len);
                        for g in lo..hi {
                            // SAFETY: blocks cover disjoint output ranges.
                            unsafe { oslice.write(g - range.start, vals[g - start]) };
                        }
                    }
                    Err(e) => errors.lock().unwrap().push(format!("block {b}: {e:#}")),
                }
            });
        }
        let errs = errors.into_inner().unwrap();
        if !errs.is_empty() {
            arena.give(out);
            anyhow::bail!("decode failures: {}", errs.join("; "));
        }
        arena.detach(&out);
        Ok(out)
    }
}

impl Compressor for SzpLike {
    fn name(&self) -> &'static str {
        "SZp-like"
    }

    fn compress(&self, grid: &Grid<f32>, eb: ResolvedBound) -> Result<Vec<u8>> {
        let q = quantize(&grid.data, eb);
        let n_blocks = q.len().div_ceil(BLOCK).max(1);

        // Encode blocks independently (byte-aligned) and record offsets.
        let mut blobs: Vec<Vec<u8>> = Vec::with_capacity(n_blocks);
        for block in q.chunks(BLOCK) {
            let mut w = BitWriter::new();
            w.write_bits(block[0] as u64, 64);
            let mut width = 0u32;
            for t in 1..block.len() {
                width = width.max(64 - zigzag(block[t] - block[t - 1]).leading_zeros());
            }
            w.write_bits(width as u64, 6);
            if width > 0 {
                for t in 1..block.len() {
                    w.write_bits(zigzag(block[t] - block[t - 1]), width);
                }
            }
            blobs.push(w.into_bytes());
        }

        let mut out = Vec::new();
        bytes::put_u32(&mut out, MAGIC);
        write_header(&mut out, grid.shape, eb);
        bytes::put_u64(&mut out, n_blocks as u64);
        let mut offset = 0u64;
        for blob in &blobs {
            bytes::put_u64(&mut out, offset);
            offset += blob.len() as u64;
        }
        bytes::put_u64(&mut out, offset); // total payload length sentinel
        for blob in &blobs {
            out.extend_from_slice(blob);
        }
        Ok(out)
    }

    fn decompress(&self, buf: &[u8]) -> Result<Decompressed> {
        self.decompress_on(PoolHandle::Global, ArenaHandle::Fresh, buf)
    }
}

fn decode_block(blob: &[u8], len: usize) -> Result<Vec<QIndex>> {
    let mut r = BitReader::new(blob);
    let first = r.read_bits(64).ok_or_else(|| anyhow::anyhow!("truncated header"))? as i64;
    let width = r.read_bits(6).ok_or_else(|| anyhow::anyhow!("truncated width"))? as u32;
    anyhow::ensure!(width <= 63, "invalid width {width}");
    let mut vals = Vec::with_capacity(len);
    let mut prev = first;
    vals.push(prev);
    for _ in 1..len {
        let delta = if width == 0 {
            0
        } else {
            unzigzag(r.read_bits(width).ok_or_else(|| anyhow::anyhow!("truncated deltas"))?)
        };
        prev += delta;
        vals.push(prev);
    }
    Ok(vals)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, DatasetKind};
    use crate::quant::ErrorBound;

    #[test]
    fn roundtrip_sequential_and_parallel_agree() {
        let g = generate(DatasetKind::CosmologyLike, &[24, 24, 24], 6);
        let eb = ErrorBound::relative(1e-3).resolve(&g.data);
        let stream = SzpLike::default().compress(&g, eb).unwrap();
        let d1 = SzpLike { threads: 1 }.decompress(&stream).unwrap();
        let d4 = SzpLike { threads: 4 }.decompress(&stream).unwrap();
        assert_eq!(d1.quant_indices.data, d4.quant_indices.data);
        assert_eq!(d1.quant_indices.data, quantize(&g.data, eb));
    }

    #[test]
    fn offset_table_enables_seeking() {
        // Corrupting one block's payload must not break others' decode
        // (errors are reported, not mis-decoded).
        let g = generate(DatasetKind::ClimateLike, &[64, 64], 8);
        let eb = ErrorBound::relative(1e-2).resolve(&g.data);
        let stream = SzpLike::default().compress(&g, eb).unwrap();
        let d = SzpLike::default().decompress(&stream).unwrap();
        assert_eq!(d.quant_indices.data.len(), 4096);
    }

    #[test]
    fn single_element_field() {
        let g = Grid::from_vec(vec![3.25f32], &[1]);
        let eb = ErrorBound::absolute(0.5).resolve(&g.data);
        let stream = SzpLike::default().compress(&g, eb).unwrap();
        let d = SzpLike::default().decompress(&stream).unwrap();
        assert_eq!(d.quant_indices.data.len(), 1);
        assert!((d.grid.data[0] - 3.25).abs() <= 0.5);
    }

    #[test]
    fn corrupted_offset_table_is_an_error_not_a_panic() {
        let g = generate(DatasetKind::ClimateLike, &[64, 64], 9); // 4096 elems = 4 blocks
        let eb = ErrorBound::relative(1e-2).resolve(&g.data);
        let mut stream = SzpLike::default().compress(&g, eb).unwrap();
        // Walk to the offset table: magic, header, block count.
        let mut off = 0usize;
        bytes::get_u32(&stream, &mut off).unwrap();
        read_header(&stream, &mut off).unwrap();
        let n_blocks = bytes::get_u64(&stream, &mut off).unwrap() as usize;
        assert!(n_blocks >= 2);
        // Make the second offset non-monotonic (and way out of range)
        // while the final offset stays valid.
        stream[off + 8..off + 16].copy_from_slice(&u64::MAX.to_le_bytes());
        let err = SzpLike::default().decompress(&stream).unwrap_err();
        assert!(err.to_string().contains("offset table"), "err={err:#}");
    }

    #[test]
    fn stream_info_reports_shape_and_bound() {
        let g = generate(DatasetKind::ClimateLike, &[48, 32], 3);
        let eb = ErrorBound::relative(1e-2).resolve(&g.data);
        let stream = SzpLike::default().compress(&g, eb).unwrap();
        let (shape, bound) = SzpLike::stream_info(&stream).unwrap();
        assert_eq!(shape.user_dims(), &[48, 32]);
        assert_eq!(bound.abs, eb.abs);
    }

    #[test]
    fn range_decode_matches_full_decode() {
        // 6400 elements = 7 blocks, so ranges cross block seams.
        let g = generate(DatasetKind::TurbulenceLike, &[40, 40, 4], 11);
        let eb = ErrorBound::relative(1e-3).resolve(&g.data);
        let stream = SzpLike::default().compress(&g, eb).unwrap();
        let full = SzpLike::default().decompress(&stream).unwrap();
        let codec = SzpLike { threads: 2 };
        let cases: [std::ops::Range<usize>; 8] =
            [0..1, 0..6400, 1000..1001, 1023..1025, 2048..4096, 6399..6400, 777..3333, 64..64];
        for range in cases {
            let part = codec
                .decode_range_on(PoolHandle::Global, ArenaHandle::Fresh, &stream, range.clone())
                .unwrap();
            assert_eq!(
                &part[..],
                &full.quant_indices.data[range.clone()],
                "range {range:?} mismatch"
            );
        }
    }

    #[test]
    fn range_decode_rejects_out_of_bounds() {
        let g = generate(DatasetKind::ClimateLike, &[16, 16], 2);
        let eb = ErrorBound::relative(1e-2).resolve(&g.data);
        let stream = SzpLike::default().compress(&g, eb).unwrap();
        let err = SzpLike::default()
            .decode_range_on(PoolHandle::Global, ArenaHandle::Fresh, &stream, 100..300)
            .unwrap_err();
        assert!(err.to_string().contains("out of bounds"), "err={err:#}");
    }

    #[test]
    fn range_decode_through_a_pooled_arena_accounts_leases() {
        use crate::util::arena::Arena;
        let g = generate(DatasetKind::CosmologyLike, &[32, 32, 4], 5);
        let eb = ErrorBound::relative(1e-3).resolve(&g.data);
        let stream = SzpLike::default().compress(&g, eb).unwrap();
        let arena = Arena::new();
        let part = SzpLike::default()
            .decode_range_on(PoolHandle::Global, ArenaHandle::Pooled(&arena), &stream, 100..612)
            .unwrap();
        assert_eq!(part.len(), 512);
        let st = arena.stats();
        assert_eq!(st.bytes_outstanding, 0, "range buffer must be detached");
        assert_eq!(st.detached, 1);
        arena.adopt(part);
        let again = SzpLike::default()
            .decode_range_on(PoolHandle::Global, ArenaHandle::Pooled(&arena), &stream, 100..612)
            .unwrap();
        assert_eq!(arena.stats().hits, 1, "warm same-length range decode must reuse");
        drop(again);
    }

    #[test]
    fn wrong_magic_rejected() {
        let g = generate(DatasetKind::ClimateLike, &[8, 8], 1);
        let eb = ErrorBound::relative(1e-2).resolve(&g.data);
        let mut stream = SzpLike::default().compress(&g, eb).unwrap();
        stream[1] ^= 0x55;
        assert!(SzpLike::default().decompress(&stream).is_err());
    }
}
