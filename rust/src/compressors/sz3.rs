//! Simplified SZ3 [33,35]: multi-level interpolation-predictive
//! compressor with error-controlled residual quantization.
//!
//! This is **not** a pre-quantization compressor (prediction happens on
//! reconstructed values, level by level); it exists as the second
//! OpenMP decompression-throughput baseline of Fig. 8 and as a
//! rate-distortion reference point. Relative to upstream SZ3 it is
//! simplified to 1D multi-level cubic interpolation over the flattened
//! array (upstream interleaves axes per level); the parallel granularity
//! — independent predictions within a level — is the same, which is what
//! the efficiency comparison exercises.
//!
//! Anchors sit at stride `2^L`; each level `l = L..1` predicts the
//! midpoints of stride `2^l` with 4-point cubic interpolation (linear /
//! nearest at the borders), quantizes the prediction residual at the
//! error bound, and reconstructs — so every point obeys `|d−d'| ≤ ε`.

use crate::compressors::bitio::{bytes, unzigzag, zigzag};
use crate::compressors::cusz::{read_header, write_header};
use crate::compressors::huffman;
use crate::data::grid::{Grid, Shape};
use crate::quant::ResolvedBound;
use crate::util::arena::ArenaHandle;
use crate::util::pool::{PoolHandle, UnsafeSlice};
use anyhow::{Context, Result};

/// Max interpolation levels: anchors every 2^10 = 1024 points.
const MAX_LEVEL: u32 = 10;
/// Residual symbols ≥ this escape to the outlier channel.
const ESCAPE: u64 = 1 << 16;
/// Stream magic.
const MAGIC: u32 = 0x535A_3300; // "SZ3"

/// The simplified SZ3 codec; `threads` parallelizes within-level work
/// during decompression (the Fig. 8 knob).
#[derive(Debug, Clone)]
pub struct Sz3Like {
    /// Decompression threads.
    pub threads: usize,
}

impl Default for Sz3Like {
    fn default() -> Self {
        Sz3Like { threads: 1 }
    }
}

/// Number of levels for `n` points.
fn levels_for(n: usize) -> u32 {
    if n < 2 {
        return 0;
    }
    MAX_LEVEL.min(usize::BITS - 1 - (n - 1).leading_zeros())
}

/// Cubic (or degraded) interpolation prediction at position `i` with
/// half-stride `h`, over the reconstruction buffer.
#[inline]
fn predict(recon: &[f32], i: usize, h: usize) -> f64 {
    let n = recon.len();
    let im1 = i - h; // always valid: i ≥ h by construction
    if i + h < n {
        if i >= 3 * h && i + 3 * h < n {
            // 4-point cubic: (-f₋₃ + 9f₋₁ + 9f₊₁ − f₊₃)/16
            (-(recon[i - 3 * h] as f64) + 9.0 * recon[im1] as f64 + 9.0 * recon[i + h] as f64
                - recon[i + 3 * h] as f64)
                / 16.0
        } else {
            0.5 * (recon[im1] as f64 + recon[i + h] as f64)
        }
    } else {
        recon[im1] as f64
    }
}

impl Sz3Like {
    /// Name for bench tables.
    pub fn name(&self) -> &'static str {
        "SZ3-like"
    }

    /// Compress under a resolved bound.
    pub fn compress(&self, grid: &Grid<f32>, eb: ResolvedBound) -> Result<Vec<u8>> {
        let n = grid.len();
        let data = &grid.data;
        let lv = levels_for(n);
        let anchor_stride = 1usize << lv;

        let mut recon = vec![0.0f32; n];
        let mut anchors = Vec::new();
        for i in (0..n).step_by(anchor_stride) {
            anchors.push(data[i]);
            recon[i] = data[i];
        }

        let two_eps = 2.0 * eb.abs;
        let mut codes: Vec<i64> = Vec::with_capacity(n);
        for lvl in (1..=lv).rev() {
            let s = 1usize << lvl;
            let h = s >> 1;
            let mut i = h;
            while i < n {
                let pred = predict(&recon, i, h);
                let code = ((data[i] as f64 - pred) / two_eps).round() as i64;
                recon[i] = (pred + code as f64 * two_eps) as f32;
                codes.push(code);
                i += s;
            }
        }

        // Entropy-code residuals with outlier escape.
        let mut symbols = Vec::with_capacity(codes.len());
        let mut outliers = Vec::new();
        for &c in &codes {
            let zz = zigzag(c);
            if zz < ESCAPE {
                symbols.push(zz as u32);
            } else {
                symbols.push(ESCAPE as u32);
                outliers.push(zz);
            }
        }
        let payload = huffman::encode(&symbols);

        let mut out = Vec::new();
        bytes::put_u32(&mut out, MAGIC);
        write_header(&mut out, grid.shape, eb);
        bytes::put_u64(&mut out, anchors.len() as u64);
        for &a in &anchors {
            bytes::put_u32(&mut out, a.to_bits());
        }
        bytes::put_u64(&mut out, outliers.len() as u64);
        for &o in &outliers {
            bytes::put_u64(&mut out, o);
        }
        out.extend_from_slice(&payload);
        Ok(out)
    }

    /// Decompress (within-level parallel over `self.threads`, regions
    /// on the global pool, buffers freshly allocated).
    pub fn decompress(&self, buf: &[u8]) -> Result<Grid<f32>> {
        self.decompress_on(PoolHandle::Global, ArenaHandle::Fresh, buf)
    }

    /// Shape and resolved bound of an SZ3-like stream without decoding
    /// the payload (used by the tiled executor to plan tile geometry).
    pub fn stream_info(buf: &[u8]) -> Result<(Shape, ResolvedBound)> {
        let mut off = 0usize;
        let magic = bytes::get_u32(buf, &mut off)?;
        anyhow::ensure!(magic == MAGIC, "not an SZ3-like stream");
        read_header(buf, &mut off)
    }

    /// [`Sz3Like::decompress`] with the within-level parallel decode
    /// confined to `pool` instead of the global one, and every full-grid
    /// buffer (reconstruction output, entropy-coder symbols, and the
    /// residual-code scratch) acquired from `arena` — a warm decode is
    /// allocation-free. The reconstruction escapes inside the returned
    /// grid and is accounted as detached; hand it back with
    /// [`crate::util::arena::Arena::adopt`] to keep it so.
    pub fn decompress_on(
        &self,
        pool: PoolHandle<'_>,
        arena: ArenaHandle<'_>,
        buf: &[u8],
    ) -> Result<Grid<f32>> {
        let mut off = 0usize;
        let magic = bytes::get_u32(buf, &mut off)?;
        anyhow::ensure!(magic == MAGIC, "not an SZ3-like stream");
        let (shape, eb) = read_header(buf, &mut off)?;
        let n = shape.len();
        let lv = levels_for(n);
        let anchor_stride = 1usize << lv;

        let n_anchors = bytes::get_u64(buf, &mut off)? as usize;
        anyhow::ensure!(n_anchors == n.div_ceil(anchor_stride), "anchor count mismatch");
        // RAII lease: any decode error drops it and the buffer returns
        // to the arena without a manual give-back on each early exit.
        let mut recon = arena.lease_filled(n, 0.0f32);
        self.decode_into(pool, arena, buf, off, n_anchors, anchor_stride, lv, eb, &mut recon)?;
        let mut grid = Grid::from_vec(recon.detach(), shape.user_dims());
        grid.shape.ndim = shape.ndim;
        Ok(grid)
    }

    /// Decode only `range` of the flattened reconstruction.
    ///
    /// SZ3's multi-level interpolation makes every point depend on a
    /// cone of coarser-level neighbors that spans the whole array, so —
    /// unlike [`crate::compressors::szp::SzpLike::decode_range_on`],
    /// whose blocks are independent — there is no O(range) seek path.
    /// This entry point is an *honest* fallback for the tiled executor:
    /// it replays the full field into arena-recycled scratch (zero
    /// allocations when warm, and the scratch returns to the arena
    /// before this call completes) and copies out just the requested
    /// range. Compute stays O(field); only the escaping memory is
    /// O(range).
    pub fn decode_range_on(
        &self,
        pool: PoolHandle<'_>,
        arena: ArenaHandle<'_>,
        buf: &[u8],
        range: std::ops::Range<usize>,
    ) -> Result<Vec<f32>> {
        if range.is_empty() {
            return Ok(Vec::new());
        }
        let mut off = 0usize;
        let magic = bytes::get_u32(buf, &mut off)?;
        anyhow::ensure!(magic == MAGIC, "not an SZ3-like stream");
        let (shape, eb) = read_header(buf, &mut off)?;
        let n = shape.len();
        anyhow::ensure!(range.end <= n, "range {range:?} out of bounds for {n} elements");
        let lv = levels_for(n);
        let anchor_stride = 1usize << lv;

        let n_anchors = bytes::get_u64(buf, &mut off)? as usize;
        anyhow::ensure!(n_anchors == n.div_ceil(anchor_stride), "anchor count mismatch");
        let mut recon = arena.lease_filled(n, 0.0f32);
        self.decode_into(pool, arena, buf, off, n_anchors, anchor_stride, lv, eb, &mut recon)?;
        let mut part: Vec<f32> = arena.take_stale(range.len());
        part.copy_from_slice(&recon[range]);
        arena.detach(&part);
        Ok(part)
    }

    /// The fallible body of [`Sz3Like::decompress_on`] after the output
    /// lease is taken: anchors, outliers, entropy decode, and the
    /// level replay into `recon`.
    #[allow(clippy::too_many_arguments)]
    fn decode_into(
        &self,
        pool: PoolHandle<'_>,
        arena: ArenaHandle<'_>,
        buf: &[u8],
        mut off: usize,
        n_anchors: usize,
        anchor_stride: usize,
        lv: u32,
        eb: ResolvedBound,
        recon: &mut [f32],
    ) -> Result<()> {
        let n = recon.len();
        for a in 0..n_anchors {
            let bits = bytes::get_u32(buf, &mut off)?;
            recon[a * anchor_stride] = f32::from_bits(bits);
        }
        let n_out = bytes::get_u64(buf, &mut off)? as usize;
        anyhow::ensure!(n_out <= n, "outlier count exceeds data size");
        let mut outliers = Vec::with_capacity(n_out);
        for _ in 0..n_out {
            outliers.push(bytes::get_u64(buf, &mut off)?);
        }
        // Entropy-decode into a leased symbol buffer — the last
        // full-size allocation on the warm decode path is gone.
        let n_sym = huffman::decoded_len(&buf[off..]).context("huffman payload")?;
        anyhow::ensure!(n_sym <= n, "symbol count exceeds data size");
        let mut symbols = arena.lease_stale::<u32>(n_sym);
        huffman::decode_into(&buf[off..], &mut symbols).context("huffman payload")?;

        // Rebuild codes into leased scratch (returned on drop — it
        // never escapes this function; stale lease: the zip loop
        // writes every slot before any read).
        let mut codes = arena.lease_stale::<i64>(n_sym);
        let mut next_outlier = 0usize;
        for (slot, &s) in codes.iter_mut().zip(symbols.iter()) {
            let zz = if s as u64 == ESCAPE {
                anyhow::ensure!(next_outlier < outliers.len(), "missing outlier");
                let v = outliers[next_outlier];
                next_outlier += 1;
                v
            } else {
                s as u64
            };
            *slot = unzigzag(zz);
        }
        drop(symbols);

        // Replay levels; within a level all predictions read only
        // coarser positions, so the level is embarrassingly parallel.
        let two_eps = 2.0 * eb.abs;
        let mut code_base = 0usize;
        for lvl in (1..=lv).rev() {
            let s = 1usize << lvl;
            let h = s >> 1;
            let count = if n > h { (n - h).div_ceil(s) } else { 0 };
            anyhow::ensure!(code_base + count <= codes.len(), "codes exhausted at level {lvl}");
            {
                let rs = UnsafeSlice::new(recon);
                let codes: &[i64] = &codes;
                pool.for_range(count, self.threads, 1024, |t| {
                    let i = h + t * s;
                    // SAFETY: this level writes only positions ≡ h (mod s),
                    // reads only positions ≡ 0 (mod s) — disjoint.
                    let pred = {
                        let r = unsafe { rs.slice_mut(0, n) };
                        predict(r, i, h)
                    };
                    let code = codes[code_base + t];
                    unsafe { rs.write(i, (pred + code as f64 * two_eps) as f32) };
                });
            }
            code_base += count;
        }
        anyhow::ensure!(code_base == codes.len(), "trailing codes in stream");
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, DatasetKind};
    use crate::metrics::max_abs_error;
    use crate::quant::ErrorBound;
    use crate::util::prop::prop_check;

    #[test]
    fn error_bound_respected() {
        let g = generate(DatasetKind::TurbulenceLike, &[20, 20, 20], 17);
        let eb = ErrorBound::relative(1e-3).resolve(&g.data);
        let c = Sz3Like::default();
        let stream = c.compress(&g, eb).unwrap();
        let d = c.decompress(&stream).unwrap();
        // f32 rounding of recon can add ~1 ulp of the value magnitude.
        let tol = eb.abs * (1.0 + 1e-5) + g.value_range() as f64 * f32::EPSILON as f64 * 4.0;
        assert!(max_abs_error(&g.data, &d.data) <= tol);
    }

    #[test]
    fn parallel_decode_is_bitwise_identical() {
        let g = generate(DatasetKind::MirandaLike, &[24, 24, 24], 3);
        let eb = ErrorBound::relative(1e-3).resolve(&g.data);
        let stream = Sz3Like::default().compress(&g, eb).unwrap();
        let d1 = Sz3Like { threads: 1 }.decompress(&stream).unwrap();
        let d4 = Sz3Like { threads: 4 }.decompress(&stream).unwrap();
        assert_eq!(d1.data, d4.data);
    }

    #[test]
    fn beats_bound_compressors_on_ratio_for_smooth_1d_data() {
        // Interpolation prediction outperforms fixed-length delta packing
        // on smooth 1D signals (the regime its 1D multi-level spline
        // models directly; in 3D the simplified flattening gives up some
        // of upstream SZ3's advantage — see module docs).
        use crate::compressors::{cuszp::CuszpLike, Compressor};
        let n = 32768;
        let data: Vec<f32> =
            (0..n).map(|i| ((i as f32) * 0.002).sin() + 0.3 * ((i as f32) * 0.0007).cos()).collect();
        let g = Grid::from_vec(data, &[n]);
        let eb = ErrorBound::relative(1e-3).resolve(&g.data);
        let a = Sz3Like::default().compress(&g, eb).unwrap().len();
        let b = CuszpLike.compress(&g, eb).unwrap().len();
        assert!(a < b, "sz3={a} cuszp={b}");
    }

    #[test]
    fn range_decode_matches_full_decode() {
        use crate::util::pool::PoolHandle;
        let g = generate(DatasetKind::TurbulenceLike, &[30, 30, 4], 9);
        let eb = ErrorBound::relative(1e-3).resolve(&g.data);
        let c = Sz3Like { threads: 2 };
        let stream = c.compress(&g, eb).unwrap();
        let full = c.decompress(&stream).unwrap();
        let n = g.len();
        for range in [0..1, 0..n, 100..1100, n - 1..n, 64..64] {
            let part = c
                .decode_range_on(PoolHandle::Global, ArenaHandle::Fresh, &stream, range.clone())
                .unwrap();
            assert_eq!(&part[..], &full.data[range.clone()], "range {range:?}");
        }
        assert!(c
            .decode_range_on(PoolHandle::Global, ArenaHandle::Fresh, &stream, n..n + 5)
            .is_err());
    }

    #[test]
    fn range_decode_recycles_scratch_through_a_pooled_arena() {
        use crate::util::arena::Arena;
        use crate::util::pool::PoolHandle;
        let g = generate(DatasetKind::MirandaLike, &[16, 16, 8], 5);
        let eb = ErrorBound::relative(1e-3).resolve(&g.data);
        let c = Sz3Like::default();
        let stream = c.compress(&g, eb).unwrap();
        let arena = Arena::new();
        let part = c
            .decode_range_on(PoolHandle::Global, ArenaHandle::Pooled(&arena), &stream, 10..500)
            .unwrap();
        assert_eq!(part.len(), 490);
        let s = arena.stats();
        assert_eq!(s.detached, 1, "only the escaping range slice detaches");
        assert_eq!(s.bytes_outstanding, 0, "all replay scratch returned before the call finished");
        arena.adopt(part);
        // Warm rerun: every buffer comes off the free lists.
        let before = arena.stats().misses;
        let again = c
            .decode_range_on(PoolHandle::Global, ArenaHandle::Pooled(&arena), &stream, 10..500)
            .unwrap();
        assert_eq!(again.len(), 490);
        assert_eq!(arena.stats().misses, before, "warm range decode allocates nothing");
    }

    #[test]
    fn stream_info_reads_header_only() {
        let g = generate(DatasetKind::TurbulenceLike, &[12, 12], 1);
        let eb = ErrorBound::absolute(0.01).resolve(&g.data);
        let stream = Sz3Like::default().compress(&g, eb).unwrap();
        let (shape, bound) = Sz3Like::stream_info(&stream).unwrap();
        assert_eq!(shape.user_dims(), &[12, 12]);
        assert_eq!(bound.abs, eb.abs);
        assert!(Sz3Like::stream_info(&[0u8; 16]).is_err());
    }

    #[test]
    fn tiny_fields() {
        for n in [1usize, 2, 3, 5] {
            let g = Grid::from_vec((0..n).map(|i| i as f32 * 0.3).collect(), &[n]);
            let eb = ErrorBound::absolute(0.01).resolve(&g.data);
            let stream = Sz3Like::default().compress(&g, eb).unwrap();
            let d = Sz3Like::default().decompress(&stream).unwrap();
            assert!(max_abs_error(&g.data, &d.data) <= 0.011, "n={n}");
        }
    }

    #[test]
    fn roundtrip_property() {
        prop_check("sz3 error bound", 20, |gen| {
            let n = gen.usize_in(1, 3000);
            let field = Grid::from_vec(gen.smooth_field(n, 0.1), &[n]);
            let rel = *gen.choose(&[1e-3, 1e-2]);
            let eb = ErrorBound::relative(rel).resolve(&field.data);
            let c = Sz3Like::default();
            let stream = c.compress(&field, eb).unwrap();
            let d = c.decompress(&stream).unwrap();
            let tol =
                eb.abs * (1.0 + 1e-5) + field.value_range() as f64 * f32::EPSILON as f64 * 4.0;
            assert!(max_abs_error(&field.data, &d.data) <= tol);
        });
    }
}
