//! Multidimensional Lorenzo predictor [6] over the quantization-index
//! field. In a pre-quantization pipeline the predictor runs on the
//! already-quantized integers, so prediction+residual is exactly
//! invertible (lossless) and the *forward* pass has no sequential
//! dependency — every residual reads only original values, which is the
//! parallelism pre-quantization buys (paper §III-A).
//!
//! Prediction is the inclusion–exclusion corner sum of the preceding
//! hyper-box: 1D `q[k-1]`; 2D `q[j-1]+q[k-1]−q[j-1,k-1]`; 3D the
//! 7-term version. Out-of-domain neighbors read as 0.

use crate::data::grid::{Grid, Shape};
use crate::quant::QIndex;
use crate::util::simd::{self, SimdLevel};

/// Forward Lorenzo: residuals `r = q − pred(q)`. Parallel-safe (pure
/// gather), though this implementation is single-pass sequential.
/// Dispatches on the process-wide [`simd::level`]; see [`forward_with`].
pub fn forward(q: &Grid<QIndex>) -> Vec<QIndex> {
    forward_with(simd::level(), q)
}

/// [`forward`] at a forced SIMD level. `Scalar` runs the original
/// point-at-a-time triple loop (the semantic reference); vector levels
/// run a row-kernel form ([`simd::delta_row_with`] /
/// [`simd::lorenzo_row2_with`] / [`simd::lorenzo_row3_with`]) that is
/// bit-identical — prediction is pure integer inclusion–exclusion, so
/// regrouping per row changes nothing (pinned by `rust/tests/simd.rs`).
pub fn forward_with(level: SimdLevel, q: &Grid<QIndex>) -> Vec<QIndex> {
    let shape = q.shape;
    let mut out = vec![0 as QIndex; q.len()];
    let dims = shape.dims;
    if level == SimdLevel::Scalar {
        for i in 0..dims[0] {
            for j in 0..dims[1] {
                for k in 0..dims[2] {
                    let idx = shape.idx(i, j, k);
                    out[idx] = q.data[idx] - predict(&q.data, shape, i, j, k);
                }
            }
        }
        return out;
    }
    // Row-kernel form: each contiguous k-row's residuals depend only on
    // the row itself and its (i−1)/(j−1)/(i−1,j−1) neighbor rows.
    let nk = dims[2];
    for i in 0..dims[0] {
        for j in 0..dims[1] {
            let row = shape.idx(i, j, 0);
            let c = &q.data[row..row + nk];
            let o = &mut out[row..row + nk];
            if i == 0 && j == 0 {
                simd::delta_row_with(level, o, c);
            } else if i == 0 || j == 0 {
                let m = if i == 0 { shape.idx(i, j - 1, 0) } else { shape.idx(i - 1, j, 0) };
                simd::lorenzo_row2_with(level, o, c, &q.data[m..m + nk]);
            } else {
                let a = shape.idx(i - 1, j, 0);
                let b = shape.idx(i, j - 1, 0);
                let ab = shape.idx(i - 1, j - 1, 0);
                simd::lorenzo_row3_with(
                    level,
                    o,
                    c,
                    &q.data[a..a + nk],
                    &q.data[b..b + nk],
                    &q.data[ab..ab + nk],
                );
            }
        }
    }
    out
}

/// Inverse Lorenzo: reconstruct `q` from residuals in scan order (each
/// point's prediction depends only on already-reconstructed values).
/// Dispatches on the process-wide [`simd::level`]; see [`inverse_with`].
pub fn inverse(residuals: &[QIndex], shape: Shape) -> Grid<QIndex> {
    inverse_with(simd::level(), residuals, shape)
}

/// [`inverse`] at a forced SIMD level. `Scalar` runs the original
/// scan-order recurrence; vector levels factor it into an in-row prefix
/// sum (`h[j] = g[j] − g[j−1]` satisfies `h[k] = r[k] + h[k−1]`) plus
/// vectorized cross-row and cross-plane [`simd::add_assign_i64_with`]
/// accumulations — an exact integer identity, so the result is
/// bit-identical to the scalar form.
pub fn inverse_with(level: SimdLevel, residuals: &[QIndex], shape: Shape) -> Grid<QIndex> {
    assert_eq!(residuals.len(), shape.len());
    let dims = shape.dims;
    if level == SimdLevel::Scalar {
        let mut g = Grid::<QIndex> { shape, data: vec![0; residuals.len()] };
        for i in 0..dims[0] {
            for j in 0..dims[1] {
                for k in 0..dims[2] {
                    let idx = shape.idx(i, j, k);
                    let pred = predict(&g.data, shape, i, j, k);
                    g.data[idx] = residuals[idx] + pred;
                }
            }
        }
        return g;
    }
    // Factored form: per row, h = prefix_sum(residual row); per plane,
    // g-row_j = g-row_{j−1} + h; across planes, g-plane_i = g-plane_{i−1}
    // + (2D-inverse of residual plane i).
    let mut g = Grid::<QIndex> { shape, data: residuals.to_vec() };
    let nk = dims[2];
    let plane = dims[1] * nk;
    for i in 0..dims[0] {
        let base = i * plane;
        for j in 0..dims[1] {
            let row = base + j * nk;
            simd::prefix_sum_i64(&mut g.data[row..row + nk]);
            if j > 0 {
                let (prev, cur) = g.data.split_at_mut(row);
                simd::add_assign_i64_with(level, &mut cur[..nk], &prev[row - nk..]);
            }
        }
        if i > 0 {
            let (prev, cur) = g.data.split_at_mut(base);
            simd::add_assign_i64_with(level, &mut cur[..plane], &prev[base - plane..]);
        }
    }
    g
}

/// Lorenzo prediction at `(i, j, k)` from the preceding corner values.
#[inline]
fn predict(data: &[QIndex], shape: Shape, i: usize, j: usize, k: usize) -> QIndex {
    // Inclusion–exclusion over the 2³−1 preceding corners; unit axes
    // contribute nothing because their "previous" index is out of domain.
    let at = |a: isize, b: isize, c: isize| -> QIndex {
        if a < 0 || b < 0 || c < 0 {
            0
        } else {
            data[shape.idx(a as usize, b as usize, c as usize)]
        }
    };
    let (i, j, k) = (i as isize, j as isize, k as isize);
    at(i - 1, j, k) + at(i, j - 1, k) + at(i, j, k - 1) - at(i - 1, j - 1, k)
        - at(i - 1, j, k - 1)
        - at(i, j - 1, k - 1)
        + at(i - 1, j - 1, k - 1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::prop_check;

    #[test]
    fn roundtrip_1d() {
        let q = Grid::from_vec(vec![5i64, 5, 6, 7, 7, 3, -2], &[7]);
        let r = forward(&q);
        // 1D Lorenzo = delta coding
        assert_eq!(r, vec![5, 0, 1, 1, 0, -4, -5]);
        assert_eq!(inverse(&r, q.shape).data, q.data);
    }

    #[test]
    fn roundtrip_2d_3d_property() {
        prop_check("lorenzo roundtrip", 60, |g| {
            let ndim = g.usize_in(1, 3);
            let dims: Vec<usize> = (0..ndim).map(|_| g.usize_in(1, 10)).collect();
            let n: usize = dims.iter().product();
            let vals: Vec<i64> =
                (0..n).map(|_| g.usize_in(0, 2000) as i64 - 1000).collect();
            let q = Grid::from_vec(vals, &dims);
            let r = forward(&q);
            assert_eq!(inverse(&r, q.shape).data, q.data);
        });
    }

    #[test]
    fn smooth_field_residuals_are_small() {
        // Quantized smooth ramp → tiny residuals, the whole point of Lorenzo.
        let mut q = Grid::<QIndex>::zeros(&[16, 16]);
        for j in 0..16 {
            for k in 0..16 {
                *q.at_mut(0, j, k) = (j + k) as i64;
            }
        }
        let r = forward(&q);
        // Interior of a linear field is predicted exactly; only the first
        // row/column (whose out-of-domain neighbors read as 0) carry
        // nonzero residuals.
        for j in 1..16 {
            for k in 1..16 {
                assert_eq!(r[q.shape.idx(0, j, k)], 0, "interior residual at {j},{k}");
            }
        }
        let nonzero = r.iter().filter(|&&v| v != 0).count();
        assert!(nonzero <= 31, "nonzero={nonzero}");
    }

    #[test]
    fn constant_field_residuals_zero_after_first() {
        let q = Grid::from_vec(vec![9i64; 27], &[3, 3, 3]);
        let r = forward(&q);
        assert_eq!(r[0], 9);
        assert!(r[1..].iter().all(|&v| v == 0));
    }
}
