//! Multidimensional Lorenzo predictor [6] over the quantization-index
//! field. In a pre-quantization pipeline the predictor runs on the
//! already-quantized integers, so prediction+residual is exactly
//! invertible (lossless) and the *forward* pass has no sequential
//! dependency — every residual reads only original values, which is the
//! parallelism pre-quantization buys (paper §III-A).
//!
//! Prediction is the inclusion–exclusion corner sum of the preceding
//! hyper-box: 1D `q[k-1]`; 2D `q[j-1]+q[k-1]−q[j-1,k-1]`; 3D the
//! 7-term version. Out-of-domain neighbors read as 0.

use crate::data::grid::{Grid, Shape};
use crate::quant::QIndex;

/// Forward Lorenzo: residuals `r = q − pred(q)`. Parallel-safe (pure
/// gather), though this implementation is single-pass sequential.
pub fn forward(q: &Grid<QIndex>) -> Vec<QIndex> {
    let shape = q.shape;
    let mut out = vec![0 as QIndex; q.len()];
    let dims = shape.dims;
    for i in 0..dims[0] {
        for j in 0..dims[1] {
            for k in 0..dims[2] {
                let idx = shape.idx(i, j, k);
                out[idx] = q.data[idx] - predict(&q.data, shape, i, j, k);
            }
        }
    }
    out
}

/// Inverse Lorenzo: reconstruct `q` from residuals in scan order (each
/// point's prediction depends only on already-reconstructed values).
pub fn inverse(residuals: &[QIndex], shape: Shape) -> Grid<QIndex> {
    assert_eq!(residuals.len(), shape.len());
    let mut g = Grid::<QIndex> { shape, data: vec![0; residuals.len()] };
    let dims = shape.dims;
    for i in 0..dims[0] {
        for j in 0..dims[1] {
            for k in 0..dims[2] {
                let idx = shape.idx(i, j, k);
                let pred = predict(&g.data, shape, i, j, k);
                g.data[idx] = residuals[idx] + pred;
            }
        }
    }
    g
}

/// Lorenzo prediction at `(i, j, k)` from the preceding corner values.
#[inline]
fn predict(data: &[QIndex], shape: Shape, i: usize, j: usize, k: usize) -> QIndex {
    // Inclusion–exclusion over the 2³−1 preceding corners; unit axes
    // contribute nothing because their "previous" index is out of domain.
    let at = |a: isize, b: isize, c: isize| -> QIndex {
        if a < 0 || b < 0 || c < 0 {
            0
        } else {
            data[shape.idx(a as usize, b as usize, c as usize)]
        }
    };
    let (i, j, k) = (i as isize, j as isize, k as isize);
    at(i - 1, j, k) + at(i, j - 1, k) + at(i, j, k - 1) - at(i - 1, j - 1, k)
        - at(i - 1, j, k - 1)
        - at(i, j - 1, k - 1)
        + at(i - 1, j - 1, k - 1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::prop_check;

    #[test]
    fn roundtrip_1d() {
        let q = Grid::from_vec(vec![5i64, 5, 6, 7, 7, 3, -2], &[7]);
        let r = forward(&q);
        // 1D Lorenzo = delta coding
        assert_eq!(r, vec![5, 0, 1, 1, 0, -4, -5]);
        assert_eq!(inverse(&r, q.shape).data, q.data);
    }

    #[test]
    fn roundtrip_2d_3d_property() {
        prop_check("lorenzo roundtrip", 60, |g| {
            let ndim = g.usize_in(1, 3);
            let dims: Vec<usize> = (0..ndim).map(|_| g.usize_in(1, 10)).collect();
            let n: usize = dims.iter().product();
            let vals: Vec<i64> =
                (0..n).map(|_| g.usize_in(0, 2000) as i64 - 1000).collect();
            let q = Grid::from_vec(vals, &dims);
            let r = forward(&q);
            assert_eq!(inverse(&r, q.shape).data, q.data);
        });
    }

    #[test]
    fn smooth_field_residuals_are_small() {
        // Quantized smooth ramp → tiny residuals, the whole point of Lorenzo.
        let mut q = Grid::<QIndex>::zeros(&[16, 16]);
        for j in 0..16 {
            for k in 0..16 {
                *q.at_mut(0, j, k) = (j + k) as i64;
            }
        }
        let r = forward(&q);
        // Interior of a linear field is predicted exactly; only the first
        // row/column (whose out-of-domain neighbors read as 0) carry
        // nonzero residuals.
        for j in 1..16 {
            for k in 1..16 {
                assert_eq!(r[q.shape.idx(0, j, k)], 0, "interior residual at {j},{k}");
            }
        }
        let nonzero = r.iter().filter(|&&v| v != 0).count();
        assert!(nonzero <= 31, "nonzero={nonzero}");
    }

    #[test]
    fn constant_field_residuals_zero_after_first() {
        let q = Grid::from_vec(vec![9i64; 27], &[3, 3, 3]);
        let r = forward(&q);
        assert_eq!(r[0], 9);
        assert!(r[1..].iter().all(|&v| v == 0));
    }
}
