//! Pre-quantization based compressors (the systems whose artifacts we
//! mitigate) and the bit-level codecs they are built from.
//!
//! All pipelines share the shape: **pre-quantize** (the only lossy step,
//! [`crate::quant`]) → **predict** losslessly over the index field →
//! **encode**. Decompression inverts encode/predict exactly, then
//! dequantizes — so the decompressed data is fully determined by the
//! quantization indices, which is what the mitigation pipeline consumes.
//!
//! * [`cusz`] — cuSZ-like: multidimensional Lorenzo prediction + Huffman
//!   coding with outlier escape (ref [5]);
//! * [`cuszp`] — cuSZp2-like: 1-prior delta prediction + per-block
//!   fixed-length bit-packing (refs [8,9]);
//! * [`szp`] — SZp-like: block-independent 1D Lorenzo + bit-plane
//!   packing with OpenMP-style block-parallel decompression (ref [10]);
//! * [`sz3`] — simplified SZ3: cubic-spline interpolation prediction
//!   (*not* pre-quantization based; the Fig. 8 decompression-throughput
//!   baseline, refs [33,35]).

pub mod bitio;
pub mod cusz;
pub mod cuszp;
pub mod huffman;
pub mod lorenzo;
pub mod sz3;
pub mod szp;

use crate::data::grid::Grid;
use crate::quant::{QIndex, ResolvedBound};
use anyhow::Result;

/// Decompression output of a pre-quantization compressor: the
/// reconstructed field plus the quantization-index field that fully
/// determines it (the mitigation pipeline's second input).
pub struct Decompressed {
    /// Reconstructed data `d' = 2qε`.
    pub grid: Grid<f32>,
    /// Quantization indices.
    pub quant_indices: Grid<QIndex>,
    /// The resolved bound the stream was compressed with.
    pub bound: ResolvedBound,
}

/// A pre-quantization based error-bounded lossy compressor.
pub trait Compressor {
    /// Human-readable name (used in bench tables).
    fn name(&self) -> &'static str;

    /// Compress `grid` under the resolved bound.
    fn compress(&self, grid: &Grid<f32>, eb: ResolvedBound) -> Result<Vec<u8>>;

    /// Decompress a stream produced by [`Compressor::compress`].
    fn decompress(&self, bytes: &[u8]) -> Result<Decompressed>;
}
