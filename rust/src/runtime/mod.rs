//! PJRT runtime: loads the AOT-compiled JAX/Pallas artifacts
//! (`artifacts/*.hlo.txt`, built once by `make artifacts`) and executes
//! them from the Rust hot path. Python never runs at request time.
//!
//! Interchange format is **HLO text**, not serialized `HloModuleProto`:
//! jax ≥ 0.5 emits protos with 64-bit instruction ids that the crate's
//! xla_extension 0.5.1 rejects; the text parser reassigns ids and
//! round-trips cleanly (see /opt/xla-example/README.md).
//!
//! The executables operate on fixed shapes (XLA programs are
//! shape-specialized): elementwise kernels on flat `f32[CHUNK]` vectors,
//! the boundary stencil on ghost-padded `i32[B+2,...]` blocks. [`ops`]
//! tiles arbitrary grids onto those shapes — that padding/tiling logic is
//! part of the L3 coordinator's job, mirroring how the distributed
//! coordinator tiles rank-local blocks.

pub mod engine;
pub mod ops;

pub use engine::Engine;
