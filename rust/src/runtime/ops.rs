//! Typed entry points over the AOT artifacts, plus the tiling that maps
//! arbitrary grids onto the executables' fixed shapes.
//!
//! Shape contract (must match `python/compile/aot.py`):
//!
//! | artifact          | inputs                                   | outputs            |
//! |-------------------|------------------------------------------|--------------------|
//! | `idw_65536`       | dq,d1,d2,s: f32[65536]; eta_eps: f32[]   | (f32[65536],)      |
//! | `prequant_65536`  | d: f32[65536]; eps: f32[]                | (i32, f32)[65536]  |
//! | `boundary3d_64`   | q: i32[66,66,66]                         | (mask,sign) i32[64³]|
//! | `boundary2d_256`  | q: i32[258,258]                          | (mask,sign) i32[256²]|
//!
//! Distances cross the boundary as f32 with the convention
//! `INF → −1.0` (a negative distance is "no boundary anywhere"), so the
//! kernel can branch with `select` instead of risking `inf/inf` NaNs.

use crate::data::grid::Grid;
use crate::mitigation::boundary::BoundaryResult;
use crate::mitigation::edt::INF;
use crate::quant::QIndex;
use crate::runtime::engine;
use anyhow::{Context, Result};

/// Flat chunk length of the elementwise kernels.
pub const CHUNK: usize = 65536;
/// Interior tile extent of the 3D boundary stencil.
pub const TILE3D: usize = 64;
/// Interior tile extent of the 2D boundary stencil.
pub const TILE2D: usize = 256;

/// Map a squared distance to the kernel's f32 convention.
#[inline]
fn dist_to_f32(d_sq: i64) -> f32 {
    if d_sq >= INF {
        -1.0
    } else {
        (d_sq as f64).sqrt() as f32
    }
}

/// Step E via PJRT: `data[i] += idw_weight(d1,d2) · sign · eta_eps`,
/// chunked over `idw_65536`.
pub fn compensate_pjrt(
    data: &mut [f32],
    dist1_sq: &[i64],
    dist2_sq: &[i64],
    sign: &[i8],
    eta_eps: f64,
) -> Result<()> {
    let eng = engine::global()?;
    let n = data.len();
    let mut dq = vec![0.0f32; CHUNK];
    let mut d1 = vec![-1.0f32; CHUNK];
    let mut d2 = vec![-1.0f32; CHUNK];
    let mut s = vec![0.0f32; CHUNK];
    let mut start = 0usize;
    while start < n {
        let len = (n - start).min(CHUNK);
        for t in 0..len {
            dq[t] = data[start + t];
            d1[t] = dist_to_f32(dist1_sq[start + t]);
            d2[t] = dist_to_f32(dist2_sq[start + t]);
            s[t] = sign[start + t] as f32;
        }
        // Pad: sign 0 ⇒ padded lanes are no-ops regardless of distances.
        for t in len..CHUNK {
            s[t] = 0.0;
            dq[t] = 0.0;
            d1[t] = -1.0;
            d2[t] = -1.0;
        }
        let out = eng.run(
            "idw_65536",
            &[
                xla::Literal::vec1(&dq),
                xla::Literal::vec1(&d1),
                xla::Literal::vec1(&d2),
                xla::Literal::vec1(&s),
                xla::Literal::scalar(eta_eps as f32),
            ],
        )?;
        let got: Vec<f32> = out[0].to_vec().context("idw output")?;
        data[start..start + len].copy_from_slice(&got[..len]);
        start += len;
    }
    Ok(())
}

/// Pre-quantization via PJRT (`q = round(d/2ε)`, `dq = 2qε`), chunked.
/// Note: XLA `round_nearest_even` differs from Rust's half-away rounding
/// exactly on ties, which are measure-zero for real data; the compressors
/// use the native quantizer for bit-exactness.
pub fn prequant_pjrt(data: &[f32], eps_abs: f64) -> Result<(Vec<i32>, Vec<f32>)> {
    let eng = engine::global()?;
    let n = data.len();
    let mut q = Vec::with_capacity(n);
    let mut dq = Vec::with_capacity(n);
    let mut buf = vec![0.0f32; CHUNK];
    let mut start = 0usize;
    while start < n {
        let len = (n - start).min(CHUNK);
        buf[..len].copy_from_slice(&data[start..start + len]);
        buf[len..].fill(0.0);
        let out = eng.run(
            "prequant_65536",
            &[xla::Literal::vec1(&buf), xla::Literal::scalar(eps_abs as f32)],
        )?;
        let qs: Vec<i32> = out[0].to_vec().context("prequant q output")?;
        let ds: Vec<f32> = out[1].to_vec().context("prequant dq output")?;
        q.extend_from_slice(&qs[..len]);
        dq.extend_from_slice(&ds[..len]);
        start += len;
    }
    Ok((q, dq))
}

/// Step A via PJRT: tile the index grid onto the fixed-shape boundary
/// stencil with replicated ghost cells, then clear the domain-edge
/// planes to match Alg. 2's loop bounds. 2D and 3D only (1D fields use
/// the native path).
pub fn boundary_and_sign_pjrt(q: &Grid<QIndex>) -> Result<BoundaryResult> {
    let shape = q.shape;
    anyhow::ensure!(
        shape.ndim >= 2,
        "PJRT boundary stencil supports 2D/3D grids (got 1D) — use Backend::Native"
    );
    // i32 range check once up front.
    for &v in &q.data {
        anyhow::ensure!(
            v >= i32::MIN as i64 && v <= i32::MAX as i64,
            "quantization index {v} exceeds i32 range of the PJRT kernel"
        );
    }
    let mut mask = Grid::<bool>::like(q);
    let mut sign = Grid::<i8>::like(q);

    if shape.dims[0] > 1 {
        run_tiles_3d(q, &mut mask, &mut sign)?;
    } else {
        run_tiles_2d(q, &mut mask, &mut sign)?;
    }

    clear_domain_edges(&mut mask, &mut sign);
    Ok(BoundaryResult { mask, sign })
}

/// Clamped-replicate read of the index grid.
#[inline]
fn at_clamped(q: &Grid<QIndex>, i: isize, j: isize, k: isize) -> i32 {
    let d = q.shape.dims;
    let c = |p: isize, n: usize| p.clamp(0, n as isize - 1) as usize;
    q.data[q.shape.idx(c(i, d[0]), c(j, d[1]), c(k, d[2]))] as i32
}

fn run_tiles_3d(q: &Grid<QIndex>, mask: &mut Grid<bool>, sign: &mut Grid<i8>) -> Result<()> {
    let eng = engine::global()?;
    let dims = q.shape.dims;
    let t = TILE3D;
    let p = t + 2;
    let mut padded = vec![0i32; p * p * p];
    for oi in (0..dims[0]).step_by(t) {
        for oj in (0..dims[1]).step_by(t) {
            for ok in (0..dims[2]).step_by(t) {
                // Gather ghost-padded tile (replicate-clamped).
                for a in 0..p {
                    for b in 0..p {
                        for c in 0..p {
                            padded[(a * p + b) * p + c] = at_clamped(
                                q,
                                oi as isize + a as isize - 1,
                                oj as isize + b as isize - 1,
                                ok as isize + c as isize - 1,
                            );
                        }
                    }
                }
                let lit = xla::Literal::vec1(&padded)
                    .reshape(&[p as i64, p as i64, p as i64])
                    .context("reshape padded tile")?;
                let out = eng.run("boundary3d_64", &[lit])?;
                let m: Vec<i32> = out[0].to_vec().context("mask output")?;
                let s: Vec<i32> = out[1].to_vec().context("sign output")?;
                // Scatter the valid intersection back.
                let ei = (oi + t).min(dims[0]);
                let ej = (oj + t).min(dims[1]);
                let ek = (ok + t).min(dims[2]);
                for i in oi..ei {
                    for j in oj..ej {
                        for k in ok..ek {
                            let src = ((i - oi) * t + (j - oj)) * t + (k - ok);
                            let dst = q.shape.idx(i, j, k);
                            mask.data[dst] = m[src] != 0;
                            sign.data[dst] = s[src] as i8;
                        }
                    }
                }
            }
        }
    }
    Ok(())
}

fn run_tiles_2d(q: &Grid<QIndex>, mask: &mut Grid<bool>, sign: &mut Grid<i8>) -> Result<()> {
    let eng = engine::global()?;
    let dims = q.shape.dims;
    let t = TILE2D;
    let p = t + 2;
    let mut padded = vec![0i32; p * p];
    for oj in (0..dims[1]).step_by(t) {
        for ok in (0..dims[2]).step_by(t) {
            for b in 0..p {
                for c in 0..p {
                    padded[b * p + c] = at_clamped(
                        q,
                        0,
                        oj as isize + b as isize - 1,
                        ok as isize + c as isize - 1,
                    );
                }
            }
            let lit = xla::Literal::vec1(&padded)
                .reshape(&[p as i64, p as i64])
                .context("reshape padded tile")?;
            let out = eng.run("boundary2d_256", &[lit])?;
            let m: Vec<i32> = out[0].to_vec().context("mask output")?;
            let s: Vec<i32> = out[1].to_vec().context("sign output")?;
            let ej = (oj + t).min(dims[1]);
            let ek = (ok + t).min(dims[2]);
            for j in oj..ej {
                for k in ok..ek {
                    let src = (j - oj) * t + (k - ok);
                    let dst = q.shape.idx(0, j, k);
                    mask.data[dst] = m[src] != 0;
                    sign.data[dst] = s[src] as i8;
                }
            }
        }
    }
    Ok(())
}

/// Alg. 2 never marks points on the domain edge of an active axis.
fn clear_domain_edges(mask: &mut Grid<bool>, sign: &mut Grid<i8>) {
    let shape = mask.shape;
    let dims = shape.dims;
    let active: Vec<usize> = shape.active_axes().collect();
    for i in 0..dims[0] {
        for j in 0..dims[1] {
            for k in 0..dims[2] {
                let pos = [i, j, k];
                let edge = active.iter().any(|&a| pos[a] == 0 || pos[a] == dims[a] - 1);
                if edge {
                    let idx = shape.idx(i, j, k);
                    mask.data[idx] = false;
                    sign.data[idx] = 0;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Tests that need compiled artifacts live in `rust/tests/pjrt.rs`;
    /// here we only check the pure helpers.
    #[test]
    fn dist_mapping() {
        assert_eq!(dist_to_f32(INF), -1.0);
        assert_eq!(dist_to_f32(0), 0.0);
        assert_eq!(dist_to_f32(25), 5.0);
    }

    #[test]
    fn clamped_reads_replicate_edges() {
        let q = Grid::from_vec(vec![1i64, 2, 3, 4], &[2, 2]);
        assert_eq!(at_clamped(&q, 0, -1, 0), 1);
        assert_eq!(at_clamped(&q, 0, 5, 5), 4);
        assert_eq!(at_clamped(&q, -3, 0, 1), 2);
    }

    #[test]
    fn clear_edges_2d() {
        let mut m = Grid::from_vec(vec![true; 16], &[4, 4]);
        let mut s = Grid::from_vec(vec![1i8; 16], &[4, 4]);
        clear_domain_edges(&mut m, &mut s);
        assert!(m.at(0, 1, 1) && m.at(0, 2, 2));
        assert!(!m.at(0, 0, 0) && !m.at(0, 3, 3) && !m.at(0, 0, 2));
        assert_eq!(s.at(0, 0, 2), 0);
    }
}
