//! PJRT client + executable cache.
//!
//! The `xla` crate's `PjRtClient` is `!Send`/`!Sync` (Rc-based handles
//! over the C API), so the process cannot share one client across
//! threads; instead each thread lazily owns an engine via
//! [`with_global`]. The mitigation pipeline drives PJRT from a single
//! thread, so in practice exactly one client exists.

use anyhow::{Context, Result};
use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

/// A PJRT CPU client with a cache of compiled executables, keyed by
/// artifact name (file stem under the artifacts directory).
pub struct Engine {
    client: xla::PjRtClient,
    dir: PathBuf,
    exes: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
}

impl Engine {
    /// Create an engine over the given artifacts directory.
    pub fn new(dir: impl Into<PathBuf>) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(Engine { client, dir: dir.into(), exes: RefCell::new(HashMap::new()) })
    }

    /// The artifacts directory this engine loads from.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// PJRT platform name (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile (or fetch from cache) the artifact `<name>.hlo.txt`.
    pub fn executable(&self, name: &str) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.exes.borrow().get(name) {
            return Ok(exe.clone());
        }
        let path = self.dir.join(format!("{name}.hlo.txt"));
        anyhow::ensure!(path.exists(), "artifact {path:?} not found — run `make artifacts` first");
        let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
            .with_context(|| format!("parse HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(
            self.client.compile(&comp).with_context(|| format!("compile artifact {name}"))?,
        );
        self.exes.borrow_mut().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Execute an artifact with the given input literals; returns the
    /// output tuple elements (jax lowers with `return_tuple=True`).
    pub fn run(&self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let exe = self.executable(name)?;
        let result = exe.execute::<xla::Literal>(inputs).context("execute")?;
        let literal = result[0][0].to_literal_sync().context("fetch result")?;
        literal.to_tuple().context("untuple result")
    }
}

thread_local! {
    static ENGINE: RefCell<Option<Rc<Engine>>> = const { RefCell::new(None) };
}

/// Thread-local engine over the default artifacts directory
/// (`$QAI_ARTIFACTS` or `./artifacts`). First use on a thread creates
/// the PJRT client.
pub fn global() -> Result<Rc<Engine>> {
    ENGINE.with(|slot| {
        let mut slot = slot.borrow_mut();
        if let Some(eng) = slot.as_ref() {
            return Ok(eng.clone());
        }
        let dir = std::env::var("QAI_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string());
        let eng = Rc::new(Engine::new(dir)?);
        *slot = Some(eng.clone());
        Ok(eng)
    })
}
