//! Shared rate-distortion sweep machinery for the Fig. 5/6 benches:
//! for each (codec, dataset, ε) cell, compress → decompress → apply each
//! mitigation method → record bit-rate + SSIM + PSNR.

use crate::compressors::{cusz::CuszLike, cuszp::CuszpLike, Compressor};
use crate::data::synthetic::{generate, DatasetKind};
use crate::filters::{gaussian_filter, uniform_filter, wiener_filter};
use crate::metrics::{bit_rate, psnr, ssim};
use crate::mitigation::engine::{self, MitigationRequest};
use crate::quant::ErrorBound;

/// One rate-distortion measurement cell.
#[derive(Debug, Clone)]
pub struct RdPoint {
    /// Codec name.
    pub codec: &'static str,
    /// Dataset paper name.
    pub dataset: &'static str,
    /// Value-range-relative error bound.
    pub rel_eb: f64,
    /// Bits per value of the compressed stream.
    pub bit_rate: f64,
    /// (method name, ssim, psnr) per mitigation method.
    pub methods: Vec<(&'static str, f64, f64)>,
}

/// The paper's small-scale dataset configurations, scaled to this host.
pub fn small_scale_cases() -> Vec<(DatasetKind, Vec<usize>, u64)> {
    vec![
        (DatasetKind::ClimateLike, vec![256, 512], 20),
        (DatasetKind::HurricaneLike, vec![50, 100, 100], 21),
        (DatasetKind::CosmologyLike, vec![64, 64, 64], 22),
        (DatasetKind::CombustionLike, vec![64, 64, 64], 23),
    ]
}

/// The ε sweep of Figs. 5/6.
pub const EB_SWEEP: [f64; 5] = [1e-3, 2e-3, 5e-3, 1e-2, 2e-2];

/// Run the full sweep. `quick` limits to 3 bounds for smoke runs.
pub fn sweep(quick: bool) -> Vec<RdPoint> {
    let codecs: Vec<(&'static str, Box<dyn Compressor>)> =
        vec![("cuSZ", Box::new(CuszLike)), ("cuSZp2", Box::new(CuszpLike))];
    let bounds: Vec<f64> =
        if quick { vec![1e-3, 1e-2, 2e-2] } else { EB_SWEEP.to_vec() };

    let mut out = Vec::new();
    for (codec_name, codec) in &codecs {
        for (kind, dims, seed) in small_scale_cases() {
            let orig = generate(kind, &dims, seed);
            for &rel in &bounds {
                let eb = ErrorBound::relative(rel).resolve(&orig.data);
                let stream = codec.compress(&orig, eb).unwrap();
                let dec = codec.decompress(&stream).unwrap();

                // Shared handle: the request payload is a pointer
                // bump, and the decompressed field stays readable for
                // the baselines and metrics.
                let dq: crate::data::grid::SharedGrid<f32> = dec.grid.into();
                let request = MitigationRequest::new(dq.clone(), dec.quant_indices, eb);
                let ours = engine::execute(&request).expect("mitigation failed").output;
                let gauss = gaussian_filter(&dq, 1.0);
                let unif = uniform_filter(&dq);
                let wien = wiener_filter(&dq, eb.abs);

                let eval = |g: &crate::Grid<f32>| {
                    (ssim(&orig, g, 7, 2), psnr(&orig.data, &g.data))
                };
                let methods = vec![
                    ("quantized", eval(&*dq).0, eval(&*dq).1),
                    ("gaussian", eval(&gauss).0, eval(&gauss).1),
                    ("uniform", eval(&unif).0, eval(&unif).1),
                    ("wiener", eval(&wien).0, eval(&wien).1),
                    ("ours", eval(&ours).0, eval(&ours).1),
                ];
                out.push(RdPoint {
                    codec: codec_name,
                    dataset: kind.paper_name(),
                    rel_eb: rel,
                    bit_rate: bit_rate(stream.len(), orig.len()),
                    methods,
                });
            }
        }
    }
    out
}

/// Method value accessor.
pub fn method_value(p: &RdPoint, method: &str, use_ssim: bool) -> f64 {
    let (_, s, ps) = p.methods.iter().find(|(m, _, _)| *m == method).unwrap();
    if use_ssim {
        *s
    } else {
        *ps
    }
}
