//! Minimal statistical timing harness: warmup + N samples, reporting
//! mean / stddev / min, used by every `cargo bench` target.

use std::time::Instant;

/// Timing summary of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// Case label.
    pub name: String,
    /// Number of measured samples.
    pub samples: usize,
    /// Mean seconds per iteration.
    pub mean: f64,
    /// Sample standard deviation (seconds).
    pub stddev: f64,
    /// Fastest sample (seconds).
    pub min: f64,
}

impl BenchResult {
    /// Mean throughput in MB/s for `bytes` processed per iteration.
    pub fn mbs(&self, bytes: usize) -> f64 {
        bytes as f64 / 1e6 / self.mean.max(1e-12)
    }
}

impl std::fmt::Display for BenchResult {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<44} {:>10.4} ms ±{:>8.4} ms (min {:>10.4} ms, n={})",
            self.name,
            self.mean * 1e3,
            self.stddev * 1e3,
            self.min * 1e3,
            self.samples
        )
    }
}

/// Time `f` with `warmup` unmeasured runs then `samples` measured runs.
/// Prints the summary line and returns it.
pub fn bench_fn<T>(name: &str, warmup: usize, samples: usize, mut f: impl FnMut() -> T) -> BenchResult {
    assert!(samples > 0);
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut times = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t0 = Instant::now();
        std::hint::black_box(f());
        times.push(t0.elapsed().as_secs_f64());
    }
    let mean = times.iter().sum::<f64>() / samples as f64;
    let var = if samples > 1 {
        times.iter().map(|t| (t - mean).powi(2)).sum::<f64>() / (samples - 1) as f64
    } else {
        0.0
    };
    let result = BenchResult {
        name: name.to_string(),
        samples,
        mean,
        stddev: var.sqrt(),
        min: times.iter().cloned().fold(f64::INFINITY, f64::min),
    };
    println!("{result}");
    result
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reports_sane_statistics() {
        let r = bench_fn("noop", 1, 5, || 1 + 1);
        assert_eq!(r.samples, 5);
        assert!(r.mean >= 0.0 && r.min <= r.mean + 1e-12);
    }

    #[test]
    fn throughput_computation() {
        let r = BenchResult { name: "x".into(), samples: 1, mean: 0.5, stddev: 0.0, min: 0.5 };
        assert_eq!(r.mbs(1_000_000), 2.0);
    }
}
