//! Trajectory-file append shared by the benches.
//!
//! Each bench keeps a `BENCH_*.json` file at the repo root holding an
//! array of run records — one JSON object per invocation — so CI and
//! humans can track performance over time. The dependency tree has no
//! serde, so the append is plain string surgery on the array brackets.

/// Append `record` (a complete JSON object, no trailing comma) to the
/// JSON array in `path`, creating the file if needed.
///
/// Three existing shapes are handled: a fresh/empty file becomes a
/// one-element array, an existing array grows by one element, and a
/// legacy single-object file (written before the format became an
/// array) is wrapped into an array first. Prints an
/// `appended run record to {path}` confirmation line on success.
///
/// # Panics
///
/// Panics if the file cannot be written.
pub fn append_json_record(path: &str, record: &str) {
    let existing = std::fs::read_to_string(path).unwrap_or_default();
    let trimmed = existing.trim();
    let json = if trimmed.is_empty() {
        format!("[\n{record}\n]\n")
    } else if let Some(body) =
        trimmed.strip_prefix('[').and_then(|s| s.strip_suffix(']')).map(str::trim)
    {
        if body.is_empty() {
            format!("[\n{record}\n]\n")
        } else {
            format!("[\n{body},\n{record}\n]\n")
        }
    } else {
        format!("[\n{trimmed},\n{record}\n]\n")
    };
    std::fs::write(path, &json).unwrap_or_else(|e| panic!("write {path}: {e}"));
    println!("appended run record to {path}");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn append_covers_fresh_array_and_legacy_shapes() {
        let dir = std::env::temp_dir().join(format!("qai_json_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("traj.json");
        let path = path.to_str().unwrap();

        // Fresh file -> one-element array.
        let _ = std::fs::remove_file(path);
        append_json_record(path, "{\"a\": 1}");
        assert_eq!(std::fs::read_to_string(path).unwrap(), "[\n{\"a\": 1}\n]\n");

        // Existing array -> grows by one element.
        append_json_record(path, "{\"b\": 2}");
        assert_eq!(std::fs::read_to_string(path).unwrap(), "[\n{\"a\": 1},\n{\"b\": 2}\n]\n");

        // Legacy single-object file -> wrapped into an array.
        std::fs::write(path, "{\"old\": true}\n").unwrap();
        append_json_record(path, "{\"c\": 3}");
        assert_eq!(std::fs::read_to_string(path).unwrap(), "[\n{\"old\": true},\n{\"c\": 3}\n]\n");

        let _ = std::fs::remove_file(path);
        let _ = std::fs::remove_dir(&dir);
    }
}
