//! Aligned table / series printers so each bench regenerates its paper
//! table or figure as text rows (EXPERIMENTS.md copies them verbatim).

/// A simple column-aligned table.
#[derive(Debug, Default)]
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// New table with the given column headers.
    pub fn new(headers: &[&str]) -> Self {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    /// Append a row (must match the header count).
    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells.to_vec());
    }

    /// Render to a string.
    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (c, cell) in row.iter().enumerate() {
                widths[c] = widths[c].max(cell.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| {
            let mut line = String::new();
            for c in 0..ncol {
                line.push_str(&format!("{:<w$}  ", cells[c], w = widths[c]));
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (ncol - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    /// Print with a title banner.
    pub fn print(&self, title: &str) {
        println!("\n=== {title} ===");
        print!("{}", self.render());
    }
}

/// Print a table with a title (one-shot convenience).
pub fn print_table(title: &str, headers: &[&str], rows: &[Vec<String>]) {
    let mut t = Table::new(headers);
    for r in rows {
        t.row(r);
    }
    t.print(title);
}

/// Print an x/y series (one figure curve) as aligned pairs.
pub fn print_series(title: &str, x_label: &str, y_label: &str, points: &[(f64, f64)]) {
    println!("\n--- {title} ---");
    println!("{x_label:>14}  {y_label:>14}");
    for &(x, y) in points {
        println!("{x:>14.6}  {y:>14.6}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned_columns() {
        let mut t = Table::new(&["name", "value"]);
        t.row(&["a".into(), "1".into()]);
        t.row(&["longer-name".into(), "2.5".into()]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("name"));
        assert!(lines[3].starts_with("longer-name"));
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn rejects_mismatched_rows() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["only-one".into()]);
    }
}
