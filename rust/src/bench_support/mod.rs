//! Offline benchmark harness (criterion replacement, DESIGN.md §10) and
//! table/series printers shared by the per-figure benches.

pub mod harness;
pub mod json;
pub mod rd;
pub mod tables;

pub use harness::{bench_fn, BenchResult};
pub use json::append_json_record;
pub use tables::{print_series, print_table, Table};
