//! Length-prefixed wire framing and message codec for the cluster
//! subsystem.
//!
//! Every byte that crosses a socket is a **frame**: a little-endian
//! `u32` length prefix followed by that many payload bytes (bounded by
//! [`MAX_FRAME_BYTES`] — an oversized prefix is rejected *before* any
//! allocation). Inside a frame sits exactly one [`Message`], encoded
//! with the same hand-rolled little-endian discipline as the
//! coordinator's [`Pod`](crate::coordinator::transport::Pod) slices —
//! no external serialization crates (the build is offline).
//!
//! Decoding is **total**: any input — truncated, oversized, wrong
//! magic, wrong protocol version, unknown tag, trailing garbage —
//! produces a typed [`WireError`], never a panic and never an unbounded
//! read (the property tests in `rust/tests/cluster.rs` fuzz this
//! contract).
//!
//! Deadlines never serialize as absolute instants: a request's
//! `deadline` field crosses the wire as the *remaining budget* at send
//! time (the sender subtracts time already burned), and the receiving
//! engine re-anchors it at its own enqueue instant — the same
//! from-submission semantics the local path has always had.

#![deny(missing_docs)]

use crate::coordinator::strategy::Strategy;
use crate::coordinator::transport::Pod;
use crate::data::grid::{Grid, SharedGrid};
use crate::mitigation::admission::{Priority, SubmitError};
use crate::mitigation::engine::{MitigationRequest, MitigationResponse};
use crate::mitigation::pipeline::{Backend, MitigationConfig};
use crate::mitigation::quality::QualityTarget;
use crate::mitigation::service::Job;
use crate::mitigation::tiled::TiledConfig;
use crate::quant::{QIndex, ResolvedBound};
use std::io::{Read, Write};
use std::time::Duration;

/// Wire protocol version; bumped on any incompatible layout change.
/// Handshakes carrying any other version fail with
/// [`WireError::VersionMismatch`].
pub const PROTOCOL_VERSION: u32 = 1;

/// Magic bytes opening every handshake payload.
pub const MAGIC: [u8; 4] = *b"QAIC";

/// Upper bound on a single frame's payload (1 GiB). A length prefix
/// above this is rejected before any buffer is allocated.
pub const MAX_FRAME_BYTES: usize = 1 << 30;

/// Typed decode/transport-framing failure. Every malformed input maps
/// to one of these — the codec never panics and never hangs.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum WireError {
    /// Clean end-of-stream at a frame boundary (the peer closed).
    Eof,
    /// The input ended inside a frame or field.
    Truncated {
        /// Bytes the decoder needed.
        needed: usize,
        /// Bytes actually available.
        got: usize,
    },
    /// A length prefix exceeded [`MAX_FRAME_BYTES`].
    Oversized {
        /// The claimed length.
        len: u64,
        /// The enforced maximum.
        max: u64,
    },
    /// A handshake did not open with [`MAGIC`].
    BadMagic(
        /// The four bytes found instead.
        [u8; 4],
    ),
    /// The peer speaks a different protocol version.
    VersionMismatch {
        /// Our [`PROTOCOL_VERSION`].
        ours: u32,
        /// The version the peer sent.
        theirs: u32,
    },
    /// Unknown message tag byte.
    BadTag(
        /// The tag found.
        u8,
    ),
    /// A message decoded cleanly but left unconsumed bytes.
    TrailingBytes {
        /// How many bytes were left over.
        extra: usize,
    },
    /// A field held a structurally invalid value (named).
    BadPayload(
        /// What was wrong.
        &'static str,
    ),
    /// An underlying socket read/write failed.
    Io(
        /// The I/O error, stringified (keeps `WireError: Clone + Eq`).
        String,
    ),
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Eof => write!(f, "end of stream"),
            WireError::Truncated { needed, got } => {
                write!(f, "truncated frame: needed {needed} bytes, got {got}")
            }
            WireError::Oversized { len, max } => {
                write!(f, "oversized length prefix: {len} > max {max}")
            }
            WireError::BadMagic(m) => write!(f, "bad handshake magic {m:?}"),
            WireError::VersionMismatch { ours, theirs } => {
                write!(f, "protocol version mismatch: ours {ours}, theirs {theirs}")
            }
            WireError::BadTag(t) => write!(f, "unknown message tag {t}"),
            WireError::TrailingBytes { extra } => {
                write!(f, "{extra} trailing bytes after message")
            }
            WireError::BadPayload(what) => write!(f, "bad payload: {what}"),
            WireError::Io(e) => write!(f, "io: {e}"),
        }
    }
}

impl std::error::Error for WireError {}

// ---------------------------------------------------------------------
// Frame layer
// ---------------------------------------------------------------------

/// Write one length-prefixed frame (flushes the writer).
pub fn write_frame(w: &mut impl Write, payload: &[u8]) -> Result<(), WireError> {
    if payload.len() > MAX_FRAME_BYTES {
        return Err(WireError::Oversized {
            len: payload.len() as u64,
            max: MAX_FRAME_BYTES as u64,
        });
    }
    let io = |e: std::io::Error| WireError::Io(e.to_string());
    w.write_all(&(payload.len() as u32).to_le_bytes()).map_err(io)?;
    w.write_all(payload).map_err(io)?;
    w.flush().map_err(io)
}

/// Read one length-prefixed frame. A clean close at a frame boundary is
/// [`WireError::Eof`]; a close mid-frame is [`WireError::Truncated`];
/// a length prefix above [`MAX_FRAME_BYTES`] is rejected before any
/// allocation.
pub fn read_frame(r: &mut impl Read) -> Result<Vec<u8>, WireError> {
    let mut len4 = [0u8; 4];
    // First byte separately, to tell a clean EOF from a torn prefix.
    let mut first = [0u8; 1];
    loop {
        match r.read(&mut first) {
            Ok(0) => return Err(WireError::Eof),
            Ok(_) => break,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(WireError::Io(e.to_string())),
        }
    }
    len4[0] = first[0];
    read_exact_or(r, &mut len4[1..], 4, 1)?;
    let len = u32::from_le_bytes(len4) as u64;
    if len > MAX_FRAME_BYTES as u64 {
        return Err(WireError::Oversized { len, max: MAX_FRAME_BYTES as u64 });
    }
    let mut buf = vec![0u8; len as usize];
    read_exact_or(r, &mut buf, len as usize, 0)?;
    Ok(buf)
}

fn read_exact_or(
    r: &mut impl Read,
    buf: &mut [u8],
    needed: usize,
    already: usize,
) -> Result<(), WireError> {
    match r.read_exact(buf) {
        Ok(()) => Ok(()),
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => {
            Err(WireError::Truncated { needed, got: already })
        }
        Err(e) => Err(WireError::Io(e.to_string())),
    }
}

// ---------------------------------------------------------------------
// Field-level encoder/decoder
// ---------------------------------------------------------------------

/// Little-endian field writer backing [`encode_message`].
#[derive(Default)]
struct Enc {
    buf: Vec<u8>,
}

impl Enc {
    fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }
    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn boolean(&mut self, v: bool) {
        self.u8(u8::from(v));
    }
    fn opt_u64(&mut self, v: Option<u64>) {
        match v {
            Some(x) => {
                self.u8(1);
                self.u64(x);
            }
            None => self.u8(0),
        }
    }
    fn opt_f64(&mut self, v: Option<f64>) {
        match v {
            Some(x) => {
                self.u8(1);
                self.f64(x);
            }
            None => self.u8(0),
        }
    }
    fn blob(&mut self, bytes: &[u8]) {
        self.u64(bytes.len() as u64);
        self.buf.extend_from_slice(bytes);
    }
    fn string(&mut self, s: &str) {
        self.blob(s.as_bytes());
    }
    fn opt_string(&mut self, s: Option<&str>) {
        match s {
            Some(s) => {
                self.u8(1);
                self.string(s);
            }
            None => self.u8(0),
        }
    }
    fn duration(&mut self, d: Duration) {
        self.u64(d.as_nanos().min(u128::from(u64::MAX)) as u64);
    }
    fn opt_duration(&mut self, d: Option<Duration>) {
        match d {
            Some(d) => {
                self.u8(1);
                self.duration(d);
            }
            None => self.u8(0),
        }
    }
    fn grid<T: WireElem>(&mut self, g: &Grid<T>) {
        self.u8(g.shape.ndim as u8);
        for d in g.shape.dims {
            self.u64(d as u64);
        }
        self.blob(&T::encode(&g.data));
    }
}

/// Little-endian field reader backing [`decode_message`]. Every read is
/// bounds-checked; exhaustion yields [`WireError::Truncated`].
struct Dec<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Dec { buf, pos: 0 }
    }
    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }
    fn take(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        if n > self.remaining() {
            return Err(WireError::Truncated { needed: n, got: self.remaining() });
        }
        let out = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(out)
    }
    fn u8(&mut self) -> Result<u8, WireError> {
        Ok(self.take(1)?[0])
    }
    fn u32(&mut self) -> Result<u32, WireError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> Result<u64, WireError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn f64(&mut self) -> Result<f64, WireError> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn boolean(&mut self) -> Result<bool, WireError> {
        match self.u8()? {
            0 => Ok(false),
            1 => Ok(true),
            _ => Err(WireError::BadPayload("bool flag not 0/1")),
        }
    }
    fn flag(&mut self) -> Result<bool, WireError> {
        self.boolean()
    }
    fn opt_u64(&mut self) -> Result<Option<u64>, WireError> {
        Ok(if self.flag()? { Some(self.u64()?) } else { None })
    }
    fn opt_f64(&mut self) -> Result<Option<f64>, WireError> {
        Ok(if self.flag()? { Some(self.f64()?) } else { None })
    }
    fn blob(&mut self) -> Result<&'a [u8], WireError> {
        let len = self.u64()?;
        if len > MAX_FRAME_BYTES as u64 {
            return Err(WireError::Oversized { len, max: MAX_FRAME_BYTES as u64 });
        }
        self.take(len as usize)
    }
    fn string(&mut self) -> Result<String, WireError> {
        let bytes = self.blob()?;
        String::from_utf8(bytes.to_vec())
            .map_err(|_| WireError::BadPayload("string is not UTF-8"))
    }
    fn opt_string(&mut self) -> Result<Option<String>, WireError> {
        Ok(if self.flag()? { Some(self.string()?) } else { None })
    }
    fn duration(&mut self) -> Result<Duration, WireError> {
        Ok(Duration::from_nanos(self.u64()?))
    }
    fn opt_duration(&mut self) -> Result<Option<Duration>, WireError> {
        Ok(if self.flag()? { Some(self.duration()?) } else { None })
    }
    fn grid<T: WireElem>(&mut self) -> Result<Grid<T>, WireError> {
        let ndim = self.u8()? as usize;
        if !(1..=3).contains(&ndim) {
            return Err(WireError::BadPayload("grid ndim not in 1..=3"));
        }
        let mut dims = [0usize; 3];
        for d in &mut dims {
            let v = self.u64()?;
            if v == 0 || v > MAX_FRAME_BYTES as u64 {
                return Err(WireError::BadPayload("grid dim zero or absurd"));
            }
            *d = v as usize;
        }
        // Leading axes beyond the declared ndim must be the normalized 1s.
        if dims[..3 - ndim].iter().any(|&d| d != 1) {
            return Err(WireError::BadPayload("grid leading dims not normalized"));
        }
        let elems = dims[0]
            .checked_mul(dims[1])
            .and_then(|p| p.checked_mul(dims[2]))
            .ok_or(WireError::BadPayload("grid dims overflow"))?;
        let bytes = self.blob()?;
        if bytes.len() != elems * T::SIZE {
            return Err(WireError::BadPayload("grid data length mismatch"));
        }
        Ok(Grid::from_vec(T::decode(bytes), &dims[3 - ndim..]))
    }
    fn finish(self) -> Result<(), WireError> {
        if self.remaining() != 0 {
            return Err(WireError::TrailingBytes { extra: self.remaining() });
        }
        Ok(())
    }
}

/// [`Pod`] elements with a statically known wire width — the two grid
/// element types the cluster actually ships.
trait WireElem: Pod {
    /// Bytes per element on the wire.
    const SIZE: usize;
}
impl WireElem for f32 {
    const SIZE: usize = 4;
}
impl WireElem for i64 {
    const SIZE: usize = 8;
}

// ---------------------------------------------------------------------
// Messages
// ---------------------------------------------------------------------

/// Handshake payload: who is speaking and which protocol they speak.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Handshake {
    /// The sender's cluster node id.
    pub node_id: u64,
    /// The sender's [`PROTOCOL_VERSION`].
    pub version: u32,
}

/// Typed rejection category mirrored from
/// [`SubmitError`](crate::mitigation::admission::SubmitError) (plus
/// [`RejectKind::Failed`] for execution errors) so remote callers see
/// the same taxonomy local callers do.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RejectKind {
    /// The routed shard's bounded queue was full.
    QueueFull,
    /// A blocking submit exhausted its timeout.
    Timeout,
    /// The remote engine is shutting down.
    Shutdown,
    /// The tenant was at quota on the remote node.
    QuotaExceeded,
    /// The remote node shed the request: its service-time estimate
    /// proved the (re-anchored) remaining deadline budget infeasible.
    DeadlineInfeasible,
    /// The job was admitted but its execution failed.
    Failed,
}

impl RejectKind {
    /// Map a local [`SubmitError`] to its wire category.
    pub fn from_submit(e: &SubmitError) -> RejectKind {
        match e {
            SubmitError::QueueFull(_) => RejectKind::QueueFull,
            SubmitError::Timeout(_) => RejectKind::Timeout,
            SubmitError::Shutdown(_) => RejectKind::Shutdown,
            SubmitError::QuotaExceeded(_) => RejectKind::QuotaExceeded,
            SubmitError::DeadlineInfeasible(_) => RejectKind::DeadlineInfeasible,
        }
    }
    fn code(self) -> u8 {
        match self {
            RejectKind::QueueFull => 1,
            RejectKind::Timeout => 2,
            RejectKind::Shutdown => 3,
            RejectKind::QuotaExceeded => 4,
            RejectKind::DeadlineInfeasible => 5,
            RejectKind::Failed => 6,
        }
    }
    fn from_code(c: u8) -> Result<RejectKind, WireError> {
        Ok(match c {
            1 => RejectKind::QueueFull,
            2 => RejectKind::Timeout,
            3 => RejectKind::Shutdown,
            4 => RejectKind::QuotaExceeded,
            5 => RejectKind::DeadlineInfeasible,
            6 => RejectKind::Failed,
            _ => return Err(WireError::BadPayload("unknown reject kind")),
        })
    }
}

/// Outcome of a remotely executed request: a full response, or a typed
/// rejection. Per-step [`PipelineStats`](crate::mitigation::pipeline::PipelineStats)
/// are not transported — a remote response always carries `stats: None`.
#[derive(Debug)]
pub enum RemoteOutcome {
    /// The request ran; here is its response.
    Ok(MitigationResponse),
    /// The request was rejected or failed on the remote node.
    Rejected {
        /// Typed category (shed, quota, queue-full, …).
        kind: RejectKind,
        /// Human-readable detail from the remote error.
        message: String,
    },
}

/// Per-rank work order for a forked multi-process distributed run
/// (`qai rank-worker`, spawned by
/// [`run_distributed_procs`](crate::cluster::procs::run_distributed_procs)).
#[derive(Debug)]
pub struct RankSetup {
    /// This worker's rank.
    pub rank: u64,
    /// World size.
    pub n_ranks: u64,
    /// Parallelization strategy to run.
    pub strategy: Strategy,
    /// Compensation factor η.
    pub eta: f64,
    /// Shared-memory threads per rank.
    pub threads: u64,
    /// Resolved error bound.
    pub eb: ResolvedBound,
    /// Global field dims (normalized to 3).
    pub shape_dims: [u64; 3],
    /// Global field declared dimensionality.
    pub shape_ndim: u8,
    /// The rank's local dequantized block.
    pub dq: Grid<f32>,
    /// The rank's local quantization-index block.
    pub q: Grid<QIndex>,
    /// Rank-indexed mesh listen addresses for peer-to-peer halo
    /// connections.
    pub mesh: Vec<String>,
}

/// A rank worker's result, returned to the forking driver.
#[derive(Debug)]
pub struct RankResult {
    /// The reporting rank.
    pub rank: u64,
    /// Nanoseconds this rank spent inside transport send/recv — the
    /// *measured* communication time fig11 reports instead of the
    /// analytic `CommModel`.
    pub comm_nanos: u64,
    /// Wire bytes this rank sent over the mesh (frame payload + prefix).
    pub sent_bytes: u64,
    /// Mesh messages this rank sent.
    pub sent_msgs: u64,
    /// Wire bytes this rank received.
    pub recv_bytes: u64,
    /// Mesh messages this rank received.
    pub recv_msgs: u64,
    /// The rank's compensated local block.
    pub out: Grid<f32>,
}

/// One frame's payload: everything that crosses a cluster socket.
#[derive(Debug)]
pub enum Message {
    /// Client → server handshake.
    Hello(Handshake),
    /// Server → client handshake reply, with the node ids the server
    /// currently knows about.
    Welcome {
        /// The server's node id.
        node_id: u64,
        /// The server's protocol version.
        version: u32,
        /// Node ids known to the server (including itself).
        nodes: Vec<u64>,
    },
    /// A mitigation request forwarded to a remote shard. The embedded
    /// request's `deadline` holds the **remaining budget at send
    /// time**, never an absolute instant; the receiver re-anchors it
    /// at its own enqueue.
    Request {
        /// Per-connection correlation id.
        req_id: u64,
        /// The request payload (grids, config, priority, tenant,
        /// trace id — the trace id is preserved across the wire).
        request: Box<MitigationRequest>,
    },
    /// The matching reply for a [`Message::Request`].
    Response {
        /// Correlation id of the request this answers.
        req_id: u64,
        /// Response or typed rejection.
        outcome: Box<RemoteOutcome>,
    },
    /// Ask the receiving server to stop accepting and exit.
    Shutdown,
    /// Raw tagged rank-mesh traffic (halo planes, gather/scatter
    /// slices) — the socket twin of the in-process fabric's messages.
    Tagged {
        /// MPI-style message tag.
        tag: u64,
        /// Opaque payload ([`Pod`]-encoded by the caller).
        data: Vec<u8>,
    },
    /// Rank-worker introduction (to the driver: carries the worker's
    /// mesh listen address; between workers: identifies the connecting
    /// rank, `mesh_addr` empty).
    RankHello {
        /// The introducing rank.
        rank: u64,
        /// The rank's mesh listener address ("" on peer connections).
        mesh_addr: String,
    },
    /// Driver → rank work order.
    RankSetup(
        /// The order (boxed: it embeds the rank's data blocks).
        Box<RankSetup>,
    ),
    /// Rank → driver result.
    RankResult(
        /// The result (boxed: it embeds the output block).
        Box<RankResult>,
    ),
}

const TAG_HELLO: u8 = 1;
const TAG_WELCOME: u8 = 2;
const TAG_REQUEST: u8 = 3;
const TAG_RESPONSE: u8 = 4;
const TAG_SHUTDOWN: u8 = 5;
const TAG_TAGGED: u8 = 6;
const TAG_RANK_HELLO: u8 = 7;
const TAG_RANK_SETUP: u8 = 8;
const TAG_RANK_RESULT: u8 = 9;

fn priority_code(p: Priority) -> u8 {
    match p {
        Priority::Bulk => 0,
        Priority::Interactive => 1,
    }
}

fn priority_from(c: u8) -> Result<Priority, WireError> {
    match c {
        0 => Ok(Priority::Bulk),
        1 => Ok(Priority::Interactive),
        _ => Err(WireError::BadPayload("unknown priority code")),
    }
}

fn backend_code(b: Backend) -> u8 {
    match b {
        Backend::Native => 0,
        Backend::Pjrt => 1,
    }
}

fn backend_from(c: u8) -> Result<Backend, WireError> {
    match c {
        0 => Ok(Backend::Native),
        1 => Ok(Backend::Pjrt),
        _ => Err(WireError::BadPayload("unknown backend code")),
    }
}

fn strategy_code(s: Strategy) -> u8 {
    match s {
        Strategy::Embarrassing => 0,
        Strategy::Exact => 1,
        Strategy::Approximate => 2,
    }
}

fn strategy_from(c: u8) -> Result<Strategy, WireError> {
    match c {
        0 => Ok(Strategy::Embarrassing),
        1 => Ok(Strategy::Exact),
        2 => Ok(Strategy::Approximate),
        _ => Err(WireError::BadPayload("unknown strategy code")),
    }
}

fn enc_handshake(e: &mut Enc, node_id: u64, version: u32) {
    e.buf.extend_from_slice(&MAGIC);
    e.u32(version);
    e.u64(node_id);
}

fn dec_handshake(d: &mut Dec) -> Result<Handshake, WireError> {
    let magic: [u8; 4] = d.take(4)?.try_into().unwrap();
    if magic != MAGIC {
        return Err(WireError::BadMagic(magic));
    }
    let version = d.u32()?;
    if version != PROTOCOL_VERSION {
        return Err(WireError::VersionMismatch { ours: PROTOCOL_VERSION, theirs: version });
    }
    let node_id = d.u64()?;
    Ok(Handshake { node_id, version })
}

fn enc_request(e: &mut Enc, r: &MitigationRequest) {
    e.u64(r.trace_id);
    e.u8(priority_code(r.priority));
    e.opt_duration(r.deadline);
    e.opt_duration(r.timeout);
    e.opt_string(r.tenant.as_deref());
    e.boolean(r.collect_stats);
    let cfg = r.job.cfg;
    e.f64(cfg.eta);
    e.u64(cfg.threads as u64);
    e.u8(backend_code(cfg.backend));
    e.opt_f64(cfg.taper_radius);
    e.f64(r.job.eb.abs);
    e.opt_f64(r.job.eb.rel);
    e.grid(&*r.job.dq);
    e.grid(&*r.job.q);
    match &r.job.reference {
        Some(g) => {
            e.u8(1);
            e.grid(&**g);
        }
        None => e.u8(0),
    }
    match r.job.target {
        None => e.u8(0),
        Some(QualityTarget::Psnr(v)) => {
            e.u8(1);
            e.f64(v);
        }
        Some(QualityTarget::Ssim(v)) => {
            e.u8(2);
            e.f64(v);
        }
    }
    match r.job.tiled {
        Some(t) => {
            e.u8(1);
            e.u8(t.tile.ndim as u8);
            for d in t.tile.dims {
                e.u64(d as u64);
            }
            e.u64(t.halo as u64);
        }
        None => e.u8(0),
    }
}

fn dec_request(d: &mut Dec) -> Result<MitigationRequest, WireError> {
    let trace_id = d.u64()?;
    let priority = priority_from(d.u8()?)?;
    let deadline = d.opt_duration()?;
    let timeout = d.opt_duration()?;
    let tenant = d.opt_string()?;
    let collect_stats = d.boolean()?;
    let eta = d.f64()?;
    let threads = d.u64()? as usize;
    let backend = backend_from(d.u8()?)?;
    let taper_radius = d.opt_f64()?;
    let cfg = MitigationConfig { eta, threads, backend, taper_radius };
    let abs = d.f64()?;
    let rel = d.opt_f64()?;
    let eb = ResolvedBound { abs, rel };
    let dq: Grid<f32> = d.grid()?;
    let q: Grid<QIndex> = d.grid()?;
    let reference = if d.flag()? { Some(SharedGrid::new(d.grid()?)) } else { None };
    let target = match d.u8()? {
        0 => None,
        1 => Some(QualityTarget::Psnr(d.f64()?)),
        2 => Some(QualityTarget::Ssim(d.f64()?)),
        _ => return Err(WireError::BadPayload("unknown quality-target code")),
    };
    let tiled = if d.flag()? {
        let ndim = d.u8()? as usize;
        if !(1..=3).contains(&ndim) {
            return Err(WireError::BadPayload("tile ndim not in 1..=3"));
        }
        let mut dims = [0usize; 3];
        for dim in &mut dims {
            let v = d.u64()?;
            if v == 0 || v > MAX_FRAME_BYTES as u64 {
                return Err(WireError::BadPayload("tile dim zero or absurd"));
            }
            *dim = v as usize;
        }
        let halo = d.u64()? as usize;
        Some(TiledConfig::new(&dims[3 - ndim..]).with_halo(halo))
    } else {
        None
    };
    let job = Job {
        dq: SharedGrid::new(dq),
        q: SharedGrid::new(q),
        eb,
        cfg,
        reference,
        target,
        tiled,
    };
    Ok(MitigationRequest { job, priority, deadline, timeout, tenant, collect_stats, trace_id })
}

fn enc_response(e: &mut Enc, r: &MitigationResponse) {
    e.u64(r.trace_id);
    e.u8(priority_code(r.priority));
    e.opt_u64(r.shard.map(|s| s as u64));
    e.opt_string(r.tenant.as_deref());
    e.opt_u64(r.seq);
    e.duration(r.queue_wait);
    e.duration(r.exec);
    e.opt_duration(r.deadline);
    e.boolean(r.deadline_missed);
    e.opt_f64(r.quality);
    e.grid(&r.output);
}

fn dec_response(d: &mut Dec) -> Result<MitigationResponse, WireError> {
    let trace_id = d.u64()?;
    let priority = priority_from(d.u8()?)?;
    let shard = d.opt_u64()?.map(|s| s as usize);
    let tenant = d.opt_string()?;
    let seq = d.opt_u64()?;
    let queue_wait = d.duration()?;
    let exec = d.duration()?;
    let deadline = d.opt_duration()?;
    let deadline_missed = d.boolean()?;
    let quality = d.opt_f64()?;
    let output: Grid<f32> = d.grid()?;
    Ok(MitigationResponse {
        output,
        stats: None,
        shard,
        tenant,
        seq,
        trace_id,
        priority,
        queue_wait,
        exec,
        deadline,
        deadline_missed,
        quality,
    })
}

/// Encode one [`Message`] into a frame payload.
pub fn encode_message(msg: &Message) -> Vec<u8> {
    let mut e = Enc::default();
    match msg {
        Message::Hello(h) => {
            e.u8(TAG_HELLO);
            enc_handshake(&mut e, h.node_id, h.version);
        }
        Message::Welcome { node_id, version, nodes } => {
            e.u8(TAG_WELCOME);
            enc_handshake(&mut e, *node_id, *version);
            e.u64(nodes.len() as u64);
            for n in nodes {
                e.u64(*n);
            }
        }
        Message::Request { req_id, request } => {
            e.u8(TAG_REQUEST);
            e.u64(*req_id);
            enc_request(&mut e, request);
        }
        Message::Response { req_id, outcome } => {
            e.u8(TAG_RESPONSE);
            e.u64(*req_id);
            match &**outcome {
                RemoteOutcome::Ok(resp) => {
                    e.u8(0);
                    enc_response(&mut e, resp);
                }
                RemoteOutcome::Rejected { kind, message } => {
                    e.u8(1);
                    e.u8(kind.code());
                    e.string(message);
                }
            }
        }
        Message::Shutdown => e.u8(TAG_SHUTDOWN),
        Message::Tagged { tag, data } => {
            e.u8(TAG_TAGGED);
            e.u64(*tag);
            e.blob(data);
        }
        Message::RankHello { rank, mesh_addr } => {
            e.u8(TAG_RANK_HELLO);
            e.u64(*rank);
            e.string(mesh_addr);
        }
        Message::RankSetup(s) => {
            e.u8(TAG_RANK_SETUP);
            e.u64(s.rank);
            e.u64(s.n_ranks);
            e.u8(strategy_code(s.strategy));
            e.f64(s.eta);
            e.u64(s.threads);
            e.f64(s.eb.abs);
            e.opt_f64(s.eb.rel);
            for d in s.shape_dims {
                e.u64(d);
            }
            e.u8(s.shape_ndim);
            e.grid(&s.dq);
            e.grid(&s.q);
            e.u64(s.mesh.len() as u64);
            for a in &s.mesh {
                e.string(a);
            }
        }
        Message::RankResult(r) => {
            e.u8(TAG_RANK_RESULT);
            e.u64(r.rank);
            e.u64(r.comm_nanos);
            e.u64(r.sent_bytes);
            e.u64(r.sent_msgs);
            e.u64(r.recv_bytes);
            e.u64(r.recv_msgs);
            e.grid(&r.out);
        }
    }
    e.buf
}

/// Decode one frame payload into a [`Message`]. Total: every malformed
/// input yields a typed [`WireError`].
pub fn decode_message(buf: &[u8]) -> Result<Message, WireError> {
    let mut d = Dec::new(buf);
    let tag = d.u8()?;
    let msg = match tag {
        TAG_HELLO => Message::Hello(dec_handshake(&mut d)?),
        TAG_WELCOME => {
            let h = dec_handshake(&mut d)?;
            let count = d.u64()?;
            if count > 1 << 20 {
                return Err(WireError::BadPayload("absurd node count"));
            }
            let mut nodes = Vec::with_capacity(count as usize);
            for _ in 0..count {
                nodes.push(d.u64()?);
            }
            Message::Welcome { node_id: h.node_id, version: h.version, nodes }
        }
        TAG_REQUEST => {
            let req_id = d.u64()?;
            let request = Box::new(dec_request(&mut d)?);
            Message::Request { req_id, request }
        }
        TAG_RESPONSE => {
            let req_id = d.u64()?;
            let outcome = match d.u8()? {
                0 => RemoteOutcome::Ok(dec_response(&mut d)?),
                1 => {
                    let kind = RejectKind::from_code(d.u8()?)?;
                    let message = d.string()?;
                    RemoteOutcome::Rejected { kind, message }
                }
                _ => return Err(WireError::BadPayload("unknown response status")),
            };
            Message::Response { req_id, outcome: Box::new(outcome) }
        }
        TAG_SHUTDOWN => Message::Shutdown,
        TAG_TAGGED => {
            let tag = d.u64()?;
            let data = d.blob()?.to_vec();
            Message::Tagged { tag, data }
        }
        TAG_RANK_HELLO => {
            let rank = d.u64()?;
            let mesh_addr = d.string()?;
            Message::RankHello { rank, mesh_addr }
        }
        TAG_RANK_SETUP => {
            let rank = d.u64()?;
            let n_ranks = d.u64()?;
            let strategy = strategy_from(d.u8()?)?;
            let eta = d.f64()?;
            let threads = d.u64()?;
            let abs = d.f64()?;
            let rel = d.opt_f64()?;
            let mut shape_dims = [0u64; 3];
            for sd in &mut shape_dims {
                *sd = d.u64()?;
            }
            let shape_ndim = d.u8()?;
            let dq: Grid<f32> = d.grid()?;
            let q: Grid<QIndex> = d.grid()?;
            let count = d.u64()?;
            if count > 1 << 20 {
                return Err(WireError::BadPayload("absurd mesh size"));
            }
            let mut mesh = Vec::with_capacity(count as usize);
            for _ in 0..count {
                mesh.push(d.string()?);
            }
            Message::RankSetup(Box::new(RankSetup {
                rank,
                n_ranks,
                strategy,
                eta,
                threads,
                eb: ResolvedBound { abs, rel },
                shape_dims,
                shape_ndim,
                dq,
                q,
                mesh,
            }))
        }
        TAG_RANK_RESULT => {
            let rank = d.u64()?;
            let comm_nanos = d.u64()?;
            let sent_bytes = d.u64()?;
            let sent_msgs = d.u64()?;
            let recv_bytes = d.u64()?;
            let recv_msgs = d.u64()?;
            let out: Grid<f32> = d.grid()?;
            Message::RankResult(Box::new(RankResult {
                rank,
                comm_nanos,
                sent_bytes,
                sent_msgs,
                recv_bytes,
                recv_msgs,
                out,
            }))
        }
        other => return Err(WireError::BadTag(other)),
    };
    d.finish()?;
    Ok(msg)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frame_roundtrip_and_eof() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"hello").unwrap();
        write_frame(&mut buf, b"").unwrap();
        let mut cur = std::io::Cursor::new(buf);
        assert_eq!(read_frame(&mut cur).unwrap(), b"hello");
        assert_eq!(read_frame(&mut cur).unwrap(), b"");
        assert_eq!(read_frame(&mut cur), Err(WireError::Eof));
    }

    #[test]
    fn oversized_prefix_rejected_before_allocation() {
        let bytes = (u32::MAX).to_le_bytes().to_vec();
        let mut cur = std::io::Cursor::new(bytes);
        match read_frame(&mut cur) {
            Err(WireError::Oversized { len, .. }) => assert_eq!(len, u64::from(u32::MAX)),
            other => panic!("expected Oversized, got {other:?}"),
        }
    }

    #[test]
    fn handshake_version_mismatch_is_typed() {
        let hello =
            encode_message(&Message::Hello(Handshake { node_id: 7, version: PROTOCOL_VERSION }));
        // Corrupt the version field (bytes 5..9 after tag + magic).
        let mut bad = hello.clone();
        bad[5] = PROTOCOL_VERSION as u8 + 1;
        match decode_message(&bad) {
            Err(WireError::VersionMismatch { ours, theirs }) => {
                assert_eq!(ours, PROTOCOL_VERSION);
                assert_eq!(theirs, u32::from(PROTOCOL_VERSION as u8 + 1));
            }
            other => panic!("expected VersionMismatch, got {other:?}"),
        }
        // The clean one still decodes.
        match decode_message(&hello).unwrap() {
            Message::Hello(h) => assert_eq!(h.node_id, 7),
            other => panic!("expected Hello, got {other:?}"),
        }
    }

    #[test]
    fn tagged_roundtrip() {
        let msg = Message::Tagged { tag: 42, data: vec![1, 2, 3] };
        match decode_message(&encode_message(&msg)).unwrap() {
            Message::Tagged { tag, data } => {
                assert_eq!(tag, 42);
                assert_eq!(data, vec![1, 2, 3]);
            }
            other => panic!("unexpected {other:?}"),
        }
    }
}
