//! The pluggable [`Transport`] trait and its socket implementation.
//!
//! Rank-level distributed mitigation (`coordinator::{strategy,halo}`)
//! talks to peers through `&mut dyn Transport`. Two implementations
//! exist:
//!
//! * the in-process loopback — the coordinator's
//!   [`Endpoint`](crate::coordinator::transport::Endpoint) mailbox,
//!   adapted behind the trait with **bit-identical** behavior (the
//!   trait methods delegate to the same inherent `send`/`recv` the
//!   fabric always had);
//! * [`SocketTransport`] — length-prefixed frames
//!   ([`wire`](crate::cluster::wire)) over TCP or Unix-domain
//!   sockets, one duplex stream per peer, with per-peer send/recv
//!   byte/message counters and a wall-clock communication timer that
//!   feeds fig11's measured comm breakdown.
//!
//! [`ClusterListener`] / [`connect_backoff`] are the shared
//! accept/connect plumbing (also used by the node-level
//! [`ClusterServer`](crate::cluster::node::ClusterServer)).

#![deny(missing_docs)]

use crate::cluster::wire::{
    decode_message, encode_message, read_frame, write_frame, Message, WireError,
};
use crate::coordinator::transport::Pod;
use std::io::{self, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Immutable snapshot of one peer's traffic counters.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PeerCounters {
    /// Peer identifier (rank or node id, depending on the scope).
    pub peer: u64,
    /// Wire bytes sent to this peer (frame payload + length prefix).
    pub sent_bytes: u64,
    /// Messages sent to this peer.
    pub sent_msgs: u64,
    /// Wire bytes received from this peer.
    pub recv_bytes: u64,
    /// Messages received from this peer.
    pub recv_msgs: u64,
}

/// Shared mutable per-peer counter cell (atomics; cloned into reader
/// threads).
#[derive(Debug, Default)]
pub struct CounterCell {
    sent_bytes: AtomicU64,
    sent_msgs: AtomicU64,
    recv_bytes: AtomicU64,
    recv_msgs: AtomicU64,
}

impl CounterCell {
    /// Record one sent message of `bytes` wire bytes.
    pub fn note_sent(&self, bytes: u64) {
        self.sent_bytes.fetch_add(bytes, Ordering::Relaxed);
        self.sent_msgs.fetch_add(1, Ordering::Relaxed);
    }
    /// Record one received message of `bytes` wire bytes.
    pub fn note_recv(&self, bytes: u64) {
        self.recv_bytes.fetch_add(bytes, Ordering::Relaxed);
        self.recv_msgs.fetch_add(1, Ordering::Relaxed);
    }
    /// Snapshot the cell for peer id `peer`.
    pub fn snapshot(&self, peer: u64) -> PeerCounters {
        PeerCounters {
            peer,
            sent_bytes: self.sent_bytes.load(Ordering::Relaxed),
            sent_msgs: self.sent_msgs.load(Ordering::Relaxed),
            recv_bytes: self.recv_bytes.load(Ordering::Relaxed),
            recv_msgs: self.recv_msgs.load(Ordering::Relaxed),
        }
    }
}

/// Typed transport failure. The loopback implementation never returns
/// these (its mailbox panics on fabric teardown exactly as before);
/// the socket implementation surfaces peer loss and codec faults.
#[derive(Debug)]
pub enum TransportError {
    /// The stream to `peer` was closed.
    Closed {
        /// The lost peer's rank.
        peer: usize,
    },
    /// A socket operation failed.
    Io {
        /// The peer whose stream failed.
        peer: usize,
        /// Stringified I/O error.
        detail: String,
    },
    /// The peer sent bytes the codec rejected.
    Codec(
        /// The underlying codec error.
        WireError,
    ),
}

impl std::fmt::Display for TransportError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TransportError::Closed { peer } => write!(f, "peer {peer} closed the stream"),
            TransportError::Io { peer, detail } => write!(f, "io with peer {peer}: {detail}"),
            TransportError::Codec(e) => write!(f, "codec: {e}"),
        }
    }
}

impl std::error::Error for TransportError {}

/// Object-safe rank-to-rank message transport. `coordinator`'s halo
/// exchange and gather/scatter strategies are written against
/// `&mut dyn Transport`, so the same numerics run over the in-process
/// fabric and over sockets.
pub trait Transport: Send {
    /// This endpoint's rank.
    fn rank(&self) -> usize;
    /// World size.
    fn n_ranks(&self) -> usize;
    /// Send `payload` to rank `to` under message `tag`.
    fn send(&mut self, to: usize, tag: u64, payload: Vec<u8>) -> Result<(), TransportError>;
    /// Receive the next payload from rank `from` under `tag`
    /// (out-of-order arrivals are buffered, MPI-style).
    fn recv(&mut self, from: usize, tag: u64) -> Result<Vec<u8>, TransportError>;
    /// Per-peer traffic counter snapshots.
    fn counters(&self) -> Vec<PeerCounters>;
    /// Nanoseconds spent inside send/recv so far (0 where not tracked).
    fn comm_nanos(&self) -> u64 {
        0
    }
}

/// Typed-slice helpers over any [`Transport`] (a blanket extension —
/// generic methods can't live on the object-safe trait itself). These
/// panic on transport failure, matching the original `Endpoint`
/// slice-helper contract the numeric kernels rely on.
pub trait TransportExt: Transport {
    /// Send a typed slice ([`Pod`]-encoded).
    fn send_slice<T: Pod>(&mut self, to: usize, tag: u64, data: &[T]) {
        self.send(to, tag, T::encode(data)).expect("transport send failed");
    }
    /// Receive a typed slice ([`Pod`]-decoded).
    fn recv_slice<T: Pod>(&mut self, from: usize, tag: u64) -> Vec<T> {
        T::decode(&self.recv(from, tag).expect("transport recv failed"))
    }
}

impl<Tr: Transport + ?Sized> TransportExt for Tr {}

// ---------------------------------------------------------------------
// Socket plumbing shared by SocketTransport and the cluster node layer
// ---------------------------------------------------------------------

/// A cloneable bidirectional byte stream (TCP or Unix-domain).
pub trait Duplex: Read + Write + Send {
    /// Clone the underlying stream (for split reader/writer ownership).
    fn try_clone_box(&self) -> io::Result<Box<dyn Duplex>>;
}

impl Duplex for TcpStream {
    fn try_clone_box(&self) -> io::Result<Box<dyn Duplex>> {
        Ok(Box::new(self.try_clone()?))
    }
}

impl Duplex for UnixStream {
    fn try_clone_box(&self) -> io::Result<Box<dyn Duplex>> {
        Ok(Box::new(self.try_clone()?))
    }
}

impl Read for Box<dyn Duplex> {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        (**self).read(buf)
    }
}

impl Write for Box<dyn Duplex> {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        (**self).write(buf)
    }
    fn flush(&mut self) -> io::Result<()> {
        (**self).flush()
    }
}

impl Duplex for Box<dyn Duplex> {
    fn try_clone_box(&self) -> io::Result<Box<dyn Duplex>> {
        (**self).try_clone_box()
    }
}

/// A parsed cluster address: `host:port` TCP, or `unix:/path` for a
/// Unix-domain socket.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ClusterAddr {
    /// TCP `host:port`.
    Tcp(String),
    /// Unix-domain socket path.
    Unix(PathBuf),
}

impl ClusterAddr {
    /// Parse an address string (`unix:` prefix selects a Unix socket).
    pub fn parse(s: &str) -> ClusterAddr {
        match s.strip_prefix("unix:") {
            Some(path) => ClusterAddr::Unix(PathBuf::from(path)),
            None => ClusterAddr::Tcp(s.to_string()),
        }
    }
}

impl std::fmt::Display for ClusterAddr {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ClusterAddr::Tcp(a) => write!(f, "{a}"),
            ClusterAddr::Unix(p) => write!(f, "unix:{}", p.display()),
        }
    }
}

/// A bound accept socket over either address family.
pub enum ClusterListener {
    /// TCP listener.
    Tcp(TcpListener),
    /// Unix-domain listener (path retained for unlink-on-drop).
    Unix(UnixListener, PathBuf),
}

impl ClusterListener {
    /// Bind to `addr` (use port 0 for an OS-assigned TCP port).
    pub fn bind(addr: &ClusterAddr) -> io::Result<ClusterListener> {
        match addr {
            ClusterAddr::Tcp(a) => Ok(ClusterListener::Tcp(TcpListener::bind(a)?)),
            ClusterAddr::Unix(p) => {
                // A stale socket file from a dead process blocks bind.
                let _removed = std::fs::remove_file(p);
                Ok(ClusterListener::Unix(UnixListener::bind(p)?, p.clone()))
            }
        }
    }

    /// The bound address, with OS-assigned ports resolved.
    pub fn local_addr(&self) -> io::Result<ClusterAddr> {
        match self {
            ClusterListener::Tcp(l) => Ok(ClusterAddr::Tcp(l.local_addr()?.to_string())),
            ClusterListener::Unix(_, p) => Ok(ClusterAddr::Unix(p.clone())),
        }
    }

    /// Toggle non-blocking accept (used by the poll-for-shutdown loop).
    pub fn set_nonblocking(&self, nb: bool) -> io::Result<()> {
        match self {
            ClusterListener::Tcp(l) => l.set_nonblocking(nb),
            ClusterListener::Unix(l, _) => l.set_nonblocking(nb),
        }
    }

    /// Accept one connection; the returned stream is forced back to
    /// blocking mode (non-blocking inheritance from the listener is
    /// platform-dependent).
    pub fn accept(&self) -> io::Result<Box<dyn Duplex>> {
        match self {
            ClusterListener::Tcp(l) => {
                let (s, _) = l.accept()?;
                s.set_nonblocking(false)?;
                s.set_nodelay(true)?;
                Ok(Box::new(s))
            }
            ClusterListener::Unix(l, _) => {
                let (s, _) = l.accept()?;
                s.set_nonblocking(false)?;
                Ok(Box::new(s))
            }
        }
    }
}

impl Drop for ClusterListener {
    fn drop(&mut self) {
        if let ClusterListener::Unix(_, p) = self {
            let _removed = std::fs::remove_file(p);
        }
    }
}

/// Connect to `addr`, retrying with doubling backoff (10 ms start,
/// 500 ms cap) for up to `attempts` tries — peers racing through
/// startup connect before their listener is up without failing.
pub fn connect_backoff(addr: &ClusterAddr, attempts: u32) -> io::Result<Box<dyn Duplex>> {
    let mut delay = Duration::from_millis(10);
    let mut last: Option<io::Error> = None;
    for _ in 0..attempts.max(1) {
        match addr {
            ClusterAddr::Tcp(a) => match TcpStream::connect(a) {
                Ok(s) => {
                    s.set_nodelay(true)?;
                    return Ok(Box::new(s));
                }
                Err(e) => last = Some(e),
            },
            ClusterAddr::Unix(p) => match UnixStream::connect(p) {
                Ok(s) => return Ok(Box::new(s)),
                Err(e) => last = Some(e),
            },
        }
        std::thread::sleep(delay);
        delay = (delay * 2).min(Duration::from_millis(500));
    }
    Err(last.unwrap_or_else(|| io::Error::new(io::ErrorKind::Other, "no connect attempts")))
}

// ---------------------------------------------------------------------
// SocketTransport
// ---------------------------------------------------------------------

struct Inbound {
    from: usize,
    tag: u64,
    data: Vec<u8>,
}

/// Rank-to-rank transport over one duplex socket per peer. Each peer
/// stream gets a detached reader thread that frames, decodes, counts,
/// and forwards [`Message::Tagged`] payloads into a single mailbox;
/// `recv` does the same `(from, tag)` pending-buffer matching the
/// in-process `Endpoint` does, so out-of-order tags behave
/// identically.
pub struct SocketTransport {
    rank: usize,
    n_ranks: usize,
    writers: Vec<Option<Box<dyn Duplex>>>,
    counters: Vec<Arc<CounterCell>>,
    rx: Receiver<Result<Inbound, TransportError>>,
    pending: Vec<Inbound>,
    comm_ns: u64,
}

impl SocketTransport {
    /// Assemble a transport from already-connected peer streams
    /// (`peers` holds `(peer_rank, stream)`; the local rank needs no
    /// entry). Spawns one detached reader thread per peer.
    pub fn from_mesh(
        rank: usize,
        n_ranks: usize,
        peers: Vec<(usize, Box<dyn Duplex>)>,
    ) -> io::Result<SocketTransport> {
        let mut writers: Vec<Option<Box<dyn Duplex>>> = (0..n_ranks).map(|_| None).collect();
        let counters: Vec<Arc<CounterCell>> =
            (0..n_ranks).map(|_| Arc::new(CounterCell::default())).collect();
        let (tx, rx) = channel::<Result<Inbound, TransportError>>();
        for (peer, stream) in peers {
            assert!(peer < n_ranks && peer != rank, "bad peer rank {peer}");
            let reader = stream.try_clone_box()?;
            writers[peer] = Some(stream);
            let cell = Arc::clone(&counters[peer]);
            let tx = tx.clone();
            std::thread::spawn(move || reader_loop(peer, reader, cell, tx));
        }
        Ok(SocketTransport { rank, n_ranks, writers, counters, rx, pending: Vec::new(), comm_ns: 0 })
    }
}

fn reader_loop(
    peer: usize,
    mut stream: Box<dyn Duplex>,
    cell: Arc<CounterCell>,
    tx: Sender<Result<Inbound, TransportError>>,
) {
    loop {
        let frame = match read_frame(&mut stream) {
            Ok(f) => f,
            Err(WireError::Eof) => return,
            Err(e) => {
                let _sent = tx.send(Err(TransportError::Codec(e)));
                return;
            }
        };
        cell.note_recv(frame.len() as u64 + 4);
        match decode_message(&frame) {
            Ok(Message::Tagged { tag, data }) => {
                if tx.send(Ok(Inbound { from: peer, tag, data })).is_err() {
                    return; // transport dropped
                }
            }
            Ok(_) => {
                let _sent = tx.send(Err(TransportError::Codec(WireError::BadPayload(
                    "non-Tagged message on rank mesh",
                ))));
                return;
            }
            Err(e) => {
                let _sent = tx.send(Err(TransportError::Codec(e)));
                return;
            }
        }
    }
}

impl Transport for SocketTransport {
    fn rank(&self) -> usize {
        self.rank
    }

    fn n_ranks(&self) -> usize {
        self.n_ranks
    }

    fn send(&mut self, to: usize, tag: u64, payload: Vec<u8>) -> Result<(), TransportError> {
        if to == self.rank {
            // Self-sends (the exact strategy's leader gathers from
            // itself) never touch a wire: deliver straight into the
            // pending buffer, uncounted.
            self.pending.push(Inbound { from: to, tag, data: payload });
            return Ok(());
        }
        let t0 = Instant::now();
        let frame = encode_message(&Message::Tagged { tag, data: payload });
        let writer = self.writers[to]
            .as_mut()
            .ok_or(TransportError::Closed { peer: to })?;
        let wire_len = frame.len() as u64 + 4;
        match write_frame(writer, &frame) {
            Ok(()) => {
                self.counters[to].note_sent(wire_len);
                self.comm_ns += t0.elapsed().as_nanos() as u64;
                Ok(())
            }
            Err(WireError::Io(detail)) => {
                self.writers[to] = None;
                Err(TransportError::Io { peer: to, detail })
            }
            Err(e) => Err(TransportError::Codec(e)),
        }
    }

    fn recv(&mut self, from: usize, tag: u64) -> Result<Vec<u8>, TransportError> {
        let t0 = Instant::now();
        if let Some(i) = self.pending.iter().position(|m| m.from == from && m.tag == tag) {
            let m = self.pending.remove(i);
            self.comm_ns += t0.elapsed().as_nanos() as u64;
            return Ok(m.data);
        }
        loop {
            match self.rx.recv() {
                Ok(Ok(m)) => {
                    if m.from == from && m.tag == tag {
                        self.comm_ns += t0.elapsed().as_nanos() as u64;
                        return Ok(m.data);
                    }
                    self.pending.push(m);
                }
                Ok(Err(e)) => return Err(e),
                Err(_) => return Err(TransportError::Closed { peer: from }),
            }
        }
    }

    fn counters(&self) -> Vec<PeerCounters> {
        (0..self.n_ranks)
            .filter(|&p| p != self.rank)
            .map(|p| self.counters[p].snapshot(p as u64))
            .collect()
    }

    fn comm_nanos(&self) -> u64 {
        self.comm_ns
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Two socket transports over a localhost TCP pair behave like the
    /// in-process mailbox: tag matching, out-of-order buffering, and
    /// byte counters.
    #[test]
    fn socket_pair_tagged_roundtrip() {
        let listener = ClusterListener::bind(&ClusterAddr::parse("127.0.0.1:0")).unwrap();
        let addr = listener.local_addr().unwrap();
        let accepted = std::thread::spawn(move || listener.accept().unwrap());
        let client = connect_backoff(&addr, 20).unwrap();
        let server = accepted.join().unwrap();

        let mut t0 = SocketTransport::from_mesh(0, 2, vec![(1, client)]).unwrap();
        let mut t1 = SocketTransport::from_mesh(1, 2, vec![(0, server)]).unwrap();

        // Out-of-order tags: send tag 7 then 5; receive 5 first.
        t0.send(1, 7, vec![7u8; 3]).unwrap();
        t0.send(1, 5, vec![5u8; 2]).unwrap();
        assert_eq!(t1.recv(0, 5).unwrap(), vec![5u8; 2]);
        assert_eq!(t1.recv(0, 7).unwrap(), vec![7u8; 3]);

        // Typed slices through the blanket extension.
        t1.send_slice::<i64>(0, 9, &[1, -2, 3]);
        let got: Vec<i64> = (&mut t0 as &mut dyn Transport).recv_slice(1, 9);
        assert_eq!(got, vec![1, -2, 3]);

        let c0 = t0.counters();
        assert_eq!(c0.len(), 1);
        assert_eq!(c0[0].sent_msgs, 2);
        assert_eq!(c0[0].recv_msgs, 1);
        assert!(c0[0].sent_bytes > 0 && c0[0].recv_bytes > 0);
        assert!(t0.comm_nanos() > 0);
    }
}
