//! Cluster-scale engine: multi-process shards over a pluggable
//! transport.
//!
//! This subsystem turns N `qai serve` processes into one logical
//! engine (paper contribution 3, taken past the single process):
//!
//! * [`wire`] — length-prefixed framing and a total, typed codec for
//!   every cross-socket message (handshakes, mitigation
//!   request/response, rank-mesh traffic). Deadlines serialize as
//!   remaining budget, never absolute instants.
//! * [`transport`] — the object-safe [`Transport`](transport::Transport)
//!   trait the distributed numerics are written against, with the
//!   in-process loopback (`coordinator::transport::Endpoint`, adapted
//!   bit-identically) and [`SocketTransport`](transport::SocketTransport)
//!   (TCP/Unix sockets, per-peer byte/message counters) behind it.
//! * [`registry`] — rendezvous (HRW) hashed tenant → node routing:
//!   adding a node moves only ~1/N of tenants.
//! * [`node`] — [`ClusterServer`](node::ClusterServer) (accept loop,
//!   `--listen`) and [`ClusterEngine`](node::ClusterEngine) (routing
//!   front door, `--join`), with `SharedGrid` zero-copy preserved on
//!   the locally-owned path.
//! * [`procs`] — forked multi-process rank runs over localhost for the
//!   fig9/fig11 benches: real wires, measured comm breakdown.

#![deny(missing_docs)]

pub mod node;
pub mod procs;
pub mod registry;
pub mod transport;
pub mod wire;
