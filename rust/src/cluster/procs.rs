//! Real multi-process distributed runs over localhost sockets.
//!
//! [`run_distributed_procs`] is the process-level twin of
//! `coordinator::driver::run_distributed`: instead of spawning
//! in-process ranks over the loopback [`Fabric`], it **forks child
//! processes** (`qai rank-worker`) and moves every byte over TCP —
//! the fig9/fig11 benches drive it so the paper's MPI-scaling and
//! comm-breakdown figures measure a real wire, with fig11's
//! communication column read from the transport's byte counters
//! instead of the analytic `CommModel`.
//!
//! Control plane: the driver binds a localhost listener, forks one
//! `qai rank-worker --connect <addr> --rank <r>` per rank, and each
//! worker (a) binds its own mesh listener, (b) introduces itself with
//! `RankHello` carrying that mesh address, (c) receives a `RankSetup`
//! with its data block plus the full rank→address mesh table, (d)
//! dials every lower rank and accepts every higher rank (all listeners
//! exist before any setup ships, so the mesh forms without deadlock),
//! and (e) runs `mitigate_rank` over a [`SocketTransport`], returning
//! a `RankResult` with its output block and measured traffic.

#![deny(missing_docs)]

use crate::cluster::transport::{
    connect_backoff, ClusterAddr, ClusterListener, Duplex, SocketTransport, Transport,
};
use crate::cluster::wire::{
    decode_message, encode_message, read_frame, write_frame, Message, RankResult, RankSetup,
};
use crate::coordinator::strategy::{mitigate_rank, Strategy};
use crate::coordinator::topology::Topology;
use crate::data::grid::{Grid, Shape, SharedGrid};
use crate::quant::{QIndex, ResolvedBound};
use anyhow::{anyhow, bail, Context};
use std::path::Path;
use std::process::{Command, Stdio};
use std::time::Instant;

/// Measured results of one multi-process run.
#[derive(Debug, Clone, Copy)]
pub struct ProcsReport {
    /// Number of worker processes.
    pub ranks: usize,
    /// Wall-clock seconds from setup-sent to all results received.
    pub wall_s: f64,
    /// Worst per-rank seconds spent inside transport send/recv — the
    /// measured communication share of the critical path.
    pub comm_s: f64,
    /// Total mesh wire bytes sent across all ranks.
    pub bytes: u64,
    /// Total mesh messages sent across all ranks.
    pub msgs: u64,
    /// Size of the dequantized field in MB (for throughput math).
    pub data_mb: f64,
}

impl ProcsReport {
    /// End-to-end throughput in MB/s.
    pub fn throughput_mbs(&self) -> f64 {
        if self.wall_s > 0.0 {
            self.data_mb / self.wall_s
        } else {
            0.0
        }
    }

    /// Fraction of wall time the worst rank spent communicating.
    pub fn comm_fraction(&self) -> f64 {
        if self.wall_s > 0.0 {
            (self.comm_s / self.wall_s).min(1.0)
        } else {
            0.0
        }
    }
}

fn send_msg(stream: &mut Box<dyn Duplex>, msg: &Message) -> anyhow::Result<()> {
    write_frame(stream, &encode_message(msg)).map_err(|e| anyhow!("send: {e}"))
}

fn recv_msg(stream: &mut Box<dyn Duplex>) -> anyhow::Result<Message> {
    let frame = read_frame(stream).map_err(|e| anyhow!("recv: {e}"))?;
    decode_message(&frame).map_err(|e| anyhow!("decode: {e}"))
}

/// Fork `n_ranks` `qai rank-worker` processes over localhost, run the
/// distributed mitigation, and reassemble the global field. Returns
/// the output grid plus measured wall/comm/traffic numbers.
#[allow(clippy::too_many_arguments)]
pub fn run_distributed_procs(
    qai_bin: &Path,
    dq: &Grid<f32>,
    q: &Grid<QIndex>,
    eb: ResolvedBound,
    strategy: Strategy,
    n_ranks: usize,
    eta: f64,
    threads_per_rank: usize,
) -> anyhow::Result<(Grid<f32>, ProcsReport)> {
    assert!(n_ranks >= 1, "need at least one rank");
    let topo = Topology::new(n_ranks, dq.shape);
    let control = ClusterListener::bind(&ClusterAddr::parse("127.0.0.1:0"))
        .context("bind control listener")?;
    let control_addr = match control.local_addr().context("resolve control addr")? {
        ClusterAddr::Tcp(a) => a,
        ClusterAddr::Unix(_) => unreachable!("control listener is TCP"),
    };

    let mut children = Vec::with_capacity(n_ranks);
    for rank in 0..n_ranks {
        let child = Command::new(qai_bin)
            .arg("rank-worker")
            .arg("--connect")
            .arg(&control_addr)
            .arg("--rank")
            .arg(rank.to_string())
            .stdin(Stdio::null())
            .stdout(Stdio::null())
            .stderr(Stdio::inherit())
            .spawn()
            .with_context(|| format!("spawn rank-worker {rank}"))?;
        children.push(child);
    }

    // Collect one RankHello per rank; remember each rank's control
    // stream and mesh listener address.
    let mut streams: Vec<Option<Box<dyn Duplex>>> = (0..n_ranks).map(|_| None).collect();
    let mut mesh: Vec<String> = vec![String::new(); n_ranks];
    for _ in 0..n_ranks {
        let mut stream = control.accept().context("accept rank-worker")?;
        match recv_msg(&mut stream)? {
            Message::RankHello { rank, mesh_addr } => {
                let rank = rank as usize;
                if rank >= n_ranks || streams[rank].is_some() {
                    bail!("bad or duplicate rank hello for rank {rank}");
                }
                mesh[rank] = mesh_addr;
                streams[rank] = Some(stream);
            }
            other => bail!("expected RankHello, got {other:?}"),
        }
    }

    // Ship every rank its block and the full mesh table.
    for rank in 0..n_ranks {
        let (lo, size) = topo.block(rank);
        let setup = RankSetup {
            rank: rank as u64,
            n_ranks: n_ranks as u64,
            strategy,
            eta,
            threads: threads_per_rank as u64,
            eb,
            shape_dims: [dq.shape.dims[0] as u64, dq.shape.dims[1] as u64, dq.shape.dims[2] as u64],
            shape_ndim: dq.shape.ndim as u8,
            dq: dq.extract(lo, size),
            q: q.extract(lo, size),
            mesh: mesh.clone(),
        };
        let stream = streams[rank].as_mut().expect("stream collected above");
        send_msg(stream, &Message::RankSetup(Box::new(setup)))
            .with_context(|| format!("ship setup to rank {rank}"))?;
    }

    // Collect results; wall clock covers compute + mesh traffic, not
    // process startup.
    let t0 = Instant::now();
    let mut out = Grid::zeros(dq.shape.user_dims());
    let mut comm_ns_max = 0u64;
    let mut bytes = 0u64;
    let mut msgs = 0u64;
    for rank in 0..n_ranks {
        let stream = streams[rank].as_mut().expect("stream collected above");
        match recv_msg(stream).with_context(|| format!("result from rank {rank}"))? {
            Message::RankResult(res) => {
                if res.rank as usize != rank {
                    bail!("rank {rank} stream answered as rank {}", res.rank);
                }
                let (lo, _) = topo.block(rank);
                out.insert(lo, &res.out);
                comm_ns_max = comm_ns_max.max(res.comm_nanos);
                bytes += res.sent_bytes;
                msgs += res.sent_msgs;
            }
            other => bail!("expected RankResult, got {other:?}"),
        }
    }
    let wall_s = t0.elapsed().as_secs_f64();

    for (rank, mut child) in children.into_iter().enumerate() {
        let status = child.wait().with_context(|| format!("wait rank {rank}"))?;
        if !status.success() {
            bail!("rank-worker {rank} exited with {status}");
        }
    }

    let report = ProcsReport {
        ranks: n_ranks,
        wall_s,
        comm_s: comm_ns_max as f64 / 1e9,
        bytes,
        msgs,
        data_mb: (dq.shape.len() * std::mem::size_of::<f32>()) as f64 / 1e6,
    };
    Ok((out, report))
}

/// Child-process entry point for `qai rank-worker`: join the control
/// plane, form the rank mesh, run `mitigate_rank` over sockets, and
/// report the result. Never called interactively.
pub fn rank_worker(connect: &str, rank: usize) -> anyhow::Result<()> {
    // Mesh listener first: the driver only ships setups (and therefore
    // peers only dial) after every rank has introduced its address.
    let mesh_listener = ClusterListener::bind(&ClusterAddr::parse("127.0.0.1:0"))
        .context("bind mesh listener")?;
    let mesh_addr = match mesh_listener.local_addr().context("resolve mesh addr")? {
        ClusterAddr::Tcp(a) => a,
        ClusterAddr::Unix(_) => unreachable!("mesh listener is TCP"),
    };

    let mut control =
        connect_backoff(&ClusterAddr::parse(connect), 50).context("dial control plane")?;
    send_msg(
        &mut control,
        &Message::RankHello { rank: rank as u64, mesh_addr },
    )?;

    let setup = match recv_msg(&mut control)? {
        Message::RankSetup(s) => s,
        other => bail!("expected RankSetup, got {other:?}"),
    };
    let n_ranks = setup.n_ranks as usize;
    if setup.rank as usize != rank || setup.mesh.len() != n_ranks {
        bail!("setup does not match this rank");
    }
    let ndim = setup.shape_ndim as usize;
    if !(1..=3).contains(&ndim) {
        bail!("setup shape ndim {ndim} out of range");
    }
    let user_dims: Vec<usize> =
        setup.shape_dims[3 - ndim..].iter().map(|&d| d as usize).collect();
    let topo = Topology::new(n_ranks, Shape::new(&user_dims));

    // Form the full mesh: dial every lower rank, accept every higher
    // one (identified by its RankHello).
    let mut peers: Vec<(usize, Box<dyn Duplex>)> = Vec::with_capacity(n_ranks - 1);
    for (peer, addr) in setup.mesh.iter().enumerate().take(rank) {
        let mut stream = connect_backoff(&ClusterAddr::parse(addr), 50)
            .with_context(|| format!("dial mesh peer {peer}"))?;
        send_msg(
            &mut stream,
            &Message::RankHello { rank: rank as u64, mesh_addr: String::new() },
        )?;
        peers.push((peer, stream));
    }
    for _ in rank + 1..n_ranks {
        let mut stream = mesh_listener.accept().context("accept mesh peer")?;
        match recv_msg(&mut stream)? {
            Message::RankHello { rank: peer, .. } => peers.push((peer as usize, stream)),
            other => bail!("expected mesh RankHello, got {other:?}"),
        }
    }
    let mut transport =
        SocketTransport::from_mesh(rank, n_ranks, peers).context("assemble mesh transport")?;

    let dq: SharedGrid<f32> = setup.dq.into();
    let q: SharedGrid<QIndex> = setup.q.into();
    let out = mitigate_rank(
        setup.strategy,
        &topo,
        &mut transport,
        &dq,
        &q,
        setup.eb,
        setup.eta,
        setup.threads as usize,
    );

    let counters = transport.counters();
    let result = RankResult {
        rank: rank as u64,
        comm_nanos: transport.comm_nanos(),
        sent_bytes: counters.iter().map(|c| c.sent_bytes).sum(),
        sent_msgs: counters.iter().map(|c| c.sent_msgs).sum(),
        recv_bytes: counters.iter().map(|c| c.recv_bytes).sum(),
        recv_msgs: counters.iter().map(|c| c.recv_msgs).sum(),
        out,
    };
    send_msg(&mut control, &Message::RankResult(Box::new(result)))?;
    Ok(())
}
