//! Node registry with rendezvous (highest-random-weight) routing.
//!
//! Every node scores every tenant as
//! `fnv1a(node_id_le_bytes ‖ tenant_bytes)` — the same FNV-1a the
//! engine's shard router uses — and a tenant lives on its
//! highest-scoring node (ties broken toward the lowest node id).
//! Because each (node, tenant) score is independent, adding a node to
//! an N+1-node cluster steals only the tenants the new node now
//! out-scores: in expectation T/(N+1), and never more than the number
//! it wins — the movement bound `rust/tests/cluster.rs` asserts
//! directly.

#![deny(missing_docs)]

use crate::mitigation::engine::fnv1a;
use std::cmp::Reverse;

/// Sorted, deduplicated set of known node ids with rendezvous routing.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct NodeRegistry {
    nodes: Vec<u64>,
}

impl NodeRegistry {
    /// A registry knowing only the local node.
    pub fn new(local: u64) -> NodeRegistry {
        NodeRegistry { nodes: vec![local] }
    }

    /// Add a node; returns `false` if already present.
    pub fn add(&mut self, node: u64) -> bool {
        match self.nodes.binary_search(&node) {
            Ok(_) => false,
            Err(i) => {
                self.nodes.insert(i, node);
                true
            }
        }
    }

    /// Remove a node; returns `false` if it was not present.
    pub fn remove(&mut self, node: u64) -> bool {
        match self.nodes.binary_search(&node) {
            Ok(i) => {
                self.nodes.remove(i);
                true
            }
            Err(_) => false,
        }
    }

    /// The known node ids, ascending.
    pub fn nodes(&self) -> &[u64] {
        &self.nodes
    }

    /// Number of known nodes.
    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    /// True when no nodes are registered (only possible after
    /// `remove`-ing the local node).
    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// The rendezvous score of `node` for `tenant`.
    pub fn score(node: u64, tenant: &str) -> u64 {
        let mut key = Vec::with_capacity(8 + tenant.len());
        key.extend_from_slice(&node.to_le_bytes());
        key.extend_from_slice(tenant.as_bytes());
        fnv1a(&key)
    }

    /// The node that owns `tenant`: highest rendezvous score, ties to
    /// the lowest node id. `None` only for an empty registry.
    pub fn route(&self, tenant: &str) -> Option<u64> {
        self.nodes
            .iter()
            .copied()
            .max_by_key(|&n| (Self::score(n, tenant), Reverse(n)))
    }
}

/// Derive a stable node id from a seed string (listen address,
/// hostname, …). Forced odd so an id is never 0 — 0 is reserved as
/// "unset" in CLI plumbing.
pub fn auto_node_id(seed: &str) -> u64 {
    fnv1a(seed.as_bytes()) | 1
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn routing_is_deterministic_and_total() {
        let mut r = NodeRegistry::new(11);
        r.add(22);
        r.add(33);
        for t in ["alice", "bob", "carol", ""] {
            let owner = r.route(t).unwrap();
            assert!(r.nodes().contains(&owner));
            assert_eq!(r.route(t), Some(owner), "route must be stable");
        }
        assert_eq!(r.clone(), r);
    }

    #[test]
    fn add_remove_dedup() {
        let mut r = NodeRegistry::new(5);
        assert!(!r.add(5));
        assert!(r.add(9));
        assert!(!r.add(9));
        assert_eq!(r.nodes(), &[5, 9]);
        assert!(r.remove(5));
        assert!(!r.remove(5));
        assert_eq!(r.nodes(), &[9]);
    }

    #[test]
    fn removing_a_node_only_moves_its_own_tenants() {
        let mut r = NodeRegistry::new(1);
        r.add(2);
        r.add(3);
        let tenants: Vec<String> = (0..200).map(|i| format!("tenant-{i}")).collect();
        let before: Vec<u64> = tenants.iter().map(|t| r.route(t).unwrap()).collect();
        r.remove(2);
        for (t, &owner) in tenants.iter().zip(&before) {
            if owner != 2 {
                assert_eq!(r.route(t), Some(owner), "tenant {t} moved without cause");
            } else {
                assert_ne!(r.route(t), Some(2));
            }
        }
    }
}
