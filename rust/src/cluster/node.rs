//! Node-level clustering: remote-addressable engine shards.
//!
//! A **node** is one `qai serve` process wrapping one
//! [`Engine`](crate::mitigation::engine::Engine). Nodes form a cluster
//! two ways:
//!
//! * [`ClusterServer`] — `qai serve --listen <addr>` binds an accept
//!   loop; each accepted connection handshakes (magic + protocol
//!   version + node id) and then serves framed
//!   [`Message::Request`](crate::cluster::wire::Message) traffic
//!   against the local engine.
//! * [`ClusterEngine`] — `qai serve --join <addr>` wraps the local
//!   engine and a [`NodeRegistry`]; every submit routes by rendezvous
//!   hashing. Tenants owned locally take the exact in-process path
//!   (`SharedGrid` zero-copy — the request struct moves, the grids
//!   don't); remote tenants serialize over the peer connection.
//!
//! Deadlines cross the wire as **remaining budget**: the sender
//! subtracts time already spent before encoding, and the remote engine
//! re-anchors the budget at its own enqueue (the engine's `deadline`
//! has from-enqueue semantics already). A nearly-expired budget is
//! shed by the remote node's admission EWMA exactly like a local one.
//!
//! Failure semantics: a dead peer connection fails all of its in-flight
//! tickets with [`ClusterError::Disconnected`]; the next submit to that
//! peer attempts one reconnect-with-backoff (fresh handshake), and if
//! that fails the peer is dropped from the registry so rendezvous
//! routing degrades onto the surviving nodes.

#![deny(missing_docs)]

use crate::cluster::registry::NodeRegistry;
use crate::cluster::transport::{
    connect_backoff, ClusterAddr, ClusterListener, CounterCell, Duplex, PeerCounters,
};
use crate::cluster::wire::{
    decode_message, encode_message, read_frame, write_frame, Handshake, Message, RejectKind,
    RemoteOutcome, WireError, PROTOCOL_VERSION,
};
use crate::mitigation::admission::SubmitError;
use crate::mitigation::engine::{
    Engine, MitigationRequest, MitigationResponse, ResponseTicket, TransportStatsSource,
};
use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Typed cluster-path failure.
#[derive(Debug)]
pub enum ClusterError {
    /// The local engine rejected the request at admission.
    Local(
        /// The admission error (job returned inside).
        SubmitError,
    ),
    /// A remote node rejected the request.
    Rejected {
        /// Typed rejection category.
        kind: RejectKind,
        /// Remote error detail.
        message: String,
    },
    /// The connection to `peer` died with the request in flight.
    Disconnected {
        /// The lost peer's node id.
        peer: u64,
    },
    /// The peer sent bytes the codec rejected.
    Wire(
        /// The codec error.
        WireError,
    ),
    /// A socket operation failed.
    Io(
        /// Stringified I/O error.
        String,
    ),
    /// The request was admitted but execution failed.
    Exec(
        /// Stringified execution error.
        String,
    ),
    /// The registry is empty (no node can own the tenant).
    NoRoute,
}

impl std::fmt::Display for ClusterError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ClusterError::Local(e) => write!(f, "local admission: {e}"),
            ClusterError::Rejected { kind, message } => {
                write!(f, "remote rejected ({kind:?}): {message}")
            }
            ClusterError::Disconnected { peer } => {
                write!(f, "peer {peer} disconnected with request in flight")
            }
            ClusterError::Wire(e) => write!(f, "wire: {e}"),
            ClusterError::Io(e) => write!(f, "io: {e}"),
            ClusterError::Exec(e) => write!(f, "execution: {e}"),
            ClusterError::NoRoute => write!(f, "no node can own this tenant"),
        }
    }
}

impl std::error::Error for ClusterError {}

type RemoteResult = Result<MitigationResponse, ClusterError>;
type Inflight = Arc<Mutex<HashMap<u64, Sender<RemoteResult>>>>;

/// Node-scoped per-peer traffic counters, attachable to an
/// [`Engine`] so `metrics_text` emits `scope=transport` lines.
pub struct ClusterTransportStats {
    node_id: u64,
    cells: Mutex<Vec<(u64, Arc<CounterCell>)>>,
}

impl ClusterTransportStats {
    /// New counter set for node `node_id`.
    pub fn new(node_id: u64) -> Arc<ClusterTransportStats> {
        Arc::new(ClusterTransportStats { node_id, cells: Mutex::new(Vec::new()) })
    }

    /// The cell tracking traffic with `peer` (created on first use).
    pub fn register(&self, peer: u64) -> Arc<CounterCell> {
        let mut cells = self.cells.lock().unwrap();
        if let Some((_, cell)) = cells.iter().find(|(p, _)| *p == peer) {
            return Arc::clone(cell);
        }
        let cell = Arc::new(CounterCell::default());
        cells.push((peer, Arc::clone(&cell)));
        cells.sort_by_key(|(p, _)| *p);
        cell
    }

    /// Snapshot all peers' counters.
    pub fn snapshot(&self) -> Vec<PeerCounters> {
        self.cells.lock().unwrap().iter().map(|(p, c)| c.snapshot(*p)).collect()
    }
}

impl TransportStatsSource for ClusterTransportStats {
    fn transport_node(&self) -> u64 {
        self.node_id
    }
    fn transport_counters(&self) -> Vec<PeerCounters> {
        self.snapshot()
    }
}

/// Client-side state for one connected peer node.
struct PeerState {
    id: u64,
    addr: ClusterAddr,
    writer: Mutex<Option<Box<dyn Duplex>>>,
    counters: Arc<CounterCell>,
    inflight: Inflight,
    next_req: AtomicU64,
    alive: Arc<AtomicBool>,
}

/// Spawn the detached thread that drains one peer connection's
/// responses into the in-flight table. On stream death it fails every
/// outstanding ticket (dropped senders → `Disconnected` at receivers).
fn spawn_client_reader(
    peer_id: u64,
    mut reader: Box<dyn Duplex>,
    cell: Arc<CounterCell>,
    inflight: Inflight,
    alive: Arc<AtomicBool>,
) {
    std::thread::spawn(move || {
        loop {
            let frame = match read_frame(&mut reader) {
                Ok(f) => f,
                Err(_) => break,
            };
            cell.note_recv(frame.len() as u64 + 4);
            match decode_message(&frame) {
                Ok(Message::Response { req_id, outcome }) => {
                    let sender = inflight.lock().unwrap().remove(&req_id);
                    if let Some(tx) = sender {
                        let result = match *outcome {
                            RemoteOutcome::Ok(resp) => Ok(resp),
                            RemoteOutcome::Rejected { kind, message } => {
                                Err(ClusterError::Rejected { kind, message })
                            }
                        };
                        let _delivered = tx.send(result);
                    }
                }
                Ok(_) | Err(_) => break,
            }
        }
        alive.store(false, Ordering::SeqCst);
        inflight.lock().unwrap().clear();
        let _unused = peer_id;
    });
}

/// Client side of the cluster handshake on a fresh stream: send
/// `Hello`, expect `Welcome`. Returns the peer's node id and its known
/// nodes.
fn client_handshake(
    stream: &mut Box<dyn Duplex>,
    node_id: u64,
) -> Result<(u64, Vec<u64>), ClusterError> {
    let hello =
        encode_message(&Message::Hello(Handshake { node_id, version: PROTOCOL_VERSION }));
    write_frame(stream, &hello).map_err(ClusterError::Wire)?;
    let frame = read_frame(stream).map_err(ClusterError::Wire)?;
    match decode_message(&frame).map_err(ClusterError::Wire)? {
        Message::Welcome { node_id: peer, nodes, .. } => Ok((peer, nodes)),
        _ => Err(ClusterError::Wire(WireError::BadPayload("expected Welcome"))),
    }
}

/// A ticket for a cluster-routed request: either a plain local engine
/// ticket (zero-copy path) or a receiver for a remote response.
pub enum ClusterTicket {
    /// The request was admitted by the local engine.
    Local(
        /// The local engine's ticket.
        ResponseTicket,
    ),
    /// The request went to a remote node.
    Remote {
        /// The serving peer's node id.
        peer: u64,
        /// The request's trace id (preserved across the wire).
        trace_id: u64,
        /// Delivers the remote outcome (or `Disconnected`).
        rx: Receiver<RemoteResult>,
    },
}

impl ClusterTicket {
    /// True when the request was routed to a remote node.
    pub fn is_remote(&self) -> bool {
        matches!(self, ClusterTicket::Remote { .. })
    }

    /// The request's trace id.
    pub fn trace_id(&self) -> u64 {
        match self {
            ClusterTicket::Local(t) => t.trace_id(),
            ClusterTicket::Remote { trace_id, .. } => *trace_id,
        }
    }

    /// Block until the response arrives (local execution or remote
    /// reply).
    pub fn wait(self) -> RemoteResult {
        match self {
            ClusterTicket::Local(t) => t.wait().map_err(|e| ClusterError::Exec(e.to_string())),
            ClusterTicket::Remote { peer, rx, .. } => match rx.recv() {
                Ok(result) => result,
                Err(_) => Err(ClusterError::Disconnected { peer }),
            },
        }
    }
}

/// The cluster-routing front door: wraps a local [`Engine`] plus the
/// peer connections formed by [`ClusterEngine::join`], and routes every
/// submit by rendezvous hashing over the [`NodeRegistry`].
pub struct ClusterEngine {
    node_id: u64,
    engine: Arc<Engine>,
    registry: Mutex<NodeRegistry>,
    peers: Mutex<BTreeMap<u64, Arc<PeerState>>>,
    stats: Arc<ClusterTransportStats>,
}

impl ClusterEngine {
    /// Wrap `engine` as cluster node `node_id`. Attaches a transport
    /// counter source to the engine so `metrics_text` grows
    /// `scope=transport` lines.
    pub fn new(node_id: u64, engine: Arc<Engine>) -> ClusterEngine {
        let stats = ClusterTransportStats::new(node_id);
        engine.attach_transport(Arc::clone(&stats) as Arc<dyn TransportStatsSource>);
        ClusterEngine {
            node_id,
            engine: Arc::clone(&engine),
            registry: Mutex::new(NodeRegistry::new(node_id)),
            peers: Mutex::new(BTreeMap::new()),
            stats,
        }
    }

    /// This node's id.
    pub fn node_id(&self) -> u64 {
        self.node_id
    }

    /// The wrapped local engine.
    pub fn engine(&self) -> &Arc<Engine> {
        &self.engine
    }

    /// The node ids currently in the routing registry, ascending.
    pub fn nodes(&self) -> Vec<u64> {
        self.registry.lock().unwrap().nodes().to_vec()
    }

    /// The transport counter source (shared with the engine's
    /// metrics).
    pub fn transport_stats(&self) -> &Arc<ClusterTransportStats> {
        &self.stats
    }

    /// Connect to a listening peer, handshake, and add it to the
    /// routing registry. Returns the peer's node id.
    pub fn join(&self, addr: &str) -> Result<u64, ClusterError> {
        let addr = ClusterAddr::parse(addr);
        let mut stream =
            connect_backoff(&addr, 30).map_err(|e| ClusterError::Io(e.to_string()))?;
        let (peer_id, _nodes) = client_handshake(&mut stream, self.node_id)?;
        let reader = stream
            .try_clone_box()
            .map_err(|e| ClusterError::Io(e.to_string()))?;
        let counters = self.stats.register(peer_id);
        let inflight: Inflight = Arc::new(Mutex::new(HashMap::new()));
        let alive = Arc::new(AtomicBool::new(true));
        spawn_client_reader(
            peer_id,
            reader,
            Arc::clone(&counters),
            Arc::clone(&inflight),
            Arc::clone(&alive),
        );
        let state = Arc::new(PeerState {
            id: peer_id,
            addr,
            writer: Mutex::new(Some(stream)),
            counters,
            inflight,
            next_req: AtomicU64::new(1),
            alive,
        });
        self.peers.lock().unwrap().insert(peer_id, state);
        self.registry.lock().unwrap().add(peer_id);
        Ok(peer_id)
    }

    /// The node id that owns `tenant` under the current registry
    /// (tenant-less requests stay local).
    pub fn route_node(&self, tenant: Option<&str>) -> u64 {
        match tenant {
            None => self.node_id,
            Some(t) => self
                .registry
                .lock()
                .unwrap()
                .route(t)
                .unwrap_or(self.node_id),
        }
    }

    /// Drop a peer from routing (dead connection); rendezvous routing
    /// degrades onto the survivors.
    fn drop_peer(&self, peer: u64) {
        self.registry.lock().unwrap().remove(peer);
        self.peers.lock().unwrap().remove(&peer);
    }

    /// One reconnect attempt for a dead peer connection: fresh stream,
    /// fresh handshake, fresh reader thread.
    fn reconnect(&self, peer: &PeerState) -> Result<Box<dyn Duplex>, ClusterError> {
        let mut stream =
            connect_backoff(&peer.addr, 5).map_err(|e| ClusterError::Io(e.to_string()))?;
        let (got, _nodes) = client_handshake(&mut stream, self.node_id)?;
        if got != peer.id {
            return Err(ClusterError::Wire(WireError::BadPayload(
                "peer answered with a different node id",
            )));
        }
        let reader = stream
            .try_clone_box()
            .map_err(|e| ClusterError::Io(e.to_string()))?;
        peer.alive.store(true, Ordering::SeqCst);
        spawn_client_reader(
            peer.id,
            reader,
            Arc::clone(&peer.counters),
            Arc::clone(&peer.inflight),
            Arc::clone(&peer.alive),
        );
        Ok(stream)
    }

    /// Submit a request through cluster routing. Locally owned tenants
    /// take the engine's zero-copy path; remote tenants serialize with
    /// the deadline re-encoded as remaining budget at send time.
    pub fn submit(&self, mut request: MitigationRequest) -> Result<ClusterTicket, ClusterError> {
        let mut t0 = Instant::now();
        loop {
            let target = self.route_node(request.tenant.as_deref());
            if target == self.node_id {
                return self
                    .engine
                    .submit(request)
                    .map(ClusterTicket::Local)
                    .map_err(ClusterError::Local);
            }
            let peer = match self.peers.lock().unwrap().get(&target) {
                Some(p) => Arc::clone(p),
                None => {
                    // Registry knows a node we hold no connection to
                    // (it died earlier); drop it and re-route.
                    self.drop_peer(target);
                    continue;
                }
            };
            match self.submit_remote(&peer, request, t0) {
                Ok(ticket) => return Ok(ticket),
                Err((req, _err)) => {
                    // Connection is gone and reconnect failed: degrade
                    // routing and retry on the survivors. The recovered
                    // request's deadline is already reduced to the
                    // budget remaining at the failed send, so the
                    // elapsed-time anchor restarts here.
                    self.drop_peer(target);
                    request = req;
                    t0 = Instant::now();
                }
            }
        }
    }

    /// Send `request` to `peer`; on failure the request is handed back
    /// for re-routing.
    fn submit_remote(
        &self,
        peer: &Arc<PeerState>,
        mut request: MitigationRequest,
        t0: Instant,
    ) -> Result<ClusterTicket, (MitigationRequest, ClusterError)> {
        // Deadline crosses the wire as remaining budget at send time.
        if let Some(d) = request.deadline {
            request.deadline = Some(d.saturating_sub(t0.elapsed()));
        }
        let req_id = peer.next_req.fetch_add(1, Ordering::SeqCst);
        let trace_id = request.trace_id;
        let (tx, rx) = channel::<RemoteResult>();
        peer.inflight.lock().unwrap().insert(req_id, tx);
        let frame = encode_message(&Message::Request { req_id, request: Box::new(request) });
        let wire_len = frame.len() as u64 + 4;

        let mut writer = peer.writer.lock().unwrap();
        let mut attempt_reconnect = writer.is_none() || !peer.alive.load(Ordering::SeqCst);
        for _ in 0..2 {
            if attempt_reconnect {
                match self.reconnect(peer) {
                    Ok(stream) => *writer = Some(stream),
                    Err(e) => {
                        peer.inflight.lock().unwrap().remove(&req_id);
                        let request = decode_request_back(&frame);
                        return Err((request, e));
                    }
                }
            }
            let stream = writer.as_mut().expect("writer present after reconnect");
            match write_frame(stream, &frame) {
                Ok(()) => {
                    peer.counters.note_sent(wire_len);
                    return Ok(ClusterTicket::Remote { peer: peer.id, trace_id, rx });
                }
                Err(_) => {
                    *writer = None;
                    attempt_reconnect = true;
                }
            }
        }
        peer.inflight.lock().unwrap().remove(&req_id);
        let request = decode_request_back(&frame);
        Err((request, ClusterError::Disconnected { peer: peer.id }))
    }
}

/// Recover the owned request from its already-encoded frame (used only
/// on the failed-send path, where the original was moved into the
/// encoder).
fn decode_request_back(frame: &[u8]) -> MitigationRequest {
    match decode_message(frame) {
        Ok(Message::Request { request, .. }) => *request,
        _ => unreachable!("frame was encoded from a Request"),
    }
}

// ---------------------------------------------------------------------
// Server side
// ---------------------------------------------------------------------

/// The accept-loop half of a cluster node (`qai serve --listen`).
pub struct ClusterServer {
    addr: ClusterAddr,
    shutdown: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl ClusterServer {
    /// Bind `addr` and start serving `engine` to connecting peers.
    /// `stats` collects per-peer traffic counters (attach it to the
    /// engine for `scope=transport` metrics lines).
    pub fn start(
        engine: Arc<Engine>,
        node_id: u64,
        addr: &str,
        stats: Arc<ClusterTransportStats>,
    ) -> Result<ClusterServer, ClusterError> {
        let listener = ClusterListener::bind(&ClusterAddr::parse(addr))
            .map_err(|e| ClusterError::Io(e.to_string()))?;
        let addr = listener.local_addr().map_err(|e| ClusterError::Io(e.to_string()))?;
        listener
            .set_nonblocking(true)
            .map_err(|e| ClusterError::Io(e.to_string()))?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let flag = Arc::clone(&shutdown);
        let handle = std::thread::spawn(move || {
            accept_loop(listener, engine, node_id, stats, flag);
        });
        Ok(ClusterServer { addr, shutdown, handle: Some(handle) })
    }

    /// The bound listen address (OS-assigned ports resolved).
    pub fn addr(&self) -> &ClusterAddr {
        &self.addr
    }

    /// Block until a peer requests shutdown, then join the accept
    /// loop.
    pub fn wait(&mut self) {
        while !self.shutdown.load(Ordering::SeqCst) {
            std::thread::sleep(Duration::from_millis(50));
        }
        if let Some(h) = self.handle.take() {
            let _joined = h.join();
        }
    }

    /// Request shutdown locally and join the accept loop.
    pub fn stop(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        if let Some(h) = self.handle.take() {
            let _joined = h.join();
        }
    }
}

impl Drop for ClusterServer {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        if let Some(h) = self.handle.take() {
            let _joined = h.join();
        }
    }
}

fn accept_loop(
    listener: ClusterListener,
    engine: Arc<Engine>,
    node_id: u64,
    stats: Arc<ClusterTransportStats>,
    shutdown: Arc<AtomicBool>,
) {
    loop {
        if shutdown.load(Ordering::SeqCst) {
            return;
        }
        match listener.accept() {
            Ok(stream) => {
                let engine = Arc::clone(&engine);
                let stats = Arc::clone(&stats);
                let shutdown = Arc::clone(&shutdown);
                std::thread::spawn(move || {
                    serve_connection(stream, engine, node_id, stats, shutdown);
                });
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(20));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(20)),
        }
    }
}

/// Serve one accepted peer connection: handshake, then framed
/// request/response traffic until EOF or a `Shutdown` message. A bad
/// handshake (wrong magic or protocol version) drops the connection
/// without a `Welcome` — the codec's typed error is the server's log
/// line, never a panic.
fn serve_connection(
    mut stream: Box<dyn Duplex>,
    engine: Arc<Engine>,
    node_id: u64,
    stats: Arc<ClusterTransportStats>,
    shutdown: Arc<AtomicBool>,
) {
    let first = match read_frame(&mut stream) {
        Ok(f) => f,
        Err(_) => return,
    };
    let peer_id = match decode_message(&first) {
        Ok(Message::Hello(h)) => h.node_id,
        _ => return, // bad magic / version / message: refuse silently
    };
    let cell = stats.register(peer_id);
    cell.note_recv(first.len() as u64 + 4);
    let welcome = encode_message(&Message::Welcome {
        node_id,
        version: PROTOCOL_VERSION,
        nodes: vec![node_id],
    });
    if write_frame(&mut stream, &welcome).is_err() {
        return;
    }
    cell.note_sent(welcome.len() as u64 + 4);

    let writer: Arc<Mutex<Box<dyn Duplex>>> = match stream.try_clone_box() {
        Ok(w) => Arc::new(Mutex::new(w)),
        Err(_) => return,
    };
    loop {
        let frame = match read_frame(&mut stream) {
            Ok(f) => f,
            Err(_) => return, // peer dropped; in-flight work is abandoned
        };
        cell.note_recv(frame.len() as u64 + 4);
        match decode_message(&frame) {
            Ok(Message::Request { req_id, request }) => {
                let engine = Arc::clone(&engine);
                let writer = Arc::clone(&writer);
                let cell = Arc::clone(&cell);
                // One thread per in-flight remote request: submit
                // re-anchors the remaining-budget deadline at enqueue,
                // and the blocking wait runs off the accept path.
                std::thread::spawn(move || {
                    let outcome = execute_remote(&engine, *request);
                    let reply = encode_message(&Message::Response {
                        req_id,
                        outcome: Box::new(outcome),
                    });
                    let mut w = writer.lock().unwrap();
                    if write_frame(&mut *w, &reply).is_ok() {
                        cell.note_sent(reply.len() as u64 + 4);
                    }
                });
            }
            Ok(Message::Shutdown) => {
                shutdown.store(true, Ordering::SeqCst);
                return;
            }
            Ok(_) | Err(_) => return,
        }
    }
}

/// Run one remotely received request on the local engine, folding
/// every failure into a typed [`RemoteOutcome`].
fn execute_remote(engine: &Engine, request: MitigationRequest) -> RemoteOutcome {
    match engine.submit(request) {
        Ok(ticket) => match ticket.wait() {
            Ok(resp) => RemoteOutcome::Ok(resp),
            Err(e) => {
                RemoteOutcome::Rejected { kind: RejectKind::Failed, message: e.to_string() }
            }
        },
        Err(e) => RemoteOutcome::Rejected {
            kind: RejectKind::from_submit(&e),
            message: e.to_string(),
        },
    }
}

/// Connect to a listening node and ask it to shut down (used by tests,
/// benches, and operational tooling).
pub fn request_shutdown(addr: &str, node_id: u64) -> Result<(), ClusterError> {
    let mut stream = connect_backoff(&ClusterAddr::parse(addr), 10)
        .map_err(|e| ClusterError::Io(e.to_string()))?;
    client_handshake(&mut stream, node_id)?;
    write_frame(&mut stream, &encode_message(&Message::Shutdown)).map_err(ClusterError::Wire)
}
