//! Data substrates: grid types, synthetic dataset analogs, raw f32 I/O.

pub mod grid;
pub mod io;
pub mod synthetic;
