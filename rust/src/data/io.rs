//! Raw binary field I/O in the SZ ecosystem convention: little-endian
//! IEEE-754 f32, row-major, no header (dims supplied out of band — here
//! through the CLI / config, like `sz -3 512 512 512`).

use crate::data::grid::Grid;
use anyhow::{Context, Result};
use std::io::{Read, Write};
use std::path::Path;

/// Read a raw little-endian f32 file into a grid of the given dims.
pub fn read_f32(path: &Path, user_dims: &[usize]) -> Result<Grid<f32>> {
    let mut file = std::fs::File::open(path).with_context(|| format!("open {path:?}"))?;
    let mut bytes = Vec::new();
    file.read_to_end(&mut bytes).with_context(|| format!("read {path:?}"))?;
    let expect = user_dims.iter().product::<usize>() * 4;
    anyhow::ensure!(
        bytes.len() == expect,
        "{path:?}: got {} bytes, expected {expect} for dims {user_dims:?}",
        bytes.len()
    );
    let data = bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    Ok(Grid::from_vec(data, user_dims))
}

/// Write a grid as raw little-endian f32.
pub fn write_f32(path: &Path, grid: &Grid<f32>) -> Result<()> {
    let mut bytes = Vec::with_capacity(grid.len() * 4);
    for &v in &grid.data {
        bytes.extend_from_slice(&v.to_le_bytes());
    }
    let mut file = std::fs::File::create(path).with_context(|| format!("create {path:?}"))?;
    file.write_all(&bytes).with_context(|| format!("write {path:?}"))?;
    Ok(())
}

/// Write an arbitrary byte buffer (compressed streams).
pub fn write_bytes(path: &Path, bytes: &[u8]) -> Result<()> {
    std::fs::write(path, bytes).with_context(|| format!("write {path:?}"))?;
    Ok(())
}

/// Read an arbitrary byte buffer (compressed streams).
pub fn read_bytes(path: &Path) -> Result<Vec<u8>> {
    std::fs::read(path).with_context(|| format!("read {path:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpfile(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("qai_io_test_{}_{name}", std::process::id()));
        p
    }

    #[test]
    fn f32_roundtrip() {
        let path = tmpfile("rt.f32");
        let g = Grid::from_vec(vec![1.5f32, -2.25, 0.0, f32::MIN_POSITIVE], &[2, 2]);
        write_f32(&path, &g).unwrap();
        let h = read_f32(&path, &[2, 2]).unwrap();
        assert_eq!(g.data, h.data);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn wrong_dims_rejected() {
        let path = tmpfile("bad.f32");
        let g = Grid::from_vec(vec![0.0f32; 6], &[6]);
        write_f32(&path, &g).unwrap();
        assert!(read_f32(&path, &[7]).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn bytes_roundtrip() {
        let path = tmpfile("bytes.bin");
        write_bytes(&path, &[1, 2, 3, 255]).unwrap();
        assert_eq!(read_bytes(&path).unwrap(), vec![1, 2, 3, 255]);
        std::fs::remove_file(&path).ok();
    }
}
