//! Synthetic dataset analogs (substitution for the paper's real datasets,
//! see DESIGN.md §5).
//!
//! The paper evaluates on CESM (climate, 2D), Hurricane (weather, 3D),
//! NYX (cosmology, 3D), S3D (combustion, 3D), JHTDB (turbulence, 3D) and
//! uses Miranda (density) for the Fig. 2 characterization. Those files
//! are not redistributable here, so each generator below synthesizes a
//! deterministic field with the *smoothness regime* and value structure
//! that drives pre-quantization artifacts in that dataset family:
//! posterization banding appears wherever the local gradient is small
//! relative to `2ε·range`, and its geometry follows the level sets.
//!
//! All generators are seeded and pure — every experiment in
//! EXPERIMENTS.md reproduces bit-for-bit from (kind, dims, seed).

use crate::data::grid::Grid;
use crate::util::rng::Rng;

/// Which dataset family to synthesize.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DatasetKind {
    /// CESM-like: smooth large-scale 2D climate field (e.g. cloud cover)
    /// with banded latitudinal structure + mesoscale detail.
    ClimateLike,
    /// Hurricane-like: 3D swirl (vortex) field with an eye and spiral
    /// bands; matches fields such as Uf48/Wf48.
    HurricaneLike,
    /// NYX-like: cosmological density/velocity — broadly smooth with
    /// clustered high-magnitude halos (heavy-tailed values).
    CosmologyLike,
    /// S3D-like: combustion scalar with a sharp flame front separating
    /// two smooth plateaus (large smooth areas → strongest banding).
    CombustionLike,
    /// JHTDB-like: isotropic turbulence with a power-law spectrum.
    TurbulenceLike,
    /// Miranda-like: density with smooth mixing-layer contours, used for
    /// the Fig. 2 characterization.
    MirandaLike,
}

impl DatasetKind {
    /// Paper dataset this analog stands in for.
    pub fn paper_name(&self) -> &'static str {
        match self {
            DatasetKind::ClimateLike => "CESM",
            DatasetKind::HurricaneLike => "Hurricane",
            DatasetKind::CosmologyLike => "NYX",
            DatasetKind::CombustionLike => "S3D",
            DatasetKind::TurbulenceLike => "JHTDB",
            DatasetKind::MirandaLike => "Miranda",
        }
    }

    /// All kinds used in the small-scale (rate-distortion) experiments.
    pub fn small_scale() -> [DatasetKind; 4] {
        [
            DatasetKind::ClimateLike,
            DatasetKind::HurricaneLike,
            DatasetKind::CosmologyLike,
            DatasetKind::CombustionLike,
        ]
    }
}

/// A named field from a dataset analog (datasets have multiple fields in
/// the paper; we model that with per-field seeds).
#[derive(Debug, Clone)]
pub struct Field {
    /// Dataset family.
    pub kind: DatasetKind,
    /// Field name, e.g. "CLDHGH" analog.
    pub name: String,
    /// The data.
    pub grid: Grid<f32>,
}

/// Generate one field of the given kind. `dims` follows the paper's
/// dimension convention (2D for climate, 3D otherwise, but any 1..=3 dims
/// work). `seed` selects the field variant.
///
/// Generators are *resolution-aware*: spectral content is capped so the
/// shortest wavelength spans ≥ ~8 cells of the smallest active dim. The
/// paper's datasets are 512²+ grids of locally smooth physics; without
/// this cap a small test grid would alias into a rough field that never
/// exhibits the banding regime under study.
pub fn generate(kind: DatasetKind, dims: &[usize], seed: u64) -> Grid<f32> {
    let mut g = Grid::<f32>::zeros(dims);
    let mut rng = Rng::new(seed ^ 0xDA7A_5E7 ^ (kind as u64) << 32);
    let min_dim = dims.iter().copied().filter(|&d| d > 1).min().unwrap_or(1).max(2);
    let sm = Smoothness {
        // Shortest wavelength ≥ ~32 cells: the banding regime of the
        // paper's 512³-class data at value-range-relative bounds of
        // 1e-3..1e-2 (per-cell value change ≪ 2ε·range).
        k_cap: (min_dim as f64 / 32.0).max(1.5),
        min_feature: 8.0 / min_dim as f64,
        two_d: g.shape.ndim < 3,
    };
    match kind {
        DatasetKind::ClimateLike => climate(&mut g, &mut rng, sm),
        DatasetKind::HurricaneLike => hurricane(&mut g, &mut rng, sm),
        DatasetKind::CosmologyLike => cosmology(&mut g, &mut rng, sm),
        DatasetKind::CombustionLike => combustion(&mut g, &mut rng, sm),
        DatasetKind::TurbulenceLike => turbulence(&mut g, &mut rng, sm),
        DatasetKind::MirandaLike => miranda(&mut g, &mut rng, sm),
    }
    g
}

/// Resolution-dependent smoothness limits.
#[derive(Clone, Copy)]
struct Smoothness {
    /// Max wavenumber (cycles per domain) any spectral mix may use.
    k_cap: f64,
    /// Minimum feature scale (fraction of the domain) for fronts/halos.
    min_feature: f64,
    /// True for 1D/2D grids, where normalized axis 0 is degenerate and
    /// "front" directions must use an active axis.
    two_d: bool,
}

impl Smoothness {
    fn k(&self, requested: f64) -> f64 {
        requested.min(self.k_cap)
    }
    fn feat(&self, requested: f64) -> f64 {
        requested.max(self.min_feature)
    }
    /// Coordinate to use as the "front"/stratification direction.
    fn front(&self, x: f64, y: f64) -> f64 {
        if self.two_d {
            y
        } else {
            x
        }
    }
}

/// Generate a catalog of `n_fields` named fields for a dataset analog.
pub fn field_catalog(kind: DatasetKind, dims: &[usize], n_fields: usize, seed: u64) -> Vec<Field> {
    (0..n_fields)
        .map(|f| Field {
            kind,
            name: format!("{}_f{f}", kind.paper_name()),
            grid: generate(kind, dims, seed.wrapping_add(f as u64 * 7919)),
        })
        .collect()
}

/// Normalized coordinates in [0,1] for each axis (unit-extent axes → 0).
#[inline]
fn unit_coords(g: &Grid<f32>, i: usize, j: usize, k: usize) -> (f64, f64, f64) {
    let d = g.shape.dims;
    let u = |x: usize, n: usize| if n > 1 { x as f64 / (n - 1) as f64 } else { 0.0 };
    (u(i, d[0]), u(j, d[1]), u(k, d[2]))
}

/// Fill by evaluating `f(x, y, z)` at every grid point (x slowest axis).
fn fill(g: &mut Grid<f32>, f: impl Fn(f64, f64, f64) -> f64) {
    let dims = g.shape.dims;
    let mut idx = 0usize;
    for i in 0..dims[0] {
        for j in 0..dims[1] {
            for k in 0..dims[2] {
                let (x, y, z) = {
                    let u = |p: usize, n: usize| if n > 1 { p as f64 / (n - 1) as f64 } else { 0.0 };
                    (u(i, dims[0]), u(j, dims[1]), u(k, dims[2]))
                };
                g.data[idx] = f(x, y, z) as f32;
                idx += 1;
            }
        }
    }
    let _ = unit_coords; // kept for external callers/tests
}

/// A band-limited random field: sum of `modes` random plane waves with
/// amplitude ~ k^(-slope). This is the common building block — smoothness
/// is controlled by the spectral slope and max wavenumber.
struct SpectralMix {
    modes: Vec<(f64, f64, f64, f64, f64)>, // (kx, ky, kz, phase, amp)
}

impl SpectralMix {
    fn new(rng: &mut Rng, n_modes: usize, k_min: f64, k_max: f64, slope: f64) -> Self {
        let mut modes = Vec::with_capacity(n_modes);
        for _ in 0..n_modes {
            // log-uniform wavenumber magnitude
            let lk = rng.range_f64(k_min.ln(), k_max.ln());
            let kmag = lk.exp();
            // random direction
            let theta = rng.range_f64(0.0, std::f64::consts::PI);
            let phi = rng.range_f64(0.0, 2.0 * std::f64::consts::PI);
            let (st, ct) = theta.sin_cos();
            let (sp, cp) = phi.sin_cos();
            let dir = (st * cp, st * sp, ct);
            let amp = kmag.powf(-slope);
            modes.push((
                kmag * dir.0,
                kmag * dir.1,
                kmag * dir.2,
                rng.range_f64(0.0, 2.0 * std::f64::consts::PI),
                amp,
            ));
        }
        SpectralMix { modes }
    }

    #[inline]
    fn eval(&self, x: f64, y: f64, z: f64) -> f64 {
        let tau = 2.0 * std::f64::consts::PI;
        self.modes
            .iter()
            .map(|&(kx, ky, kz, ph, a)| a * (tau * (kx * x + ky * y + kz * z) + ph).sin())
            .sum()
    }
}

fn climate(g: &mut Grid<f32>, rng: &mut Rng, sm: Smoothness) {
    // Latitudinal banding + synoptic-scale waves + mesoscale detail,
    // clipped to [0, 1] like a cloud-fraction field.
    let synoptic = SpectralMix::new(rng, 24, 1.0, sm.k(6.0), 1.2);
    let meso = SpectralMix::new(rng, 32, sm.k(6.0).min(3.0), sm.k(24.0), 1.6);
    let band_freq = rng.range_f64(2.0, 4.0);
    fill(g, |_x, y, z| {
        let lat = y; // rows = latitude
        let band = 0.45 + 0.3 * (band_freq * std::f64::consts::PI * lat).sin();
        let v = band + 0.35 * synoptic.eval(0.0, y, z) + 0.08 * meso.eval(0.0, y, z);
        // Soft saturation into [0, 1]: cloud-fraction-like squashing that
        // keeps a small gradient everywhere (a hard clamp would create
        // exactly-constant plateaus, which over-represent the paper's
        // known homogeneous-region limitation, §IX).
        0.5 + 0.5 * ((v - 0.5) / 0.4).tanh()
    });
}

fn hurricane(g: &mut Grid<f32>, rng: &mut Rng, sm: Smoothness) {
    // Swirl velocity component around a tilted eye + spiral bands.
    let cx = rng.range_f64(0.4, 0.6);
    let cy = rng.range_f64(0.4, 0.6);
    let tilt = rng.range_f64(-0.15, 0.15);
    let bands = SpectralMix::new(rng, 16, 1.5, sm.k(12.0), 1.3);
    let spiral_k = sm.k(rng.range_f64(6.0, 10.0));
    fill(g, |x, y, z| {
        let (ex, ey) = (cy + tilt * (x - 0.5), cx + tilt * (0.5 - x));
        let dy = y - ex;
        let dz = z - ey;
        let r = (dy * dy + dz * dz).sqrt().max(1e-6);
        let theta = dz.atan2(dy);
        // Rankine-like vortex tangential speed profile.
        let r_eye = sm.feat(0.08);
        let v_t = if r < r_eye { r / r_eye } else { (r_eye / r).powf(0.6) };
        let spiral = 0.25 * (spiral_k * theta + sm.k(18.0) * r).sin() * (-3.0 * r).exp();
        40.0 * (v_t * (-1.5 * x).exp() + spiral) + 4.0 * bands.eval(x, y, z)
    });
}

fn cosmology(g: &mut Grid<f32>, rng: &mut Rng, sm: Smoothness) {
    // Smooth background + clustered Gaussian halos with heavy-tailed
    // amplitudes (log-normal-ish), like a baryon density field.
    let bg = SpectralMix::new(rng, 24, 1.0, sm.k(8.0), 1.8);
    let n_halos = 40;
    let mut halos = Vec::with_capacity(n_halos);
    for _ in 0..n_halos {
        let amp = (rng.normal() * 1.2).exp(); // log-normal
        halos.push((
            rng.f64(),
            rng.f64(),
            rng.f64(),
            sm.feat(rng.range_f64(0.01, 0.06)), // radius
            amp,
        ));
    }
    fill(g, |x, y, z| {
        let mut v = 1.0 + 0.2 * bg.eval(x, y, z);
        for &(hx, hy, hz, r, a) in &halos {
            let d2 = (x - hx).powi(2) + (y - hy).powi(2) + (z - hz).powi(2);
            v += a * (-d2 / (2.0 * r * r)).exp();
        }
        v * 1e8 // NYX density magnitudes are ~1e8..1e11
    });
}

fn combustion(g: &mut Grid<f32>, rng: &mut Rng, sm: Smoothness) {
    // Two smooth plateaus separated by a wrinkled flame front: the
    // plateau regions are where pre-quantization banding is worst.
    let wrinkle = SpectralMix::new(rng, 20, 2.0, sm.k(10.0), 1.4);
    let plateau = SpectralMix::new(rng, 12, 1.0, sm.k(4.0), 1.5);
    let front_pos = rng.range_f64(0.4, 0.6);
    let front_width = sm.feat(rng.range_f64(0.02, 0.05));
    fill(g, |x, y, z| {
        let fx = sm.front(x, y);
        let front = front_pos + 0.08 * wrinkle.eval(0.0, y, z);
        let phase = ((fx - front) / front_width).tanh(); // -1 burnt, +1 fresh
        let base = 0.5 + 0.5 * phase;
        // Plateaus carry weak but nonzero structure (species diffusion).
        base * 0.12 + 0.01 * plateau.eval(x, y, z) * (1.0 - phase * phase)
            + 0.004 * plateau.eval(z, x, y)
    });
}

fn turbulence(g: &mut Grid<f32>, rng: &mut Rng, sm: Smoothness) {
    // Kolmogorov-like spectrum: E(k) ~ k^-5/3 → amplitude slope ~ 5/6+1.
    let mix = SpectralMix::new(rng, 96, 1.0, sm.k(32.0), 11.0 / 6.0);
    fill(g, |x, y, z| mix.eval(x, y, z));
}

fn miranda(g: &mut Grid<f32>, rng: &mut Rng, sm: Smoothness) {
    // Density across a perturbed mixing layer: two fluids + interface
    // roll-ups, yielding the smooth contoured field of the paper's Fig 2.
    let interface = SpectralMix::new(rng, 16, 2.0, sm.k(8.0), 1.2);
    let rolls = SpectralMix::new(rng, 24, 2.0, sm.k(16.0), 1.5);
    fill(g, |x, y, z| {
        let fx = sm.front(x, y);
        let iface = 0.5 + 0.1 * interface.eval(0.0, 0.3 * y, z);
        let s = ((fx - iface) / sm.feat(0.08)).tanh();
        let rho = 1.5 + 0.5 * s; // 1.0 .. 2.0 g/cc
        // Roll-ups concentrate at the interface, but the bulk fluids keep
        // gentle acoustic/stratification structure — never exactly flat.
        rho + 0.05 * rolls.eval(x, y, z) * (1.0 - s * s) + 0.015 * interface.eval(x, y, z)
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let a = generate(DatasetKind::TurbulenceLike, &[16, 16, 16], 1);
        let b = generate(DatasetKind::TurbulenceLike, &[16, 16, 16], 1);
        assert_eq!(a.data, b.data);
        let c = generate(DatasetKind::TurbulenceLike, &[16, 16, 16], 2);
        assert_ne!(a.data, c.data);
    }

    #[test]
    fn all_kinds_generate_finite_nonconstant_fields() {
        for kind in [
            DatasetKind::ClimateLike,
            DatasetKind::HurricaneLike,
            DatasetKind::CosmologyLike,
            DatasetKind::CombustionLike,
            DatasetKind::TurbulenceLike,
            DatasetKind::MirandaLike,
        ] {
            let dims: &[usize] =
                if kind == DatasetKind::ClimateLike { &[32, 64] } else { &[16, 16, 16] };
            let g = generate(kind, dims, 99);
            assert!(g.data.iter().all(|v| v.is_finite()), "{kind:?} not finite");
            assert!(g.value_range() > 0.0, "{kind:?} constant");
        }
    }

    #[test]
    fn climate_clamped_to_unit() {
        let g = generate(DatasetKind::ClimateLike, &[64, 64], 5);
        let (lo, hi) = g.min_max();
        assert!(lo >= 0.0 && hi <= 1.0);
    }

    #[test]
    fn cosmology_is_heavy_tailed() {
        let g = generate(DatasetKind::CosmologyLike, &[24, 24, 24], 7);
        let (lo, hi) = g.min_max();
        let mean = g.data.iter().map(|&v| v as f64).sum::<f64>() / g.len() as f64;
        // peak well above mean → clustered halos present (radii are
        // widened on tiny grids by the smoothness floor, so the peak is
        // fuzzier than at production resolution)
        assert!((hi as f64) > 1.5 * mean, "hi={hi} mean={mean} lo={lo}");
    }

    #[test]
    fn field_catalog_names_and_variants() {
        let fields = field_catalog(DatasetKind::ClimateLike, &[16, 16], 3, 42);
        assert_eq!(fields.len(), 3);
        assert_eq!(fields[0].name, "CESM_f0");
        assert_ne!(fields[0].grid.data, fields[1].grid.data);
    }

    #[test]
    fn combustion_has_two_plateaus() {
        let g = generate(DatasetKind::CombustionLike, &[32, 32, 32], 11);
        let (lo, hi) = g.min_max();
        // count points near each plateau
        let near_lo = g.data.iter().filter(|&&v| (v - lo) < 0.25 * (hi - lo)).count();
        let near_hi = g.data.iter().filter(|&&v| (hi - v) < 0.25 * (hi - lo)).count();
        assert!(near_lo > g.len() / 10 && near_hi > g.len() / 10);
    }
}
