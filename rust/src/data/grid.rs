//! Dense regular grids of scalars (1D/2D/3D), the common currency of the
//! whole stack: compressors consume and produce them, the mitigation
//! pipeline transforms them, the metrics compare them.
//!
//! Internally every grid is normalized to 3D row-major `[d0, d1, d2]`
//! (d2 fastest-varying); lower-dimensional data uses leading dims of 1.
//! Algorithms that walk neighbors simply skip axes of extent 1, which
//! makes the boundary/EDT/filter code dimension-generic for free.
//!
//! [`SharedGrid`] is the zero-copy companion: an `Arc`-backed,
//! immutable view of a [`Grid`] whose clone is a pointer bump. It is
//! the currency of the serving layer — a
//! [`Job`](crate::mitigation::service::Job) holds its inputs as
//! `SharedGrid`s so submission, queueing, and batch fan-out move
//! pointers instead of copying fields.

use std::sync::Arc;

/// Shape of a grid, normalized to 3 dims (leading 1s for 1D/2D data).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Shape {
    /// Normalized dims `[d0, d1, d2]`, `d2` fastest.
    pub dims: [usize; 3],
    /// Dimensionality the user declared (1, 2 or 3).
    pub ndim: usize,
}

impl Shape {
    /// Build from a user-facing dims slice (1..=3 entries, all > 0).
    pub fn new(user_dims: &[usize]) -> Self {
        assert!(
            (1..=3).contains(&user_dims.len()),
            "grids are 1D..3D, got {} dims",
            user_dims.len()
        );
        assert!(user_dims.iter().all(|&d| d > 0), "zero-sized dim in {user_dims:?}");
        let mut dims = [1usize; 3];
        dims[3 - user_dims.len()..].copy_from_slice(user_dims);
        Shape { dims, ndim: user_dims.len() }
    }

    /// Total number of elements.
    #[inline]
    pub fn len(&self) -> usize {
        self.dims[0] * self.dims[1] * self.dims[2]
    }

    /// True for an empty shape (never constructed via `new`).
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Flat index of `(i, j, k)` in normalized coordinates.
    #[inline]
    pub fn idx(&self, i: usize, j: usize, k: usize) -> usize {
        debug_assert!(i < self.dims[0] && j < self.dims[1] && k < self.dims[2]);
        (i * self.dims[1] + j) * self.dims[2] + k
    }

    /// Inverse of [`Shape::idx`].
    #[inline]
    pub fn coords(&self, flat: usize) -> (usize, usize, usize) {
        let k = flat % self.dims[2];
        let j = (flat / self.dims[2]) % self.dims[1];
        let i = flat / (self.dims[1] * self.dims[2]);
        (i, j, k)
    }

    /// Strides (in elements) of the three normalized axes.
    #[inline]
    pub fn strides(&self) -> [usize; 3] {
        [self.dims[1] * self.dims[2], self.dims[2], 1]
    }

    /// The user-facing dims (without leading 1s).
    pub fn user_dims(&self) -> &[usize] {
        &self.dims[3 - self.ndim..]
    }

    /// Axes with extent > 1, i.e. the axes along which neighbors exist.
    pub fn active_axes(&self) -> impl Iterator<Item = usize> + '_ {
        (0..3).filter(|&a| self.dims[a] > 1)
    }
}

/// A dense grid of `T` over a [`Shape`].
#[derive(Debug, Clone, PartialEq)]
pub struct Grid<T = f32> {
    /// Shape/layout of the grid.
    pub shape: Shape,
    /// Row-major data, `shape.len()` elements.
    pub data: Vec<T>,
}

impl<T: Copy + Default> Grid<T> {
    /// A zero/default-filled grid.
    pub fn zeros(user_dims: &[usize]) -> Self {
        let shape = Shape::new(user_dims);
        Grid { data: vec![T::default(); shape.len()], shape }
    }

    /// Same shape, default-filled.
    pub fn like<U>(other: &Grid<U>) -> Self {
        Grid { shape: other.shape, data: vec![T::default(); other.shape.len()] }
    }
}

impl<T: Copy> Grid<T> {
    /// Wrap an existing buffer (length must match the shape).
    pub fn from_vec(data: Vec<T>, user_dims: &[usize]) -> Self {
        let shape = Shape::new(user_dims);
        assert_eq!(data.len(), shape.len(), "data length != shape volume");
        Grid { shape, data }
    }

    /// Number of elements.
    #[inline]
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// True if the grid has no elements (cannot happen via constructors).
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Element at normalized coordinates.
    #[inline]
    pub fn at(&self, i: usize, j: usize, k: usize) -> T {
        self.data[self.shape.idx(i, j, k)]
    }

    /// Mutable element at normalized coordinates.
    #[inline]
    pub fn at_mut(&mut self, i: usize, j: usize, k: usize) -> &mut T {
        let idx = self.shape.idx(i, j, k);
        &mut self.data[idx]
    }

    /// Copy the sub-block `[lo, lo+size)` (normalized coords) into a new
    /// grid of shape `size`. Used by the coordinator's scatter.
    pub fn extract(&self, lo: [usize; 3], size: [usize; 3]) -> Grid<T> {
        for a in 0..3 {
            assert!(lo[a] + size[a] <= self.shape.dims[a], "extract out of bounds on axis {a}");
        }
        let mut out = Vec::with_capacity(size[0] * size[1] * size[2]);
        for i in 0..size[0] {
            for j in 0..size[1] {
                let src = self.shape.idx(lo[0] + i, lo[1] + j, lo[2]);
                out.extend_from_slice(&self.data[src..src + size[2]]);
            }
        }
        let mut g = Grid::from_vec(out, &[size[0], size[1], size[2]]);
        g.shape.ndim = self.shape.ndim;
        g
    }

    /// Write `block` into this grid at offset `lo`. Inverse of
    /// [`Grid::extract`]; used by the coordinator's gather.
    pub fn insert(&mut self, lo: [usize; 3], block: &Grid<T>) {
        let size = block.shape.dims;
        for a in 0..3 {
            assert!(lo[a] + size[a] <= self.shape.dims[a], "insert out of bounds on axis {a}");
        }
        for i in 0..size[0] {
            for j in 0..size[1] {
                let dst = self.shape.idx(lo[0] + i, lo[1] + j, lo[2]);
                let src = block.shape.idx(i, j, 0);
                self.data[dst..dst + size[2]].copy_from_slice(&block.data[src..src + size[2]]);
            }
        }
    }
}

impl Grid<f32> {
    /// (min, max) over the data. Panics on empty; NaNs are ignored unless
    /// all values are NaN (then returns (inf, -inf) like a fold).
    pub fn min_max(&self) -> (f32, f32) {
        assert!(!self.data.is_empty());
        let mut lo = f32::INFINITY;
        let mut hi = f32::NEG_INFINITY;
        for &v in &self.data {
            if v < lo {
                lo = v;
            }
            if v > hi {
                hi = v;
            }
        }
        (lo, hi)
    }

    /// Value range `max - min` (0 for constant fields).
    pub fn value_range(&self) -> f32 {
        let (lo, hi) = self.min_max();
        hi - lo
    }
}

/// An immutable, cheaply-cloneable (`Arc`-backed) shared grid.
///
/// The read-only currency of the data plane: cloning a `SharedGrid` is
/// a reference-count bump, never a data copy, so job payloads can fan
/// out through queues and batches for free. All of [`Grid`]'s read API
/// is available through `Deref`. Two escape hatches exist for the rare
/// writer: [`SharedGrid::make_mut`] (copy-on-write — clones the
/// underlying grid only if other handles still share it) and
/// [`SharedGrid::into_grid`] (unwrap, cloning only when shared).
///
/// Sharing is observable: [`SharedGrid::ptr_eq`] and
/// [`SharedGrid::handle_count`] let tests prove a path moved pointers
/// instead of copying grids.
pub struct SharedGrid<T = f32> {
    inner: Arc<Grid<T>>,
}

impl<T> Clone for SharedGrid<T> {
    fn clone(&self) -> Self {
        SharedGrid { inner: Arc::clone(&self.inner) }
    }
}

impl<T> std::ops::Deref for SharedGrid<T> {
    type Target = Grid<T>;
    fn deref(&self) -> &Grid<T> {
        &self.inner
    }
}

impl<T> From<Grid<T>> for SharedGrid<T> {
    fn from(grid: Grid<T>) -> Self {
        SharedGrid { inner: Arc::new(grid) }
    }
}

impl<T> From<Arc<Grid<T>>> for SharedGrid<T> {
    fn from(inner: Arc<Grid<T>>) -> Self {
        SharedGrid { inner }
    }
}

impl<T: std::fmt::Debug> std::fmt::Debug for SharedGrid<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("SharedGrid")
            .field("handles", &self.handle_count())
            .field("grid", &*self.inner)
            .finish()
    }
}

impl<T: PartialEq> PartialEq for SharedGrid<T> {
    fn eq(&self, other: &Self) -> bool {
        self.ptr_eq(other) || *self.inner == *other.inner
    }
}

impl<T> SharedGrid<T> {
    /// Wrap an owned grid (equivalent to `.into()`).
    pub fn new(grid: Grid<T>) -> Self {
        grid.into()
    }

    /// True iff both handles share the same allocation — the zero-copy
    /// observable: a path that moved this grid by pointer preserves it,
    /// a path that deep-copied cannot.
    pub fn ptr_eq(&self, other: &Self) -> bool {
        Arc::ptr_eq(&self.inner, &other.inner)
    }

    /// Number of live handles to this allocation.
    pub fn handle_count(&self) -> usize {
        Arc::strong_count(&self.inner)
    }
}

impl<T: Clone> SharedGrid<T> {
    /// Copy-on-write access: mutate in place when this is the only
    /// handle, otherwise clone the grid first (other handles keep the
    /// old data).
    pub fn make_mut(&mut self) -> &mut Grid<T> {
        Arc::make_mut(&mut self.inner)
    }

    /// Unwrap into an owned [`Grid`], cloning only if other handles
    /// still share the allocation.
    pub fn into_grid(self) -> Grid<T> {
        Arc::try_unwrap(self.inner).unwrap_or_else(|arc| (*arc).clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_normalizes_lower_dims() {
        let s1 = Shape::new(&[10]);
        assert_eq!(s1.dims, [1, 1, 10]);
        assert_eq!(s1.ndim, 1);
        let s2 = Shape::new(&[4, 5]);
        assert_eq!(s2.dims, [1, 4, 5]);
        assert_eq!(s2.user_dims(), &[4, 5]);
        let s3 = Shape::new(&[2, 3, 4]);
        assert_eq!(s3.dims, [2, 3, 4]);
        assert_eq!(s3.len(), 24);
    }

    #[test]
    #[should_panic(expected = "1D..3D")]
    fn shape_rejects_4d() {
        Shape::new(&[2, 2, 2, 2]);
    }

    #[test]
    fn idx_coords_roundtrip() {
        let s = Shape::new(&[3, 4, 5]);
        for flat in 0..s.len() {
            let (i, j, k) = s.coords(flat);
            assert_eq!(s.idx(i, j, k), flat);
        }
    }

    #[test]
    fn active_axes_skips_unit_dims() {
        let s = Shape::new(&[4, 5]);
        let axes: Vec<usize> = s.active_axes().collect();
        assert_eq!(axes, vec![1, 2]);
    }

    #[test]
    fn extract_insert_roundtrip() {
        let mut g = Grid::<f32>::zeros(&[4, 6, 8]);
        for (i, v) in g.data.iter_mut().enumerate() {
            *v = i as f32;
        }
        let block = g.extract([1, 2, 3], [2, 3, 4]);
        assert_eq!(block.shape.dims, [2, 3, 4]);
        assert_eq!(block.at(0, 0, 0), g.at(1, 2, 3));
        assert_eq!(block.at(1, 2, 3), g.at(2, 4, 6));

        let mut h = Grid::<f32>::zeros(&[4, 6, 8]);
        h.insert([1, 2, 3], &block);
        assert_eq!(h.at(2, 4, 6), g.at(2, 4, 6));
        assert_eq!(h.at(0, 0, 0), 0.0);
    }

    #[test]
    fn min_max_and_range() {
        let g = Grid::from_vec(vec![3.0f32, -1.0, 2.0, 0.5], &[4]);
        assert_eq!(g.min_max(), (-1.0, 3.0));
        assert_eq!(g.value_range(), 4.0);
    }

    #[test]
    fn grid_2d_indexing_matches_row_major() {
        let g = Grid::from_vec((0..12).map(|x| x as f32).collect(), &[3, 4]);
        assert_eq!(g.at(0, 2, 3), 11.0);
        assert_eq!(g.at(0, 0, 1), 1.0);
    }

    #[test]
    fn shared_grid_clone_is_a_pointer_bump() {
        let g: SharedGrid<f32> = Grid::from_vec(vec![1.0; 8], &[8]).into();
        let h = g.clone();
        assert!(g.ptr_eq(&h));
        assert_eq!(g.handle_count(), 2);
        // Read API flows through Deref.
        assert_eq!(h.len(), 8);
        assert_eq!(h.at(0, 0, 3), 1.0);
    }

    #[test]
    fn shared_grid_copy_on_write() {
        let mut g: SharedGrid<f32> = Grid::from_vec(vec![0.0; 4], &[4]).into();
        let snapshot = g.clone();
        *g.make_mut().at_mut(0, 0, 0) = 9.0;
        assert!(!g.ptr_eq(&snapshot), "make_mut on a shared handle must detach");
        assert_eq!(snapshot.at(0, 0, 0), 0.0, "other handles keep the old data");
        assert_eq!(g.at(0, 0, 0), 9.0);
        // Sole handle: mutation stays in place.
        let mut sole: SharedGrid<f32> = Grid::from_vec(vec![0.0; 4], &[4]).into();
        let before = sole.data.as_ptr();
        *sole.make_mut().at_mut(0, 0, 1) = 5.0;
        assert_eq!(sole.data.as_ptr(), before);
    }

    #[test]
    fn shared_grid_into_grid_unwraps_or_clones() {
        let g: SharedGrid<f32> = Grid::from_vec(vec![2.0; 4], &[4]).into();
        let keep = g.clone();
        let owned = g.into_grid(); // shared: clones
        assert_eq!(owned.data, keep.data);
        let sole: SharedGrid<f32> = Grid::from_vec(vec![3.0; 2], &[2]).into();
        let owned = sole.into_grid(); // sole handle: moves
        assert_eq!(owned.data, vec![3.0; 2]);
    }
}
