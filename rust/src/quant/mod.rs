//! Pre-quantization: the error-introducing stage of every compressor in
//! this repo (paper §III-A, Eq. 1).
//!
//! Given an absolute error bound `ε_abs`, each value maps to the integer
//! index `q = round(d / 2ε_abs)` and reconstructs as `d' = 2qε_abs`, so
//! `|d − d'| ≤ ε_abs` by construction. Everything downstream of this
//! stage (prediction, encoding) is lossless, which is what makes the
//! compressors parallel — and what makes the artifact structure entirely
//! determined by the quantization-index field `Q`.
//!
//! The paper's evaluation uses *value-range relative* bounds
//! (`ε_abs = ε_rel · (max−min)`, §VIII-B); [`ErrorBound`] carries either
//! form and resolves to a concrete [`ResolvedBound`] per field.

use crate::data::grid::Grid;

/// A user-specified error bound, absolute or value-range relative.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ErrorBound {
    /// Absolute bound in data units.
    Abs(f64),
    /// Relative to the field's value range (the paper's convention).
    Rel(f64),
}

impl ErrorBound {
    /// Convenience constructor for a relative bound.
    pub fn relative(eps: f64) -> Self {
        ErrorBound::Rel(eps)
    }

    /// Convenience constructor for an absolute bound.
    pub fn absolute(eps: f64) -> Self {
        ErrorBound::Abs(eps)
    }

    /// Resolve against concrete data (computes the value range if
    /// relative). Constant fields resolve a relative bound to a tiny
    /// positive absolute bound so quantization stays well-defined.
    pub fn resolve(self, data: &[f32]) -> ResolvedBound {
        match self {
            ErrorBound::Abs(a) => {
                assert!(a > 0.0, "error bound must be positive");
                ResolvedBound { abs: a, rel: None }
            }
            ErrorBound::Rel(r) => {
                assert!(r > 0.0, "error bound must be positive");
                let (lo, hi) = min_max(data);
                let range = (hi - lo) as f64;
                let abs = if range > 0.0 {
                    r * range
                } else {
                    // Constant field: any positive bound preserves the data
                    // exactly after rounding; pick one that keeps indices
                    // well inside i64 (|q| ≈ 5e8 at most).
                    let peak = hi.abs().max(lo.abs()) as f64;
                    if peak > 0.0 {
                        peak * 1e-9
                    } else {
                        1e-30
                    }
                };
                ResolvedBound { abs, rel: Some(r) }
            }
        }
    }
}

/// An error bound resolved to a concrete absolute value for one field.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ResolvedBound {
    /// Absolute bound used by quantization.
    pub abs: f64,
    /// The relative bound it came from, if any (for reporting).
    pub rel: Option<f64>,
}

fn min_max(data: &[f32]) -> (f32, f32) {
    let mut lo = f32::INFINITY;
    let mut hi = f32::NEG_INFINITY;
    for &v in data {
        if v < lo {
            lo = v;
        }
        if v > hi {
            hi = v;
        }
    }
    (lo, hi)
}

/// Quantization index type. i64 so that tiny absolute bounds on
/// large-magnitude fields (NYX densities ~1e11) cannot overflow.
pub type QIndex = i64;

/// Pre-quantize a field: `q_i = round(d_i / 2ε)`.
///
/// Runs on the [`crate::util::simd`] substrate (AVX2 when detected;
/// the scalar reference under `QAI_SIMD=scalar`) — both paths are
/// bit-identical, including round-half-away-from-zero ties.
pub fn quantize(data: &[f32], eb: ResolvedBound) -> Vec<QIndex> {
    let inv = 1.0 / (2.0 * eb.abs);
    let mut out: Vec<QIndex> = vec![0; data.len()];
    crate::util::simd::quantize(data, inv, &mut out);
    out
}

/// Reconstruct from indices: `d'_i = 2 q_i ε`.
pub fn dequantize(q: &[QIndex], eb: ResolvedBound) -> Vec<f32> {
    let mut out = vec![0.0f32; q.len()];
    dequantize_into(q, eb, &mut out);
    out
}

/// [`dequantize`] into a caller-provided buffer (`out.len() ==
/// q.len()`), so decoders can reconstruct into recycled scratch from
/// [`crate::util::arena`] instead of allocating per call. Every element
/// of `out` is overwritten.
pub fn dequantize_into(q: &[QIndex], eb: ResolvedBound, out: &mut [f32]) {
    assert_eq!(q.len(), out.len(), "dequantize buffer length mismatch");
    let two_eps = 2.0 * eb.abs;
    crate::util::simd::dequantize_into(q, two_eps, out);
}

/// Quantize-then-dequantize convenience: what a pre-quantization
/// compressor's decompressed output looks like (lossless downstream).
pub fn quantize_grid(grid: &Grid<f32>, eb: ResolvedBound) -> (Grid<QIndex>, Grid<f32>) {
    let q = quantize(&grid.data, eb);
    let dq = dequantize(&q, eb);
    let mut qg = Grid::from_vec(q, grid.shape.user_dims());
    let mut dg = Grid::from_vec(dq, grid.shape.user_dims());
    qg.shape.ndim = grid.shape.ndim;
    dg.shape.ndim = grid.shape.ndim;
    (qg, dg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::prop_check;

    #[test]
    fn round_half_cases() {
        let eb = ResolvedBound { abs: 0.5, rel: None };
        // interval width 2ε = 1.0; round() ties away from zero in Rust
        let q = quantize(&[0.0, 0.49, 0.5, -0.5, 1.0], eb);
        assert_eq!(q, vec![0, 0, 1, -1, 1]);
    }

    #[test]
    fn error_always_within_bound_property() {
        prop_check("|d - dq| <= eps", 200, |g| {
            let n = g.usize_in(1, 400);
            let data = g.smooth_field(n, 0.3);
            let eps = g.f64_in(1e-4, 0.5);
            let eb = ErrorBound::Abs(eps).resolve(&data);
            let q = quantize(&data, eb);
            let dq = dequantize(&q, eb);
            for (d, r) in data.iter().zip(&dq) {
                let err = (*d as f64 - *r as f64).abs();
                assert!(err <= eps * (1.0 + 1e-9), "err={err} eps={eps}");
            }
        });
    }

    #[test]
    fn relative_bound_scales_with_range() {
        let data = vec![0.0f32, 10.0];
        let eb = ErrorBound::relative(1e-2).resolve(&data);
        assert!((eb.abs - 0.1).abs() < 1e-12);
        assert_eq!(eb.rel, Some(1e-2));
    }

    #[test]
    fn constant_field_relative_bound_is_positive() {
        let data = vec![3.0f32; 10];
        let eb = ErrorBound::relative(1e-3).resolve(&data);
        assert!(eb.abs > 0.0);
        let q = quantize(&data, eb);
        let dq = dequantize(&q, eb);
        for (d, r) in data.iter().zip(&dq) {
            assert!((d - r).abs() <= (eb.abs * 1.001) as f32 + f32::EPSILON);
        }
    }

    #[test]
    fn large_magnitude_field_does_not_overflow() {
        // NYX-like densities with a tight relative bound.
        let data = vec![1.0e11f32, 5.0e10, 0.0];
        let eb = ErrorBound::relative(1e-6).resolve(&data);
        let q = quantize(&data, eb);
        let dq = dequantize(&q, eb);
        for (d, r) in data.iter().zip(&dq) {
            // f32 rounding of the reconstruction costs at most ~1 ulp of 1e11
            let tol = eb.abs * 1.001 + 1.0e11 * f32::EPSILON as f64;
            assert!(((*d - *r) as f64).abs() <= tol);
        }
        assert!(q[0] > q[1]);
    }

    #[test]
    fn quantize_grid_preserves_shape() {
        let g = Grid::from_vec((0..24).map(|x| x as f32).collect(), &[4, 6]);
        let eb = ErrorBound::absolute(0.5).resolve(&g.data);
        let (qg, dg) = quantize_grid(&g, eb);
        assert_eq!(qg.shape, g.shape);
        assert_eq!(dg.shape, g.shape);
    }
}
