//! Fused, pooled SSIM kernels.
//!
//! [`ssim`](super::ssim::ssim) computes its five window moments with
//! five separate sequential box-sum passes. The kernels here fuse all
//! five moments into **one traversal per axis** — each line is gathered
//! once into thread-local scratch and the sums for x, y, x², y², xy are
//! produced together — and run the line loop on the shared worker pool
//! ([`util::pool`](crate::util::pool)), with every full-grid
//! intermediate held in [`ArenaLease`](crate::util::arena::ArenaLease)
//! scratch so warm repeated evaluations allocate nothing. That makes
//! the metric cheap enough to evaluate inline on every serving response
//! (the `quality=` field in
//! [`MitigationResponse`](crate::mitigation::MitigationResponse)).
//!
//! Two window shapes are exposed:
//!
//! * [`ssim_fast`] — the **uniform (box) window**, a drop-in for the
//!   reference [`ssim`](super::ssim::ssim): same normalization, same
//!   anchor grid, and the per-line rolling sums replay the reference's
//!   arithmetic operation-for-operation, so the result is bit-identical
//!   for every window/stride/thread-count combination (the
//!   `rust/tests/quality.rs` matrix pins `|Δ| ≤ 1e-9`).
//! * [`ssim_gaussian`] — the 11-tap **gaussian window** (σ = 1.5, the
//!   convention of Wang et al. and the gausplat/mssim exemplar),
//!   evaluated densely at every valid window position. Used as the
//!   default quality score for [`QualityTarget::Ssim`](crate::mitigation::QualityTarget).
//!
//! Determinism: within an axis pass every line is computed with
//! identical arithmetic regardless of which worker runs it, axis passes
//! are sequential, and the final anchor reduction writes per-anchor
//! scores into an indexed buffer summed serially — so the result is
//! bitwise independent of thread count and of work-stealing order.

use crate::data::grid::Grid;
use crate::util::arena::ArenaHandle;
use crate::util::pool::{self, PoolHandle, UnsafeSlice};

use super::ssim::{C1, C2};

/// Window extent of the gaussian SSIM kernel (taps per axis).
pub const GAUSSIAN_WINDOW: usize = 11;
/// Standard deviation of the gaussian SSIM window.
pub const GAUSSIAN_SIGMA: f64 = 1.5;

/// Pooled uniform-window SSIM, bit-identical to the reference
/// [`ssim`](super::ssim::ssim) (same window/stride semantics). Runs on
/// the global pool with full parallelism and fresh scratch.
pub fn ssim_fast(original: &Grid<f32>, other: &Grid<f32>, window: usize, stride: usize) -> f64 {
    ssim_fast_on(
        PoolHandle::Global,
        ArenaHandle::Fresh,
        original,
        other,
        window,
        stride,
        pool::parallelism(),
    )
}

/// [`ssim_fast`] with an explicit worker count (`threads <= 1` runs
/// inline and never touches the global pool).
pub fn ssim_fast_threads(
    original: &Grid<f32>,
    other: &Grid<f32>,
    window: usize,
    stride: usize,
    threads: usize,
) -> f64 {
    ssim_fast_on(PoolHandle::Global, ArenaHandle::Fresh, original, other, window, stride, threads)
}

/// [`ssim_fast`] with its parallel regions confined to `pool` and its
/// full-grid scratch leased from `arena`.
#[allow(clippy::too_many_arguments)]
pub fn ssim_fast_on(
    pool: PoolHandle<'_>,
    arena: ArenaHandle<'_>,
    original: &Grid<f32>,
    other: &Grid<f32>,
    window: usize,
    stride: usize,
    threads: usize,
) -> f64 {
    fused_ssim(pool, arena, original, other, window, stride, false, threads)
}

/// Pooled gaussian-window SSIM: 11 taps per active axis, σ = 1.5,
/// evaluated densely (stride 1). Runs on the global pool with full
/// parallelism and fresh scratch.
pub fn ssim_gaussian(original: &Grid<f32>, other: &Grid<f32>) -> f64 {
    ssim_gaussian_on(PoolHandle::Global, ArenaHandle::Fresh, original, other, pool::parallelism())
}

/// [`ssim_gaussian`] with an explicit worker count (`threads <= 1` runs
/// inline and never touches the global pool).
pub fn ssim_gaussian_threads(original: &Grid<f32>, other: &Grid<f32>, threads: usize) -> f64 {
    ssim_gaussian_on(PoolHandle::Global, ArenaHandle::Fresh, original, other, threads)
}

/// [`ssim_gaussian`] with its parallel regions confined to `pool` and
/// its full-grid scratch leased from `arena`.
pub fn ssim_gaussian_on(
    pool: PoolHandle<'_>,
    arena: ArenaHandle<'_>,
    original: &Grid<f32>,
    other: &Grid<f32>,
    threads: usize,
) -> f64 {
    fused_ssim(pool, arena, original, other, GAUSSIAN_WINDOW, 1, true, threads)
}

/// Unnormalized gaussian taps for a window of `len` samples centered on
/// `(len - 1) / 2` (half-integer centers for even clamped windows).
fn gaussian_taps(len: usize, sigma: f64) -> Vec<f64> {
    let c = (len as f64 - 1.0) / 2.0;
    (0..len).map(|t| (-(t as f64 - c).powi(2) / (2.0 * sigma * sigma)).exp()).collect()
}

/// Shared body of both window shapes. `gaussian = false` replays the
/// reference box-sum arithmetic exactly (rolling per-line sums);
/// `gaussian = true` applies the weighted taps directly.
#[allow(clippy::too_many_arguments)]
fn fused_ssim(
    pool: PoolHandle<'_>,
    arena: ArenaHandle<'_>,
    original: &Grid<f32>,
    other: &Grid<f32>,
    window: usize,
    stride: usize,
    gaussian: bool,
    threads: usize,
) -> f64 {
    assert_eq!(original.shape, other.shape, "shape mismatch");
    assert!(window > 0 && stride > 0);
    let shape = original.shape;
    let dims = shape.dims;

    // Normalize by the original's value range (QCAT convention,
    // identical to the reference).
    let (lo, hi) = original.min_max();
    let range = (hi - lo) as f64;
    if range == 0.0 {
        // Constant original: SSIM degenerates; define 1.0 iff identical.
        let same = original.data == other.data;
        return if same { 1.0 } else { 0.0 };
    }
    let inv = 1.0 / range;
    let lof = lo as f64;

    // Per-axis window extent: full `window` on active axes, 1 on unit
    // axes (the reference's clamping rule).
    let w = [
        if dims[0] > 1 { window.min(dims[0]) } else { 1 },
        if dims[1] > 1 { window.min(dims[1]) } else { 1 },
        if dims[2] > 1 { window.min(dims[2]) } else { 1 },
    ];
    // Per-axis taps and the window normalizer (Σ taps over the box).
    let kernels: [Vec<f64>; 3] = if gaussian {
        [
            gaussian_taps(w[0], GAUSSIAN_SIGMA),
            gaussian_taps(w[1], GAUSSIAN_SIGMA),
            gaussian_taps(w[2], GAUSSIAN_SIGMA),
        ]
    } else {
        [vec![1.0; w[0]], vec![1.0; w[1]], vec![1.0; w[2]]]
    };
    let norm: f64 = if gaussian {
        kernels.iter().map(|k| k.iter().sum::<f64>()).product()
    } else {
        (w[0] * w[1] * w[2]) as f64
    };

    let n = original.data.len();
    let mut sx = arena.lease_stale::<f64>(n);
    let mut sy = arena.lease_stale::<f64>(n);
    let mut sxx = arena.lease_stale::<f64>(n);
    let mut syy = arena.lease_stale::<f64>(n);
    let mut sxy = arena.lease_stale::<f64>(n);
    let px = UnsafeSlice::new(&mut sx);
    let py = UnsafeSlice::new(&mut sy);
    let pxx = UnsafeSlice::new(&mut sxx);
    let pyy = UnsafeSlice::new(&mut syy);
    let pxy = UnsafeSlice::new(&mut sxy);

    // Pointwise init of the five moment fields (elementwise, disjoint).
    {
        let xs = &original.data;
        let ys = &other.data;
        pool.for_batches(n, threads, 4096, |r| {
            let (start, len) = (r.start, r.len());
            // SAFETY: each batch owns a disjoint index range of all five
            // moment fields, so the sub-slices are unaliased.
            unsafe {
                crate::util::simd::ssim_moments(
                    &xs[start..start + len],
                    &ys[start..start + len],
                    lof,
                    inv,
                    px.slice_mut(start, len),
                    py.slice_mut(start, len),
                    pxx.slice_mut(start, len),
                    pyy.slice_mut(start, len),
                    pxy.slice_mut(start, len),
                );
            }
        });
    }

    // One fused pass per axis: gather each line of all five fields into
    // thread-local scratch, windowed-sum, scatter back in place. Lines
    // along an axis are independent, so only the axis passes themselves
    // must stay sequential.
    let strides = shape.strides();
    for axis in 0..3 {
        let k = &kernels[axis];
        let wa = k.len();
        if wa <= 1 {
            continue;
        }
        let nax = dims[axis];
        let sax = strides[axis];
        let (oa, ob) = match axis {
            0 => (1, 2),
            1 => (0, 2),
            _ => (0, 1),
        };
        let n_lines = dims[oa] * dims[ob];
        pool.for_batches(n_lines, threads, 4, |lines| {
            let mut scratch = vec![0.0f64; 5 * nax];
            for lid in lines {
                let a = lid / dims[ob];
                let b = lid % dims[ob];
                let base = match axis {
                    0 => shape.idx(0, a, b),
                    1 => shape.idx(a, 0, b),
                    _ => shape.idx(a, b, 0),
                };
                // SAFETY (whole line loop): line `lid` owns exactly the
                // indices `base + t * sax`; index sets of distinct lines
                // are disjoint and each worker reads only lines it owns,
                // so no index is touched by two workers in this pass.
                for t in 0..nax {
                    let i = base + t * sax;
                    unsafe {
                        scratch[t] = px.read(i);
                        scratch[nax + t] = py.read(i);
                        scratch[2 * nax + t] = pxx.read(i);
                        scratch[3 * nax + t] = pyy.read(i);
                        scratch[4 * nax + t] = pxy.read(i);
                    }
                }
                let bufs = [&px, &py, &pxx, &pyy, &pxy];
                if gaussian {
                    for p in 0..=(nax - wa) {
                        let i = base + p * sax;
                        for (m, buf) in bufs.iter().enumerate() {
                            let line = &scratch[m * nax..(m + 1) * nax];
                            let mut acc = 0.0f64;
                            for (t, &wt) in k.iter().enumerate() {
                                acc += wt * line[p + t];
                            }
                            unsafe { buf.write(i, acc) };
                        }
                    }
                } else {
                    // Rolling sums, replaying the reference's
                    // `sliding_sum_axis` arithmetic exactly.
                    for (m, buf) in bufs.iter().enumerate() {
                        let line = &scratch[m * nax..(m + 1) * nax];
                        let mut acc: f64 = line[..wa].iter().sum();
                        unsafe { buf.write(base, acc) };
                        for p in 1..=(nax - wa) {
                            acc += line[p + wa - 1] - line[p - 1];
                            unsafe { buf.write(base + p * sax, acc) };
                        }
                    }
                }
            }
        });
    }

    // Valid window anchor positions per axis: 0, stride, ..., dim - w.
    let anchors = |dim: usize, wa: usize| -> Vec<usize> {
        let mut v = Vec::new();
        let mut p = 0;
        while p + wa <= dim {
            v.push(p);
            p += stride;
        }
        if v.is_empty() {
            v.push(0); // window clamped to dim already
        }
        v
    };
    let ai = anchors(dims[0], w[0]);
    let aj = anchors(dims[1], w[1]);
    let ak = anchors(dims[2], w[2]);
    let (nj, nk) = (aj.len(), ak.len());
    let n_anchors = ai.len() * nj * nk;

    // Per-anchor scores land in an indexed buffer and are summed
    // serially in anchor order: the total is independent of scheduling
    // and matches the reference's nested-loop summation order.
    let mut svals = arena.lease_stale::<f64>(n_anchors);
    let ps = UnsafeSlice::new(&mut svals);
    pool.for_batches(n_anchors, threads, 1024, |r| {
        for t in r {
            let i = ai[t / (nj * nk)];
            let rem = t % (nj * nk);
            let j = aj[rem / nk];
            let kk = ak[rem % nk];
            let idx = shape.idx(i, j, kk);
            // SAFETY: anchor `t` is written by exactly one batch, and
            // the moment fields are only read during this pass.
            let s = unsafe {
                let mx = px.read(idx) / norm;
                let my = py.read(idx) / norm;
                let vx = (pxx.read(idx) / norm - mx * mx).max(0.0);
                let vy = (pyy.read(idx) / norm - my * my).max(0.0);
                let cxy = pxy.read(idx) / norm - mx * my;
                ((2.0 * mx * my + C1) * (2.0 * cxy + C2))
                    / ((mx * mx + my * my + C1) * (vx + vy + C2))
            };
            unsafe { ps.write(t, s) };
        }
    });
    let total: f64 = svals.iter().sum();
    total / n_anchors as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::ssim::ssim;
    use crate::util::arena::Arena;
    use crate::util::pool::ThreadPool;
    use crate::util::rng::Rng;

    fn noisy_pair(n: usize, dims: &[usize], seed: u64) -> (Grid<f32>, Grid<f32>) {
        let mut rng = Rng::new(seed);
        let a: Vec<f32> = (0..n).map(|_| rng.f32()).collect();
        let b: Vec<f32> = a.iter().map(|v| v + 0.05 * (rng.f32() - 0.5)).collect();
        (Grid::from_vec(a, dims), Grid::from_vec(b, dims))
    }

    #[test]
    fn box_mode_matches_reference_serial() {
        let (ga, gb) = noisy_pair(9 * 11 * 13, &[9, 11, 13], 21);
        for (w, s) in [(7, 2), (3, 1), (5, 3), (11, 4)] {
            let reference = ssim(&ga, &gb, w, s);
            let fast = ssim_fast_threads(&ga, &gb, w, s, 1);
            assert_eq!(reference, fast, "w={w} s={s}");
        }
    }

    #[test]
    fn box_mode_matches_reference_pooled() {
        let _guard = pool::test_guard();
        let (ga, gb) = noisy_pair(20 * 30, &[20, 30], 22);
        let reference = ssim(&ga, &gb, 7, 2);
        let pool = ThreadPool::new(3);
        let arena = Arena::new();
        let fast = ssim_fast_on(
            PoolHandle::Explicit(&pool),
            ArenaHandle::Pooled(&arena),
            &ga,
            &gb,
            7,
            2,
            3,
        );
        assert_eq!(reference, fast);
    }

    #[test]
    fn gaussian_identical_fields_score_one() {
        let (ga, _) = noisy_pair(16 * 16, &[16, 16], 23);
        let s = ssim_gaussian_threads(&ga, &ga, 1);
        assert!((s - 1.0).abs() < 1e-9, "s={s}");
    }

    #[test]
    fn gaussian_orders_degradation() {
        let mut rng = Rng::new(24);
        let base: Vec<f32> = (0..(24 * 24)).map(|i| ((i % 24) as f32 * 0.3).sin()).collect();
        let light: Vec<f32> = base.iter().map(|v| v + 0.01 * (rng.f32() - 0.5)).collect();
        let heavy: Vec<f32> = base.iter().map(|v| v + 0.5 * (rng.f32() - 0.5)).collect();
        let g = Grid::from_vec(base, &[24, 24]);
        let gl = Grid::from_vec(light, &[24, 24]);
        let gh = Grid::from_vec(heavy, &[24, 24]);
        let sl = ssim_gaussian_threads(&g, &gl, 1);
        let sh = ssim_gaussian_threads(&g, &gh, 1);
        assert!(sl > sh, "sl={sl} sh={sh}");
        assert!(sl > 0.9);
    }

    #[test]
    fn gaussian_thread_count_invariant() {
        let _guard = pool::test_guard();
        let (ga, gb) = noisy_pair(12 * 14 * 10, &[12, 14, 10], 25);
        let serial = ssim_gaussian_threads(&ga, &gb, 1);
        let pool = ThreadPool::new(4);
        let arena = Arena::new();
        let pooled = ssim_gaussian_on(
            PoolHandle::Explicit(&pool),
            ArenaHandle::Pooled(&arena),
            &ga,
            &gb,
            4,
        );
        assert_eq!(serial, pooled);
    }

    #[test]
    fn gaussian_window_clamps_to_small_dims() {
        let (ga, gb) = noisy_pair(5 * 6, &[5, 6], 26);
        let s = ssim_gaussian_threads(&ga, &gb, 1);
        assert!(s.is_finite() && s <= 1.0 + 1e-12, "s={s}");
    }

    #[test]
    fn constant_original_defined() {
        let g = Grid::from_vec(vec![3.0f32; 16], &[4, 4]);
        assert_eq!(ssim_fast_threads(&g, &g, 7, 2, 1), 1.0);
        let other = Grid::from_vec(vec![4.0f32; 16], &[4, 4]);
        assert_eq!(ssim_fast_threads(&g, &other, 7, 2, 1), 0.0);
        assert_eq!(ssim_gaussian_threads(&g, &g, 1), 1.0);
    }

    #[test]
    fn warm_reuse_allocates_nothing() {
        let _guard = pool::test_guard();
        let (ga, gb) = noisy_pair(16 * 16, &[16, 16], 27);
        let arena = Arena::new();
        let pool = ThreadPool::new(2);
        let h = PoolHandle::Explicit(&pool);
        let a = ArenaHandle::Pooled(&arena);
        let first = ssim_fast_on(h, a, &ga, &gb, 7, 2, 2);
        let misses_after_first = arena.stats().misses;
        let second = ssim_fast_on(h, a, &ga, &gb, 7, 2, 2);
        assert_eq!(first, second);
        assert_eq!(arena.stats().misses, misses_after_first, "warm run should reuse scratch");
    }
}
