//! Quality and efficiency metrics from the paper's evaluation (§IV-A,
//! §VIII-B): SSIM (QCAT convention), PSNR, max abs/relative error and
//! bit-rate.

pub mod errors;
pub mod psnr;
pub mod ssim;

pub use errors::{bit_rate, max_abs_error, max_rel_error};
pub use psnr::psnr;
pub use ssim::ssim;
