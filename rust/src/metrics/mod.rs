//! Quality and efficiency metrics from the paper's evaluation (§IV-A,
//! §VIII-B): SSIM (QCAT convention), PSNR, max abs/relative error and
//! bit-rate.

pub mod errors;
pub mod psnr;
pub mod ssim;
pub mod ssim_fast;

pub use errors::{bit_rate, max_abs_error, max_rel_error, mse};
pub use psnr::psnr;
pub use ssim::ssim;
pub use ssim_fast::{
    ssim_fast, ssim_fast_on, ssim_fast_threads, ssim_gaussian, ssim_gaussian_on,
    ssim_gaussian_threads,
};
