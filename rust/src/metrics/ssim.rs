//! Structural Similarity Index (paper Eq. 3), following the QCAT
//! convention the paper cites [57]:
//!
//! * both fields are normalized by the *original* field's value range,
//!   so the stabilizing constants are `c1 = 1e-4 = (0.01)²` and
//!   `c2 = 9e-4 = (0.03)²` with dynamic range L = 1;
//! * a sliding window (default size 7, stride 2) computes a local SSIM
//!   from window means/variances/covariance, and the dataset SSIM is the
//!   average over windows;
//! * windows slide along every active axis, so 1D/2D/3D are handled
//!   uniformly (a 2D field uses 7×7 windows, a 3D field 7×7×7).
//!
//! Implementation note: the window statistics are computed with
//! separable sliding box-sums (prefix-sum per line, O(N) per axis per
//! moment) rather than per-window loops — this turns the 7³·windows cost
//! into 15 linear passes, which matters for the 256³+ fields in the
//! benches. See EXPERIMENTS.md §Perf.

use crate::data::grid::{Grid, Shape};

/// QCAT constants for range-normalized data.
pub const C1: f64 = 1e-4;
pub const C2: f64 = 9e-4;

/// Windowed SSIM between `original` and `other` (same shape required).
/// `window` is the per-axis window extent, `stride` the window step.
pub fn ssim(original: &Grid<f32>, other: &Grid<f32>, window: usize, stride: usize) -> f64 {
    assert_eq!(original.shape, other.shape, "shape mismatch");
    assert!(window > 0 && stride > 0);
    let shape = original.shape;

    // Normalize by the original's value range (QCAT convention).
    let (lo, hi) = original.min_max();
    let range = (hi - lo) as f64;
    if range == 0.0 {
        // Constant original: SSIM degenerates; define 1.0 iff identical.
        let same = original.data == other.data;
        return if same { 1.0 } else { 0.0 };
    }
    let inv = 1.0 / range;
    let x: Vec<f64> = original.data.iter().map(|&v| (v as f64 - lo as f64) * inv).collect();
    let y: Vec<f64> = other.data.iter().map(|&v| (v as f64 - lo as f64) * inv).collect();

    // Per-axis window extent: full `window` on active axes, 1 on unit axes.
    let w = [
        if shape.dims[0] > 1 { window.min(shape.dims[0]) } else { 1 },
        if shape.dims[1] > 1 { window.min(shape.dims[1]) } else { 1 },
        if shape.dims[2] > 1 { window.min(shape.dims[2]) } else { 1 },
    ];
    let wn = (w[0] * w[1] * w[2]) as f64;

    // Box-sums of the five moments.
    let sx = box_sum(&x, shape, w);
    let sy = box_sum(&y, shape, w);
    let sxx = box_sum_sq(&x, shape, w);
    let syy = box_sum_sq(&y, shape, w);
    let sxy = box_sum_prod(&x, &y, shape, w);

    // Valid window anchor positions per axis: 0, stride, ..., dim - w.
    let anchors = |dim: usize, wa: usize| -> Vec<usize> {
        let mut v = Vec::new();
        let mut p = 0;
        while p + wa <= dim {
            v.push(p);
            p += stride;
        }
        if v.is_empty() {
            v.push(0); // window clamped to dim already
        }
        v
    };
    let ai = anchors(shape.dims[0], w[0]);
    let aj = anchors(shape.dims[1], w[1]);
    let ak = anchors(shape.dims[2], w[2]);

    let mut total = 0.0f64;
    let mut count = 0usize;
    for &i in &ai {
        for &j in &aj {
            for &k in &ak {
                let idx = shape.idx(i, j, k);
                let mx = sx[idx] / wn;
                let my = sy[idx] / wn;
                let vx = (sxx[idx] / wn - mx * mx).max(0.0);
                let vy = (syy[idx] / wn - my * my).max(0.0);
                let cxy = sxy[idx] / wn - mx * my;
                let s = ((2.0 * mx * my + C1) * (2.0 * cxy + C2))
                    / ((mx * mx + my * my + C1) * (vx + vy + C2));
                total += s;
                count += 1;
            }
        }
    }
    total / count as f64
}

/// For each anchor position `p`, the sum of `data` over the box
/// `[p, p+w)` per axis, stored at the anchor's flat index. Positions
/// whose box would exceed the domain hold garbage (never sampled).
fn box_sum(data: &[f64], shape: Shape, w: [usize; 3]) -> Vec<f64> {
    let mut buf = data.to_vec();
    for axis in 0..3 {
        if w[axis] > 1 {
            sliding_sum_axis(&mut buf, shape, axis, w[axis]);
        }
    }
    buf
}

fn box_sum_sq(data: &[f64], shape: Shape, w: [usize; 3]) -> Vec<f64> {
    let sq: Vec<f64> = data.iter().map(|&v| v * v).collect();
    box_sum(&sq, shape, w)
}

fn box_sum_prod(a: &[f64], b: &[f64], shape: Shape, w: [usize; 3]) -> Vec<f64> {
    let prod: Vec<f64> = a.iter().zip(b).map(|(&x, &y)| x * y).collect();
    box_sum(&prod, shape, w)
}

/// In-place sliding-window sum of width `w` along `axis`: after the call,
/// `buf[p] = sum_{t=0..w} old[p + t*stride_axis]` for every position with
/// the full window in bounds.
fn sliding_sum_axis(buf: &mut [f64], shape: Shape, axis: usize, w: usize) {
    let dims = shape.dims;
    let stride = shape.strides()[axis];
    let n = dims[axis];
    debug_assert!(w <= n);
    // Iterate all lines along `axis`.
    let (oa, ob) = match axis {
        0 => (1, 2),
        1 => (0, 2),
        _ => (0, 1),
    };
    let mut line = vec![0.0f64; n];
    for a in 0..dims[oa] {
        for b in 0..dims[ob] {
            let base = match axis {
                0 => shape.idx(0, a, b),
                1 => shape.idx(a, 0, b),
                _ => shape.idx(a, b, 0),
            };
            // Gather line.
            for (t, dst) in line.iter_mut().enumerate() {
                *dst = buf[base + t * stride];
            }
            // Rolling sum.
            let mut acc: f64 = line[..w].iter().sum();
            buf[base] = acc;
            for p in 1..=(n - w) {
                acc += line[p + w - 1] - line[p - 1];
                buf[base + p * stride] = acc;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Brute-force reference SSIM for validation.
    fn ssim_naive(original: &Grid<f32>, other: &Grid<f32>, window: usize, stride: usize) -> f64 {
        let shape = original.shape;
        let (lo, hi) = original.min_max();
        let range = (hi - lo) as f64;
        let norm = |v: f32| (v as f64 - lo as f64) / range;
        let w = [
            if shape.dims[0] > 1 { window.min(shape.dims[0]) } else { 1 },
            if shape.dims[1] > 1 { window.min(shape.dims[1]) } else { 1 },
            if shape.dims[2] > 1 { window.min(shape.dims[2]) } else { 1 },
        ];
        let mut total = 0.0;
        let mut count = 0;
        let anchors = |dim: usize, wa: usize| -> Vec<usize> {
            let mut v = Vec::new();
            let mut p = 0;
            while p + wa <= dim {
                v.push(p);
                p += stride;
            }
            if v.is_empty() {
                v.push(0);
            }
            v
        };
        for &i in &anchors(shape.dims[0], w[0]) {
            for &j in &anchors(shape.dims[1], w[1]) {
                for &k in &anchors(shape.dims[2], w[2]) {
                    let mut xs = Vec::new();
                    let mut ys = Vec::new();
                    for a in 0..w[0] {
                        for b in 0..w[1] {
                            for c in 0..w[2] {
                                xs.push(norm(original.at(i + a, j + b, k + c)));
                                ys.push(norm(other.at(i + a, j + b, k + c)));
                            }
                        }
                    }
                    let n = xs.len() as f64;
                    let mx = xs.iter().sum::<f64>() / n;
                    let my = ys.iter().sum::<f64>() / n;
                    let vx = xs.iter().map(|x| x * x).sum::<f64>() / n - mx * mx;
                    let vy = ys.iter().map(|y| y * y).sum::<f64>() / n - my * my;
                    let cxy = xs.iter().zip(&ys).map(|(x, y)| x * y).sum::<f64>() / n - mx * my;
                    total += ((2.0 * mx * my + C1) * (2.0 * cxy + C2))
                        / ((mx * mx + my * my + C1) * (vx + vy + C2));
                    count += 1;
                }
            }
        }
        total / count as f64
    }

    #[test]
    fn identical_fields_score_one() {
        let g = Grid::from_vec((0..100).map(|i| (i as f32).sin()).collect(), &[10, 10]);
        let s = ssim(&g, &g, 7, 2);
        assert!((s - 1.0).abs() < 1e-9, "s={s}");
    }

    #[test]
    fn matches_naive_2d() {
        let mut rng = Rng::new(12);
        let a: Vec<f32> = (0..(20 * 30)).map(|_| rng.f32()).collect();
        let b: Vec<f32> = a.iter().map(|v| v + 0.1 * (rng.f32() - 0.5)).collect();
        let ga = Grid::from_vec(a, &[20, 30]);
        let gb = Grid::from_vec(b, &[20, 30]);
        let fast = ssim(&ga, &gb, 7, 2);
        let slow = ssim_naive(&ga, &gb, 7, 2);
        assert!((fast - slow).abs() < 1e-10, "fast={fast} slow={slow}");
    }

    #[test]
    fn matches_naive_3d() {
        let mut rng = Rng::new(13);
        let a: Vec<f32> = (0..(9 * 11 * 13)).map(|_| rng.f32()).collect();
        let b: Vec<f32> = a.iter().map(|v| v * 0.9 + 0.05).collect();
        let ga = Grid::from_vec(a, &[9, 11, 13]);
        let gb = Grid::from_vec(b, &[9, 11, 13]);
        let fast = ssim(&ga, &gb, 7, 2);
        let slow = ssim_naive(&ga, &gb, 7, 2);
        assert!((fast - slow).abs() < 1e-10, "fast={fast} slow={slow}");
    }

    #[test]
    fn matches_naive_1d_and_small_windows() {
        let mut rng = Rng::new(14);
        let a: Vec<f32> = (0..40).map(|_| rng.f32()).collect();
        let b: Vec<f32> = a.iter().map(|v| v + 0.2).collect();
        let ga = Grid::from_vec(a, &[40]);
        let gb = Grid::from_vec(b, &[40]);
        for (w, s) in [(7, 2), (3, 1), (5, 3)] {
            let fast = ssim(&ga, &gb, w, s);
            let slow = ssim_naive(&ga, &gb, w, s);
            assert!((fast - slow).abs() < 1e-10, "w={w} s={s}");
        }
    }

    #[test]
    fn degraded_field_scores_lower() {
        let mut rng = Rng::new(15);
        let base: Vec<f32> = (0..(32 * 32)).map(|i| ((i % 32) as f32 * 0.2).sin()).collect();
        let light: Vec<f32> = base.iter().map(|v| v + 0.01 * (rng.f32() - 0.5)).collect();
        let heavy: Vec<f32> = base.iter().map(|v| v + 0.5 * (rng.f32() - 0.5)).collect();
        let g = Grid::from_vec(base, &[32, 32]);
        let gl = Grid::from_vec(light, &[32, 32]);
        let gh = Grid::from_vec(heavy, &[32, 32]);
        let sl = ssim(&g, &gl, 7, 2);
        let sh = ssim(&g, &gh, 7, 2);
        assert!(sl > sh, "sl={sl} sh={sh}");
        assert!(sl > 0.9);
    }

    #[test]
    fn constant_original_defined() {
        let g = Grid::from_vec(vec![1.0f32; 16], &[4, 4]);
        let h = Grid::from_vec(vec![1.0f32; 16], &[4, 4]);
        assert_eq!(ssim(&g, &h, 7, 2), 1.0);
        let other = Grid::from_vec(vec![2.0f32; 16], &[4, 4]);
        assert_eq!(ssim(&g, &other, 7, 2), 0.0);
    }

    #[test]
    fn window_larger_than_dim_is_clamped() {
        let g = Grid::from_vec((0..12).map(|x| x as f32).collect(), &[3, 4]);
        let s = ssim(&g, &g, 7, 2);
        assert!((s - 1.0).abs() < 1e-9);
    }
}
