//! Peak signal-to-noise ratio (paper Eq. 4): value-range-based PSNR in
//! dB, the scientific-data-compression convention (range of the
//! *original* data, not 255).

use crate::metrics::errors::mse;

/// `PSNR = 20·log10( (max(D1) − min(D1)) / sqrt(MSE(D1, D2)) )`.
/// Returns `f64::INFINITY` for identical arrays.
pub fn psnr(original: &[f32], other: &[f32]) -> f64 {
    let (lo, hi) = original.iter().fold((f32::INFINITY, f32::NEG_INFINITY), |(l, h), &v| {
        (l.min(v), h.max(v))
    });
    let range = (hi - lo) as f64;
    let m = mse(original, other);
    if m == 0.0 {
        return f64::INFINITY;
    }
    20.0 * (range / m.sqrt()).log10()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identical_is_infinite() {
        assert!(psnr(&[1.0, 2.0], &[1.0, 2.0]).is_infinite());
    }

    #[test]
    fn known_value() {
        // range 1, uniform error 0.1 → PSNR = 20 log10(1/0.1) = 20 dB
        let orig = [0.0f32, 1.0];
        let other = [0.1f32, 1.1];
        // f32 representation of 1.1 costs a few ulps of slack
        assert!((psnr(&orig, &other) - 20.0).abs() < 1e-5);
    }

    #[test]
    fn smaller_error_is_larger_psnr() {
        let orig: Vec<f32> = (0..100).map(|i| i as f32).collect();
        let near: Vec<f32> = orig.iter().map(|v| v + 0.01).collect();
        let far: Vec<f32> = orig.iter().map(|v| v + 1.0).collect();
        assert!(psnr(&orig, &near) > psnr(&orig, &far));
    }
}
