//! Pointwise error metrics and bit-rate (§VIII-B).

/// Maximum absolute error `max_i |a_i - b_i|`.
pub fn max_abs_error(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(&x, &y)| ((x as f64) - (y as f64)).abs()).fold(0.0, f64::max)
}

/// Maximum value-range-relative error: `max|a-b| / (max(a)-min(a))`.
/// This is the quantity Table II reports.
pub fn max_rel_error(original: &[f32], other: &[f32]) -> f64 {
    let (lo, hi) = original.iter().fold((f32::INFINITY, f32::NEG_INFINITY), |(l, h), &v| {
        (l.min(v), h.max(v))
    });
    let range = (hi - lo) as f64;
    if range == 0.0 {
        return if max_abs_error(original, other) == 0.0 { 0.0 } else { f64::INFINITY };
    }
    max_abs_error(original, other) / range
}

/// Mean squared error in f64. Empty inputs are defined as 0.0 (two
/// empty fields are identical), so `psnr` on empty inputs is `+∞`
/// rather than a panic.
pub fn mse(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    if a.is_empty() {
        return 0.0;
    }
    a.iter().zip(b).map(|(&x, &y)| ((x as f64) - (y as f64)).powi(2)).sum::<f64>() / a.len() as f64
}

/// Bit-rate = bitwidth / compression-ratio = 8·compressed_bytes / n
/// (bitwidth 32 for single precision, §VIII-B).
pub fn bit_rate(compressed_bytes: usize, n_elements: usize) -> f64 {
    assert!(n_elements > 0);
    compressed_bytes as f64 * 8.0 / n_elements as f64
}

/// Compression ratio = original bytes / compressed bytes.
pub fn compression_ratio(compressed_bytes: usize, n_elements: usize) -> f64 {
    assert!(compressed_bytes > 0);
    (n_elements * 4) as f64 / compressed_bytes as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn max_abs_basic() {
        assert_eq!(max_abs_error(&[1.0, 2.0, 3.0], &[1.0, 2.5, 2.0]), 1.0);
    }

    #[test]
    fn max_rel_uses_original_range() {
        let orig = [0.0f32, 10.0];
        let other = [0.5f32, 10.0];
        assert!((max_rel_error(&orig, &other) - 0.05).abs() < 1e-12);
    }

    #[test]
    fn rel_error_constant_field() {
        assert_eq!(max_rel_error(&[2.0, 2.0], &[2.0, 2.0]), 0.0);
        assert_eq!(max_rel_error(&[2.0, 2.0], &[2.5, 2.0]), f64::INFINITY);
    }

    #[test]
    fn bitrate_and_ratio() {
        // 1000 f32 = 4000 bytes compressed to 500 bytes → ratio 8, 4 bits/value
        assert_eq!(compression_ratio(500, 1000), 8.0);
        assert_eq!(bit_rate(500, 1000), 4.0);
    }

    #[test]
    fn mse_basic() {
        assert_eq!(mse(&[0.0, 0.0], &[3.0, 4.0]), 12.5);
    }

    #[test]
    fn mse_empty_is_zero() {
        // Regression: this used to assert (panic) on empty input.
        assert_eq!(mse(&[], &[]), 0.0);
    }

    #[test]
    fn max_abs_empty_is_zero() {
        assert_eq!(max_abs_error(&[], &[]), 0.0);
    }
}
