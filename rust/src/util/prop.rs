//! Minimal property-test driver (offline `proptest` stand-in).
//!
//! Runs a check over many seeded random cases and reports the first
//! failing seed, so failures reproduce exactly:
//!
//! ```no_run
//! use qai::util::prop::{prop_check, Gen};
//! prop_check("abs never negative", 200, |g: &mut Gen| {
//!     let x = g.f64_in(-10.0, 10.0);
//!     assert!(x.abs() >= 0.0);
//! });
//! ```
//!
//! (`no_run`: doctest binaries don't inherit the `-Wl,-rpath` link flag
//! that locates `libxla_extension.so`'s bundled libstdc++ at run time.)
//!
//! There is no shrinking; cases are kept small instead, which in practice
//! makes failures directly readable from the reported seed.

use crate::util::rng::Rng;

/// Case-local generator handed to the property body.
pub struct Gen {
    rng: Rng,
    /// Seed of this particular case (for the failure message).
    pub seed: u64,
}

impl Gen {
    /// Uniform usize in `[lo, hi]` (inclusive).
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.rng.below(hi - lo + 1)
    }

    /// Uniform f64 in `[lo, hi)`.
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.range_f64(lo, hi)
    }

    /// Uniform f32 in `[lo, hi)`.
    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.range_f64(lo as f64, hi as f64) as f32
    }

    /// Bernoulli with probability `p`.
    pub fn bool_with(&mut self, p: f64) -> bool {
        self.rng.f64() < p
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len())]
    }

    /// A random smooth-ish f32 field of length `n`: random low-frequency
    /// sinusoid mix plus optional noise — the typical "scientific data"
    /// shape for quantization properties.
    pub fn smooth_field(&mut self, n: usize, noise: f32) -> Vec<f32> {
        let a1 = self.f64_in(0.5, 2.0);
        let a2 = self.f64_in(0.1, 1.0);
        let f1 = self.f64_in(1.0, 4.0);
        let f2 = self.f64_in(4.0, 16.0);
        let ph1 = self.f64_in(0.0, 6.28);
        let ph2 = self.f64_in(0.0, 6.28);
        (0..n)
            .map(|i| {
                let t = i as f64 / n.max(1) as f64;
                let v = a1 * (f1 * t * 6.28318 + ph1).sin() + a2 * (f2 * t * 6.28318 + ph2).sin();
                v as f32 + noise * (self.rng.f32() - 0.5)
            })
            .collect()
    }

    /// Raw access to the underlying RNG.
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
}

/// Run `body` for `cases` seeded cases. Panics (re-raising the body's
/// panic) with the failing seed in the message.
pub fn prop_check<F>(name: &str, cases: u64, body: F)
where
    F: Fn(&mut Gen) + std::panic::RefUnwindSafe,
{
    for case in 0..cases {
        // Derive a per-case seed that is stable across runs.
        let seed = 0xC0FFEE ^ case.wrapping_mul(0x9E3779B97F4A7C15);
        let result = std::panic::catch_unwind(|| {
            let mut g = Gen { rng: Rng::new(seed), seed };
            body(&mut g);
        });
        if let Err(err) = result {
            let msg = err
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| err.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!("property '{name}' failed at case {case} (seed {seed:#x}): {msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivially_true_property() {
        prop_check("x*x >= 0", 50, |g| {
            let x = g.f64_in(-5.0, 5.0);
            assert!(x * x >= 0.0);
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn reports_failing_seed() {
        prop_check("always fails", 5, |_| panic!("boom"));
    }

    #[test]
    fn gen_ranges_respected() {
        prop_check("gen ranges", 100, |g| {
            let n = g.usize_in(3, 9);
            assert!((3..=9).contains(&n));
            let x = g.f32_in(-1.0, 1.0);
            assert!((-1.0..1.0).contains(&x));
        });
    }

    #[test]
    fn smooth_field_has_requested_len() {
        prop_check("smooth field len", 20, |g| {
            let n = g.usize_in(1, 100);
            assert_eq!(g.smooth_field(n, 0.0).len(), n);
        });
    }
}
