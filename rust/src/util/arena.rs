//! Reusable scratch-buffer arena for the mitigation data plane.
//!
//! One warm `mitigate()` call needs eight full-grid buffers (boundary
//! mask, sign map, two distance fields, the feature transform, the
//! propagated signs, the sign-flip mask, and the output), and the block
//! decoders need two more per field. Allocating them fresh per job makes
//! the allocator — not the math — the hot path once a service runs many
//! same-shaped jobs back to back. This module provides the fix: a
//! thread-safe **size-classed free list**, keyed by `(element type,
//! length)`, that hands previously-used buffers back out instead of
//! allocating.
//!
//! Design points:
//!
//! * **Rounded size classes** — a requested length is rounded up to the
//!   next power of two ([`size_class`]), so near-shapes (a `24³` and a
//!   `25×24×24` field, say) share free-list classes instead of opening
//!   one class per exact length. A miss allocates the full class
//!   capacity up front, and a recycled buffer is trimmed/extended to
//!   the requested length within that capacity — never reallocating on
//!   the warm path. Every consumer can (and must) fully re-initialize
//!   a leased buffer: [`Arena::take_filled`] / [`Arena::take_copy`] do
//!   this for them, and [`Arena::take_stale`] callers provably
//!   overwrite every element themselves. Outputs are therefore
//!   bit-identical to the fresh-allocation path by construction, which
//!   the arena test suite sweeps across datasets × dims × threads.
//! * **Explicit lifecycle** — [`take_filled`](Arena::take_filled) /
//!   [`take_copy`](Arena::take_copy) lease a buffer out,
//!   [`give`](Arena::give) returns it, [`detach`](Arena::detach)
//!   records that a leased buffer escapes to the caller (a pipeline
//!   output embedded in a returned [`Grid`](crate::data::grid::Grid)),
//!   and [`adopt`](Arena::adopt) recycles a foreign buffer (e.g. an
//!   output the caller hands back via
//!   [`MitigationService::recycle`](crate::mitigation::service::MitigationService::recycle)).
//! * **Counter-proven reuse** — [`ArenaStats`] exposes hit/miss/return
//!   counters and a bytes-outstanding gauge, mirroring the
//!   `os_thread_spawns` trick from the pool runtime: tests assert that a
//!   warm same-shaped job performs **zero** new full-grid allocations
//!   (miss counter unchanged) and that bytes-outstanding returns to
//!   zero once all lessees are done.
//! * **Bounded retention** — each `(type, size class)` bucket keeps at
//!   most [`MAX_FREE_PER_CLASS`] buffers, the total parked across *all*
//!   classes is capped at [`MAX_POOLED_BYTES`], and emptied classes
//!   are removed from the map; surplus `give`s fall through to the
//!   allocator (counted in [`ArenaStats::dropped`]), so a service that
//!   sees many distinct shapes cannot hoard unbounded memory.
//!   [`Arena::with_limits`] tightens both knobs per deployment.
//!
//! [`Arena`] is a cheaply-cloneable handle (shared interior, like a
//! pool `Arc`); [`ArenaHandle`] is the `Copy` selector threaded through
//! the pipeline — [`ArenaHandle::Fresh`] preserves the historical
//! allocate-per-call behavior exactly and touches no counters, which is
//! what every standalone entry point (`mitigate`, `edt`, `decompress`)
//! defaults to.
//!
//! Accounting caveat: a panic between a raw `take_*` and its `give`
//! frees the buffer to the allocator as usual but leaves
//! `bytes_outstanding` non-zero — the raw gauge tracks *accounted*
//! leases, not RAII ownership. [`ArenaLease`] closes that hole: a
//! lease returns its buffer to the arena on drop (including unwinds),
//! keeping hit/miss/bytes-outstanding accounting exact on panic paths,
//! with [`ArenaLease::detach`] as the explicit escape hatch for buffers
//! that outlive the lease (pipeline outputs). The pipeline holds all of
//! its intermediates this way: raw vectors as [`ArenaLease`]s, grids as
//! [`GridLease`]s ([`Arena::relend`] / [`Arena::relend_grid`] wrap a
//! buffer that a `take_*` already accounted), so a panic anywhere in
//! steps A–E unwinds with every buffer parked and the gauges exact.
//!
//! # Examples
//!
//! ```
//! use qai::util::arena::Arena;
//!
//! let arena = Arena::new();
//! let buf: Vec<f32> = arena.take_filled(1024, 0.0);
//! arena.give(buf); // back to the free list
//! let again: Vec<f32> = arena.take_filled(1024, 0.0);
//! assert_eq!(arena.stats().hits, 1);
//! arena.give(again);
//! assert_eq!(arena.stats().bytes_outstanding, 0);
//! ```

#![deny(missing_docs)]

use crate::data::grid::Grid;
use std::any::{Any, TypeId};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Default maximum buffers retained per `(element type, size class)`
/// bucket; surplus returns are dropped to the allocator. Eight covers
/// every full-grid buffer one pipeline run cycles through a single
/// class.
pub const MAX_FREE_PER_CLASS: usize = 8;

/// Round a requested length up to its free-list size class — the next
/// power of two — so near-shapes share classes (a ROADMAP follow-up:
/// without rounding, every distinct grid shape opened its own class
/// and near-identical workloads could not reuse each other's buffers).
///
/// Zero-length requests bypass the arena entirely and never reach a
/// class.
pub fn size_class(len: usize) -> usize {
    len.next_power_of_two()
}

/// Largest size class a buffer of `capacity` elements can fully back:
/// the greatest power of two `<= capacity`. Buffers are parked under
/// this class so a future lease of any length in the class fits within
/// the buffer's capacity without reallocating.
fn park_class(capacity: usize) -> usize {
    debug_assert!(capacity > 0);
    1usize << (usize::BITS - 1 - capacity.leading_zeros())
}

/// Default cap on total bytes parked across *all* free lists (1 GiB).
/// The per-class cap alone would not bound a workload of many distinct
/// shapes — each new `(type, size class)` pair opens a fresh class — so
/// returns and adoptions that would push the pooled total past this
/// cap are dropped to the allocator instead (counted in
/// [`ArenaStats::dropped`]). Enforced exactly: the gauge is only
/// touched under the free-list lock.
pub const MAX_POOLED_BYTES: u64 = 1 << 30;

/// Point-in-time snapshot of an arena's counters.
///
/// All `u64` fields are monotonic totals since the arena was built;
/// `bytes_outstanding` / `bytes_pooled` are instantaneous gauges.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ArenaStats {
    /// Take requests served from the free list (no allocation).
    pub hits: u64,
    /// Take requests that had to allocate fresh.
    pub misses: u64,
    /// Leased buffers returned via [`Arena::give`].
    pub returns: u64,
    /// Leased buffers that escaped via [`Arena::detach`] (pipeline
    /// outputs embedded in grids handed to the caller).
    pub detached: u64,
    /// Foreign buffers recycled via [`Arena::adopt`].
    pub adopted: u64,
    /// Returned/adopted buffers dropped because their class was full.
    pub dropped: u64,
    /// Bytes currently leased out (taken, neither given nor detached).
    pub bytes_outstanding: u64,
    /// Bytes currently parked in the free lists.
    pub bytes_pooled: u64,
    /// High-water mark of `bytes_outstanding` since the arena was
    /// built: the largest scratch footprint any moment of the arena's
    /// life has required. This is what makes memory-budget claims
    /// scrapeable — the tiled executor's "peak scratch ≤ tile budget ×
    /// lanes" invariant is asserted against this counter, not against
    /// a racy sampling of the instantaneous gauge.
    pub bytes_peak: u64,
}

impl ArenaStats {
    /// Fraction of takes served without allocating (0 when idle).
    pub fn reuse_fraction(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// One free-list class: recycled buffers of a single
/// `(type, size class)` — lengths within the class vary, capacities
/// are at least the class.
type FreeList = Vec<Box<dyn Any + Send>>;

struct ArenaInner {
    classes: Mutex<HashMap<(TypeId, usize), FreeList>>,
    hits: AtomicU64,
    misses: AtomicU64,
    returns: AtomicU64,
    detached: AtomicU64,
    adopted: AtomicU64,
    dropped: AtomicU64,
    bytes_outstanding: AtomicU64,
    bytes_pooled: AtomicU64,
    bytes_peak: AtomicU64,
    /// Retention limits (see [`MAX_FREE_PER_CLASS`] / [`MAX_POOLED_BYTES`]).
    per_class_cap: usize,
    max_pooled_bytes: u64,
}

/// A thread-safe scratch-buffer arena. Cloning the handle shares the
/// same free lists and counters (the service clones one handle into
/// every job); the backing storage is freed when the last handle drops.
#[derive(Clone)]
pub struct Arena {
    inner: Arc<ArenaInner>,
}

impl Default for Arena {
    fn default() -> Self {
        Arena::new()
    }
}

impl std::fmt::Debug for Arena {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Arena").field("stats", &self.stats()).finish()
    }
}

fn bytes_of<T>(len: usize) -> u64 {
    (len * std::mem::size_of::<T>()) as u64
}

impl Arena {
    /// An arena with the default retention limits
    /// ([`MAX_FREE_PER_CLASS`], [`MAX_POOLED_BYTES`]).
    pub fn new() -> Self {
        Arena::with_limits(MAX_FREE_PER_CLASS, MAX_POOLED_BYTES)
    }

    /// An arena with explicit retention limits: at most `per_class_cap`
    /// free buffers per `(type, size class)` bucket and at most
    /// `max_pooled_bytes` parked in total. Use to bound a deployment
    /// that serves many distinct grid shapes.
    pub fn with_limits(per_class_cap: usize, max_pooled_bytes: u64) -> Self {
        Arena {
            inner: Arc::new(ArenaInner {
                classes: Mutex::new(HashMap::new()),
                hits: AtomicU64::new(0),
                misses: AtomicU64::new(0),
                returns: AtomicU64::new(0),
                detached: AtomicU64::new(0),
                adopted: AtomicU64::new(0),
                dropped: AtomicU64::new(0),
                bytes_outstanding: AtomicU64::new(0),
                bytes_pooled: AtomicU64::new(0),
                bytes_peak: AtomicU64::new(0),
                per_class_cap,
                max_pooled_bytes,
            }),
        }
    }

    /// Pop a recycled buffer from size class `class`, or `None` on a
    /// class miss. Contents and length are whatever the previous user
    /// left (capacity is at least `class` by the parking invariant);
    /// the `take_*` front ends trim or extend to the requested length.
    /// Emptied classes are removed so a stream of one-off shapes cannot
    /// grow the map without bound. The `bytes_pooled` gauge is updated
    /// while the class lock is held (here and in [`Arena::park`]), so
    /// it can never transiently underflow.
    fn pop<T: Send + 'static>(&self, class: usize) -> Option<Vec<T>> {
        let key = (TypeId::of::<T>(), class);
        let mut classes = self.inner.classes.lock().unwrap();
        let list = classes.get_mut(&key)?;
        let boxed = list.pop()?;
        if list.is_empty() {
            classes.remove(&key);
        }
        self.inner.bytes_pooled.fetch_sub(bytes_of::<T>(class), Ordering::Relaxed);
        drop(classes);
        let vec = *boxed.downcast::<Vec<T>>().expect("arena class type confusion");
        debug_assert!(vec.capacity() >= class);
        Some(vec)
    }

    /// Park `vec` in its class free list unless a retention limit says
    /// drop it. The class is derived from the buffer's *capacity*
    /// ([`park_class`]), so arena-allocated buffers (capacity = their
    /// size class) round-trip into the class they were leased from.
    /// Foreign buffers whose capacity falls short of their own
    /// length's class (e.g. an exactly-sized `vec![..]` handed to
    /// [`Arena::adopt`]) are grown to it first — a one-time
    /// reallocation at park time, so a later same-length lease hits
    /// instead of permanently missing its rounded class. Shared by
    /// [`Arena::give`] and [`Arena::adopt`], which differ only in how
    /// the lease accounting treats the buffer.
    fn park<T: Send + 'static>(&self, mut vec: Vec<T>) {
        let want = size_class(vec.len());
        if vec.capacity() < want {
            vec.reserve_exact(want - vec.len());
        }
        let class = park_class(vec.capacity());
        let bytes = bytes_of::<T>(class);
        let key = (TypeId::of::<T>(), class);
        let mut classes = self.inner.classes.lock().unwrap();
        // Gauge reads/writes happen under the lock, so this check is
        // exact, not racy.
        let over_total =
            self.inner.bytes_pooled.load(Ordering::Relaxed) + bytes > self.inner.max_pooled_bytes;
        let list = classes.entry(key).or_default();
        if over_total || list.len() >= self.inner.per_class_cap {
            if list.is_empty() {
                classes.remove(&key);
            }
            drop(classes);
            self.inner.dropped.fetch_add(1, Ordering::Relaxed);
            return;
        }
        list.push(Box::new(vec));
        self.inner.bytes_pooled.fetch_add(bytes, Ordering::Relaxed);
    }

    /// Account one lease of `len` elements of `T` and pop a recycled
    /// buffer from the rounded [`size_class`] for it: `Some` is a hit,
    /// `None` a miss (the caller allocates the full class capacity).
    /// The single home of the hit/miss/outstanding bookkeeping, so the
    /// `take_*` front ends cannot drift apart. The outstanding gauge
    /// tracks *requested* lengths, not class capacities.
    fn lease<T: Send + 'static>(&self, len: usize) -> Option<Vec<T>> {
        let popped = self.pop::<T>(size_class(len));
        let counter = if popped.is_some() { &self.inner.hits } else { &self.inner.misses };
        counter.fetch_add(1, Ordering::Relaxed);
        let prev = self.inner.bytes_outstanding.fetch_add(bytes_of::<T>(len), Ordering::Relaxed);
        // CAS-max the high-water mark. This is the only site that grows
        // the outstanding gauge, so updating the peak here (and only
        // here) keeps the two exactly consistent.
        let now = prev + bytes_of::<T>(len);
        let mut peak = self.inner.bytes_peak.load(Ordering::Relaxed);
        while now > peak {
            match self.inner.bytes_peak.compare_exchange_weak(
                peak,
                now,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => break,
                Err(observed) => peak = observed,
            }
        }
        popped
    }

    /// Lease a buffer of `len` elements, every element set to `fill` —
    /// the arena equivalent of `vec![fill; len]`. Zero-length requests
    /// bypass the arena entirely.
    pub fn take_filled<T: Copy + Send + 'static>(&self, len: usize, fill: T) -> Vec<T> {
        if len == 0 {
            return Vec::new();
        }
        match self.lease::<T>(len) {
            Some(mut vec) => {
                // Within the class capacity: never reallocates.
                vec.clear();
                vec.resize(len, fill);
                vec
            }
            None => {
                let mut vec = Vec::with_capacity(size_class(len));
                vec.resize(len, fill);
                vec
            }
        }
    }

    /// Lease a buffer holding a copy of `src` — the arena equivalent of
    /// `src.to_vec()`.
    pub fn take_copy<T: Copy + Send + 'static>(&self, src: &[T]) -> Vec<T> {
        if src.is_empty() {
            return Vec::new();
        }
        match self.lease::<T>(src.len()) {
            Some(mut vec) => {
                vec.clear();
                vec.extend_from_slice(src);
                vec
            }
            None => {
                let mut vec = Vec::with_capacity(size_class(src.len()));
                vec.extend_from_slice(src);
                vec
            }
        }
    }

    /// Lease a buffer of `len` elements **without initializing it**:
    /// recycled buffers keep their stale (but memory-safe — every
    /// element is an initialized `T`) previous contents up to the
    /// recycled length (elements past it, when the recycled buffer was
    /// shorter, are `T::default()`-filled); fresh allocations are
    /// `T::default()`-filled. For consumers that provably overwrite
    /// every element before reading (the decoders' reconstruction
    /// passes), where [`Arena::take_filled`]'s fill would be a wasted
    /// full-buffer memset on the warm path.
    pub fn take_stale<T: Copy + Default + Send + 'static>(&self, len: usize) -> Vec<T> {
        if len == 0 {
            return Vec::new();
        }
        match self.lease::<T>(len) {
            Some(mut vec) => {
                // Trim or default-extend within the class capacity.
                vec.resize(len, T::default());
                vec
            }
            None => {
                let mut vec = Vec::with_capacity(size_class(len));
                vec.resize(len, T::default());
                vec
            }
        }
    }

    /// Return a leased buffer to its free list. Must only be called
    /// with buffers obtained from [`Arena::take_filled`] /
    /// [`Arena::take_copy`] on this arena (the lease accounting
    /// underflows otherwise); recycle foreign buffers with
    /// [`Arena::adopt`].
    pub fn give<T: Copy + Send + 'static>(&self, vec: Vec<T>) {
        if vec.is_empty() {
            return;
        }
        self.inner.returns.fetch_add(1, Ordering::Relaxed);
        self.inner.bytes_outstanding.fetch_sub(bytes_of::<T>(vec.len()), Ordering::Relaxed);
        self.park(vec);
    }

    /// Record that a leased buffer escapes to the caller (it will never
    /// be `give`n back — e.g. it now backs an output grid the user
    /// owns). Clears it from the outstanding gauge.
    pub fn detach<T: Copy + Send + 'static>(&self, escaped: &[T]) {
        if escaped.is_empty() {
            return;
        }
        self.inner.detached.fetch_add(1, Ordering::Relaxed);
        self.inner.bytes_outstanding.fetch_sub(bytes_of::<T>(escaped.len()), Ordering::Relaxed);
    }

    /// Recycle a buffer the arena never leased (e.g. an output grid the
    /// caller is done with). It joins the free list without touching
    /// the lease accounting, making warm outputs allocation-free too.
    pub fn adopt<T: Copy + Send + 'static>(&self, vec: Vec<T>) {
        if vec.is_empty() {
            return;
        }
        self.inner.adopted.fetch_add(1, Ordering::Relaxed);
        self.park(vec);
    }

    /// RAII form of [`Arena::take_filled`]: the buffer returns to its
    /// size class when the lease drops — on every path, including
    /// unwinds — so the accounting stays exact even if the consumer
    /// panics.
    pub fn lease_filled<T: Copy + Send + 'static>(&self, len: usize, fill: T) -> ArenaLease<T> {
        ArenaLease { buf: Some(self.take_filled(len, fill)), arena: Some(self.clone()) }
    }

    /// RAII form of [`Arena::take_copy`] (see [`Arena::lease_filled`]).
    pub fn lease_copy<T: Copy + Send + 'static>(&self, src: &[T]) -> ArenaLease<T> {
        ArenaLease { buf: Some(self.take_copy(src)), arena: Some(self.clone()) }
    }

    /// RAII form of [`Arena::take_stale`] (see [`Arena::lease_filled`]).
    /// Callers must overwrite every element before reading.
    pub fn lease_stale<T: Copy + Default + Send + 'static>(&self, len: usize) -> ArenaLease<T> {
        ArenaLease { buf: Some(self.take_stale(len)), arena: Some(self.clone()) }
    }

    /// Snapshot the counters and gauges.
    pub fn stats(&self) -> ArenaStats {
        ArenaStats {
            hits: self.inner.hits.load(Ordering::Relaxed),
            misses: self.inner.misses.load(Ordering::Relaxed),
            returns: self.inner.returns.load(Ordering::Relaxed),
            detached: self.inner.detached.load(Ordering::Relaxed),
            adopted: self.inner.adopted.load(Ordering::Relaxed),
            dropped: self.inner.dropped.load(Ordering::Relaxed),
            bytes_outstanding: self.inner.bytes_outstanding.load(Ordering::Relaxed),
            bytes_pooled: self.inner.bytes_pooled.load(Ordering::Relaxed),
            bytes_peak: self.inner.bytes_peak.load(Ordering::Relaxed),
        }
    }

    /// Wrap an **already-accounted** leased buffer (obtained from a
    /// `take_*` on this arena) into an RAII [`ArenaLease`], so the rest
    /// of its life is panic-safe: the buffer is given back on drop and
    /// escapes via [`ArenaLease::detach`]. No counters move at wrap
    /// time — the original `take_*` already recorded the lease — which
    /// is the difference from [`Arena::lease_filled`] and friends.
    ///
    /// Calling this with a buffer the arena never leased corrupts the
    /// outstanding gauge on drop; recycle foreign buffers with
    /// [`Arena::adopt`] instead.
    pub fn relend<T: Copy + Send + 'static>(&self, buf: Vec<T>) -> ArenaLease<T> {
        ArenaLease { buf: Some(buf), arena: Some(self.clone()) }
    }

    /// [`Arena::relend`] for a buffer embedded in a
    /// [`Grid`](crate::data::grid::Grid): the grid stays usable through
    /// the lease (`Deref<Target = Grid<T>>`), and its backing `data`
    /// vector is given back to the arena when the lease drops.
    pub fn relend_grid<T: Copy + Send + 'static>(&self, grid: Grid<T>) -> GridLease<T> {
        GridLease { grid: Some(grid), arena: Some(self.clone()) }
    }
}

/// Which arena (if any) a scratch acquisition goes through.
///
/// Mirrors [`PoolHandle`](crate::util::pool::PoolHandle): most code
/// does not care and uses [`ArenaHandle::Fresh`], which is a direct
/// pass-through to the allocator — bit- and behavior-identical to the
/// historical `vec![..]` paths, with zero bookkeeping. The serving
/// layer threads [`ArenaHandle::Pooled`] down so every full-grid
/// buffer of a job is recycled across jobs.
#[derive(Clone, Copy, Default)]
pub enum ArenaHandle<'a> {
    /// Allocate fresh and drop on return — the historical behavior.
    #[default]
    Fresh,
    /// Lease from / return to the given arena.
    Pooled(&'a Arena),
}

impl ArenaHandle<'_> {
    /// [`Arena::take_filled`], or `vec![fill; len]` when `Fresh`.
    pub fn take_filled<T: Copy + Send + 'static>(self, len: usize, fill: T) -> Vec<T> {
        match self {
            ArenaHandle::Fresh => vec![fill; len],
            ArenaHandle::Pooled(a) => a.take_filled(len, fill),
        }
    }

    /// [`Arena::take_copy`], or `src.to_vec()` when `Fresh`.
    pub fn take_copy<T: Copy + Send + 'static>(self, src: &[T]) -> Vec<T> {
        match self {
            ArenaHandle::Fresh => src.to_vec(),
            ArenaHandle::Pooled(a) => a.take_copy(src),
        }
    }

    /// [`Arena::take_stale`], or `vec![T::default(); len]` when
    /// `Fresh`. Callers must overwrite every element before reading.
    pub fn take_stale<T: Copy + Default + Send + 'static>(self, len: usize) -> Vec<T> {
        match self {
            ArenaHandle::Fresh => vec![T::default(); len],
            ArenaHandle::Pooled(a) => a.take_stale(len),
        }
    }

    /// [`Arena::give`], or a plain drop when `Fresh`.
    pub fn give<T: Copy + Send + 'static>(self, vec: Vec<T>) {
        match self {
            ArenaHandle::Fresh => drop(vec),
            ArenaHandle::Pooled(a) => a.give(vec),
        }
    }

    /// [`Arena::detach`]; no-op when `Fresh`.
    pub fn detach<T: Copy + Send + 'static>(self, escaped: &[T]) {
        if let ArenaHandle::Pooled(a) = self {
            a.detach(escaped);
        }
    }

    /// [`Arena::lease_filled`] through the handle; a `Fresh` lease is a
    /// plain allocation that simply drops.
    pub fn lease_filled<T: Copy + Send + 'static>(self, len: usize, fill: T) -> ArenaLease<T> {
        match self {
            ArenaHandle::Fresh => ArenaLease { buf: Some(vec![fill; len]), arena: None },
            ArenaHandle::Pooled(a) => a.lease_filled(len, fill),
        }
    }

    /// [`Arena::lease_copy`] through the handle (see
    /// [`ArenaHandle::lease_filled`]).
    pub fn lease_copy<T: Copy + Send + 'static>(self, src: &[T]) -> ArenaLease<T> {
        match self {
            ArenaHandle::Fresh => ArenaLease { buf: Some(src.to_vec()), arena: None },
            ArenaHandle::Pooled(a) => a.lease_copy(src),
        }
    }

    /// [`Arena::lease_stale`] through the handle (see
    /// [`ArenaHandle::lease_filled`]). Callers must overwrite every
    /// element before reading.
    pub fn lease_stale<T: Copy + Default + Send + 'static>(self, len: usize) -> ArenaLease<T> {
        match self {
            ArenaHandle::Fresh => ArenaLease { buf: Some(vec![T::default(); len]), arena: None },
            ArenaHandle::Pooled(a) => a.lease_stale(len),
        }
    }

    /// [`Arena::relend`] through the handle: wrap a buffer this handle
    /// previously leased (via a `take_*`) into an RAII lease with no
    /// counter movement. For `Fresh` the lease is a plain owner that
    /// simply drops. The buffer **must** have come from a `take_*` on
    /// the same handle — see [`Arena::relend`].
    pub fn relend<T: Copy + Send + 'static>(self, buf: Vec<T>) -> ArenaLease<T> {
        match self {
            ArenaHandle::Fresh => ArenaLease { buf: Some(buf), arena: None },
            ArenaHandle::Pooled(a) => a.relend(buf),
        }
    }

    /// [`Arena::relend_grid`] through the handle (see
    /// [`ArenaHandle::relend`]).
    pub fn relend_grid<T: Copy + Send + 'static>(self, grid: Grid<T>) -> GridLease<T> {
        match self {
            ArenaHandle::Fresh => GridLease { grid: Some(grid), arena: None },
            ArenaHandle::Pooled(a) => a.relend_grid(grid),
        }
    }
}

/// An RAII lease of one arena buffer: derefs to the underlying slice,
/// **returns the buffer to its size class on drop** — on every exit
/// path, including panics, which keeps the arena's
/// hit/miss/bytes-outstanding accounting exact where the raw
/// `take_*`/`give` pairs would strand a lease on unwind — and escapes
/// via [`ArenaLease::detach`] when the buffer must outlive the lease
/// (e.g. a pipeline output embedded in a returned grid).
///
/// The lease deliberately exposes only slice access (no `Vec` growth or
/// shrink): the give-back accounting is keyed on the leased length, so
/// resizing under a lease would corrupt the gauges.
///
/// # Examples
///
/// ```
/// use qai::util::arena::Arena;
///
/// let arena = Arena::new();
/// {
///     let mut lease = arena.lease_filled(64, 0.0f32);
///     lease[0] = 1.5;
/// } // drop: buffer parked back in its class
/// assert_eq!(arena.stats().bytes_outstanding, 0);
/// assert_eq!(arena.stats().returns, 1);
/// let kept = arena.lease_filled(64, 0.0f32).detach(); // hit + escape
/// assert_eq!(kept.len(), 64);
/// assert_eq!(arena.stats().hits, 1);
/// assert_eq!(arena.stats().bytes_outstanding, 0);
/// ```
pub struct ArenaLease<T: Copy + Send + 'static> {
    /// `None` only after `detach` (and transiently during drop).
    buf: Option<Vec<T>>,
    /// `None` for `Fresh` leases: a plain allocation, no accounting.
    arena: Option<Arena>,
}

impl<T: Copy + Send + 'static> ArenaLease<T> {
    /// Number of leased elements.
    pub fn len(&self) -> usize {
        self.buf.as_ref().map_or(0, |b| b.len())
    }

    /// True if the lease holds no elements.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Keep the buffer: record the escape with the arena (clearing it
    /// from the outstanding gauge) and hand the `Vec` to the caller.
    /// The arena equivalent of [`Arena::detach`], as a move.
    pub fn detach(mut self) -> Vec<T> {
        let buf = self.buf.take().expect("lease already detached");
        if let Some(arena) = &self.arena {
            arena.detach(&buf);
        }
        buf
    }
}

impl<T: Copy + Send + 'static> std::ops::Deref for ArenaLease<T> {
    type Target = [T];

    fn deref(&self) -> &[T] {
        self.buf.as_deref().unwrap_or(&[])
    }
}

impl<T: Copy + Send + 'static> std::ops::DerefMut for ArenaLease<T> {
    fn deref_mut(&mut self) -> &mut [T] {
        self.buf.as_deref_mut().unwrap_or(&mut [])
    }
}

impl<T: Copy + Send + 'static> Drop for ArenaLease<T> {
    fn drop(&mut self) {
        if let Some(buf) = self.buf.take() {
            if let Some(arena) = &self.arena {
                arena.give(buf);
            }
        }
    }
}

impl<T: Copy + Send + std::fmt::Debug + 'static> std::fmt::Debug for ArenaLease<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ArenaLease")
            .field("len", &self.len())
            .field("pooled", &self.arena.is_some())
            .finish()
    }
}

/// An RAII lease of a whole [`Grid`] whose backing vector was leased
/// from an arena: derefs to the grid — so it can be passed anywhere a
/// `&Grid<T>` is expected for the lease's lifetime — and **gives the
/// grid's `data` vector back to the arena on drop**, including unwinds.
/// Produced by [`Arena::relend_grid`] / [`ArenaHandle::relend_grid`];
/// [`GridLease::detach`] is the escape hatch for grids that outlive the
/// lease (outputs handed to the caller).
///
/// This is what lets the pipeline keep its step A–D intermediates
/// (boundary mask, sign map, propagated signs, flip mask) as plain
/// grids flowing between steps while still being panic-safe leases —
/// the same RAII story [`ArenaLease`] gives raw vectors.
pub struct GridLease<T: Copy + Send + 'static> {
    /// `None` only after `detach` (and transiently during drop).
    grid: Option<Grid<T>>,
    /// `None` for `Fresh` leases: a plain owner, no accounting.
    arena: Option<Arena>,
}

impl<T: Copy + Send + 'static> GridLease<T> {
    /// Keep the grid: record the escape with the arena (clearing its
    /// data from the outstanding gauge) and hand the grid to the
    /// caller.
    pub fn detach(mut self) -> Grid<T> {
        let grid = self.grid.take().expect("grid lease already detached");
        if let Some(arena) = &self.arena {
            arena.detach(&grid.data);
        }
        grid
    }
}

impl<T: Copy + Send + 'static> std::ops::Deref for GridLease<T> {
    type Target = Grid<T>;

    fn deref(&self) -> &Grid<T> {
        self.grid.as_ref().expect("grid lease already detached")
    }
}

impl<T: Copy + Send + 'static> Drop for GridLease<T> {
    fn drop(&mut self) {
        if let Some(grid) = self.grid.take() {
            if let Some(arena) = &self.arena {
                arena.give(grid.data);
            }
        }
    }
}

impl<T: Copy + Send + std::fmt::Debug + 'static> std::fmt::Debug for GridLease<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("GridLease")
            .field("len", &self.grid.as_ref().map_or(0, |g| g.len()))
            .field("pooled", &self.arena.is_some())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn miss_then_hit_roundtrip() {
        let arena = Arena::new();
        let a: Vec<i64> = arena.take_filled(100, 7);
        assert!(a.iter().all(|&v| v == 7));
        assert_eq!(a.len(), 100);
        assert_eq!(a.capacity(), 128, "a miss allocates the full size class");
        let st = arena.stats();
        assert_eq!((st.hits, st.misses), (0, 1));
        assert_eq!(st.bytes_outstanding, 800, "outstanding tracks requested lengths");
        arena.give(a);
        let st = arena.stats();
        assert_eq!(st.bytes_outstanding, 0);
        assert_eq!(st.bytes_pooled, 1024, "pooled tracks the rounded class (128 x 8B)");
        let b: Vec<i64> = arena.take_filled(100, -3);
        assert!(b.iter().all(|&v| v == -3), "recycled buffer must be re-initialized");
        let st = arena.stats();
        assert_eq!((st.hits, st.misses), (1, 1));
        assert_eq!(st.bytes_pooled, 0);
        arena.give(b);
    }

    #[test]
    fn size_classes_round_to_the_next_power_of_two() {
        assert_eq!(size_class(1), 1);
        assert_eq!(size_class(8), 8);
        assert_eq!(size_class(9), 16);
        assert_eq!(size_class(100), 128);
        assert_eq!(size_class(13824), 16384); // a 24^3 grid
        assert_eq!(size_class(14400), 16384); // a 25x24x24 near-shape
    }

    #[test]
    fn classes_are_type_exact_and_length_rounded() {
        let arena = Arena::new();
        let a: Vec<f32> = arena.take_filled(64, 0.0);
        arena.give(a);
        // Same class, different type: miss.
        let b: Vec<u32> = arena.take_filled(64, 0);
        // Same type, next class up (65 -> 128): miss.
        let c: Vec<f32> = arena.take_filled(65, 0.0);
        assert_eq!(arena.stats().hits, 0);
        assert_eq!(arena.stats().misses, 3);
        // Near length in the same class (60 -> 64): hit on the parked
        // 64-element buffer.
        let d: Vec<f32> = arena.take_filled(60, 1.0);
        assert_eq!(d.len(), 60);
        assert!(d.iter().all(|&v| v == 1.0));
        assert_eq!(arena.stats().hits, 1);
        arena.give(b);
        arena.give(c);
        arena.give(d);
    }

    #[test]
    fn take_stale_skips_reinitialization() {
        let arena = Arena::new();
        let mut v: Vec<i64> = arena.take_stale(4);
        assert_eq!(v, vec![0; 4], "fresh stale leases are default-filled");
        v.copy_from_slice(&[1, 2, 3, 4]);
        arena.give(v);
        let v: Vec<i64> = arena.take_stale(4);
        assert_eq!(v, vec![1, 2, 3, 4], "recycled stale lease keeps previous contents");
        assert_eq!(arena.stats().hits, 1);
        arena.give(v);
    }

    #[test]
    fn take_copy_matches_source() {
        let arena = Arena::new();
        let src: Vec<f32> = (0..50).map(|i| i as f32 * 0.5).collect();
        let a = arena.take_copy(&src);
        assert_eq!(a, src);
        arena.give(a);
        let b = arena.take_copy(&src);
        assert_eq!(b, src);
        assert_eq!(arena.stats().hits, 1);
        arena.give(b);
    }

    #[test]
    fn detach_clears_outstanding_without_pooling() {
        let arena = Arena::new();
        let out: Vec<f32> = arena.take_filled(32, 1.0);
        arena.detach(&out);
        let st = arena.stats();
        assert_eq!(st.bytes_outstanding, 0);
        assert_eq!(st.bytes_pooled, 0);
        assert_eq!(st.detached, 1);
        // The buffer escaped; taking again is a miss.
        let next: Vec<f32> = arena.take_filled(32, 2.0);
        assert_eq!(arena.stats().misses, 2);
        arena.give(next);
        drop(out);
    }

    #[test]
    fn adopt_feeds_the_free_list() {
        let arena = Arena::new();
        arena.adopt(vec![0.25f32; 16]);
        let st = arena.stats();
        assert_eq!(st.adopted, 1);
        assert_eq!(st.bytes_outstanding, 0);
        let v: Vec<f32> = arena.take_filled(16, 0.0);
        assert_eq!(arena.stats().hits, 1);
        arena.give(v);
    }

    #[test]
    fn adopting_an_exactly_sized_foreign_buffer_serves_its_own_length() {
        // A `vec![..; 100]` has capacity 100 < its 128 size class;
        // park must grow it so a same-length lease hits instead of
        // permanently missing the rounded class.
        let arena = Arena::new();
        arena.adopt(vec![7i64; 100]);
        assert_eq!(arena.stats().bytes_pooled, 1024, "parked at the full 128 class");
        let v: Vec<i64> = arena.take_filled(100, 1);
        assert_eq!(arena.stats().hits, 1, "adopted foreign buffer must serve its own shape");
        assert!(v.iter().all(|&x| x == 1));
        arena.give(v);
    }

    #[test]
    fn class_capacity_is_bounded() {
        let arena = Arena::new();
        for _ in 0..(MAX_FREE_PER_CLASS + 3) {
            arena.adopt(vec![0u32; 8]);
        }
        let st = arena.stats();
        assert_eq!(st.dropped, 3);
        assert_eq!(st.bytes_pooled, (MAX_FREE_PER_CLASS * 8 * 4) as u64);
    }

    #[test]
    fn total_pooled_bytes_are_soft_capped_across_classes() {
        // 100-byte cap: distinct classes, so the per-class cap alone
        // would retain all of these (power-of-two lengths keep the
        // park class equal to the adopted capacity).
        let arena = Arena::with_limits(8, 100);
        arena.adopt(vec![0u8; 64]); // pooled: 64
        arena.adopt(vec![0u8; 32]); // pooled: 96
        arena.adopt(vec![0u8; 16]); // 96 + 16 > 100 → dropped
        arena.adopt(vec![0u8; 4]); // pooled: 100
        let st = arena.stats();
        assert_eq!(st.bytes_pooled, 100);
        assert_eq!(st.dropped, 1);
    }

    #[test]
    fn emptied_classes_are_removed_from_the_map() {
        let arena = Arena::new();
        let v: Vec<i64> = arena.take_filled(7, 0); // miss
        arena.give(v); // class (i64, 7) holds one buffer
        assert_eq!(arena.inner.classes.lock().unwrap().len(), 1);
        let v: Vec<i64> = arena.take_filled(7, 0); // hit: pops the last buffer
        assert_eq!(
            arena.inner.classes.lock().unwrap().len(),
            0,
            "popping a class empty must remove its map entry"
        );
        assert_eq!(arena.stats().hits, 1);
        arena.detach(&v); // balance the lease accounting
    }

    #[test]
    fn zero_length_bypasses_counters() {
        let arena = Arena::new();
        let v: Vec<i8> = arena.take_filled(0, 0);
        arena.give(v);
        arena.detach::<i8>(&[]);
        arena.adopt::<i8>(Vec::new());
        assert_eq!(arena.stats(), ArenaStats::default());
    }

    #[test]
    fn fresh_handle_is_a_pure_passthrough() {
        let h = ArenaHandle::Fresh;
        let v: Vec<f32> = h.take_filled(10, 3.0);
        assert_eq!(v, vec![3.0; 10]);
        let c = h.take_copy(&v);
        assert_eq!(c, v);
        h.give(v);
        h.detach(&c);
        h.give(c);
    }

    #[test]
    fn handles_share_state_across_clones_and_threads() {
        let arena = Arena::new();
        let a2 = arena.clone();
        let t = std::thread::spawn(move || {
            let v: Vec<i64> = a2.take_filled(256, 0);
            a2.give(v);
        });
        t.join().unwrap();
        let v: Vec<i64> = arena.take_filled(256, 1);
        assert_eq!(arena.stats().hits, 1);
        arena.give(v);
    }

    #[test]
    fn lease_returns_on_drop_and_detach_escapes() {
        let arena = Arena::new();
        {
            let mut lease = arena.lease_filled(100, 1.0f32);
            assert_eq!(lease.len(), 100);
            lease[10] = 2.0;
            assert_eq!(lease[10], 2.0);
            assert_eq!(arena.stats().bytes_outstanding, 400);
        } // drop gives back
        let st = arena.stats();
        assert_eq!((st.returns, st.bytes_outstanding), (1, 0));
        assert_eq!(st.bytes_pooled, 512, "parked at the rounded 128-element class");

        let kept = arena.lease_copy(&[3.0f32; 100]).detach();
        assert_eq!(kept, vec![3.0; 100]);
        let st = arena.stats();
        assert_eq!(st.hits, 1, "lease must reuse the dropped lease's buffer");
        assert_eq!(st.detached, 1);
        assert_eq!(st.bytes_outstanding, 0);
    }

    #[test]
    fn lease_keeps_accounting_exact_across_panics() {
        // The ROADMAP follow-up the lease exists for: a consumer panic
        // must not strand the outstanding gauge (raw take/give pairs
        // do).
        let arena = Arena::new();
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut lease = arena.lease_stale::<i64>(32);
            lease[0] = 7;
            panic!("consumer exploded mid-lease");
        }));
        assert!(result.is_err());
        let st = arena.stats();
        assert_eq!(st.bytes_outstanding, 0, "unwound lease must clear the gauge");
        assert_eq!(st.returns, 1, "unwound lease must park its buffer");
        // And the parked buffer is reusable.
        let v = arena.lease_stale::<i64>(32);
        assert_eq!(arena.stats().hits, 1);
        drop(v);
    }

    #[test]
    fn fresh_lease_is_plain_allocation() {
        let h = ArenaHandle::Fresh;
        let mut lease = h.lease_filled(8, 4u32);
        lease[3] = 9;
        assert_eq!(&lease[..], &[4, 4, 4, 9, 4, 4, 4, 4]);
        let owned = h.lease_copy(&[1u8, 2, 3]).detach();
        assert_eq!(owned, vec![1, 2, 3]);
        drop(lease); // no arena: nothing to account
        let stale: Vec<u16> = h.lease_stale(4).detach();
        assert_eq!(stale, vec![0; 4]);
    }

    #[test]
    fn bytes_peak_is_a_high_water_mark() {
        let arena = Arena::new();
        assert_eq!(arena.stats().bytes_peak, 0);
        let a: Vec<i64> = arena.take_filled(100, 0); // 800 B outstanding
        let b: Vec<i64> = arena.take_filled(50, 0); // 1200 B outstanding
        assert_eq!(arena.stats().bytes_peak, 1200, "peak = sum of concurrent leases");
        arena.give(a);
        arena.give(b);
        let st = arena.stats();
        assert_eq!(st.bytes_outstanding, 0);
        assert_eq!(st.bytes_peak, 1200, "gives must not lower the peak");
        // A smaller lease leaves the peak where it was; a pair that
        // overlaps higher raises it.
        let c: Vec<i64> = arena.take_filled(10, 0);
        assert_eq!(arena.stats().bytes_peak, 1200);
        let d: Vec<i64> = arena.take_filled(200, 0); // 80 + 1600 = 1680
        assert_eq!(arena.stats().bytes_peak, 1680);
        arena.give(c);
        arena.give(d);
    }

    #[test]
    fn relend_moves_no_counters_and_gives_on_drop() {
        let arena = Arena::new();
        let raw: Vec<f32> = arena.take_filled(64, 0.0);
        let before = arena.stats();
        {
            let lease = arena.relend(raw);
            assert_eq!(lease.len(), 64);
            // Wrapping is accounting-neutral: the take above already
            // recorded the lease.
            assert_eq!(arena.stats(), before);
        } // drop gives back
        let st = arena.stats();
        assert_eq!(st.returns, 1);
        assert_eq!(st.bytes_outstanding, 0);
    }

    #[test]
    fn grid_lease_derefs_and_returns_backing_data() {
        use crate::data::grid::Grid;
        let arena = Arena::new();
        let data: Vec<f32> = arena.take_filled(64, 2.0);
        {
            let lease = arena.relend_grid(Grid::from_vec(data, &[8, 8]));
            assert_eq!(lease.shape.user_dims(), &[8, 8]);
            assert_eq!(lease.data[0], 2.0);
        } // drop gives the backing vector back
        let st = arena.stats();
        assert_eq!((st.returns, st.bytes_outstanding), (1, 0));
        // detach escapes: outstanding clears without parking.
        let data: Vec<f32> = arena.take_filled(64, 3.0);
        let grid = arena.relend_grid(Grid::from_vec(data, &[8, 8])).detach();
        assert_eq!(grid.data[63], 3.0);
        let st = arena.stats();
        assert_eq!((st.detached, st.bytes_outstanding), (1, 0));
    }

    #[test]
    fn reuse_fraction_reported() {
        let arena = Arena::new();
        assert_eq!(arena.stats().reuse_fraction(), 0.0);
        let v: Vec<f32> = arena.take_filled(4, 0.0);
        arena.give(v);
        let v: Vec<f32> = arena.take_filled(4, 0.0);
        assert!((arena.stats().reuse_fraction() - 0.5).abs() < 1e-12);
        arena.give(v);
    }
}
