//! Log-bucketed latency histograms for the serving SLO layer.
//!
//! The admission scheduler records every completed job's queue wait and
//! service time into a [`LatencyHistogram`] — a fixed-size array of
//! power-of-two microsecond buckets. The fixed layout keeps recording
//! O(1) and allocation-free (the hot path runs under the admission
//! stats discipline, so a `Vec` resize there would be a latency spike
//! of its own), while still spanning sub-microsecond to multi-day
//! latencies. Quantiles are extracted by walking the cumulative counts
//! and reporting the *upper edge* of the bucket holding the target
//! rank, so a reported p99 is always an upper bound on the true p99 —
//! the conservative direction for an SLO readout.
//!
//! Bucket layout: bucket 0 holds sub-microsecond samples (`< 1 µs`);
//! bucket `i >= 1` holds `[2^(i-1), 2^i)` µs. With [`BUCKETS`] = 40 the
//! last regular bucket ends at `2^39` µs (~6.4 days); anything larger
//! clamps into it.

use std::time::Duration;

/// Number of power-of-two buckets. Bucket 0 is `< 1 µs`; bucket `i`
/// (for `i >= 1`) covers `[2^(i-1), 2^i)` µs.
pub const BUCKETS: usize = 40;

/// A fixed-layout log2 histogram over microsecond latencies.
///
/// `Copy` on purpose: snapshots are taken by value under a lock and
/// examined outside it, exactly like `ServiceStats`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LatencyHistogram {
    counts: [u64; BUCKETS],
    count: u64,
    total_us: u64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self { counts: [0; BUCKETS], count: 0, total_us: 0 }
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        Self::default()
    }

    /// Bucket index for a latency of `us` microseconds.
    pub fn bucket_index(us: u64) -> usize {
        if us == 0 {
            0
        } else {
            // floor(log2(us)) + 1, clamped into the last bucket.
            let idx = 64 - us.leading_zeros() as usize;
            idx.min(BUCKETS - 1)
        }
    }

    /// Inclusive upper edge (in µs) reported for bucket `idx`.
    pub fn bucket_upper_us(idx: usize) -> u64 {
        // Bucket 0 is "< 1 µs"; report 1 µs as its upper edge.
        1u64 << idx.min(63)
    }

    pub fn record(&mut self, latency: Duration) {
        let us = latency.as_micros().min(u64::MAX as u128) as u64;
        self.counts[Self::bucket_index(us)] += 1;
        self.count += 1;
        self.total_us = self.total_us.saturating_add(us);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn counts(&self) -> &[u64; BUCKETS] {
        &self.counts
    }

    /// Mean latency in milliseconds (0.0 when empty).
    pub fn mean_ms(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.total_us as f64 / self.count as f64 / 1000.0
        }
    }

    /// Quantile `q` in `[0, 1]` as milliseconds, reported as the upper
    /// edge of the bucket containing the `ceil(q * count)`-th sample
    /// (1-based). Returns 0.0 when the histogram is empty.
    pub fn quantile_ms(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let q = q.clamp(0.0, 1.0);
        let target = ((q * self.count as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (idx, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= target {
                return Self::bucket_upper_us(idx) as f64 / 1000.0;
            }
        }
        Self::bucket_upper_us(BUCKETS - 1) as f64 / 1000.0
    }

    /// Fold another histogram into this one (used to aggregate shards).
    pub fn merge(&mut self, other: &Self) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.count += other.count;
        self.total_us = self.total_us.saturating_add(other.total_us);
    }
}

/// A queue-wait / service-time histogram pair — the split every latency
/// readout in the metrics surface reports.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct LatencyPair {
    /// Time from enqueue to dispatch.
    pub wait: LatencyHistogram,
    /// Time from dispatch to completion (pipeline execution).
    pub exec: LatencyHistogram,
}

impl LatencyPair {
    pub fn merge(&mut self, other: &Self) {
        self.wait.merge(&other.wait);
        self.exec.merge(&other.exec);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_index_edges() {
        assert_eq!(LatencyHistogram::bucket_index(0), 0);
        assert_eq!(LatencyHistogram::bucket_index(1), 1);
        assert_eq!(LatencyHistogram::bucket_index(2), 2);
        assert_eq!(LatencyHistogram::bucket_index(3), 2);
        assert_eq!(LatencyHistogram::bucket_index(4), 3);
        assert_eq!(LatencyHistogram::bucket_index(7), 3);
        assert_eq!(LatencyHistogram::bucket_index(8), 4);
        assert_eq!(LatencyHistogram::bucket_index(u64::MAX), BUCKETS - 1);
    }

    #[test]
    fn quantiles_walk_cumulative_counts() {
        let mut h = LatencyHistogram::new();
        // 99 samples at 1 ms (bucket upper edge 1.024 ms) and one at
        // ~1 s: p50 sits in the 1 ms bucket, p99 still does (the 99th
        // of 100 ranked samples), p100 reaches the outlier.
        for _ in 0..99 {
            h.record(Duration::from_millis(1));
        }
        h.record(Duration::from_secs(1));
        assert_eq!(h.count(), 100);
        let p50 = h.quantile_ms(0.50);
        let p99 = h.quantile_ms(0.99);
        let p100 = h.quantile_ms(1.0);
        assert!(p50 >= 1.0 && p50 < 2.1, "p50={p50}");
        assert!(p99 >= 1.0 && p99 < 2.1, "p99={p99}");
        assert!(p100 >= 1000.0, "p100={p100}");
        assert!(h.mean_ms() > 1.0 && h.mean_ms() < 20.0);
    }

    #[test]
    fn empty_histogram_reports_zero() {
        let h = LatencyHistogram::new();
        assert_eq!(h.quantile_ms(0.5), 0.0);
        assert_eq!(h.mean_ms(), 0.0);
        assert_eq!(h.count(), 0);
    }

    #[test]
    fn merge_adds_counts() {
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        a.record(Duration::from_micros(3));
        b.record(Duration::from_micros(300));
        b.record(Duration::from_micros(300));
        a.merge(&b);
        assert_eq!(a.count(), 3);
        assert_eq!(a.counts()[LatencyHistogram::bucket_index(3)], 1);
        assert_eq!(a.counts()[LatencyHistogram::bucket_index(300)], 2);
    }
}
