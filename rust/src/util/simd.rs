//! Runtime-dispatched SIMD substrate for the numeric hot loops.
//!
//! Every kernel here exists in (up to) three forms — a portable scalar
//! reference that is always compiled, an SSE2 form, and an AVX2 form
//! (`std::arch` intrinsics, x86-64 only) — and every vectorized form is
//! **bit-identical** to its scalar twin: the kernels are built
//! exclusively from operations IEEE 754 defines exactly (add, sub, mul,
//! div, sqrt, format conversions) applied in the same order as the
//! scalar code, with no FMA contraction and no reassociation of
//! floating-point sums. `rust/tests/simd.rs` pins that equivalence
//! across datasets, dimensionalities, and thread counts.
//!
//! # Dispatch-once rule
//!
//! The active level is chosen **once per process** — the first call to
//! [`level`] runs CPU feature detection
//! (`is_x86_feature_detected!("avx2")`, with SSE2 implied by the
//! x86-64 baseline) and honors the `QAI_SIMD` environment variable
//! (`scalar` | `sse2` | `avx2` | `auto`; requests above what the CPU
//! supports clamp down, never up) — and is cached forever. Hot loops
//! therefore never re-branch on features, and the level is observable:
//! [`token`] feeds the `simd=` field of
//! [`render_metrics`](crate::mitigation::service::render_metrics) and
//! `qai version`.
//!
//! # Per-kernel level support
//!
//! Not every kernel has every form; a kernel called at a level it does
//! not implement runs the next level down (ultimately the scalar
//! reference), so forcing any level is always safe:
//!
//! | kernel | SSE2 | AVX2 |
//! |---|---|---|
//! | [`dequantize_into_with`] | yes | yes |
//! | [`quantize_with`] | scalar | yes |
//! | [`compensate_with`] | scalar | yes |
//! | [`delta_row_with`] / [`lorenzo_row2_with`] / [`lorenzo_row3_with`] | yes | yes |
//! | [`add_assign_i64_with`] | yes | yes |
//! | [`convolve_valid_with`] | yes | yes |
//! | [`ssim_moments_with`] | yes | yes |
//!
//! # Tail handling
//!
//! Every vectorized loop processes `len / LANES` full vector groups and
//! finishes the remainder with the scalar reference — and a vector
//! group whose inputs fall outside a fast path's exactness
//! preconditions (e.g. an `i64 → f64` magic-bias conversion needs
//! `|v| < 2⁵¹`) drops that one group to the scalar reference too, so
//! exactness never depends on input ranges.
//!
//! The `*_with(level, …)` variants exist so tests and benches can force
//! both the scalar reference and the detected ISA in one process; the
//! plain wrappers dispatch on the cached [`level`].

use std::sync::OnceLock;

/// Vector instruction set selected for this process.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum SimdLevel {
    /// Portable scalar reference (always available, every target).
    Scalar,
    /// 128-bit SSE2 (the x86-64 baseline).
    Sse2,
    /// 256-bit AVX2.
    Avx2,
}

impl SimdLevel {
    /// Lowercase token for metrics lines and the CLI (`scalar` | `sse2`
    /// | `avx2`).
    pub fn token(self) -> &'static str {
        match self {
            SimdLevel::Scalar => "scalar",
            SimdLevel::Sse2 => "sse2",
            SimdLevel::Avx2 => "avx2",
        }
    }
}

/// Best level this CPU can execute (independent of `QAI_SIMD`).
pub fn best_supported() -> SimdLevel {
    static BEST: OnceLock<SimdLevel> = OnceLock::new();
    *BEST.get_or_init(|| {
        #[cfg(target_arch = "x86_64")]
        {
            if std::arch::is_x86_feature_detected!("avx2") {
                SimdLevel::Avx2
            } else {
                // SSE2 is architecturally guaranteed on x86-64.
                SimdLevel::Sse2
            }
        }
        #[cfg(not(target_arch = "x86_64"))]
        {
            SimdLevel::Scalar
        }
    })
}

/// The process-wide dispatch level: CPU detection clamped by the
/// `QAI_SIMD` environment variable (`scalar` | `sse2` | `avx2` |
/// `auto`). Chosen on first call, cached forever (dispatch-once rule).
pub fn level() -> SimdLevel {
    static LEVEL: OnceLock<SimdLevel> = OnceLock::new();
    *LEVEL.get_or_init(|| {
        let best = best_supported();
        match std::env::var("QAI_SIMD").ok().as_deref() {
            Some("scalar") => SimdLevel::Scalar,
            Some("sse2") => best.min(SimdLevel::Sse2),
            Some("avx2") => best.min(SimdLevel::Avx2),
            _ => best,
        }
    })
}

/// [`SimdLevel::token`] of the active [`level`] — the `simd=` metrics
/// field.
pub fn token() -> &'static str {
    level().token()
}

/// Clamp a requested level to what this CPU can execute, so forced
/// `*_with` calls (tests, benches) are safe on any machine.
#[inline]
fn clamp(level: SimdLevel) -> SimdLevel {
    level.min(best_supported())
}

// ---------------------------------------------------------------------
// dequantize: out[i] = (q[i] as f64 * two_eps) as f32
// ---------------------------------------------------------------------

/// Dequantize `q` into `out`: `out[i] = (q[i] as f64 * two_eps) as
/// f32`, dispatched on the cached [`level`].
pub fn dequantize_into(q: &[i64], two_eps: f64, out: &mut [f32]) {
    dequantize_into_with(level(), q, two_eps, out)
}

/// [`dequantize_into`] at a forced level.
pub fn dequantize_into_with(level: SimdLevel, q: &[i64], two_eps: f64, out: &mut [f32]) {
    assert_eq!(q.len(), out.len(), "dequantize buffer length mismatch");
    match clamp(level) {
        SimdLevel::Scalar => dequantize_scalar(q, two_eps, out),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `clamp` proved the CPU supports the feature.
        SimdLevel::Sse2 => unsafe { x86::dequantize_sse2(q, two_eps, out) },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `clamp` proved the CPU supports the feature.
        SimdLevel::Avx2 => unsafe { x86::dequantize_avx2(q, two_eps, out) },
        #[cfg(not(target_arch = "x86_64"))]
        _ => dequantize_scalar(q, two_eps, out),
    }
}

fn dequantize_scalar(q: &[i64], two_eps: f64, out: &mut [f32]) {
    for (o, &qi) in out.iter_mut().zip(q) {
        *o = (qi as f64 * two_eps) as f32;
    }
}

// ---------------------------------------------------------------------
// quantize: out[i] = (data[i] as f64 * inv).round() as i64
// ---------------------------------------------------------------------

/// Quantize `data` into `out`: `out[i] = (data[i] as f64 *
/// inv).round() as i64` (round half away from zero, saturating cast),
/// dispatched on the cached [`level`].
pub fn quantize(data: &[f32], inv: f64, out: &mut [i64]) {
    quantize_with(level(), data, inv, out)
}

/// [`quantize`] at a forced level (SSE2 runs the scalar reference —
/// the tie-exact rounding needs `roundpd`, an SSE4.1 instruction).
pub fn quantize_with(level: SimdLevel, data: &[f32], inv: f64, out: &mut [i64]) {
    assert_eq!(data.len(), out.len(), "quantize buffer length mismatch");
    match clamp(level) {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `clamp` proved the CPU supports the feature.
        SimdLevel::Avx2 => unsafe { x86::quantize_avx2(data, inv, out) },
        _ => quantize_scalar(data, inv, out),
    }
}

fn quantize_scalar(data: &[f32], inv: f64, out: &mut [i64]) {
    for (o, &d) in out.iter_mut().zip(data) {
        *o = (d as f64 * inv).round() as i64;
    }
}

// ---------------------------------------------------------------------
// compensate: data[i] += idw_weight(d1[i], d2[i]) * sign[i] * eta_eps
// ---------------------------------------------------------------------

/// IDW compensation kernel (paper §VI, the non-tapered step-E inner
/// loop): for every `i` with `sign[i] != 0`,
/// `data[i] += (w(d1[i], d2[i]) * sign[i] as f64 * eta_eps) as f32`
/// where `w` is `k₂/(k₁+k₂)` from square-rooted distances with the
/// limit conventions of
/// [`idw_weight`](crate::mitigation::interpolate::idw_weight): `d1 >=
/// inf → 0`, `d1 == 0 → 1`, `d2 >= inf → 1`, `d2 == 0 → 0` (priority
/// in that order). `inf` is the caller's sentinel
/// ([`edt::INF`](crate::mitigation::edt::INF) in the pipeline).
/// Elements with `sign[i] == 0` are left bit-untouched.
pub fn compensate(data: &mut [f32], d1: &[i64], d2: &[i64], sign: &[i8], eta_eps: f64, inf: i64) {
    compensate_with(level(), data, d1, d2, sign, eta_eps, inf)
}

/// [`compensate`] at a forced level (SSE2 runs the scalar reference —
/// the mask logic needs 64-bit compares and `blendv`, SSE4.1+).
pub fn compensate_with(
    level: SimdLevel,
    data: &mut [f32],
    d1: &[i64],
    d2: &[i64],
    sign: &[i8],
    eta_eps: f64,
    inf: i64,
) {
    assert_eq!(data.len(), d1.len());
    assert_eq!(data.len(), d2.len());
    assert_eq!(data.len(), sign.len());
    match clamp(level) {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `clamp` proved the CPU supports the feature.
        SimdLevel::Avx2 => unsafe { x86::compensate_avx2(data, d1, d2, sign, eta_eps, inf) },
        _ => compensate_scalar(data, d1, d2, sign, eta_eps, inf),
    }
}

/// Scalar IDW weight with a parameterized sentinel — the same
/// arithmetic as
/// [`idw_weight`](crate::mitigation::interpolate::idw_weight), kept
/// here so the kernel has a self-contained scalar twin.
#[inline]
fn idw_weight_inf(d1: i64, d2: i64, inf: i64) -> f64 {
    if d1 >= inf {
        return 0.0;
    }
    if d1 == 0 {
        return 1.0;
    }
    if d2 >= inf {
        return 1.0;
    }
    if d2 == 0 {
        return 0.0;
    }
    let k1 = (d1 as f64).sqrt();
    let k2 = (d2 as f64).sqrt();
    k2 / (k1 + k2)
}

fn compensate_scalar(
    data: &mut [f32],
    d1: &[i64],
    d2: &[i64],
    sign: &[i8],
    eta_eps: f64,
    inf: i64,
) {
    for (i, v) in data.iter_mut().enumerate() {
        let s = sign[i];
        if s == 0 {
            continue;
        }
        let w = idw_weight_inf(d1[i], d2[i], inf);
        *v += (w * s as f64 * eta_eps) as f32;
    }
}

// ---------------------------------------------------------------------
// Lorenzo row kernels (pure i64 — any reorganization is bit-exact)
// ---------------------------------------------------------------------

/// Forward-Lorenzo row with no preceding neighbor rows (1D / first row
/// of a plane): `out[0] = c[0]`, `out[t] = c[t] − c[t−1]`.
pub fn delta_row(out: &mut [i64], c: &[i64]) {
    delta_row_with(level(), out, c)
}

/// [`delta_row`] at a forced level.
pub fn delta_row_with(level: SimdLevel, out: &mut [i64], c: &[i64]) {
    assert_eq!(out.len(), c.len());
    if out.is_empty() {
        return;
    }
    out[0] = c[0];
    match clamp(level) {
        SimdLevel::Scalar => delta_row_scalar(out, c),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `clamp` proved the CPU supports the feature.
        SimdLevel::Sse2 => unsafe { x86::delta_row_sse2(out, c) },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `clamp` proved the CPU supports the feature.
        SimdLevel::Avx2 => unsafe { x86::delta_row_avx2(out, c) },
        #[cfg(not(target_arch = "x86_64"))]
        _ => delta_row_scalar(out, c),
    }
}

fn delta_row_scalar(out: &mut [i64], c: &[i64]) {
    for t in 1..out.len() {
        out[t] = c[t] - c[t - 1];
    }
}

/// Forward-Lorenzo row with one preceding neighbor row `m` (2D
/// interior, or a 3D row on an `i = 0` / `j = 0` face): `out[0] = c[0]
/// − m[0]`, `out[t] = c[t] − m[t] − c[t−1] + m[t−1]`.
pub fn lorenzo_row2(out: &mut [i64], c: &[i64], m: &[i64]) {
    lorenzo_row2_with(level(), out, c, m)
}

/// [`lorenzo_row2`] at a forced level.
pub fn lorenzo_row2_with(level: SimdLevel, out: &mut [i64], c: &[i64], m: &[i64]) {
    assert_eq!(out.len(), c.len());
    assert_eq!(out.len(), m.len());
    if out.is_empty() {
        return;
    }
    out[0] = c[0] - m[0];
    match clamp(level) {
        SimdLevel::Scalar => lorenzo_row2_scalar(out, c, m),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `clamp` proved the CPU supports the feature.
        SimdLevel::Sse2 => unsafe { x86::lorenzo_row2_sse2(out, c, m) },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `clamp` proved the CPU supports the feature.
        SimdLevel::Avx2 => unsafe { x86::lorenzo_row2_avx2(out, c, m) },
        #[cfg(not(target_arch = "x86_64"))]
        _ => lorenzo_row2_scalar(out, c, m),
    }
}

fn lorenzo_row2_scalar(out: &mut [i64], c: &[i64], m: &[i64]) {
    for t in 1..out.len() {
        out[t] = c[t] - m[t] - c[t - 1] + m[t - 1];
    }
}

/// Forward-Lorenzo row in the 3D interior, with both axis-neighbor
/// rows `a` (i−1), `b` (j−1) and the diagonal row `ab` (i−1, j−1):
/// `out[0] = c[0] − a[0] − b[0] + ab[0]`,
/// `out[t] = c[t] − a[t] − b[t] + ab[t] − c[t−1] + a[t−1] + b[t−1] −
/// ab[t−1]` — the full 7-term inclusion–exclusion corner sum.
pub fn lorenzo_row3(out: &mut [i64], c: &[i64], a: &[i64], b: &[i64], ab: &[i64]) {
    lorenzo_row3_with(level(), out, c, a, b, ab)
}

/// [`lorenzo_row3`] at a forced level.
pub fn lorenzo_row3_with(
    level: SimdLevel,
    out: &mut [i64],
    c: &[i64],
    a: &[i64],
    b: &[i64],
    ab: &[i64],
) {
    assert_eq!(out.len(), c.len());
    assert_eq!(out.len(), a.len());
    assert_eq!(out.len(), b.len());
    assert_eq!(out.len(), ab.len());
    if out.is_empty() {
        return;
    }
    out[0] = c[0] - a[0] - b[0] + ab[0];
    match clamp(level) {
        SimdLevel::Scalar => lorenzo_row3_scalar(out, c, a, b, ab),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `clamp` proved the CPU supports the feature.
        SimdLevel::Sse2 => unsafe { x86::lorenzo_row3_sse2(out, c, a, b, ab) },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `clamp` proved the CPU supports the feature.
        SimdLevel::Avx2 => unsafe { x86::lorenzo_row3_avx2(out, c, a, b, ab) },
        #[cfg(not(target_arch = "x86_64"))]
        _ => lorenzo_row3_scalar(out, c, a, b, ab),
    }
}

fn lorenzo_row3_scalar(out: &mut [i64], c: &[i64], a: &[i64], b: &[i64], ab: &[i64]) {
    for t in 1..out.len() {
        out[t] = c[t] - a[t] - b[t] + ab[t] - c[t - 1] + a[t - 1] + b[t - 1] - ab[t - 1];
    }
}

/// In-place inclusive prefix sum (sequential carry — the inverse
/// Lorenzo in-row recurrence `h[k] = r[k] + h[k−1]`). Inherently
/// serial; kept here so the inverse's vectorizable row/plane adds have
/// their serial companion next to them.
pub fn prefix_sum_i64(row: &mut [i64]) {
    for t in 1..row.len() {
        row[t] += row[t - 1];
    }
}

/// `out[t] += prev[t]` over i64 rows/planes — the inverse Lorenzo
/// cross-row (`g[j] = g[j−1] + h[j]`) and cross-plane (`g[i] = g[i−1] +
/// d[i]`) accumulation, dispatched on the cached [`level`].
pub fn add_assign_i64(out: &mut [i64], prev: &[i64]) {
    add_assign_i64_with(level(), out, prev)
}

/// [`add_assign_i64`] at a forced level.
pub fn add_assign_i64_with(level: SimdLevel, out: &mut [i64], prev: &[i64]) {
    assert_eq!(out.len(), prev.len());
    match clamp(level) {
        SimdLevel::Scalar => add_assign_i64_scalar(out, prev),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `clamp` proved the CPU supports the feature.
        SimdLevel::Sse2 => unsafe { x86::add_assign_sse2(out, prev) },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `clamp` proved the CPU supports the feature.
        SimdLevel::Avx2 => unsafe { x86::add_assign_avx2(out, prev) },
        #[cfg(not(target_arch = "x86_64"))]
        _ => add_assign_i64_scalar(out, prev),
    }
}

fn add_assign_i64_scalar(out: &mut [i64], prev: &[i64]) {
    for (o, &p) in out.iter_mut().zip(prev) {
        *o += p;
    }
}

// ---------------------------------------------------------------------
// Convolution (interior positions, no boundary reflection)
// ---------------------------------------------------------------------

/// Valid-region 1D convolution: `out[p] = Σ_t kernel[t] * line[p + t]`
/// with taps accumulated in `t` order (the boundary-free interior of
/// [`filters`](crate::filters)' reflect-padded convolution), dispatched
/// on the cached [`level`]. Requires `line.len() == out.len() +
/// kernel.len() - 1`.
pub fn convolve_valid(out: &mut [f64], line: &[f64], kernel: &[f64]) {
    convolve_valid_with(level(), out, line, kernel)
}

/// [`convolve_valid`] at a forced level.
pub fn convolve_valid_with(level: SimdLevel, out: &mut [f64], line: &[f64], kernel: &[f64]) {
    assert!(!kernel.is_empty());
    assert_eq!(line.len(), out.len() + kernel.len() - 1, "valid-convolution length mismatch");
    match clamp(level) {
        SimdLevel::Scalar => convolve_valid_scalar(out, line, kernel),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `clamp` proved the CPU supports the feature.
        SimdLevel::Sse2 => unsafe { x86::convolve_valid_sse2(out, line, kernel) },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `clamp` proved the CPU supports the feature.
        SimdLevel::Avx2 => unsafe { x86::convolve_valid_avx2(out, line, kernel) },
        #[cfg(not(target_arch = "x86_64"))]
        _ => convolve_valid_scalar(out, line, kernel),
    }
}

fn convolve_valid_scalar(out: &mut [f64], line: &[f64], kernel: &[f64]) {
    for (p, o) in out.iter_mut().enumerate() {
        let mut acc = 0.0f64;
        for (t, &w) in kernel.iter().enumerate() {
            acc += w * line[p + t];
        }
        *o = acc;
    }
}

// ---------------------------------------------------------------------
// SSIM pointwise moment initialization
// ---------------------------------------------------------------------

/// Pointwise init of the five SSIM moment fields over range-normalized
/// inputs: `x = (xs[i] − lof) * inv`, `y = (ys[i] − lof) * inv`, then
/// `sx = x`, `sy = y`, `sxx = x·x`, `syy = y·y`, `sxy = x·y` — the
/// first pass of
/// [`ssim_fast`](crate::metrics::ssim_fast::ssim_fast), dispatched on
/// the cached [`level`]. All seven slices must share one length.
#[allow(clippy::too_many_arguments)]
pub fn ssim_moments(
    xs: &[f32],
    ys: &[f32],
    lof: f64,
    inv: f64,
    sx: &mut [f64],
    sy: &mut [f64],
    sxx: &mut [f64],
    syy: &mut [f64],
    sxy: &mut [f64],
) {
    ssim_moments_with(level(), xs, ys, lof, inv, sx, sy, sxx, syy, sxy)
}

/// [`ssim_moments`] at a forced level.
#[allow(clippy::too_many_arguments)]
pub fn ssim_moments_with(
    level: SimdLevel,
    xs: &[f32],
    ys: &[f32],
    lof: f64,
    inv: f64,
    sx: &mut [f64],
    sy: &mut [f64],
    sxx: &mut [f64],
    syy: &mut [f64],
    sxy: &mut [f64],
) {
    let n = xs.len();
    assert!(
        ys.len() == n
            && sx.len() == n
            && sy.len() == n
            && sxx.len() == n
            && syy.len() == n
            && sxy.len() == n,
        "ssim moment buffer length mismatch"
    );
    match clamp(level) {
        SimdLevel::Scalar => ssim_moments_scalar(xs, ys, lof, inv, sx, sy, sxx, syy, sxy),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `clamp` proved the CPU supports the feature.
        SimdLevel::Sse2 => unsafe {
            x86::ssim_moments_sse2(xs, ys, lof, inv, sx, sy, sxx, syy, sxy)
        },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `clamp` proved the CPU supports the feature.
        SimdLevel::Avx2 => unsafe {
            x86::ssim_moments_avx2(xs, ys, lof, inv, sx, sy, sxx, syy, sxy)
        },
        #[cfg(not(target_arch = "x86_64"))]
        _ => ssim_moments_scalar(xs, ys, lof, inv, sx, sy, sxx, syy, sxy),
    }
}

#[allow(clippy::too_many_arguments)]
fn ssim_moments_scalar(
    xs: &[f32],
    ys: &[f32],
    lof: f64,
    inv: f64,
    sx: &mut [f64],
    sy: &mut [f64],
    sxx: &mut [f64],
    syy: &mut [f64],
    sxy: &mut [f64],
) {
    for i in 0..xs.len() {
        let x = (xs[i] as f64 - lof) * inv;
        let y = (ys[i] as f64 - lof) * inv;
        sx[i] = x;
        sy[i] = y;
        sxx[i] = x * x;
        syy[i] = y * y;
        sxy[i] = x * y;
    }
}

// ---------------------------------------------------------------------
// x86-64 intrinsic implementations
// ---------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod x86 {
    use super::{
        compensate_scalar, convolve_valid_scalar, delta_row_scalar, dequantize_scalar,
        lorenzo_row2_scalar, lorenzo_row3_scalar, quantize_scalar, ssim_moments_scalar,
    };
    use std::arch::x86_64::*;

    /// Bit pattern of 1.5·2⁵², the bias of the exact `i64 → f64` trick:
    /// integer-adding `v` (|v| < 2⁵¹) into the mantissa field and
    /// subtracting the bias as a double yields exactly `v as f64`.
    const MAGIC_I: i64 = 0x4338000000000000;
    const MAGIC_D: f64 = 6755399441055744.0; // 1.5 * 2^52
    /// Magic-conversion validity bound: |v| < 2⁵¹.
    const LIM: i64 = 1 << 51;

    #[inline]
    fn magic_ok(v: i64) -> bool {
        (-LIM..LIM).contains(&v)
    }

    // ---- dequantize ----

    #[target_feature(enable = "avx2")]
    pub unsafe fn dequantize_avx2(q: &[i64], two_eps: f64, out: &mut [f32]) {
        let n = q.len();
        let te = _mm256_set1_pd(two_eps);
        let magic_i = _mm256_set1_epi64x(MAGIC_I);
        let magic_d = _mm256_set1_pd(MAGIC_D);
        let hi = _mm256_set1_epi64x(LIM - 1); // v > LIM-1  ⇔  v >= LIM
        let lo = _mm256_set1_epi64x(-LIM); // -LIM > v ⇔ v < -LIM
        let mut i = 0usize;
        while i + 4 <= n {
            let v = _mm256_loadu_si256(q.as_ptr().add(i) as *const __m256i);
            let too_hi = _mm256_cmpgt_epi64(v, hi);
            let too_lo = _mm256_cmpgt_epi64(lo, v);
            let bad = _mm256_or_si256(too_hi, too_lo);
            if _mm256_movemask_pd(_mm256_castsi256_pd(bad)) != 0 {
                dequantize_scalar(&q[i..i + 4], two_eps, &mut out[i..i + 4]);
            } else {
                let biased = _mm256_add_epi64(v, magic_i);
                let d = _mm256_sub_pd(_mm256_castsi256_pd(biased), magic_d);
                let f = _mm256_cvtpd_ps(_mm256_mul_pd(d, te));
                _mm_storeu_ps(out.as_mut_ptr().add(i), f);
            }
            i += 4;
        }
        dequantize_scalar(&q[i..], two_eps, &mut out[i..]);
    }

    #[target_feature(enable = "sse2")]
    pub unsafe fn dequantize_sse2(q: &[i64], two_eps: f64, out: &mut [f32]) {
        let n = q.len();
        let te = _mm_set1_pd(two_eps);
        let magic_i = _mm_set1_epi64x(MAGIC_I);
        let magic_d = _mm_set1_pd(MAGIC_D);
        let mut i = 0usize;
        while i + 2 <= n {
            // SSE2 has no 64-bit compare; range-check the pair in scalar.
            if magic_ok(q[i]) && magic_ok(q[i + 1]) {
                let v = _mm_loadu_si128(q.as_ptr().add(i) as *const __m128i);
                let biased = _mm_add_epi64(v, magic_i);
                let d = _mm_sub_pd(_mm_castsi128_pd(biased), magic_d);
                let f = _mm_cvtpd_ps(_mm_mul_pd(d, te));
                // Low two f32 lanes hold the results; store them as the
                // low 8 bytes (std::arch has no __m64, so go through i64).
                _mm_storel_epi64(out.as_mut_ptr().add(i) as *mut __m128i, _mm_castps_si128(f));
            } else {
                dequantize_scalar(&q[i..i + 2], two_eps, &mut out[i..i + 2]);
            }
            i += 2;
        }
        dequantize_scalar(&q[i..], two_eps, &mut out[i..]);
    }

    // ---- quantize ----

    #[target_feature(enable = "avx2")]
    pub unsafe fn quantize_avx2(data: &[f32], inv: f64, out: &mut [i64]) {
        let n = data.len();
        let vinv = _mm256_set1_pd(inv);
        let sign_mask = _mm256_set1_pd(-0.0);
        let half = _mm256_set1_pd(0.5);
        let one = _mm256_set1_pd(1.0);
        let mut i = 0usize;
        while i + 4 <= n {
            let d = _mm256_cvtps_pd(_mm_loadu_ps(data.as_ptr().add(i)));
            let x = _mm256_mul_pd(d, vinv);
            // round-half-away-from-zero, exactly matching f64::round():
            // trunc, take the (exact) fractional part, and bump by
            // copysign(1, x) where |frac| >= 0.5.
            let t = _mm256_round_pd(x, _MM_FROUND_TO_ZERO | _MM_FROUND_NO_EXC);
            let frac = _mm256_sub_pd(x, t);
            let absfrac = _mm256_andnot_pd(sign_mask, frac);
            let bump = _mm256_cmp_pd(absfrac, half, _CMP_GE_OQ);
            let signed_one = _mm256_or_pd(_mm256_and_pd(x, sign_mask), one);
            let r = _mm256_add_pd(t, _mm256_and_pd(bump, signed_one));
            let mut tmp = [0.0f64; 4];
            _mm256_storeu_pd(tmp.as_mut_ptr(), r);
            // f64 → i64 saturating casts stay scalar (no packed form in
            // AVX2); the rounded doubles are already bit-identical to
            // the scalar path's, so the casts agree too.
            for (l, &tv) in tmp.iter().enumerate() {
                *out.get_unchecked_mut(i + l) = tv as i64;
            }
            i += 4;
        }
        quantize_scalar(&data[i..], inv, &mut out[i..]);
    }

    // ---- compensate ----

    #[target_feature(enable = "avx2")]
    pub unsafe fn compensate_avx2(
        data: &mut [f32],
        d1: &[i64],
        d2: &[i64],
        sign: &[i8],
        eta_eps: f64,
        inf: i64,
    ) {
        let n = data.len();
        let vinf = _mm256_set1_epi64x(inf - 1); // v > inf-1 ⇔ v >= inf
        let lim = _mm256_set1_epi64x(LIM - 1);
        let zero_i = _mm256_setzero_si256();
        let zero_d = _mm256_setzero_pd();
        let one_d = _mm256_set1_pd(1.0);
        let magic_i = _mm256_set1_epi64x(MAGIC_I);
        let magic_d = _mm256_set1_pd(MAGIC_D);
        let veta = _mm256_set1_pd(eta_eps);
        let mut i = 0usize;
        while i + 4 <= n {
            let v1 = _mm256_loadu_si256(d1.as_ptr().add(i) as *const __m256i);
            let v2 = _mm256_loadu_si256(d2.as_ptr().add(i) as *const __m256i);
            // Lanes at or above the sentinel are masked to 0/1 and never
            // converted; remaining lanes must sit inside the magic
            // conversion range (negative distances never occur). A lane
            // in the gap [2^51, inf) falls back to scalar for the group.
            let inf1 = _mm256_cmpgt_epi64(v1, vinf);
            let inf2 = _mm256_cmpgt_epi64(v2, vinf);
            let big1 = _mm256_andnot_si256(inf1, _mm256_cmpgt_epi64(v1, lim));
            let big2 = _mm256_andnot_si256(inf2, _mm256_cmpgt_epi64(v2, lim));
            let neg = _mm256_or_si256(
                _mm256_cmpgt_epi64(zero_i, v1),
                _mm256_cmpgt_epi64(zero_i, v2),
            );
            let bad = _mm256_or_si256(_mm256_or_si256(big1, big2), neg);
            if _mm256_movemask_pd(_mm256_castsi256_pd(bad)) != 0 {
                compensate_scalar(
                    &mut data[i..i + 4],
                    &d1[i..i + 4],
                    &d2[i..i + 4],
                    &sign[i..i + 4],
                    eta_eps,
                    inf,
                );
                i += 4;
                continue;
            }
            let zero1 = _mm256_cmpeq_epi64(v1, zero_i);
            let zero2 = _mm256_cmpeq_epi64(v2, zero_i);
            // k2 / (k1 + k2) — correctly-rounded sqrt/add/div, same ops
            // and order as the scalar weight.
            let k1 = _mm256_sqrt_pd(_mm256_sub_pd(
                _mm256_castsi256_pd(_mm256_add_epi64(v1, magic_i)),
                magic_d,
            ));
            let k2 = _mm256_sqrt_pd(_mm256_sub_pd(
                _mm256_castsi256_pd(_mm256_add_epi64(v2, magic_i)),
                magic_d,
            ));
            let mut w = _mm256_div_pd(k2, _mm256_add_pd(k1, k2));
            // Limit conventions, lowest priority first so the highest
            // priority blend lands last (d1>=inf beats everything).
            w = _mm256_blendv_pd(w, zero_d, _mm256_castsi256_pd(zero2));
            w = _mm256_blendv_pd(w, one_d, _mm256_castsi256_pd(inf2));
            w = _mm256_blendv_pd(w, one_d, _mm256_castsi256_pd(zero1));
            w = _mm256_blendv_pd(w, zero_d, _mm256_castsi256_pd(inf1));
            // contribution = (w * sign) * eta_eps, narrowed to f32 and
            // added — identical op order to the scalar loop.
            let s0 = *sign.get_unchecked(i) as i32;
            let s1 = *sign.get_unchecked(i + 1) as i32;
            let s2 = *sign.get_unchecked(i + 2) as i32;
            let s3 = *sign.get_unchecked(i + 3) as i32;
            let sv = _mm256_set_pd(s3 as f64, s2 as f64, s1 as f64, s0 as f64);
            let c = _mm_cvtpd_ps(_mm256_mul_pd(_mm256_mul_pd(w, sv), veta));
            let old = _mm_loadu_ps(data.as_ptr().add(i));
            let new = _mm_add_ps(old, c);
            // sign == 0 lanes keep their original bits (the scalar loop
            // skips them entirely — adding ±0.0 could flip -0.0).
            let keep = _mm_castsi128_ps(_mm_setr_epi32(
                if s0 == 0 { -1 } else { 0 },
                if s1 == 0 { -1 } else { 0 },
                if s2 == 0 { -1 } else { 0 },
                if s3 == 0 { -1 } else { 0 },
            ));
            _mm_storeu_ps(data.as_mut_ptr().add(i), _mm_blendv_ps(new, old, keep));
            i += 4;
        }
        compensate_scalar(&mut data[i..], &d1[i..], &d2[i..], &sign[i..], eta_eps, inf);
    }

    // ---- Lorenzo i64 row kernels ----

    #[target_feature(enable = "avx2")]
    pub unsafe fn delta_row_avx2(out: &mut [i64], c: &[i64]) {
        let n = out.len();
        let mut t = 1usize;
        while t + 4 <= n {
            let cur = _mm256_loadu_si256(c.as_ptr().add(t) as *const __m256i);
            let prev = _mm256_loadu_si256(c.as_ptr().add(t - 1) as *const __m256i);
            let r = _mm256_sub_epi64(cur, prev);
            _mm256_storeu_si256(out.as_mut_ptr().add(t) as *mut __m256i, r);
            t += 4;
        }
        for t in t..n {
            out[t] = c[t] - c[t - 1];
        }
    }

    #[target_feature(enable = "sse2")]
    pub unsafe fn delta_row_sse2(out: &mut [i64], c: &[i64]) {
        let n = out.len();
        let mut t = 1usize;
        while t + 2 <= n {
            let cur = _mm_loadu_si128(c.as_ptr().add(t) as *const __m128i);
            let prev = _mm_loadu_si128(c.as_ptr().add(t - 1) as *const __m128i);
            let r = _mm_sub_epi64(cur, prev);
            _mm_storeu_si128(out.as_mut_ptr().add(t) as *mut __m128i, r);
            t += 2;
        }
        for t in t..n {
            out[t] = c[t] - c[t - 1];
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn lorenzo_row2_avx2(out: &mut [i64], c: &[i64], m: &[i64]) {
        let n = out.len();
        let mut t = 1usize;
        while t + 4 <= n {
            let cc = _mm256_loadu_si256(c.as_ptr().add(t) as *const __m256i);
            let mm = _mm256_loadu_si256(m.as_ptr().add(t) as *const __m256i);
            let cp = _mm256_loadu_si256(c.as_ptr().add(t - 1) as *const __m256i);
            let mp = _mm256_loadu_si256(m.as_ptr().add(t - 1) as *const __m256i);
            let r = _mm256_add_epi64(
                _mm256_sub_epi64(_mm256_sub_epi64(cc, mm), cp),
                mp,
            );
            _mm256_storeu_si256(out.as_mut_ptr().add(t) as *mut __m256i, r);
            t += 4;
        }
        if t < n {
            lorenzo_row2_tail(out, c, m, t);
        }
    }

    #[target_feature(enable = "sse2")]
    pub unsafe fn lorenzo_row2_sse2(out: &mut [i64], c: &[i64], m: &[i64]) {
        let n = out.len();
        let mut t = 1usize;
        while t + 2 <= n {
            let cc = _mm_loadu_si128(c.as_ptr().add(t) as *const __m128i);
            let mm = _mm_loadu_si128(m.as_ptr().add(t) as *const __m128i);
            let cp = _mm_loadu_si128(c.as_ptr().add(t - 1) as *const __m128i);
            let mp = _mm_loadu_si128(m.as_ptr().add(t - 1) as *const __m128i);
            let r = _mm_add_epi64(_mm_sub_epi64(_mm_sub_epi64(cc, mm), cp), mp);
            _mm_storeu_si128(out.as_mut_ptr().add(t) as *mut __m128i, r);
            t += 2;
        }
        if t < n {
            lorenzo_row2_tail(out, c, m, t);
        }
    }

    fn lorenzo_row2_tail(out: &mut [i64], c: &[i64], m: &[i64], from: usize) {
        for t in from..out.len() {
            out[t] = c[t] - m[t] - c[t - 1] + m[t - 1];
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn lorenzo_row3_avx2(out: &mut [i64], c: &[i64], a: &[i64], b: &[i64], ab: &[i64]) {
        let n = out.len();
        let mut t = 1usize;
        while t + 4 <= n {
            let cc = _mm256_loadu_si256(c.as_ptr().add(t) as *const __m256i);
            let aa = _mm256_loadu_si256(a.as_ptr().add(t) as *const __m256i);
            let bb = _mm256_loadu_si256(b.as_ptr().add(t) as *const __m256i);
            let dd = _mm256_loadu_si256(ab.as_ptr().add(t) as *const __m256i);
            let cp = _mm256_loadu_si256(c.as_ptr().add(t - 1) as *const __m256i);
            let ap = _mm256_loadu_si256(a.as_ptr().add(t - 1) as *const __m256i);
            let bp = _mm256_loadu_si256(b.as_ptr().add(t - 1) as *const __m256i);
            let dp = _mm256_loadu_si256(ab.as_ptr().add(t - 1) as *const __m256i);
            let cur = _mm256_add_epi64(_mm256_sub_epi64(_mm256_sub_epi64(cc, aa), bb), dd);
            let prev = _mm256_add_epi64(_mm256_sub_epi64(_mm256_sub_epi64(cp, ap), bp), dp);
            let r = _mm256_sub_epi64(cur, prev);
            _mm256_storeu_si256(out.as_mut_ptr().add(t) as *mut __m256i, r);
            t += 4;
        }
        if t < n {
            lorenzo_row3_tail(out, c, a, b, ab, t);
        }
    }

    #[target_feature(enable = "sse2")]
    pub unsafe fn lorenzo_row3_sse2(out: &mut [i64], c: &[i64], a: &[i64], b: &[i64], ab: &[i64]) {
        let n = out.len();
        let mut t = 1usize;
        while t + 2 <= n {
            let cc = _mm_loadu_si128(c.as_ptr().add(t) as *const __m128i);
            let aa = _mm_loadu_si128(a.as_ptr().add(t) as *const __m128i);
            let bb = _mm_loadu_si128(b.as_ptr().add(t) as *const __m128i);
            let dd = _mm_loadu_si128(ab.as_ptr().add(t) as *const __m128i);
            let cp = _mm_loadu_si128(c.as_ptr().add(t - 1) as *const __m128i);
            let ap = _mm_loadu_si128(a.as_ptr().add(t - 1) as *const __m128i);
            let bp = _mm_loadu_si128(b.as_ptr().add(t - 1) as *const __m128i);
            let dp = _mm_loadu_si128(ab.as_ptr().add(t - 1) as *const __m128i);
            let cur = _mm_add_epi64(_mm_sub_epi64(_mm_sub_epi64(cc, aa), bb), dd);
            let prev = _mm_add_epi64(_mm_sub_epi64(_mm_sub_epi64(cp, ap), bp), dp);
            let r = _mm_sub_epi64(cur, prev);
            _mm_storeu_si128(out.as_mut_ptr().add(t) as *mut __m128i, r);
            t += 2;
        }
        if t < n {
            lorenzo_row3_tail(out, c, a, b, ab, t);
        }
    }

    fn lorenzo_row3_tail(
        out: &mut [i64],
        c: &[i64],
        a: &[i64],
        b: &[i64],
        ab: &[i64],
        from: usize,
    ) {
        for t in from..out.len() {
            out[t] = c[t] - a[t] - b[t] + ab[t] - c[t - 1] + a[t - 1] + b[t - 1] - ab[t - 1];
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn add_assign_avx2(out: &mut [i64], prev: &[i64]) {
        let n = out.len();
        let mut i = 0usize;
        while i + 4 <= n {
            let o = _mm256_loadu_si256(out.as_ptr().add(i) as *const __m256i);
            let p = _mm256_loadu_si256(prev.as_ptr().add(i) as *const __m256i);
            _mm256_storeu_si256(out.as_mut_ptr().add(i) as *mut __m256i, _mm256_add_epi64(o, p));
            i += 4;
        }
        for i in i..n {
            out[i] += prev[i];
        }
    }

    #[target_feature(enable = "sse2")]
    pub unsafe fn add_assign_sse2(out: &mut [i64], prev: &[i64]) {
        let n = out.len();
        let mut i = 0usize;
        while i + 2 <= n {
            let o = _mm_loadu_si128(out.as_ptr().add(i) as *const __m128i);
            let p = _mm_loadu_si128(prev.as_ptr().add(i) as *const __m128i);
            _mm_storeu_si128(out.as_mut_ptr().add(i) as *mut __m128i, _mm_add_epi64(o, p));
            i += 2;
        }
        for i in i..n {
            out[i] += prev[i];
        }
    }

    // ---- convolution ----

    #[target_feature(enable = "avx2")]
    pub unsafe fn convolve_valid_avx2(out: &mut [f64], line: &[f64], kernel: &[f64]) {
        let n = out.len();
        let mut p = 0usize;
        while p + 4 <= n {
            let mut acc = _mm256_setzero_pd();
            // Taps accumulate in `t` order per output lane — the exact
            // scalar summation order (mul then add; no FMA, which would
            // change the rounding).
            for (t, &w) in kernel.iter().enumerate() {
                let vw = _mm256_set1_pd(w);
                let vl = _mm256_loadu_pd(line.as_ptr().add(p + t));
                acc = _mm256_add_pd(acc, _mm256_mul_pd(vw, vl));
            }
            _mm256_storeu_pd(out.as_mut_ptr().add(p), acc);
            p += 4;
        }
        convolve_valid_scalar(&mut out[p..], &line[p..], kernel);
    }

    #[target_feature(enable = "sse2")]
    pub unsafe fn convolve_valid_sse2(out: &mut [f64], line: &[f64], kernel: &[f64]) {
        let n = out.len();
        let mut p = 0usize;
        while p + 2 <= n {
            let mut acc = _mm_setzero_pd();
            for (t, &w) in kernel.iter().enumerate() {
                let vw = _mm_set1_pd(w);
                let vl = _mm_loadu_pd(line.as_ptr().add(p + t));
                acc = _mm_add_pd(acc, _mm_mul_pd(vw, vl));
            }
            _mm_storeu_pd(out.as_mut_ptr().add(p), acc);
            p += 2;
        }
        convolve_valid_scalar(&mut out[p..], &line[p..], kernel);
    }

    // ---- SSIM moments ----

    #[target_feature(enable = "avx2")]
    #[allow(clippy::too_many_arguments)]
    pub unsafe fn ssim_moments_avx2(
        xs: &[f32],
        ys: &[f32],
        lof: f64,
        inv: f64,
        sx: &mut [f64],
        sy: &mut [f64],
        sxx: &mut [f64],
        syy: &mut [f64],
        sxy: &mut [f64],
    ) {
        let n = xs.len();
        let vlo = _mm256_set1_pd(lof);
        let vinv = _mm256_set1_pd(inv);
        let mut i = 0usize;
        while i + 4 <= n {
            let x = _mm256_mul_pd(
                _mm256_sub_pd(_mm256_cvtps_pd(_mm_loadu_ps(xs.as_ptr().add(i))), vlo),
                vinv,
            );
            let y = _mm256_mul_pd(
                _mm256_sub_pd(_mm256_cvtps_pd(_mm_loadu_ps(ys.as_ptr().add(i))), vlo),
                vinv,
            );
            _mm256_storeu_pd(sx.as_mut_ptr().add(i), x);
            _mm256_storeu_pd(sy.as_mut_ptr().add(i), y);
            _mm256_storeu_pd(sxx.as_mut_ptr().add(i), _mm256_mul_pd(x, x));
            _mm256_storeu_pd(syy.as_mut_ptr().add(i), _mm256_mul_pd(y, y));
            _mm256_storeu_pd(sxy.as_mut_ptr().add(i), _mm256_mul_pd(x, y));
            i += 4;
        }
        ssim_moments_scalar(
            &xs[i..],
            &ys[i..],
            lof,
            inv,
            &mut sx[i..],
            &mut sy[i..],
            &mut sxx[i..],
            &mut syy[i..],
            &mut sxy[i..],
        );
    }

    #[target_feature(enable = "sse2")]
    #[allow(clippy::too_many_arguments)]
    pub unsafe fn ssim_moments_sse2(
        xs: &[f32],
        ys: &[f32],
        lof: f64,
        inv: f64,
        sx: &mut [f64],
        sy: &mut [f64],
        sxx: &mut [f64],
        syy: &mut [f64],
        sxy: &mut [f64],
    ) {
        let n = xs.len();
        let vlo = _mm_set1_pd(lof);
        let vinv = _mm_set1_pd(inv);
        let mut i = 0usize;
        while i + 2 <= n {
            // `_mm_cvtps_pd` widens the two low f32 lanes.
            let xf = _mm_castsi128_ps(_mm_loadl_epi64(xs.as_ptr().add(i) as *const __m128i));
            let yf = _mm_castsi128_ps(_mm_loadl_epi64(ys.as_ptr().add(i) as *const __m128i));
            let x = _mm_mul_pd(_mm_sub_pd(_mm_cvtps_pd(xf), vlo), vinv);
            let y = _mm_mul_pd(_mm_sub_pd(_mm_cvtps_pd(yf), vlo), vinv);
            _mm_storeu_pd(sx.as_mut_ptr().add(i), x);
            _mm_storeu_pd(sy.as_mut_ptr().add(i), y);
            _mm_storeu_pd(sxx.as_mut_ptr().add(i), _mm_mul_pd(x, x));
            _mm_storeu_pd(syy.as_mut_ptr().add(i), _mm_mul_pd(y, y));
            _mm_storeu_pd(sxy.as_mut_ptr().add(i), _mm_mul_pd(x, y));
            i += 2;
        }
        ssim_moments_scalar(
            &xs[i..],
            &ys[i..],
            lof,
            inv,
            &mut sx[i..],
            &mut sy[i..],
            &mut sxx[i..],
            &mut syy[i..],
            &mut sxy[i..],
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::prop_check;

    /// Widest lane count of any vector form (AVX2 i64/f64 = 4).
    const LANES: usize = 4;

    fn levels() -> Vec<SimdLevel> {
        // Scalar plus everything the CPU can actually run.
        let mut v = vec![SimdLevel::Scalar];
        if best_supported() >= SimdLevel::Sse2 {
            v.push(SimdLevel::Sse2);
        }
        if best_supported() >= SimdLevel::Avx2 {
            v.push(SimdLevel::Avx2);
        }
        v
    }

    #[test]
    fn level_token_is_stable() {
        assert!(["scalar", "sse2", "avx2"].contains(&token()));
        assert_eq!(level(), level(), "dispatch must be chosen once");
    }

    #[test]
    fn dequantize_bit_identical_all_lengths_and_offsets() {
        // Tail lengths 1..=2*LANES and unaligned starting offsets, plus
        // values outside the magic-conversion range.
        prop_check("simd dequantize", 60, |g| {
            let off = g.usize_in(0, LANES);
            let n = off + g.usize_in(1, 2 * LANES + 9);
            let mut q: Vec<i64> =
                (0..n).map(|_| g.usize_in(0, 2_000_000) as i64 - 1_000_000).collect();
            if g.bool_with(0.3) {
                let i = g.usize_in(0, n - 1);
                q[i] = [(1i64 << 51), -(1 << 51), i64::MAX / 4, i64::MIN / 4][g.usize_in(0, 3)];
            }
            let two_eps = g.f64_in(1e-9, 10.0);
            let mut want = vec![0.0f32; n - off];
            dequantize_into_with(SimdLevel::Scalar, &q[off..], two_eps, &mut want);
            for lvl in levels() {
                let mut got = vec![0.0f32; n - off];
                dequantize_into_with(lvl, &q[off..], two_eps, &mut got);
                let wb: Vec<u32> = want.iter().map(|v| v.to_bits()).collect();
                let gb: Vec<u32> = got.iter().map(|v| v.to_bits()).collect();
                assert_eq!(gb, wb, "level={lvl:?} off={off}");
            }
        });
    }

    #[test]
    fn quantize_bit_identical_including_ties() {
        prop_check("simd quantize", 60, |g| {
            let off = g.usize_in(0, LANES);
            let n = off + g.usize_in(1, 2 * LANES + 9);
            let mut data: Vec<f32> = g.smooth_field(n, 0.4);
            // Exact ties and near-ties: with inv = 1.0 these exercise
            // the round-half-away edge directly.
            if n - off > 3 {
                data[off] = 0.5;
                data[off + 1] = -2.5;
                data[off + 2] = 0.499_999_97;
            }
            let inv = if g.bool_with(0.5) { 1.0 } else { 1.0 / (2.0 * g.f64_in(1e-4, 0.5)) };
            let mut want = vec![0i64; n - off];
            quantize_with(SimdLevel::Scalar, &data[off..], inv, &mut want);
            for lvl in levels() {
                let mut got = vec![0i64; n - off];
                quantize_with(lvl, &data[off..], inv, &mut got);
                assert_eq!(got, want, "level={lvl:?} off={off}");
            }
        });
    }

    #[test]
    fn quantize_dequantize_roundtrip_within_bound_under_simd() {
        prop_check("simd quant roundtrip", 60, |g| {
            let n = g.usize_in(1, 3 * LANES);
            let data = g.smooth_field(n, 0.3);
            let eps = g.f64_in(1e-4, 0.5);
            let inv = 1.0 / (2.0 * eps);
            for lvl in levels() {
                let mut q = vec![0i64; n];
                quantize_with(lvl, &data, inv, &mut q);
                let mut dq = vec![0.0f32; n];
                dequantize_into_with(lvl, &q, 2.0 * eps, &mut dq);
                for (d, r) in data.iter().zip(&dq) {
                    let err = (*d as f64 - *r as f64).abs();
                    assert!(err <= eps * (1.0 + 1e-9), "level={lvl:?} err={err} eps={eps}");
                }
            }
        });
    }

    #[test]
    fn compensate_bit_identical_with_sentinels() {
        const INF: i64 = i64::MAX / 4;
        prop_check("simd compensate", 60, |g| {
            let off = g.usize_in(0, LANES);
            let n = off + g.usize_in(1, 2 * LANES + 9);
            let d1: Vec<i64> = (0..n)
                .map(|_| match g.usize_in(0, 9) {
                    0 => 0,
                    1 => INF,
                    2 => INF + 7,
                    3 => (1 << 51) + 1, // gap value → scalar fallback group
                    _ => g.usize_in(1, 4000) as i64,
                })
                .collect();
            let d2: Vec<i64> = (0..n)
                .map(|_| match g.usize_in(0, 9) {
                    0 => 0,
                    1 => INF,
                    _ => g.usize_in(1, 4000) as i64,
                })
                .collect();
            let sign: Vec<i8> = (0..n).map(|_| [-1i8, 0, 1][g.usize_in(0, 2)]).collect();
            let base: Vec<f32> = g.smooth_field(n, 0.5);
            let eta_eps = g.f64_in(1e-6, 0.5);
            let mut want = base[off..].to_vec();
            compensate_with(
                SimdLevel::Scalar,
                &mut want,
                &d1[off..],
                &d2[off..],
                &sign[off..],
                eta_eps,
                INF,
            );
            for lvl in levels() {
                let mut got = base[off..].to_vec();
                compensate_with(lvl, &mut got, &d1[off..], &d2[off..], &sign[off..], eta_eps, INF);
                let wb: Vec<u32> = want.iter().map(|v| v.to_bits()).collect();
                let gb: Vec<u32> = got.iter().map(|v| v.to_bits()).collect();
                assert_eq!(gb, wb, "level={lvl:?} off={off}");
            }
        });
    }

    #[test]
    fn lorenzo_rows_bit_identical_and_invertible() {
        prop_check("simd lorenzo rows", 60, |g| {
            let n = g.usize_in(1, 3 * LANES + 1);
            let c: Vec<i64> = (0..n).map(|_| g.usize_in(0, 2000) as i64 - 1000).collect();
            let a: Vec<i64> = (0..n).map(|_| g.usize_in(0, 2000) as i64 - 1000).collect();
            let b: Vec<i64> = (0..n).map(|_| g.usize_in(0, 2000) as i64 - 1000).collect();
            let ab: Vec<i64> = (0..n).map(|_| g.usize_in(0, 2000) as i64 - 1000).collect();
            let mut want1 = vec![0i64; n];
            let mut want2 = vec![0i64; n];
            let mut want3 = vec![0i64; n];
            delta_row_with(SimdLevel::Scalar, &mut want1, &c);
            lorenzo_row2_with(SimdLevel::Scalar, &mut want2, &c, &a);
            lorenzo_row3_with(SimdLevel::Scalar, &mut want3, &c, &a, &b, &ab);
            for lvl in levels() {
                let mut got = vec![0i64; n];
                delta_row_with(lvl, &mut got, &c);
                assert_eq!(got, want1, "delta level={lvl:?}");
                lorenzo_row2_with(lvl, &mut got, &c, &a);
                assert_eq!(got, want2, "row2 level={lvl:?}");
                lorenzo_row3_with(lvl, &mut got, &c, &a, &b, &ab);
                assert_eq!(got, want3, "row3 level={lvl:?}");
                // delta_row then prefix_sum is the identity.
                let mut rt = vec![0i64; n];
                delta_row_with(lvl, &mut rt, &c);
                prefix_sum_i64(&mut rt);
                assert_eq!(rt, c, "delta/prefix inverse level={lvl:?}");
                // add_assign agrees with scalar addition.
                let mut sum = want1.clone();
                add_assign_i64_with(lvl, &mut sum, &c);
                let want: Vec<i64> = want1.iter().zip(&c).map(|(x, y)| x + y).collect();
                assert_eq!(sum, want, "add_assign level={lvl:?}");
            }
        });
    }

    #[test]
    fn convolve_valid_bit_identical() {
        prop_check("simd convolve", 60, |g| {
            let klen = [1usize, 3, 5, 7][g.usize_in(0, 3)];
            let out_len = g.usize_in(1, 3 * LANES);
            let line: Vec<f64> =
                (0..out_len + klen - 1).map(|_| g.f64_in(-2.0, 2.0)).collect();
            let kernel: Vec<f64> = (0..klen).map(|_| g.f64_in(-1.0, 1.0)).collect();
            let mut want = vec![0.0f64; out_len];
            convolve_valid_with(SimdLevel::Scalar, &mut want, &line, &kernel);
            for lvl in levels() {
                let mut got = vec![0.0f64; out_len];
                convolve_valid_with(lvl, &mut got, &line, &kernel);
                let wb: Vec<u64> = want.iter().map(|v| v.to_bits()).collect();
                let gb: Vec<u64> = got.iter().map(|v| v.to_bits()).collect();
                assert_eq!(gb, wb, "level={lvl:?}");
            }
        });
    }

    #[test]
    fn ssim_moments_bit_identical() {
        prop_check("simd ssim moments", 60, |g| {
            let n = g.usize_in(1, 3 * LANES);
            let xs = g.smooth_field(n, 0.3);
            let ys = g.smooth_field(n, 0.3);
            let lof = g.f64_in(-1.0, 1.0);
            let inv = g.f64_in(0.1, 10.0);
            let mut want = vec![vec![0.0f64; n]; 5];
            {
                let [sx, sy, sxx, syy, sxy] = &mut want[..] else { unreachable!() };
                ssim_moments_with(SimdLevel::Scalar, &xs, &ys, lof, inv, sx, sy, sxx, syy, sxy);
            }
            for lvl in levels() {
                let mut got = vec![vec![0.0f64; n]; 5];
                {
                    let [sx, sy, sxx, syy, sxy] = &mut got[..] else { unreachable!() };
                    ssim_moments_with(lvl, &xs, &ys, lof, inv, sx, sy, sxx, syy, sxy);
                }
                for (m, (w, o)) in want.iter().zip(&got).enumerate() {
                    let wb: Vec<u64> = w.iter().map(|v| v.to_bits()).collect();
                    let gb: Vec<u64> = o.iter().map(|v| v.to_bits()).collect();
                    assert_eq!(gb, wb, "moment {m} level={lvl:?}");
                }
            }
        });
    }
}
