//! Small infrastructure substrates that would normally come from crates
//! (`rand`, `rayon`, `proptest`) but are implemented in-repo because the
//! build environment is offline (DESIGN.md §10).
//!
//! Parallelism lives in two modules: [`pool`] is the persistent
//! thread-pool runtime every hot path uses; [`par`] is the original
//! fork-join implementation, kept as the overhead baseline for
//! `hotpath_microbench` and as the provider of [`par::UnsafeSlice`].

pub mod par;
pub mod pool;
pub mod prop;
pub mod rng;
pub mod timer;
