//! Small infrastructure substrates that would normally come from crates
//! (`rand`, `rayon`, `proptest`) but are implemented in-repo because the
//! build environment is offline (DESIGN.md §10).
//!
//! Shared-memory parallelism runs on [`pool`], the persistent
//! thread-pool runtime (which also hosts [`pool::UnsafeSlice`], the
//! disjoint-writes cell of every parallel kernel); full-grid scratch
//! buffers are recycled through [`arena`]. The original fork-join
//! substrate (`util::par`) is retired — a minimal copy survives only
//! inside `hotpath_microbench` as the dispatch-overhead baseline.

//! Numeric hot loops dispatch through [`simd`], the runtime-selected
//! vector substrate (AVX2/SSE2 with an always-compiled scalar twin).

pub mod arena;
pub mod hist;
pub mod pool;
pub mod prop;
pub mod rng;
pub mod simd;
pub mod timer;
