//! Small infrastructure substrates that would normally come from crates
//! (`rand`, `rayon`, `proptest`) but are implemented in-repo because the
//! build environment is offline (DESIGN.md §10).

pub mod par;
pub mod prop;
pub mod rng;
pub mod timer;
