//! Shared-memory fork-join parallelism (the paper's OpenMP analog).
//!
//! The build environment has no `rayon`, so this module provides the two
//! primitives the pipeline needs, built on `std::thread::scope`:
//!
//! * [`parallel_chunks_mut`] — split a mutable slice into contiguous
//!   chunks and process them on worker threads ("collapsed loop" of
//!   §VII-A, steps A/C/E);
//! * [`parallel_for_range`] — index-space fork-join over disjoint work
//!   (EDT line batches of steps B/D, block decompression in SZp/SZ3).
//!
//! Both take an explicit thread count so the Fig. 8 efficiency bench can
//! sweep it like the paper sweeps OMP_NUM_THREADS. `threads == 1` runs
//! inline with zero overhead, which is also the profiling baseline.

/// Process `data` in `threads` contiguous chunks, calling
/// `f(chunk_start_index, chunk)` on each. Chunks are balanced to within
/// one element.
pub fn parallel_chunks_mut<T: Send, F>(data: &mut [T], threads: usize, f: F)
where
    F: Fn(usize, &mut [T]) + Sync,
{
    let n = data.len();
    if threads <= 1 || n < 2 {
        f(0, data);
        return;
    }
    let threads = threads.min(n);
    let base = n / threads;
    let extra = n % threads;
    std::thread::scope(|s| {
        let mut rest = data;
        let mut start = 0usize;
        for t in 0..threads {
            let len = base + usize::from(t < extra);
            let (chunk, tail) = rest.split_at_mut(len);
            rest = tail;
            let fr = &f;
            s.spawn(move || fr(start, chunk));
            start += len;
        }
    });
}

/// Fork-join over the index range `0..n`: each worker thread repeatedly
/// claims a batch of `grain` indices and calls `f(i)` for each. Use when
/// iterations write to disjoint data through raw pointers or interior
/// mutability (callers guarantee disjointness).
pub fn parallel_for_range<F>(n: usize, threads: usize, grain: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    if threads <= 1 || n <= grain {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let next = std::sync::atomic::AtomicUsize::new(0);
    let grain = grain.max(1);
    std::thread::scope(|s| {
        for _ in 0..threads.min(n) {
            let next = &next;
            let fr = &f;
            s.spawn(move || loop {
                let start = next.fetch_add(grain, std::sync::atomic::Ordering::Relaxed);
                if start >= n {
                    break;
                }
                let end = (start + grain).min(n);
                for i in start..end {
                    fr(i);
                }
            });
        }
    });
}

/// Like [`parallel_for_range`] but hands each worker a whole contiguous
/// batch `start..end` at a time, so per-batch scratch state (e.g. the
/// EDT's Voronoi stacks) is allocated once per batch instead of once per
/// index — the §Perf iteration 2 of EXPERIMENTS.md.
pub fn parallel_for_batches<F>(n: usize, threads: usize, grain: usize, f: F)
where
    F: Fn(std::ops::Range<usize>) + Sync,
{
    let grain = grain.max(1);
    if threads <= 1 || n <= grain {
        if n > 0 {
            f(0..n);
        }
        return;
    }
    let next = std::sync::atomic::AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..threads.min(n.div_ceil(grain)) {
            let next = &next;
            let fr = &f;
            s.spawn(move || loop {
                let start = next.fetch_add(grain, std::sync::atomic::Ordering::Relaxed);
                if start >= n {
                    break;
                }
                fr(start..(start + grain).min(n));
            });
        }
    });
}

/// A slice wrapper that asserts disjoint-index writes at the type level's
/// edge: workers write through raw pointers. The caller must guarantee
/// that no index is written by two workers (all users in this crate index
/// by disjoint line/block decompositions).
pub struct UnsafeSlice<'a, T> {
    ptr: *mut T,
    len: usize,
    _marker: std::marker::PhantomData<&'a mut [T]>,
}

unsafe impl<T: Send> Send for UnsafeSlice<'_, T> {}
unsafe impl<T: Send> Sync for UnsafeSlice<'_, T> {}

impl<'a, T> UnsafeSlice<'a, T> {
    /// Wrap a mutable slice for disjoint parallel writes.
    pub fn new(slice: &'a mut [T]) -> Self {
        UnsafeSlice { ptr: slice.as_mut_ptr(), len: slice.len(), _marker: std::marker::PhantomData }
    }

    /// Length of the underlying slice.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True if empty.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Write `value` at `i`.
    ///
    /// # Safety
    /// `i < len` and no other thread concurrently reads or writes `i`.
    #[inline]
    pub unsafe fn write(&self, i: usize, value: T) {
        debug_assert!(i < self.len);
        *self.ptr.add(i) = value;
    }

    /// Read the value at `i` (T: Copy).
    ///
    /// # Safety
    /// `i < len` and no other thread concurrently writes `i`.
    #[inline]
    pub unsafe fn read(&self, i: usize) -> T
    where
        T: Copy,
    {
        debug_assert!(i < self.len);
        *self.ptr.add(i)
    }

    /// Get a mutable sub-slice `[start, start+len)`.
    ///
    /// # Safety
    /// The range is in bounds and not aliased by any concurrent access.
    #[inline]
    pub unsafe fn slice_mut(&self, start: usize, len: usize) -> &'a mut [T] {
        debug_assert!(start + len <= self.len);
        std::slice::from_raw_parts_mut(self.ptr.add(start), len)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunks_cover_everything_once() {
        for threads in [1, 2, 3, 7, 16] {
            let mut v = vec![0u32; 1000];
            parallel_chunks_mut(&mut v, threads, |start, chunk| {
                for (k, x) in chunk.iter_mut().enumerate() {
                    *x = (start + k) as u32 + 1;
                }
            });
            for (i, x) in v.iter().enumerate() {
                assert_eq!(*x, i as u32 + 1, "threads={threads} i={i}");
            }
        }
    }

    #[test]
    fn chunks_handles_tiny_inputs() {
        let mut v = vec![0u8; 1];
        parallel_chunks_mut(&mut v, 8, |_, c| c[0] = 9);
        assert_eq!(v[0], 9);
        let mut empty: Vec<u8> = vec![];
        parallel_chunks_mut(&mut empty, 8, |_, _| {});
    }

    #[test]
    fn for_range_visits_each_index_once() {
        for threads in [1, 2, 4, 8] {
            let n = 5000;
            let mut out = vec![0u32; n];
            let s = UnsafeSlice::new(&mut out);
            parallel_for_range(n, threads, 64, |i| unsafe { s.write(i, i as u32 * 3) });
            for (i, x) in out.iter().enumerate() {
                assert_eq!(*x, i as u32 * 3);
            }
        }
    }

    #[test]
    fn for_range_empty_and_small() {
        parallel_for_range(0, 4, 8, |_| panic!("no work"));
        let count = std::sync::atomic::AtomicUsize::new(0);
        parallel_for_range(3, 4, 8, |_| {
            count.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        });
        assert_eq!(count.load(std::sync::atomic::Ordering::Relaxed), 3);
    }
}
