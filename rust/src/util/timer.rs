//! Lightweight wall-clock timing used by the pipeline instrumentation,
//! the coordinator's compute/communication breakdown (Fig. 11), and the
//! bench harness.

use std::time::{Duration, Instant};

/// Accumulating stopwatch: start/stop many times, read the total.
#[derive(Debug, Default, Clone)]
pub struct Stopwatch {
    total: Duration,
    started: Option<Instant>,
}

impl Stopwatch {
    /// New, stopped, zero-total stopwatch.
    pub fn new() -> Self {
        Self::default()
    }

    /// Begin (or re-begin) timing. Starting twice is a no-op.
    pub fn start(&mut self) {
        if self.started.is_none() {
            self.started = Some(Instant::now());
        }
    }

    /// Stop timing and fold the elapsed interval into the total.
    pub fn stop(&mut self) {
        if let Some(t0) = self.started.take() {
            self.total += t0.elapsed();
        }
    }

    /// Total accumulated time.
    pub fn total(&self) -> Duration {
        match self.started {
            Some(t0) => self.total + t0.elapsed(),
            None => self.total,
        }
    }

    /// Total in seconds.
    pub fn secs(&self) -> f64 {
        self.total().as_secs_f64()
    }

    /// Time `f`, accumulating its wall-clock into this stopwatch.
    pub fn time<T>(&mut self, f: impl FnOnce() -> T) -> T {
        self.start();
        let out = f();
        self.stop();
        out
    }
}

/// Time a closure once, returning (result, seconds).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// CPU time consumed by the *calling thread* (seconds). Unlike wall
/// clock, this excludes time the thread spent descheduled or blocked —
/// which is how the coordinator attributes per-rank compute cost fairly
/// while many rank threads share the host's cores (DESIGN.md §5).
pub fn thread_cpu_time() -> f64 {
    let mut ts = libc::timespec { tv_sec: 0, tv_nsec: 0 };
    let rc = unsafe { libc::clock_gettime(libc::CLOCK_THREAD_CPUTIME_ID, &mut ts) };
    assert_eq!(rc, 0, "clock_gettime failed");
    ts.tv_sec as f64 + ts.tv_nsec as f64 * 1e-9
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_across_intervals() {
        let mut sw = Stopwatch::new();
        sw.time(|| std::thread::sleep(Duration::from_millis(5)));
        sw.time(|| std::thread::sleep(Duration::from_millis(5)));
        assert!(sw.secs() >= 0.009, "secs={}", sw.secs());
    }

    #[test]
    fn double_start_is_noop() {
        let mut sw = Stopwatch::new();
        sw.start();
        sw.start();
        sw.stop();
        sw.stop();
        let t = sw.secs();
        assert!(t >= 0.0);
        // A second stop must not add time.
        assert_eq!(sw.secs(), t);
    }

    #[test]
    fn timed_returns_value() {
        let (v, secs) = timed(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(secs >= 0.0);
    }
}
