//! Persistent shared-memory thread-pool runtime.
//!
//! The original parallel substrate (the retired `util::par` fork-join
//! module) spawned fresh OS threads via `std::thread::scope` on *every*
//! call, so one `mitigate()` run paid fork-join startup five-plus times
//! (steps A–E) and each SZp/SZ3 block decompression paid it again. This
//! module replaces that with a **persistent pool**: workers are spawned once
//! (lazily, for the [`global`] pool) and then parked on a condition
//! variable; each parallel region is published as a heap-allocated
//! ticket that woken workers *and the calling thread* drain
//! cooperatively through an atomic work cursor (self-scheduling — the
//! pool-level analog of OpenMP `schedule(dynamic)` work stealing).
//!
//! Guarantees relied on throughout the crate:
//!
//! * **Drop-in semantics** — [`chunks_mut`] / [`for_range`] /
//!   [`for_batches`] take the same `(…, threads, …)` arguments and use
//!   the same work decomposition as the old fork-join free functions, so
//!   outputs are bit-identical to both the fork-join implementation and
//!   the sequential path (every call site writes disjoint data, making
//!   results schedule-independent). One deliberate divergence: actual
//!   concurrency is capped at the pool's lane count — `threads` beyond
//!   that changes only the work decomposition, not the OS-thread count
//!   (the fork-join code really spawned `threads` threads). Outputs are
//!   unaffected; for true oversubscription experiments size an explicit
//!   [`ThreadPool::new`] or set `QAI_POOL_THREADS`.
//! * **`threads == 1` is free** — the sequential path runs inline with
//!   zero synchronization, preserving profiling baselines and the
//!   default `MitigationConfig` behavior exactly.
//! * **Zero steady-state spawns** — after the pool is warm, parallel
//!   regions spawn no OS threads ([`os_thread_spawns`] exposes the
//!   counter so tests can assert this).
//! * **Nesting is safe** — a worker executing a task may itself open a
//!   parallel region (the batched [`crate::mitigation::service`] does
//!   exactly this). The opener always participates in its own region,
//!   so progress never depends on other workers being free.
//!
//! Mutually-blocking task sets (the coordinator's simulated-MPI ranks,
//! which block in `recv` on each other) must *not* share pool lanes —
//! that can deadlock when tasks outnumber workers. [`scope_blocking`]
//! is the explicit escape hatch: dedicated scoped threads, counted by
//! the same spawn counter.
//!
//! # Choosing a pool: [`PoolHandle`]
//!
//! Most code does not care which pool it runs on and uses the free
//! functions ([`chunks_mut`] / [`for_range`] / [`for_batches`]), which
//! target the lazily-created [`global`] pool. Code that must **confine**
//! its parallelism — e.g. a [`crate::mitigation::service`] job whose
//! service was built with an explicit pool — threads a [`PoolHandle`]
//! down instead. `PoolHandle::Global` behaves exactly like the free
//! functions (including never forcing global-pool creation on the
//! `threads == 1` fast path); `PoolHandle::Explicit` opens every region
//! on the given pool and nowhere else. [`ThreadPool::regions_opened`]
//! and [`global_is_initialized`] make confinement observable in tests.
//!
//! # Examples
//!
//! ```
//! use qai::util::pool::ThreadPool;
//!
//! let pool = ThreadPool::new(2);
//! let mut data = vec![0u32; 64];
//! pool.chunks_mut(&mut data, 2, |start, chunk| {
//!     for (k, v) in chunk.iter_mut().enumerate() {
//!         *v = (start + k) as u32;
//!     }
//! });
//! assert_eq!(data[10], 10);
//! assert!(pool.regions_opened() >= 1);
//! ```

#![deny(missing_docs)]

use std::collections::VecDeque;
use std::ops::Range;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

/// Global count of OS threads ever spawned by this module and the
/// serving layer built on it (pool workers, [`scope_blocking`] threads,
/// and the admission scheduler of [`crate::mitigation::service`]).
/// Tests use it to assert that warm parallel regions spawn nothing.
static OS_THREAD_SPAWNS: AtomicUsize = AtomicUsize::new(0);

/// Record one OS-thread spawn in [`os_thread_spawns`]. For runtime
/// threads spawned outside this module (the admission scheduler), so
/// the "zero steady-state spawns" accounting stays complete.
pub(crate) fn note_os_thread_spawn() {
    OS_THREAD_SPAWNS.fetch_add(1, Ordering::SeqCst);
}

/// Total OS threads spawned through the pool runtime so far.
pub fn os_thread_spawns() -> usize {
    OS_THREAD_SPAWNS.load(Ordering::SeqCst)
}

/// One published parallel region. Workers and the caller claim batches
/// of the index space `0..n` through `cursor`; the caller blocks until
/// every participant has left the body, then closes the region so late
/// tickets (still queued behind other regions) become no-ops without
/// ever touching the by-then-dead closure pointer.
struct Region {
    /// Type-erased `&F` living on the caller's stack; valid until the
    /// region is closed.
    ctx: *const (),
    /// Monomorphized trampoline: `call(ctx, start, end)` runs the body
    /// over one claimed batch.
    call: unsafe fn(*const (), usize, usize),
    /// Index-space extent.
    n: usize,
    /// Batch size per claim.
    grain: usize,
    /// Next unclaimed index.
    cursor: AtomicUsize,
    /// Participation bookkeeping (see `run_ticket` / `dispatch`).
    state: Mutex<RegionState>,
    /// Signaled when the last participant leaves the body.
    done: Condvar,
    /// First panic payload raised inside the body, re-raised by the
    /// caller after the region quiesces (fork-join parity).
    panic_payload: Mutex<Option<Box<dyn std::any::Any + Send>>>,
}

struct RegionState {
    /// Participants currently inside the body.
    in_flight: usize,
    /// Set by the caller once the region is complete; late tickets must
    /// not enter.
    closed: bool,
}

// SAFETY: `ctx` is only dereferenced between a successful `try_enter`
// and the matching exit, and the caller keeps the referent alive until
// the region is closed with no participant in flight.
unsafe impl Send for Region {}
unsafe impl Sync for Region {}

/// Sentinel the cursor jumps to when a participant panics, so remaining
/// batches are abandoned. Far above any real `n`, with headroom so
/// racing `fetch_add(grain)` increments cannot wrap.
const CANCELLED: usize = usize::MAX / 2;

impl Region {
    /// Drain batches until the index space is exhausted. Panics inside
    /// the body are captured into `panic_payload` (first wins) and
    /// cancel the remaining work.
    fn drain(&self) {
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| loop {
            let start = self.cursor.fetch_add(self.grain, Ordering::Relaxed);
            if start >= self.n {
                break;
            }
            let end = (start + self.grain).min(self.n);
            // SAFETY: the caller guarantees `ctx` outlives the open
            // region, and `call` is the trampoline monomorphized for
            // the same closure type.
            unsafe { (self.call)(self.ctx, start, end) };
        }));
        if let Err(payload) = result {
            self.cursor.store(CANCELLED, Ordering::Relaxed);
            let mut slot = self.panic_payload.lock().unwrap();
            if slot.is_none() {
                *slot = Some(payload);
            }
        }
    }

    /// Worker-side entry: enter (unless closed), drain, exit, and wake
    /// the caller when this was the last participant.
    fn run_ticket(&self) {
        {
            let mut st = self.state.lock().unwrap();
            if st.closed {
                return;
            }
            st.in_flight += 1;
        }
        self.drain();
        let mut st = self.state.lock().unwrap();
        st.in_flight -= 1;
        if st.in_flight == 0 {
            self.done.notify_all();
        }
    }
}

/// One queued unit of pool work: a ticket of a parallel region, or a
/// detached one-shot task (the admission scheduler's job bodies). Tasks
/// are fire-and-forget: they run exactly once on some worker, so they
/// are only correct on pools that *have* workers — callers must fall
/// back to inline execution on a single-lane pool (see
/// [`ThreadPool::submit_task`]).
enum Ticket {
    Region(Arc<Region>),
    Task(Box<dyn FnOnce() + Send>),
}

/// Shared worker state: a FIFO of tickets plus shutdown flag.
struct Injector {
    queue: Mutex<VecDeque<Ticket>>,
    ready: Condvar,
    shutdown: AtomicBool,
}

fn worker_loop(injector: Arc<Injector>) {
    loop {
        let ticket = {
            let mut q = injector.queue.lock().unwrap();
            loop {
                if injector.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                if let Some(t) = q.pop_front() {
                    break t;
                }
                q = injector.ready.wait(q).unwrap();
            }
        };
        match ticket {
            Ticket::Region(region) => region.run_ticket(),
            Ticket::Task(task) => task(),
        }
    }
}

/// A persistent worker pool sized for `lanes`-way parallelism (the
/// caller of a parallel region is always one lane, so `lanes - 1`
/// workers are spawned). [`ThreadPool::new`] exists for explicit sizing
/// — e.g. the Fig. 8 thread sweep — while most code uses [`global`].
pub struct ThreadPool {
    injector: Arc<Injector>,
    lanes: usize,
    handles: Vec<std::thread::JoinHandle<()>>,
    /// Parallel regions ever opened on this pool (see
    /// [`ThreadPool::regions_opened`]).
    regions: AtomicUsize,
}

impl ThreadPool {
    /// Build a pool for `lanes`-way parallelism (`lanes >= 1`); spawns
    /// `lanes - 1` persistent workers immediately.
    pub fn new(lanes: usize) -> Self {
        let lanes = lanes.max(1);
        let injector = Arc::new(Injector {
            queue: Mutex::new(VecDeque::new()),
            ready: Condvar::new(),
            shutdown: AtomicBool::new(false),
        });
        let handles = (0..lanes - 1)
            .map(|w| {
                OS_THREAD_SPAWNS.fetch_add(1, Ordering::SeqCst);
                let inj = injector.clone();
                std::thread::Builder::new()
                    .name(format!("qai-pool-{w}"))
                    .spawn(move || worker_loop(inj))
                    .expect("spawn pool worker")
            })
            .collect();
        ThreadPool { injector, lanes, handles, regions: AtomicUsize::new(0) }
    }

    /// Maximum useful parallelism of this pool (workers + caller).
    pub fn lanes(&self) -> usize {
        self.lanes
    }

    /// Number of persistent worker threads (`lanes - 1`).
    pub fn workers(&self) -> usize {
        self.handles.len()
    }

    /// How many parallel regions have been opened on this pool so far.
    /// Sequential fast paths (`threads == 1`, or work too small to
    /// split) do not open a region. Confinement tests use this to prove
    /// that a job's internal steps really ran on a specific pool.
    pub fn regions_opened(&self) -> usize {
        self.regions.load(Ordering::SeqCst)
    }

    /// Enqueue a detached one-shot task for some worker to run.
    ///
    /// Unlike regions, nobody participates on the caller's thread and
    /// nobody waits: on a pool with zero workers the task would never
    /// run, so callers (the admission scheduler) must check
    /// [`ThreadPool::workers`] and execute inline when it is zero.
    pub(crate) fn submit_task(&self, task: Box<dyn FnOnce() + Send>) {
        debug_assert!(self.workers() > 0, "detached task on a worker-less pool never runs");
        let mut q = self.injector.queue.lock().unwrap();
        q.push_back(Ticket::Task(task));
        drop(q);
        self.injector.ready.notify_one();
    }

    /// Publish a region over `0..n` with the given `grain`, offer up to
    /// `extra` tickets to the workers, participate, and block until the
    /// region quiesces. Re-raises the first panic from the body.
    fn dispatch<F>(&self, n: usize, grain: usize, extra: usize, body: &F)
    where
        F: Fn(usize, usize) + Sync,
    {
        unsafe fn trampoline<F: Fn(usize, usize)>(ctx: *const (), start: usize, end: usize) {
            (*(ctx as *const F))(start, end);
        }
        self.regions.fetch_add(1, Ordering::SeqCst);
        let region = Arc::new(Region {
            ctx: body as *const F as *const (),
            call: trampoline::<F>,
            n,
            grain: grain.max(1),
            cursor: AtomicUsize::new(0),
            state: Mutex::new(RegionState { in_flight: 0, closed: false }),
            done: Condvar::new(),
            panic_payload: Mutex::new(None),
        });
        let extra = extra.min(self.lanes.saturating_sub(1));
        if extra > 0 {
            let mut q = self.injector.queue.lock().unwrap();
            for _ in 0..extra {
                q.push_back(Ticket::Region(region.clone()));
            }
            drop(q);
            self.injector.ready.notify_all();
        }

        // The caller is always a participant: even with every worker
        // busy (or zero workers), the region completes.
        region.drain();

        let mut st = region.state.lock().unwrap();
        while st.in_flight > 0 {
            st = region.done.wait(st).unwrap();
        }
        st.closed = true;
        drop(st);

        if let Some(payload) = region.panic_payload.lock().unwrap().take() {
            std::panic::resume_unwind(payload);
        }
    }

    /// Process `data` in `threads` contiguous chunks, calling
    /// `f(chunk_start_index, chunk)` on each — drop-in for the retired
    /// fork-join `parallel_chunks_mut` (identical chunk decomposition,
    /// balanced to within one element).
    pub fn chunks_mut<T: Send, F>(&self, data: &mut [T], threads: usize, f: F)
    where
        F: Fn(usize, &mut [T]) + Sync,
    {
        let n = data.len();
        if threads <= 1 || n < 2 {
            f(0, data);
            return;
        }
        let chunks = threads.min(n);
        let base = n / chunks;
        let extra = n % chunks;
        let slice = UnsafeSlice::new(data);
        let body = |lo: usize, hi: usize| {
            for c in lo..hi {
                let start = c * base + c.min(extra);
                let len = base + usize::from(c < extra);
                // SAFETY: chunk index ranges are disjoint by construction.
                let chunk = unsafe { slice.slice_mut(start, len) };
                f(start, chunk);
            }
        };
        self.dispatch(chunks, 1, chunks - 1, &body);
    }

    /// Self-scheduled loop over `0..n` claiming `grain` indices at a
    /// time — drop-in for the retired fork-join `parallel_for_range`.
    pub fn for_range<F>(&self, n: usize, threads: usize, grain: usize, f: F)
    where
        F: Fn(usize) + Sync,
    {
        if threads <= 1 || n <= grain {
            for i in 0..n {
                f(i);
            }
            return;
        }
        let body = |lo: usize, hi: usize| {
            for i in lo..hi {
                f(i);
            }
        };
        self.dispatch(n, grain.max(1), threads.min(n) - 1, &body);
    }

    /// Like [`ThreadPool::for_range`] but hands the body whole
    /// contiguous batches, so per-batch scratch (e.g. the EDT's Voronoi
    /// stacks) is allocated once per batch — drop-in for the retired
    /// fork-join `parallel_for_batches`.
    pub fn for_batches<F>(&self, n: usize, threads: usize, grain: usize, f: F)
    where
        F: Fn(Range<usize>) + Sync,
    {
        let grain = grain.max(1);
        if threads <= 1 || n <= grain {
            if n > 0 {
                f(0..n);
            }
            return;
        }
        let body = |lo: usize, hi: usize| f(lo..hi);
        self.dispatch(n, grain, threads.min(n.div_ceil(grain)) - 1, &body);
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.injector.shutdown.store(true, Ordering::SeqCst);
        // Take the queue lock so no worker is between the shutdown
        // check and the wait when we notify.
        drop(self.injector.queue.lock().unwrap());
        self.injector.ready.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Lane count for the global pool: `QAI_POOL_THREADS` if set, else the
/// host's available parallelism floored at 8, so the Fig. 8-style
/// thread sweeps (≤ 8) and the batched service get real concurrency
/// even on small CI hosts (parked workers are nearly free).
fn default_lanes() -> usize {
    if let Ok(v) = std::env::var("QAI_POOL_THREADS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).max(8)
}

static GLOBAL_POOL: OnceLock<ThreadPool> = OnceLock::new();

/// The process-wide pool, created on first use. Workers persist for the
/// life of the process (the pool is never dropped).
pub fn global() -> &'static ThreadPool {
    GLOBAL_POOL.get_or_init(|| ThreadPool::new(default_lanes()))
}

/// Whether the global pool has been created yet. Sequential fast paths
/// and pool-confined work never force its creation, which the
/// pool-confinement tests assert through this probe.
pub fn global_is_initialized() -> bool {
    GLOBAL_POOL.get().is_some()
}

/// Which pool a parallel region runs on.
///
/// `Global` matches the module's free functions exactly — including the
/// guarantee that `threads == 1` work runs inline without ever creating
/// the global pool. `Explicit` confines every region to the given pool:
/// nothing escapes to the global one, which is what lets
/// [`crate::mitigation::service::MitigationService::with_pool`] bound a
/// job's *internal* parallelism, not just the cross-job fan-out.
#[derive(Clone, Copy, Default)]
pub enum PoolHandle<'p> {
    /// The lazily-created process-wide pool ([`global`]).
    #[default]
    Global,
    /// An explicit pool; every region opens on it and nowhere else.
    Explicit(&'p ThreadPool),
}

impl<'p> PoolHandle<'p> {
    /// Resolve to a concrete pool, creating the global pool if this is
    /// `Global` and it does not exist yet.
    pub fn resolve(self) -> &'p ThreadPool {
        match self {
            PoolHandle::Global => global(),
            PoolHandle::Explicit(pool) => pool,
        }
    }

    /// [`ThreadPool::chunks_mut`] on the selected pool; `threads <= 1`
    /// (or trivially small data) runs inline without resolving it.
    pub fn chunks_mut<T: Send, F>(self, data: &mut [T], threads: usize, f: F)
    where
        F: Fn(usize, &mut [T]) + Sync,
    {
        if threads <= 1 || data.len() < 2 {
            f(0, data);
            return;
        }
        self.resolve().chunks_mut(data, threads, f)
    }

    /// [`ThreadPool::for_range`] on the selected pool; `threads <= 1`
    /// (or `n <= grain`) runs inline without resolving it.
    pub fn for_range<F>(self, n: usize, threads: usize, grain: usize, f: F)
    where
        F: Fn(usize) + Sync,
    {
        if threads <= 1 || n <= grain {
            for i in 0..n {
                f(i);
            }
            return;
        }
        self.resolve().for_range(n, threads, grain, f)
    }

    /// [`ThreadPool::for_batches`] on the selected pool; `threads <= 1`
    /// (or `n <= grain`) runs inline without resolving it.
    pub fn for_batches<F>(self, n: usize, threads: usize, grain: usize, f: F)
    where
        F: Fn(Range<usize>) + Sync,
    {
        let grain = grain.max(1);
        if threads <= 1 || n <= grain {
            if n > 0 {
                f(0..n);
            }
            return;
        }
        self.resolve().for_batches(n, threads, grain, f)
    }
}

/// Useful parallelism of the global pool.
pub fn parallelism() -> usize {
    global().lanes()
}

/// [`ThreadPool::chunks_mut`] on the global pool (`threads <= 1` never
/// touches or initializes it).
pub fn chunks_mut<T: Send, F>(data: &mut [T], threads: usize, f: F)
where
    F: Fn(usize, &mut [T]) + Sync,
{
    PoolHandle::Global.chunks_mut(data, threads, f)
}

/// [`ThreadPool::for_range`] on the global pool (`threads <= 1` never
/// touches or initializes it).
pub fn for_range<F>(n: usize, threads: usize, grain: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    PoolHandle::Global.for_range(n, threads, grain, f)
}

/// [`ThreadPool::for_batches`] on the global pool (`threads <= 1` never
/// touches or initializes it).
pub fn for_batches<F>(n: usize, threads: usize, grain: usize, f: F)
where
    F: Fn(Range<usize>) + Sync,
{
    PoolHandle::Global.for_batches(n, threads, grain, f)
}

/// A slice wrapper that asserts disjoint-index writes at the type
/// level's edge: workers write through raw pointers. The caller must
/// guarantee that no index is written by two workers (all users in this
/// crate index by disjoint line/block decompositions). This is the
/// disjoint-writes cell every parallel kernel in the crate builds on
/// (it moved here from the retired fork-join module, whose only
/// surviving piece it is).
pub struct UnsafeSlice<'a, T> {
    ptr: *mut T,
    len: usize,
    _marker: std::marker::PhantomData<&'a mut [T]>,
}

unsafe impl<T: Send> Send for UnsafeSlice<'_, T> {}
unsafe impl<T: Send> Sync for UnsafeSlice<'_, T> {}

impl<'a, T> UnsafeSlice<'a, T> {
    /// Wrap a mutable slice for disjoint parallel writes.
    pub fn new(slice: &'a mut [T]) -> Self {
        UnsafeSlice { ptr: slice.as_mut_ptr(), len: slice.len(), _marker: std::marker::PhantomData }
    }

    /// Length of the underlying slice.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True if empty.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Write `value` at `i`.
    ///
    /// # Safety
    /// `i < len` and no other thread concurrently reads or writes `i`.
    #[inline]
    pub unsafe fn write(&self, i: usize, value: T) {
        debug_assert!(i < self.len);
        *self.ptr.add(i) = value;
    }

    /// Read the value at `i` (T: Copy).
    ///
    /// # Safety
    /// `i < len` and no other thread concurrently writes `i`.
    #[inline]
    pub unsafe fn read(&self, i: usize) -> T
    where
        T: Copy,
    {
        debug_assert!(i < self.len);
        *self.ptr.add(i)
    }

    /// Get a mutable sub-slice `[start, start+len)`.
    ///
    /// # Safety
    /// The range is in bounds and not aliased by any concurrent access.
    #[inline]
    pub unsafe fn slice_mut(&self, start: usize, len: usize) -> &'a mut [T] {
        debug_assert!(start + len <= self.len);
        std::slice::from_raw_parts_mut(self.ptr.add(start), len)
    }
}

/// Run a set of **mutually-blocking** tasks to completion, one
/// dedicated scoped thread each (single tasks run inline). This exists
/// for the coordinator's simulated-MPI ranks, which block in `recv` on
/// one another: multiplexing such tasks onto a bounded worker set can
/// deadlock, so they must not share pool lanes. Spawns are counted by
/// [`os_thread_spawns`].
pub fn scope_blocking<'env, T, F>(tasks: Vec<F>) -> Vec<T>
where
    T: Send + 'env,
    F: FnOnce() -> T + Send + 'env,
{
    if tasks.len() <= 1 {
        return tasks.into_iter().map(|t| t()).collect();
    }
    std::thread::scope(|s| {
        let handles: Vec<_> = tasks
            .into_iter()
            .map(|t| {
                OS_THREAD_SPAWNS.fetch_add(1, Ordering::SeqCst);
                s.spawn(t)
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("blocking task panicked")).collect()
    })
}

/// The spawn counter is process-global, so unit tests anywhere in the
/// crate that spawn counted OS threads (explicit pools, services and
/// their admission schedulers, `scope_blocking`) or assert on the
/// counter must serialize on this guard to keep the counter assertions
/// race-free under the parallel test harness.
#[cfg(test)]
pub(crate) fn test_guard() -> std::sync::MutexGuard<'static, ()> {
    static GUARD: Mutex<()> = Mutex::new(());
    GUARD.lock().unwrap_or_else(|e| e.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunks_cover_everything_once() {
        let _g = test_guard();
        let pool = ThreadPool::new(4);
        for threads in [1, 2, 3, 7, 16] {
            let mut v = vec![0u32; 1000];
            pool.chunks_mut(&mut v, threads, |start, chunk| {
                for (k, x) in chunk.iter_mut().enumerate() {
                    *x = (start + k) as u32 + 1;
                }
            });
            for (i, x) in v.iter().enumerate() {
                assert_eq!(*x, i as u32 + 1, "threads={threads} i={i}");
            }
        }
    }

    #[test]
    fn chunk_decomposition_matches_forkjoin_exactly() {
        let _g = test_guard();
        // Same (start, len) pairs as util::par::parallel_chunks_mut.
        let pool = ThreadPool::new(3);
        for (n, threads) in [(10, 3), (1000, 7), (5, 8), (2, 2)] {
            let mut v = vec![0usize; n];
            let seen = Mutex::new(Vec::new());
            pool.chunks_mut(&mut v, threads, |start, chunk| {
                seen.lock().unwrap().push((start, chunk.len()));
            });
            let mut got = seen.into_inner().unwrap();
            got.sort_unstable();
            let mut want = Vec::new();
            let chunks = threads.min(n);
            let base = n / chunks;
            let extra = n % chunks;
            let mut start = 0;
            for c in 0..chunks {
                let len = base + usize::from(c < extra);
                want.push((start, len));
                start += len;
            }
            assert_eq!(got, want, "n={n} threads={threads}");
        }
    }

    #[test]
    fn for_range_visits_each_index_once() {
        let _g = test_guard();
        let pool = ThreadPool::new(4);
        for threads in [1, 2, 4, 8, 64] {
            let n = 5000;
            let mut out = vec![0u32; n];
            let s = UnsafeSlice::new(&mut out);
            pool.for_range(n, threads, 64, |i| unsafe { s.write(i, i as u32 * 3) });
            for (i, x) in out.iter().enumerate() {
                assert_eq!(*x, i as u32 * 3);
            }
        }
    }

    #[test]
    fn for_batches_covers_range_disjointly() {
        let _g = test_guard();
        let pool = ThreadPool::new(4);
        for (n, grain) in [(0usize, 4usize), (1, 4), (97, 4), (4096, 16), (10, 100)] {
            let counts: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
            pool.for_batches(n, 5, grain, |r| {
                for i in r {
                    counts[i].fetch_add(1, Ordering::Relaxed);
                }
            });
            for (i, c) in counts.iter().enumerate() {
                assert_eq!(c.load(Ordering::Relaxed), 1, "n={n} grain={grain} i={i}");
            }
        }
    }

    #[test]
    fn single_thread_requests_run_inline() {
        let _g = test_guard();
        // threads == 1 must work even on a zero-worker pool.
        let pool = ThreadPool::new(1);
        let mut v = vec![0u8; 16];
        pool.chunks_mut(&mut v, 1, |_, c| c.iter_mut().for_each(|x| *x = 1));
        assert!(v.iter().all(|&x| x == 1));
        pool.for_range(8, 1, 2, |_| {});
        pool.for_batches(8, 1, 2, |_| {});
    }

    #[test]
    fn zero_worker_pool_still_completes_parallel_requests() {
        let _g = test_guard();
        let pool = ThreadPool::new(1);
        let n = 257;
        let mut out = vec![0u32; n];
        let s = UnsafeSlice::new(&mut out);
        pool.for_range(n, 8, 4, |i| unsafe { s.write(i, 7) });
        assert!(out.iter().all(|&x| x == 7));
    }

    #[test]
    fn nested_regions_complete() {
        let _g = test_guard();
        // A region body that itself opens a region — the service's
        // batch-over-pipelines shape.
        let pool = ThreadPool::new(3);
        let outer = 6usize;
        let inner = 64usize;
        let hits = AtomicUsize::new(0);
        pool.for_range(outer, 3, 1, |_| {
            pool.for_range(inner, 3, 8, |_| {
                hits.fetch_add(1, Ordering::Relaxed);
            });
        });
        assert_eq!(hits.load(Ordering::Relaxed), outer * inner);
    }

    #[test]
    fn warm_pool_spawns_nothing_per_region() {
        let _g = test_guard();
        let pool = ThreadPool::new(4);
        pool.for_range(128, 4, 8, |_| {}); // warm-up
        let before = os_thread_spawns();
        for _ in 0..50 {
            pool.for_range(512, 4, 8, |i| {
                std::hint::black_box(i);
            });
            let mut v = vec![0u8; 256];
            pool.chunks_mut(&mut v, 4, |_, c| c.iter_mut().for_each(|x| *x = 1));
            pool.for_batches(256, 4, 16, |r| {
                std::hint::black_box(r.len());
            });
        }
        assert_eq!(os_thread_spawns(), before, "warm regions must not spawn OS threads");
    }

    #[test]
    fn body_panic_propagates_to_caller() {
        let _g = test_guard();
        let pool = ThreadPool::new(3);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.for_range(100, 3, 1, |i| {
                if i == 42 {
                    panic!("boom at {i}");
                }
            });
        }));
        let payload = result.expect_err("panic must propagate");
        let msg = payload.downcast_ref::<String>().cloned().unwrap_or_default();
        assert!(msg.contains("boom"), "msg={msg}");
        // The pool must stay usable after a panicked region.
        let count = AtomicUsize::new(0);
        pool.for_range(10, 3, 1, |_| {
            count.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(count.load(Ordering::Relaxed), 10);
    }

    #[test]
    fn scope_blocking_runs_mutually_dependent_tasks() {
        let _g = test_guard();
        use std::sync::mpsc::channel;
        let (tx_a, rx_a) = channel::<u32>();
        let (tx_b, rx_b) = channel::<u32>();
        let t1 = move || {
            tx_b.send(1).unwrap();
            rx_a.recv().unwrap()
        };
        let t2 = move || {
            tx_a.send(2).unwrap();
            rx_b.recv().unwrap()
        };
        let boxed: Vec<Box<dyn FnOnce() -> u32 + Send>> = vec![Box::new(t1), Box::new(t2)];
        let got = scope_blocking(boxed);
        assert_eq!(got, vec![2, 1]);
    }

    #[test]
    fn explicit_pool_drop_joins_workers() {
        let _g = test_guard();
        let before = os_thread_spawns();
        {
            let pool = ThreadPool::new(3);
            pool.for_range(64, 3, 4, |_| {});
        } // drop: workers must exit cleanly
        assert!(os_thread_spawns() >= before + 2);
    }

    #[test]
    fn scope_blocking_rank_set_larger_than_pool_size() {
        let _g = test_guard();
        // Regression (coordinator path): mutually-blocking rank sets
        // must get one dedicated thread each, never pool lanes — with
        // more ranks than any pool has lanes, multiplexing onto a
        // bounded worker set would deadlock. The barrier forces every
        // rank to be alive at the same instant, so this hangs (and the
        // harness times out) if ranks ever share threads.
        let pool = ThreadPool::new(2); // deliberately smaller than the rank set
        assert!(pool.lanes() < 12);
        let barrier = Arc::new(std::sync::Barrier::new(12));
        let tasks: Vec<_> = (0..12usize)
            .map(|rank| {
                let b = barrier.clone();
                move || {
                    b.wait();
                    rank
                }
            })
            .collect();
        let got = scope_blocking(tasks);
        assert_eq!(got, (0..12).collect::<Vec<_>>());
    }

    #[test]
    fn detached_tasks_run_on_workers() {
        let _g = test_guard();
        let pool = ThreadPool::new(2);
        let (tx, rx) = std::sync::mpsc::channel();
        pool.submit_task(Box::new(move || {
            tx.send(42u32).unwrap();
        }));
        let got = rx.recv_timeout(std::time::Duration::from_secs(30)).unwrap();
        assert_eq!(got, 42);
    }

    #[test]
    fn explicit_handle_opens_regions_only_on_its_pool() {
        let _g = test_guard();
        let pool = ThreadPool::new(3);
        let before = pool.regions_opened();
        let handle = PoolHandle::Explicit(&pool);
        let hits = AtomicUsize::new(0);
        handle.for_range(100, 3, 4, |_| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 100);
        assert_eq!(pool.regions_opened(), before + 1);
        // Sequential requests stay inline: no region opened anywhere.
        handle.for_range(100, 1, 4, |_| {});
        let mut v = vec![0u8; 8];
        handle.chunks_mut(&mut v, 1, |_, c| c.iter_mut().for_each(|x| *x = 1));
        handle.for_batches(8, 1, 2, |_| {});
        assert_eq!(pool.regions_opened(), before + 1);
    }

    #[test]
    fn global_pool_free_functions_work() {
        let _g = test_guard();
        let n = 1000;
        let mut out = vec![0u32; n];
        let s = UnsafeSlice::new(&mut out);
        for_range(n, 4, 32, |i| unsafe { s.write(i, i as u32) });
        for (i, x) in out.iter().enumerate() {
            assert_eq!(*x, i as u32);
        }
        assert!(parallelism() >= 1);
    }
}
