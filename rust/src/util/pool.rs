//! Persistent shared-memory thread-pool runtime with a **work-stealing
//! scheduler** and **cooperative blocking**.
//!
//! The original parallel substrate (the retired `util::par` fork-join
//! module) spawned fresh OS threads via `std::thread::scope` on *every*
//! call. Its first replacement was a persistent pool draining one
//! global `Mutex<VecDeque>` — which fixed the spawn cost but left every
//! sub-task of every parallel region contending on a single lock, and
//! left blocked threads (region openers waiting for stragglers,
//! `scope_blocking` callers, the admission scheduler) burning a thread
//! while contributing nothing. This module is the second generation:
//!
//! * **Per-worker deques** — each worker owns a private LIFO deque:
//!   it pushes and pops work at the *bottom* (newest first, cache-warm
//!   for deep nesting), while idle workers **steal** from the *top*
//!   (oldest first) in a randomized victim order seeded
//!   deterministically per worker. The global **injector** queue
//!   survives only as the FIFO entry point for external submissions
//!   (threads that are not workers of this pool).
//! * **Every blocked thread is a worker** — a region opener waiting for
//!   stragglers, a [`scope_blocking`] caller waiting for its rank set,
//!   the admission scheduler waiting for a free lane, and any thread
//!   parked in [`ThreadPool::help_until`] all run queued tickets while
//!   they wait. This removes the tasks-outnumber-workers deadlock class
//!   by construction: whoever is waiting for queued work can execute
//!   it.
//! * **Counters, not vibes** — [`ThreadPool::counters`] exposes
//!   `local_hits` / `injector_pops` / `steals` / `help_runs` so tests
//!   (and the microbench) can prove that stealing and helping actually
//!   happen, the same trick as [`os_thread_spawns`].
//!
//! Each parallel region is still published as a heap-allocated ticket
//! that participants drain cooperatively through an atomic work cursor
//! (self-scheduling — the pool-level analog of OpenMP
//! `schedule(dynamic)`).
//!
//! Guarantees relied on throughout the crate:
//!
//! * **Drop-in semantics** — [`chunks_mut`] / [`for_range`] /
//!   [`for_batches`] take the same `(…, threads, …)` arguments and use
//!   the same work decomposition as the old fork-join free functions
//!   (identical `(start, len)` chunks), so outputs are bit-identical to
//!   both the fork-join implementation and the sequential path whatever
//!   the execution order (every call site writes disjoint data, making
//!   results schedule-independent). One deliberate divergence: actual
//!   concurrency is capped at the pool's lane count — `threads` beyond
//!   that changes only the work decomposition, not the OS-thread count.
//! * **`threads == 1` is free** — the sequential path runs inline with
//!   zero synchronization, preserving profiling baselines and the
//!   default `MitigationConfig` behavior exactly.
//! * **Zero steady-state spawns** — after the pool is warm, parallel
//!   regions spawn no OS threads ([`os_thread_spawns`] exposes the
//!   counter so tests can assert this).
//! * **Nesting is safe** — a worker executing a task may itself open a
//!   parallel region. The opener always participates in its own region
//!   and *helps* with other queued work while waiting for stragglers,
//!   so progress never depends on other workers being free.
//!
//! Mutually-blocking task sets (the coordinator's simulated-MPI ranks,
//! which block in `recv` on each other) still must not *share* pool
//! lanes — but they may *reserve* them: [`scope_blocking`] pins each
//! task to a currently-parked global-pool worker when capacity
//! suffices, and spawns dedicated scoped threads only for the overflow,
//! while the calling thread runs one task itself and then helps the
//! pool.
//!
//! # Choosing a pool: [`PoolHandle`]
//!
//! Most code does not care which pool it runs on and uses the free
//! functions ([`chunks_mut`] / [`for_range`] / [`for_batches`]), which
//! target the lazily-created [`global`] pool. Code that must **confine**
//! its parallelism — e.g. a [`crate::mitigation::service`] job whose
//! service was built with an explicit pool — threads a [`PoolHandle`]
//! down instead. `PoolHandle::Global` behaves exactly like the free
//! functions (including never forcing global-pool creation on the
//! `threads == 1` fast path); `PoolHandle::Explicit` opens every region
//! on the given pool and nowhere else. [`ThreadPool::regions_opened`]
//! and [`global_is_initialized`] make confinement observable in tests.
//!
//! # Examples
//!
//! ```
//! use qai::util::pool::ThreadPool;
//!
//! let pool = ThreadPool::new(2);
//! let mut data = vec![0u32; 64];
//! pool.chunks_mut(&mut data, 2, |start, chunk| {
//!     for (k, v) in chunk.iter_mut().enumerate() {
//!         *v = (start + k) as u32;
//!     }
//! });
//! assert_eq!(data[10], 10);
//! assert!(pool.regions_opened() >= 1);
//! // Every executed ticket is counted once by claim source (help_runs
//! // is an overlapping attribution on top — see [`PoolCounters`]).
//! let c = pool.counters();
//! assert!(c.local_hits + c.injector_pops + c.steals <= 1);
//! ```

#![deny(missing_docs)]

use std::cell::Cell;
use std::collections::VecDeque;
use std::ops::Range;
use std::sync::atomic::{AtomicBool, AtomicI64, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::time::Duration;

/// Global count of OS threads ever spawned by this module and the
/// serving layer built on it (pool workers, [`scope_blocking`] overflow
/// threads, and the admission scheduler of
/// [`crate::mitigation::service`]). Tests use it to assert that warm
/// parallel regions spawn nothing.
static OS_THREAD_SPAWNS: AtomicUsize = AtomicUsize::new(0);

/// Record one OS-thread spawn in [`os_thread_spawns`]. For runtime
/// threads spawned outside this module (the admission scheduler), so
/// the "zero steady-state spawns" accounting stays complete.
pub(crate) fn note_os_thread_spawn() {
    OS_THREAD_SPAWNS.fetch_add(1, Ordering::SeqCst);
}

/// Total OS threads spawned through the pool runtime so far.
pub fn os_thread_spawns() -> usize {
    OS_THREAD_SPAWNS.load(Ordering::SeqCst)
}

/// How long a parked worker or helper sleeps before re-scanning for
/// work. Wakeups are notification-driven; the timeout is a safety net
/// that bounds the cost of any lost wakeup to one period.
const PARK_TIMEOUT: Duration = Duration::from_millis(20);

/// How long a region opener parks on the region's own condvar between
/// help attempts while stragglers are still inside the body.
const REGION_WAIT_TIMEOUT: Duration = Duration::from_millis(1);

thread_local! {
    /// `(pool id, worker index)` when the current thread is a pool
    /// worker — how region dispatch decides between the worker-local
    /// deque and the injector.
    static CURRENT_WORKER: Cell<Option<(u64, usize)>> = const { Cell::new(None) };
}

/// Unique ids so a worker of pool A is "external" to pool B.
static NEXT_POOL_ID: AtomicU64 = AtomicU64::new(1);

/// One published parallel region. Workers and the caller claim batches
/// of the index space `0..n` through `cursor`; the caller blocks until
/// every participant has left the body, then closes the region so late
/// tickets (still queued behind other work) become no-ops without ever
/// touching the by-then-dead closure pointer.
struct Region {
    /// Type-erased `&F` living on the caller's stack; valid until the
    /// region is closed.
    ctx: *const (),
    /// Monomorphized trampoline: `call(ctx, start, end)` runs the body
    /// over one claimed batch.
    call: unsafe fn(*const (), usize, usize),
    /// Index-space extent.
    n: usize,
    /// Batch size per claim.
    grain: usize,
    /// Next unclaimed index.
    cursor: AtomicUsize,
    /// Participation bookkeeping (see `run_ticket` / `dispatch`).
    state: Mutex<RegionState>,
    /// Signaled when the last participant leaves the body.
    done: Condvar,
    /// First panic payload raised inside the body, re-raised by the
    /// caller after the region quiesces (fork-join parity).
    panic_payload: Mutex<Option<Box<dyn std::any::Any + Send>>>,
}

struct RegionState {
    /// Participants currently inside the body.
    in_flight: usize,
    /// Set by the caller once the region is complete; late tickets must
    /// not enter.
    closed: bool,
}

// SAFETY: `ctx` is only dereferenced between a successful `try_enter`
// and the matching exit, and the caller keeps the referent alive until
// the region is closed with no participant in flight.
unsafe impl Send for Region {}
unsafe impl Sync for Region {}

/// Sentinel the cursor jumps to when a participant panics, so remaining
/// batches are abandoned. Far above any real `n`, with headroom so
/// racing `fetch_add(grain)` increments cannot wrap.
const CANCELLED: usize = usize::MAX / 2;

impl Region {
    /// Drain batches until the index space is exhausted. Panics inside
    /// the body are captured into `panic_payload` (first wins) and
    /// cancel the remaining work.
    fn drain(&self) {
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| loop {
            let start = self.cursor.fetch_add(self.grain, Ordering::Relaxed);
            if start >= self.n {
                break;
            }
            let end = (start + self.grain).min(self.n);
            // SAFETY: the caller guarantees `ctx` outlives the open
            // region, and `call` is the trampoline monomorphized for
            // the same closure type.
            unsafe { (self.call)(self.ctx, start, end) };
        }));
        if let Err(payload) = result {
            self.cursor.store(CANCELLED, Ordering::Relaxed);
            let mut slot = self.panic_payload.lock().unwrap();
            if slot.is_none() {
                *slot = Some(payload);
            }
        }
    }

    /// Worker-side entry: enter (unless closed), drain, exit, and wake
    /// the caller when this was the last participant.
    fn run_ticket(&self) {
        {
            let mut st = self.state.lock().unwrap();
            if st.closed {
                return;
            }
            st.in_flight += 1;
        }
        self.drain();
        let mut st = self.state.lock().unwrap();
        st.in_flight -= 1;
        if st.in_flight == 0 {
            self.done.notify_all();
        }
    }
}

/// One queued unit of pool work: a ticket of a parallel region, or a
/// detached one-shot task (the admission scheduler's job bodies,
/// [`ThreadPool::spawn`]). Tasks run exactly once, on a worker or on
/// any thread that helps while blocked; a panic inside a detached task
/// is swallowed (region-body panics, by contrast, propagate to the
/// region opener).
enum Ticket {
    Region(Arc<Region>),
    Task(Box<dyn FnOnce() + Send>),
}

/// What a claim is allowed to take (see [`PoolShared::next_ticket`]).
#[derive(Clone, Copy, PartialEq, Eq)]
enum Claim {
    /// Anything: regions and whole detached tasks. Workers and the
    /// explicit lending loop ([`ThreadPool::help_until`]) use this.
    Any,
    /// Region tickets only — bounded slices of already-running
    /// parallel work. Waits that must stay responsive to their own
    /// wake condition (the region-close wait, the admission
    /// scheduler's lane wait, [`scope_blocking`]'s completion wait)
    /// use this so they cannot vanish into a whole foreign job.
    RegionsOnly,
}

/// A blocking task pinned to one reserved worker by [`scope_blocking`]:
/// a type-erased pointer into the caller's stack plus its trampoline.
/// The caller blocks until every pinned task has signalled completion,
/// which keeps the referent alive for the worker.
struct PinnedTask {
    ctx: *mut (),
    run: unsafe fn(*mut ()),
}

// SAFETY: the referent outlives the task by the scope_blocking
// completion protocol (the caller waits on `remaining` before
// returning).
unsafe impl Send for PinnedTask {}

/// Per-worker shared state: the local LIFO deque (owner pushes/pops at
/// the back, thieves steal from the front) and the reservation slot for
/// one pinned blocking task.
struct WorkerShared {
    deque: Mutex<VecDeque<Ticket>>,
    pinned: Mutex<Option<PinnedTask>>,
    /// CPU the worker observed itself on after startup (and after
    /// affinity pinning, when enabled): `sched_getcpu` on Linux, −1
    /// elsewhere or before the worker reports in.
    cpu: AtomicI64,
}

/// The injector: FIFO entry queue for external submissions, plus the
/// park bookkeeping. `parked[i]` is set/cleared only while holding this
/// mutex, so anyone holding it observes exactly which workers are
/// quiescent inside the condvar wait — the property worker reservation
/// ([`scope_blocking`]) relies on.
struct InjectorInner {
    queue: VecDeque<Ticket>,
    parked: Vec<bool>,
}

/// State shared by the pool handle, its workers, and any
/// [`PoolHelper`]s (which may outlive the pool handle itself).
struct PoolShared {
    id: u64,
    injector: Mutex<InjectorInner>,
    /// Wakes parked workers and helpers: ticket pushed anywhere, pinned
    /// task placed, pinned task finished, shutdown.
    ready: Condvar,
    shutdown: AtomicBool,
    workers: Vec<WorkerShared>,
    /// Round-robin cursor for routing detached tasks onto worker
    /// deques.
    next_task_worker: AtomicUsize,
    /// Threads parked on `ready` that can only claim region tickets
    /// ([`scope_blocking`]'s completion wait). While any exist, a
    /// single-ticket task publication must wake the whole herd — a
    /// `notify_one` could land on a waiter that cannot run the task,
    /// delaying it by a full park timeout while workers sleep.
    regions_only_waiters: AtomicUsize,
    // Scheduler counters (see [`PoolCounters`]).
    local_hits: AtomicU64,
    injector_pops: AtomicU64,
    steals: AtomicU64,
    help_runs: AtomicU64,
}

/// Cheap xorshift64* step for randomized steal order. Deterministic per
/// seed; each worker derives its seed from its index.
fn xorshift(state: &mut u64) -> u64 {
    let mut x = *state;
    x ^= x << 13;
    x ^= x >> 7;
    x ^= x << 17;
    *state = x;
    x
}

fn steal_seed(tag: u64) -> u64 {
    // SplitMix-style spread; never zero (xorshift's fixed point).
    (tag.wrapping_add(1)).wrapping_mul(0x9E37_79B9_7F4A_7C15) | 1
}

impl PoolShared {
    /// The worker index of the current thread *on this pool*, or `None`
    /// for external threads (including workers of other pools).
    fn current_worker(&self) -> Option<usize> {
        CURRENT_WORKER.with(|c| match c.get() {
            Some((pool, w)) if pool == self.id => Some(w),
            _ => None,
        })
    }

    /// Synchronize with parked threads, then wake them all. Taking and
    /// releasing the injector mutex guarantees that any thread that
    /// checked for work before our publication is already inside the
    /// condvar wait (and so receives the notification) — the standard
    /// no-lost-wakeup handshake.
    fn notify_all_sync(&self) {
        drop(self.injector.lock().unwrap());
        self.ready.notify_all();
    }

    /// The single-ticket form of [`PoolShared::notify_all_sync`]: one
    /// woken thread suffices for one published ticket (any parked
    /// worker can reach it through the steal sweep), and waking the
    /// whole herd for every dispatched job is O(workers²) mutex churn.
    /// Two cases still need the herd: a registered regions-only waiter
    /// could swallow the single wakeup without being able to claim a
    /// task, and a woken helper whose done-flag just flipped exits
    /// without claiming — the first is detected and handled here, the
    /// second is rare and bounded by the park timeout.
    fn notify_one_sync(&self) {
        drop(self.injector.lock().unwrap());
        if self.regions_only_waiters.load(Ordering::SeqCst) > 0 {
            self.ready.notify_all();
        } else {
            self.ready.notify_one();
        }
    }

    /// Publish a ticket on the injector (external submissions).
    fn push_injector(&self, ticket: Ticket) {
        self.injector.lock().unwrap().queue.push_back(ticket);
        self.ready.notify_all();
    }

    /// Publish a detached task: round-robin onto a worker deque (the
    /// owner runs it LIFO, anyone else can steal it FIFO), or the
    /// injector on a worker-less pool (where only helpers can run it).
    fn push_task(&self, ticket: Ticket) {
        if self.workers.is_empty() {
            self.push_injector(ticket);
        } else {
            let w = self.next_task_worker.fetch_add(1, Ordering::Relaxed) % self.workers.len();
            self.workers[w].deque.lock().unwrap().push_back(ticket);
            self.notify_one_sync();
        }
    }

    /// Claim the next runnable ticket for the current thread: own deque
    /// bottom (workers only), then injector front, then steal from a
    /// randomized victim order. Updates the matching counter.
    ///
    /// With `Claim::RegionsOnly`, detached one-shot tasks are left in
    /// place and only region tickets (bounded slices of already-running
    /// parallel work) are claimed — the policy for waiters that must
    /// stay responsive to their own wake condition (the region-close
    /// wait, the admission scheduler's lane wait), which must not
    /// disappear into a whole foreign job.
    fn next_ticket(&self, me: Option<usize>, rng: &mut u64, claim: Claim) -> Option<Ticket> {
        let allowed = |t: Option<&Ticket>| match t {
            None => false,
            Some(Ticket::Region(_)) => true,
            Some(Ticket::Task(_)) => claim == Claim::Any,
        };
        if let Some(w) = me {
            let mut dq = self.workers[w].deque.lock().unwrap();
            if allowed(dq.back()) {
                let t = dq.pop_back();
                drop(dq);
                self.local_hits.fetch_add(1, Ordering::Relaxed);
                return t;
            }
        }
        {
            let mut inner = self.injector.lock().unwrap();
            if allowed(inner.queue.front()) {
                let t = inner.queue.pop_front();
                drop(inner);
                self.injector_pops.fetch_add(1, Ordering::Relaxed);
                return t;
            }
        }
        let n = self.workers.len();
        if n == 0 {
            return None;
        }
        let start = (xorshift(rng) % n as u64) as usize;
        for k in 0..n {
            let victim = (start + k) % n;
            if Some(victim) == me {
                continue;
            }
            let mut dq = self.workers[victim].deque.lock().unwrap();
            if allowed(dq.front()) {
                let t = dq.pop_front();
                drop(dq);
                self.steals.fetch_add(1, Ordering::Relaxed);
                return t;
            }
        }
        None
    }

    /// Execute one claimed ticket. Detached-task panics are swallowed
    /// here (the admission layer catches its own job panics; ad-hoc
    /// [`ThreadPool::spawn`] tasks must not unwind into an unrelated
    /// helper's stack or kill a worker).
    fn run_ticket(&self, ticket: Ticket) {
        match ticket {
            Ticket::Region(region) => region.run_ticket(),
            Ticket::Task(task) => {
                let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(task));
            }
        }
    }

    /// Whether any deque or pinned slot holds work (park re-check).
    fn has_visible_work(&self, me: Option<usize>) -> bool {
        if let Some(w) = me {
            if self.workers[w].pinned.lock().unwrap().is_some() {
                return true;
            }
        }
        self.workers.iter().any(|w| !w.deque.lock().unwrap().is_empty())
    }

    /// Claim one currently-parked worker for a pinned blocking task.
    /// Holding the injector mutex, `parked[i] == true` proves worker
    /// `i` is quiescent inside the condvar wait, so the pinned slot is
    /// guaranteed to be the next thing it runs — a dedicated thread for
    /// the blocking task, with no circular-wait risk (a parked worker
    /// is, by definition, waiting on nothing).
    fn try_pin(&self, task: PinnedTask) -> bool {
        let inner = self.injector.lock().unwrap();
        if self.shutdown.load(Ordering::SeqCst) {
            return false;
        }
        for i in 0..inner.parked.len() {
            if !inner.parked[i] {
                continue;
            }
            let mut slot = self.workers[i].pinned.lock().unwrap();
            if slot.is_none() {
                *slot = Some(task);
                drop(slot);
                drop(inner);
                self.ready.notify_all();
                return true;
            }
        }
        false
    }

    /// Claim and run one ticket under `claim` as a **help step**;
    /// returns whether one ran (always `false` after shutdown — a
    /// stale ticket is never started). The single body behind every
    /// help path — `help_while`, `help_one`, the region-close wait,
    /// and [`scope_blocking`]'s completion wait — so the shutdown
    /// check, claim policy, and counter accounting live in exactly
    /// one audited place. Note the `help_runs` increment *overlaps*
    /// the source counter `next_ticket` already bumped for the claim.
    fn try_help_step(&self, rng: &mut u64, claim: Claim) -> bool {
        if self.shutdown.load(Ordering::SeqCst) {
            return false;
        }
        match self.next_ticket(self.current_worker(), rng, claim) {
            Some(t) => {
                self.help_runs.fetch_add(1, Ordering::Relaxed);
                self.run_ticket(t);
                true
            }
            None => false,
        }
    }

    /// Run queued tickets until `done()` holds. Used by every blocked
    /// thread — this is what makes waiters workers. Returns early if
    /// the pool shuts down (without ever starting a stale ticket).
    fn help_while(&self, seed: u64, done: impl Fn() -> bool) {
        let mut rng = steal_seed(seed);
        loop {
            if done() || self.shutdown.load(Ordering::SeqCst) {
                return;
            }
            if self.try_help_step(&mut rng, Claim::Any) {
                continue;
            }
            let inner = self.injector.lock().unwrap();
            if done() || self.shutdown.load(Ordering::SeqCst) {
                return;
            }
            if inner.queue.is_empty() && !self.has_visible_work(self.current_worker()) {
                drop(self.ready.wait_timeout(inner, PARK_TIMEOUT).unwrap());
            }
        }
    }

    /// One regions-only help step (see [`ThreadPool::try_help_one`]):
    /// the shared body behind both the pool handle's and the
    /// [`PoolHelper`]'s `try_help_one`.
    fn help_one(&self, seed: u64) -> bool {
        let mut rng = steal_seed(seed);
        self.try_help_step(&mut rng, Claim::RegionsOnly)
    }
}

/// Pin the calling thread to `cpu` and return the CPU it subsequently
/// observes itself on. On non-Linux targets this is a no-op returning
/// −1; `pin = false` skips the affinity call but still reports the CPU.
fn pin_current_thread(pin: bool, cpu: usize) -> i64 {
    #[cfg(target_os = "linux")]
    {
        if pin {
            let mut mask = libc::cpu_set_t::zero();
            mask.set(cpu);
            // Best-effort: a failed call (e.g. a restrictive cgroup
            // cpuset) just leaves the thread unpinned.
            unsafe { libc::sched_setaffinity(0, std::mem::size_of::<libc::cpu_set_t>(), &mask) };
        }
        unsafe { libc::sched_getcpu() as i64 }
    }
    #[cfg(not(target_os = "linux"))]
    {
        let _ = (pin, cpu);
        -1
    }
}

/// `QAI_POOL_PIN=1` opts every pool into worker pinning by default
/// (read once, cached); anything else — including unset — leaves
/// placement to the OS scheduler. [`ThreadPool::with_pinning`] and
/// [`EngineBuilder::pin_workers`](crate::mitigation::engine::EngineBuilder::pin_workers)
/// override per pool.
pub fn pin_workers_default() -> bool {
    static PIN: OnceLock<bool> = OnceLock::new();
    *PIN.get_or_init(|| {
        std::env::var("QAI_POOL_PIN").map(|v| v.trim() == "1").unwrap_or(false)
    })
}

fn worker_loop(shared: Arc<PoolShared>, me: usize, pin: bool) {
    CURRENT_WORKER.with(|c| c.set(Some((shared.id, me))));
    // Spread workers round-robin over the host's CPUs; worker w of any
    // pool lands on CPU w mod n_cpus, so per-shard pools of equal size
    // overlay identically.
    let n_cpus = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let observed = pin_current_thread(pin, me % n_cpus);
    shared.workers[me].cpu.store(observed, Ordering::Relaxed);
    let mut rng = steal_seed(me as u64);
    loop {
        if shared.shutdown.load(Ordering::SeqCst) {
            return;
        }
        // A pinned blocking task always runs first: the reservation
        // protocol promised its owner a dedicated thread.
        let pinned = shared.workers[me].pinned.lock().unwrap().take();
        if let Some(p) = pinned {
            // SAFETY: the reserving scope_blocking caller keeps `ctx`
            // alive until the trampoline signals completion.
            unsafe { (p.run)(p.ctx) };
            continue;
        }
        if let Some(t) = shared.next_ticket(Some(me), &mut rng, Claim::Any) {
            shared.run_ticket(t);
            continue;
        }
        // Park: re-check everything under the injector mutex so a
        // concurrent publisher either sees us pre-wait (we spot the
        // work) or post-wait (we get the notification).
        let mut inner = shared.injector.lock().unwrap();
        if shared.shutdown.load(Ordering::SeqCst) {
            return;
        }
        if !inner.queue.is_empty() || shared.has_visible_work(Some(me)) {
            continue;
        }
        inner.parked[me] = true;
        let (mut inner, _) = shared.ready.wait_timeout(inner, PARK_TIMEOUT).unwrap();
        inner.parked[me] = false;
    }
}

/// Point-in-time snapshot of a pool's scheduler counters — monotonic
/// totals since the pool was built. They prove behavior the exactness
/// tests cannot see: `steals > 0` means the deques really shed load,
/// `help_runs > 0` means blocked threads really executed queued work.
///
/// Accounting: every executed ticket is counted **exactly once** by
/// claim source (`local_hits` + `injector_pops` + `steals` is the
/// total executed), and `help_runs` is an **overlapping** attribution
/// — a ticket claimed by a blocked thread increments both its source
/// counter and `help_runs` — so do not add it into a ticket total.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PoolCounters {
    /// Tickets a worker popped from its own deque (LIFO locality hits).
    pub local_hits: u64,
    /// Tickets taken from the global injector queue.
    pub injector_pops: u64,
    /// Tickets stolen from another worker's deque.
    pub steals: u64,
    /// Tickets executed by a *blocked* thread (a region opener waiting
    /// for stragglers, a [`scope_blocking`] caller, the admission
    /// scheduler, or an explicit [`ThreadPool::help_until`] loop).
    /// Overlaps the three source counters above.
    pub help_runs: u64,
}

/// A persistent worker pool sized for `lanes`-way parallelism (the
/// caller of a parallel region is always one lane, so `lanes - 1`
/// workers are spawned). [`ThreadPool::new`] exists for explicit sizing
/// — e.g. the Fig. 8 thread sweep — while most code uses [`global`].
pub struct ThreadPool {
    shared: Arc<PoolShared>,
    lanes: usize,
    handles: Vec<std::thread::JoinHandle<()>>,
    /// Parallel regions ever opened on this pool (see
    /// [`ThreadPool::regions_opened`]).
    regions: AtomicUsize,
}

impl ThreadPool {
    /// Build a pool for `lanes`-way parallelism (`lanes >= 1`); spawns
    /// `lanes - 1` persistent workers immediately. Worker pinning
    /// follows the `QAI_POOL_PIN` process default
    /// ([`pin_workers_default`]); use [`ThreadPool::with_pinning`] to
    /// choose explicitly.
    pub fn new(lanes: usize) -> Self {
        Self::with_pinning(lanes, pin_workers_default())
    }

    /// [`ThreadPool::new`] with worker pinning chosen explicitly: with
    /// `pin = true` each worker `w` sets its CPU affinity to `w mod
    /// available_parallelism` at startup (Linux `sched_setaffinity`;
    /// no-op elsewhere), trading the scheduler's freedom to migrate for
    /// cache residency on kernel-bound workloads. The CPU each worker
    /// actually observed is reported by [`ThreadPool::worker_cpus`].
    pub fn with_pinning(lanes: usize, pin: bool) -> Self {
        let lanes = lanes.max(1);
        let n_workers = lanes - 1;
        let shared = Arc::new(PoolShared {
            id: NEXT_POOL_ID.fetch_add(1, Ordering::Relaxed),
            injector: Mutex::new(InjectorInner {
                queue: VecDeque::new(),
                parked: vec![false; n_workers],
            }),
            ready: Condvar::new(),
            shutdown: AtomicBool::new(false),
            workers: (0..n_workers)
                .map(|_| WorkerShared {
                    deque: Mutex::new(VecDeque::new()),
                    pinned: Mutex::new(None),
                    cpu: AtomicI64::new(-1),
                })
                .collect(),
            next_task_worker: AtomicUsize::new(0),
            regions_only_waiters: AtomicUsize::new(0),
            local_hits: AtomicU64::new(0),
            injector_pops: AtomicU64::new(0),
            steals: AtomicU64::new(0),
            help_runs: AtomicU64::new(0),
        });
        let handles = (0..n_workers)
            .map(|w| {
                OS_THREAD_SPAWNS.fetch_add(1, Ordering::SeqCst);
                let sh = shared.clone();
                std::thread::Builder::new()
                    .name(format!("qai-pool-{w}"))
                    .spawn(move || worker_loop(sh, w, pin))
                    .expect("spawn pool worker")
            })
            .collect();
        ThreadPool { shared, lanes, handles, regions: AtomicUsize::new(0) }
    }

    /// Maximum useful parallelism of this pool (workers + caller).
    pub fn lanes(&self) -> usize {
        self.lanes
    }

    /// Number of persistent worker threads (`lanes - 1`).
    pub fn workers(&self) -> usize {
        self.handles.len()
    }

    /// Number of workers currently parked inside the condvar wait —
    /// the same quiescence proof [`scope_blocking`]'s pinning uses
    /// (`parked[i]` flips only under the injector mutex).
    /// This is spare capacity an adaptive admission shard may grow
    /// its lane cap into; the instantaneous value is advisory — a
    /// worker can unpark the moment the lock is released.
    pub fn parked_workers(&self) -> usize {
        let inner = self.shared.injector.lock().unwrap();
        inner.parked.iter().filter(|&&p| p).count()
    }

    /// How many parallel regions have been opened on this pool so far.
    /// Sequential fast paths (`threads == 1`, or work too small to
    /// split) do not open a region. Confinement tests use this to prove
    /// that a job's internal steps really ran on a specific pool.
    pub fn regions_opened(&self) -> usize {
        self.regions.load(Ordering::SeqCst)
    }

    /// The CPU each worker last observed itself on (index = worker id):
    /// `sched_getcpu` at worker startup, after affinity pinning when
    /// enabled. −1 means not reported — non-Linux targets, or a worker
    /// that has not reached its loop yet. With pinning on, entry `w` is
    /// `w mod available_parallelism` once the worker is up (modulo a
    /// cgroup rejecting the affinity call).
    pub fn worker_cpus(&self) -> Vec<i64> {
        self.shared.workers.iter().map(|w| w.cpu.load(Ordering::Relaxed)).collect()
    }

    /// Snapshot of the scheduler counters.
    pub fn counters(&self) -> PoolCounters {
        PoolCounters {
            local_hits: self.shared.local_hits.load(Ordering::Relaxed),
            injector_pops: self.shared.injector_pops.load(Ordering::Relaxed),
            steals: self.shared.steals.load(Ordering::Relaxed),
            help_runs: self.shared.help_runs.load(Ordering::Relaxed),
        }
    }

    /// A [`PoolHelper`] — a handle on the pool's scheduler state that
    /// can lend this (or any) thread to the pool and that remains valid
    /// after the pool is dropped (its methods then return immediately).
    pub fn helper(&self) -> PoolHelper {
        PoolHelper { shared: self.shared.clone() }
    }

    /// Enqueue a detached one-shot task. It runs exactly once — on a
    /// worker, or on any thread helping while blocked. On a pool with
    /// zero workers the task waits until somebody helps (the admission
    /// scheduler therefore still runs its jobs inline on single-lane
    /// pools). A panicking task is swallowed, not propagated.
    pub fn spawn(&self, task: impl FnOnce() + Send + 'static) {
        self.submit_task(Box::new(task));
    }

    /// Run queued tickets until `done` is true (or the pool shuts
    /// down — shutdown never starts another ticket). This is the
    /// cooperative-blocking entry point for code that waits on
    /// something the pool itself will eventually produce: instead of
    /// burning a thread, lend it.
    pub fn help_until(&self, done: &AtomicBool) {
        self.shared.help_while(self.shared.id, || done.load(Ordering::SeqCst));
    }

    /// Claim and run a single queued **region** ticket — a bounded
    /// slice of already-running parallel work — if one is available
    /// right now; returns whether one ran. Counted as a help run.
    /// Deliberately never claims a whole detached task: this is for
    /// waiters that must stay responsive to their own wake condition
    /// (the admission scheduler's lane wait) and cannot afford to
    /// disappear into a foreign job. To lend a thread fully, use
    /// [`ThreadPool::help_until`].
    pub fn try_help_one(&self) -> bool {
        self.shared.help_one(self.shared.next_task_worker.load(Ordering::Relaxed) as u64)
    }

    /// Enqueue a detached one-shot task (boxed form; see
    /// [`ThreadPool::spawn`]).
    pub(crate) fn submit_task(&self, task: Box<dyn FnOnce() + Send>) {
        self.shared.push_task(Ticket::Task(task));
    }

    /// Publish a region over `0..n` with the given `grain`, offer up to
    /// `extra` tickets (on the opener's own deque when the opener is a
    /// worker of this pool, on the injector otherwise), participate,
    /// and help with other queued work until the region quiesces.
    /// Re-raises the first panic from the body.
    fn dispatch<F>(&self, n: usize, grain: usize, extra: usize, body: &F)
    where
        F: Fn(usize, usize) + Sync,
    {
        unsafe fn trampoline<F: Fn(usize, usize)>(ctx: *const (), start: usize, end: usize) {
            (*(ctx as *const F))(start, end);
        }
        self.regions.fetch_add(1, Ordering::SeqCst);
        let region = Arc::new(Region {
            ctx: body as *const F as *const (),
            call: trampoline::<F>,
            n,
            grain: grain.max(1),
            cursor: AtomicUsize::new(0),
            state: Mutex::new(RegionState { in_flight: 0, closed: false }),
            done: Condvar::new(),
            panic_payload: Mutex::new(None),
        });
        let extra = extra.min(self.lanes.saturating_sub(1));
        if extra > 0 {
            match self.shared.current_worker() {
                Some(me) => {
                    // Worker-local publication: newest work at the
                    // bottom of our own deque (LIFO locality for deep
                    // nesting); idle workers steal from the top.
                    let mut dq = self.shared.workers[me].deque.lock().unwrap();
                    for _ in 0..extra {
                        dq.push_back(Ticket::Region(region.clone()));
                    }
                    drop(dq);
                    self.shared.notify_all_sync();
                }
                None => {
                    let mut inner = self.shared.injector.lock().unwrap();
                    for _ in 0..extra {
                        inner.queue.push_back(Ticket::Region(region.clone()));
                    }
                    drop(inner);
                    self.shared.ready.notify_all();
                }
            }
        }

        // The opener is always a participant: even with every worker
        // busy (or zero workers), the region completes.
        region.drain();
        self.wait_region_close(&region);

        if let Some(payload) = region.panic_payload.lock().unwrap().take() {
            std::panic::resume_unwind(payload);
        }
    }

    /// Wait for every straggler to leave the region body, **helping**
    /// with other queued region tickets meanwhile (never whole detached
    /// tasks: the opener must return promptly when its region closes,
    /// and must not inflate an unrelated job's measured latency by
    /// running it mid-wait), then close the region. Helping stops at
    /// shutdown (no stale ticket is ever started), but the close still
    /// waits for stragglers — they are live threads that will finish
    /// their batches.
    fn wait_region_close(&self, region: &Region) {
        let mut rng = steal_seed(self.shared.id ^ 0x5bd1_e995);
        // Escalating backoff: re-scan for helpable tickets quickly at
        // first, but once a scan comes up empty fall back to the long
        // park period — the straggler's exit notifies `done` directly,
        // so a slow region never polls at 1 kHz just to discover,
        // repeatedly, that there is nothing to help with.
        let mut idle_wait = REGION_WAIT_TIMEOUT;
        loop {
            {
                let mut st = region.state.lock().unwrap();
                if st.in_flight == 0 {
                    st.closed = true;
                    return;
                }
            }
            if self.shared.try_help_step(&mut rng, Claim::RegionsOnly) {
                idle_wait = REGION_WAIT_TIMEOUT;
                continue;
            }
            let st = region.state.lock().unwrap();
            if st.in_flight > 0 {
                drop(region.done.wait_timeout(st, idle_wait).unwrap());
                idle_wait = PARK_TIMEOUT;
            }
        }
    }

    /// Process `data` in `threads` contiguous chunks, calling
    /// `f(chunk_start_index, chunk)` on each — drop-in for the retired
    /// fork-join `parallel_chunks_mut` (identical chunk decomposition,
    /// balanced to within one element).
    pub fn chunks_mut<T: Send, F>(&self, data: &mut [T], threads: usize, f: F)
    where
        F: Fn(usize, &mut [T]) + Sync,
    {
        let n = data.len();
        if threads <= 1 || n < 2 {
            f(0, data);
            return;
        }
        let chunks = threads.min(n);
        let base = n / chunks;
        let extra = n % chunks;
        let slice = UnsafeSlice::new(data);
        let body = |lo: usize, hi: usize| {
            for c in lo..hi {
                let start = c * base + c.min(extra);
                let len = base + usize::from(c < extra);
                // SAFETY: chunk index ranges are disjoint by construction.
                let chunk = unsafe { slice.slice_mut(start, len) };
                f(start, chunk);
            }
        };
        self.dispatch(chunks, 1, chunks - 1, &body);
    }

    /// Self-scheduled loop over `0..n` claiming `grain` indices at a
    /// time — drop-in for the retired fork-join `parallel_for_range`.
    pub fn for_range<F>(&self, n: usize, threads: usize, grain: usize, f: F)
    where
        F: Fn(usize) + Sync,
    {
        if threads <= 1 || n <= grain {
            for i in 0..n {
                f(i);
            }
            return;
        }
        let body = |lo: usize, hi: usize| {
            for i in lo..hi {
                f(i);
            }
        };
        self.dispatch(n, grain.max(1), threads.min(n) - 1, &body);
    }

    /// Like [`ThreadPool::for_range`] but hands the body whole
    /// contiguous batches, so per-batch scratch (e.g. the EDT's Voronoi
    /// stacks) is allocated once per batch — drop-in for the retired
    /// fork-join `parallel_for_batches`.
    pub fn for_batches<F>(&self, n: usize, threads: usize, grain: usize, f: F)
    where
        F: Fn(Range<usize>) + Sync,
    {
        let grain = grain.max(1);
        if threads <= 1 || n <= grain {
            if n > 0 {
                f(0..n);
            }
            return;
        }
        let body = |lo: usize, hi: usize| f(lo..hi);
        self.dispatch(n, grain, threads.min(n.div_ceil(grain)) - 1, &body);
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        // Synchronize with parked threads so none is between its
        // shutdown check and the wait when we notify; then wake
        // everyone. Workers exit before starting any further ticket;
        // external helpers ([`PoolHelper`]) observe the flag and return
        // without running anything stale.
        self.shared.notify_all_sync();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// A handle on a pool's scheduler that lends the calling thread to the
/// pool. Unlike `&ThreadPool`, a helper may outlive the pool: once the
/// pool is dropped, every method returns immediately without running a
/// stale ticket (the shutdown-drain contract the scheduler tests pin).
#[derive(Clone)]
pub struct PoolHelper {
    shared: Arc<PoolShared>,
}

impl PoolHelper {
    /// Run queued tickets until `done` is true or the pool shuts down
    /// (see [`ThreadPool::help_until`]).
    pub fn help_until(&self, done: &AtomicBool) {
        self.shared.help_while(self.shared.id ^ 0x1234_5678, || done.load(Ordering::SeqCst));
    }

    /// Enqueue a detached one-shot task (see [`ThreadPool::spawn`]).
    /// No-op after the pool has shut down.
    pub fn spawn(&self, task: impl FnOnce() + Send + 'static) {
        if self.shared.shutdown.load(Ordering::SeqCst) {
            return;
        }
        self.shared.push_task(Ticket::Task(Box::new(task)));
    }

    /// Claim and run a single queued region ticket if one is available
    /// (see [`ThreadPool::try_help_one`]; never a whole detached
    /// task). Always `false` after shutdown.
    pub fn try_help_one(&self) -> bool {
        self.shared.help_one(self.shared.help_runs.load(Ordering::Relaxed))
    }
}

impl std::fmt::Debug for PoolHelper {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PoolHelper").field("pool_id", &self.shared.id).finish()
    }
}

/// Lane count for the global pool: `QAI_POOL_THREADS` if set, else the
/// host's available parallelism floored at 8, so the Fig. 8-style
/// thread sweeps (≤ 8) and the batched service get real concurrency
/// even on small CI hosts (parked workers are nearly free).
fn default_lanes() -> usize {
    if let Ok(v) = std::env::var("QAI_POOL_THREADS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).max(8)
}

static GLOBAL_POOL: OnceLock<ThreadPool> = OnceLock::new();

/// The process-wide pool, created on first use. Workers persist for the
/// life of the process (the pool is never dropped).
pub fn global() -> &'static ThreadPool {
    GLOBAL_POOL.get_or_init(|| ThreadPool::new(default_lanes()))
}

/// Whether the global pool has been created yet. Sequential fast paths
/// and pool-confined work never force its creation, which the
/// pool-confinement tests assert through this probe.
pub fn global_is_initialized() -> bool {
    GLOBAL_POOL.get().is_some()
}

/// Which pool a parallel region runs on.
///
/// `Global` matches the module's free functions exactly — including the
/// guarantee that `threads == 1` work runs inline without ever creating
/// the global pool. `Explicit` confines every region to the given pool:
/// nothing escapes to the global one, which is what lets
/// [`crate::mitigation::service::MitigationService::with_pool`] bound a
/// job's *internal* parallelism, not just the cross-job fan-out.
#[derive(Clone, Copy, Default)]
pub enum PoolHandle<'p> {
    /// The lazily-created process-wide pool ([`global`]).
    #[default]
    Global,
    /// An explicit pool; every region opens on it and nowhere else.
    Explicit(&'p ThreadPool),
}

impl<'p> PoolHandle<'p> {
    /// Resolve to a concrete pool, creating the global pool if this is
    /// `Global` and it does not exist yet.
    pub fn resolve(self) -> &'p ThreadPool {
        match self {
            PoolHandle::Global => global(),
            PoolHandle::Explicit(pool) => pool,
        }
    }

    /// [`ThreadPool::chunks_mut`] on the selected pool; `threads <= 1`
    /// (or trivially small data) runs inline without resolving it.
    pub fn chunks_mut<T: Send, F>(self, data: &mut [T], threads: usize, f: F)
    where
        F: Fn(usize, &mut [T]) + Sync,
    {
        if threads <= 1 || data.len() < 2 {
            f(0, data);
            return;
        }
        self.resolve().chunks_mut(data, threads, f)
    }

    /// [`ThreadPool::for_range`] on the selected pool; `threads <= 1`
    /// (or `n <= grain`) runs inline without resolving it.
    pub fn for_range<F>(self, n: usize, threads: usize, grain: usize, f: F)
    where
        F: Fn(usize) + Sync,
    {
        if threads <= 1 || n <= grain {
            for i in 0..n {
                f(i);
            }
            return;
        }
        self.resolve().for_range(n, threads, grain, f)
    }

    /// [`ThreadPool::for_batches`] on the selected pool; `threads <= 1`
    /// (or `n <= grain`) runs inline without resolving it.
    pub fn for_batches<F>(self, n: usize, threads: usize, grain: usize, f: F)
    where
        F: Fn(Range<usize>) + Sync,
    {
        let grain = grain.max(1);
        if threads <= 1 || n <= grain {
            if n > 0 {
                f(0..n);
            }
            return;
        }
        self.resolve().for_batches(n, threads, grain, f)
    }
}

/// Useful parallelism of the global pool.
pub fn parallelism() -> usize {
    global().lanes()
}

/// [`ThreadPool::chunks_mut`] on the global pool (`threads <= 1` never
/// touches or initializes it).
pub fn chunks_mut<T: Send, F>(data: &mut [T], threads: usize, f: F)
where
    F: Fn(usize, &mut [T]) + Sync,
{
    PoolHandle::Global.chunks_mut(data, threads, f)
}

/// [`ThreadPool::for_range`] on the global pool (`threads <= 1` never
/// touches or initializes it).
pub fn for_range<F>(n: usize, threads: usize, grain: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    PoolHandle::Global.for_range(n, threads, grain, f)
}

/// [`ThreadPool::for_batches`] on the global pool (`threads <= 1` never
/// touches or initializes it).
pub fn for_batches<F>(n: usize, threads: usize, grain: usize, f: F)
where
    F: Fn(Range<usize>) + Sync,
{
    PoolHandle::Global.for_batches(n, threads, grain, f)
}

/// A slice wrapper that asserts disjoint-index writes at the type
/// level's edge: workers write through raw pointers. The caller must
/// guarantee that no index is written by two workers (all users in this
/// crate index by disjoint line/block decompositions). This is the
/// disjoint-writes cell every parallel kernel in the crate builds on
/// (it moved here from the retired fork-join module, whose only
/// surviving piece it is).
pub struct UnsafeSlice<'a, T> {
    ptr: *mut T,
    len: usize,
    _marker: std::marker::PhantomData<&'a mut [T]>,
}

unsafe impl<T: Send> Send for UnsafeSlice<'_, T> {}
unsafe impl<T: Send> Sync for UnsafeSlice<'_, T> {}

impl<'a, T> UnsafeSlice<'a, T> {
    /// Wrap a mutable slice for disjoint parallel writes.
    pub fn new(slice: &'a mut [T]) -> Self {
        UnsafeSlice { ptr: slice.as_mut_ptr(), len: slice.len(), _marker: std::marker::PhantomData }
    }

    /// Length of the underlying slice.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True if empty.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Write `value` at `i`.
    ///
    /// # Safety
    /// `i < len` and no other thread concurrently reads or writes `i`.
    #[inline]
    pub unsafe fn write(&self, i: usize, value: T) {
        debug_assert!(i < self.len);
        *self.ptr.add(i) = value;
    }

    /// Read the value at `i` (T: Copy).
    ///
    /// # Safety
    /// `i < len` and no other thread concurrently writes `i`.
    #[inline]
    pub unsafe fn read(&self, i: usize) -> T
    where
        T: Copy,
    {
        debug_assert!(i < self.len);
        *self.ptr.add(i)
    }

    /// Get a mutable sub-slice `[start, start+len)`.
    ///
    /// # Safety
    /// The range is in bounds and not aliased by any concurrent access.
    #[inline]
    pub unsafe fn slice_mut(&self, start: usize, len: usize) -> &'a mut [T] {
        debug_assert!(start + len <= self.len);
        std::slice::from_raw_parts_mut(self.ptr.add(start), len)
    }
}

/// Per-task shared cell of one [`scope_blocking`] run, referenced by
/// pinned workers through a type-erased pointer. `remaining` and
/// `shared` are raw because the trampoline erases lifetimes; the caller
/// keeps both alive until every pinned task has decremented
/// `remaining`.
struct PinCtx<T, F> {
    task: Mutex<Option<F>>,
    out: Mutex<Option<std::thread::Result<T>>>,
    remaining: *const AtomicUsize,
    shared: *const PoolShared,
}

// SAFETY: all interior access is mutex-guarded; the raw pointers are
// only dereferenced while the scope_blocking caller (which owns the
// referents) is still blocked in its completion wait.
unsafe impl<T: Send, F: Send> Send for PinCtx<T, F> {}
unsafe impl<T: Send, F: Send> Sync for PinCtx<T, F> {}

/// Trampoline a reserved worker runs for one pinned blocking task.
unsafe fn run_pinned<T, F: FnOnce() -> T>(ctx: *mut ()) {
    let c = &*(ctx as *const PinCtx<T, F>);
    // Copy the raw pointers out first: the decrement below is the
    // caller's license to free the ctx, so `c` must not be touched
    // after it. The pool shared state outlives everything (the global
    // pool is never dropped), and the caller cannot free `remaining`
    // until it has observed the decremented value.
    let remaining = c.remaining;
    let shared = c.shared;
    let f = c.task.lock().unwrap().take().expect("pinned task already taken");
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(f));
    *c.out.lock().unwrap() = Some(result);
    // Order matters: decrement after the result is stored, notify after
    // the decrement, so the caller's wake-up check observes completion.
    (*remaining).fetch_sub(1, Ordering::SeqCst);
    (*shared).notify_all_sync();
}

/// Run a set of **mutually-blocking** tasks to completion, each on a
/// thread it does not share with any other task (single tasks run
/// inline). This exists for the coordinator's simulated-MPI ranks,
/// which block in `recv` on one another: multiplexing such tasks onto
/// shared lanes can deadlock, so each needs a dedicated thread for its
/// whole lifetime.
///
/// Cooperative version: instead of spawning one scoped OS thread per
/// task, the runtime **reserves currently-parked global-pool workers**
/// (a parked worker is waiting on nothing, so dedicating it is
/// deadlock-free) and pins one task to each; only the overflow beyond
/// the reserved capacity gets scoped threads (counted by
/// [`os_thread_spawns`]). The calling thread runs the first task
/// itself, then **helps the pool** with queued region tickets (the
/// ranks' own internal parallel regions, typically — never a whole
/// foreign detached job, which would delay the return long after the
/// last rank finished) while the pinned ranks drain. The first
/// panicking task's payload is re-raised after all tasks complete.
///
/// Trade-off: a pinned worker is unavailable for stealing while its
/// task runs, so a rank set that saturates the pool also consumes the
/// workers that would otherwise execute the ranks' *internal* parallel
/// regions — with `tasks ≈ lanes` and intra-task `threads > 1`, those
/// regions degrade toward each opener running its own chunks (every
/// opener always participates, so nothing stalls). The coordinator's
/// paper configuration (`threads_per_rank = 1`) is unaffected; size
/// the global pool above the rank count if you need both.
pub fn scope_blocking<'env, T, F>(tasks: Vec<F>) -> Vec<T>
where
    T: Send + 'env,
    F: FnOnce() -> T + Send + 'env,
{
    let n = tasks.len();
    if n <= 1 {
        return tasks.into_iter().map(|t| t()).collect();
    }
    let pool = global();
    let shared: &PoolShared = &pool.shared;
    let pinned_left = AtomicUsize::new(0);
    let ctxs: Vec<PinCtx<T, F>> = tasks
        .into_iter()
        .map(|f| PinCtx {
            task: Mutex::new(Some(f)),
            out: Mutex::new(None),
            remaining: &pinned_left as *const AtomicUsize,
            shared: shared as *const PoolShared,
        })
        .collect();

    // Reserve a parked worker for each task beyond the caller's own;
    // tasks that cannot be pinned fall back to scoped threads.
    let mut scoped: Vec<usize> = Vec::new();
    for (i, ctx) in ctxs.iter().enumerate().skip(1) {
        // Count before pinning: the pinned task may finish (and
        // decrement) before fetch_add would otherwise run.
        pinned_left.fetch_add(1, Ordering::SeqCst);
        let task = PinnedTask {
            ctx: ctx as *const PinCtx<T, F> as *mut (),
            run: run_pinned::<T, F>,
        };
        if !shared.try_pin(task) {
            pinned_left.fetch_sub(1, Ordering::SeqCst);
            scoped.push(i);
        }
    }

    std::thread::scope(|s| {
        let handles: Vec<_> = scoped
            .iter()
            .map(|&i| {
                OS_THREAD_SPAWNS.fetch_add(1, Ordering::SeqCst);
                let ctx = &ctxs[i];
                s.spawn(move || {
                    let f = ctx.task.lock().unwrap().take().expect("scoped task already taken");
                    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(f));
                    *ctx.out.lock().unwrap() = Some(result);
                })
            })
            .collect();

        // The caller is a rank too: run the first task inline.
        {
            let f = ctxs[0].task.lock().unwrap().take().expect("caller task already taken");
            let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(f));
            *ctxs[0].out.lock().unwrap() = Some(result);
        }

        // Every blocked thread is a worker: help the pool while the
        // pinned ranks finish — with region tickets only, so the last
        // rank completing never finds this caller buried in a whole
        // foreign job (the measured distributed wall-clock would
        // inflate by that job's duration otherwise). Registering as a
        // regions-only waiter makes single-task publications wake the
        // whole herd while we park (a lone `notify_one` aimed at a
        // worker could land here instead and be swallowed). This wait
        // must not return early (the pinned trampolines dereference
        // `ctxs`), so it outlasts even a shutdown — which cannot
        // happen on the never-dropped global pool, but the loop is
        // written to survive it anyway.
        let mut rng = steal_seed(shared.id ^ 0x7343_11c3);
        shared.regions_only_waiters.fetch_add(1, Ordering::SeqCst);
        // Same escalating backoff as the region-close wait: pinned
        // completions notify `ready` directly, so long park periods
        // cost no completion latency.
        let mut idle_wait = REGION_WAIT_TIMEOUT;
        while pinned_left.load(Ordering::SeqCst) > 0 {
            if shared.try_help_step(&mut rng, Claim::RegionsOnly) {
                idle_wait = REGION_WAIT_TIMEOUT;
                continue;
            }
            let inner = shared.injector.lock().unwrap();
            if pinned_left.load(Ordering::SeqCst) > 0 {
                drop(shared.ready.wait_timeout(inner, idle_wait).unwrap());
                idle_wait = PARK_TIMEOUT;
            }
        }
        shared.regions_only_waiters.fetch_sub(1, Ordering::SeqCst);

        for h in handles {
            let _ = h.join();
        }
    });

    let mut first_panic: Option<Box<dyn std::any::Any + Send>> = None;
    let mut outs = Vec::with_capacity(n);
    for c in ctxs {
        match c.out.into_inner().unwrap().expect("blocking task never produced a result") {
            Ok(v) => outs.push(v),
            Err(p) => {
                if first_panic.is_none() {
                    first_panic = Some(p);
                }
            }
        }
    }
    if let Some(p) = first_panic {
        std::panic::resume_unwind(p);
    }
    outs
}

/// The spawn counter is process-global, so unit tests anywhere in the
/// crate that spawn counted OS threads (explicit pools, services and
/// their admission schedulers, `scope_blocking`) or assert on the
/// counter must serialize on this guard to keep the counter assertions
/// race-free under the parallel test harness.
#[cfg(test)]
pub(crate) fn test_guard() -> std::sync::MutexGuard<'static, ()> {
    static GUARD: Mutex<()> = Mutex::new(());
    GUARD.lock().unwrap_or_else(|e| e.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunks_cover_everything_once() {
        let _g = test_guard();
        let pool = ThreadPool::new(4);
        for threads in [1, 2, 3, 7, 16] {
            let mut v = vec![0u32; 1000];
            pool.chunks_mut(&mut v, threads, |start, chunk| {
                for (k, x) in chunk.iter_mut().enumerate() {
                    *x = (start + k) as u32 + 1;
                }
            });
            for (i, x) in v.iter().enumerate() {
                assert_eq!(*x, i as u32 + 1, "threads={threads} i={i}");
            }
        }
    }

    #[test]
    fn chunk_decomposition_matches_forkjoin_exactly() {
        let _g = test_guard();
        // Same (start, len) pairs as util::par::parallel_chunks_mut.
        let pool = ThreadPool::new(3);
        for (n, threads) in [(10, 3), (1000, 7), (5, 8), (2, 2)] {
            let mut v = vec![0usize; n];
            let seen = Mutex::new(Vec::new());
            pool.chunks_mut(&mut v, threads, |start, chunk| {
                seen.lock().unwrap().push((start, chunk.len()));
            });
            let mut got = seen.into_inner().unwrap();
            got.sort_unstable();
            let mut want = Vec::new();
            let chunks = threads.min(n);
            let base = n / chunks;
            let extra = n % chunks;
            let mut start = 0;
            for c in 0..chunks {
                let len = base + usize::from(c < extra);
                want.push((start, len));
                start += len;
            }
            assert_eq!(got, want, "n={n} threads={threads}");
        }
    }

    #[test]
    fn for_range_visits_each_index_once() {
        let _g = test_guard();
        let pool = ThreadPool::new(4);
        for threads in [1, 2, 4, 8, 64] {
            let n = 5000;
            let mut out = vec![0u32; n];
            let s = UnsafeSlice::new(&mut out);
            pool.for_range(n, threads, 64, |i| unsafe { s.write(i, i as u32 * 3) });
            for (i, x) in out.iter().enumerate() {
                assert_eq!(*x, i as u32 * 3);
            }
        }
    }

    #[test]
    fn for_batches_covers_range_disjointly() {
        let _g = test_guard();
        let pool = ThreadPool::new(4);
        for (n, grain) in [(0usize, 4usize), (1, 4), (97, 4), (4096, 16), (10, 100)] {
            let counts: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
            pool.for_batches(n, 5, grain, |r| {
                for i in r {
                    counts[i].fetch_add(1, Ordering::Relaxed);
                }
            });
            for (i, c) in counts.iter().enumerate() {
                assert_eq!(c.load(Ordering::Relaxed), 1, "n={n} grain={grain} i={i}");
            }
        }
    }

    #[test]
    fn single_thread_requests_run_inline() {
        let _g = test_guard();
        // threads == 1 must work even on a zero-worker pool.
        let pool = ThreadPool::new(1);
        let mut v = vec![0u8; 16];
        pool.chunks_mut(&mut v, 1, |_, c| c.iter_mut().for_each(|x| *x = 1));
        assert!(v.iter().all(|&x| x == 1));
        pool.for_range(8, 1, 2, |_| {});
        pool.for_batches(8, 1, 2, |_| {});
    }

    #[test]
    fn zero_worker_pool_still_completes_parallel_requests() {
        let _g = test_guard();
        let pool = ThreadPool::new(1);
        let n = 257;
        let mut out = vec![0u32; n];
        let s = UnsafeSlice::new(&mut out);
        pool.for_range(n, 8, 4, |i| unsafe { s.write(i, 7) });
        assert!(out.iter().all(|&x| x == 7));
    }

    #[test]
    fn nested_regions_complete() {
        let _g = test_guard();
        // A region body that itself opens a region — the service's
        // batch-over-pipelines shape.
        let pool = ThreadPool::new(3);
        let outer = 6usize;
        let inner = 64usize;
        let hits = AtomicUsize::new(0);
        pool.for_range(outer, 3, 1, |_| {
            pool.for_range(inner, 3, 8, |_| {
                hits.fetch_add(1, Ordering::Relaxed);
            });
        });
        assert_eq!(hits.load(Ordering::Relaxed), outer * inner);
    }

    #[test]
    fn warm_pool_spawns_nothing_per_region() {
        let _g = test_guard();
        let pool = ThreadPool::new(4);
        pool.for_range(128, 4, 8, |_| {}); // warm-up
        let before = os_thread_spawns();
        for _ in 0..50 {
            pool.for_range(512, 4, 8, |i| {
                std::hint::black_box(i);
            });
            let mut v = vec![0u8; 256];
            pool.chunks_mut(&mut v, 4, |_, c| c.iter_mut().for_each(|x| *x = 1));
            pool.for_batches(256, 4, 16, |r| {
                std::hint::black_box(r.len());
            });
        }
        assert_eq!(os_thread_spawns(), before, "warm regions must not spawn OS threads");
    }

    #[test]
    fn body_panic_propagates_to_caller() {
        let _g = test_guard();
        let pool = ThreadPool::new(3);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.for_range(100, 3, 1, |i| {
                if i == 42 {
                    panic!("boom at {i}");
                }
            });
        }));
        let payload = result.expect_err("panic must propagate");
        let msg = payload.downcast_ref::<String>().cloned().unwrap_or_default();
        assert!(msg.contains("boom"), "msg={msg}");
        // The pool must stay usable after a panicked region.
        let count = AtomicUsize::new(0);
        pool.for_range(10, 3, 1, |_| {
            count.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(count.load(Ordering::Relaxed), 10);
    }

    #[test]
    fn scope_blocking_runs_mutually_dependent_tasks() {
        let _g = test_guard();
        use std::sync::mpsc::channel;
        let (tx_a, rx_a) = channel::<u32>();
        let (tx_b, rx_b) = channel::<u32>();
        let t1 = move || {
            tx_b.send(1).unwrap();
            rx_a.recv().unwrap()
        };
        let t2 = move || {
            tx_a.send(2).unwrap();
            rx_b.recv().unwrap()
        };
        let boxed: Vec<Box<dyn FnOnce() -> u32 + Send>> = vec![Box::new(t1), Box::new(t2)];
        let got = scope_blocking(boxed);
        assert_eq!(got, vec![2, 1]);
    }

    #[test]
    fn scope_blocking_propagates_task_panics() {
        let _g = test_guard();
        let result = std::panic::catch_unwind(|| {
            let tasks: Vec<Box<dyn FnOnce() -> u32 + Send>> = vec![
                Box::new(|| 1),
                Box::new(|| panic!("rank exploded")),
                Box::new(|| 3),
            ];
            scope_blocking(tasks)
        });
        let payload = result.expect_err("rank panic must propagate to the caller");
        let msg = payload.downcast_ref::<&str>().copied().unwrap_or_default();
        assert!(msg.contains("rank exploded"), "msg={msg}");
    }

    #[test]
    fn explicit_pool_drop_joins_workers() {
        let _g = test_guard();
        let before = os_thread_spawns();
        {
            let pool = ThreadPool::new(3);
            pool.for_range(64, 3, 4, |_| {});
        } // drop: workers must exit cleanly
        assert!(os_thread_spawns() >= before + 2);
    }

    #[test]
    fn scope_blocking_rank_set_larger_than_pool_size() {
        let _g = test_guard();
        // Regression (coordinator path): mutually-blocking rank sets
        // must each get a thread they share with no other rank — pinned
        // pool workers or scoped threads, never multiplexed lanes. The
        // barrier forces every rank to be alive at the same instant, so
        // this hangs (and the harness times out) if ranks ever share
        // threads.
        let pool = ThreadPool::new(2); // deliberately smaller than the rank set
        assert!(pool.lanes() < 12);
        let barrier = Arc::new(std::sync::Barrier::new(12));
        let tasks: Vec<_> = (0..12usize)
            .map(|rank| {
                let b = barrier.clone();
                move || {
                    b.wait();
                    rank
                }
            })
            .collect();
        let got = scope_blocking(tasks);
        assert_eq!(got, (0..12).collect::<Vec<_>>());
    }

    #[test]
    fn detached_tasks_run_on_workers() {
        let _g = test_guard();
        let pool = ThreadPool::new(2);
        let (tx, rx) = std::sync::mpsc::channel();
        pool.spawn(move || {
            tx.send(42u32).unwrap();
        });
        let got = rx.recv_timeout(std::time::Duration::from_secs(30)).unwrap();
        assert_eq!(got, 42);
    }

    #[test]
    fn explicit_handle_opens_regions_only_on_its_pool() {
        let _g = test_guard();
        let pool = ThreadPool::new(3);
        let before = pool.regions_opened();
        let handle = PoolHandle::Explicit(&pool);
        let hits = AtomicUsize::new(0);
        handle.for_range(100, 3, 4, |_| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 100);
        assert_eq!(pool.regions_opened(), before + 1);
        // Sequential requests stay inline: no region opened anywhere.
        handle.for_range(100, 1, 4, |_| {});
        let mut v = vec![0u8; 8];
        handle.chunks_mut(&mut v, 1, |_, c| c.iter_mut().for_each(|x| *x = 1));
        handle.for_batches(8, 1, 2, |_| {});
        assert_eq!(pool.regions_opened(), before + 1);
    }

    #[test]
    fn global_pool_free_functions_work() {
        let _g = test_guard();
        let n = 1000;
        let mut out = vec![0u32; n];
        let s = UnsafeSlice::new(&mut out);
        for_range(n, 4, 32, |i| unsafe { s.write(i, i as u32) });
        for (i, x) in out.iter().enumerate() {
            assert_eq!(*x, i as u32);
        }
        assert!(parallelism() >= 1);
    }

    #[test]
    fn counters_account_for_executed_tickets() {
        let _g = test_guard();
        let pool = ThreadPool::new(4);
        let c0 = pool.counters();
        // External dispatch publishes on the injector; a sustained
        // region guarantees workers actually pop tickets.
        let spin = AtomicUsize::new(0);
        pool.for_range(4096, 4, 1, |_| {
            spin.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(spin.load(Ordering::Relaxed), 4096);
        let c1 = pool.counters();
        // 3 extra tickets were published; each is claimed at most once,
        // and each claim is counted exactly once by source (help_runs
        // is a documented overlapping attribution, not a fourth
        // source).
        let executed = (c1.local_hits - c0.local_hits)
            + (c1.injector_pops - c0.injector_pops)
            + (c1.steals - c0.steals);
        assert!(executed <= 3, "executed={executed}");
        assert!(c1.help_runs - c0.help_runs <= executed, "help_runs must overlap, not add");
    }

    #[test]
    fn help_until_returns_when_flag_set() {
        let _g = test_guard();
        let pool = ThreadPool::new(2);
        let done = Arc::new(AtomicBool::new(false));
        let helper = pool.helper();
        let d = done.clone();
        let h = std::thread::spawn(move || helper.help_until(&d));
        std::thread::sleep(Duration::from_millis(20));
        done.store(true, Ordering::SeqCst);
        h.join().expect("helper must return once the flag is set");
    }

    #[test]
    fn helper_survives_pool_drop_without_running_stale_tickets() {
        let _g = test_guard();
        // A zero-worker pool with a queued detached task: after the
        // pool drops, a helper must return immediately and must NOT run
        // the stale ticket.
        let pool = ThreadPool::new(1);
        let helper = pool.helper();
        let ran = Arc::new(AtomicBool::new(false));
        let r = ran.clone();
        pool.spawn(move || r.store(true, Ordering::SeqCst));
        drop(pool);
        let never = AtomicBool::new(false);
        helper.help_until(&never); // must return despite the unset flag
        assert!(!ran.load(Ordering::SeqCst), "stale ticket ran after pool shutdown");
        assert!(!helper.try_help_one());
    }

    #[test]
    fn parked_helper_exits_when_pool_drops() {
        let _g = test_guard();
        let pool = ThreadPool::new(1);
        let helper = pool.helper();
        let h = std::thread::spawn(move || {
            let never = AtomicBool::new(false);
            helper.help_until(&never);
        });
        std::thread::sleep(Duration::from_millis(30));
        drop(pool);
        h.join().expect("parked helper must exit when the pool shuts down");
    }
}
