//! Persistent shared-memory thread-pool runtime.
//!
//! The original parallel substrate ([`crate::util::par`]) spawns fresh
//! OS threads via `std::thread::scope` on *every* call, so one
//! `mitigate()` run pays fork-join startup five-plus times (steps A–E)
//! and each SZp/SZ3 block decompression pays it again. This module
//! replaces that with a **persistent pool**: workers are spawned once
//! (lazily, for the [`global`] pool) and then parked on a condition
//! variable; each parallel region is published as a heap-allocated
//! ticket that woken workers *and the calling thread* drain
//! cooperatively through an atomic work cursor (self-scheduling — the
//! pool-level analog of OpenMP `schedule(dynamic)` work stealing).
//!
//! Guarantees relied on throughout the crate:
//!
//! * **Drop-in semantics** — [`chunks_mut`] / [`for_range`] /
//!   [`for_batches`] take the same `(…, threads, …)` arguments and use
//!   the same work decomposition as the `util::par` free functions, so
//!   outputs are bit-identical to both the fork-join implementation and
//!   the sequential path (every call site writes disjoint data, making
//!   results schedule-independent). One deliberate divergence: actual
//!   concurrency is capped at the pool's lane count — `threads` beyond
//!   that changes only the work decomposition, not the OS-thread count
//!   (the fork-join code really spawned `threads` threads). Outputs are
//!   unaffected; for true oversubscription experiments size an explicit
//!   [`ThreadPool::new`] or set `QAI_POOL_THREADS`.
//! * **`threads == 1` is free** — the sequential path runs inline with
//!   zero synchronization, preserving profiling baselines and the
//!   default `MitigationConfig` behavior exactly.
//! * **Zero steady-state spawns** — after the pool is warm, parallel
//!   regions spawn no OS threads ([`os_thread_spawns`] exposes the
//!   counter so tests can assert this).
//! * **Nesting is safe** — a worker executing a task may itself open a
//!   parallel region (the batched [`crate::mitigation::service`] does
//!   exactly this). The opener always participates in its own region,
//!   so progress never depends on other workers being free.
//!
//! Mutually-blocking task sets (the coordinator's simulated-MPI ranks,
//! which block in `recv` on each other) must *not* share pool lanes —
//! that can deadlock when tasks outnumber workers. [`scope_blocking`]
//! is the explicit escape hatch: dedicated scoped threads, counted by
//! the same spawn counter.

use crate::util::par::UnsafeSlice;
use std::collections::VecDeque;
use std::ops::Range;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

/// Global count of OS threads ever spawned by this module (pool workers
/// plus [`scope_blocking`] threads). Tests use it to assert that warm
/// parallel regions spawn nothing.
static OS_THREAD_SPAWNS: AtomicUsize = AtomicUsize::new(0);

/// Total OS threads spawned through the pool runtime so far.
pub fn os_thread_spawns() -> usize {
    OS_THREAD_SPAWNS.load(Ordering::SeqCst)
}

/// One published parallel region. Workers and the caller claim batches
/// of the index space `0..n` through `cursor`; the caller blocks until
/// every participant has left the body, then closes the region so late
/// tickets (still queued behind other regions) become no-ops without
/// ever touching the by-then-dead closure pointer.
struct Region {
    /// Type-erased `&F` living on the caller's stack; valid until the
    /// region is closed.
    ctx: *const (),
    /// Monomorphized trampoline: `call(ctx, start, end)` runs the body
    /// over one claimed batch.
    call: unsafe fn(*const (), usize, usize),
    /// Index-space extent.
    n: usize,
    /// Batch size per claim.
    grain: usize,
    /// Next unclaimed index.
    cursor: AtomicUsize,
    /// Participation bookkeeping (see `run_ticket` / `dispatch`).
    state: Mutex<RegionState>,
    /// Signaled when the last participant leaves the body.
    done: Condvar,
    /// First panic payload raised inside the body, re-raised by the
    /// caller after the region quiesces (fork-join parity).
    panic_payload: Mutex<Option<Box<dyn std::any::Any + Send>>>,
}

struct RegionState {
    /// Participants currently inside the body.
    in_flight: usize,
    /// Set by the caller once the region is complete; late tickets must
    /// not enter.
    closed: bool,
}

// SAFETY: `ctx` is only dereferenced between a successful `try_enter`
// and the matching exit, and the caller keeps the referent alive until
// the region is closed with no participant in flight.
unsafe impl Send for Region {}
unsafe impl Sync for Region {}

/// Sentinel the cursor jumps to when a participant panics, so remaining
/// batches are abandoned. Far above any real `n`, with headroom so
/// racing `fetch_add(grain)` increments cannot wrap.
const CANCELLED: usize = usize::MAX / 2;

impl Region {
    /// Drain batches until the index space is exhausted. Panics inside
    /// the body are captured into `panic_payload` (first wins) and
    /// cancel the remaining work.
    fn drain(&self) {
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| loop {
            let start = self.cursor.fetch_add(self.grain, Ordering::Relaxed);
            if start >= self.n {
                break;
            }
            let end = (start + self.grain).min(self.n);
            // SAFETY: the caller guarantees `ctx` outlives the open
            // region, and `call` is the trampoline monomorphized for
            // the same closure type.
            unsafe { (self.call)(self.ctx, start, end) };
        }));
        if let Err(payload) = result {
            self.cursor.store(CANCELLED, Ordering::Relaxed);
            let mut slot = self.panic_payload.lock().unwrap();
            if slot.is_none() {
                *slot = Some(payload);
            }
        }
    }

    /// Worker-side entry: enter (unless closed), drain, exit, and wake
    /// the caller when this was the last participant.
    fn run_ticket(&self) {
        {
            let mut st = self.state.lock().unwrap();
            if st.closed {
                return;
            }
            st.in_flight += 1;
        }
        self.drain();
        let mut st = self.state.lock().unwrap();
        st.in_flight -= 1;
        if st.in_flight == 0 {
            self.done.notify_all();
        }
    }
}

/// Shared worker state: a FIFO of region tickets plus shutdown flag.
struct Injector {
    queue: Mutex<VecDeque<Arc<Region>>>,
    ready: Condvar,
    shutdown: AtomicBool,
}

fn worker_loop(injector: Arc<Injector>) {
    loop {
        let region = {
            let mut q = injector.queue.lock().unwrap();
            loop {
                if injector.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                if let Some(r) = q.pop_front() {
                    break r;
                }
                q = injector.ready.wait(q).unwrap();
            }
        };
        region.run_ticket();
    }
}

/// A persistent worker pool sized for `lanes`-way parallelism (the
/// caller of a parallel region is always one lane, so `lanes - 1`
/// workers are spawned). [`ThreadPool::new`] exists for explicit sizing
/// — e.g. the Fig. 8 thread sweep — while most code uses [`global`].
pub struct ThreadPool {
    injector: Arc<Injector>,
    lanes: usize,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl ThreadPool {
    /// Build a pool for `lanes`-way parallelism (`lanes >= 1`); spawns
    /// `lanes - 1` persistent workers immediately.
    pub fn new(lanes: usize) -> Self {
        let lanes = lanes.max(1);
        let injector = Arc::new(Injector {
            queue: Mutex::new(VecDeque::new()),
            ready: Condvar::new(),
            shutdown: AtomicBool::new(false),
        });
        let handles = (0..lanes - 1)
            .map(|w| {
                OS_THREAD_SPAWNS.fetch_add(1, Ordering::SeqCst);
                let inj = injector.clone();
                std::thread::Builder::new()
                    .name(format!("qai-pool-{w}"))
                    .spawn(move || worker_loop(inj))
                    .expect("spawn pool worker")
            })
            .collect();
        ThreadPool { injector, lanes, handles }
    }

    /// Maximum useful parallelism of this pool (workers + caller).
    pub fn lanes(&self) -> usize {
        self.lanes
    }

    /// Publish a region over `0..n` with the given `grain`, offer up to
    /// `extra` tickets to the workers, participate, and block until the
    /// region quiesces. Re-raises the first panic from the body.
    fn dispatch<F>(&self, n: usize, grain: usize, extra: usize, body: &F)
    where
        F: Fn(usize, usize) + Sync,
    {
        unsafe fn trampoline<F: Fn(usize, usize)>(ctx: *const (), start: usize, end: usize) {
            (*(ctx as *const F))(start, end);
        }
        let region = Arc::new(Region {
            ctx: body as *const F as *const (),
            call: trampoline::<F>,
            n,
            grain: grain.max(1),
            cursor: AtomicUsize::new(0),
            state: Mutex::new(RegionState { in_flight: 0, closed: false }),
            done: Condvar::new(),
            panic_payload: Mutex::new(None),
        });
        let extra = extra.min(self.lanes.saturating_sub(1));
        if extra > 0 {
            let mut q = self.injector.queue.lock().unwrap();
            for _ in 0..extra {
                q.push_back(region.clone());
            }
            drop(q);
            self.injector.ready.notify_all();
        }

        // The caller is always a participant: even with every worker
        // busy (or zero workers), the region completes.
        region.drain();

        let mut st = region.state.lock().unwrap();
        while st.in_flight > 0 {
            st = region.done.wait(st).unwrap();
        }
        st.closed = true;
        drop(st);

        if let Some(payload) = region.panic_payload.lock().unwrap().take() {
            std::panic::resume_unwind(payload);
        }
    }

    /// Process `data` in `threads` contiguous chunks, calling
    /// `f(chunk_start_index, chunk)` on each — drop-in for
    /// [`crate::util::par::parallel_chunks_mut`] (identical chunk
    /// decomposition, balanced to within one element).
    pub fn chunks_mut<T: Send, F>(&self, data: &mut [T], threads: usize, f: F)
    where
        F: Fn(usize, &mut [T]) + Sync,
    {
        let n = data.len();
        if threads <= 1 || n < 2 {
            f(0, data);
            return;
        }
        let chunks = threads.min(n);
        let base = n / chunks;
        let extra = n % chunks;
        let slice = UnsafeSlice::new(data);
        let body = |lo: usize, hi: usize| {
            for c in lo..hi {
                let start = c * base + c.min(extra);
                let len = base + usize::from(c < extra);
                // SAFETY: chunk index ranges are disjoint by construction.
                let chunk = unsafe { slice.slice_mut(start, len) };
                f(start, chunk);
            }
        };
        self.dispatch(chunks, 1, chunks - 1, &body);
    }

    /// Self-scheduled loop over `0..n` claiming `grain` indices at a
    /// time — drop-in for [`crate::util::par::parallel_for_range`].
    pub fn for_range<F>(&self, n: usize, threads: usize, grain: usize, f: F)
    where
        F: Fn(usize) + Sync,
    {
        if threads <= 1 || n <= grain {
            for i in 0..n {
                f(i);
            }
            return;
        }
        let body = |lo: usize, hi: usize| {
            for i in lo..hi {
                f(i);
            }
        };
        self.dispatch(n, grain.max(1), threads.min(n) - 1, &body);
    }

    /// Like [`ThreadPool::for_range`] but hands the body whole
    /// contiguous batches, so per-batch scratch (e.g. the EDT's Voronoi
    /// stacks) is allocated once per batch — drop-in for
    /// [`crate::util::par::parallel_for_batches`].
    pub fn for_batches<F>(&self, n: usize, threads: usize, grain: usize, f: F)
    where
        F: Fn(Range<usize>) + Sync,
    {
        let grain = grain.max(1);
        if threads <= 1 || n <= grain {
            if n > 0 {
                f(0..n);
            }
            return;
        }
        let body = |lo: usize, hi: usize| f(lo..hi);
        self.dispatch(n, grain, threads.min(n.div_ceil(grain)) - 1, &body);
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.injector.shutdown.store(true, Ordering::SeqCst);
        // Take the queue lock so no worker is between the shutdown
        // check and the wait when we notify.
        drop(self.injector.queue.lock().unwrap());
        self.injector.ready.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Lane count for the global pool: `QAI_POOL_THREADS` if set, else the
/// host's available parallelism floored at 8, so the Fig. 8-style
/// thread sweeps (≤ 8) and the batched service get real concurrency
/// even on small CI hosts (parked workers are nearly free).
fn default_lanes() -> usize {
    if let Ok(v) = std::env::var("QAI_POOL_THREADS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).max(8)
}

/// The process-wide pool, created on first use. Workers persist for the
/// life of the process (the pool is never dropped).
pub fn global() -> &'static ThreadPool {
    static POOL: OnceLock<ThreadPool> = OnceLock::new();
    POOL.get_or_init(|| ThreadPool::new(default_lanes()))
}

/// Useful parallelism of the global pool.
pub fn parallelism() -> usize {
    global().lanes()
}

/// [`ThreadPool::chunks_mut`] on the global pool.
pub fn chunks_mut<T: Send, F>(data: &mut [T], threads: usize, f: F)
where
    F: Fn(usize, &mut [T]) + Sync,
{
    if threads <= 1 || data.len() < 2 {
        // Fast path that never touches (or initializes) the pool.
        f(0, data);
        return;
    }
    global().chunks_mut(data, threads, f)
}

/// [`ThreadPool::for_range`] on the global pool.
pub fn for_range<F>(n: usize, threads: usize, grain: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    if threads <= 1 || n <= grain {
        for i in 0..n {
            f(i);
        }
        return;
    }
    global().for_range(n, threads, grain, f)
}

/// [`ThreadPool::for_batches`] on the global pool.
pub fn for_batches<F>(n: usize, threads: usize, grain: usize, f: F)
where
    F: Fn(Range<usize>) + Sync,
{
    let grain = grain.max(1);
    if threads <= 1 || n <= grain {
        if n > 0 {
            f(0..n);
        }
        return;
    }
    global().for_batches(n, threads, grain, f)
}

/// Run a set of **mutually-blocking** tasks to completion, one
/// dedicated scoped thread each (single tasks run inline). This exists
/// for the coordinator's simulated-MPI ranks, which block in `recv` on
/// one another: multiplexing such tasks onto a bounded worker set can
/// deadlock, so they must not share pool lanes. Spawns are counted by
/// [`os_thread_spawns`].
pub fn scope_blocking<'env, T, F>(tasks: Vec<F>) -> Vec<T>
where
    T: Send + 'env,
    F: FnOnce() -> T + Send + 'env,
{
    if tasks.len() <= 1 {
        return tasks.into_iter().map(|t| t()).collect();
    }
    std::thread::scope(|s| {
        let handles: Vec<_> = tasks
            .into_iter()
            .map(|t| {
                OS_THREAD_SPAWNS.fetch_add(1, Ordering::SeqCst);
                s.spawn(t)
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("blocking task panicked")).collect()
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The spawn counter is process-global, so tests that construct
    /// pools (or assert on the counter) are serialized to keep the
    /// counter assertions race-free under the parallel test harness.
    fn test_guard() -> std::sync::MutexGuard<'static, ()> {
        static GUARD: Mutex<()> = Mutex::new(());
        GUARD.lock().unwrap_or_else(|e| e.into_inner())
    }

    #[test]
    fn chunks_cover_everything_once() {
        let _g = test_guard();
        let pool = ThreadPool::new(4);
        for threads in [1, 2, 3, 7, 16] {
            let mut v = vec![0u32; 1000];
            pool.chunks_mut(&mut v, threads, |start, chunk| {
                for (k, x) in chunk.iter_mut().enumerate() {
                    *x = (start + k) as u32 + 1;
                }
            });
            for (i, x) in v.iter().enumerate() {
                assert_eq!(*x, i as u32 + 1, "threads={threads} i={i}");
            }
        }
    }

    #[test]
    fn chunk_decomposition_matches_forkjoin_exactly() {
        let _g = test_guard();
        // Same (start, len) pairs as util::par::parallel_chunks_mut.
        let pool = ThreadPool::new(3);
        for (n, threads) in [(10, 3), (1000, 7), (5, 8), (2, 2)] {
            let mut v = vec![0usize; n];
            let seen = Mutex::new(Vec::new());
            pool.chunks_mut(&mut v, threads, |start, chunk| {
                seen.lock().unwrap().push((start, chunk.len()));
            });
            let mut got = seen.into_inner().unwrap();
            got.sort_unstable();
            let mut want = Vec::new();
            let chunks = threads.min(n);
            let base = n / chunks;
            let extra = n % chunks;
            let mut start = 0;
            for c in 0..chunks {
                let len = base + usize::from(c < extra);
                want.push((start, len));
                start += len;
            }
            assert_eq!(got, want, "n={n} threads={threads}");
        }
    }

    #[test]
    fn for_range_visits_each_index_once() {
        let _g = test_guard();
        let pool = ThreadPool::new(4);
        for threads in [1, 2, 4, 8, 64] {
            let n = 5000;
            let mut out = vec![0u32; n];
            let s = UnsafeSlice::new(&mut out);
            pool.for_range(n, threads, 64, |i| unsafe { s.write(i, i as u32 * 3) });
            for (i, x) in out.iter().enumerate() {
                assert_eq!(*x, i as u32 * 3);
            }
        }
    }

    #[test]
    fn for_batches_covers_range_disjointly() {
        let _g = test_guard();
        let pool = ThreadPool::new(4);
        for (n, grain) in [(0usize, 4usize), (1, 4), (97, 4), (4096, 16), (10, 100)] {
            let counts: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
            pool.for_batches(n, 5, grain, |r| {
                for i in r {
                    counts[i].fetch_add(1, Ordering::Relaxed);
                }
            });
            for (i, c) in counts.iter().enumerate() {
                assert_eq!(c.load(Ordering::Relaxed), 1, "n={n} grain={grain} i={i}");
            }
        }
    }

    #[test]
    fn single_thread_requests_run_inline() {
        let _g = test_guard();
        // threads == 1 must work even on a zero-worker pool.
        let pool = ThreadPool::new(1);
        let mut v = vec![0u8; 16];
        pool.chunks_mut(&mut v, 1, |_, c| c.iter_mut().for_each(|x| *x = 1));
        assert!(v.iter().all(|&x| x == 1));
        pool.for_range(8, 1, 2, |_| {});
        pool.for_batches(8, 1, 2, |_| {});
    }

    #[test]
    fn zero_worker_pool_still_completes_parallel_requests() {
        let _g = test_guard();
        let pool = ThreadPool::new(1);
        let n = 257;
        let mut out = vec![0u32; n];
        let s = UnsafeSlice::new(&mut out);
        pool.for_range(n, 8, 4, |i| unsafe { s.write(i, 7) });
        assert!(out.iter().all(|&x| x == 7));
    }

    #[test]
    fn nested_regions_complete() {
        let _g = test_guard();
        // A region body that itself opens a region — the service's
        // batch-over-pipelines shape.
        let pool = ThreadPool::new(3);
        let outer = 6usize;
        let inner = 64usize;
        let hits = AtomicUsize::new(0);
        pool.for_range(outer, 3, 1, |_| {
            pool.for_range(inner, 3, 8, |_| {
                hits.fetch_add(1, Ordering::Relaxed);
            });
        });
        assert_eq!(hits.load(Ordering::Relaxed), outer * inner);
    }

    #[test]
    fn warm_pool_spawns_nothing_per_region() {
        let _g = test_guard();
        let pool = ThreadPool::new(4);
        pool.for_range(128, 4, 8, |_| {}); // warm-up
        let before = os_thread_spawns();
        for _ in 0..50 {
            pool.for_range(512, 4, 8, |i| {
                std::hint::black_box(i);
            });
            let mut v = vec![0u8; 256];
            pool.chunks_mut(&mut v, 4, |_, c| c.iter_mut().for_each(|x| *x = 1));
            pool.for_batches(256, 4, 16, |r| {
                std::hint::black_box(r.len());
            });
        }
        assert_eq!(os_thread_spawns(), before, "warm regions must not spawn OS threads");
    }

    #[test]
    fn body_panic_propagates_to_caller() {
        let _g = test_guard();
        let pool = ThreadPool::new(3);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.for_range(100, 3, 1, |i| {
                if i == 42 {
                    panic!("boom at {i}");
                }
            });
        }));
        let payload = result.expect_err("panic must propagate");
        let msg = payload.downcast_ref::<String>().cloned().unwrap_or_default();
        assert!(msg.contains("boom"), "msg={msg}");
        // The pool must stay usable after a panicked region.
        let count = AtomicUsize::new(0);
        pool.for_range(10, 3, 1, |_| {
            count.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(count.load(Ordering::Relaxed), 10);
    }

    #[test]
    fn scope_blocking_runs_mutually_dependent_tasks() {
        let _g = test_guard();
        use std::sync::mpsc::channel;
        let (tx_a, rx_a) = channel::<u32>();
        let (tx_b, rx_b) = channel::<u32>();
        let t1 = move || {
            tx_b.send(1).unwrap();
            rx_a.recv().unwrap()
        };
        let t2 = move || {
            tx_a.send(2).unwrap();
            rx_b.recv().unwrap()
        };
        let boxed: Vec<Box<dyn FnOnce() -> u32 + Send>> = vec![Box::new(t1), Box::new(t2)];
        let got = scope_blocking(boxed);
        assert_eq!(got, vec![2, 1]);
    }

    #[test]
    fn explicit_pool_drop_joins_workers() {
        let _g = test_guard();
        let before = os_thread_spawns();
        {
            let pool = ThreadPool::new(3);
            pool.for_range(64, 3, 4, |_| {});
        } // drop: workers must exit cleanly
        assert!(os_thread_spawns() >= before + 2);
    }

    #[test]
    fn global_pool_free_functions_work() {
        let _g = test_guard();
        let n = 1000;
        let mut out = vec![0u32; n];
        let s = UnsafeSlice::new(&mut out);
        for_range(n, 4, 32, |i| unsafe { s.write(i, i as u32) });
        for (i, x) in out.iter().enumerate() {
            assert_eq!(*x, i as u32);
        }
        assert!(parallelism() >= 1);
    }
}
