//! Deterministic pseudo-random number generation.
//!
//! A small, fast, seedable PRNG (xoshiro256**) used by the synthetic
//! dataset generators and the property-test driver. Determinism matters:
//! every experiment in EXPERIMENTS.md is reproducible from its seed.

/// xoshiro256** by Blackman & Vigna — public domain reference algorithm.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

/// SplitMix64, used to seed xoshiro from a single u64.
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform f64 in `[lo, hi)`.
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in `[0, n)`. `n` must be > 0.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style rejection-free multiply-shift is fine for tests
        // and generators (tiny modulo bias is irrelevant here).
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fill a slice with uniform values in `[lo, hi)`.
    pub fn fill_uniform(&mut self, out: &mut [f32], lo: f32, hi: f32) {
        for v in out.iter_mut() {
            *v = lo + (hi - lo) * self.f32();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(4);
        for _ in 0..10_000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn uniform_mean_roughly_half() {
        let mut r = Rng::new(5);
        let mean: f64 = (0..100_000).map(|_| r.f64()).sum::<f64>() / 100_000.0;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(6);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }
}
