//! # QAI — Quantization-Aware Interpolation for artifact mitigation
//!
//! Production-grade reproduction of *"Mitigating Artifacts in
//! Pre-quantization Based Scientific Data Compressors with
//! Quantization-aware Interpolation"* (CS.DC 2026).
//!
//! Pre-quantization based error-bounded lossy compressors (cuSZ, cuSZp2,
//! SZp, FZ-GPU, ...) quantize the input *first* — `q = round(d / 2ε)` —
//! which makes every later stage lossless and massively parallel, but
//! leaves posterization (banding) artifacts in the decompressed data at
//! medium/large error bounds. This crate implements the paper's
//! post-decompression mitigation algorithm and every substrate it needs:
//!
//! * [`quant`] — the pre-quantization transform itself (abs and
//!   value-range-relative error bounds);
//! * [`compressors`] — faithful CPU pipelines of the compressors the
//!   paper evaluates (cuSZ-like, cuSZp2-like, SZp, simplified SZ3) plus
//!   the bit-level codecs they need (Huffman, bit I/O, Lorenzo);
//! * [`mitigation`] — the paper's contribution: quantization-boundary
//!   detection (Alg. 2), exact linear-time Euclidean distance transform
//!   with feature transform (Alg. 1, Maurer et al.), sign propagation
//!   (Alg. 3) and inverse-distance-weighted error compensation (Alg. 4),
//!   sequential and multi-threaded, plus
//!   [`mitigation::engine`] — the **one front door** for running it: a
//!   typed [`MitigationRequest`](mitigation::engine::MitigationRequest)
//!   → [`Engine`](mitigation::engine::Engine) request/response API over
//!   sharded bounded admission queues ([`mitigation::admission`]) with
//!   backpressure, priority classes + EDF dispatch, completion tickets,
//!   deadline accounting, consistent-hash tenant routing, and
//!   per-tenant admission quotas — the `qai batch` and `qai serve` CLI
//!   subcommands (the legacy
//!   [`MitigationService`](mitigation::service::MitigationService) and
//!   `mitigate*` free functions remain as deprecated bit-identical
//!   wrappers);
//! * [`filters`] — the Gaussian / uniform / Wiener baselines of §VIII;
//! * [`metrics`] — SSIM (QCAT convention), PSNR, max-error, bit-rate;
//! * [`coordinator`] — the distributed-memory runtime with the paper's
//!   three parallelization strategies, written against the cluster's
//!   pluggable transport (in-process loopback or real sockets);
//! * [`cluster`] — multi-process shards over a pluggable
//!   [`Transport`](cluster::transport::Transport): length-prefixed
//!   wire codec with typed errors ([`cluster::wire`]), rendezvous
//!   (HRW) tenant → node routing ([`cluster::registry`]),
//!   remote-addressable engine shards with `--listen`/`--join`
//!   ([`cluster::node`]), and real forked multi-process fig9/fig11
//!   runs ([`cluster::procs`]);
//! * [`runtime`] — the PJRT bridge that loads the AOT-compiled JAX/Pallas
//!   artifacts (`artifacts/*.hlo.txt`) and runs them from the Rust hot
//!   path (Python is build-time only);
//! * [`data`] — grid types, synthetic dataset analogs, raw f32 I/O;
//! * [`bench_support`] — the offline criterion-like bench harness used by
//!   the per-figure/table benches;
//! * [`util`] — offline substrates, including [`util::pool`], the
//!   persistent **work-stealing** thread-pool runtime all
//!   shared-memory parallelism runs on: per-worker LIFO deques with
//!   randomized stealing, a FIFO injector for external submissions,
//!   and cooperative blocking (every blocked thread — region openers,
//!   `scope_blocking` callers, the admission scheduler — runs queued
//!   tickets while it waits, counter-proven via
//!   [`util::pool::ThreadPool::counters`]); `threads == 1` stays a
//!   zero-overhead inline path, warm parallel regions spawn no OS
//!   threads, and [`util::pool::PoolHandle`] selects which pool a
//!   region opens on. [`util::arena`] is the size-classed
//!   scratch-buffer arena the zero-copy data plane recycles every
//!   full-grid buffer through (warm same-shaped jobs allocate nothing,
//!   counter-proven), with [`util::arena::ArenaHandle`] selecting it
//!   per call, [`util::arena::ArenaLease`] keeping the accounting
//!   exact across panics, and [`data::grid::SharedGrid`] making job
//!   payloads `Arc`-shared.
//!
//! ## Guides
//!
//! * `docs/ARCHITECTURE.md` — top-to-bottom tour (data → quant →
//!   compressors → mitigation steps A–E → service/admission →
//!   coordinator) with the data-flow diagram and module pointers.
//! * `docs/SERVING.md` — operator guide for the admission queue:
//!   capacity sizing, backpressure semantics, priority classes,
//!   deadline stats, and CLI usage.
//! * `ROADMAP.md` — north star and open items.
//!
//! ## Quickstart
//!
//! ```no_run
//! use qai::data::synthetic::{DatasetKind, generate};
//! use qai::quant::ErrorBound;
//! use qai::compressors::{Compressor, cusz::CuszLike};
//! use qai::mitigation::engine::{self, MitigationRequest};
//! use qai::metrics::ssim::ssim;
//! use qai::SharedGrid;
//!
//! let field = generate(DatasetKind::ClimateLike, &[256, 256], 42);
//! let eb = ErrorBound::relative(1e-2).resolve(&field.data);
//! let codec = CuszLike::default();
//! let compressed = codec.compress(&field, eb).unwrap();
//! let decoded = codec.decompress(&compressed).unwrap();
//! // Zero-copy handle so the decoded field survives for the metrics.
//! let dq: SharedGrid<f32> = decoded.grid.into();
//! let request = MitigationRequest::new(dq.clone(), decoded.quant_indices, eb);
//! let fixed = engine::execute(&request).unwrap().output;
//! let before = ssim(&field, &dq, 7, 2);
//! let after = ssim(&field, &fixed, 7, 2);
//! assert!(after >= before);
//! ```

pub mod bench_support;
pub mod cli;
pub mod cluster;
pub mod compressors;
pub mod coordinator;
pub mod data;
pub mod filters;
pub mod metrics;
pub mod mitigation;
pub mod quant;
pub mod runtime;
pub mod util;

pub use data::grid::{Grid, SharedGrid};
pub use quant::{ErrorBound, ResolvedBound};
