//! Hand-rolled CLI argument parsing (no `clap` offline — DESIGN.md §10).
//!
//! Supports `--flag value`, `--flag=value` and boolean `--flag` /
//! `--flag=false` forms, plus positional arguments, with typed
//! accessors and an unknown-flag check that fails loudly and suggests
//! the nearest valid key for likely typos.

use anyhow::{Context, Result};
use std::collections::BTreeMap;

/// Parsed command line: subcommand, flags, positionals.
#[derive(Debug, Default)]
pub struct Args {
    /// First non-flag token (the subcommand), if any.
    pub command: Option<String>,
    flags: BTreeMap<String, String>,
    positional: Vec<String>,
    consumed: std::cell::RefCell<Vec<String>>,
}

impl Args {
    /// Parse from an iterator of tokens (exclude argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(tokens: I) -> Result<Self> {
        let mut args = Args::default();
        let mut iter = tokens.into_iter().peekable();
        while let Some(tok) = iter.next() {
            if let Some(rest) = tok.strip_prefix("--") {
                anyhow::ensure!(!rest.is_empty(), "bare `--` is not supported");
                if let Some((k, v)) = rest.split_once('=') {
                    args.flags.insert(k.to_string(), v.to_string());
                } else if iter.peek().is_some_and(|next| !next.starts_with("--")) {
                    let v = iter.next().unwrap();
                    args.flags.insert(rest.to_string(), v);
                } else {
                    args.flags.insert(rest.to_string(), "true".to_string());
                }
            } else if args.command.is_none() {
                args.command = Some(tok);
            } else {
                args.positional.push(tok);
            }
        }
        Ok(args)
    }

    /// String flag.
    pub fn get(&self, key: &str) -> Option<String> {
        self.consumed.borrow_mut().push(key.to_string());
        self.flags.get(key).cloned()
    }

    /// String flag with default.
    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or_else(|| default.to_string())
    }

    /// Required string flag.
    pub fn require(&self, key: &str) -> Result<String> {
        self.get(key).with_context(|| format!("missing required flag --{key}"))
    }

    /// Typed flag with default.
    pub fn get_parse<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(key) {
            None => Ok(default),
            Some(s) => s.parse::<T>().map_err(|e| anyhow::anyhow!("--{key} {s}: {e}")),
        }
    }

    /// Boolean flag: absent → `false`; bare `--flag` or
    /// `--flag=true|1|yes` → `true`; `--flag=false|0|no` → `false`;
    /// any other value is an error (it used to be silently `false`,
    /// hiding typos like `--metrics=ture`).
    pub fn get_bool(&self, key: &str) -> Result<bool> {
        match self.get(key).as_deref() {
            None => Ok(false),
            Some("true" | "1" | "yes") => Ok(true),
            Some("false" | "0" | "no") => Ok(false),
            Some(other) => {
                anyhow::bail!("--{key} expects a boolean (true/false/1/0/yes/no), got {other:?}")
            }
        }
    }

    /// Positional arguments.
    pub fn positionals(&self) -> &[String] {
        &self.positional
    }

    /// Error on flags nobody consumed (call after all `get*`s), with a
    /// nearest-valid-key suggestion for likely typos.
    pub fn finish(&self) -> Result<()> {
        let consumed = self.consumed.borrow();
        let unknown: Vec<String> = self
            .flags
            .keys()
            .filter(|k| !consumed.contains(k))
            .map(|k| match nearest_key(k, &consumed) {
                Some(best) => format!("--{k} (did you mean --{best}?)"),
                None => format!("--{k}"),
            })
            .collect();
        anyhow::ensure!(unknown.is_empty(), "unknown flags: {}", unknown.join(", "));
        Ok(())
    }
}

/// The closest key the program actually looked up, if it is close
/// enough to be a plausible typo (edit distance ≤ 2 and smaller than
/// the flag's own length). Ties resolve to the lexicographically
/// smallest candidate, keeping error text deterministic.
fn nearest_key<'a>(unknown: &str, candidates: &'a [String]) -> Option<&'a str> {
    let mut best: Option<(usize, &str)> = None;
    for cand in candidates {
        let cand = cand.as_str();
        let d = edit_distance(unknown, cand);
        let better = match best {
            None => true,
            Some((bd, bc)) => d < bd || (d == bd && cand < bc),
        };
        if better {
            best = Some((d, cand));
        }
    }
    best.filter(|&(d, _)| d <= 2 && d < unknown.len()).map(|(_, c)| c)
}

/// Plain Levenshtein distance, small inputs only (flag names).
fn edit_distance(a: &str, b: &str) -> usize {
    let a: Vec<char> = a.chars().collect();
    let b: Vec<char> = b.chars().collect();
    let mut prev: Vec<usize> = (0..=b.len()).collect();
    let mut cur = vec![0usize; b.len() + 1];
    for (i, &ca) in a.iter().enumerate() {
        cur[0] = i + 1;
        for (j, &cb) in b.iter().enumerate() {
            let sub = prev[j] + usize::from(ca != cb);
            cur[j + 1] = sub.min(prev[j + 1] + 1).min(cur[j] + 1);
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[b.len()]
}

/// Parse a `AxBxC` dims string into a dims vector.
pub fn parse_dims(s: &str) -> Result<Vec<usize>> {
    let dims: Vec<usize> = s
        .split(['x', 'X', ','])
        .map(|t| t.trim().parse::<usize>().with_context(|| format!("bad dim {t:?}")))
        .collect::<Result<_>>()?;
    anyhow::ensure!((1..=3).contains(&dims.len()), "need 1-3 dims, got {}", dims.len());
    anyhow::ensure!(dims.iter().all(|&d| d > 0), "dims must be positive");
    Ok(dims)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(tokens: &[&str]) -> Args {
        Args::parse(tokens.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse(&["compress", "--rel", "1e-3", "--codec=cusz", "--verbose"]);
        assert_eq!(a.command.as_deref(), Some("compress"));
        assert_eq!(a.get("rel").as_deref(), Some("1e-3"));
        assert_eq!(a.get("codec").as_deref(), Some("cusz"));
        assert!(a.get_bool("verbose").unwrap());
        a.finish().unwrap();
    }

    #[test]
    fn bool_flags_accept_explicit_values() {
        let a = parse(&["x", "--metrics=false", "--verbose=true", "--quiet=no"]);
        assert!(!a.get_bool("metrics").unwrap());
        assert!(a.get_bool("verbose").unwrap());
        assert!(!a.get_bool("quiet").unwrap());
        assert!(!a.get_bool("absent").unwrap());
        a.finish().unwrap();
    }

    #[test]
    fn bool_flag_rejects_garbage_values() {
        let a = parse(&["x", "--metrics=ture"]);
        let err = a.get_bool("metrics").unwrap_err().to_string();
        assert!(err.contains("expects a boolean"), "err={err}");
    }

    #[test]
    fn typed_and_defaults() {
        let a = parse(&["x", "--threads", "8"]);
        assert_eq!(a.get_parse::<usize>("threads", 1).unwrap(), 8);
        assert_eq!(a.get_parse::<f64>("eta", 0.9).unwrap(), 0.9);
        assert_eq!(a.get_or("codec", "cusz"), "cusz");
    }

    #[test]
    fn unknown_flags_detected() {
        let a = parse(&["x", "--oops", "1"]);
        let _ = a.get("threads");
        assert!(a.finish().is_err());
    }

    #[test]
    fn unknown_flag_suggests_the_nearest_valid_key() {
        let a = parse(&["x", "--thread", "4"]);
        let _ = a.get("threads");
        let _ = a.get("capacity");
        let err = a.finish().unwrap_err().to_string();
        assert!(
            err.contains("--thread (did you mean --threads?)"),
            "err={err}"
        );

        // Far-off garbage gets no suggestion.
        let b = parse(&["x", "--zzzzzzzz", "1"]);
        let _ = b.get("threads");
        let err = b.finish().unwrap_err().to_string();
        assert!(err.contains("--zzzzzzzz"), "err={err}");
        assert!(!err.contains("did you mean"), "err={err}");
    }

    #[test]
    fn edit_distance_basics() {
        assert_eq!(edit_distance("", ""), 0);
        assert_eq!(edit_distance("abc", "abc"), 0);
        assert_eq!(edit_distance("abc", "abd"), 1);
        assert_eq!(edit_distance("thread", "threads"), 1);
        assert_eq!(edit_distance("kitten", "sitting"), 3);
    }

    #[test]
    fn missing_required_flag_errors() {
        let a = parse(&["x"]);
        assert!(a.require("input").is_err());
    }

    #[test]
    fn dims_parser() {
        assert_eq!(parse_dims("512x512").unwrap(), vec![512, 512]);
        assert_eq!(parse_dims("100,500,500").unwrap(), vec![100, 500, 500]);
        assert!(parse_dims("1x2x3x4").is_err());
        assert!(parse_dims("0x5").is_err());
        assert!(parse_dims("axb").is_err());
    }

    #[test]
    fn positionals_collected() {
        let a = parse(&["run", "file1", "file2", "--k", "v"]);
        assert_eq!(a.positionals(), &["file1".to_string(), "file2".to_string()]);
    }
}
