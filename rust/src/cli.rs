//! Hand-rolled CLI argument parsing (no `clap` offline — DESIGN.md §10).
//!
//! Supports `--flag value`, `--flag=value` and boolean `--flag` forms,
//! plus positional arguments, with typed accessors and an
//! unknown-flag check so typos fail loudly.

use anyhow::{Context, Result};
use std::collections::BTreeMap;

/// Parsed command line: subcommand, flags, positionals.
#[derive(Debug, Default)]
pub struct Args {
    /// First non-flag token (the subcommand), if any.
    pub command: Option<String>,
    flags: BTreeMap<String, String>,
    positional: Vec<String>,
    consumed: std::cell::RefCell<Vec<String>>,
}

impl Args {
    /// Parse from an iterator of tokens (exclude argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(tokens: I) -> Result<Self> {
        let mut args = Args::default();
        let mut iter = tokens.into_iter().peekable();
        while let Some(tok) = iter.next() {
            if let Some(rest) = tok.strip_prefix("--") {
                anyhow::ensure!(!rest.is_empty(), "bare `--` is not supported");
                if let Some((k, v)) = rest.split_once('=') {
                    args.flags.insert(k.to_string(), v.to_string());
                } else if iter.peek().is_some_and(|next| !next.starts_with("--")) {
                    let v = iter.next().unwrap();
                    args.flags.insert(rest.to_string(), v);
                } else {
                    args.flags.insert(rest.to_string(), "true".to_string());
                }
            } else if args.command.is_none() {
                args.command = Some(tok);
            } else {
                args.positional.push(tok);
            }
        }
        Ok(args)
    }

    /// String flag.
    pub fn get(&self, key: &str) -> Option<String> {
        self.consumed.borrow_mut().push(key.to_string());
        self.flags.get(key).cloned()
    }

    /// String flag with default.
    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or_else(|| default.to_string())
    }

    /// Required string flag.
    pub fn require(&self, key: &str) -> Result<String> {
        self.get(key).with_context(|| format!("missing required flag --{key}"))
    }

    /// Typed flag with default.
    pub fn get_parse<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(key) {
            None => Ok(default),
            Some(s) => s.parse::<T>().map_err(|e| anyhow::anyhow!("--{key} {s}: {e}")),
        }
    }

    /// Boolean flag (present or `=true`).
    pub fn get_bool(&self, key: &str) -> bool {
        matches!(self.get(key).as_deref(), Some("true") | Some("1") | Some("yes"))
    }

    /// Positional arguments.
    pub fn positionals(&self) -> &[String] {
        &self.positional
    }

    /// Error on flags nobody consumed (call after all `get*`s).
    pub fn finish(&self) -> Result<()> {
        let consumed = self.consumed.borrow();
        let unknown: Vec<&String> =
            self.flags.keys().filter(|k| !consumed.contains(k)).collect();
        anyhow::ensure!(unknown.is_empty(), "unknown flags: {unknown:?}");
        Ok(())
    }
}

/// Parse a `AxBxC` dims string into a dims vector.
pub fn parse_dims(s: &str) -> Result<Vec<usize>> {
    let dims: Vec<usize> = s
        .split(['x', 'X', ','])
        .map(|t| t.trim().parse::<usize>().with_context(|| format!("bad dim {t:?}")))
        .collect::<Result<_>>()?;
    anyhow::ensure!((1..=3).contains(&dims.len()), "need 1-3 dims, got {}", dims.len());
    anyhow::ensure!(dims.iter().all(|&d| d > 0), "dims must be positive");
    Ok(dims)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(tokens: &[&str]) -> Args {
        Args::parse(tokens.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse(&["compress", "--rel", "1e-3", "--codec=cusz", "--verbose"]);
        assert_eq!(a.command.as_deref(), Some("compress"));
        assert_eq!(a.get("rel").as_deref(), Some("1e-3"));
        assert_eq!(a.get("codec").as_deref(), Some("cusz"));
        assert!(a.get_bool("verbose"));
        a.finish().unwrap();
    }

    #[test]
    fn typed_and_defaults() {
        let a = parse(&["x", "--threads", "8"]);
        assert_eq!(a.get_parse::<usize>("threads", 1).unwrap(), 8);
        assert_eq!(a.get_parse::<f64>("eta", 0.9).unwrap(), 0.9);
        assert_eq!(a.get_or("codec", "cusz"), "cusz");
    }

    #[test]
    fn unknown_flags_detected() {
        let a = parse(&["x", "--oops", "1"]);
        let _ = a.get("threads");
        assert!(a.finish().is_err());
    }

    #[test]
    fn missing_required_flag_errors() {
        let a = parse(&["x"]);
        assert!(a.require("input").is_err());
    }

    #[test]
    fn dims_parser() {
        assert_eq!(parse_dims("512x512").unwrap(), vec![512, 512]);
        assert_eq!(parse_dims("100,500,500").unwrap(), vec![100, 500, 500]);
        assert!(parse_dims("1x2x3x4").is_err());
        assert!(parse_dims("0x5").is_err());
        assert!(parse_dims("axb").is_err());
    }

    #[test]
    fn positionals_collected() {
        let a = parse(&["run", "file1", "file2", "--k", "v"]);
        assert_eq!(a.positionals(), &["file1".to_string(), "file2".to_string()]);
    }
}
