//! Gaussian smoothing over a 3-wide window (σ = 1.0, radius 1), the
//! Table II / Fig. 5–6 "Gaussian" baseline.

use crate::data::grid::Grid;
use crate::filters::separable_filter;
use crate::util::pool::PoolHandle;

/// Discrete, normalized Gaussian taps for the given sigma and radius.
pub fn gaussian_kernel(sigma: f64, radius: usize) -> Vec<f64> {
    assert!(sigma > 0.0);
    let mut k: Vec<f64> = (-(radius as isize)..=radius as isize)
        .map(|t| (-(t as f64).powi(2) / (2.0 * sigma * sigma)).exp())
        .collect();
    let sum: f64 = k.iter().sum();
    for w in k.iter_mut() {
        *w /= sum;
    }
    k
}

/// Separable Gaussian filter with the paper's 3×3(×3) window (radius 1).
/// Sequential (the quality-baseline execution model).
pub fn gaussian_filter(grid: &Grid<f32>, sigma: f64) -> Grid<f32> {
    separable_filter(grid, &gaussian_kernel(sigma, 1), 1, PoolHandle::Global)
}

/// [`gaussian_filter`] with its convolution lines on the shared pool;
/// output is bit-identical to the sequential path.
pub fn gaussian_filter_threads(grid: &Grid<f32>, sigma: f64, threads: usize) -> Grid<f32> {
    gaussian_filter_on(PoolHandle::Global, grid, sigma, threads)
}

/// [`gaussian_filter_threads`] with its parallel regions confined to
/// `pool`.
pub fn gaussian_filter_on(
    pool: PoolHandle<'_>,
    grid: &Grid<f32>,
    sigma: f64,
    threads: usize,
) -> Grid<f32> {
    separable_filter(grid, &gaussian_kernel(sigma, 1), threads, pool)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn kernel_normalized_and_symmetric() {
        let k = gaussian_kernel(1.0, 1);
        assert_eq!(k.len(), 3);
        assert!((k.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!((k[0] - k[2]).abs() < 1e-15);
        assert!(k[1] > k[0]);
    }

    #[test]
    fn smooths_noise() {
        let mut rng = Rng::new(1);
        let n = 64;
        let noisy: Vec<f32> = (0..n * n).map(|_| rng.f32()).collect();
        let g = Grid::from_vec(noisy, &[n, n]);
        let f = gaussian_filter(&g, 1.0);
        // variance must shrink
        let var = |d: &[f32]| {
            let m = d.iter().map(|&v| v as f64).sum::<f64>() / d.len() as f64;
            d.iter().map(|&v| (v as f64 - m).powi(2)).sum::<f64>() / d.len() as f64
        };
        assert!(var(&f.data) < 0.5 * var(&g.data));
    }

    #[test]
    fn preserves_linear_ramp_interior() {
        // Symmetric kernels preserve affine signals away from edges.
        let n = 16;
        let g = Grid::from_vec((0..n).map(|i| i as f32).collect(), &[n]);
        let f = gaussian_filter(&g, 1.0);
        for i in 1..n - 1 {
            assert!((f.data[i] - i as f32).abs() < 1e-5, "i={i}");
        }
    }
}
